//! Golden-output integration test: a fixed-seed synthetic workload (no
//! `artifacts/` required) whose summary metrics are checked against
//! expected values derived *independently* from the workload's own traces
//! — released/scheduled counts, correctness, latency sums, and unit
//! split are all computable by hand for this configuration — plus a
//! bless-style JSON snapshot for full-precision regression coverage.
//!
//! The configuration is chosen so the arithmetic is exact: persistent
//! power (never browns out), zero release jitter (releases at t = 300k ms
//! exactly), EDF with no early exit (every unit of every job runs), and a
//! 30 s horizon → exactly 100 jobs, each executing 3 × 20 ms units
//! back-to-back starting at its release.

use zygarde::coordinator::sched::{ExitPolicy, SchedulerKind};
use zygarde::nvm::{CommitPolicy, NvmModelKind, NvmSpec};
use zygarde::sim::sweep::{run_matrix, HarvesterSpec, ScenarioMatrix, TaskMix};
use zygarde::sim::workload::synthetic_task;

const GOLDEN_SEED: u64 = 0x601D;
const N_TRACES: usize = 40;
const N_JOBS: usize = 100;

fn golden_matrix() -> (zygarde::coordinator::task::TaskSpec, ScenarioMatrix) {
    // 3 units × 20 ms × 2 mJ in 4 fragments; T = 300 ms, D = 600 ms.
    let task = synthetic_task(0, 3, 300.0, 600.0, N_TRACES, GOLDEN_SEED);
    let matrix = ScenarioMatrix::new("golden-small", GOLDEN_SEED)
        .mixes(vec![TaskMix::from_tasks("golden", vec![task.clone()])])
        .harvesters(vec![HarvesterSpec::Persistent { power_mw: 600.0 }])
        .capacitors_mf(vec![50.0])
        .schedulers(vec![SchedulerKind::Edf])
        .exits(vec![ExitPolicy::None])
        .release_jitter(0.0)
        .duration_ms(30_000.0);
    (task, matrix)
}

#[test]
fn golden_summary_matches_first_principles() {
    let (task, matrix) = golden_matrix();
    let report = run_matrix(&matrix, 2);
    assert_eq!(report.n_scenarios, 1);
    let m = &report.cells[0].metrics;

    // Releases at t = 0, 300, …, 29 700: exactly 100 jobs, all of which
    // finish 60 ms after release — far inside D = 600 ms — on a supply
    // that never fails.
    assert_eq!(m.released, N_JOBS as u64);
    assert_eq!(m.scheduled, N_JOBS as u64);
    assert_eq!(m.deadline_missed, 0);
    assert_eq!(m.capture_missed, 0);
    assert_eq!(m.queue_dropped, 0);
    assert_eq!(m.reboots, 1, "persistent supply boots once and stays up");

    // EDF + ExitPolicy::None runs every unit of every job: job k uses
    // trace k mod 40 (the engine cycles traces round-robin).
    assert_eq!(m.mandatory_units + m.optional_units, 3 * N_JOBS as u64);
    assert_eq!(m.fragments, 4 * 3 * N_JOBS as u64);
    assert_eq!(m.refragments, 0);

    // Independent derivations from the trace set -----------------------

    // Final prediction = last unit's prediction (all units execute).
    let expected_correct = (0..N_JOBS)
        .filter(|k| task.traces[k % N_TRACES].units.last().unwrap().correct)
        .count() as u64;
    assert_eq!(m.correct, expected_correct);

    // The mandatory part of job k spans units 0..=exit_unit, so its
    // latency (release → mandatory done) is 20 ms × (exit_unit + 1) and
    // units at indices > exit_unit execute as optional refinements.
    let expected_latency: f64 = (0..N_JOBS)
        .map(|k| 20.0 * (task.traces[k % N_TRACES].exit_unit as f64 + 1.0))
        .sum();
    assert!(
        (m.latency_sum_ms - expected_latency).abs() < 1e-6,
        "latency {} != expected {expected_latency}",
        m.latency_sum_ms
    );
    let expected_mandatory: u64 = (0..N_JOBS)
        .map(|k| task.traces[k % N_TRACES].exit_unit as u64 + 1)
        .sum();
    assert_eq!(m.mandatory_units, expected_mandatory);
    assert_eq!(m.optional_units, 3 * N_JOBS as u64 - expected_mandatory);

    // Sanity on the derived quantities themselves: the synthetic trace
    // generator is deterministic, so these are fixed for GOLDEN_SEED.
    assert!(expected_correct >= (N_JOBS / 2) as u64, "traces mostly correct");
    assert!((m.sim_time_ms - 30_000.0).abs() < 1e-9);

    // NVM accounting under the default (ideal every-fragment) policy:
    // one commit per successful fragment, all free, nothing ever lost.
    assert_eq!(m.commits, 4 * 3 * N_JOBS as u64);
    assert_eq!(m.commit_mj, 0.0);
    assert_eq!(m.lost_fragments, 0);
    assert_eq!(m.restores, 0, "persistent supply never reboots mid-run");
}

/// The golden contract of the NVM subsystem: `EveryFragment` with zero
/// commit cost *is* the blessed golden, bitwise — and a zero-cost
/// `UnitBoundary` run has identical dynamics (free commits disturb
/// neither time nor energy nor RNG), differing only in commit counts:
/// 300 unit commits instead of 1200 fragment commits.
#[test]
fn zero_cost_policies_reproduce_golden_dynamics_bitwise() {
    let (_task, matrix) = golden_matrix();
    let default_json = run_matrix(&matrix, 1).json_string();

    let explicit = matrix.clone().nvms(vec![NvmSpec::ideal()]);
    assert_eq!(
        run_matrix(&explicit, 1).json_string(),
        default_json,
        "explicit zero-cost EveryFragment must be the golden, bitwise"
    );

    let unit_matrix = matrix.clone().nvms(vec![NvmSpec {
        model: NvmModelKind::Ideal,
        policy: CommitPolicy::UnitBoundary,
    }]);
    let unit = run_matrix(&unit_matrix, 1);
    let m = &unit.cells[0].metrics;
    assert_eq!(m.commits, 3 * N_JOBS as u64, "one commit per completed unit");
    assert_eq!(m.commit_mj, 0.0);
    // Same dynamics as the golden cell on every non-NVM counter.
    let golden = run_matrix(&matrix, 1);
    let g = &golden.cells[0].metrics;
    assert_eq!(m.released, g.released);
    assert_eq!(m.scheduled, g.scheduled);
    assert_eq!(m.correct, g.correct);
    assert_eq!(m.fragments, g.fragments);
    assert_eq!(m.latency_sum_ms, g.latency_sum_ms);
    assert_eq!(m.harvested_mj, g.harvested_mj);
    assert_eq!(m.consumed_mj, g.consumed_mj);
}

/// `cargo test --features slow-reference` leg: the naive reference
/// stepper (the baseline the differential-exactness suite compares the
/// optimized engine against) is pinned to the optimized engine on the
/// golden matrix. The pin to the blessed snapshot is transitive —
/// `golden_json_snapshot_is_stable` holds the optimized engine to the
/// snapshot, this test holds the reference stepper to the optimized
/// engine — so the snapshot file is deliberately not read here (the
/// sibling test may be blessing it concurrently in the same binary).
#[cfg(feature = "slow-reference")]
#[test]
fn reference_stepper_reproduces_the_golden_sweep() {
    use zygarde::sim::sweep::run_matrix_reference;

    let (_task, matrix) = golden_matrix();
    assert_eq!(
        run_matrix_reference(&matrix, 1).json_string(),
        run_matrix(&matrix, 1).json_string(),
        "reference stepper diverged from the optimized engine on the golden matrix"
    );
}

/// Full-precision snapshot (bless pattern): the first run writes
/// `rust/tests/golden/sweep_small.json`; later runs must reproduce it
/// byte-for-byte. Delete the file (or set UPDATE_GOLDEN=1) to re-bless
/// after an intentional engine change — and say so in the commit.
#[test]
fn golden_json_snapshot_is_stable() {
    let (_task, matrix) = golden_matrix();
    let json = run_matrix(&matrix, 1).json_string();

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/sweep_small.json");
    let bless = std::env::var("UPDATE_GOLDEN").as_deref() == Ok("1") || !path.exists();
    if bless {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &json).unwrap();
        eprintln!("blessed golden snapshot at {}", path.display());
        return;
    }
    let recorded = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        recorded, json,
        "sweep output drifted from the blessed snapshot at {} — if the \
         engine change is intentional, re-bless with UPDATE_GOLDEN=1",
        path.display()
    );
}
