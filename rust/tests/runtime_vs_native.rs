//! Integration: the PJRT execution of the AOT per-unit HLO artifacts
//! (which embed the Pallas kernels) must agree element-wise with the
//! pure-Rust native forward — this closes the loop across all three
//! layers: Pallas kernel (L1) → jax unit (L2) → rust runtime (L3).
//!
//! Gated on the `pjrt` feature: without it `runtime::Runtime` is a stub
//! and there is nothing to cross-check.
#![cfg(feature = "pjrt")]

use zygarde::dnn::kmeans::Scratch;
use zygarde::dnn::network::Network;
use zygarde::runtime::Runtime;

fn ready(ds: &str) -> bool {
    zygarde::artifacts_root().join(ds).join("unit0.hlo.txt").exists()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() / denom < tol,
            "{what}[{i}]: pjrt={x} native={y}"
        );
    }
}

#[test]
fn pjrt_units_match_native_forward() {
    // One shared CPU client across datasets (PJRT clients are heavy).
    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => panic!("PJRT CPU client unavailable: {e}"),
    };
    let mut checked = 0;
    for ds in ["mnist", "esc10", "cifar100", "vww", "sign", "shape"] {
        if !ready(ds) {
            continue;
        }
        let dir = zygarde::artifacts_root().join(ds);
        let net = Network::load(&dir).unwrap();
        rt.load_network(&dir, &net.meta).unwrap();
        let mut scratch = Scratch::default();
        // A handful of samples through every unit.
        for s in 0..3.min(net.test.len()) {
            let mut act = net.test.sample(s).to_vec();
            for li in 0..net.meta.n_layers {
                let (pjrt_act, pjrt_dists) = rt
                    .execute_unit(ds, li, &act, &net.classifiers[li].centroids)
                    .unwrap();
                let (nat_act, _res) = net.run_unit_native(li, &act, &mut scratch);
                let mut nat_dists = vec![0f32; net.classifiers[li].k];
                let mut feat = Vec::new();
                net.classifiers[li].gather(&nat_act, &mut feat);
                net.classifiers[li].distances(&feat, &mut nat_dists);
                assert_close(&pjrt_act, &nat_act, 2e-3, &format!("{ds} unit{li} act"));
                assert_close(&pjrt_dists, &nat_dists, 2e-3, &format!("{ds} unit{li} dists"));
                act = nat_act;
                checked += 1;
            }
        }
    }
    assert!(checked > 0, "no artifacts found — run `make artifacts`");
}

#[test]
fn pjrt_early_exit_agrees_with_native() {
    if !ready("mnist") {
        return;
    }
    let dir = zygarde::artifacts_root().join("mnist");
    let net = Network::load(&dir).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    rt.load_network(&dir, &net.meta).unwrap();
    let mut scratch = Scratch::default();
    let mut agree = 0usize;
    let n = 40.min(net.test.len());
    for i in 0..n {
        // PJRT path with utility exits.
        let mut act = net.test.sample(i).to_vec();
        let mut pjrt = (0usize, 0i32);
        for li in 0..net.meta.n_layers {
            let (next, dists) = rt
                .execute_unit("mnist", li, &act, &net.classifiers[li].centroids)
                .unwrap();
            let res = net.classifiers[li].classify_from_dists(&dists);
            pjrt = (li, res.pred);
            if res.exit {
                break;
            }
            act = next;
        }
        let native = net.infer_native(net.test.sample(i), &mut scratch);
        if pjrt == native {
            agree += 1;
        }
    }
    // f32 reassociation can flip a razor-thin utility test on rare inputs.
    assert!(agree >= n - 1, "agreement {agree}/{n}");
}

#[test]
fn runtime_rejects_bad_inputs() {
    if !ready("mnist") {
        return;
    }
    let dir = zygarde::artifacts_root().join("mnist");
    let net = Network::load(&dir).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    rt.load_unit(&dir, &net.meta, 0).unwrap();
    // Wrong activation length.
    let bad = vec![0f32; 7];
    assert!(rt.execute_unit("mnist", 0, &bad, &net.classifiers[0].centroids).is_err());
    // Wrong centroid length.
    let x = net.test.sample(0).to_vec();
    assert!(rt.execute_unit("mnist", 0, &x, &[0.0, 1.0]).is_err());
    // Unknown unit.
    assert!(rt.execute_unit("mnist", 99, &x, &net.classifiers[0].centroids).is_err());
}
