//! Metrics-registry determinism suite: the campaign profile
//! (`zygarde profile`, `sim::sweep::profile`) must be a pure function of
//! the matrix — byte-identical at any thread count, and reassembled
//! byte-identically from any shard split merged in any order. That holds
//! because per-cell registries are themselves pure functions of their
//! scenario and [`Registry::merge`] is order-independent integer
//! addition; this suite pins both legs plus the passivity contract (a
//! profiled sweep's report bytes equal an unprofiled one's).

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::sim::sweep::{
    profile_matrix, run_matrix, run_scenario_profiled, run_scenarios_profiled, HarvesterSpec,
    ProfileReport, ScenarioMatrix, ShardSpec, SweepReport, AXES,
};
use zygarde::telemetry::registry::Registry;

/// 16 cells across two harvesters, two schedulers, two capacitor sizes,
/// and two reps — enough that 8 threads and 7-way shards all get real
/// work, small enough to stay quick in debug builds.
fn matrix() -> ScenarioMatrix {
    ScenarioMatrix::new("registry-det", 0xDE7)
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 600.0 },
            HarvesterSpec::Piezo { eta: 0.3 },
        ])
        .capacitors_mf(vec![10.0, 50.0])
        .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
        .reps(2)
        .duration_ms(5_000.0)
}

#[test]
fn profile_json_is_byte_identical_at_any_thread_count() {
    let m = matrix();
    let reference = profile_matrix(&m, 1, "harvester").unwrap().json_string();
    for threads in [2usize, 4, 8] {
        let got = profile_matrix(&m, threads, "harvester").unwrap().json_string();
        assert_eq!(got, reference, "{threads} threads changed the profile bytes");
    }
}

#[test]
fn per_cell_registries_are_pure_functions_of_the_scenario() {
    let m = matrix();
    let scenarios = m.expand();
    for sc in scenarios.iter().step_by(5) {
        let (c1, r1) = run_scenario_profiled(sc);
        let (c2, r2) = run_scenario_profiled(sc);
        assert_eq!(c1.label, c2.label);
        assert_eq!(
            r1.snapshot_string(),
            r2.snapshot_string(),
            "registry for {} is not reproducible",
            c1.label
        );
        assert!(!r1.is_zero(), "{} recorded nothing", c1.label);
    }
}

/// Run each shard of a {1,3,7}-way split as its own profiled execution
/// (what `zygarde profile` on that shard would do), then reassemble the
/// shard outputs in forward, reverse, and interleaved order — every
/// grouping must reproduce the whole-matrix profile byte for byte.
#[test]
fn shard_splits_reassemble_byte_identically_in_any_merge_order() {
    let m = matrix();
    let reference = profile_matrix(&m, 2, "sched").unwrap().json_string();
    let scenarios = m.expand();
    for shard_count in [1usize, 3, 7] {
        let shards: Vec<Vec<(String, Registry)>> = (0..shard_count)
            .map(|shard_index| {
                let spec = ShardSpec { shard_index, shard_count };
                let owned: Vec<_> =
                    scenarios.iter().filter(|sc| spec.owns(sc.index)).cloned().collect();
                run_scenarios_profiled(&owned, 1)
                    .into_iter()
                    .map(|(c, r)| (c.label, r))
                    .collect()
            })
            .collect();
        let assemble = |order: Vec<usize>| {
            ProfileReport::from_cells(
                &m.name,
                m.seed,
                "sched",
                order.into_iter().flat_map(|i| shards[i].iter().cloned()),
            )
            .unwrap()
            .json_string()
        };
        let fwd = assemble((0..shard_count).collect());
        let rev = assemble((0..shard_count).rev().collect());
        let interleaved = {
            // Round-robin one cell at a time across shards — the order a
            // streaming merge would see them in.
            let mut cursors = vec![0usize; shard_count];
            let mut cells = Vec::new();
            loop {
                let mut any = false;
                for (s, cur) in cursors.iter_mut().enumerate() {
                    if let Some(cell) = shards[s].get(*cur) {
                        cells.push(cell.clone());
                        *cur += 1;
                        any = true;
                    }
                }
                if !any {
                    break;
                }
            }
            ProfileReport::from_cells(&m.name, m.seed, "sched", cells).unwrap().json_string()
        };
        assert_eq!(fwd, reference, "{shard_count}-way split diverged");
        assert_eq!(rev, reference, "{shard_count}-way reverse merge diverged");
        assert_eq!(interleaved, reference, "{shard_count}-way interleave diverged");
    }
}

/// Passivity: attaching a registry to every engine must not change one
/// byte of the sweep report.
#[test]
fn profiled_sweep_report_is_byte_identical_to_plain() {
    let m = matrix();
    let plain = run_matrix(&m, 2).json_string();
    let profiled = run_scenarios_profiled(&m.expand(), 2);
    let report = SweepReport::new(
        &m.name,
        m.seed,
        profiled.into_iter().map(|(c, _)| c).collect(),
    );
    assert_eq!(report.json_string(), plain, "the registry is not a passive observer");
}

/// The grouped totals are conserved: whatever axis the cells are grouped
/// by, the campaign-total registry is the same bytes, and group counts
/// sum to the cell count.
#[test]
fn grouping_axis_never_changes_the_campaign_total() {
    let m = matrix();
    let reference = profile_matrix(&m, 2, AXES[0]).unwrap();
    for axis in &AXES[1..] {
        let p = profile_matrix(&m, 2, axis).unwrap();
        assert_eq!(
            p.total.snapshot_string(),
            reference.total.snapshot_string(),
            "axis {axis} changed the total"
        );
        assert_eq!(p.n_cells, reference.n_cells);
        assert_eq!(p.groups.iter().map(|g| g.n_cells).sum::<usize>(), p.n_cells);
    }
}
