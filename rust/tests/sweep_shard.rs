//! Shard-merge contract tests: for ANY partition of a matrix into strided
//! shards — any shard count, any per-shard thread count, any shard-file
//! order — `sweep::shard::merge` reproduces the single-process
//! `SweepReport::json_string` **byte-for-byte**; and shards that were not
//! cut from the same matrix refuse to merge. The CI shard-matrix job
//! proves the same property end-to-end through the CLI
//! (`zygarde sweep --shard i/3` × 3 → `zygarde merge` → `diff`).

use zygarde::coordinator::sched::{ExitPolicy, SchedulerKind};
use zygarde::energy::harvester::HarvesterKind;
use zygarde::nvm::NvmSpec;
use zygarde::sim::sweep::{
    fingerprint, merge, run_matrix, run_shard, FaultPlan, HarvesterSpec, MergeError,
    PartialReport, ScenarioMatrix, SeedPolicy, ShardSpec, TaskMix,
};
use zygarde::sim::workload::synthetic_task;
use zygarde::util::prop::{forall, Config, Size};
use zygarde::util::rng::Pcg32;

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

/// The golden-snapshot matrix from `rust/tests/sweep_golden.rs` — the
/// acceptance criterion demands that any shard partition of it merges
/// back to the byte-identical single-process report.
fn golden_matrix() -> ScenarioMatrix {
    let task = synthetic_task(0, 3, 300.0, 600.0, 40, 0x601D);
    ScenarioMatrix::new("golden-small", 0x601D)
        .mixes(vec![TaskMix::from_tasks("golden", vec![task])])
        .harvesters(vec![HarvesterSpec::Persistent { power_mw: 600.0 }])
        .capacitors_mf(vec![50.0])
        .schedulers(vec![SchedulerKind::Edf])
        .exits(vec![ExitPolicy::None])
        .release_jitter(0.0)
        .duration_ms(30_000.0)
}

/// A multi-dimensional matrix big enough that every shard count in
/// `SHARD_COUNTS` produces non-trivial shards.
fn grid_matrix(seed: u64) -> ScenarioMatrix {
    ScenarioMatrix::new("shard-grid", seed)
        .mixes(vec![
            TaskMix::synthetic("uni", 1, 3, seed ^ 0x1),
            TaskMix::synthetic("duo", 2, 2, seed ^ 0x2),
        ])
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 500.0 },
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 110.0,
                q: 0.88,
                duty: 0.55,
                eta: 0.5,
            },
        ])
        .capacitors_mf(vec![5.0, 50.0])
        .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
        .faults(vec![
            FaultPlan::none(),
            FaultPlan::none().with_brownouts(1_200.0, 250.0, 50.0),
        ])
        .nvms(vec![NvmSpec::ideal(), NvmSpec::fram_jit()])
        .reps(1)
        .duration_ms(4_000.0)
}

/// Round-trip every shard through its JSON form, as the CLI does, then
/// merge — so the test covers serialization, not just in-memory merging.
fn merge_via_json(parts: &[PartialReport]) -> String {
    let rt: Vec<PartialReport> = parts
        .iter()
        .map(|p| PartialReport::parse(&p.json_string()).expect("shard json round trip"))
        .collect();
    merge(&rt).expect("merge").json_string()
}

#[test]
fn golden_matrix_merges_byte_identically_for_any_shard_count() {
    let m = golden_matrix();
    let reference = run_matrix(&m, 1).json_string();
    for &count in &SHARD_COUNTS {
        let parts: Vec<PartialReport> = (0..count)
            .map(|i| run_shard(&m, ShardSpec::new(i, count).unwrap(), 2))
            .collect();
        assert_eq!(
            merge_via_json(&parts),
            reference,
            "{count}-way shard merge of the golden matrix diverged"
        );
    }
}

#[test]
fn grid_merges_byte_identically_across_shard_and_thread_counts() {
    let m = grid_matrix(0x5AD);
    assert_eq!(m.len(), 64);
    let reference = run_matrix(&m, 4).json_string();
    for (k, &count) in SHARD_COUNTS.iter().enumerate() {
        // Vary per-shard thread counts so shards finish out of order.
        let parts: Vec<PartialReport> = (0..count)
            .map(|i| run_shard(&m, ShardSpec::new(i, count).unwrap(), 1 + (i + k) % 4))
            .collect();
        assert_eq!(
            merge_via_json(&parts),
            reference,
            "{count}-way shard merge diverged from the 4-thread single process"
        );
    }
}

#[test]
fn shard_file_order_does_not_matter() {
    let m = grid_matrix(0x0DD);
    let reference = run_matrix(&m, 2).json_string();
    let mut parts: Vec<PartialReport> =
        (0..7).map(|i| run_shard(&m, ShardSpec::new(i, 7).unwrap(), 2)).collect();
    let mut rng = Pcg32::seeded(99);
    for round in 0..5 {
        rng.shuffle(&mut parts);
        assert_eq!(
            merge_via_json(&parts),
            reference,
            "shuffled merge round {round} diverged"
        );
    }
}

#[test]
fn mismatched_fingerprints_are_an_error() {
    // Same shape, different matrix seed → different engine seeds.
    let a = run_shard(&grid_matrix(1), ShardSpec::new(0, 2).unwrap(), 1);
    let b = run_shard(&grid_matrix(2), ShardSpec::new(1, 2).unwrap(), 1);
    assert!(matches!(
        merge(&[a.clone(), b]),
        Err(MergeError::FingerprintMismatch { .. })
    ));
    // Same seed, different axis (duration) → different fingerprint too.
    let c = run_shard(&grid_matrix(1).duration_ms(5_000.0), ShardSpec::new(1, 2).unwrap(), 1);
    assert!(matches!(
        merge(&[a, c]),
        Err(MergeError::FingerprintMismatch { .. })
    ));
}

#[test]
fn paired_seed_matrices_shard_identically_too() {
    // PairedEnvironment seeds derive from dimension indices, not the
    // scenario stream — sharding must not disturb them either.
    let m = grid_matrix(0x7A1).seed_policy(SeedPolicy::PairedEnvironment);
    let reference = run_matrix(&m, 3).json_string();
    let parts: Vec<PartialReport> =
        (0..3).map(|i| run_shard(&m, ShardSpec::new(i, 3).unwrap(), 2)).collect();
    assert_eq!(merge_via_json(&parts), reference);
}

/// Property: a randomly generated matrix, partitioned into a random shard
/// count and merged from JSON in random order, reproduces the
/// single-process report byte-for-byte.
#[test]
fn random_matrices_merge_byte_identically() {
    let cfg = Config { iters: 10, ..Default::default() };
    forall(
        "shard-merge-byte-identical",
        cfg,
        |rng: &mut Pcg32, size: Size| {
            let seed = rng.next_u64();
            let n_sched = 1 + rng.below(2) as usize;
            let scheds = [SchedulerKind::Zygarde, SchedulerKind::EdfMandatory];
            let m = ScenarioMatrix::new("prop-shard", seed)
                .mixes(vec![TaskMix::synthetic("m", 1 + rng.below(2) as usize, 2, seed)])
                .harvesters(vec![
                    HarvesterSpec::Persistent { power_mw: 300.0 + rng.f64() * 300.0 },
                    HarvesterSpec::Markov {
                        kind: HarvesterKind::Solar,
                        on_power_mw: 150.0 + rng.f64() * 200.0,
                        q: 0.85,
                        duty: 0.5,
                        eta: 0.55,
                    },
                ])
                .capacitors_mf(vec![5.0, 50.0])
                .schedulers(scheds[..n_sched].to_vec())
                .reps(1 + rng.below(3))
                .duration_ms(1_500.0 + 500.0 * size.0.min(4) as f64);
            let count = 1 + rng.below(7) as usize;
            let order_seed = rng.next_u64();
            (m, count, order_seed)
        },
        |(m, count, order_seed)| {
            let reference = run_matrix(m, 2).json_string();
            let mut parts: Vec<PartialReport> = (0..*count)
                .map(|i| run_shard(m, ShardSpec::new(i, *count).unwrap(), 1 + i % 3))
                .collect();
            Pcg32::seeded(*order_seed).shuffle(&mut parts);
            let merged = merge_via_json(&parts);
            if merged != reference {
                return Err(format!(
                    "{count}-way merge diverged for matrix seed {} ({} cells)",
                    m.seed,
                    m.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn shard_counts_beyond_cells_still_merge() {
    // More shards than scenarios: trailing shards are empty but still
    // required members of the partition.
    let m = ScenarioMatrix::new("tiny", 3)
        .mixes(vec![TaskMix::synthetic("m", 1, 2, 3)])
        .reps(2)
        .duration_ms(2_000.0);
    assert_eq!(m.len(), 2);
    let reference = run_matrix(&m, 1).json_string();
    let parts: Vec<PartialReport> =
        (0..5).map(|i| run_shard(&m, ShardSpec::new(i, 5).unwrap(), 1)).collect();
    assert!(parts[3].cells.is_empty() && parts[4].cells.is_empty());
    assert_eq!(merge_via_json(&parts), reference);
    // Dropping an *empty* shard still fails the partition check: merge
    // cannot know it was empty without its fingerprinted report.
    assert!(matches!(
        merge(&parts[..4]),
        Err(MergeError::MissingShard(4))
    ));
}

/// A corrupt `index` in a shard file must be rejected when the cell is
/// parsed — a plain `as usize` cast would silently saturate NaN and
/// negatives onto cell 0 and truncate fractions, and the merge would then
/// mis-order cells with no diagnostic.
#[test]
fn corrupt_cell_index_is_rejected_at_parse_time() {
    use zygarde::util::json::Value;
    let m = golden_matrix();
    let part = run_shard(&m, ShardSpec::new(0, 1).unwrap(), 1);
    assert!(!part.cells.is_empty());
    let good = part.json_string();
    // The honest round trip keeps working.
    assert!(PartialReport::parse(&good).is_ok());
    let with_index = |idx_json: Value| {
        let mut v = Value::parse(&good).unwrap();
        if let Value::Obj(top) = &mut v {
            if let Some(Value::Arr(cells)) = top.get_mut("cells") {
                if let Value::Obj(cell) = &mut cells[0] {
                    cell.insert("index".to_string(), idx_json);
                }
            }
        }
        v
    };
    for (name, bad) in [
        ("NaN", Value::Num(f64::NAN)),
        ("negative", Value::Num(-1.0)),
        ("negative fraction", Value::Num(-0.75)),
        ("fractional", Value::Num(1.5)),
        ("overflow", Value::Num(1e300)),
        ("non-numeric", Value::Str("0".to_string())),
    ] {
        assert!(
            PartialReport::from_json(&with_index(bad)).is_err(),
            "{name} `index` must be rejected"
        );
    }
    // An exact integer written the canonical way still parses.
    let ok = PartialReport::from_json(&with_index(Value::Num(0.0)));
    assert!(ok.is_ok(), "exact integer index must still parse");
}

#[test]
fn fingerprint_matches_cli_contract() {
    // The fingerprint is what `zygarde merge` trusts across hosts: equal
    // for identical matrices, different when any axis moves.
    let fp = fingerprint(&grid_matrix(5));
    assert_eq!(fp, fingerprint(&grid_matrix(5)));
    assert_eq!(fp.n_scenarios, 64);
    assert_ne!(fp, fingerprint(&grid_matrix(6)));
    assert_ne!(
        fp.axes_hash,
        fingerprint(&grid_matrix(5).capacitors_mf(vec![50.0])).axes_hash
    );
}
