//! Property suite for the streaming serve dispatcher: the merged report
//! must be **byte-identical** to the single-process `SweepReport` (and to
//! the static `shard::merge` path) for arbitrary lease sizes, shuffled
//! completion orders, stolen leases, killed-and-reissued workers, and
//! stalled-then-late workers — with the merger's memory bounded by the
//! spill-run size, not the matrix size.
//!
//! The dispatcher core is a pure state machine, so the suite drives it
//! directly: simulated workers hold real computed cells and a scripted
//! scheduler delivers their messages in seeded-random interleavings.
//! The real-IO path (pipes, processes, `kill -9`) is covered by the
//! end-to-end test below and by the CI serve job.

use std::collections::VecDeque;
use std::path::PathBuf;

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::sim::sweep::serve::{DispatcherCore, Msg, Out, SpillMerger, WorkerId};
use zygarde::sim::sweep::shard::{self, fingerprint, run_shard, ShardSpec};
use zygarde::sim::sweep::{
    run_matrix, run_scenario, FaultPlan, HarvesterSpec, Scenario, ScenarioMatrix, TaskMix,
};
use zygarde::util::json::Value;
use zygarde::util::rng::Pcg32;

fn matrix(seed: u64) -> ScenarioMatrix {
    ScenarioMatrix::new("serve-test", seed)
        .mixes(vec![TaskMix::synthetic("m", 1, 3, seed ^ 0x5E)])
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 600.0 },
            HarvesterSpec::Markov {
                kind: zygarde::energy::harvester::HarvesterKind::Rf,
                on_power_mw: 120.0,
                q: 0.9,
                duty: 0.6,
                eta: 0.51,
            },
        ])
        .capacitors_mf(vec![5.0, 50.0])
        .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
        .faults(vec![FaultPlan::none(), FaultPlan::none().with_brownouts(900.0, 200.0, 50.0)])
        .reps(2)
        .duration_ms(1_200.0)
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("zygarde_serve_test_{tag}_{}", std::process::id()))
}

/// A simulated worker: computes leased cells lazily (real `run_scenario`
/// results) and queues protocol messages the interleaver delivers later.
struct SimWorker {
    id: WorkerId,
    outbox: VecDeque<Msg>,
    dead: bool,
}

/// Drive a full serve session against the core with `n_workers` simulated
/// workers, seeded-random batch sizes, and seeded-random interleaving of
/// message delivery. Optionally kill one worker mid-run. Returns the
/// merged report bytes plus the core (for stat assertions).
fn drive(
    m: &ScenarioMatrix,
    n_workers: usize,
    lease_size: usize,
    rng_seed: u64,
    kill_one: bool,
    spill_limit: usize,
    tag: &str,
) -> (Vec<u8>, DispatcherCore, usize, usize) {
    let scenarios: Vec<Scenario> = m.expand();
    let fp = fingerprint(m);
    let n = fp.n_scenarios;
    let mut core = DispatcherCore::new(&m.name, Value::Null, fp.clone(), lease_size, 0);
    let mut merger = SpillMerger::new(temp_dir(tag), spill_limit).unwrap();
    let mut rng = Pcg32::new(rng_seed, 0xD15);
    let mut workers: Vec<SimWorker> = Vec::new();
    let mut done = false;
    let mut killed = false;

    // Dispatcher->worker messages apply immediately (sends are ordered
    // per worker anyway); worker->dispatcher messages go through each
    // worker's outbox and are delivered one at a time from a randomly
    // chosen worker — the shuffled completion order.
    let mut inflight: Vec<Out> = Vec::new();
    for w in 0..n_workers {
        workers.push(SimWorker { id: w, outbox: VecDeque::new(), dead: false });
        inflight.extend(core.on_connect(w));
    }

    let mut now = 0u64;
    while !done {
        now += 1;
        // Apply every pending dispatcher effect.
        let outs = std::mem::take(&mut inflight);
        for o in outs {
            match o {
                Out::Send(w, msg) => {
                    let worker = &mut workers[w];
                    if worker.dead {
                        continue;
                    }
                    match msg {
                        Msg::Matrix { .. } => {
                            worker.outbox.push_back(Msg::Ready { fingerprint: fp.clone() });
                        }
                        Msg::Lease { id, start, end } => {
                            // Compute the lease now, stream it in random
                            // batch sizes (1..=4 cells per message).
                            let mut at = start;
                            while at < end {
                                let stop = (at + 1 + rng.below(4) as usize).min(end);
                                let cells = scenarios[at..stop]
                                    .iter()
                                    .map(run_scenario)
                                    .collect::<Vec<_>>();
                                worker.outbox.push_back(Msg::Cells { lease: id, cells });
                                at = stop;
                            }
                            worker.outbox.push_back(Msg::LeaseDone { lease: id });
                        }
                        Msg::Shutdown => worker.outbox.clear(),
                        other => panic!("unexpected dispatcher send {other:?}"),
                    }
                }
                Out::Ingest(cell) => merger.push(cell).unwrap(),
                Out::Done => done = true,
                Out::Kick(w) => workers[w].dead = true,
            }
        }
        if done {
            break;
        }
        // Mid-run kill: once at least a quarter of the cells are in,
        // drop a worker that still holds undelivered cell results —
        // exactly the data loss a kill -9 causes (its lease tail must
        // then be reissued elsewhere).
        if kill_one && !killed && core.cells_received() >= n / 4 {
            let victim = (0..workers.len())
                .filter(|&w| {
                    !workers[w].dead
                        && workers[w]
                            .outbox
                            .iter()
                            .any(|m| matches!(m, Msg::Cells { .. }))
                })
                .max_by_key(|&w| workers[w].outbox.len());
            if let Some(victim) = victim {
                workers[victim].dead = true;
                workers[victim].outbox.clear();
                inflight.extend(core.on_disconnect(victim, now));
                killed = true;
                continue;
            }
        }
        // Deliver one queued message from a random live worker.
        let with_mail: Vec<usize> = workers
            .iter()
            .filter(|w| !w.dead && !w.outbox.is_empty())
            .map(|w| w.id)
            .collect();
        if with_mail.is_empty() {
            // Nothing in flight: let the tick re-grant (idle workers
            // after a death pick the requeued ranges up here).
            inflight.extend(core.on_tick(now));
            assert!(
                !inflight.is_empty() || done,
                "dispatcher stalled with {}/{n} cells",
                core.cells_received()
            );
            continue;
        }
        let pick = with_mail[rng.below(with_mail.len() as u64) as usize];
        let msg = workers[pick].outbox.pop_front().unwrap();
        inflight.extend(core.on_message(pick, msg, now));
    }

    let runs = merger.runs_spilled();
    let peak = merger.peak_buffered();
    let mut bytes = Vec::new();
    merger.finalize(&m.name, m.seed, n, &mut bytes).unwrap();
    (bytes, core, runs, peak)
}

#[test]
fn random_lease_sizes_and_interleavings_are_byte_identical() {
    let m = matrix(0xA11CE);
    let want = run_matrix(&m, 2).json_string();
    let mut rng = Pcg32::new(0xC0FFEE, 1);
    for trial in 0..6u64 {
        let workers = 1 + (rng.below(4) as usize);
        let lease = 1 + (rng.below(9) as usize);
        let (bytes, core, _, _) = drive(
            &m,
            workers,
            lease,
            0x5EED ^ trial,
            false,
            1_000_000,
            &format!("interleave{trial}"),
        );
        assert_eq!(
            String::from_utf8(bytes).unwrap(),
            want,
            "trial {trial}: {workers} workers, lease {lease}"
        );
        assert_eq!(core.cells_received(), m.len());
    }
}

#[test]
fn killed_worker_reissues_and_stays_byte_identical() {
    let m = matrix(0xB0B5);
    let want = run_matrix(&m, 2).json_string();
    for trial in 0..4u64 {
        let (bytes, core, _, _) =
            drive(&m, 3, 3, 0x9999 + trial, true, 1_000_000, &format!("kill{trial}"));
        assert_eq!(String::from_utf8(bytes).unwrap(), want, "trial {trial}");
        assert!(
            core.stats.reissues >= 1 || core.stats.steals >= 1,
            "the kill should force a reissue or steal (trial {trial}): {:?}",
            core.stats
        );
    }
}

#[test]
fn dispatcher_report_matches_static_shard_merge_byte_for_byte() {
    let m = matrix(0x7777);
    // Static path: 3 strided shards, merged.
    let parts: Vec<_> =
        (0..3).map(|i| run_shard(&m, ShardSpec::new(i, 3).unwrap(), 1)).collect();
    let static_merge = shard::merge(&parts).unwrap().json_string();
    // Dynamic path: 2 workers, small leases, shuffled delivery.
    let (bytes, ..) = drive(&m, 2, 2, 0xABAB, false, 1_000_000, "vs-shard");
    assert_eq!(String::from_utf8(bytes).unwrap(), static_merge);
}

#[test]
fn out_of_core_merge_bounds_memory_and_matches_bytes() {
    let m = matrix(0x00C);
    let want = run_matrix(&m, 2).json_string();
    let limit = 5;
    let (bytes, _, runs, peak) = drive(&m, 3, 2, 0xF00D, false, limit, "oom");
    assert!(peak <= limit, "merger buffered {peak} cells, limit {limit}");
    assert!(
        runs >= m.len() / limit - 1,
        "a {limit}-cell limit over {} cells must spill (got {runs} runs)",
        m.len()
    );
    assert_eq!(String::from_utf8(bytes).unwrap(), want);
}

#[test]
fn stalled_lease_times_out_reissues_and_dedups_late_results() {
    let m = matrix(0x51AB);
    let scenarios = m.expand();
    let fp = fingerprint(&m);
    let n = fp.n_scenarios;
    // Tiny timeout; lease_size covers the whole matrix so worker 0 owns
    // everything, stalls, and worker 1 must recover all of it.
    let mut core = DispatcherCore::new(&m.name, Value::Null, fp.clone(), n, 10);
    let mut merger = SpillMerger::new(temp_dir("timeout"), 1_000_000).unwrap();
    let mut outs = core.on_connect(0);
    outs.extend(core.on_message(0, Msg::Ready { fingerprint: fp.clone() }, 0));
    let lease0 = outs
        .iter()
        .find_map(|o| match o {
            Out::Send(0, Msg::Lease { id, .. }) => Some(*id),
            _ => None,
        })
        .expect("worker 0 got a lease");
    // Worker 0 goes silent. Time passes; the lease expires.
    assert!(core.on_tick(100).is_empty());
    assert_eq!(core.stats.reissues, 1);
    // Worker 1 joins, gets the reissued whole range, and delivers it.
    let mut outs = core.on_connect(1);
    outs.extend(core.on_message(1, Msg::Ready { fingerprint: fp.clone() }, 101));
    let (l1, s1, e1) = outs
        .iter()
        .find_map(|o| match o {
            Out::Send(1, Msg::Lease { id, start, end }) => Some((*id, *start, *end)),
            _ => None,
        })
        .expect("worker 1 got the reissued lease");
    assert_eq!((s1, e1), (0, n));
    let cells: Vec<_> = scenarios.iter().map(run_scenario).collect();
    let outs = core.on_message(1, Msg::Cells { lease: l1, cells: cells.clone() }, 102);
    for o in &outs {
        if let Out::Ingest(c) = o {
            merger.push(c.clone()).unwrap();
        }
    }
    assert!(core.is_done());
    // The stalled worker wakes up and floods its stale lease: every cell
    // is a duplicate, none reach the merger.
    let outs = core.on_message(0, Msg::Cells { lease: lease0, cells }, 103);
    assert!(
        !outs.iter().any(|o| matches!(o, Out::Ingest(_))),
        "late duplicates must not double-ingest"
    );
    assert_eq!(core.stats.duplicates as usize, n);
    let mut bytes = Vec::new();
    merger.finalize(&m.name, m.seed, n, &mut bytes).unwrap();
    assert_eq!(String::from_utf8(bytes).unwrap(), run_matrix(&m, 1).json_string());
}

#[test]
fn foreign_fingerprint_is_rejected_at_admission() {
    let m = matrix(0xF00);
    let fp = fingerprint(&m);
    let mut alien = fp.clone();
    alien.axes_hash ^= 0xDEAD;
    let mut core = DispatcherCore::new(&m.name, Value::Null, fp, 4, 0);
    core.on_connect(0);
    let outs = core.on_message(0, Msg::Ready { fingerprint: alien }, 0);
    assert!(
        matches!(outs[..], [Out::Send(0, Msg::Error { .. }), Out::Kick(0)]),
        "admission must fail closed: {outs:?}"
    );
}

/// End-to-end over real pipes and processes: `zygarde serve --workers 2`
/// spawns real `zygarde work --connect -` children; the written report
/// must be byte-identical to the in-process single-thread run.
#[test]
fn serve_cli_over_pipes_matches_single_process_bytes() {
    let exe = env!("CARGO_BIN_EXE_zygarde");
    let out = std::env::temp_dir()
        .join(format!("zygarde_serve_e2e_{}.json", std::process::id()));
    let status = std::process::Command::new(exe)
        .args([
            "serve",
            "--matrix",
            "synthetic",
            "--seed",
            "23",
            "--reps",
            "1",
            "--duration-ms",
            "1500",
            "--workers",
            "2",
            "--lease",
            "3",
            "--spill-cells",
            "6",
            "--quiet=true",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning zygarde serve");
    assert!(status.success(), "serve exited with {status}");
    let got = std::fs::read_to_string(&out).expect("serve wrote the report");
    let _ = std::fs::remove_file(&out);
    let m = zygarde::exp::sweep_cli::synthetic_matrix(23, 1, 1_500.0);
    assert_eq!(got, run_matrix(&m, 1).json_string());
}
