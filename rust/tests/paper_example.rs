//! The paper's own worked example (§2.2, Tables 1–2, Fig. 3) as an
//! executable test: two jobs of one task; J_{1,1} has 1 mandatory + 3
//! optional units, J_{1,2} has 2 mandatory + 2 optional; the scheduler's
//! decision at each timestep must match Table 2's reasoning.

use std::sync::Arc;

use zygarde::coordinator::priority::{EnergyView, PriorityParams};
use zygarde::coordinator::sched::{Scheduler, SchedulerKind};
use zygarde::coordinator::task::{Job, TaskSpec};
use zygarde::dnn::trace::{SampleTrace, UnitOutcome};

fn trace(exit_unit: usize, n: usize) -> SampleTrace {
    SampleTrace {
        label: 0,
        units: (0..n)
            .map(|i| UnitOutcome {
                gap: if i >= exit_unit { 8.0 } else { 1.0 },
                pred: 0,
                exit: i == exit_unit,
                correct: true,
            })
            .collect(),
        exit_unit,
        oracle_unit: Some(exit_unit),
    }
}

fn spec() -> TaskSpec {
    TaskSpec {
        id: 0,
        name: "tau1".into(),
        period_ms: 2.0,
        deadline_ms: 6.0, // relative deadline: t1+6 = t7 in paper units
        unit_time_ms: vec![1.0; 4],
        unit_energy_mj: vec![1.0; 4],
        unit_fragments: vec![1; 4],
        release_energy_mj: 0.0,
        unit_state_bytes: vec![2048; 4],
        traces: Arc::new(vec![trace(0, 4), trace(1, 4)]),
        imprecise: true,
    }
}

const PARAMS: PriorityParams = PriorityParams { alpha: 1.0 / 6.0, beta: 1.0 / 8.0 };

fn plentiful() -> EnergyView {
    EnergyView { e_curr_mj: 100.0, e_opt_mj: 50.0, e_man_mj: 1.0, eta: 0.9 }
}

fn scarce() -> EnergyView {
    EnergyView { e_curr_mj: 20.0, e_opt_mj: 50.0, e_man_mj: 1.0, eta: 0.9 }
}

#[test]
fn table2_schedule_decisions() {
    let s = spec();
    let mut sched = Scheduler::new(SchedulerKind::Zygarde, PARAMS);
    // t1: J_{1,1} released (deadline t7 = release+6); only job -> runs.
    let mut j11 = Job::new(&s, 0, 1.0, 0); // trace 0: exits after unit 1
    let queue = vec![j11.clone()];
    assert_eq!(sched.pick(&queue, 1.0, &plentiful()), Some(0));
    // Unit 1 of J11 completes; utility test passes -> rest optional.
    assert!(j11.complete_unit(&s.traces[0], 4, 2.0));
    assert!(!j11.next_is_mandatory());
    assert!(j11.mandatory_done);

    // t2: E_curr < E_opt -> optional J11^2 must NOT be scheduled.
    let queue = vec![j11.clone()];
    assert_eq!(sched.pick(&queue, 2.0, &scarce()), None, "Table 2 @ t2");

    // t3: J_{1,2} released (mandatory); prioritized over optional J11^2
    // even with plentiful energy (γ term).
    let mut j12 = Job::new(&s, 1, 3.0, 1); // trace 1: exits after unit 2
    let queue = vec![j11.clone(), j12.clone()];
    let pick = sched.pick(&queue, 3.0, &plentiful()).unwrap();
    assert_eq!(queue[pick].id, 1, "Table 2 @ t3: mandatory J12 first");
    assert!(!j12.complete_unit(&s.traces[1], 4, 4.0)); // unit 1: not confident

    // t4: E_curr < E_man -> engine-level: nothing runs (mandatory gate).
    let starved = EnergyView { e_curr_mj: 0.5, e_opt_mj: 50.0, e_man_mj: 1.0, eta: 0.9 };
    assert!(starved.e_curr_mj < starved.e_man_mj, "Table 2 @ t4 premise");

    // t5: mandatory J12^2 over optional J11^2.
    let queue = vec![j11.clone(), j12.clone()];
    let pick = sched.pick(&queue, 5.0, &plentiful()).unwrap();
    assert_eq!(queue[pick].id, 1, "Table 2 @ t5");
    assert!(j12.complete_unit(&s.traces[1], 4, 6.0)); // unit 2: confident now

    // t6: only optional units remain, E_curr > E_opt; J11 has the tighter
    // deadline (t7 = 7 vs J12's t9 = 9) -> J11 wins.
    let queue = vec![j11.clone(), j12.clone()];
    let pick = sched.pick(&queue, 6.0, &plentiful()).unwrap();
    assert_eq!(queue[pick].id, 0, "Table 2 @ t6: tighter-deadline optional");
    j11.complete_unit(&s.traces[0], 4, 7.0);

    // t7: J11 hits its deadline and leaves; J12^3 is the only job.
    let queue = vec![j12.clone()];
    assert_eq!(sched.pick(&queue, 7.0, &plentiful()), Some(0), "Table 2 @ t7");
    j12.complete_unit(&s.traces[1], 4, 8.0);

    // t8: J12^4 (the only job) gets scheduled.
    let queue = vec![j12.clone()];
    assert_eq!(sched.pick(&queue, 8.0, &plentiful()), Some(0), "Table 2 @ t8");
    j12.complete_unit(&s.traces[1], 4, 9.0);
    assert!(j12.finished());
}

#[test]
fn figure1_imprecise_fixes_the_missed_deadline() {
    // Fig. 1: two jobs, release 0 and 20, relative deadline 34, full
    // execution 28, intermittent power. Under full execution J2 misses;
    // under the imprecise model both mandatory parts complete.
    use zygarde::clock::Rtc;
    use zygarde::coordinator::sched::ExitPolicy;
    use zygarde::energy::capacitor::Capacitor;
    use zygarde::energy::harvester::Harvester;
    use zygarde::energy::manager::EnergyManager;
    use zygarde::sim::engine::{Engine, SimConfig};

    let mk_task = |mandatory_units: usize| TaskSpec {
        id: 0,
        name: "fig1".into(),
        period_ms: 20_000.0,
        deadline_ms: 34_000.0,
        unit_time_ms: vec![7000.0; 4], // 4 units x 7 s = 28 s
        unit_energy_mj: vec![7000.0 * 0.110; 4],
        // SONIC-grade fragments (~11 mJ each): each fragment must fit in
        // the capacitor's boot-to-brownout band or no progress is possible.
        unit_fragments: vec![70; 4],
        release_energy_mj: 0.0,
        unit_state_bytes: vec![2048; 4],
        traces: Arc::new(vec![trace(mandatory_units - 1, 4)]),
        imprecise: true,
    };
    // ~55 mW harvester: half the active draw -> intermittent regime.
    let run = |exit: ExitPolicy, mandatory_units: usize| {
        let mut cap = Capacitor::standard();
        cap.precharge();
        let h = Harvester::markov(
            zygarde::energy::harvester::HarvesterKind::Rf,
            55.0,
            0.9,
            0.6,
            1000.0,
            4,
        );
        let em = EnergyManager::new(cap, h, 0.6, 1.0);
        Engine::new(
            SimConfig { duration_ms: 80_000.0, seed: 4, ..Default::default() },
            vec![mk_task(mandatory_units)],
            Scheduler::new(SchedulerKind::Zygarde, PARAMS),
            exit,
            em,
            Box::new(Rtc),
        )
        .run()
    };
    // Full execution (all 4 units mandatory): under intermittent power at
    // U ≈ 28/20, deadlines are missed.
    let full = run(ExitPolicy::None, 4);
    // Imprecise (1 mandatory unit): mandatory parts complete on time.
    let imprecise = run(ExitPolicy::Utility, 1);
    assert!(
        imprecise.scheduled_rate() > full.scheduled_rate(),
        "imprecise {} vs full {}",
        imprecise.scheduled_rate(),
        full.scheduled_rate()
    );
    assert!(imprecise.scheduled_rate() > 0.9, "{}", imprecise.scheduled_rate());
}
