//! Telemetry contract suite: traced and untraced runs are byte-identical,
//! and the traces themselves are well-formed.
//!
//! The first half pins the tentpole guarantee — attaching a `TraceSink`
//! must not perturb the simulation (no RNG draws, no `Metrics` writes,
//! no dispatch-path changes), proven by `SweepReport::json_string()`
//! equality over a matrix that exercises both harvest regimes, brown-out
//! injection, JIT commits, and a skewed CHRT clock. The second half is a
//! property test over the recorded event streams: timestamps are
//! monotone, fragment start/end pairs alternate and balance, bulk
//! fast-forward spans tile exactly the gaps between surrounding events,
//! power edges alternate, and every counted event class reconciles with
//! the run's `Metrics` — the trace is a faithful journal, not a sample.

use zygarde::clock::{ChrtTier, ClockSpec};
use zygarde::coordinator::sched::SchedulerKind;
use zygarde::energy::harvester::HarvesterKind;
use zygarde::nvm::NvmSpec;
use zygarde::sim::sweep::{
    run_matrix, run_scenario, run_scenario_traced, FaultPlan, HarvesterSpec, ScenarioMatrix,
    SweepReport,
};
use zygarde::sim::Metrics;
use zygarde::telemetry::export::{chrome_string, jsonl_string, ScenarioTrace};
use zygarde::telemetry::{EventKind, TraceEvent};
use zygarde::util::json::Value;

/// A deliberately hostile little matrix: a bursty RF harvester and a
/// steady piezo one, a capacitor small enough to brown out under load,
/// ideal and JIT-voltage NVM policies, and a fault plan layering
/// periodic forced outages over a Tier-3 CHRT clock's post-reboot skew.
/// Every event kind the engine can emit occurs somewhere in this grid.
fn mixed_matrix(seed: u64) -> ScenarioMatrix {
    ScenarioMatrix::new("telemetry-mix", seed)
        .harvesters(vec![
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 60.0,
                q: 0.92,
                duty: 0.25,
                eta: 0.4,
            },
            HarvesterSpec::Piezo { eta: 0.3 },
        ])
        .capacitors_mf(vec![5.0])
        .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
        .nvms(vec![NvmSpec::ideal(), NvmSpec::fram_jit()])
        .faults(vec![
            FaultPlan::none(),
            FaultPlan::none()
                .with_brownouts(9_000.0, 1_500.0, 2_000.0)
                .with_clock(ClockSpec::Chrt(ChrtTier::Tier3)),
        ])
        .reps(1)
        .duration_ms(60_000.0)
}

#[test]
fn tracing_does_not_change_report_bytes() {
    let m = mixed_matrix(0x7E1E);
    let untraced = run_matrix(&m, 2).json_string();
    let mut cells = Vec::new();
    let mut total_events = 0usize;
    for sc in m.expand() {
        let (cell, events) = run_scenario_traced(&sc);
        total_events += events.len();
        cells.push(cell);
    }
    let traced = SweepReport::new(&m.name, m.seed, cells).json_string();
    assert!(total_events > 0, "traced runs recorded nothing");
    assert_eq!(
        untraced, traced,
        "attaching a trace sink changed the report bytes — telemetry is in-band"
    );
}

/// Walk one scenario's event stream and enforce every structural
/// invariant plus the `Metrics` reconciliation.
fn check_trace(label: &str, events: &[TraceEvent], m: &Metrics) {
    let mut prev_t = f64::NEG_INFINITY;
    // (task, job, unit) of the currently-open fragment, if any.
    let mut open_frag: Option<(usize, u64, usize)> = None;
    // Some(true) after a Boot, Some(false) after a BrownOut.
    let mut powered: Option<bool> = None;
    let (mut frag_starts, mut frag_fails) = (0u64, 0u64);
    let (mut releases, mut met, mut missed) = (0u64, 0u64, 0u64);
    let (mut commits, mut jit_commits, mut restores) = (0u64, 0u64, 0u64);
    let (mut brownout_lost, mut rollback_lost) = (0u64, 0u64);
    for ev in events {
        assert!(
            ev.t_ms >= prev_t,
            "{label}: t_ms went backwards ({} after {prev_t})",
            ev.t_ms
        );
        match &ev.kind {
            EventKind::FragmentStart { task, job, unit } => {
                assert!(
                    open_frag.is_none(),
                    "{label}: fragment started inside fragment {open_frag:?}"
                );
                open_frag = Some((*task, *job, *unit));
                frag_starts += 1;
            }
            EventKind::FragmentEnd { task, job, unit, ok } => {
                assert_eq!(
                    open_frag,
                    Some((*task, *job, *unit)),
                    "{label}: fragment end does not match the open fragment"
                );
                open_frag = None;
                if !ok {
                    frag_fails += 1;
                }
            }
            EventKind::FastForward { from_ms, ticks, .. } => {
                assert!(*ticks > 0, "{label}: empty fast-forward span");
                assert!(
                    *from_ms <= ev.t_ms,
                    "{label}: fast-forward span ends before it starts"
                );
                // Emissions happen only outside bulk blocks, so a span
                // starting at or after the previous event's timestamp
                // means no event ever falls strictly inside a span —
                // spans exactly tile the engine's idle gaps.
                assert!(
                    *from_ms >= prev_t,
                    "{label}: fast-forward span [{from_ms}, {}] swallows the \
                     event at {prev_t}",
                    ev.t_ms
                );
            }
            EventKind::Boot { .. } => {
                assert_ne!(powered, Some(true), "{label}: two boots without a brown-out");
                powered = Some(true);
            }
            EventKind::BrownOut { lost_fragments } => {
                assert_ne!(powered, Some(false), "{label}: two brown-outs without a boot");
                powered = Some(false);
                brownout_lost += lost_fragments;
            }
            EventKind::Rollback { lost_fragments, .. } => {
                assert!(*lost_fragments > 0, "{label}: empty rollback event");
                rollback_lost += lost_fragments;
            }
            EventKind::Release { .. } => releases += 1,
            EventKind::DeadlineMet { .. } => met += 1,
            EventKind::DeadlineMissed { .. } => missed += 1,
            EventKind::Commit { jit, .. } => {
                commits += 1;
                if *jit {
                    jit_commits += 1;
                }
            }
            EventKind::Restore { .. } => restores += 1,
            EventKind::Probe => {
                panic!("{label}: probe event recorded with no probe attached")
            }
        }
        prev_t = ev.t_ms;
    }
    assert!(open_frag.is_none(), "{label}: fragment still open at end of run");
    // Every counted event class reconciles with the run's Metrics. A
    // released job that was queue-dropped never materializes, so it has
    // no Release event.
    assert_eq!(frag_starts, m.fragments, "{label}: fragment starts vs metrics");
    assert_eq!(frag_fails, m.refragments, "{label}: failed fragments vs metrics");
    assert_eq!(
        releases,
        m.released - m.queue_dropped,
        "{label}: releases vs metrics"
    );
    assert_eq!(met, m.scheduled, "{label}: deadlines met vs metrics");
    assert_eq!(missed, m.deadline_missed, "{label}: deadlines missed vs metrics");
    assert_eq!(commits, m.commits, "{label}: commits vs metrics");
    assert_eq!(jit_commits, m.jit_commits, "{label}: JIT commits vs metrics");
    assert_eq!(restores, m.restores, "{label}: restores vs metrics");
    assert_eq!(brownout_lost, m.lost_fragments, "{label}: lost fragments vs metrics");
    assert_eq!(
        rollback_lost, brownout_lost,
        "{label}: per-job rollbacks do not sum to the brown-out totals"
    );
}

#[test]
fn traces_are_well_formed_across_randomized_matrices() {
    let mut checked = 0usize;
    let mut nonempty = 0usize;
    for seed in [0xA11CEu64, 0x5EED2, 0xD00DAD] {
        let m = mixed_matrix(seed);
        for sc in m.expand() {
            let (cell, events) = run_scenario_traced(&sc);
            if !events.is_empty() {
                nonempty += 1;
            }
            check_trace(&cell.label, &events, &cell.metrics);
            checked += 1;
        }
    }
    assert!(checked >= 48, "matrix shrank: only {checked} cells checked");
    assert!(nonempty * 2 > checked, "most traces were empty — hooks are dead");
}

#[test]
fn traced_cell_metrics_match_untraced_cell_by_cell() {
    let m = mixed_matrix(0xCAFE);
    for sc in m.expand().into_iter().take(4) {
        let plain = run_scenario(&sc);
        let (traced, _) = run_scenario_traced(&sc);
        assert_eq!(
            plain.metrics.to_json().to_json(),
            traced.metrics.to_json().to_json(),
            "{}: tracing changed the cell metrics",
            plain.label
        );
    }
}

#[test]
fn exporters_emit_valid_chrome_and_jsonl() {
    let m = mixed_matrix(0xE49);
    let scenarios = m.expand();
    let sc = &scenarios[0];
    let (cell, events) = run_scenario_traced(sc);
    assert!(!events.is_empty(), "{}: no events to export", cell.label);

    // JSONL: one parseable object per line, each with a kind.
    let jsonl = jsonl_string(&events);
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), events.len());
    for line in &lines {
        let v = Value::parse(line).expect("jsonl line parses");
        assert!(v.get("kind").and_then(|k| k.as_str()).is_some());
    }

    // Chrome: parseable, valid phases, balanced B/E, spans well-formed.
    let doc = Value::parse(&chrome_string(&[ScenarioTrace {
        label: cell.label.clone(),
        index: sc.index,
        events,
    }]))
    .expect("chrome trace parses");
    let evs = doc.req("traceEvents").arr();
    assert!(!evs.is_empty());
    let mut depth = 0i64;
    for e in evs {
        let ph = e.req("ph").str();
        assert!(matches!(ph, "B" | "E" | "X" | "i" | "M"), "bad ph {ph}");
        match ph {
            "B" => depth += 1,
            "E" => {
                depth -= 1;
                assert!(depth >= 0, "E without open B");
            }
            "X" => assert!(e.req("dur").f64() >= 0.0),
            "i" => assert_eq!(e.req("s").str(), "t"),
            _ => {}
        }
    }
    assert_eq!(depth, 0, "unbalanced B/E pairs");
}
