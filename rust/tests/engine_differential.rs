//! Differential exactness harness: the optimized engine (off-phase
//! fast-forward, flattened per-fragment gates, short-circuited release /
//! deadline scans) must produce **byte-identical metrics JSON** to the
//! naive reference stepper (`Engine::reference = true`) on randomized
//! scenarios covering every harvester kind (persistent, calibrated
//! Table-4 system, Markov RF/solar, piezo, diurnal solar), every
//! scheduler, every NVM commit policy, blackout-burst fault plans, CHRT
//! clock skew, cold and precharged starts, and probes on/off.
//!
//! This suite is what makes hot-path optimizations cheap to verify: any
//! future change to the fast paths either reproduces the reference
//! stepper bit for bit or fails here with a reproducible seed
//! (`PROP_SEED=<n>`). Scenario count is `DIFF_SCENARIOS` (default 64;
//! the CI bench job runs an extended release-mode pass). Handcrafted
//! event-boundary collisions — a release on a boot tick, a deadline on a
//! harvester window edge, a JIT crossing inside a budgeted idle run,
//! zero-length blackout windows — get their own deterministic cases
//! because random sampling essentially never aligns two events on one
//! tick.

use std::cell::Cell;
use std::rc::Rc;

use zygarde::clock::{ChrtTier, ClockSpec};
use zygarde::coordinator::sched::SchedulerKind;
use zygarde::energy::harvester::HarvesterKind;
use zygarde::nvm::NvmSpec;
use zygarde::sim::sweep::{
    build_engine, FaultPlan, HarvesterSpec, Scenario, ScenarioMatrix, TaskMix,
};
use zygarde::sim::workload::synthetic_task;
use zygarde::util::prop::{forall, Config, Size};
use zygarde::util::rng::Pcg32;

fn iters() -> usize {
    std::env::var("DIFF_SCENARIOS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
        .max(50) // the exactness contract promises >= 50 scenarios
}

/// A random single-cell matrix. Off-dominated harvesters get the long
/// horizons where the fast-forward actually engages; dense ones keep the
/// runtime of the naive baseline in check.
fn random_scenario(rng: &mut Pcg32, size: Size) -> Scenario {
    let n_tasks = 1 + rng.below(2) as usize;
    let n_units = 1 + rng.below(3) as usize;
    let scheduler = *rng.choice(&[
        SchedulerKind::Zygarde,
        SchedulerKind::Edf,
        SchedulerKind::EdfMandatory,
        SchedulerKind::RoundRobin,
    ]);
    let capacitor_mf = *rng.choice(&[1.0, 5.0, 50.0]);
    let nvm = *rng.choice(&[
        NvmSpec::ideal(),
        NvmSpec::fram_every_fragment(),
        NvmSpec::fram_unit_boundary(),
        NvmSpec::fram_jit(),
    ]);
    let grow = 1_000.0 * size.0.min(8) as f64;
    let (harvester, duration_ms) = match rng.below(6) {
        0 => (HarvesterSpec::Persistent { power_mw: 200.0 + rng.f64() * 400.0 }, 4_000.0 + grow),
        // A Table 4 system: exercises the calibrated-q RwLock path (one
        // fixed id so this binary pays a single calibration search).
        1 => (HarvesterSpec::System(6), 20_000.0 + 4.0 * grow),
        2 => (
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 40.0 + rng.f64() * 160.0,
                q: 0.7 + rng.f64() * 0.28,
                duty: 0.1 + rng.f64() * 0.7,
                eta: 0.3 + rng.f64() * 0.6,
            },
            30_000.0 + 10.0 * grow,
        ),
        3 => (
            HarvesterSpec::Markov {
                kind: HarvesterKind::Solar,
                on_power_mw: 200.0 + rng.f64() * 400.0,
                q: 0.85 + rng.f64() * 0.13,
                duty: 0.2 + rng.f64() * 0.5,
                eta: 0.3 + rng.f64() * 0.6,
            },
            30_000.0 + 10.0 * grow,
        ),
        // The off-dominated regimes (ΔT = 5 min): long horizons so whole
        // dark windows fast-forward.
        4 => (HarvesterSpec::Piezo { eta: 0.2 + rng.f64() * 0.3 }, 1_800_000.0 + 400.0 * grow),
        _ => (
            HarvesterSpec::SolarDiurnal { eta: 0.3 + rng.f64() * 0.3 },
            3_600_000.0 + 400.0 * grow,
        ),
    };
    let mut fault = if rng.chance(0.5) {
        FaultPlan::none()
    } else {
        FaultPlan::none().with_brownouts(
            500.0 + rng.f64() * 2000.0,
            rng.f64() * 500.0,
            rng.f64() * 300.0,
        )
    };
    if rng.chance(0.3) {
        fault = fault.with_clock(ClockSpec::Chrt(ChrtTier::Tier3));
    }
    ScenarioMatrix::new("diff", rng.next_u64())
        .mixes(vec![TaskMix::synthetic("m", n_tasks, n_units, rng.next_u64())])
        .harvesters(vec![harvester])
        .capacitors_mf(vec![capacitor_mf])
        .schedulers(vec![scheduler])
        .faults(vec![fault])
        .nvms(vec![nvm])
        .precharge(rng.chance(0.7))
        .queue_size(1 + rng.below(3) as usize)
        .duration_ms(duration_ms)
        .log_jobs(rng.chance(0.5))
        .expand()
        .pop()
        .unwrap()
}

fn metrics_json(sc: &Scenario, reference: bool) -> String {
    let mut engine = build_engine(sc);
    engine.reference = reference;
    engine.run().to_json().to_json()
}

#[test]
fn fast_engine_matches_reference_byte_for_byte() {
    forall(
        "fast-vs-reference-metrics",
        Config { iters: iters(), ..Default::default() },
        random_scenario,
        |sc| {
            let fast = metrics_json(sc, false);
            let reference = metrics_json(sc, true);
            if fast != reference {
                return Err(format!(
                    "metrics JSON diverged on {}:\n fast: {fast}\n ref:  {reference}",
                    sc.label()
                ));
            }
            Ok(())
        },
    );
}

/// Handcrafted scenarios that pin every event the next-event budget
/// predicts onto the exact tick where another event fires. Random
/// scenarios almost never align a release with a boot tick or a deadline
/// with a harvester window edge, so an off-by-one in any of the analytic
/// crossing predictors (`off_ticks_hint`, `idle_ticks_above`,
/// `ticks_above_voltage`, the believed-deadline watch) could hide for
/// thousands of random iterations. Each case must still be byte-identical
/// to the reference stepper.
#[test]
fn event_boundaries_colliding_on_one_tick_stay_byte_identical() {
    let cases: Vec<(&str, ScenarioMatrix)> = vec![
        (
            // Brown-out period == task period, zero release jitter: every
            // post-blackout boot tick carries a due release, so the
            // off-phase loop's boot exit and release exit race on the
            // same tick.
            "release lands on the boot tick",
            ScenarioMatrix::new("bnd-release-boot", 0xB0B1)
                .mixes(vec![TaskMix::from_tasks(
                    "m",
                    vec![synthetic_task(0, 2, 1_000.0, 2_000.0, 40, 0xB0B1)],
                )])
                .harvesters(vec![HarvesterSpec::Persistent { power_mw: 500.0 }])
                .capacitors_mf(vec![5.0])
                .schedulers(vec![SchedulerKind::Zygarde])
                .faults(vec![FaultPlan::none().with_brownouts(1_000.0, 200.0, 0.0)])
                .precharge(true)
                .release_jitter(0.0)
                .duration_ms(60_000.0),
        ),
        (
            // Period == deadline == the diurnal harvester's 5-minute
            // window edge (all multiples of the tick): believed deadlines
            // expire exactly when a dark window opens or closes, under a
            // skewed CHRT clock so the watch's constant offset is
            // non-zero.
            "deadline lands on a harvester window edge",
            ScenarioMatrix::new("bnd-deadline-edge", 0xB0B2)
                .mixes(vec![TaskMix::from_tasks(
                    "m",
                    vec![synthetic_task(0, 2, 300_000.0, 300_000.0, 40, 0xB0B2)],
                )])
                .harvesters(vec![HarvesterSpec::SolarDiurnal { eta: 0.4 }])
                .capacitors_mf(vec![50.0])
                .schedulers(vec![SchedulerKind::EdfMandatory])
                .faults(vec![FaultPlan::none().with_clock(ClockSpec::Chrt(ChrtTier::Tier3))])
                .precharge(true)
                .release_jitter(0.0)
                .duration_ms(3_600_000.0),
        ),
        (
            // A 1 mF capacitor swings across the JIT trigger voltage in a
            // handful of idle ticks: the `ticks_above_voltage` budget and
            // the commit-then-disarm sequencing must agree with the
            // per-tick `jit_check` on the exact crossing tick.
            "jit trigger crosses on a budgeted idle tick",
            ScenarioMatrix::new("bnd-jit-cross", 0xB0B3)
                .mixes(vec![TaskMix::from_tasks(
                    "m",
                    vec![synthetic_task(0, 3, 800.0, 1_600.0, 40, 0xB0B3)],
                )])
                .harvesters(vec![HarvesterSpec::Markov {
                    kind: HarvesterKind::Rf,
                    on_power_mw: 60.0,
                    q: 0.9,
                    duty: 0.4,
                    eta: 0.5,
                }])
                .capacitors_mf(vec![1.0])
                .schedulers(vec![SchedulerKind::Zygarde])
                .nvms(vec![NvmSpec::fram_jit()])
                .precharge(true)
                .duration_ms(120_000.0),
        ),
        (
            // Zero-length blackout windows aligned to tick boundaries:
            // the fault mask flips on and off within the same tick the
            // budget targeted, a degenerate edge the window-crossing
            // hints must treat as an ordinary boundary tick.
            "zero-length blackout windows",
            ScenarioMatrix::new("bnd-zero-window", 0xB0B4)
                .mixes(vec![TaskMix::from_tasks(
                    "m",
                    vec![synthetic_task(0, 2, 500.0, 1_500.0, 40, 0xB0B4)],
                )])
                .harvesters(vec![HarvesterSpec::Markov {
                    kind: HarvesterKind::Rf,
                    on_power_mw: 80.0,
                    q: 0.95,
                    duty: 0.2,
                    eta: 0.4,
                }])
                .capacitors_mf(vec![10.0])
                .schedulers(vec![SchedulerKind::Zygarde])
                .faults(vec![FaultPlan::none().with_brownouts(700.0, 0.0, 35.0)])
                .queue_size(3)
                .duration_ms(600_000.0),
        ),
    ];
    for (name, matrix) in cases {
        let sc = matrix.expand().pop().unwrap();
        let fast = metrics_json(&sc, false);
        let reference = metrics_json(&sc, true);
        assert_eq!(fast, reference, "{name}: fast engine diverged from reference");
        assert!(fast.contains("released"), "{name}: metrics JSON looks empty");
    }
}

/// With a probe attached the fast path must stand down entirely: both
/// engines step naively, the probe observes the identical tick sequence,
/// and the metrics still match byte for byte.
#[test]
fn probed_engines_agree_and_observe_identical_ticks() {
    forall(
        "fast-vs-reference-probed",
        Config { iters: 24, ..Default::default() },
        random_scenario,
        |sc| {
            let run = |reference: bool| {
                let mut engine = build_engine(sc);
                engine.reference = reference;
                let ticks = Rc::new(Cell::new(0u64));
                let t = ticks.clone();
                engine.probe = Some(Box::new(move |_now, _em, _m| t.set(t.get() + 1)));
                (engine.run().to_json().to_json(), ticks.get())
            };
            let (fast_json, fast_ticks) = run(false);
            let (ref_json, ref_ticks) = run(true);
            if fast_json != ref_json {
                return Err(format!("probed metrics diverged on {}", sc.label()));
            }
            if fast_ticks != ref_ticks {
                return Err(format!(
                    "probe tick counts diverged on {}: fast {fast_ticks} vs ref {ref_ticks}",
                    sc.label()
                ));
            }
            if fast_ticks == 0 {
                return Err("probe never fired".to_string());
            }
            Ok(())
        },
    );
}
