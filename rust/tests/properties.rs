//! Property-based tests over the coordinator, energy, and simulator
//! invariants, using the in-crate `util::prop` harness (seed overridable
//! via PROP_SEED).

use std::sync::Arc;

use zygarde::clock::Rtc;
use zygarde::coordinator::priority::{zeta, zeta_intermittent, EnergyView, PriorityParams};
use zygarde::coordinator::sched::{ExitPolicy, Scheduler, SchedulerKind};
use zygarde::coordinator::task::{Job, JobState, TaskSpec};
use zygarde::dnn::trace::{SampleTrace, UnitOutcome};
use zygarde::energy::capacitor::Capacitor;
use zygarde::energy::events::eta_factor;
use zygarde::energy::harvester::Harvester;
use zygarde::energy::manager::EnergyManager;
use zygarde::sim::engine::{Engine, SimConfig};
use zygarde::util::prop::{forall, Config, Size};
use zygarde::util::rng::Pcg32;

fn rand_trace(rng: &mut Pcg32, n_units: usize) -> SampleTrace {
    let exit_unit = rng.below(n_units as u64) as usize;
    let units = (0..n_units)
        .map(|i| UnitOutcome {
            gap: rng.f32() * 10.0,
            pred: rng.below(4) as i32,
            exit: i == exit_unit,
            correct: rng.chance(0.7),
        })
        .collect::<Vec<_>>();
    let oracle_unit = units.iter().position(|u| u.correct);
    SampleTrace { label: 0, units, exit_unit, oracle_unit }
}

fn rand_task(rng: &mut Pcg32, id: usize, size: Size) -> TaskSpec {
    let n_units = 1 + rng.below(4) as usize;
    let n_traces = 1 + rng.below(size.0 as u64 + 1) as usize;
    TaskSpec {
        id,
        name: format!("t{id}"),
        period_ms: 50.0 + rng.f64() * 500.0,
        deadline_ms: 100.0 + rng.f64() * 1000.0,
        unit_time_ms: (0..n_units).map(|_| 5.0 + rng.f64() * 50.0).collect(),
        unit_energy_mj: (0..n_units).map(|_| 0.5 + rng.f64() * 5.0).collect(),
        unit_fragments: (0..n_units).map(|_| 1 + rng.below(8) as usize).collect(),
        release_energy_mj: rng.f64() * 2.0,
        unit_state_bytes: (0..n_units).map(|_| 256 + rng.below(8192) as usize).collect(),
        traces: Arc::new((0..n_traces).map(|_| rand_trace(rng, n_units)).collect()),
        imprecise: true,
    }
}

#[test]
fn prop_priority_mandatory_dominates_under_pressure() {
    // ζ_I of ANY optional unit is 0 under energy pressure; ζ_I of any
    // mandatory unit is what ζ would give without the γ bonus — hence
    // positive whenever the deadline has not absurdly receded.
    forall(
        "zeta-i-optional-zero-under-pressure",
        Config::default(),
        |rng, _size| {
            let spec = rand_task(rng, 0, Size(4));
            let mut j = Job::new(&spec, 0, rng.f64() * 100.0, 0);
            j.utility = rng.f32() * 20.0;
            if rng.chance(0.5) {
                j.state = JobState::Optional;
            }
            let p = PriorityParams::new(1000.0, 20.0);
            let e = EnergyView {
                e_curr_mj: rng.f64() * 50.0,
                e_opt_mj: 100.0,
                e_man_mj: 0.1,
                eta: rng.f64() * 0.9,
            };
            (j, p, e)
        },
        |(j, p, e)| {
            assert!(!e.optional_allowed());
            let z = zeta_intermittent(j, 0.0, *p, e);
            if j.next_is_mandatory() {
                if z == 0.0 {
                    return Err("mandatory unit scored 0 under pressure".into());
                }
            } else if z != 0.0 {
                return Err(format!("optional unit scored {z} under pressure"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_zeta_i_equals_zeta_when_energy_plentiful() {
    forall(
        "zeta-i-reduces-to-zeta",
        Config::default(),
        |rng, _| {
            let spec = rand_task(rng, 0, Size(4));
            let mut j = Job::new(&spec, 0, rng.f64() * 100.0, 0);
            j.utility = rng.f32() * 20.0;
            let p = PriorityParams::new(500.0 + rng.f64() * 1000.0, 5.0 + rng.f64() * 30.0);
            (j, p, rng.f64() * 500.0)
        },
        |(j, p, t)| {
            let e = EnergyView::persistent();
            let a = zeta_intermittent(j, *t, *p, &e);
            let b = zeta(j, *t, *p);
            if (a - b).abs() < 1e-12 {
                Ok(())
            } else {
                Err(format!("zeta_I={a} != zeta={b}"))
            }
        },
    );
}

#[test]
fn prop_capacitor_energy_bounded() {
    forall(
        "capacitor-bounds",
        Config { iters: 128, ..Default::default() },
        |rng, size| {
            let c = 0.001 + rng.f64() * 0.1;
            let ops: Vec<(bool, f64)> = (0..size.0 * 4)
                .map(|_| (rng.chance(0.5), rng.f64() * 50.0))
                .collect();
            (c, ops)
        },
        |(c, ops)| {
            let mut cap = Capacitor::new(*c, 3.3, 2.8, 1.9);
            for &(is_charge, amt) in ops {
                if is_charge {
                    cap.charge(amt * 10.0, 100.0);
                } else {
                    let _ = cap.draw(amt);
                }
                let e = cap.energy_mj();
                if e < -1e-9 || e > cap.capacity_mj() + 1e-9 {
                    return Err(format!("energy {e} outside [0, {}]", cap.capacity_mj()));
                }
                if cap.mcu_on() && cap.voltage() < cap.v_off - 1e-9 {
                    return Err("MCU on below brown-out voltage".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eta_in_unit_interval_any_trace() {
    forall(
        "eta-in-[0,1]",
        Config { iters: 64, ..Default::default() },
        |rng, size| {
            let n = 200 + rng.below(2000) as usize;
            let style = rng.below(3);
            let mut state = true;
            (0..n)
                .map(|i| match style {
                    0 => rng.chance(0.5),
                    1 => {
                        if !rng.chance(0.85 + 0.1 * (size.0 as f64 / 64.0)) {
                            state = !state;
                        }
                        state
                    }
                    _ => i % (2 + rng.below(5) as usize) == 0,
                })
                .collect::<Vec<bool>>()
        },
        |trace| {
            let e = eta_factor(trace, 15, 3);
            if (0.0..=1.0).contains(&e.eta) && e.kw_harvester >= 0.0 && e.kw_random >= 0.0 {
                Ok(())
            } else {
                Err(format!("eta={} kw_h={} kw_r={}", e.eta, e.kw_harvester, e.kw_random))
            }
        },
    );
}

/// Engine-level invariants under randomized workloads, harvesters and
/// schedulers: conservation of jobs, no negative counters, mandatory
/// before optional counts, energy conservation within tolerance.
#[test]
fn prop_engine_invariants() {
    forall(
        "engine-invariants",
        Config { iters: 48, max_size: 24, ..Default::default() },
        |rng, size| {
            let n_tasks = 1 + rng.below(3) as usize;
            let tasks: Vec<TaskSpec> =
                (0..n_tasks).map(|i| rand_task(rng, i, size)).collect();
            let kind = *rng.choice(&[
                SchedulerKind::Zygarde,
                SchedulerKind::Edf,
                SchedulerKind::EdfMandatory,
                SchedulerKind::RoundRobin,
            ]);
            let exit = *rng.choice(&[ExitPolicy::None, ExitPolicy::Utility, ExitPolicy::Oracle]);
            let power = 20.0 + rng.f64() * 300.0;
            let seed = rng.next_u64();
            (tasks, kind, exit, power, seed)
        },
        |(tasks, kind, exit, power, seed)| {
            let mut cap = Capacitor::standard();
            cap.precharge();
            let h = Harvester::markov(
                zygarde::energy::harvester::HarvesterKind::Rf,
                *power,
                0.9,
                0.6,
                1000.0,
                *seed,
            );
            let em = EnergyManager::new(cap, h, 0.6, 0.5);
            let engine = Engine::new(
                SimConfig { duration_ms: 20_000.0, seed: *seed, ..Default::default() },
                tasks.clone(),
                Scheduler::new(*kind, PriorityParams::new(1000.0, 20.0)),
                *exit,
                em,
                Box::new(Rtc),
            );
            let m = engine.run();
            // Conservation: scheduled + missed <= released (jobs still in
            // queue at sim end are neither).
            if m.scheduled + m.deadline_missed > m.released {
                return Err(format!(
                    "job conservation violated: {} + {} > {}",
                    m.scheduled, m.deadline_missed, m.released
                ));
            }
            if m.correct > m.scheduled {
                return Err("more correct than scheduled".into());
            }
            let per_task: u64 = m.per_task_released.iter().sum();
            if per_task != m.released {
                return Err("per-task released does not sum".into());
            }
            if m.on_time_ms > m.sim_time_ms + 1e-6 {
                return Err("on-time exceeds sim time".into());
            }
            // EDF-M never executes optional units.
            if *kind == SchedulerKind::EdfMandatory && m.optional_units > 0 {
                return Err("EDF-M ran optional units".into());
            }
            Ok(())
        },
    );
}

/// Fragment idempotence: injecting power failures mid-unit never corrupts
/// the unit sequence — a job's units complete in order, each exactly once.
#[test]
fn prop_failure_injection_preserves_unit_order() {
    forall(
        "unit-order-under-failures",
        Config { iters: 48, max_size: 16, ..Default::default() },
        |rng, size| {
            let task = rand_task(rng, 0, size);
            (task, rng.next_u64())
        },
        |(task, seed)| {
            // Weak, very bursty harvester: frequent mid-fragment failures.
            let mut cap = Capacitor::new(0.002, 3.3, 2.8, 1.9);
            cap.precharge();
            let h = Harvester::markov(
                zygarde::energy::harvester::HarvesterKind::Rf,
                40.0,
                0.7,
                0.5,
                200.0,
                *seed,
            );
            let em = EnergyManager::new(cap, h, 0.3, 0.2);
            let engine = Engine::new(
                SimConfig { duration_ms: 15_000.0, seed: *seed, ..Default::default() },
                vec![task.clone()],
                Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(1000.0, 20.0)),
                ExitPolicy::Utility,
                em,
                Box::new(Rtc),
            );
            let m = engine.run();
            // Unit accounting: every completed unit belongs to some job and
            // total units never exceeds released * n_units.
            let max_units = m.released * task.n_units() as u64;
            if m.mandatory_units + m.optional_units > max_units {
                return Err(format!(
                    "unit count {} exceeds possible {max_units}",
                    m.mandatory_units + m.optional_units
                ));
            }
            // Fragments: completed + re-executed >= fragments of completed
            // units (sanity: counters are consistent).
            if m.refragments > m.fragments {
                return Err("more re-executions than fragment attempts".into());
            }
            Ok(())
        },
    );
}
