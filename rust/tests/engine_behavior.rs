//! Focused behavioural tests for the intermittent engine: queue eviction,
//! capture-miss accounting, CHRT-induced losses, multi-task fairness, and
//! the optional-unit opportunism contract.

use std::sync::Arc;

use zygarde::clock::{Chrt, ChrtTier, Rtc};
use zygarde::coordinator::priority::PriorityParams;
use zygarde::coordinator::sched::{ExitPolicy, Scheduler, SchedulerKind};
use zygarde::coordinator::task::TaskSpec;
use zygarde::dnn::trace::{SampleTrace, UnitOutcome};
use zygarde::energy::capacitor::Capacitor;
use zygarde::energy::harvester::{Harvester, HarvesterKind};
use zygarde::energy::manager::EnergyManager;
use zygarde::sim::engine::{Engine, SimConfig};

fn trace(exit_at: usize, n: usize) -> SampleTrace {
    SampleTrace {
        label: 1,
        units: (0..n)
            .map(|i| UnitOutcome { gap: if i >= exit_at { 9.0 } else { 0.1 }, pred: 1,
                                   exit: i == exit_at, correct: true })
            .collect(),
        exit_unit: exit_at,
        oracle_unit: Some(exit_at),
    }
}

fn task(id: usize, period: f64, deadline: f64, exit_at: usize) -> TaskSpec {
    TaskSpec {
        id,
        name: format!("t{id}"),
        period_ms: period,
        deadline_ms: deadline,
        unit_time_ms: vec![30.0; 4],
        unit_energy_mj: vec![3.3; 4], // 110 mW at 30 ms/unit
        unit_fragments: vec![4; 4],
        release_energy_mj: 0.1,
        unit_state_bytes: vec![2048; 4],
        traces: Arc::new(vec![trace(exit_at, 4)]),
        imprecise: true,
    }
}

fn full_cap() -> Capacitor {
    let mut c = Capacitor::standard();
    c.precharge();
    c
}

fn engine(tasks: Vec<TaskSpec>, kind: SchedulerKind, exit: ExitPolicy,
          harvester: Harvester, eta: f64, duration: f64, seed: u64) -> Engine {
    let em = EnergyManager::new(full_cap(), harvester, eta, 0.9);
    Engine::new(
        SimConfig { duration_ms: duration, seed, ..Default::default() },
        tasks,
        Scheduler::new(kind, PriorityParams::new(2000.0, 10.0)),
        exit,
        em,
        Box::new(Rtc),
    )
}

#[test]
fn confident_jobs_are_evicted_for_fresh_releases() {
    // Early-exit task at unit 0 leaves confident jobs with 3 optional units
    // each; at eta=1 with persistent power Zygarde keeps refining them.
    // A flood of releases must not be dropped: confident jobs get evicted.
    let t = task(0, 40.0, 2000.0, 0);
    let m = engine(
        vec![t],
        SchedulerKind::Zygarde,
        ExitPolicy::Utility,
        Harvester::persistent(600.0),
        1.0,
        20_000.0,
        3,
    )
    .run();
    assert!(m.released > 100);
    assert_eq!(m.queue_dropped, 0, "releases were dropped: {m:?}");
    assert!(m.scheduled_rate() > 0.95, "{}", m.scheduled_rate());
}

#[test]
fn captures_fail_only_when_energy_lacks() {
    // Persistent power: zero capture misses. Dead harvester: all misses.
    let alive = engine(
        vec![task(0, 100.0, 500.0, 1)],
        SchedulerKind::Zygarde,
        ExitPolicy::Utility,
        Harvester::persistent(400.0),
        1.0,
        10_000.0,
        1,
    )
    .run();
    assert_eq!(alive.capture_missed, 0);

    let mut dead_engine = engine(
        vec![task(0, 100.0, 500.0, 1)],
        SchedulerKind::Zygarde,
        ExitPolicy::Utility,
        Harvester::markov(HarvesterKind::Rf, 0.001, 0.9, 0.01, 1000.0, 2),
        0.3,
        60_000.0,
        1,
    );
    // Start with an empty capacitor for the dead case.
    dead_engine.energy.capacitor = Capacitor::standard();
    let dead = dead_engine.run();
    assert_eq!(dead.released, 0, "released jobs with no energy: {dead:?}");
    assert!(dead.capture_missed > 100);
}

#[test]
fn chrt_positive_error_discards_early_sometimes() {
    // With a feasible workload the CHRT clock's ±1-2 s error may cost a
    // few jobs but never *gains* capacity (scheduled is judged on true
    // deadlines).
    let run_with = |chrt: bool| {
        let t = task(0, 300.0, 1500.0, 1);
        let clock: Box<dyn zygarde::clock::Clock> = if chrt {
            Box::new(Chrt::new(ChrtTier::Tier3, 7))
        } else {
            Box::new(Rtc)
        };
        let h = Harvester::markov(HarvesterKind::Rf, 90.0, 0.9, 0.7, 1000.0, 5);
        let em = EnergyManager::new(full_cap(), h, 0.6, 0.9);
        Engine::new(
            SimConfig { duration_ms: 120_000.0, seed: 5, ..Default::default() },
            vec![t],
            Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(1500.0, 10.0)),
            ExitPolicy::Utility,
            em,
            clock,
        )
        .run()
    };
    let rtc = run_with(false);
    let chrt = run_with(true);
    // Loss bounded (paper: < 0.1 % at their scale; generous here).
    let loss = (rtc.scheduled as f64 - chrt.scheduled as f64) / rtc.scheduled.max(1) as f64;
    assert!(loss.abs() < 0.10, "CHRT loss {loss}: rtc={} chrt={}", rtc.scheduled, chrt.scheduled);
}

#[test]
fn multitask_fairness_under_zygarde() {
    // Two tasks, one with 2x the execution demand: Zygarde's unit-level
    // interleaving must schedule a solid share of both.
    // U = 240/400 + 30/200 = 0.75: feasible, so fairness (not shedding)
    // is what is under test.
    let mut heavy = task(0, 400.0, 800.0, 3); // never exits early
    heavy.unit_time_ms = vec![60.0; 4];
    heavy.unit_energy_mj = vec![6.6; 4];
    let light = task(1, 200.0, 400.0, 0);
    let m = engine(
        vec![heavy, light],
        SchedulerKind::Zygarde,
        ExitPolicy::Utility,
        Harvester::persistent(600.0),
        1.0,
        30_000.0,
        9,
    )
    .run();
    for t in 0..2 {
        let r = m.per_task_scheduled[t] as f64 / m.per_task_released[t].max(1) as f64;
        assert!(r > 0.5, "task {t} starved: {r} ({m:?})");
    }
}

#[test]
fn optional_units_never_run_for_edfm_even_at_full_energy() {
    let t = task(0, 100.0, 500.0, 0);
    let m = engine(
        vec![t],
        SchedulerKind::EdfMandatory,
        ExitPolicy::Utility,
        Harvester::persistent(600.0),
        1.0,
        15_000.0,
        4,
    )
    .run();
    assert_eq!(m.optional_units, 0);
    assert!(m.scheduled > 0);
}

#[test]
fn edf_runs_to_exhaustion() {
    let t = task(0, 400.0, 2000.0, 0); // would exit at unit 0 if allowed
    let m = engine(
        vec![t],
        SchedulerKind::Edf,
        ExitPolicy::None,
        Harvester::persistent(600.0),
        1.0,
        12_000.0,
        4,
    )
    .run();
    // Every scheduled job executed all 4 units.
    assert_eq!(m.mandatory_units + m.optional_units, 4 * m.scheduled);
}
