//! Deterministic-simnet suite: whole serve campaigns over the seeded
//! simulated network (`sim::sweep::serve::simnet`), replayed from the
//! committed corpus in `rust/tests/seeds/serve/` plus property checks.
//!
//! The invariants under test:
//!
//! 1. **Byte identity** — every campaign's streamed report equals the
//!    single-process `SweepReport::json_string()`, whatever the network
//!    did (latency, reordering, duplication, drops, partitions, worker
//!    crashes mid-lease, dispatcher crash+resume through the real
//!    journal).
//! 2. **Seed determinism** — same seed, same run: the dispatcher event
//!    log (and its hash) is a pure function of the seed; disjoint seeds
//!    produce distinct plans and schedules.
//! 3. **Fidelity** — on a fault-free network the simnet, the real
//!    pipes-and-processes `zygarde serve`, and the in-process sweep all
//!    agree byte for byte.
//!
//! A failing seed found anywhere (CI exploration, local fuzzing) becomes
//! a one-line `.seed` file here and is then replayed forever.

use std::path::{Path, PathBuf};

use zygarde::exp::sweep_cli::{build_matrix, SweepOpts};
use zygarde::sim::sweep::serve::simnet::{run_campaign, FaultPlan, FaultSpec, SimConfig};
use zygarde::sim::sweep::{run_matrix, ScenarioMatrix};

/// One line of a committed `.seed` file: whitespace-separated
/// `key=value` tokens (the `faults` value may itself contain `=`/`,`).
/// Defaults mirror the `zygarde simtest` CLI defaults so a seed file and
/// the printed reproduce command mean the same campaign.
struct SeedEntry {
    seed: u64,
    workers: usize,
    reps: u64,
    duration_ms: f64,
    faults: String,
    lease: usize,
    lease_timeout_ms: u64,
    spill_cells: usize,
}

fn parse_seed_entry(text: &str, origin: &Path) -> SeedEntry {
    let mut e = SeedEntry {
        seed: 0,
        workers: 32,
        reps: 2,
        duration_ms: 6_000.0,
        faults: String::new(),
        lease: 0,
        lease_timeout_ms: 300,
        spill_cells: 32,
    };
    let mut saw_seed = false;
    for tok in text.split_whitespace() {
        let (key, val) = tok
            .split_once('=')
            .unwrap_or_else(|| panic!("{}: `{tok}` is not key=value", origin.display()));
        match key {
            "seed" => {
                e.seed = val.parse().unwrap();
                saw_seed = true;
            }
            "workers" => e.workers = val.parse().unwrap(),
            "reps" => e.reps = val.parse().unwrap(),
            "duration-ms" => e.duration_ms = val.parse().unwrap(),
            "faults" => e.faults = val.to_string(),
            "lease" => e.lease = val.parse().unwrap(),
            "lease-timeout-ms" => e.lease_timeout_ms = val.parse().unwrap(),
            "spill-cells" => e.spill_cells = val.parse().unwrap(),
            other => panic!("{}: unknown seed key `{other}`", origin.display()),
        }
    }
    assert!(saw_seed, "{}: no seed= token", origin.display());
    e
}

/// The matrix a seed entry means: always `synthetic` (no artifacts, so
/// the corpus replays on any machine), tuned by the entry's fields.
fn entry_matrix(e: &SeedEntry) -> ScenarioMatrix {
    let opts = SweepOpts {
        seed: e.seed,
        reps: e.reps,
        duration_ms: Some(e.duration_ms),
        ..Default::default()
    };
    build_matrix("synthetic", &opts).unwrap()
}

fn entry_config(e: &SeedEntry, origin: &Path) -> SimConfig {
    let spec = FaultSpec::parse(&e.faults)
        .unwrap_or_else(|err| panic!("{}: {err}", origin.display()));
    let mut cfg = SimConfig::new(e.seed, e.workers);
    cfg.spec = spec;
    cfg.lease_size = e.lease;
    cfg.lease_timeout_ms = e.lease_timeout_ms;
    cfg.spill_cells = e.spill_cells;
    cfg.threads = 2;
    cfg
}

fn corpus_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/seeds/serve")
}

/// Replay every committed seed: each campaign must complete and stream
/// bytes identical to the single-process report. This is the permanent
/// regression net — a seed that ever failed stays here forever.
#[test]
fn committed_seed_corpus_replays_byte_identical() {
    let dir = corpus_dir();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|ent| ent.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "seed"))
        .collect();
    paths.sort();
    assert!(!paths.is_empty(), "seed corpus at {} is empty", dir.display());
    for path in paths {
        let text = std::fs::read_to_string(&path).unwrap();
        let entry = parse_seed_entry(&text, &path);
        let matrix = entry_matrix(&entry);
        let cfg = entry_config(&entry, &path);
        let outcome = run_campaign(&matrix, &cfg)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(
            outcome.matches,
            "{}: seed {} diverged from the single-process bytes ({} vs {})",
            path.display(),
            entry.seed,
            outcome.report.len(),
            outcome.reference.len()
        );
    }
}

/// The CI flagship: ≥200 workers, injected partition, three crashes (the
/// victim preferentially holds a live lease — a genuine mid-lease kill),
/// duplicated and reordered delivery — and the report still comes out
/// byte-identical, with every planned fault observed by the transport.
#[test]
fn flagship_200_worker_fault_campaign_is_byte_identical() {
    let entry = SeedEntry {
        seed: 11,
        workers: 200,
        reps: 2,
        duration_ms: 1_200.0,
        faults: "latency=1..20,drop=0.02,dup=0.04,reorder=0.08,crash=3,partition=1,slow=2"
            .to_string(),
        lease: 0,
        lease_timeout_ms: 300,
        spill_cells: 32,
    };
    let origin = PathBuf::from("flagship");
    let matrix = entry_matrix(&entry);
    let cfg = entry_config(&entry, &origin);
    let outcome = run_campaign(&matrix, &cfg).unwrap();
    assert!(outcome.matches, "flagship campaign diverged");
    assert!(outcome.workers_spawned >= 200);
    assert!(outcome.net.crashes >= 1, "no crash fired: {:?}", outcome.net);
    assert!(outcome.net.partitions >= 1, "no partition opened: {:?}", outcome.net);
    assert!(
        outcome.net.dropped + outcome.net.duplicated + outcome.net.reordered >= 1,
        "the chaotic network did nothing: {:?}",
        outcome.net
    );
}

/// Dispatcher crash+resume at 200 workers, through the real journal
/// code: the `dcrash` fault kills the dispatcher mid-campaign (core,
/// journal handle, and the merger's in-memory buffer all die; preserved
/// spill runs and the write-ahead log survive), then restarts it via
/// `journal::recover` + `DispatcherCore::resume` + `adopt_run` — the
/// exact `serve --resume` path — and the report must still come out
/// byte-identical, deterministically.
#[test]
fn dispatcher_crash_and_resume_campaign_is_byte_identical() {
    let entry = SeedEntry {
        seed: 13,
        workers: 200,
        reps: 2,
        duration_ms: 1_200.0,
        faults: "latency=1..20,drop=0.02,dcrash=2".to_string(),
        lease: 0,
        lease_timeout_ms: 300,
        spill_cells: 8,
    };
    let origin = PathBuf::from("dcrash");
    let matrix = entry_matrix(&entry);
    let cfg = entry_config(&entry, &origin);
    let outcome = run_campaign(&matrix, &cfg).unwrap();
    assert!(outcome.matches, "resumed campaign diverged");
    assert!(outcome.net.dcrashes >= 1, "no dispatcher crash fired: {:?}", outcome.net);
    assert!(
        outcome.log.iter().any(|l| l.contains("dcrash#0")),
        "the crash must be in the event log"
    );
    assert!(
        outcome.log.iter().any(|l| l.contains("dispatcher resumed")),
        "the journal recovery must be in the event log"
    );
    assert!(
        outcome.workers_spawned > 200,
        "crashed-out workers reconnect under fresh ids ({} spawned)",
        outcome.workers_spawned
    );
    // Crash+resume is still a pure function of the seed.
    let again = run_campaign(&matrix, &cfg).unwrap();
    assert_eq!(outcome.report, again.report);
    assert_eq!(outcome.log_hash, again.log_hash);
    assert_eq!(outcome.net, again.net);
}

/// Same seed → same run: report bytes, the full event log, its hash, and
/// the core's stats all replay exactly.
#[test]
fn same_seed_reproduces_the_identical_event_log() {
    let entry = SeedEntry {
        seed: 0xD5,
        workers: 40,
        reps: 1,
        duration_ms: 900.0,
        faults: String::new(), // seed-derived chaos
        lease: 0,
        lease_timeout_ms: 300,
        spill_cells: 16,
    };
    let origin = PathBuf::from("same-seed");
    let matrix = entry_matrix(&entry);
    let cfg = entry_config(&entry, &origin);
    let a = run_campaign(&matrix, &cfg).unwrap();
    let b = run_campaign(&matrix, &cfg).unwrap();
    assert!(a.matches && b.matches);
    assert_eq!(a.report, b.report);
    assert_eq!(a.log, b.log, "event logs diverged between identical runs");
    assert_eq!(a.log_hash, b.log_hash);
    assert_eq!(a.virtual_ms, b.virtual_ms);
    assert_eq!(a.events, b.events);
    assert_eq!(format!("{:?}", a.stats), format!("{:?}", b.stats));
    assert_eq!(a.net, b.net);
    assert!(!a.log.is_empty(), "collect_log was on; the log cannot be empty");
}

/// Disjoint seeds → distinct fault plans and distinct schedules (both
/// reports still byte-identical to their references, of course).
#[test]
fn disjoint_seeds_produce_distinct_schedules() {
    let mk = |seed: u64| SeedEntry {
        seed,
        workers: 16,
        reps: 1,
        duration_ms: 900.0,
        faults: String::new(),
        lease: 0,
        lease_timeout_ms: 300,
        spill_cells: 32,
    };
    let origin = PathBuf::from("disjoint");
    let (ea, eb) = (mk(1), mk(2));
    let a = run_campaign(&entry_matrix(&ea), &entry_config(&ea, &origin)).unwrap();
    let b = run_campaign(&entry_matrix(&eb), &entry_config(&eb, &origin)).unwrap();
    assert!(a.matches && b.matches);
    assert_ne!(a.plan, b.plan, "two seeds drew the same fault plan");
    assert_ne!(a.log_hash, b.log_hash, "two seeds replayed the same schedule");
}

/// Plan derivation is a pure function of `(seed, workers, spec)` across
/// a spread of seeds — and neighbouring seeds never collide.
#[test]
fn fault_plans_are_deterministic_across_seeds() {
    let spec = FaultSpec::default();
    for seed in (0..25u64).map(|i| 0x5EED_0000 + i * 0x9E37) {
        let a = FaultPlan::from_seed(seed, 64, &spec);
        let b = FaultPlan::from_seed(seed, 64, &spec);
        assert_eq!(a, b, "seed {seed:#x} is not reproducible");
        let c = FaultPlan::from_seed(seed + 1, 64, &spec);
        assert_ne!(a, c, "seeds {seed:#x} and {:#x} collided", seed + 1);
    }
}

/// Fidelity cross-check: on a fault-free network, the simnet campaign,
/// the real pipes-and-processes `zygarde serve`, and the in-process
/// single-thread sweep produce the same bytes.
#[test]
fn simnet_matches_real_pipes_on_a_clean_network() {
    let entry = SeedEntry {
        seed: 29,
        workers: 2,
        reps: 1,
        duration_ms: 900.0,
        faults: "none".to_string(),
        lease: 3,
        lease_timeout_ms: 300,
        spill_cells: 6,
    };
    let origin = PathBuf::from("cross-check");
    let matrix = entry_matrix(&entry);
    let want = run_matrix(&matrix, 1).json_string();

    let sim = run_campaign(&matrix, &entry_config(&entry, &origin)).unwrap();
    assert!(sim.matches);
    assert_eq!(String::from_utf8(sim.report.clone()).unwrap(), want);
    // A clean network does exactly nothing to the traffic.
    assert_eq!(sim.net.dropped, 0, "{:?}", sim.net);
    assert_eq!(sim.net.duplicated + sim.net.reordered, 0, "{:?}", sim.net);
    assert_eq!(sim.net.crashes + sim.net.partitions + sim.net.kicks, 0, "{:?}", sim.net);

    let exe = env!("CARGO_BIN_EXE_zygarde");
    let out = std::env::temp_dir()
        .join(format!("zygarde_simnet_cross_{}.json", std::process::id()));
    let status = std::process::Command::new(exe)
        .args([
            "serve",
            "--matrix",
            "synthetic",
            "--seed",
            "29",
            "--reps",
            "1",
            "--duration-ms",
            "900",
            "--workers",
            "2",
            "--lease",
            "3",
            "--spill-cells",
            "6",
            "--quiet=true",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning zygarde serve");
    assert!(status.success(), "serve exited with {status}");
    let piped = std::fs::read_to_string(&out).expect("serve wrote the report");
    let _ = std::fs::remove_file(&out);
    assert_eq!(piped, want, "real pipes diverged from the single-process bytes");
}
