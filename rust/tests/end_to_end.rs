//! End-to-end: the full paper pipeline over real artifacts — headline
//! claims as assertions. These mirror what EXPERIMENTS.md records at full
//! scale, run here at reduced job counts to stay fast.

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::exp;

fn ready() -> bool {
    zygarde::artifacts_root().join("mnist/meta.json").exists()
}

#[test]
fn headline_early_termination_savings() {
    if !ready() {
        return;
    }
    // Paper: 5-26 % execution-time reduction via early termination.
    let rows = exp::termination::run(&["mnist", "esc10", "cifar100", "vww"]);
    let mut savings = Vec::new();
    for r in &rows {
        let s = 1.0 - r.summary.time_utility_ms / r.summary.time_full_ms;
        savings.push((r.dataset.clone(), s));
    }
    assert!(
        savings.iter().any(|(_, s)| *s > 0.05),
        "no dataset saved >5 %: {savings:?}"
    );
    for (ds, s) in &savings {
        assert!(*s > 0.0, "{ds}: early termination saved nothing");
        assert!(*s < 0.9, "{ds}: implausible saving {s}");
    }
}

#[test]
fn headline_scheduler_gains() {
    if !ready() {
        return;
    }
    // Paper: Zygarde/EDF-M schedule 9-34 % more jobs than EDF under
    // intermittent power. Check on VWW (largest), system 6 (RF, η=.51).
    let cells = exp::schedule::run("vww", &[6], Some(150), 17);
    let get = |k: SchedulerKind| {
        cells
            .iter()
            .find(|c| c.scheduler == k)
            .unwrap()
            .metrics
            .event_scheduled_rate()
    };
    let edf = get(SchedulerKind::Edf);
    let edfm = get(SchedulerKind::EdfMandatory);
    let zyg = get(SchedulerKind::Zygarde);
    assert!(
        edfm > edf && zyg > edf,
        "no gain over EDF: edf={edf} edfm={edfm} zyg={zyg}"
    );
    let gain = (zyg - edf) / edf.max(1e-9);
    assert!(gain > 0.05, "gain only {:.1}%", gain * 100.0);
}

#[test]
fn headline_solar_beats_rf_at_same_eta() {
    if !ready() {
        return;
    }
    // Paper §8.5: despite the same η, solar systems schedule 9-31 % more
    // jobs than RF due to more available power. Compare S2 vs S5 (η=.71).
    let cells = exp::schedule::run("cifar100", &[2, 5], Some(60), 23);
    let rate = |sid: usize| {
        cells
            .iter()
            .filter(|c| c.system.id == sid)
            .map(|c| c.metrics.event_scheduled_rate())
            .sum::<f64>()
            / 3.0
    };
    let solar = rate(2);
    let rf = rate(5);
    assert!(solar > rf, "solar {solar} <= rf {rf}");
}

#[test]
fn headline_zygarde_converges_to_edfm_at_low_eta() {
    if !ready() {
        return;
    }
    // Paper §8.5: "Zygarde increases the performance from EDF-M when η is
    // high. With low η, the performance of Zygarde and EDF-M becomes
    // similar as no optional units are executed." Verify the mechanism on
    // solar: optional units run at η = .71 (S2) and not at η = .38 (S4),
    // where Zygarde's metrics coincide with EDF-M's.
    let cells = exp::schedule::run("vww", &[2, 4], Some(120), 31);
    let get = |sid: usize, k: SchedulerKind| {
        &cells
            .iter()
            .find(|c| c.system.id == sid && c.scheduler == k)
            .unwrap()
            .metrics
    };
    let zyg_hi = get(2, SchedulerKind::Zygarde);
    let zyg_lo = get(4, SchedulerKind::Zygarde);
    let edfm_lo = get(4, SchedulerKind::EdfMandatory);
    assert!(zyg_hi.optional_units > 0, "no optional units at η=.71");
    assert_eq!(zyg_lo.optional_units, 0, "optional units ran at η=.38");
    let diff = (zyg_lo.event_scheduled_rate() - edfm_lo.event_scheduled_rate()).abs();
    assert!(
        diff < 0.05,
        "at low η Zygarde should track EDF-M: zyg={} edfm={}",
        zyg_lo.event_scheduled_rate(),
        edfm_lo.event_scheduled_rate()
    );
}

#[test]
fn full_cli_smoke() {
    if !ready() {
        return;
    }
    // The CLI drivers that finish quickly, exercised end to end.
    let studies = exp::eta::run(12, 5);
    assert_eq!(studies.len(), 4);
    let esc = zygarde::dnn::network::Network::load(
        &zygarde::artifacts_root().join("esc10"),
    )
    .unwrap();
    let rows = exp::overhead::run(&esc);
    assert!(!rows.is_empty());
    let sched = exp::schedulability::run(&["esc10"], &[0.5]);
    assert!(sched[0].analysis.feasible);
    let adapt = exp::adaptation::run();
    assert_eq!(adapt.len(), 3);
}
