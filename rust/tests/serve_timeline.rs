//! Serve-timeline suite: the unified campaign trace
//! (`telemetry::timeline`, `zygarde simtest --trace-out`) over the
//! committed simnet seed corpus. Every corpus campaign is replayed with
//! the timeline recorder attached; the rendered Chrome document must be
//! structurally well-formed (the same rules `tools/trace_check.py
//! --timeline` enforces in CI), byte-identical across repeat runs of the
//! same seed (virtual-clock stamps make it a pure function of the seed),
//! and recording it must not change one byte of the campaign itself.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use zygarde::exp::sweep_cli::{build_matrix, SweepOpts};
use zygarde::sim::sweep::serve::simnet::{run_campaign, FaultSpec, SimConfig};
use zygarde::sim::sweep::ScenarioMatrix;
use zygarde::util::json::Value;

const TID_DISPATCH: u64 = 0;
const TID_JOURNAL: u64 = 1;
const TID_FAULTS: u64 = 2;
const TID_WORKER_BASE: u64 = 100;
const FAULT_KINDS: [&str; 6] = ["crash", "partition", "dcrash", "heal", "kick", "relief"];
const DISPATCH_INSTANTS: [&str; 2] = ["spill-run", "done"];
const JOURNAL_INSTANTS: [&str; 3] = ["recover", "run-adopted", "finalize"];
const WORKER_INSTANTS: [&str; 3] = ["connect", "gone", "cells"];
const LEASE_OUTCOMES: [&str; 3] = ["done", "gone", "unresolved"];

/// Minimal mirror of the corpus line format (see `sweep_simnet.rs`,
/// which owns the full replay contract): whitespace-separated
/// `key=value` tokens with `zygarde simtest` defaults.
struct SeedEntry {
    seed: u64,
    workers: usize,
    reps: u64,
    duration_ms: f64,
    faults: String,
    lease: usize,
    lease_timeout_ms: u64,
    spill_cells: usize,
}

fn parse_seed_entry(text: &str, origin: &Path) -> SeedEntry {
    let mut e = SeedEntry {
        seed: 0,
        workers: 32,
        reps: 2,
        duration_ms: 6_000.0,
        faults: String::new(),
        lease: 0,
        lease_timeout_ms: 300,
        spill_cells: 32,
    };
    for tok in text.split_whitespace() {
        let (key, val) = tok
            .split_once('=')
            .unwrap_or_else(|| panic!("{}: `{tok}` is not key=value", origin.display()));
        match key {
            "seed" => e.seed = val.parse().unwrap(),
            "workers" => e.workers = val.parse().unwrap(),
            "reps" => e.reps = val.parse().unwrap(),
            "duration-ms" => e.duration_ms = val.parse().unwrap(),
            "faults" => e.faults = val.to_string(),
            "lease" => e.lease = val.parse().unwrap(),
            "lease-timeout-ms" => e.lease_timeout_ms = val.parse().unwrap(),
            "spill-cells" => e.spill_cells = val.parse().unwrap(),
            other => panic!("{}: unknown seed key `{other}`", origin.display()),
        }
    }
    e
}

fn entry_matrix(e: &SeedEntry) -> ScenarioMatrix {
    let opts = SweepOpts {
        seed: e.seed,
        reps: e.reps,
        duration_ms: Some(e.duration_ms),
        ..Default::default()
    };
    build_matrix("synthetic", &opts).unwrap()
}

fn entry_config(e: &SeedEntry, origin: &Path) -> SimConfig {
    let spec = FaultSpec::parse(&e.faults)
        .unwrap_or_else(|err| panic!("{}: {err}", origin.display()));
    let mut cfg = SimConfig::new(e.seed, e.workers);
    cfg.spec = spec;
    cfg.lease_size = e.lease;
    cfg.lease_timeout_ms = e.lease_timeout_ms;
    cfg.spill_cells = e.spill_cells;
    cfg.threads = 2;
    cfg.trace = true;
    cfg
}

fn corpus_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/seeds/serve")
}

fn num(e: &Value, key: &str) -> f64 {
    e.get(key)
        .and_then(Value::as_f64)
        .unwrap_or_else(|| panic!("event lacks numeric {key}: {}", e.to_json()))
}

/// Structural well-formedness — the Rust twin of `trace_check.py
/// --timeline`. Returns the `tid -> thread_name` map for extra asserts.
fn check_timeline(body: &str, origin: &str) -> BTreeMap<u64, String> {
    let doc = Value::parse(body).unwrap_or_else(|e| panic!("{origin}: not JSON: {e}"));
    let events = doc
        .get("traceEvents")
        .and_then(Value::as_arr)
        .unwrap_or_else(|| panic!("{origin}: no traceEvents list"));

    let mut tracks: BTreeMap<u64, String> = BTreeMap::new();
    let mut used: Vec<u64> = Vec::new();
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    for e in events {
        let ph = e.get("ph").and_then(Value::as_str).expect("ph");
        let name = e.get("name").and_then(Value::as_str).expect("name");
        let tid = num(e, "tid") as u64;
        if ph == "M" {
            if name == "thread_name" {
                let n = e.get("args").unwrap().get("name").unwrap().as_str().unwrap();
                tracks.insert(tid, n.to_string());
            }
            continue;
        }
        used.push(tid);
        let ts = num(e, "ts");
        assert!(ts >= 0.0, "{origin}: negative ts on {name}");
        match ph {
            "X" => {
                assert!(tid >= TID_WORKER_BASE, "{origin}: X span {name} off worker tracks");
                let args = e.get("args").unwrap_or_else(|| panic!("{origin}: {name} has no args"));
                let (start, end) = (num(args, "start"), num(args, "end"));
                assert!(end >= start, "{origin}: {name} has end < start");
                assert_eq!(
                    name,
                    format!("lease {}", num(args, "lease") as u64),
                    "{origin}: span name does not match args.lease"
                );
                assert!(num(args, "cells") >= 0.0);
                assert!(num(e, "dur") >= 0.0, "{origin}: negative dur on {name}");
                let outcome = args.get("outcome").and_then(Value::as_str).unwrap_or("");
                assert!(
                    LEASE_OUTCOMES.contains(&outcome),
                    "{origin}: {name} outcome {outcome:?} not in {LEASE_OUTCOMES:?}"
                );
            }
            "i" => {
                // Instants must be in stream order per track (X spans
                // are retroactive and exempt).
                if let Some(&prev) = last_ts.get(&tid) {
                    assert!(ts >= prev, "{origin}: ts went backwards on tid {tid}");
                }
                last_ts.insert(tid, ts);
                let vocab: &[&str] = if tid == TID_DISPATCH {
                    &DISPATCH_INSTANTS
                } else if tid == TID_JOURNAL {
                    &JOURNAL_INSTANTS
                } else if tid == TID_FAULTS {
                    &FAULT_KINDS
                } else if tid >= TID_WORKER_BASE {
                    &WORKER_INSTANTS
                } else {
                    panic!("{origin}: instant {name} on unknown tid {tid}");
                };
                assert!(vocab.contains(&name), "{origin}: {name:?} not in tid {tid}'s vocabulary");
            }
            other => panic!("{origin}: unexpected phase {other:?}"),
        }
    }
    assert_eq!(
        tracks.get(&TID_DISPATCH).map(String::as_str),
        Some("dispatcher"),
        "{origin}: tid 0 is not named dispatcher"
    );
    for tid in used {
        let want = if tid == TID_JOURNAL {
            Some("journal".to_string())
        } else if tid == TID_FAULTS {
            Some("faults".to_string())
        } else if tid >= TID_WORKER_BASE {
            Some(format!("worker {}", tid - TID_WORKER_BASE))
        } else {
            None
        };
        if let Some(want) = want {
            assert_eq!(
                tracks.get(&tid),
                Some(&want),
                "{origin}: tid {tid} carries events but is not named {want:?}"
            );
        }
    }
    tracks
}

/// Every committed seed replays with the timeline attached: the campaign
/// still streams byte-identical, the document is well-formed, and a
/// second run of the same seed renders the identical bytes (virtual
/// clock — no wall time anywhere).
#[test]
fn corpus_timelines_are_well_formed_and_pure_functions_of_the_seed() {
    let dir = corpus_dir();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|ent| ent.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "seed"))
        .collect();
    paths.sort();
    assert!(!paths.is_empty(), "seed corpus at {} is empty", dir.display());
    for path in paths {
        let origin = path.display().to_string();
        let text = std::fs::read_to_string(&path).unwrap();
        let entry = parse_seed_entry(&text, &path);
        let matrix = entry_matrix(&entry);
        let cfg = entry_config(&entry, &path);
        let outcome = run_campaign(&matrix, &cfg).unwrap_or_else(|e| panic!("{origin}: {e}"));
        assert!(outcome.matches, "{origin}: traced campaign diverged");
        let timeline = outcome.timeline.as_ref().unwrap_or_else(|| {
            panic!("{origin}: cfg.trace was on but no timeline came back")
        });
        check_timeline(timeline, &origin);
        let again = run_campaign(&matrix, &cfg).unwrap();
        assert_eq!(
            Some(timeline),
            again.timeline.as_ref(),
            "{origin}: same seed rendered different timeline bytes"
        );
    }
}

/// The dcrash flagship (the committed seed_13 campaign): the timeline
/// must put the dispatcher crashes, the journal recoveries, and the
/// per-worker lease spans on one time axis, stamped by the virtual
/// clock.
#[test]
fn dcrash_flagship_timeline_shows_recovery_across_all_tracks() {
    let entry = SeedEntry {
        seed: 13,
        workers: 200,
        reps: 2,
        duration_ms: 1_200.0,
        faults: "latency=1..20,drop=0.02,dcrash=2".to_string(),
        lease: 0,
        lease_timeout_ms: 300,
        spill_cells: 8,
    };
    let origin = PathBuf::from("dcrash-flagship");
    let matrix = entry_matrix(&entry);
    let cfg = entry_config(&entry, &origin);
    let outcome = run_campaign(&matrix, &cfg).unwrap();
    assert!(outcome.matches);
    let body = outcome.timeline.as_ref().unwrap();
    let tracks = check_timeline(body, "dcrash-flagship");
    assert_eq!(tracks.get(&TID_JOURNAL).map(String::as_str), Some("journal"));
    assert_eq!(tracks.get(&TID_FAULTS).map(String::as_str), Some("faults"));
    let workers = tracks.keys().filter(|&&t| t >= TID_WORKER_BASE).count();
    assert!(workers >= 200, "only {workers} worker tracks for 200 workers");

    let doc = Value::parse(body).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let on_tid = |tid: u64, name: &str| {
        events
            .iter()
            .filter(|e| {
                e.get("tid").and_then(Value::as_f64) == Some(tid as f64)
                    && e.get("name").and_then(Value::as_str) == Some(name)
            })
            .count()
    };
    let dcrashes = on_tid(TID_FAULTS, "dcrash");
    assert!(dcrashes >= 1, "no dcrash marker on the faults track");
    assert_eq!(dcrashes as u64, outcome.net.dcrashes, "marker count vs transport count");
    assert_eq!(
        on_tid(TID_JOURNAL, "recover"),
        dcrashes,
        "every dispatcher crash must be followed by a journal recovery"
    );
    // Every timestamp (and span end) fits inside the campaign's virtual
    // duration — wall time never leaks in.
    let end_us = outcome.virtual_ms as f64 * 1000.0;
    for e in events {
        if e.get("ph").and_then(Value::as_str) == Some("M") {
            continue;
        }
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        let dur = e.get("dur").and_then(Value::as_f64).unwrap_or(0.0);
        assert!(ts + dur <= end_us, "event past the virtual clock: {}", e.to_json());
    }
    // The crashes killed lease holders, so some spans resolved `gone`.
    let outcomes: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
        .map(|e| e.get("args").unwrap().get("outcome").unwrap().as_str().unwrap())
        .collect();
    assert!(!outcomes.is_empty(), "no lease spans");
    assert!(outcomes.contains(&"gone"), "no lease resolved as gone under dcrash");
}

/// Recording the timeline must not perturb the campaign: same report
/// bytes, same event-log hash, with and without the recorder.
#[test]
fn timeline_recording_is_a_passive_observer() {
    let entry = SeedEntry {
        seed: 7,
        workers: 24,
        reps: 1,
        duration_ms: 900.0,
        faults: String::new(),
        lease: 0,
        lease_timeout_ms: 300,
        spill_cells: 16,
    };
    let origin = PathBuf::from("passive");
    let matrix = entry_matrix(&entry);
    let traced_cfg = entry_config(&entry, &origin);
    let mut plain_cfg = entry_config(&entry, &origin);
    plain_cfg.trace = false;
    let traced = run_campaign(&matrix, &traced_cfg).unwrap();
    let plain = run_campaign(&matrix, &plain_cfg).unwrap();
    assert!(traced.matches && plain.matches);
    assert!(traced.timeline.is_some());
    assert!(plain.timeline.is_none(), "trace off must not render a timeline");
    assert_eq!(traced.report, plain.report, "recording changed the report bytes");
    assert_eq!(traced.log_hash, plain.log_hash, "recording changed the schedule");
    assert_eq!(traced.virtual_ms, plain.virtual_ms);
}
