//! The sweep engine's core contract: the same [`ScenarioMatrix`] produces
//! a **bitwise-identical** [`SweepReport`] regardless of thread count or
//! execution order — so any failing seed replays exactly, and the
//! recorded-seed table below turns past failures into regression cases.

use zygarde::clock::{ChrtTier, ClockSpec};
use zygarde::coordinator::sched::SchedulerKind;
use zygarde::energy::harvester::HarvesterKind;
use zygarde::sim::sweep::{
    run_matrix, run_scenario, FaultPlan, HarvesterSpec, ScenarioMatrix, TaskMix,
};

/// A 64-scenario matrix covering every dimension: two harvesters (one a
/// calibrated Table 4 system), two capacitor sizes, two schedulers, two
/// fault plans (clean vs brownout bursts + CHRT skew), two task mixes,
/// and two seeds. Short horizon keeps the whole grid under a second.
fn full_matrix(seed: u64) -> ScenarioMatrix {
    ScenarioMatrix::new("determinism-64", seed)
        .mixes(vec![
            TaskMix::synthetic("uni", 1, 3, seed ^ 0xA),
            TaskMix::synthetic("duo", 2, 2, seed ^ 0xB),
        ])
        .harvesters(vec![
            HarvesterSpec::System(6),
            HarvesterSpec::Markov {
                kind: HarvesterKind::Solar,
                on_power_mw: 400.0,
                q: 0.92,
                duty: 0.5,
                eta: 0.6,
            },
        ])
        .capacitors_mf(vec![5.0, 50.0])
        .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::EdfMandatory])
        .faults(vec![
            FaultPlan::none(),
            FaultPlan::none()
                .with_brownouts(1_500.0, 300.0, 100.0)
                .with_clock(ClockSpec::Chrt(ChrtTier::Tier3)),
        ])
        .reps(2)
        .duration_ms(6_000.0)
}

#[test]
fn report_is_bitwise_identical_at_1_and_8_threads() {
    let m = full_matrix(0xD5EED);
    assert!(m.len() >= 64, "matrix must cover >= 64 scenarios, got {}", m.len());
    let single = run_matrix(&m, 1);
    let eight = run_matrix(&m, 8);
    assert_eq!(single.n_scenarios, m.len());
    // Byte-for-byte: counters, f64 energy accounting, latencies, summary.
    assert_eq!(single.json_string(), eight.json_string());
    // And not vacuously: the grid actually exercised the system.
    assert!(single.summary.released > 0);
    assert!(single.summary.reboots > 0, "bursty cells should reboot");
}

#[test]
fn intermediate_thread_counts_agree_too() {
    let m = full_matrix(0x1CE);
    let reference = run_matrix(&m, 1).json_string();
    for threads in [2usize, 3, 5] {
        assert_eq!(
            reference,
            run_matrix(&m, threads).json_string(),
            "{threads} threads diverged"
        );
    }
}

#[test]
fn different_matrix_seeds_give_different_reports() {
    let a = run_matrix(&full_matrix(1), 4).json_string();
    let b = run_matrix(&full_matrix(2), 4).json_string();
    assert_ne!(a, b, "matrix seed must drive the outcome");
}

/// Seeds recorded from earlier sweep runs that exercised nasty edge
/// regimes (brownout mid-fragment on a tiny capacitor, CHRT negative skew
/// across long outages, queue-full eviction under flooding). Each replays
/// as a single-scenario matrix; the engine must stay deterministic and
/// uphold the basic accounting identity on every one of them. Append new
/// entries when a sweep failure is diagnosed: the seed IS the repro.
const RECORDED_SEEDS: &[(u64, &str)] = &[
    (0x000000BAD5EED, "1 mF capacitor, RF bursts: re-execution thrash"),
    (0x00000000C0FFEE, "brownout bursts aligned with release period"),
    (0x0000000000D1CE, "CHRT tier-3 skew with sub-second deadlines"),
    (0x0000000FEEDBEEF, "queue flooding: eviction + drops under overload"),
];

#[test]
fn recorded_failing_seeds_replay_deterministically() {
    for &(seed, what) in RECORDED_SEEDS {
        let m = ScenarioMatrix::new("regression", seed)
            .mixes(vec![TaskMix::synthetic("stress", 2, 3, seed)])
            .harvesters(vec![HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 90.0,
                q: 0.85,
                duty: 0.55,
                eta: 0.45,
            }])
            .capacitors_mf(vec![1.0])
            .faults(vec![FaultPlan::none()
                .with_brownouts(900.0, 300.0, 0.0)
                .with_clock(ClockSpec::Chrt(ChrtTier::Tier3))])
            .queue_size(2)
            .duration_ms(8_000.0)
            .log_jobs(true);
        let a = run_matrix(&m, 1);
        let b = run_matrix(&m, 2);
        assert_eq!(a.json_string(), b.json_string(), "{what}: replay diverged");

        // Accounting identity on the stressed cell: every released job is
        // scheduled, missed, dropped, or still queued at the horizon.
        let cell = &a.cells[0].metrics;
        assert!(
            cell.scheduled + cell.deadline_missed + cell.queue_dropped <= cell.released,
            "{what}: accounting identity violated: {cell:?}"
        );
    }
}

/// A scenario is a pure function of its spec: running one cell in
/// isolation equals the same cell inside the full parallel sweep.
#[test]
fn single_scenario_replay_matches_sweep_cell() {
    let m = full_matrix(0x7E57);
    let scenarios = m.expand();
    let report = run_matrix(&m, 8);
    for idx in [0usize, 17, 40, 63] {
        let solo = run_scenario(&scenarios[idx]);
        assert_eq!(
            solo.metrics.to_json().to_json(),
            report.cells[idx].metrics.to_json().to_json(),
            "cell {idx} ({}) differs when replayed alone",
            report.cells[idx].label
        );
    }
}
