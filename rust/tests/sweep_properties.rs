//! Property-based tests over sweep invariants, using the in-crate
//! `util::prop` harness (seed overridable via PROP_SEED). Each case is a
//! randomly generated single-scenario matrix — random harvester, capacitor,
//! scheduler, queue size, fault plan — run to completion:
//!
//! 1. capacitor energy never goes negative (and never exceeds capacity),
//! 2. no job is counted as scheduled after its deadline,
//! 3. fragment re-execution never double-counts completed work,
//! 4. NVM accounting: commits never exceed executed fragments, rollbacks
//!    never lose more than was completed, and total energy is conserved
//!    including commit/restore costs.

use std::cell::Cell;
use std::rc::Rc;

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::energy::harvester::HarvesterKind;
use zygarde::nvm::{NvmModelKind, NvmSpec};
use zygarde::sim::sweep::{
    build_engine, FaultPlan, HarvesterSpec, Scenario, ScenarioMatrix, TaskMix,
};
use zygarde::util::prop::{forall, Config, Size};
use zygarde::util::rng::Pcg32;

/// Fragments per unit in `synthetic_task` workloads (its cost model).
const FRAGS_PER_UNIT: u64 = 4;

fn random_scenario(rng: &mut Pcg32, size: Size) -> Scenario {
    let n_tasks = 1 + rng.below(2) as usize;
    let n_units = 1 + rng.below(3) as usize;
    let scheduler = *rng.choice(&[
        SchedulerKind::Zygarde,
        SchedulerKind::Edf,
        SchedulerKind::EdfMandatory,
        SchedulerKind::RoundRobin,
    ]);
    let capacitor_mf = *rng.choice(&[1.0, 5.0, 50.0]);
    let harvester = if rng.chance(0.3) {
        HarvesterSpec::Persistent { power_mw: 200.0 + rng.f64() * 400.0 }
    } else {
        HarvesterSpec::Markov {
            kind: HarvesterKind::Rf,
            on_power_mw: 40.0 + rng.f64() * 160.0,
            q: 0.7 + rng.f64() * 0.25,
            duty: 0.3 + rng.f64() * 0.6,
            eta: 0.3 + rng.f64() * 0.6,
        }
    };
    let fault = if rng.chance(0.5) {
        FaultPlan::none()
    } else {
        FaultPlan::none().with_brownouts(
            500.0 + rng.f64() * 2000.0,
            rng.f64() * 500.0,
            rng.f64() * 300.0,
        )
    };
    let nvm = *rng.choice(&[
        NvmSpec::ideal(),
        NvmSpec::fram_every_fragment(),
        NvmSpec::fram_unit_boundary(),
        NvmSpec::fram_jit(),
    ]);
    ScenarioMatrix::new("prop", rng.next_u64())
        .mixes(vec![TaskMix::synthetic("m", n_tasks, n_units, rng.next_u64())])
        .harvesters(vec![harvester])
        .capacitors_mf(vec![capacitor_mf])
        .schedulers(vec![scheduler])
        .faults(vec![fault])
        .nvms(vec![nvm])
        .precharge(rng.chance(0.7))
        .queue_size(1 + rng.below(3) as usize)
        .duration_ms(2_000.0 + 1_000.0 * size.0.min(6) as f64)
        .log_jobs(true)
        .expand()
        .pop()
        .unwrap()
}

fn cfg() -> Config {
    Config { iters: 48, ..Default::default() }
}

#[test]
fn capacitor_energy_never_negative() {
    forall("capacitor-energy-in-bounds", cfg(), random_scenario, |sc| {
        let mut engine = build_engine(sc);
        let cap_mj = engine.energy.capacitor.capacity_mj();
        let min_seen = Rc::new(Cell::new(f64::INFINITY));
        let over_cap = Rc::new(Cell::new(false));
        {
            let min_seen = min_seen.clone();
            let over_cap = over_cap.clone();
            engine.probe = Some(Box::new(move |_t, em, _m| {
                let e = em.capacitor.energy_mj();
                if e < min_seen.get() {
                    min_seen.set(e);
                }
                if e > cap_mj * (1.0 + 1e-9) {
                    over_cap.set(true);
                }
            }));
        }
        let _ = engine.run();
        if min_seen.get() < -1e-9 {
            return Err(format!("capacitor energy went negative: {}", min_seen.get()));
        }
        if over_cap.get() {
            return Err("capacitor energy exceeded capacity".to_string());
        }
        Ok(())
    });
}

#[test]
fn no_job_counted_scheduled_after_deadline() {
    forall("scheduled-implies-on-time", cfg(), random_scenario, |sc| {
        let m = build_engine(sc).run();
        for r in &m.job_log {
            if r.counted_scheduled {
                match r.mandatory_done_at {
                    Some(at) if at <= r.deadline_ms + 1e-9 => {}
                    other => {
                        return Err(format!(
                            "job of task {} counted scheduled with mandatory_done_at \
                             {other:?} vs deadline {}",
                            r.task, r.deadline_ms
                        ))
                    }
                }
            }
        }
        // The audit trail and the counter must agree exactly.
        let counted = m.job_log.iter().filter(|r| r.counted_scheduled).count() as u64;
        if counted != m.scheduled {
            return Err(format!(
                "job_log says {counted} scheduled, counter says {}",
                m.scheduled
            ));
        }
        Ok(())
    });
}

#[test]
fn fragment_reexecution_never_double_counts() {
    forall("fragment-accounting", cfg(), random_scenario, |sc| {
        let m = build_engine(sc).run();
        if m.refragments > m.fragments {
            return Err("more re-executions than attempts".to_string());
        }
        let successful = m.fragments - m.refragments;
        let units = m.mandatory_units + m.optional_units;
        // Every completed unit consumed exactly FRAGS_PER_UNIT successful
        // fragments; re-executed (lost) fragments must not be credited.
        if successful < units * FRAGS_PER_UNIT {
            return Err(format!(
                "completed units claim more successful fragments than ran: \
                 successful={successful} units={units}"
            ));
        }
        // Successes beyond completed units are partial in-flight unit
        // progress (strictly less than one unit's worth per released job)
        // plus whatever NVM rollbacks forced into re-execution.
        if successful >= (units + m.released + 1) * FRAGS_PER_UNIT + m.lost_fragments {
            return Err(format!(
                "fragment successes double-counted: successful={successful} \
                 units={units} released={} lost={}",
                m.released, m.lost_fragments
            ));
        }
        // Every released job is scheduled, missed, dropped, or in-queue.
        if m.scheduled + m.deadline_missed + m.queue_dropped > m.released {
            return Err(format!("job accounting identity violated: {m:?}"));
        }
        Ok(())
    });
}

#[test]
fn nvm_commit_and_rollback_accounting() {
    forall("nvm-accounting", cfg(), random_scenario, |sc| {
        let m = build_engine(sc).run();
        let successful = m.fragments - m.refragments;
        // Committed work can never exceed executed work: each commit
        // transaction follows at least one fragment success or unit
        // completion that made state dirty.
        if m.commits > successful + m.mandatory_units + m.optional_units + 1 {
            return Err(format!(
                "more commits than commit points: commits={} successful={successful} \
                 units={}",
                m.commits,
                m.mandatory_units + m.optional_units
            ));
        }
        // A rollback can only lose fragments that actually completed.
        if m.lost_fragments > successful {
            return Err(format!(
                "lost more fragments than ever succeeded: lost={} successful={successful}",
                m.lost_fragments
            ));
        }
        // JIT commits are a subset of all commits; restores follow reboots.
        if m.jit_commits > m.commits {
            return Err(format!("jit {} > commits {}", m.jit_commits, m.commits));
        }
        if m.restores > m.reboots {
            return Err(format!("restores {} > reboots {}", m.restores, m.reboots));
        }
        // The ideal model charges nothing — and the ideal every-fragment
        // policy (the default) never has uncommitted work to lose.
        if sc.nvm.model == NvmModelKind::Ideal {
            if m.commit_mj != 0.0 || m.restore_mj != 0.0 {
                return Err(format!(
                    "ideal NVM charged energy: commit={} restore={}",
                    m.commit_mj, m.restore_mj
                ));
            }
            if sc.nvm == NvmSpec::ideal() && m.lost_fragments != 0 {
                return Err(format!(
                    "ideal every-fragment lost work: {}",
                    m.lost_fragments
                ));
            }
        }
        Ok(())
    });
}

/// Idle-regime invariants around the off-phase fast-forward:
///
/// 1. time decomposes — `on_time_ms` plus the off idle ticks a probe
///    observes reconstructs `sim_time_ms` (every advance of the clock is
///    either MCU-on work, on-idle, or an off idle tick);
/// 2. boot edges are schedule-invariant — the optimized stepper counts
///    exactly the reboots the naive reference stepper counts (the
///    fast-forward may never move a boot to a different tick).
#[test]
fn idle_regime_time_reconstruction_and_boot_parity() {
    forall("idle-regime-invariants", cfg(), random_scenario, |sc| {
        // Boot-edge parity, fast vs reference (byte equality of the full
        // metrics JSON is the differential suite's job; reboots is the
        // one counter a coarsened off phase would corrupt first).
        let fast = build_engine(sc).run();
        let mut re = build_engine(sc);
        re.reference = true;
        let reference = re.run();
        if fast.reboots != reference.reboots {
            return Err(format!(
                "boot edges moved: fast {} vs reference {}",
                fast.reboots, reference.reboots
            ));
        }
        if fast.on_time_ms.to_bits() != reference.on_time_ms.to_bits() {
            return Err(format!(
                "on-time diverged: fast {} vs reference {}",
                fast.on_time_ms, reference.on_time_ms
            ));
        }

        // Time reconstruction via a probe (probes force naive stepping,
        // which observes every idle tick; MCU-on time that bypasses the
        // probe — fragments, NVM transactions — is in on_time_ms).
        let mut probed = build_engine(sc);
        let off_ticks = Rc::new(Cell::new(0u64));
        {
            let off_ticks = off_ticks.clone();
            probed.probe = Some(Box::new(move |_now, em, _m| {
                if !em.capacitor.mcu_on() {
                    off_ticks.set(off_ticks.get() + 1);
                }
            }));
        }
        let m = probed.run();
        let off_ms = off_ticks.get() as f64 * 5.0; // SimConfig::default idle_tick_ms
        let tol = 1e-6 * (1.0 + m.sim_time_ms);
        if (m.on_time_ms + off_ms - m.sim_time_ms).abs() > tol {
            return Err(format!(
                "time does not decompose: on {} + off {} != sim {}",
                m.on_time_ms, off_ms, m.sim_time_ms
            ));
        }
        Ok(())
    });
}

#[test]
fn energy_conserved_including_commit_and_restore() {
    forall("nvm-energy-conservation", cfg(), random_scenario, |sc| {
        let m = build_engine(sc).run();
        // Everything that entered storage either remains, was clipped at
        // the rail (wasted), or was drawn (fragments, idle, sensor reads,
        // NVM commits and restores, brownout remnants).
        let lhs = m.initial_energy_mj + m.harvested_mj;
        let rhs = m.final_energy_mj + m.wasted_mj + m.consumed_mj;
        let tol = 1e-6 * (1.0 + lhs.abs());
        if (lhs - rhs).abs() > tol {
            return Err(format!(
                "energy not conserved: initial {} + harvested {} != final {} + \
                 wasted {} + consumed {} (diff {})",
                m.initial_energy_mj,
                m.harvested_mj,
                m.final_energy_mj,
                m.wasted_mj,
                m.consumed_mj,
                lhs - rhs
            ));
        }
        // NVM spending is part of (not on top of) the consumed total.
        if m.commit_mj + m.restore_mj > m.consumed_mj + tol {
            return Err(format!(
                "NVM charged {} mJ but only {} mJ was ever drawn",
                m.commit_mj + m.restore_mj,
                m.consumed_mj
            ));
        }
        Ok(())
    });
}
