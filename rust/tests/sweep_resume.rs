//! Property suite for resumable serves: crash the dispatcher at random
//! points, recover the write-ahead journal, and assert the resumed
//! campaign (a) streams a report **byte-identical** to the single-process
//! `SweepReport::json_string()` and (b) never recomputes a cell the
//! journal already covers (`DispatchStats::cells_received` of the resumed
//! core counts exactly the missing cells). Torn tails are exercised by
//! truncating the journal at arbitrary byte offsets: every cut either
//! recovers to a byte-identical report or fails loudly with the offending
//! byte offset — never a divergent report.
//!
//! The driver below mirrors the serve shell's wiring exactly — a
//! preserving [`SpillMerger`] whose freshly spilled runs are committed to
//! the [`Journal`] the moment they land — so what crashes here is the
//! same state machine `zygarde serve --journal`/`--resume` runs. The
//! real-process path (pipes, `kill -9`, TCP reconnect) is covered by the
//! CI serve job; the seeded `dcrash` fault in `sweep_simnet.rs` covers
//! crash+resume at scale.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::sim::sweep::serve::{
    recover, DispatchStats, DispatcherCore, Journal, Msg, Out, SpillMerger,
};
use zygarde::sim::sweep::shard::fingerprint;
use zygarde::sim::sweep::{run_matrix, CellResult, HarvesterSpec, ScenarioMatrix};
use zygarde::util::json::Value;
use zygarde::util::rng::Pcg32;

fn matrix(seed: u64) -> ScenarioMatrix {
    ScenarioMatrix::new("resume-test", seed)
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 600.0 },
            HarvesterSpec::Persistent { power_mw: 150.0 },
        ])
        .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
        .reps(3)
        .duration_ms(1_500.0)
}

fn temp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("zygarde_resume_{tag}_{}", std::process::id()))
}

fn cleanup(paths: &[&Path]) {
    for p in paths {
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_dir_all(p);
    }
}

/// Drive one journaled serve session with a single simulated worker that
/// replays the precomputed reference cells (determinism makes replay and
/// recompute indistinguishable). `resume` recovers `journal_path` first,
/// exactly like `serve --resume`; `stop_after` kills the session (no
/// finalize, handles dropped where they stand) once that many cells have
/// been ingested. Returns the report (None if crashed) and the stats of
/// this core instance — the recompute-count witness.
#[allow(clippy::too_many_arguments)]
fn drive(
    m: &ScenarioMatrix,
    cells: &[CellResult],
    journal_path: &Path,
    spill_dir: &Path,
    spill_limit: usize,
    resume: bool,
    stop_after: Option<usize>,
    rng_seed: u64,
) -> (Option<String>, DispatchStats) {
    let fp = fingerprint(m);
    let n = fp.n_scenarios;
    let (mut core, mut merger, mut journal) = if resume {
        let rec = recover(journal_path).unwrap();
        rec.verify_matches(&fp, &Value::Null, journal_path).unwrap();
        assert!(!rec.finalized, "a finalized journal cannot resume");
        let mut merger = SpillMerger::new(spill_dir.to_path_buf(), spill_limit).unwrap();
        merger.set_preserve(true);
        for run in &rec.runs {
            merger.adopt_run(run).unwrap();
        }
        let journal = Journal::resume(journal_path, &rec).unwrap();
        let core = DispatcherCore::resume(
            &m.name,
            Value::Null,
            fp.clone(),
            4,
            0,
            rec.received.clone(),
        );
        (core, merger, journal)
    } else {
        let journal = Journal::create(journal_path, &fp, &Value::Null).unwrap();
        let mut merger = SpillMerger::new(spill_dir.to_path_buf(), spill_limit).unwrap();
        merger.set_preserve(true);
        let core = DispatcherCore::new(&m.name, Value::Null, fp.clone(), 4, 0);
        (core, merger, journal)
    };
    let mut rng = Pcg32::new(rng_seed, 0x7357);
    let mut done = core.is_done();
    // A journal that already covers every cell needs no worker at all.
    let mut inflight: Vec<Out> = if done { Vec::new() } else { core.on_connect(0) };
    let mut outbox: VecDeque<Msg> = VecDeque::new();
    let mut now = 0u64;
    while !done {
        now += 1;
        for o in std::mem::take(&mut inflight) {
            match o {
                Out::Send(_, Msg::Matrix { .. }) => {
                    outbox.push_back(Msg::Ready { fingerprint: fp.clone() });
                }
                Out::Send(_, Msg::Lease { id, start, end }) => {
                    let mut at = start;
                    while at < end {
                        let stop = (at + 1 + rng.below(3) as usize).min(end);
                        outbox.push_back(Msg::Cells {
                            lease: id,
                            cells: cells[at..stop].to_vec(),
                        });
                        at = stop;
                    }
                    outbox.push_back(Msg::LeaseDone { lease: id });
                }
                Out::Send(_, Msg::Shutdown) => outbox.clear(),
                Out::Send(_, other) => panic!("unexpected dispatcher send {other:?}"),
                Out::Ingest(cell) => {
                    merger.push(cell).unwrap();
                    // The serve shell's write-through: ranges first, then
                    // the run manifest that commits them.
                    for info in merger.take_spilled() {
                        journal.append_spill(&info.ranges, &info.record).unwrap();
                    }
                }
                Out::Done => done = true,
                Out::Kick(w) => panic!("unexpected kick of w{w}"),
            }
        }
        if done {
            break;
        }
        if let Some(stop) = stop_after {
            if core.cells_received() >= stop {
                // kill -9: nothing flushes, nothing finalizes. The
                // preserved run files and the journal are all that's left.
                return (None, core.stats.clone());
            }
        }
        let Some(msg) = outbox.pop_front() else {
            panic!("worker idle with {}/{} cells", core.cells_received(), n);
        };
        inflight.extend(core.on_message(0, msg, now));
    }
    let mut bytes = Vec::new();
    merger.finalize(&m.name, m.seed, n, &mut bytes).unwrap();
    journal.append_finalize(n).unwrap();
    (Some(String::from_utf8(bytes).unwrap()), core.stats.clone())
}

/// Strip the last journal record (its line, newline included).
fn strip_last_record(journal_path: &Path) {
    let bytes = std::fs::read(journal_path).unwrap();
    assert_eq!(bytes.last(), Some(&b'\n'), "journals end in a newline");
    let cut = bytes[..bytes.len() - 1]
        .iter()
        .rposition(|&b| b == b'\n')
        .map(|p| p + 1)
        .unwrap_or(0);
    std::fs::write(journal_path, &bytes[..cut]).unwrap();
}

#[test]
fn crash_at_random_points_then_resume_is_byte_identical_without_recompute() {
    let m = matrix(0xE5);
    let reference = run_matrix(&m, 2);
    let want = reference.json_string();
    let n = reference.cells.len();
    let mut rng = Pcg32::new(0xC4A5, 11);
    for trial in 0..6u64 {
        let jp = temp(&format!("crash{trial}.wal"));
        let d1 = temp(&format!("crash{trial}_a"));
        let d2 = temp(&format!("crash{trial}_b"));
        cleanup(&[&jp, &d1, &d2]);
        // Cap the crash point so it always fires: one Cells message
        // carries at most 3 cells, so received can overshoot `stop` by 2
        // before the crash check runs — keep that short of completion.
        let stop = 1 + rng.below(n as u64 - 3) as usize;
        let (none, _) =
            drive(&m, &reference.cells, &jp, &d1, 3, false, Some(stop), 0x111 + trial);
        assert!(none.is_none(), "trial {trial} was supposed to crash");
        let rec = recover(&jp).unwrap();
        assert!(rec.n_received < n, "buffered cells must not be journaled");
        let (got, stats) =
            drive(&m, &reference.cells, &jp, &d2, 3, true, None, 0x222 + trial);
        assert_eq!(got.unwrap(), want, "trial {trial}: stop {stop}");
        assert_eq!(
            stats.cells_received,
            (n - rec.n_received) as u64,
            "trial {trial}: the resumed core must lease out only the gaps"
        );
        let spent = recover(&jp).unwrap();
        assert!(spent.finalized && spent.is_complete());
        cleanup(&[&jp, &d1, &d2]);
    }
}

#[test]
fn crash_mid_spill_write_drops_the_uncommitted_group_and_recomputes_it() {
    let m = matrix(0xE6);
    let reference = run_matrix(&m, 2);
    let want = reference.json_string();
    let n = reference.cells.len();
    let (jp, d1, d2) = (temp("midspill.wal"), temp("midspill_a"), temp("midspill_b"));
    cleanup(&[&jp, &d1, &d2]);
    // Crash with two committed spill groups in the journal (limit 3,
    // stop 8 → runs at 3 and 6 cells, 2 buffered cells lost outright).
    drive(&m, &reference.cells, &jp, &d1, 3, false, Some(8), 0x333);
    let whole = recover(&jp).unwrap();
    assert!(whole.runs.len() >= 2, "need at least two committed runs");
    // Now tear the crash mid-spill-write: drop the last run manifest so
    // its range records sit uncommitted, exactly as if the process died
    // between writing the run file and committing it.
    strip_last_record(&jp);
    let torn = recover(&jp).unwrap();
    let lost = whole.runs.last().unwrap().cells;
    assert_eq!(torn.runs.len(), whole.runs.len() - 1);
    assert_eq!(torn.n_received, whole.n_received - lost);
    assert!(torn.torn_bytes > 0, "uncommitted ranges count as torn tail");
    // The orphaned run file is ignored; resume recomputes its cells and
    // still streams the byte-identical report.
    let (got, stats) = drive(&m, &reference.cells, &jp, &d2, 3, true, None, 0x444);
    assert_eq!(got.unwrap(), want);
    assert_eq!(stats.cells_received, (n - torn.n_received) as u64);
    cleanup(&[&jp, &d1, &d2]);
}

#[test]
fn journal_truncated_at_arbitrary_bytes_recovers_or_fails_loudly() {
    let m = matrix(0xE7);
    let reference = run_matrix(&m, 2);
    let want = reference.json_string();
    let n = reference.cells.len();
    let (jp, d1) = (temp("trunc.wal"), temp("trunc_a"));
    cleanup(&[&jp, &d1]);
    drive(&m, &reference.cells, &jp, &d1, 3, false, Some(8), 0x555);
    let full = std::fs::read(&jp).unwrap();
    let copy = temp("trunc_cut.wal");
    let mut rng = Pcg32::new(0xCC7, 3);
    for sample in 0..10u64 {
        let cut = rng.below(full.len() as u64 + 1) as usize;
        std::fs::write(&copy, &full[..cut]).unwrap();
        match recover(&copy) {
            Err(e) => {
                // Only an unreadable header may hard-fail a pure
                // truncation, and it must cite the offset.
                assert!(e.contains("at byte 0"), "cut {cut}: {e}");
            }
            Ok(rec) => {
                assert!(rec.n_received < n);
                let dir = temp(&format!("trunc_b{sample}"));
                cleanup(&[&dir]);
                let (got, stats) =
                    drive(&m, &reference.cells, &copy, &dir, 3, true, None, 0x666 + sample);
                assert_eq!(got.unwrap(), want, "cut {cut} diverged");
                assert_eq!(stats.cells_received, (n - rec.n_received) as u64, "cut {cut}");
                cleanup(&[&dir]);
            }
        }
    }
    cleanup(&[&jp, &d1, &copy]);
}

#[test]
fn fully_journaled_serve_resumes_to_finalize_without_any_worker() {
    let m = matrix(0xE8);
    let reference = run_matrix(&m, 2);
    let want = reference.json_string();
    let n = reference.cells.len();
    let (jp, d1, d2) = (temp("full.wal"), temp("full_a"), temp("full_b"));
    cleanup(&[&jp, &d1, &d2]);
    // Spill limit 1: every cell is durable the instant it is ingested.
    let (got, _) = drive(&m, &reference.cells, &jp, &d1, 1, false, None, 0x777);
    assert_eq!(got.unwrap(), want);
    let spent = recover(&jp).unwrap();
    assert!(spent.finalized, "a completed journal carries the finalize marker");
    // Pretend the crash hit after the last spill but before finalize:
    // strip the marker. The journal then covers all n cells and the
    // resumed serve goes straight to the report — zero cells recomputed.
    strip_last_record(&jp);
    let rec = recover(&jp).unwrap();
    assert!(rec.is_complete() && !rec.finalized);
    assert_eq!(rec.n_received, n);
    let (got, stats) = drive(&m, &reference.cells, &jp, &d2, 1, true, None, 0x888);
    assert_eq!(got.unwrap(), want);
    assert_eq!(stats.cells_received, 0, "nothing to lease, nothing recomputed");
    cleanup(&[&jp, &d1, &d2]);
}
