//! Discrete-event simulation of an intermittently-powered MCU running the
//! Zygarde runtime: harvester → capacitor → fragment-atomic execution with
//! idempotent re-execution across power failures, limited-preemption
//! scheduling at unit boundaries, deadline discard, and clock error.

pub mod engine;
pub mod metrics;
pub mod workload;

pub use engine::{Engine, SimConfig};
pub use metrics::Metrics;
pub use workload::{task_from_network, WorkloadBuilder};
