//! Discrete-event simulation of an intermittently-powered MCU running the
//! Zygarde runtime: harvester → capacitor → fragment-atomic execution with
//! idempotent re-execution across power failures, limited-preemption
//! scheduling at unit boundaries, deadline discard, and clock error.
//!
//! [`sweep`] layers a deterministic parallel scenario-sweep engine on top:
//! declarative scenario matrices, seeded per-scenario RNG streams, fault
//! injection, and thread-count-independent aggregated reports.

pub mod engine;
pub mod metrics;
pub mod sweep;
pub mod workload;

pub use engine::{Engine, SimConfig};
pub use metrics::Metrics;
pub use sweep::{Scenario, ScenarioMatrix, SweepReport};
pub use workload::{synthetic_task, task_from_network, WorkloadBuilder};
