//! The intermittently-powered MCU engine.
//!
//! Time advances in two regimes:
//!
//! * **off / charging** — the MCU is below the boot voltage (or lacks
//!   E_man): time advances in charge ticks until execution is possible.
//! * **on / executing** — the scheduler (invoked only at unit boundaries
//!   and deadlines: limited preemption, §4.1) picks a job; the engine runs
//!   its current unit one atomic *fragment* at a time. A power failure
//!   mid-fragment loses that fragment's work (the energy is spent, the
//!   fragment later re-executes — SONIC's idempotent re-execution).
//!
//! Jobs are discarded at their deadline (*scheduler-believed* deadline:
//! the clock may err after reboots, §8.7) to avoid the domino effect. A
//! job whose mandatory part completed before the deadline counts as
//! scheduled; optional units improve its prediction but never block
//! another job's mandatory work under energy pressure (ζ_I).

use crate::clock::Clock;
use crate::coordinator::priority::EnergyView;
use crate::coordinator::sched::{ExitPolicy, Scheduler};
use crate::coordinator::task::{Job, JobState, TaskSpec};
use crate::energy::manager::EnergyManager;
use crate::util::rng::Pcg32;

use super::metrics::Metrics;

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Stop after this much simulated time.
    pub duration_ms: f64,
    /// Job-queue capacity (paper: 3).
    pub queue_size: usize,
    /// Charge-tick granularity while idle/off (ms).
    pub idle_tick_ms: f64,
    /// MCU idle draw while on but not executing (mW).
    pub idle_power_mw: f64,
    pub seed: u64,
    /// Release jitter fraction of the period (sporadic, not periodic).
    pub release_jitter: f64,
    /// Record a [`JobRecord`] for every job that leaves the system
    /// (invariant audits in the sweep tests). Off by default: the log is
    /// O(jobs) memory the figure-scale sweeps do not need.
    pub log_jobs: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            duration_ms: 60_000.0,
            queue_size: 3,
            idle_tick_ms: 5.0,
            idle_power_mw: 0.3,
            seed: 1,
            release_jitter: 0.1,
            log_jobs: false,
        }
    }
}

pub struct Engine {
    pub cfg: SimConfig,
    pub tasks: Vec<TaskSpec>,
    pub scheduler: Scheduler,
    pub exit_policy: ExitPolicy,
    pub energy: EnergyManager,
    pub clock: Box<dyn Clock>,
    pub metrics: Metrics,
    queue: Vec<Job>,
    now_ms: f64,
    next_release_ms: Vec<f64>,
    next_trace: Vec<usize>,
    next_job_id: u64,
    rng: Pcg32,
    was_on: bool,
    outage_start_ms: f64,
    /// Optional per-tick probe, e.g. voltage logging for Fig. 22.
    pub probe: Option<Box<dyn FnMut(f64, &EnergyManager, &Metrics)>>,
}

impl Engine {
    pub fn new(
        cfg: SimConfig,
        tasks: Vec<TaskSpec>,
        scheduler: Scheduler,
        exit_policy: ExitPolicy,
        energy: EnergyManager,
        clock: Box<dyn Clock>,
    ) -> Self {
        let n = tasks.len();
        let rng = Pcg32::seeded(cfg.seed);
        let next_release_ms = tasks.iter().map(|_| 0.0).collect();
        Engine {
            cfg,
            tasks,
            scheduler,
            exit_policy,
            energy,
            clock,
            metrics: Metrics::new(n),
            queue: Vec::new(),
            now_ms: 0.0,
            next_release_ms,
            next_trace: vec![0; n],
            next_job_id: 0,
            rng,
            was_on: false,
            outage_start_ms: 0.0,
            probe: None,
        }
    }

    /// Run the simulation to completion and return the metrics.
    pub fn run(mut self) -> Metrics {
        while self.now_ms < self.cfg.duration_ms {
            self.step();
        }
        self.metrics.sim_time_ms = self.now_ms;
        self.metrics.reboots = self.energy.reboots;
        self.metrics.harvested_mj = self.energy.harvested_mj;
        self.metrics.wasted_mj = self.energy.capacitor.wasted_mj;
        self.metrics
    }

    fn believed_now(&mut self) -> f64 {
        self.clock.now_ms(self.now_ms)
    }

    fn step(&mut self) {
        self.track_power_edges();
        self.release_due_jobs();
        self.discard_past_deadline();

        if !self.energy.mandatory_allowed() {
            self.advance_idle();
            return;
        }

        // Scheduler invocation (limited preemption: we are at a unit
        // boundary by construction). Charge the scheduler's own overhead.
        let view = self.energy_view();
        let believed = self.believed_now();
        let Some(idx) = self.scheduler.pick(&self.queue, believed, &view) else {
            self.advance_idle();
            return;
        };
        let sched_mj = self.tasks[self.queue[idx].task]
            .release_energy_mj
            .min(0.05); // scheduler overhead is sub-fragment scale
        let _ = self.energy.capacitor.draw(sched_mj * 0.0); // accounted in unit costs
        self.execute_unit(idx);
    }

    fn energy_view(&self) -> EnergyView {
        EnergyView {
            e_curr_mj: self.energy.e_curr(),
            e_opt_mj: self.energy.e_opt_mj,
            e_man_mj: self.energy.e_man_mj,
            eta: self.energy.eta,
        }
    }

    fn track_power_edges(&mut self) {
        let on = self.energy.capacitor.mcu_on();
        if on && !self.was_on {
            let outage = self.now_ms - self.outage_start_ms;
            self.clock.on_reboot(self.now_ms, outage);
        } else if !on && self.was_on {
            self.outage_start_ms = self.now_ms;
        }
        self.was_on = on;
    }

    fn release_due_jobs(&mut self) {
        for t in 0..self.tasks.len() {
            while self.next_release_ms[t] <= self.now_ms {
                let release_at = self.next_release_ms[t];
                // Sporadic: next release after at least one period.
                let jitter =
                    1.0 + self.cfg.release_jitter * self.rng.f64();
                self.next_release_ms[t] = release_at + self.tasks[t].period_ms * jitter;

                // Sensor read energy (DMA path: no CPU time, but energy).
                if !self
                    .energy
                    .capacitor
                    .draw(self.tasks[t].release_energy_mj)
                {
                    self.metrics.capture_missed += 1;
                    continue;
                }
                self.metrics.released += 1;
                self.metrics.per_task_released[t] += 1;
                if self.queue.len() >= self.cfg.queue_size {
                    // Queue full: a job whose mandatory part already
                    // completed holds only optional refinement value — a
                    // fresh (all-mandatory) job outranks it under ζ_I's γ
                    // term, so evict the most-confident such job (it
                    // leaves as scheduled). If none exists, the release is
                    // dropped ("a job leaves the queue when it gets
                    // scheduled for execution or its deadline has passed").
                    let evict = self
                        .queue
                        .iter()
                        .enumerate()
                        .filter(|(_, j)| j.mandatory_done)
                        .max_by(|(_, a), (_, b)| {
                            a.utility.partial_cmp(&b.utility).unwrap()
                        })
                        .map(|(i, _)| i);
                    match evict {
                        Some(i) => {
                            let believed = self.believed_now();
                            let old = self.queue.swap_remove(i);
                            self.finish_job(old, believed);
                        }
                        None => {
                            self.metrics.queue_dropped += 1;
                            continue;
                        }
                    }
                }
                let tr = self.next_trace[t];
                self.next_trace[t] = (tr + 1) % self.tasks[t].traces.len().max(1);
                let job = Job::new(&self.tasks[t], self.next_job_id, release_at, tr);
                self.next_job_id += 1;
                self.queue.push(job);
            }
        }
    }

    fn discard_past_deadline(&mut self) {
        let believed = self.believed_now();
        let mut i = 0;
        while i < self.queue.len() {
            if believed >= self.queue[i].deadline_ms {
                let job = self.queue.swap_remove(i);
                self.finish_job(job, believed);
            } else {
                i += 1;
            }
        }
    }

    /// Account a job leaving the system (deadline or exhaustion).
    /// "Scheduled" is judged against the TRUE deadline — a clock running
    /// behind (CHRT negative error, §8.7) can make the scheduler *believe*
    /// a late job finished in time, but the event was still reported late.
    fn finish_job(&mut self, job: Job, _believed_now: f64) {
        let t = job.task;
        let in_time = job
            .mandatory_done_at
            .map(|at| at <= job.deadline_ms)
            .unwrap_or(false);
        if self.cfg.log_jobs {
            self.metrics.job_log.push(crate::sim::metrics::JobRecord {
                task: t,
                release_ms: job.release_ms,
                deadline_ms: job.deadline_ms,
                mandatory_done_at: job.mandatory_done_at,
                units_done: job.units_done,
                counted_scheduled: job.mandatory_done && in_time,
            });
        }
        if job.mandatory_done && in_time {
            self.metrics.scheduled += 1;
            self.metrics.per_task_scheduled[t] += 1;
            self.metrics.latency_sum_ms +=
                job.mandatory_done_at.unwrap_or(job.deadline_ms) - job.release_ms;
            let correct = job
                .pred
                .map(|p| p == self.tasks[t].traces[job.trace_idx].label)
                .unwrap_or(false);
            if correct {
                self.metrics.correct += 1;
                self.metrics.per_task_correct[t] += 1;
            }
        } else {
            self.metrics.deadline_missed += 1;
        }
    }

    /// Execute the current unit of queue[idx], fragment by fragment.
    /// Returns to the caller at the unit boundary (or power failure).
    fn execute_unit(&mut self, idx: usize) {
        let task_id = self.queue[idx].task;
        let unit = self.queue[idx].next_unit;
        let frag_ms = self.tasks[task_id].fragment_time_ms(unit);
        let frag_mj = self.tasks[task_id].fragment_energy_mj(unit);
        let n_frag = self.tasks[task_id].unit_fragments[unit];
        let mandatory = self.queue[idx].next_is_mandatory();

        let mut did_work = false;
        while self.queue[idx].fragments_done < n_frag {
            if self.now_ms >= self.cfg.duration_ms {
                return;
            }
            // Zygarde only: optional work is strictly opportunistic — it
            // may only absorb energy and CPU time that mandatory work
            // cannot use. Park the unit at this fragment boundary
            // (progress persists — SONIC-style checkpointing) when either
            // (a) the ζ_I gate closes mid-unit (η·E_curr < E_opt): keep
            //     draining and the capacitor browns out on energy a future
            //     mandatory capture needs; or
            // (b) a job with pending mandatory units arrived: under
            //     limited preemption the scheduler normally runs at unit
            //     boundaries, but discardable optional fragments make
            //     parking free, and this is what keeps Zygarde's scheduled
            //     count equal to EDF-M's (§8.5) while still converting
            //     idle capacity into accuracy.
            // The check happens only *between* fragments (`did_work`):
            // the scheduler's pick must always advance time by at least
            // one fragment or the engine would livelock re-picking a
            // parked unit. EDF-family schedulers have no such gate.
            if did_work
                && !mandatory
                && self.scheduler.kind == crate::coordinator::sched::SchedulerKind::Zygarde
            {
                let gate_closed = !self.energy_view().optional_allowed();
                let mandatory_waiting = self
                    .queue
                    .iter()
                    .enumerate()
                    .any(|(i, j)| i != idx && !j.finished() && j.next_is_mandatory());
                // A release that came due mid-unit is mandatory by
                // definition (fresh jobs start mandatory); it enters the
                // queue in the next step() — park so it can.
                let release_due = self.next_release_ms.iter().any(|&r| r <= self.now_ms);
                if gate_closed || mandatory_waiting || release_due {
                    return;
                }
            }
            did_work = true;
            // Harvest during the fragment, then pay for it.
            self.energy.tick(frag_ms);
            self.now_ms += frag_ms;
            self.metrics.on_time_ms += frag_ms;
            self.metrics.fragments += 1;
            if self.energy.capacitor.draw(frag_mj) {
                self.queue[idx].fragments_done += 1;
            } else {
                // Power failed mid-fragment: work lost, fragment will
                // re-execute when power returns (idempotent).
                self.metrics.refragments += 1;
                self.track_power_edges();
                return;
            }
            // A release or deadline may occur mid-unit; deadlines are only
            // *acted on* at unit boundaries (limited preemption), but the
            // probe sees continuous time.
            if let Some(p) = self.probe.as_mut() {
                p(self.now_ms, &self.energy, &self.metrics);
            }
        }

        // Unit boundary: evaluate the classifier outcome from the trace.
        if mandatory {
            self.metrics.mandatory_units += 1;
        } else {
            self.metrics.optional_units += 1;
        }
        let n_units = self.tasks[task_id].n_units();
        let traces = self.tasks[task_id].traces.clone();
        let trace = &traces[self.queue[idx].trace_idx];
        let now = self.now_ms;
        let imprecise = self.tasks[task_id].imprecise;
        {
            let job = &mut self.queue[idx];
            job.complete_unit(trace, n_units, now);
            if !imprecise && !job.finished() {
                // Non-imprecise tasks: everything mandatory (γ always 1).
                job.state = JobState::Mandatory;
                job.mandatory_done = false;
            }
        }

        // Exit-policy: may terminate the job now.
        let done = {
            let job = &self.queue[idx];
            match self.exit_policy {
                ExitPolicy::None => job.finished(),
                ExitPolicy::Utility => {
                    job.finished()
                        || (job.state == JobState::Optional
                            && !self.energy_view().optional_allowed()
                            && self.scheduler.kind
                                != crate::coordinator::sched::SchedulerKind::Edf)
                        || (self.scheduler.kind
                            == crate::coordinator::sched::SchedulerKind::EdfMandatory
                            && job.state == JobState::Optional)
                }
                ExitPolicy::Oracle => {
                    job.finished()
                        || trace.oracle_unit.map(|o| job.next_unit > o).unwrap_or(false)
                }
            }
        };
        if done {
            let believed = self.believed_now();
            let job = self.queue.swap_remove(idx);
            let mut job = job;
            if self.exit_policy == ExitPolicy::Oracle && !job.mandatory_done {
                // Oracle termination defines the mandatory part.
                job.mandatory_done = true;
                job.mandatory_done_at = Some(now);
            }
            self.finish_job(job, believed);
        }
    }

    fn advance_idle(&mut self) {
        // NOTE (§Perf iteration 3, REVERTED): taking 5x strides while the
        // MCU is off bought ~9 % wall-clock on `zygarde all` but coarsened
        // boot detection enough to shift scheduler outcomes at fragment
        // granularity (off-phase ends mid-stride). Determinism of the
        // experiment tables wins over the 9 %.
        let dt = self.cfg.idle_tick_ms;
        self.energy.tick(dt);
        self.energy.capacitor.idle_drain(self.cfg.idle_power_mw, dt);
        if self.energy.capacitor.mcu_on() {
            self.metrics.on_time_ms += dt;
        }
        self.now_ms += dt;
        if let Some(p) = self.probe.as_mut() {
            p(self.now_ms, &self.energy, &self.metrics);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::Rtc;
    use crate::coordinator::priority::PriorityParams;
    use crate::coordinator::sched::SchedulerKind;
    use crate::dnn::trace::{SampleTrace, UnitOutcome};
    use crate::energy::capacitor::Capacitor;
    use crate::energy::harvester::Harvester;
    use std::sync::Arc;

    fn trace(exit_at: usize, n: usize, correct: bool) -> SampleTrace {
        SampleTrace {
            label: 1,
            units: (0..n)
                .map(|i| UnitOutcome {
                    gap: if i >= exit_at { 5.0 } else { 0.1 },
                    pred: if correct { 1 } else { 0 },
                    exit: i == exit_at,
                    correct,
                })
                .collect(),
            exit_unit: exit_at,
            oracle_unit: correct.then_some(exit_at.saturating_sub(1)),
        }
    }

    fn task(id: usize, period: f64, deadline: f64) -> TaskSpec {
        TaskSpec {
            id,
            name: format!("t{id}"),
            period_ms: period,
            deadline_ms: deadline,
            unit_time_ms: vec![20.0, 20.0, 20.0],
            // 2 mJ per 20 ms unit = 100 mW active draw — well above the
            // bursty test harvester so intermittency actually bites.
            unit_energy_mj: vec![2.0, 2.0, 2.0],
            unit_fragments: vec![4, 4, 4],
            release_energy_mj: 0.05,
            traces: Arc::new(vec![trace(1, 3, true), trace(2, 3, true)]),
            imprecise: true,
        }
    }

    fn persistent_engine(kind: SchedulerKind, exit: ExitPolicy) -> Engine {
        let em = {
            let mut cap = Capacitor::standard();
            // pre-charge
            cap.charge(1e9, 1000.0);
            EnergyManager::new(cap, Harvester::persistent(600.0), 1.0, 0.05)
        };
        Engine::new(
            SimConfig { duration_ms: 30_000.0, ..Default::default() },
            vec![task(0, 300.0, 600.0)],
            Scheduler::new(kind, PriorityParams::new(600.0, 10.0)),
            exit,
            em,
            Box::new(Rtc),
        )
    }

    #[test]
    fn persistent_zygarde_schedules_everything() {
        let m = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility).run();
        assert!(m.released > 50, "released={}", m.released);
        assert_eq!(m.deadline_missed, 0, "misses with slack utilization");
        assert!(m.scheduled_rate() > 0.99, "rate={}", m.scheduled_rate());
        assert!(m.optional_units > 0, "optional units should run at eta=1");
        assert!(m.correct > 0);
    }

    #[test]
    fn persistent_edf_runs_all_units() {
        let m = persistent_engine(SchedulerKind::Edf, ExitPolicy::None).run();
        // EDF with no early exit executes 3 units per scheduled job.
        assert!(m.mandatory_units + m.optional_units >= 3 * m.scheduled);
        assert_eq!(m.deadline_missed, 0);
    }

    #[test]
    fn overload_makes_edf_miss_more_than_edfm() {
        // U > 1: full jobs cannot all fit, mandatory-only can.
        let run = |kind: SchedulerKind, exit: ExitPolicy| {
            let mut e = persistent_engine(kind, exit);
            e.tasks[0].period_ms = 45.0; // 3 units * 20ms = 60ms > T
            e.tasks[0].deadline_ms = 90.0;
            e.cfg.duration_ms = 20_000.0;
            let m = e.run();
            m.scheduled_rate()
        };
        let edf = run(SchedulerKind::Edf, ExitPolicy::None);
        let edfm = run(SchedulerKind::EdfMandatory, ExitPolicy::Utility);
        let zyg = run(SchedulerKind::Zygarde, ExitPolicy::Utility);
        assert!(edfm > edf, "edfm={edfm} edf={edf}");
        assert!(zyg > edf, "zyg={zyg} edf={edf}");
    }

    #[test]
    fn intermittent_power_causes_misses_and_reexecution() {
        let h = Harvester::markov(
            crate::energy::harvester::HarvesterKind::Rf,
            40.0,
            0.9,
            0.5,
            1000.0,
            7,
        );
        let mut cap = Capacitor::new(0.01, 3.3, 2.8, 1.9);
        cap.charge(1e7, 1000.0);
        let em = EnergyManager::new(cap, h, 0.5, 0.05);
        let e = Engine::new(
            SimConfig { duration_ms: 120_000.0, ..Default::default() },
            vec![task(0, 500.0, 1000.0)],
            Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(1000.0, 10.0)),
            ExitPolicy::Utility,
            em,
            Box::new(Rtc),
        );
        let m = e.run();
        assert!(m.released > 0);
        assert!(m.deadline_missed > 0 || m.capture_missed > 0 || m.refragments > 0,
            "expected some interference: {m:?}");
        assert!(m.on_fraction() < 1.0);
    }

    #[test]
    fn queue_capacity_drops_excess() {
        let mut e = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility);
        e.cfg.queue_size = 1;
        e.tasks[0].period_ms = 10.0; // flood
        e.tasks[0].deadline_ms = 2000.0;
        let m = e.run();
        assert!(m.queue_dropped > 0);
    }

    #[test]
    fn oracle_exit_terminates_earlier_than_utility() {
        let mu = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility).run();
        let mo = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Oracle).run();
        let units_per_job_u =
            (mu.mandatory_units + mu.optional_units) as f64 / mu.scheduled.max(1) as f64;
        let units_per_job_o =
            (mo.mandatory_units + mo.optional_units) as f64 / mo.scheduled.max(1) as f64;
        assert!(units_per_job_o <= units_per_job_u + 1e-9,
            "oracle {units_per_job_o} vs utility {units_per_job_u}");
    }
}
