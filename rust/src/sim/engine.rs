//! The intermittently-powered MCU engine.
//!
//! Time advances in two regimes:
//!
//! * **off / charging** — the MCU is below the boot voltage (or lacks
//!   E_man): time advances in charge ticks until execution is possible.
//! * **on / executing** — the scheduler (invoked only at unit boundaries
//!   and deadlines: limited preemption, §4.1) picks a job; the engine runs
//!   its current unit one atomic *fragment* at a time. A power failure
//!   mid-fragment loses that fragment's work (the energy is spent, the
//!   fragment later re-executes — SONIC's idempotent re-execution).
//!
//! What survives a power failure beyond the in-flight fragment is decided
//! by the [`crate::nvm`] subsystem: completed fragments persist only once
//! *committed* per the engine's [`CommitPolicy`], commits and post-reboot
//! restores are charged real NVM energy and latency, and on an outage
//! every queued job rolls back to its last durable checkpoint (the
//! default, [`crate::nvm::NvmSpec::ideal`], commits every fragment for
//! free — the seed engine's idealization, bit-for-bit).
//!
//! Jobs are discarded at their deadline (*scheduler-believed* deadline:
//! the clock may err after reboots, §8.7) to avoid the domino effect. A
//! job whose mandatory part completed before the deadline counts as
//! scheduled; optional units improve its prediction but never block
//! another job's mandatory work under energy pressure (ζ_I).
//!
//! # Performance: the event-driven core
//!
//! Idle regimes dominate wall-clock for every one of the paper's
//! harvesters — off/charging under the bursty low-duty sources (RF,
//! piezo, diurnal solar — Fig. 4), on-but-idle under the strong ones —
//! so the engine steps them *event to event* instead of tick by tick.
//! Each idle loop first computes a conservative **next-event budget**:
//! the minimum of analytic crossing predictors for
//!
//! * the next harvester window edge ([`crate::energy::Harvester::off_ticks_hint`],
//!   exact for every source kind — transitions only happen at ΔT edges);
//! * the simulation horizon and the next job release (`next_release_min`);
//! * the next *believed*-deadline crossing, via the clock's
//!   [`crate::clock::Clock::const_offset`] contract (an opaque clock
//!   falls back to naive stepping — perf-only, never correctness);
//! * the brown-out voltage crossing
//!   ([`crate::energy::Capacitor::idle_ticks_above`], padded two drain
//!   quanta past the √V comparison);
//! * the JIT-commit trigger ([`crate::energy::EnergyManager::ticks_above_voltage`],
//!   consulted only when a [`CommitPolicy::JitVoltage`] checkpoint could
//!   actually fire — armed, with dirty jobs queued).
//!
//! That many ticks are then replayed in bulk with the *identical
//! floating-point operations in the identical order* as the naive
//! stepper, minus work that is provably a no-op per tick (zero-power
//! harvest adds, the release/deadline scans, virtual clock reads,
//! scheduler dispatch, `√V` threshold checks). Budgets only ever cause an
//! **early exit** to the exact per-tick dispatcher — they bound when an
//! event *could* occur, never decide behavior — so every boot edge,
//! release, deadline, window transition, and JIT commit lands on exactly
//! the same tick and `Metrics` output is bit-for-bit unchanged. Three
//! regime loops share the scheme: [`Engine::advance_off_phase`] (MCU
//! down, queue in any state), [`Engine::advance_on_phase_idle`] (up but
//! starved or nothing runnable), and the budget-free
//! [`Engine::advance_idle_probed`] (a probe observes every tick; only
//! the dispatch is hoisted). The on-regime fragment loop is flattened
//! the same way: the per-fragment O(tasks) release scan and O(queue)
//! mandatory scan are replaced by incrementally maintained
//! `next_release_min` / `mandatory_pending`. Setting
//! [`Engine::reference`] disables every shortcut and steps naively —
//! the baseline `rust/tests/engine_differential.rs` proves byte-equal.

use crate::clock::Clock;
use crate::coordinator::priority::EnergyView;
use crate::coordinator::sched::{ExitPolicy, Scheduler};
use crate::coordinator::task::{Job, JobState, TaskSpec};
use crate::energy::conservative_ticks;
use crate::energy::manager::EnergyManager;
use crate::nvm::{CommitPolicy, Nvm};
use crate::telemetry::registry::{mj_to_uj, Counter, Hist, RegistryHandle};
use crate::telemetry::{EventKind, FfRegime, TraceEvent, TraceSink};
use crate::util::rng::Pcg32;

use super::metrics::Metrics;

/// Per-tick probe signature, e.g. voltage logging for Fig. 22.
pub type Probe = Box<dyn FnMut(f64, &EnergyManager, &Metrics)>;

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Stop after this much simulated time.
    pub duration_ms: f64,
    /// Job-queue capacity (paper: 3).
    pub queue_size: usize,
    /// Charge-tick granularity while idle/off (ms).
    pub idle_tick_ms: f64,
    /// MCU idle draw while on but not executing (mW).
    pub idle_power_mw: f64,
    pub seed: u64,
    /// Release jitter fraction of the period (sporadic, not periodic).
    pub release_jitter: f64,
    /// Record a [`JobRecord`] for every job that leaves the system
    /// (invariant audits in the sweep tests). Off by default: the log is
    /// O(jobs) memory the figure-scale sweeps do not need.
    pub log_jobs: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            duration_ms: 60_000.0,
            queue_size: 3,
            idle_tick_ms: 5.0,
            idle_power_mw: 0.3,
            seed: 1,
            release_jitter: 0.1,
            log_jobs: false,
        }
    }
}

pub struct Engine {
    pub cfg: SimConfig,
    pub tasks: Vec<TaskSpec>,
    pub scheduler: Scheduler,
    pub exit_policy: ExitPolicy,
    pub energy: EnergyManager,
    pub clock: Box<dyn Clock>,
    pub metrics: Metrics,
    /// Nonvolatile-progress model + commit policy. Defaults to the
    /// zero-cost every-fragment idealization; the sweep runner overrides
    /// it from the scenario's `NvmSpec`.
    pub nvm: Nvm,
    queue: Vec<Job>,
    now_ms: f64,
    next_release_ms: Vec<f64>,
    /// min(`next_release_ms`), maintained incrementally so neither the
    /// fragment loop's park gate nor the off-phase fast-forward rescans
    /// O(tasks) per fragment/tick. Exact, not approximate: recomputed
    /// whenever `next_release_ms` changes.
    next_release_min: f64,
    next_trace: Vec<usize>,
    next_job_id: u64,
    rng: Pcg32,
    was_on: bool,
    outage_start_ms: f64,
    /// Count of queued jobs in [`JobState::Mandatory`] — exactly the set
    /// the fragment gate's `mandatory_waiting` scan looked for (a job
    /// mid-optional-unit is `Optional`, a finished one `Exhausted`).
    /// Maintained at every queue push/remove and job state transition.
    mandatory_pending: usize,
    /// Step with the naive reference dispatcher: no off-phase
    /// fast-forward, scan-based fragment gates, no short-circuits. This
    /// is the differential-exactness baseline (`engine_differential`
    /// tests, `--features slow-reference` CI leg), not a performance
    /// mode — the optimized path must match it byte for byte.
    pub reference: bool,
    /// Optional per-tick probe, e.g. voltage logging for Fig. 22.
    pub probe: Option<Probe>,
    /// Optional out-of-band event sink (see [`crate::telemetry`]). Unlike
    /// `probe`, an attached sink never changes how the engine steps: it is
    /// deliberately absent from `step`'s dispatch conditions, so the
    /// event-driven fast-forwards stay engaged and surface as
    /// [`EventKind::FastForward`] span events instead of per-tick samples.
    /// Disabled cost: one `Option` discriminant check per hook site.
    pub trace: Option<Box<dyn TraceSink>>,
    /// Optional metrics registry (see [`crate::telemetry::registry`]).
    /// Same passivity contract as `trace`: hooks only read sim state and
    /// bump integer counters, never feed anything back into dispatch, so
    /// profiled and unprofiled runs are byte-identical and the
    /// accumulated registry is a pure function of the scenario. Regime
    /// occupancy, fast-forward jump attribution, and NVM
    /// commit/rollback/restore costs land here. Disabled cost: one
    /// `Option` discriminant check per hook site (bulk loops accumulate
    /// into the existing `n` and add once).
    pub registry: Option<RegistryHandle>,
}

impl Engine {
    pub fn new(
        cfg: SimConfig,
        tasks: Vec<TaskSpec>,
        scheduler: Scheduler,
        exit_policy: ExitPolicy,
        energy: EnergyManager,
        clock: Box<dyn Clock>,
    ) -> Self {
        let n = tasks.len();
        let rng = Pcg32::seeded(cfg.seed);
        let next_release_ms = tasks.iter().map(|_| 0.0).collect();
        debug_assert_eq!(
            energy.capacitor.wasted_mj, 0.0,
            "pre-t0 charging must go through Capacitor::precharge / Engine::warm_up, \
             which keep the in-simulation waste ledger at zero"
        );
        let mut metrics = Metrics::new(n);
        metrics.initial_energy_mj = energy.capacitor.energy_mj();
        let nvm = Nvm::ideal(&energy.capacitor);
        Engine {
            cfg,
            tasks,
            scheduler,
            exit_policy,
            energy,
            clock,
            metrics,
            nvm,
            queue: Vec::new(),
            now_ms: 0.0,
            next_release_ms,
            next_release_min: if n == 0 { f64::INFINITY } else { 0.0 },
            next_trace: vec![0; n],
            next_job_id: 0,
            rng,
            was_on: false,
            outage_start_ms: 0.0,
            mandatory_pending: 0,
            reference: false,
            probe: None,
            trace: None,
            registry: None,
        }
    }

    /// Registry hooks: `Option` check on the disabled path, one shared-
    /// handle add on the enabled one. Multi-metric sites guard the whole
    /// block with `self.registry.is_some()` first.
    #[inline]
    fn reg_add(&self, c: Counter, n: u64) {
        if let Some(r) = self.registry.as_ref() {
            r.add(c, n);
        }
    }

    #[inline]
    fn reg_observe(&self, h: Hist, v: u64) {
        if let Some(r) = self.registry.as_ref() {
            r.observe(h, v);
        }
    }

    /// Record a telemetry event. Hot call sites guard with
    /// `self.trace.is_some()` so payload construction is skipped on the
    /// disabled path. Emission only *reads* simulation state (true time,
    /// capacitor energy) — never RNG streams, `Metrics`, or anything
    /// dispatch consults — so traced and untraced runs are byte-identical
    /// (`rust/tests/telemetry_trace.rs` enforces this).
    fn emit(&mut self, kind: EventKind) {
        let ev = TraceEvent {
            t_ms: self.now_ms,
            energy_mj: self.energy.capacitor.energy_mj(),
            kind,
        };
        if let Some(sink) = self.trace.as_mut() {
            sink.record(ev);
        }
    }

    /// Explicit pre-t0 warm-up phase: the deployment harvested before the
    /// simulation starts, so the capacitor begins full and the energy
    /// baseline (`Metrics::initial_energy_mj`) is re-taken from the warm
    /// state. Call between construction and [`Engine::run`]. The warm-up
    /// charge is pre-deployment fiction and touches none of the
    /// in-simulation ledgers (`harvested_mj` / `wasted_mj` /
    /// `consumed_mj`) — previously this was emulated by a huge
    /// `Capacitor::charge` whose overflow slop the constructor zeroed.
    pub fn warm_up(&mut self) {
        self.energy.capacitor.precharge();
        self.metrics.initial_energy_mj = self.energy.capacitor.energy_mj();
    }

    /// Run the simulation to completion and return the metrics.
    pub fn run(mut self) -> Metrics {
        while self.now_ms < self.cfg.duration_ms {
            self.step();
        }
        self.metrics.sim_time_ms = self.now_ms;
        self.metrics.reboots = self.energy.reboots;
        self.metrics.harvested_mj = self.energy.harvested_mj;
        self.metrics.wasted_mj = self.energy.capacitor.wasted_mj;
        self.metrics.final_energy_mj = self.energy.capacitor.energy_mj();
        self.metrics.consumed_mj = self.energy.capacitor.consumed_mj;
        self.metrics
    }

    fn believed_now(&mut self) -> f64 {
        self.clock.now_ms(self.now_ms)
    }

    fn step(&mut self) {
        self.track_power_edges();
        self.release_due_jobs();
        self.discard_past_deadline();

        if !self.energy.mandatory_allowed() {
            // Event-driven idle dispatch: each regime gets the strongest
            // fast-forward its invariants allow. Reference mode steps
            // naively; a probe pins the engine to per-tick stepping (it
            // observes every tick) but still hoists the dispatch; a down
            // MCU takes the dark fast-forward; an up-but-starved MCU
            // takes the on-phase loop (idle drain + JIT budgets).
            if self.reference {
                self.advance_idle();
            } else if self.probe.is_some() {
                self.advance_idle_probed();
            } else if !self.energy.capacitor.mcu_on() {
                self.advance_off_phase();
            } else {
                self.advance_on_phase_idle(false);
            }
            return;
        }

        // Fresh boot with durable progress on record: pay the NVM restore
        // before anything executes. A brown-out mid-restore retries on the
        // next boot.
        if self.nvm.pending_restore && !self.restore_checkpoint() {
            return;
        }

        // Scheduler invocation (limited preemption: we are at a unit
        // boundary by construction). The scheduler's own overhead is
        // sub-fragment scale and accounted for in the unit costs.
        let view = self.energy_view();
        let believed = self.believed_now();
        let Some(idx) = self.scheduler.pick(&self.queue, believed, &view) else {
            // Nothing runnable despite available energy (all jobs finished,
            // or only optional work behind a closed ζ_I gate). `pick` on an
            // unchanged queue stays `None` while idle — job states only
            // move when units execute, and the one energy-dependent input
            // (the ζ_I optional gate) is a tail-guarded exit — so the
            // on-phase loop may fast-forward here too. Pick purity: every
            // scheduler's `None` is stateless except round-robin's
            // in-flight-job cleanup, which this very call just applied.
            if !self.reference && self.probe.is_none() {
                self.advance_on_phase_idle(true);
            } else {
                self.advance_idle();
            }
            return;
        };
        self.execute_unit(idx);
    }

    fn energy_view(&self) -> EnergyView {
        EnergyView {
            e_curr_mj: self.energy.e_curr(),
            e_opt_mj: self.energy.e_opt_mj,
            e_man_mj: self.energy.e_man_mj,
            eta: self.energy.eta,
        }
    }

    fn track_power_edges(&mut self) {
        let on = self.energy.capacitor.mcu_on();
        if on && !self.was_on {
            let outage = self.now_ms - self.outage_start_ms;
            self.clock.on_reboot(self.now_ms, outage);
            // A boot starts above v_on, well over the JIT threshold.
            self.nvm.jit_armed = true;
            if self.trace.is_some() {
                self.emit(EventKind::Boot { outage_ms: outage });
            }
        } else if !on && self.was_on {
            self.outage_start_ms = self.now_ms;
            // Power failed: volatile progress dies. Every queued job rolls
            // back to its last durable checkpoint; whatever it had beyond
            // that re-executes after reboot (idempotent fragments).
            let tracing = self.trace.is_some();
            let mut rollbacks: Vec<(usize, u64, u64)> = Vec::new();
            let mut lost = 0u64;
            let mut any_committed = false;
            for j in &mut self.queue {
                let l = j.rollback(&self.tasks[j.task]);
                lost += l;
                any_committed = any_committed || j.has_committed_progress();
                if tracing && l > 0 {
                    rollbacks.push((j.task, j.id, l));
                }
            }
            self.metrics.lost_fragments += lost;
            if self.registry.is_some() {
                self.reg_add(Counter::Rollbacks, 1);
                self.reg_add(Counter::RollbackLostFragments, lost);
            }
            if any_committed {
                self.nvm.pending_restore = true;
            }
            // Rollback can move any job's state (Optional back to
            // Mandatory); recount rather than track per-job deltas —
            // outages are rare next to fragments.
            self.recount_mandatory_pending();
            if tracing {
                self.emit(EventKind::BrownOut { lost_fragments: lost });
                for (task, job, lost_fragments) in rollbacks {
                    self.emit(EventKind::Rollback { task, job, lost_fragments });
                }
            }
        }
        self.was_on = on;
    }

    /// Rebuild `mandatory_pending` from the queue (bulk state changes).
    fn recount_mandatory_pending(&mut self) {
        let n = self.queue.iter().filter(|j| j.state == JobState::Mandatory).count();
        self.mandatory_pending = n;
    }

    /// Remove `queue[i]`, keeping `mandatory_pending` in sync.
    fn take_job(&mut self, i: usize) -> Job {
        let job = self.queue.swap_remove(i);
        if job.state == JobState::Mandatory {
            self.mandatory_pending -= 1;
        }
        job
    }

    /// Charge one NVM transaction (commit or restore): harvest during the
    /// write, advance time, then draw the energy. Returns false if the
    /// draw browned out — the transaction did not take effect.
    fn nvm_transaction(&mut self, e_mj: f64, t_ms: f64) -> bool {
        if t_ms > 0.0 {
            self.energy.tick(t_ms);
            self.now_ms += t_ms;
            self.metrics.on_time_ms += t_ms;
        }
        if e_mj > 0.0 && !self.energy.capacitor.draw(e_mj) {
            self.track_power_edges();
            return false;
        }
        true
    }

    /// Commit one job's volatile progress; `unit` is the unit whose state
    /// buffer the checkpoint persists (the executing unit mid-unit, the
    /// just-completed unit at a boundary — NOT `next_unit`, which has
    /// already advanced by then). Returns false on power failure
    /// mid-commit.
    fn commit_job(&mut self, idx: usize, unit: usize) -> bool {
        let spec = &self.tasks[self.queue[idx].task];
        let bytes = self.nvm.model.base_commit_bytes + spec.state_bytes(unit);
        let (e_mj, t_ms) = self.nvm.model.commit_cost(bytes);
        if !self.nvm_transaction(e_mj, t_ms) {
            return false;
        }
        self.queue[idx].checkpoint();
        self.metrics.commits += 1;
        self.metrics.commit_mj += e_mj;
        self.metrics.commit_ms += t_ms;
        if self.registry.is_some() {
            self.reg_add(Counter::Commits, 1);
            self.reg_add(Counter::CommitUj, mj_to_uj(e_mj));
        }
        if self.trace.is_some() {
            self.emit(EventKind::Commit { jit: false, e_mj, t_ms });
        }
        true
    }

    /// JIT checkpoint: one snapshot transaction covering every dirty
    /// job's live state. Returns false on power failure mid-commit.
    fn jit_commit_all(&mut self) -> bool {
        let mut bytes = self.nvm.model.base_commit_bytes;
        let mut any_dirty = false;
        for j in &self.queue {
            if j.is_dirty() {
                let spec = &self.tasks[j.task];
                bytes += spec.state_bytes(j.active_unit(spec.n_units()));
                any_dirty = true;
            }
        }
        if !any_dirty {
            return true;
        }
        let (e_mj, t_ms) = self.nvm.model.commit_cost(bytes);
        if !self.nvm_transaction(e_mj, t_ms) {
            return false;
        }
        for j in &mut self.queue {
            if j.is_dirty() {
                j.checkpoint();
            }
        }
        self.metrics.commits += 1;
        self.metrics.jit_commits += 1;
        self.metrics.commit_mj += e_mj;
        self.metrics.commit_ms += t_ms;
        if self.registry.is_some() {
            self.reg_add(Counter::Commits, 1);
            self.reg_add(Counter::JitCommits, 1);
            self.reg_add(Counter::CommitUj, mj_to_uj(e_mj));
        }
        self.nvm.jit_armed = false;
        if self.trace.is_some() {
            self.emit(EventKind::Commit { jit: true, e_mj, t_ms });
        }
        true
    }

    /// Evaluate the JIT voltage trigger (with re-arm hysteresis) and
    /// checkpoint if it fires. No-op for non-JIT policies. Returns false
    /// only on power failure mid-commit.
    fn jit_check(&mut self) -> bool {
        if !matches!(self.nvm.policy, CommitPolicy::JitVoltage { .. }) {
            return true;
        }
        if !self.nvm.jit_armed
            && self.energy.capacitor.voltage() >= self.nvm.jit_rearm_v
        {
            self.nvm.jit_armed = true;
        }
        if self.nvm.jit_armed && self.energy.jit_voltage_trigger(self.nvm.jit_threshold_v) {
            return self.jit_commit_all();
        }
        true
    }

    /// Bytes a post-reboot restore must read back: the base record plus
    /// each job's committed in-progress unit state. Zero when nothing
    /// durable is on record.
    fn restore_bytes(&self) -> usize {
        let mut bytes = 0usize;
        for j in &self.queue {
            if j.has_committed_progress() {
                let spec = &self.tasks[j.task];
                bytes += spec.state_bytes(j.committed_active_unit(spec.n_units()));
            }
        }
        if bytes > 0 {
            bytes + self.nvm.model.base_commit_bytes
        } else {
            0
        }
    }

    /// Pay the post-reboot restore. Returns false if the read browned the
    /// capacitor out again (the restore stays pending for the next boot).
    fn restore_checkpoint(&mut self) -> bool {
        let bytes = self.restore_bytes();
        if bytes == 0 {
            // Everything durable left the queue while we were down.
            self.nvm.pending_restore = false;
            return true;
        }
        let (e_mj, t_ms) = self.nvm.model.restore_cost(bytes);
        if !self.nvm_transaction(e_mj, t_ms) {
            return false;
        }
        self.nvm.pending_restore = false;
        self.metrics.restores += 1;
        self.metrics.restore_mj += e_mj;
        self.metrics.restore_ms += t_ms;
        if self.registry.is_some() {
            self.reg_add(Counter::Restores, 1);
            self.reg_add(Counter::RestoreUj, mj_to_uj(e_mj));
        }
        if self.trace.is_some() {
            self.emit(EventKind::Restore { e_mj, t_ms });
        }
        true
    }

    fn release_due_jobs(&mut self) {
        // Nothing due: the scan below would be a pure no-op (every inner
        // `while` guard false), so one compare replaces O(tasks) of them.
        if !self.reference && self.next_release_min > self.now_ms {
            return;
        }
        for t in 0..self.tasks.len() {
            while self.next_release_ms[t] <= self.now_ms {
                let release_at = self.next_release_ms[t];
                // Sporadic: next release after at least one period.
                let jitter =
                    1.0 + self.cfg.release_jitter * self.rng.f64();
                self.next_release_ms[t] = release_at + self.tasks[t].period_ms * jitter;

                // Sensor read energy (DMA path: no CPU time, but energy).
                if !self
                    .energy
                    .capacitor
                    .draw(self.tasks[t].release_energy_mj)
                {
                    self.metrics.capture_missed += 1;
                    // A sensor read can brown the capacitor out like any
                    // other draw. Observe the edge immediately — a strong
                    // harvester can recharge past v_on within this step's
                    // idle tick, and the rollback/restore bookkeeping must
                    // not miss the outage. (No-op if the MCU was already
                    // off: the edge was handled when it happened.)
                    self.track_power_edges();
                    continue;
                }
                self.metrics.released += 1;
                self.metrics.per_task_released[t] += 1;
                if self.queue.len() >= self.cfg.queue_size {
                    // Queue full: a job whose mandatory part already
                    // completed holds only optional refinement value — a
                    // fresh (all-mandatory) job outranks it under ζ_I's γ
                    // term, so evict the most-confident such job (it
                    // leaves as scheduled). If none exists, the release is
                    // dropped ("a job leaves the queue when it gets
                    // scheduled for execution or its deadline has passed").
                    let evict = self
                        .queue
                        .iter()
                        .enumerate()
                        .filter(|(_, j)| j.mandatory_done)
                        .max_by(|(_, a), (_, b)| {
                            a.utility.partial_cmp(&b.utility).unwrap()
                        })
                        .map(|(i, _)| i);
                    match evict {
                        Some(i) => {
                            let believed = self.believed_now();
                            let old = self.take_job(i);
                            self.finish_job(old, believed);
                        }
                        None => {
                            self.metrics.queue_dropped += 1;
                            continue;
                        }
                    }
                }
                let tr = self.next_trace[t];
                self.next_trace[t] = (tr + 1) % self.tasks[t].traces.len().max(1);
                let job = Job::new(&self.tasks[t], self.next_job_id, release_at, tr);
                self.next_job_id += 1;
                if self.trace.is_some() {
                    self.emit(EventKind::Release { task: t, job: job.id });
                }
                self.queue.push(job);
                // Fresh jobs start Mandatory (Progress::fresh).
                self.mandatory_pending += 1;
            }
        }
        let min = self.next_release_ms.iter().copied().fold(f64::INFINITY, f64::min);
        self.next_release_min = min;
    }

    fn discard_past_deadline(&mut self) {
        // Clock reads are pure observations (see `clock::Clock`), so an
        // empty queue makes this whole pass — virtual call included — a
        // no-op the hot idle path need not pay.
        if !self.reference && self.queue.is_empty() {
            return;
        }
        let believed = self.believed_now();
        let mut i = 0;
        while i < self.queue.len() {
            if believed >= self.queue[i].deadline_ms {
                let job = self.take_job(i);
                self.finish_job(job, believed);
            } else {
                i += 1;
            }
        }
    }

    /// Account a job leaving the system (deadline or exhaustion).
    /// "Scheduled" is judged against the TRUE deadline — a clock running
    /// behind (CHRT negative error, §8.7) can make the scheduler *believe*
    /// a late job finished in time, but the event was still reported late.
    ///
    /// Result delivery is modeled as an external action at the moment the
    /// job leaves the queue (radio TX / actuation), not as an NVM write:
    /// the MCU is up when this runs, so even a JIT-policy job whose state
    /// was never checkpointed delivers its result — what a power failure
    /// destroys is *undelivered* progress still in the queue.
    fn finish_job(&mut self, job: Job, _believed_now: f64) {
        let t = job.task;
        let in_time = job
            .mandatory_done_at
            .map(|at| at <= job.deadline_ms)
            .unwrap_or(false);
        if self.cfg.log_jobs {
            self.metrics.job_log.push(crate::sim::metrics::JobRecord {
                task: t,
                release_ms: job.release_ms,
                deadline_ms: job.deadline_ms,
                mandatory_done_at: job.mandatory_done_at,
                units_done: job.units_done,
                counted_scheduled: job.mandatory_done && in_time,
            });
        }
        if job.mandatory_done && in_time {
            self.metrics.scheduled += 1;
            self.metrics.per_task_scheduled[t] += 1;
            self.metrics.latency_sum_ms +=
                job.mandatory_done_at.unwrap_or(job.deadline_ms) - job.release_ms;
            let correct = job
                .pred
                .map(|p| p == self.tasks[t].traces[job.trace_idx].label)
                .unwrap_or(false);
            if correct {
                self.metrics.correct += 1;
                self.metrics.per_task_correct[t] += 1;
            }
            if self.trace.is_some() {
                self.emit(EventKind::DeadlineMet { task: t, job: job.id });
            }
        } else {
            self.metrics.deadline_missed += 1;
            if self.trace.is_some() {
                self.emit(EventKind::DeadlineMissed { task: t, job: job.id });
            }
        }
    }

    /// Execute the current unit of queue[idx], fragment by fragment.
    /// Returns to the caller at the unit boundary (or power failure).
    fn execute_unit(&mut self, idx: usize) {
        let task_id = self.queue[idx].task;
        let unit = self.queue[idx].next_unit;
        let frag_ms = self.tasks[task_id].fragment_time_ms(unit);
        let frag_mj = self.tasks[task_id].fragment_energy_mj(unit);
        let n_frag = self.tasks[task_id].unit_fragments[unit];
        let mandatory = self.queue[idx].next_is_mandatory();
        let job_id = self.queue[idx].id;

        let mut did_work = false;
        while self.queue[idx].fragments_done < n_frag {
            if self.now_ms >= self.cfg.duration_ms {
                return;
            }
            // Zygarde only: optional work is strictly opportunistic — it
            // may only absorb energy and CPU time that mandatory work
            // cannot use. Park the unit at this fragment boundary (the
            // progress survives per the NVM commit policy) when either
            // (a) the ζ_I gate closes mid-unit (η·E_curr < E_opt): keep
            //     draining and the capacitor browns out on energy a future
            //     mandatory capture needs; or
            // (b) a job with pending mandatory units arrived: under
            //     limited preemption the scheduler normally runs at unit
            //     boundaries, but discardable optional fragments make
            //     parking free, and this is what keeps Zygarde's scheduled
            //     count equal to EDF-M's (§8.5) while still converting
            //     idle capacity into accuracy.
            // The check happens only *between* fragments (`did_work`):
            // the scheduler's pick must always advance time by at least
            // one fragment or the engine would livelock re-picking a
            // parked unit. EDF-family schedulers have no such gate.
            if did_work
                && !mandatory
                && self.scheduler.kind == crate::coordinator::sched::SchedulerKind::Zygarde
            {
                let gate_closed = !self.energy_view().optional_allowed();
                // The executing job is mid-optional-unit (state Optional,
                // unchanged during the fragment loop), so it contributes
                // nothing to `mandatory_pending` and the counter equals
                // the old `i != idx` scan exactly.
                let mandatory_waiting = if self.reference {
                    self.queue
                        .iter()
                        .enumerate()
                        .any(|(i, j)| i != idx && !j.finished() && j.next_is_mandatory())
                } else {
                    debug_assert_eq!(
                        self.mandatory_pending,
                        self.queue.iter().filter(|j| j.state == JobState::Mandatory).count(),
                        "mandatory_pending drifted from the queue"
                    );
                    self.mandatory_pending > 0
                };
                // A release that came due mid-unit is mandatory by
                // definition (fresh jobs start mandatory); it enters the
                // queue in the next step() — park so it can.
                let release_due = if self.reference {
                    self.next_release_ms.iter().any(|&r| r <= self.now_ms)
                } else {
                    debug_assert_eq!(
                        self.next_release_min,
                        self.next_release_ms.iter().copied().fold(f64::INFINITY, f64::min),
                        "next_release_min drifted from the release table"
                    );
                    self.next_release_min <= self.now_ms
                };
                if gate_closed || mandatory_waiting || release_due {
                    return;
                }
            }
            did_work = true;
            if self.trace.is_some() {
                self.emit(EventKind::FragmentStart { task: task_id, job: job_id, unit });
            }
            // Harvest during the fragment, then pay for it.
            self.energy.tick(frag_ms);
            self.now_ms += frag_ms;
            self.metrics.on_time_ms += frag_ms;
            self.metrics.fragments += 1;
            if self.registry.is_some() {
                // Tick-equivalents: fragment times are not tick-quantized,
                // so occupancy charges round(frag_ms / dt), min 1.
                let t = (frag_ms / self.cfg.idle_tick_ms).round().max(1.0) as u64;
                self.reg_add(Counter::TicksActive, t);
            }
            if self.energy.capacitor.draw(frag_mj) {
                self.queue[idx].fragments_done += 1;
                if self.trace.is_some() {
                    self.emit(EventKind::FragmentEnd {
                        task: task_id,
                        job: job_id,
                        unit,
                        ok: true,
                    });
                }
            } else {
                // Power failed mid-fragment: work lost, fragment will
                // re-execute when power returns (idempotent).
                self.metrics.refragments += 1;
                if self.trace.is_some() {
                    self.emit(EventKind::FragmentEnd {
                        task: task_id,
                        job: job_id,
                        unit,
                        ok: false,
                    });
                }
                self.track_power_edges();
                return;
            }
            // NVM commit point after a successful fragment; the unit-
            // boundary commit below subsumes the final fragment's. A
            // `false` return means power failed mid-commit (the fragment
            // stays volatile and was already rolled back).
            if self.queue[idx].fragments_done < n_frag {
                let committed = match self.nvm.policy {
                    CommitPolicy::EveryFragment => self.commit_job(idx, unit),
                    CommitPolicy::UnitBoundary => true,
                    CommitPolicy::JitVoltage { .. } => self.jit_check(),
                };
                if !committed {
                    return;
                }
            }
            // A release or deadline may occur mid-unit; deadlines are only
            // *acted on* at unit boundaries (limited preemption), but the
            // probe sees continuous time.
            if let Some(p) = self.probe.as_mut() {
                p(self.now_ms, &self.energy, &self.metrics);
            }
            if self.probe.is_some() && self.trace.is_some() {
                self.emit(EventKind::Probe);
            }
        }

        // Unit boundary: evaluate the classifier outcome from the trace.
        if mandatory {
            self.metrics.mandatory_units += 1;
        } else {
            self.metrics.optional_units += 1;
        }
        let n_units = self.tasks[task_id].n_units();
        let now = self.now_ms;
        let imprecise = self.tasks[task_id].imprecise;
        let trace_idx = self.queue[idx].trace_idx;
        let oracle_unit = self.tasks[task_id].traces[trace_idx].oracle_unit;
        let (was_mandatory, is_mandatory) = {
            // Disjoint field borrows: the trace (shared, `tasks`) feeds
            // the job mutation (`queue`) with no per-boundary Arc clone —
            // the refcount bounce was shared across every sweep worker.
            let trace = &self.tasks[task_id].traces[trace_idx];
            let job = &mut self.queue[idx];
            let was = job.state == JobState::Mandatory;
            job.complete_unit(trace, n_units, now);
            if !imprecise && !job.finished() {
                // Non-imprecise tasks: everything mandatory (γ always 1).
                job.state = JobState::Mandatory;
                job.mandatory_done = false;
            }
            (was, job.state == JobState::Mandatory)
        };
        match (was_mandatory, is_mandatory) {
            (true, false) => self.mandatory_pending -= 1,
            (false, true) => self.mandatory_pending += 1,
            _ => {}
        }

        // NVM commit at the unit boundary (EveryFragment and UnitBoundary
        // both persist here — the completed unit's output plus the
        // classification result; JIT consults its voltage trigger instead).
        let committed = match self.nvm.policy {
            CommitPolicy::EveryFragment | CommitPolicy::UnitBoundary => {
                self.commit_job(idx, unit)
            }
            CommitPolicy::JitVoltage { .. } => self.jit_check(),
        };
        if !committed {
            return;
        }

        // Exit-policy: may terminate the job now.
        let done = {
            let job = &self.queue[idx];
            match self.exit_policy {
                ExitPolicy::None => job.finished(),
                ExitPolicy::Utility => {
                    job.finished()
                        || (job.state == JobState::Optional
                            && !self.energy_view().optional_allowed()
                            && self.scheduler.kind
                                != crate::coordinator::sched::SchedulerKind::Edf)
                        || (self.scheduler.kind
                            == crate::coordinator::sched::SchedulerKind::EdfMandatory
                            && job.state == JobState::Optional)
                }
                ExitPolicy::Oracle => {
                    job.finished()
                        || oracle_unit.map(|o| job.next_unit > o).unwrap_or(false)
                }
            }
        };
        if done {
            let believed = self.believed_now();
            let mut job = self.take_job(idx);
            if self.exit_policy == ExitPolicy::Oracle && !job.mandatory_done {
                // Oracle termination defines the mandatory part.
                job.mandatory_done = true;
                job.mandatory_done_at = Some(now);
            }
            self.finish_job(job, believed);
        }
    }

    fn advance_idle(&mut self) {
        // NOTE (§Perf iteration 3, REVERTED): taking 5x strides while the
        // MCU is off bought ~9 % wall-clock on `zygarde all` but coarsened
        // boot detection enough to shift scheduler outcomes at fragment
        // granularity (off-phase ends mid-stride). Determinism of the
        // experiment tables wins over the 9 % — the event-driven loops
        // (`advance_off_phase` / `advance_on_phase_idle`) are the exact
        // replacement: they never stride, they replay the same per-tick
        // arithmetic with events pinned to their exact ticks.
        let dt = self.cfg.idle_tick_ms;
        self.energy.tick(dt);
        self.energy.capacitor.idle_drain(self.cfg.idle_power_mw, dt);
        let on = self.energy.capacitor.mcu_on();
        if on {
            self.metrics.on_time_ms += dt;
            // The capacitor can sag through the JIT threshold while idle
            // (e.g. parked volatile progress under a closed ζ_I gate):
            // checkpoint now, not after the brown-out.
            let _ = self.jit_check();
        }
        self.now_ms += dt;
        if self.registry.is_some() {
            // Occupancy attribution follows the on-time accrual above
            // (post-drain MCU state); a probed tick is its own regime —
            // the probe pinned the engine to genuine per-tick stepping.
            let c = if self.probe.is_some() {
                Counter::TicksProbed
            } else if on {
                Counter::TicksOnIdle
            } else {
                Counter::TicksOff
            };
            self.reg_add(c, 1);
        }
        if let Some(p) = self.probe.as_mut() {
            p(self.now_ms, &self.energy, &self.metrics);
        }
        if self.probe.is_some() && self.trace.is_some() {
            self.emit(EventKind::Probe);
        }
    }

    /// Snapshot of the believed-deadline event the idle loops must not
    /// run through, taken once at loop entry. Valid while the loop holds
    /// its invariants: queue membership is frozen (releases and discards
    /// are guarded exits, jobs' `deadline_ms` never mutates) and the
    /// clock's offset is constant (no `on_reboot` — an MCU flip is a
    /// guarded exit too), so the minimum believed deadline is a single
    /// f64 crossing in true time.
    fn deadline_watch(&self) -> DeadlineWatch {
        if self.queue.is_empty() {
            return DeadlineWatch::Clear;
        }
        match self.clock.const_offset() {
            Some(offset) => {
                let min_dl = self
                    .queue
                    .iter()
                    .map(|j| j.deadline_ms)
                    .fold(f64::INFINITY, f64::min);
                DeadlineWatch::Watch { offset, min_dl }
            }
            None => DeadlineWatch::Opaque,
        }
    }

    /// Off-phase fast-forward: many naive steps' worth of idle ticks in
    /// one call, bit-for-bit, with the queue in ANY state.
    ///
    /// Preconditions (checked by `step`): MCU off, no probe, not in
    /// reference mode. Under them a naive `step()` is exactly one
    /// `advance_idle()` tick — the power-edge tracker sees off→off, the
    /// release scan is vacuous until `next_release_min` comes due, the
    /// deadline scan only reads the (pure) clock until the believed
    /// deadline watch trips, and `mandatory_allowed` is false while the
    /// MCU is down — so this loop may keep ticking until a per-tick
    /// *event* needs the full dispatcher again:
    ///
    /// * the harvester turns on / crosses a ΔT window (`off_tick` fails:
    ///   that tick runs the full `tick` + `idle_drain` sequence below,
    ///   which is `advance_idle` verbatim for a probe-less off engine);
    /// * the capacitor boots (only a charging tick can: zero-power ticks
    ///   cannot move the MCU state) — return so `step` observes the edge;
    /// * a release comes due (`next_release_min`) — return so the next
    ///   step's scan processes it on exactly the naive tick;
    /// * a queued job's believed deadline comes due — return so the next
    ///   step's discard scan acts on exactly the naive tick;
    /// * the horizon is reached — `run`'s loop condition takes over.
    ///
    /// While the source is dark and inside its ΔT window none of those
    /// can fire for a provable number of ticks (the analytic budget), and
    /// a dark tick's only state change is the harvester window clock and
    /// `now_ms` (zero harvest adds 0.0 mJ everywhere, idle drain needs
    /// the MCU on) — so whole dark stretches collapse into one bulk
    /// replay plus an exact per-tick tail that walks the final couple of
    /// ticks onto the event.
    fn advance_off_phase(&mut self) {
        debug_assert!(
            !self.energy.capacitor.mcu_on() && self.probe.is_none() && !self.reference
        );
        let dt = self.cfg.idle_tick_ms;
        let watch = self.deadline_watch();
        if matches!(watch, DeadlineWatch::Opaque) {
            // A clock with no constant-offset contract: believed-deadline
            // crossings cannot be predicted, so step naively (pure perf
            // fallback — no such clock exists today).
            self.advance_idle();
            return;
        }
        loop {
            // Analytic next-event budget: whole dark ΔT stretches at
            // once. Legs are named so an attached registry can attribute
            // the jump to its bounding event (the chained `.min()`s are
            // unchanged — same operations, same order, same value).
            let b_window = self.energy.harvester.off_ticks_hint(dt);
            let b_horizon = conservative_ticks(self.cfg.duration_ms - self.now_ms, dt);
            let b_release = conservative_ticks(self.next_release_min - self.now_ms, dt);
            let b_deadline = watch.ticks_until_due(self.now_ms, dt);
            let n = b_window.min(b_horizon).min(b_release).min(b_deadline);
            if n > 0 {
                let from_ms = self.now_ms;
                self.energy.fast_forward_dark(n, dt);
                // Sequential adds, exactly as the naive ticks would.
                for _ in 0..n {
                    self.now_ms += dt;
                }
                if self.registry.is_some() {
                    // Fixed tie-break priority (release → deadline →
                    // window → horizon) keeps attribution deterministic.
                    let bound = if b_release == n {
                        Hist::FfRelease
                    } else if b_deadline == n {
                        Hist::FfDeadline
                    } else if b_window == n {
                        Hist::FfWindow
                    } else {
                        Hist::FfHorizon
                    };
                    self.reg_add(Counter::FfOffJumps, 1);
                    self.reg_add(Counter::TicksOff, n);
                    self.reg_observe(bound, n);
                }
                if self.trace.is_some() {
                    self.emit(EventKind::FastForward {
                        regime: FfRegime::Off,
                        from_ms,
                        ticks: n,
                    });
                }
            }
            // Exact tail: zero-power per-tick steps onto the event.
            while self.energy.off_tick(dt) {
                self.now_ms += dt;
                self.reg_add(Counter::TicksOff, 1);
                if self.now_ms >= self.cfg.duration_ms
                    || self.next_release_min <= self.now_ms
                    || watch.due(self.now_ms)
                {
                    return;
                }
            }
            // Boundary tick: window crossing, state transition, or the
            // source is on — the full per-tick sequence, identical to
            // `advance_idle` (no probe attached, MCU off on entry).
            self.energy.tick(dt);
            self.energy.capacitor.idle_drain(self.cfg.idle_power_mw, dt);
            let booted = self.energy.capacitor.mcu_on();
            if booted {
                self.metrics.on_time_ms += dt;
                let _ = self.jit_check();
            }
            self.now_ms += dt;
            self.reg_add(
                if booted { Counter::TicksOnIdle } else { Counter::TicksOff },
                1,
            );
            if booted
                || self.now_ms >= self.cfg.duration_ms
                || self.next_release_min <= self.now_ms
                || watch.due(self.now_ms)
            {
                return;
            }
        }
    }

    /// How many idle ticks the JIT checkpoint machinery provably stays a
    /// no-op for, while the capacitor only drains (dark window, MCU on).
    /// Legs, in trigger order of `jit_check`:
    ///
    /// * non-JIT policies never fire — unbounded;
    /// * unarmed at or above `jit_rearm_v`: the very next tick re-arms (a
    ///   mutation) — budget 0, the exact tick performs it;
    /// * unarmed below re-arm: draining voltage is non-increasing, so it
    ///   stays unarmed — unbounded;
    /// * armed with no dirty job: `jit_commit_all` early-returns before
    ///   disarming — a pure no-op even if the trigger fires (dirtiness is
    ///   frozen while idle: only execution and rollback change it, and an
    ///   MCU flip is a guarded exit) — unbounded;
    /// * armed and dirty: if the trigger already holds, budget 0 (the
    ///   exact tick commits); else the voltage-crossing predictor bounds
    ///   how long it provably cannot.
    fn jit_idle_budget(&self, drain_mj_per_tick: f64) -> u64 {
        if !self.nvm.is_jit() {
            return u64::MAX;
        }
        if !self.nvm.jit_armed {
            return if self.energy.capacitor.voltage() >= self.nvm.jit_rearm_v {
                0
            } else {
                u64::MAX
            };
        }
        if !self.queue.iter().any(|j| j.is_dirty()) {
            return u64::MAX;
        }
        if self.energy.jit_voltage_trigger(self.nvm.jit_threshold_v) {
            return 0;
        }
        self.energy.ticks_above_voltage(self.nvm.jit_threshold_v, drain_mj_per_tick)
    }

    /// On-phase idle fast-forward: the MCU is up but nothing can run —
    /// either energy-starved (`entry_mand == false`: `mandatory_allowed`
    /// failed) or nothing schedulable (`entry_mand == true`: `pick`
    /// returned `None`). Preconditions (checked by `step`): MCU on, no
    /// probe, not in reference mode; for the `pick`-`None` entry, the
    /// restore check already passed this step (`pending_restore` is only
    /// raised at a power-down — a guarded exit).
    ///
    /// Under those, a naive step is one `advance_idle()` tick — harvest,
    /// idle drain, on-time accrual, JIT check — until an *event*: a
    /// release or believed deadline comes due, the horizon is reached,
    /// the MCU browns out, the dispatch regime changes
    /// (`mandatory_allowed` crosses `entry_mand`), or the ζ_I optional
    /// gate moves (which can change what `pick` returns). While the
    /// harvester is dark all of those are bounded by analytic predictors
    /// — the capacitor only drains, so threshold crossings
    /// (brown-out, JIT trigger, the energy gates, which only matter in
    /// their charging direction) are one-sided — and the dark stretch
    /// collapses into bulk replays of the identical per-tick f64
    /// sequence. Charging ticks (window edges, source on) fall through
    /// to the exact `advance_idle` below, where the tail guards catch
    /// every rising-edge event on its precise tick.
    fn advance_on_phase_idle(&mut self, entry_mand: bool) {
        debug_assert!(
            self.energy.capacitor.mcu_on() && self.probe.is_none() && !self.reference
        );
        debug_assert_eq!(self.energy.mandatory_allowed(), entry_mand);
        let dt = self.cfg.idle_tick_ms;
        let drain_mj = self.cfg.idle_power_mw * dt * 1e-3;
        let entry_opt = self.energy.optional_allowed();
        let watch = self.deadline_watch();
        if matches!(watch, DeadlineWatch::Opaque) {
            self.advance_idle();
            return;
        }
        loop {
            let b_window = self.energy.harvester.off_ticks_hint(dt);
            let b_horizon = conservative_ticks(self.cfg.duration_ms - self.now_ms, dt);
            let b_release = conservative_ticks(self.next_release_min - self.now_ms, dt);
            let b_deadline = watch.ticks_until_due(self.now_ms, dt);
            // Brown-out: stay provably above v_off, padded two drain
            // quanta past the √V comparison (zero idle power never
            // crosses — the predictor saturates).
            let b_boot = self.energy.capacitor.idle_ticks_above(
                self.energy.capacitor.floor_mj() + 2.0 * drain_mj,
                drain_mj,
            );
            let b_jit = self.jit_idle_budget(drain_mj);
            let n = b_window
                .min(b_horizon)
                .min(b_release)
                .min(b_deadline)
                .min(b_boot)
                .min(b_jit);
            if n > 0 {
                // Bulk replay of n dark idle ticks: harvester window
                // clock, capacitor drain, on-time, and now — each the
                // identical per-tick f64 add/min sequence, with only the
                // provably-idempotent threshold checks hoisted out.
                let from_ms = self.now_ms;
                self.energy.fast_forward_dark(n, dt);
                self.energy
                    .capacitor
                    .fast_forward_idle_drain(self.cfg.idle_power_mw, dt, n);
                for _ in 0..n {
                    self.metrics.on_time_ms += dt;
                    self.now_ms += dt;
                }
                if self.registry.is_some() {
                    // Tie-break priority: release → deadline → boot →
                    // window → jit → horizon.
                    let bound = if b_release == n {
                        Hist::FfRelease
                    } else if b_deadline == n {
                        Hist::FfDeadline
                    } else if b_boot == n {
                        Hist::FfBoot
                    } else if b_window == n {
                        Hist::FfWindow
                    } else if b_jit == n {
                        Hist::FfJit
                    } else {
                        Hist::FfHorizon
                    };
                    self.reg_add(Counter::FfOnIdleJumps, 1);
                    self.reg_add(Counter::TicksOnIdle, n);
                    self.reg_observe(bound, n);
                }
                if self.trace.is_some() {
                    self.emit(EventKind::FastForward {
                        regime: FfRegime::OnIdle,
                        from_ms,
                        ticks: n,
                    });
                }
            }
            // Event/boundary tick — the naive idle tick, verbatim (this
            // is where charging, re-arm, JIT commits, boots, and window
            // transitions actually happen).
            self.advance_idle();
            if self.now_ms >= self.cfg.duration_ms
                || self.next_release_min <= self.now_ms
                || watch.due(self.now_ms)
                || !self.energy.capacitor.mcu_on()
                || self.energy.mandatory_allowed() != entry_mand
                || self.energy.optional_allowed() != entry_opt
            {
                return;
            }
        }
    }

    /// Probe-attached idle loop: a probe observes every tick, so nothing
    /// may be bulked — but the per-step dispatch (power-edge tracker,
    /// release scan, deadline scan, virtual clock read, scheduler gate)
    /// is still provably inert between events and is hoisted out.
    /// Precondition (checked by `step`): `mandatory_allowed` is false —
    /// the MCU may be in either power state. Exits on exactly the events
    /// the hoisted work exists to handle: horizon, release, believed
    /// deadline, an MCU edge (rollback/reboot bookkeeping), or
    /// `mandatory_allowed` turning true (the dispatch regime changes).
    fn advance_idle_probed(&mut self) {
        debug_assert!(
            !self.energy.mandatory_allowed() && self.probe.is_some() && !self.reference
        );
        let entry_on = self.energy.capacitor.mcu_on();
        let watch = self.deadline_watch();
        if matches!(watch, DeadlineWatch::Opaque) {
            self.advance_idle();
            return;
        }
        loop {
            self.advance_idle();
            if self.now_ms >= self.cfg.duration_ms
                || self.next_release_min <= self.now_ms
                || watch.due(self.now_ms)
                || self.energy.capacitor.mcu_on() != entry_on
                || self.energy.mandatory_allowed()
            {
                return;
            }
        }
    }
}

/// The believed-deadline leg of the idle loops' next-event computation.
/// See [`Engine::deadline_watch`].
#[derive(Clone, Copy, Debug)]
enum DeadlineWatch {
    /// Empty queue: the discard scan has nothing to do at any time.
    Clear,
    /// The clock honors the constant-offset contract: the scan first acts
    /// when `(now + offset).max(0.0) >= min_dl` — bitwise the believed
    /// time the naive scan would compare.
    Watch { offset: f64, min_dl: f64 },
    /// Non-empty queue under a clock with no offset contract: deadline
    /// crossings are unpredictable; the loops step naively instead.
    Opaque,
}

impl DeadlineWatch {
    /// Would the discard scan act at true time `now_ms`? (Exact replica
    /// of `believed_now() >= deadline` for the earliest believed
    /// deadline, per the `const_offset` contract.)
    fn due(self, now_ms: f64) -> bool {
        match self {
            DeadlineWatch::Clear => false,
            DeadlineWatch::Watch { offset, min_dl } => (now_ms + offset).max(0.0) >= min_dl,
            DeadlineWatch::Opaque => true,
        }
    }

    /// Conservative tick budget before `due` can first hold. The `max(0)`
    /// clamp only ever delays the believed crossing (it maps a negative
    /// believed time to 0, still below any positive deadline), so the
    /// unclamped span is a safe bound; an already-due (non-positive or
    /// NaN) span yields 0, and an empty queue never bounds (saturates).
    fn ticks_until_due(self, now_ms: f64, dt_ms: f64) -> u64 {
        match self {
            DeadlineWatch::Clear => u64::MAX,
            DeadlineWatch::Watch { offset, min_dl } => {
                conservative_ticks(min_dl - offset - now_ms, dt_ms)
            }
            DeadlineWatch::Opaque => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::Rtc;
    use crate::coordinator::priority::PriorityParams;
    use crate::coordinator::sched::SchedulerKind;
    use crate::dnn::trace::{SampleTrace, UnitOutcome};
    use crate::energy::capacitor::Capacitor;
    use crate::energy::harvester::Harvester;
    use std::sync::Arc;

    fn trace(exit_at: usize, n: usize, correct: bool) -> SampleTrace {
        SampleTrace {
            label: 1,
            units: (0..n)
                .map(|i| UnitOutcome {
                    gap: if i >= exit_at { 5.0 } else { 0.1 },
                    pred: if correct { 1 } else { 0 },
                    exit: i == exit_at,
                    correct,
                })
                .collect(),
            exit_unit: exit_at,
            oracle_unit: correct.then_some(exit_at.saturating_sub(1)),
        }
    }

    fn task(id: usize, period: f64, deadline: f64) -> TaskSpec {
        TaskSpec {
            id,
            name: format!("t{id}"),
            period_ms: period,
            deadline_ms: deadline,
            unit_time_ms: vec![20.0, 20.0, 20.0],
            // 2 mJ per 20 ms unit = 100 mW active draw — well above the
            // bursty test harvester so intermittency actually bites.
            unit_energy_mj: vec![2.0, 2.0, 2.0],
            unit_fragments: vec![4, 4, 4],
            release_energy_mj: 0.05,
            unit_state_bytes: vec![2048; 3],
            traces: Arc::new(vec![trace(1, 3, true), trace(2, 3, true)]),
            imprecise: true,
        }
    }

    fn persistent_engine(kind: SchedulerKind, exit: ExitPolicy) -> Engine {
        let em = {
            let mut cap = Capacitor::standard();
            cap.precharge();
            EnergyManager::new(cap, Harvester::persistent(600.0), 1.0, 0.05)
        };
        Engine::new(
            SimConfig { duration_ms: 30_000.0, ..Default::default() },
            vec![task(0, 300.0, 600.0)],
            Scheduler::new(kind, PriorityParams::new(600.0, 10.0)),
            exit,
            em,
            Box::new(Rtc),
        )
    }

    #[test]
    fn warm_up_matches_precharged_construction_byte_for_byte() {
        // The explicit warm-up phase (cold construction + `warm_up()`)
        // must be indistinguishable from handing the engine an already
        // precharged capacitor — same initial-energy baseline, same run.
        let warm = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility).run();
        let cold = {
            let em = EnergyManager::new(
                Capacitor::standard(),
                Harvester::persistent(600.0),
                1.0,
                0.05,
            );
            let mut e = Engine::new(
                SimConfig { duration_ms: 30_000.0, ..Default::default() },
                vec![task(0, 300.0, 600.0)],
                Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(600.0, 10.0)),
                ExitPolicy::Utility,
                em,
                Box::new(Rtc),
            );
            assert_eq!(e.metrics.initial_energy_mj, 0.0, "cold start before warm_up");
            e.warm_up();
            e.run()
        };
        assert_eq!(cold.to_json().to_json(), warm.to_json().to_json());
    }

    #[test]
    fn persistent_zygarde_schedules_everything() {
        let m = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility).run();
        assert!(m.released > 50, "released={}", m.released);
        assert_eq!(m.deadline_missed, 0, "misses with slack utilization");
        assert!(m.scheduled_rate() > 0.99, "rate={}", m.scheduled_rate());
        assert!(m.optional_units > 0, "optional units should run at eta=1");
        assert!(m.correct > 0);
    }

    #[test]
    fn persistent_edf_runs_all_units() {
        let m = persistent_engine(SchedulerKind::Edf, ExitPolicy::None).run();
        // EDF with no early exit executes 3 units per scheduled job.
        assert!(m.mandatory_units + m.optional_units >= 3 * m.scheduled);
        assert_eq!(m.deadline_missed, 0);
    }

    #[test]
    fn overload_makes_edf_miss_more_than_edfm() {
        // U > 1: full jobs cannot all fit, mandatory-only can.
        let run = |kind: SchedulerKind, exit: ExitPolicy| {
            let mut e = persistent_engine(kind, exit);
            e.tasks[0].period_ms = 45.0; // 3 units * 20ms = 60ms > T
            e.tasks[0].deadline_ms = 90.0;
            e.cfg.duration_ms = 20_000.0;
            let m = e.run();
            m.scheduled_rate()
        };
        let edf = run(SchedulerKind::Edf, ExitPolicy::None);
        let edfm = run(SchedulerKind::EdfMandatory, ExitPolicy::Utility);
        let zyg = run(SchedulerKind::Zygarde, ExitPolicy::Utility);
        assert!(edfm > edf, "edfm={edfm} edf={edf}");
        assert!(zyg > edf, "zyg={zyg} edf={edf}");
    }

    #[test]
    fn intermittent_power_causes_misses_and_reexecution() {
        let h = Harvester::markov(
            crate::energy::harvester::HarvesterKind::Rf,
            40.0,
            0.9,
            0.5,
            1000.0,
            7,
        );
        let mut cap = Capacitor::new(0.01, 3.3, 2.8, 1.9);
        cap.precharge();
        let em = EnergyManager::new(cap, h, 0.5, 0.05);
        let e = Engine::new(
            SimConfig { duration_ms: 120_000.0, ..Default::default() },
            vec![task(0, 500.0, 1000.0)],
            Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(1000.0, 10.0)),
            ExitPolicy::Utility,
            em,
            Box::new(Rtc),
        );
        let m = e.run();
        assert!(m.released > 0);
        assert!(m.deadline_missed > 0 || m.capture_missed > 0 || m.refragments > 0,
            "expected some interference: {m:?}");
        assert!(m.on_fraction() < 1.0);
    }

    #[test]
    fn queue_capacity_drops_excess() {
        let mut e = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility);
        e.cfg.queue_size = 1;
        e.tasks[0].period_ms = 10.0; // flood
        e.tasks[0].deadline_ms = 2000.0;
        let m = e.run();
        assert!(m.queue_dropped > 0);
    }

    #[test]
    fn ideal_nvm_counts_commits_but_charges_nothing() {
        let m = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility).run();
        assert!(m.commits > 0, "every-fragment policy must commit");
        assert_eq!(m.commit_mj, 0.0);
        assert_eq!(m.commit_ms, 0.0);
        assert_eq!(m.lost_fragments, 0, "zero-cost commits never lose work");
        assert_eq!(m.restores, 0, "persistent power never reboots mid-run");
        assert_eq!(m.jit_commits, 0);
    }

    #[test]
    fn fram_every_fragment_charges_one_commit_per_successful_fragment() {
        let mut e = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility);
        e.nvm = Nvm::build(crate::nvm::NvmSpec::fram_every_fragment(), &e.energy.capacitor);
        let m = e.run();
        assert!(m.commits > 0);
        assert_eq!(m.commits, m.fragments - m.refragments);
        assert!(m.commit_mj > 0.0);
        assert!(m.commit_ms > 0.0);
        // Overhead stays in the low single-digit percents of the total.
        assert!(m.nvm_overhead() < 0.10, "overhead {}", m.nvm_overhead());
        assert!(m.scheduled > 0);
    }

    #[test]
    fn fram_unit_boundary_commits_once_per_unit() {
        let mut e = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility);
        e.nvm = Nvm::build(crate::nvm::NvmSpec::fram_unit_boundary(), &e.energy.capacitor);
        let m = e.run();
        assert_eq!(m.commits, m.mandatory_units + m.optional_units);
        assert!(m.commit_mj > 0.0);
    }

    #[test]
    fn jit_never_fires_on_persistent_power() {
        let mut e = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility);
        e.nvm = Nvm::build(crate::nvm::NvmSpec::fram_jit(), &e.energy.capacitor);
        let m = e.run();
        // The capacitor never sags near v_off, so nothing ever commits —
        // and with no power failures nothing is ever lost either.
        assert_eq!(m.commits, 0);
        assert_eq!(m.jit_commits, 0);
        assert_eq!(m.lost_fragments, 0);
        assert!(m.scheduled > 0);
    }

    #[test]
    fn unit_boundary_loses_more_rolled_back_work_than_every_fragment() {
        let run = |spec: crate::nvm::NvmSpec| {
            let h = Harvester::markov(
                crate::energy::harvester::HarvesterKind::Rf,
                40.0,
                0.9,
                0.5,
                1000.0,
                7,
            );
            let mut cap = Capacitor::new(0.01, 3.3, 2.8, 1.9);
            cap.precharge();
            let em = EnergyManager::new(cap, h, 0.5, 0.05);
            let mut e = Engine::new(
                SimConfig { duration_ms: 240_000.0, ..Default::default() },
                vec![task(0, 500.0, 1000.0)],
                Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(1000.0, 10.0)),
                ExitPolicy::Utility,
                em,
                Box::new(Rtc),
            );
            e.nvm = Nvm::build(spec, &e.energy.capacitor);
            e.run()
        };
        let every = run(crate::nvm::NvmSpec::fram_every_fragment());
        let unit = run(crate::nvm::NvmSpec::fram_unit_boundary());
        // Same seed, same harvester stream. Unit-boundary keeps mid-unit
        // progress volatile, so brownouts roll real work back; the
        // every-fragment policy can lose at most the fragment whose
        // commit was interrupted.
        assert!(unit.lost_fragments > 0, "brownouts must cost volatile work");
        assert!(
            unit.lost_fragments >= every.lost_fragments,
            "unit {} < every {}",
            unit.lost_fragments,
            every.lost_fragments
        );
        // And the steady-state commit bill goes the other way.
        assert!(every.commits > unit.commits);
        // Reboots with durable progress pay restore costs.
        assert!(every.restores > 0 || unit.restores > 0);
    }

    /// The tentpole invariant, at engine scope: the optimized dispatcher
    /// (off-phase fast-forward + flattened gates) and the naive reference
    /// stepper produce bit-identical metrics on an intermittent scenario
    /// that exercises long off phases, brownouts mid-fragment, NVM
    /// rollback/restore, and queue churn. (The randomized cross-product
    /// lives in `rust/tests/engine_differential.rs`.)
    #[test]
    fn fast_and_reference_steppers_agree_bitwise() {
        let mk = |nvm: crate::nvm::NvmSpec| {
            let h = Harvester::markov(
                crate::energy::harvester::HarvesterKind::Rf,
                40.0,
                0.9,
                0.3,
                1000.0,
                13,
            );
            let mut cap = Capacitor::new(0.01, 3.3, 2.8, 1.9);
            cap.precharge();
            let em = EnergyManager::new(cap, h, 0.5, 0.05);
            let mut e = Engine::new(
                SimConfig { duration_ms: 300_000.0, ..Default::default() },
                vec![task(0, 500.0, 1000.0)],
                Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(1000.0, 10.0)),
                ExitPolicy::Utility,
                em,
                Box::new(Rtc),
            );
            e.nvm = Nvm::build(nvm, &e.energy.capacitor);
            e
        };
        for nvm in [
            crate::nvm::NvmSpec::ideal(),
            crate::nvm::NvmSpec::fram_every_fragment(),
            crate::nvm::NvmSpec::fram_unit_boundary(),
            crate::nvm::NvmSpec::fram_jit(),
        ] {
            let fast = mk(nvm).run();
            let mut re = mk(nvm);
            re.reference = true;
            let refm = re.run();
            assert_eq!(
                fast.to_json().to_json(),
                refm.to_json().to_json(),
                "fast vs reference diverged under {:?}",
                nvm
            );
            assert!(refm.reboots > 0, "scenario never cycled power — no off phase exercised");
        }
    }

    /// Event-driven regime coverage: each scenario makes a different idle
    /// fast-forward loop dominate the run — on-phase idle entered via
    /// `pick`-`None` under a rich harvester, off-phase with a queued
    /// backlog under a believed-deadline watch (skewed CHRT clock),
    /// on-but-starved (usable energy below E_man while up), and a
    /// round-robin + piezo pairing that leans on RR's pick-`None` purity
    /// — and each must match the naive reference stepper bit for bit.
    #[test]
    fn event_driven_loops_agree_bitwise_in_every_regime() {
        use crate::clock::{Chrt, ChrtTier, Rtc};
        use crate::energy::harvester::HarvesterKind;

        type Build = Box<dyn Fn() -> Engine>;
        let scenarios: Vec<(&str, Build)> = vec![
            (
                "on-idle rich solar + fram_jit",
                Box::new(|| {
                    let h = Harvester::markov(HarvesterKind::Solar, 350.0, 0.97, 0.5, 700.0, 11);
                    let mut cap = Capacitor::standard();
                    cap.precharge();
                    let em = EnergyManager::new(cap, h, 0.5, 0.05);
                    let mut e = Engine::new(
                        SimConfig { duration_ms: 240_000.0, ..Default::default() },
                        vec![task(0, 5_000.0, 10_000.0)],
                        Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(10_000.0, 10.0)),
                        ExitPolicy::Utility,
                        em,
                        Box::new(Rtc),
                    );
                    e.nvm = Nvm::build(crate::nvm::NvmSpec::fram_jit(), &e.energy.capacitor);
                    e
                }),
            ),
            (
                "queued backlog across off phases, skewed clock",
                Box::new(|| {
                    let h = Harvester::markov(HarvesterKind::Rf, 40.0, 0.9, 0.3, 1000.0, 23);
                    let mut cap = Capacitor::new(0.01, 3.3, 2.8, 1.9);
                    cap.precharge();
                    let em = EnergyManager::new(cap, h, 0.5, 0.05);
                    let mut e = Engine::new(
                        SimConfig { duration_ms: 240_000.0, ..Default::default() },
                        vec![task(0, 500.0, 5_000.0)],
                        Scheduler::new(
                            SchedulerKind::EdfMandatory,
                            PriorityParams::new(5_000.0, 10.0),
                        ),
                        ExitPolicy::Utility,
                        em,
                        Box::new(Chrt::new(ChrtTier::Tier3, 5)),
                    );
                    e.nvm =
                        Nvm::build(crate::nvm::NvmSpec::fram_unit_boundary(), &e.energy.capacitor);
                    e
                }),
            ),
            (
                "on but starved: usable energy below E_man",
                Box::new(|| {
                    let h = Harvester::markov(HarvesterKind::Rf, 25.0, 0.9, 0.4, 1000.0, 31);
                    let mut cap = Capacitor::new(0.002, 3.3, 2.8, 1.9);
                    cap.precharge();
                    // E_man above the 2 mF capacitor's usable swing at
                    // boot: the MCU spends long stretches up but unable
                    // to run a fragment — the starved on-phase loop.
                    let em = EnergyManager::new(cap, h, 0.5, 6.0);
                    Engine::new(
                        SimConfig { duration_ms: 240_000.0, ..Default::default() },
                        vec![task(0, 800.0, 1_600.0)],
                        Scheduler::new(SchedulerKind::Zygarde, PriorityParams::new(1_600.0, 10.0)),
                        ExitPolicy::Utility,
                        em,
                        Box::new(Chrt::new(ChrtTier::Tier3, 9)),
                    )
                }),
            ),
            (
                "round-robin over piezo windows",
                Box::new(|| {
                    let mut cap = Capacitor::new(0.01, 3.3, 2.8, 1.9);
                    cap.precharge();
                    let em = EnergyManager::new(cap, Harvester::piezo(17), 0.5, 0.05);
                    Engine::new(
                        SimConfig { duration_ms: 240_000.0, ..Default::default() },
                        vec![task(0, 1_000.0, 4_000.0)],
                        Scheduler::new(
                            SchedulerKind::RoundRobin,
                            PriorityParams::new(4_000.0, 10.0),
                        ),
                        ExitPolicy::None,
                        em,
                        Box::new(Rtc),
                    )
                }),
            ),
        ];
        for (name, mk) in &scenarios {
            let fast = mk().run();
            let mut re = mk();
            re.reference = true;
            let refm = re.run();
            assert_eq!(
                fast.to_json().to_json(),
                refm.to_json().to_json(),
                "fast vs reference diverged: {name}"
            );
            assert!(refm.released > 0, "{name}: no jobs ever released");
        }
    }

    #[test]
    fn oracle_exit_terminates_earlier_than_utility() {
        let mu = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Utility).run();
        let mo = persistent_engine(SchedulerKind::Zygarde, ExitPolicy::Oracle).run();
        let units_per_job_u =
            (mu.mandatory_units + mu.optional_units) as f64 / mu.scheduled.max(1) as f64;
        let units_per_job_o =
            (mo.mandatory_units + mo.optional_units) as f64 / mo.scheduled.max(1) as f64;
        assert!(units_per_job_o <= units_per_job_u + 1e-9,
            "oracle {units_per_job_o} vs utility {units_per_job_u}");
    }
}
