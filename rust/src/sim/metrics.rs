//! Simulation outcome counters — the quantities the paper's evaluation
//! figures report (jobs scheduled, correct results, deadline misses,
//! optional units executed, energy accounting).

use crate::util::json::Value;

/// Audit record for one job leaving the system (deadline discard, queue
/// eviction, or completion). Collected only when `SimConfig::log_jobs` is
/// set; the sweep invariant tests check these against the counters.
#[derive(Clone, Copy, Debug)]
pub struct JobRecord {
    pub task: usize,
    pub release_ms: f64,
    /// Absolute (true-time) deadline.
    pub deadline_ms: f64,
    /// Completion time of the mandatory part, if it ever completed.
    pub mandatory_done_at: Option<f64>,
    pub units_done: usize,
    /// Whether this job was counted in [`Metrics::scheduled`].
    pub counted_scheduled: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Jobs released by the job generator (entered the system).
    pub released: u64,
    /// Sensor events missed because the capacitor could not pay the
    /// sensor-read energy (never entered the system; Fig. 22/23).
    pub capture_missed: u64,
    /// Jobs dropped because the queue was full (queue size 3, §8).
    pub queue_dropped: u64,
    /// Jobs whose mandatory part completed before the deadline
    /// ("scheduled" in §8.5's sense).
    pub scheduled: u64,
    /// Scheduled jobs whose final prediction was correct.
    pub correct: u64,
    /// Jobs discarded at their deadline with incomplete mandatory work.
    pub deadline_missed: u64,
    /// Units executed, split mandatory/optional.
    pub mandatory_units: u64,
    pub optional_units: u64,
    /// Fragments re-executed due to power failure mid-fragment.
    pub refragments: u64,
    pub fragments: u64,
    /// NVM commit transactions (fragment-, unit-, or JIT-triggered).
    pub commits: u64,
    /// Commits fired by the JIT low-voltage trigger (subset of `commits`).
    pub jit_commits: u64,
    /// Energy and time spent writing checkpoints to NVM.
    pub commit_mj: f64,
    pub commit_ms: f64,
    /// Checkpoint restores after reboots, and their cost.
    pub restores: u64,
    pub restore_mj: f64,
    pub restore_ms: f64,
    /// Completed-but-uncommitted fragments rolled back on power failure
    /// (distinct from `refragments`, the in-flight fragment the energy of
    /// which was spent without completing).
    pub lost_fragments: u64,
    /// Per-task scheduled counts (multi-task fairness, Fig. 23).
    pub per_task_released: Vec<u64>,
    pub per_task_scheduled: Vec<u64>,
    pub per_task_correct: Vec<u64>,
    /// Mean latency of scheduled jobs (release -> mandatory done), ms.
    pub latency_sum_ms: f64,
    /// Total simulated time (ms) and MCU-on time (ms).
    pub sim_time_ms: f64,
    pub on_time_ms: f64,
    pub reboots: u64,
    pub harvested_mj: f64,
    pub wasted_mj: f64,
    /// Capacitor energy at engine construction / simulation end, and the
    /// total the simulation drew (fragments + idle + commits + restores +
    /// brownout remnants). Together with `harvested_mj` and `wasted_mj`
    /// these close the energy-conservation identity the sweep property
    /// tests check: initial + harvested = final + wasted + consumed.
    pub initial_energy_mj: f64,
    pub final_energy_mj: f64,
    pub consumed_mj: f64,
    /// Per-job audit trail; empty unless `SimConfig::log_jobs` was set.
    pub job_log: Vec<JobRecord>,
}

impl Metrics {
    pub fn new(n_tasks: usize) -> Self {
        Metrics {
            per_task_released: vec![0; n_tasks],
            per_task_scheduled: vec![0; n_tasks],
            per_task_correct: vec![0; n_tasks],
            ..Default::default()
        }
    }

    pub fn scheduled_rate(&self) -> f64 {
        self.scheduled as f64 / self.released.max(1) as f64
    }

    pub fn correct_rate(&self) -> f64 {
        self.correct as f64 / self.released.max(1) as f64
    }

    /// Scheduled / all sensor events (released + capture-missed). The
    /// event stream is identical across schedulers for a given seed, so
    /// this is the apples-to-apples denominator for Figs. 17–20 — a
    /// scheduler that burns energy on optional units and then cannot pay
    /// for the next sensor read must not look *better* for it.
    pub fn event_scheduled_rate(&self) -> f64 {
        self.scheduled as f64 / (self.released + self.capture_missed).max(1) as f64
    }

    pub fn event_correct_rate(&self) -> f64 {
        self.correct as f64 / (self.released + self.capture_missed).max(1) as f64
    }

    /// Accuracy among scheduled jobs.
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.scheduled.max(1) as f64
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latency_sum_ms / self.scheduled.max(1) as f64
    }

    pub fn on_fraction(&self) -> f64 {
        self.on_time_ms / self.sim_time_ms.max(1e-9)
    }

    /// NVM commit + restore energy as a fraction of everything consumed —
    /// the checkpointing overhead the commit-policy comparison reports.
    pub fn nvm_overhead(&self) -> f64 {
        (self.commit_mj + self.restore_mj) / self.consumed_mj.max(1e-9)
    }

    /// Machine-readable summary for `sim::sweep` reports. Every field that
    /// feeds an evaluation figure is included; the `job_log` audit trail
    /// is not (it is an in-memory debugging aid, not a result).
    pub fn to_json(&self) -> Value {
        fn num(m: &mut std::collections::BTreeMap<String, Value>, k: &str, v: f64) {
            m.insert(k.to_string(), Value::Num(v));
        }
        let mut m = std::collections::BTreeMap::new();
        num(&mut m, "released", self.released as f64);
        num(&mut m, "capture_missed", self.capture_missed as f64);
        num(&mut m, "queue_dropped", self.queue_dropped as f64);
        num(&mut m, "scheduled", self.scheduled as f64);
        num(&mut m, "correct", self.correct as f64);
        num(&mut m, "deadline_missed", self.deadline_missed as f64);
        num(&mut m, "mandatory_units", self.mandatory_units as f64);
        num(&mut m, "optional_units", self.optional_units as f64);
        num(&mut m, "refragments", self.refragments as f64);
        num(&mut m, "fragments", self.fragments as f64);
        num(&mut m, "commits", self.commits as f64);
        num(&mut m, "jit_commits", self.jit_commits as f64);
        num(&mut m, "commit_mj", self.commit_mj);
        num(&mut m, "commit_ms", self.commit_ms);
        num(&mut m, "restores", self.restores as f64);
        num(&mut m, "restore_mj", self.restore_mj);
        num(&mut m, "restore_ms", self.restore_ms);
        num(&mut m, "lost_fragments", self.lost_fragments as f64);
        num(&mut m, "latency_sum_ms", self.latency_sum_ms);
        num(&mut m, "sim_time_ms", self.sim_time_ms);
        num(&mut m, "on_time_ms", self.on_time_ms);
        num(&mut m, "reboots", self.reboots as f64);
        num(&mut m, "harvested_mj", self.harvested_mj);
        num(&mut m, "wasted_mj", self.wasted_mj);
        num(&mut m, "initial_energy_mj", self.initial_energy_mj);
        num(&mut m, "final_energy_mj", self.final_energy_mj);
        num(&mut m, "consumed_mj", self.consumed_mj);
        let arr = |xs: &[u64]| Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect());
        m.insert("per_task_released".to_string(), arr(&self.per_task_released));
        m.insert("per_task_scheduled".to_string(), arr(&self.per_task_scheduled));
        m.insert("per_task_correct".to_string(), arr(&self.per_task_correct));
        Value::Obj(m)
    }

    /// Inverse of [`Metrics::to_json`] — the deserialization half of the
    /// shard-report round trip (`sim::sweep::shard`). The JSON writer emits
    /// f64s in their shortest round-tripping form, so parse-then-reserialize
    /// is byte-identical; the `job_log` audit trail is never serialized and
    /// comes back empty.
    pub fn from_json(v: &Value) -> Result<Metrics, String> {
        let num = |k: &str| -> Result<f64, String> {
            v.get(k)
                .and_then(Value::as_f64)
                .ok_or_else(|| format!("metrics: missing numeric field `{k}`"))
        };
        let count = |k: &str| -> Result<u64, String> { Ok(num(k)? as u64) };
        let counts = |k: &str| -> Result<Vec<u64>, String> {
            v.get(k)
                .and_then(Value::as_arr)
                .ok_or_else(|| format!("metrics: missing array field `{k}`"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as u64)
                        .ok_or_else(|| format!("metrics: non-numeric entry in `{k}`"))
                })
                .collect()
        };
        Ok(Metrics {
            released: count("released")?,
            capture_missed: count("capture_missed")?,
            queue_dropped: count("queue_dropped")?,
            scheduled: count("scheduled")?,
            correct: count("correct")?,
            deadline_missed: count("deadline_missed")?,
            mandatory_units: count("mandatory_units")?,
            optional_units: count("optional_units")?,
            refragments: count("refragments")?,
            fragments: count("fragments")?,
            commits: count("commits")?,
            jit_commits: count("jit_commits")?,
            commit_mj: num("commit_mj")?,
            commit_ms: num("commit_ms")?,
            restores: count("restores")?,
            restore_mj: num("restore_mj")?,
            restore_ms: num("restore_ms")?,
            lost_fragments: count("lost_fragments")?,
            per_task_released: counts("per_task_released")?,
            per_task_scheduled: counts("per_task_scheduled")?,
            per_task_correct: counts("per_task_correct")?,
            latency_sum_ms: num("latency_sum_ms")?,
            sim_time_ms: num("sim_time_ms")?,
            on_time_ms: num("on_time_ms")?,
            reboots: count("reboots")?,
            harvested_mj: num("harvested_mj")?,
            wasted_mj: num("wasted_mj")?,
            initial_energy_mj: num("initial_energy_mj")?,
            final_energy_mj: num("final_energy_mj")?,
            consumed_mj: num("consumed_mj")?,
            job_log: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip_is_exact() {
        let mut m = Metrics::new(2);
        m.released = 123;
        m.scheduled = 77;
        m.correct = 60;
        m.commits = 999;
        m.commit_mj = 0.1 + 0.2; // deliberately non-representable (0.30000000000000004)
        m.latency_sum_ms = 1234.5678901234567;
        m.harvested_mj = 1e-9;
        m.per_task_released = vec![100, 23];
        m.per_task_scheduled = vec![50, 27];
        m.per_task_correct = vec![40, 20];
        let json = m.to_json().to_json();
        let back = Metrics::from_json(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.to_json().to_json(), json, "round trip must be byte-identical");
        assert_eq!(back.released, 123);
        assert_eq!(back.commit_mj, m.commit_mj);
        assert_eq!(back.per_task_scheduled, vec![50, 27]);
    }

    #[test]
    fn from_json_reports_missing_fields() {
        let v = Value::parse(r#"{"released": 3}"#).unwrap();
        let err = Metrics::from_json(&v).unwrap_err();
        assert!(err.contains("missing"), "{err}");
    }
}
