//! Simulation outcome counters — the quantities the paper's evaluation
//! figures report (jobs scheduled, correct results, deadline misses,
//! optional units executed, energy accounting).

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Jobs released by the job generator (entered the system).
    pub released: u64,
    /// Sensor events missed because the capacitor could not pay the
    /// sensor-read energy (never entered the system; Fig. 22/23).
    pub capture_missed: u64,
    /// Jobs dropped because the queue was full (queue size 3, §8).
    pub queue_dropped: u64,
    /// Jobs whose mandatory part completed before the deadline
    /// ("scheduled" in §8.5's sense).
    pub scheduled: u64,
    /// Scheduled jobs whose final prediction was correct.
    pub correct: u64,
    /// Jobs discarded at their deadline with incomplete mandatory work.
    pub deadline_missed: u64,
    /// Units executed, split mandatory/optional.
    pub mandatory_units: u64,
    pub optional_units: u64,
    /// Fragments re-executed due to power failure mid-fragment.
    pub refragments: u64,
    pub fragments: u64,
    /// Per-task scheduled counts (multi-task fairness, Fig. 23).
    pub per_task_released: Vec<u64>,
    pub per_task_scheduled: Vec<u64>,
    pub per_task_correct: Vec<u64>,
    /// Mean latency of scheduled jobs (release -> mandatory done), ms.
    pub latency_sum_ms: f64,
    /// Total simulated time (ms) and MCU-on time (ms).
    pub sim_time_ms: f64,
    pub on_time_ms: f64,
    pub reboots: u64,
    pub harvested_mj: f64,
    pub wasted_mj: f64,
}

impl Metrics {
    pub fn new(n_tasks: usize) -> Self {
        Metrics {
            per_task_released: vec![0; n_tasks],
            per_task_scheduled: vec![0; n_tasks],
            per_task_correct: vec![0; n_tasks],
            ..Default::default()
        }
    }

    pub fn scheduled_rate(&self) -> f64 {
        self.scheduled as f64 / self.released.max(1) as f64
    }

    pub fn correct_rate(&self) -> f64 {
        self.correct as f64 / self.released.max(1) as f64
    }

    /// Scheduled / all sensor events (released + capture-missed). The
    /// event stream is identical across schedulers for a given seed, so
    /// this is the apples-to-apples denominator for Figs. 17–20 — a
    /// scheduler that burns energy on optional units and then cannot pay
    /// for the next sensor read must not look *better* for it.
    pub fn event_scheduled_rate(&self) -> f64 {
        self.scheduled as f64 / (self.released + self.capture_missed).max(1) as f64
    }

    pub fn event_correct_rate(&self) -> f64 {
        self.correct as f64 / (self.released + self.capture_missed).max(1) as f64
    }

    /// Accuracy among scheduled jobs.
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.scheduled.max(1) as f64
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latency_sum_ms / self.scheduled.max(1) as f64
    }

    pub fn on_fraction(&self) -> f64 {
        self.on_time_ms / self.sim_time_ms.max(1e-9)
    }
}
