//! Workload construction: turn a loaded [`Network`] (artifact bundle) into
//! a [`TaskSpec`] whose unit costs come from the compile-time cost model
//! and whose data-dependent behaviour comes from precomputed unit traces.

use std::sync::Arc;

use crate::coordinator::task::TaskSpec;
use crate::dnn::network::Network;
use crate::dnn::trace::{compute_traces, SampleTrace};

/// Build a task for `net` with period T and relative deadline D (ms).
/// Traces default to the network's own test set.
pub fn task_from_network(
    id: usize,
    net: &Network,
    period_ms: f64,
    deadline_ms: f64,
    traces: Option<Arc<Vec<SampleTrace>>>,
) -> TaskSpec {
    let traces = traces.unwrap_or_else(|| Arc::new(compute_traces(net, None)));
    TaskSpec {
        id,
        name: net.meta.name.clone(),
        period_ms,
        deadline_ms,
        unit_time_ms: net.meta.layers.iter().map(|l| l.time_ms).collect(),
        unit_energy_mj: net.meta.layers.iter().map(|l| l.energy_mj).collect(),
        unit_fragments: net.meta.layers.iter().map(|l| l.n_fragments).collect(),
        release_energy_mj: net.meta.cost.job_generator_energy_mj,
        traces,
        imprecise: true,
    }
}

/// Fluent builder for multi-task workloads (Fig. 23 uses two tasks).
pub struct WorkloadBuilder {
    tasks: Vec<TaskSpec>,
}

impl WorkloadBuilder {
    pub fn new() -> Self {
        WorkloadBuilder { tasks: Vec::new() }
    }

    pub fn add_network(
        mut self,
        net: &Network,
        period_ms: f64,
        deadline_ms: f64,
    ) -> Self {
        let id = self.tasks.len();
        self.tasks.push(task_from_network(id, net, period_ms, deadline_ms, None));
        self
    }

    pub fn add_task(mut self, mut spec: TaskSpec) -> Self {
        spec.id = self.tasks.len();
        self.tasks.push(spec);
        self
    }

    pub fn build(self) -> Vec<TaskSpec> {
        assert!(!self.tasks.is_empty(), "workload needs at least one task");
        self.tasks
    }
}

impl Default for WorkloadBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_task_from_real_network() {
        let dir = crate::artifacts_root().join("mnist");
        if !dir.join("meta.json").exists() {
            return;
        }
        let net = Network::load(&dir).unwrap();
        let t = task_from_network(0, &net, 3000.0, 6000.0, None);
        assert_eq!(t.n_units(), net.meta.n_layers);
        assert_eq!(t.traces.len(), net.test.len());
        assert!(t.wcet_ms() > 0.0);
        // cost model total matches the meta total
        assert!((t.wcet_ms() - net.meta.cost.total_time_ms).abs() / t.wcet_ms() < 1e-6);
    }

    #[test]
    fn builder_assigns_sequential_ids() {
        let dir = crate::artifacts_root().join("mnist");
        if !dir.join("meta.json").exists() {
            return;
        }
        let net = Network::load(&dir).unwrap();
        let tasks = WorkloadBuilder::new()
            .add_network(&net, 3000.0, 6000.0)
            .add_network(&net, 5000.0, 10_000.0)
            .build();
        assert_eq!(tasks[0].id, 0);
        assert_eq!(tasks[1].id, 1);
    }
}
