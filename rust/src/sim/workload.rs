//! Workload construction: turn a loaded [`Network`] (artifact bundle) into
//! a [`TaskSpec`] whose unit costs come from the compile-time cost model
//! and whose data-dependent behaviour comes from precomputed unit traces.

use std::sync::Arc;

use crate::coordinator::task::TaskSpec;
use crate::dnn::network::Network;
use crate::dnn::trace::{compute_traces, SampleTrace, UnitOutcome};
use crate::util::rng::Pcg32;

/// Build a task for `net` with period T and relative deadline D (ms).
/// Traces default to the network's own test set.
pub fn task_from_network(
    id: usize,
    net: &Network,
    period_ms: f64,
    deadline_ms: f64,
    traces: Option<Arc<Vec<SampleTrace>>>,
) -> TaskSpec {
    let traces = traces.unwrap_or_else(|| Arc::new(compute_traces(net, None)));
    TaskSpec {
        id,
        name: net.meta.name.clone(),
        period_ms,
        deadline_ms,
        unit_time_ms: net.meta.layers.iter().map(|l| l.time_ms).collect(),
        unit_energy_mj: net.meta.layers.iter().map(|l| l.energy_mj).collect(),
        unit_fragments: net.meta.layers.iter().map(|l| l.n_fragments).collect(),
        release_energy_mj: net.meta.cost.job_generator_energy_mj,
        // Checkpoint footprint per unit: its f32 activation buffer (the
        // state a fragment-boundary commit must persist to NVM).
        unit_state_bytes: net
            .meta
            .layers
            .iter()
            .map(|l| 4 * l.act_shape.iter().product::<usize>().max(1))
            .collect(),
        traces,
        imprecise: true,
    }
}

/// Synthetic [`TaskSpec`] fallback: an L-unit agile DNN whose unit traces
/// are generated from a seeded [`Pcg32`] instead of a compiled network, so
/// the sweep engine and its tests run without `artifacts/`. Deterministic
/// in `(seed, id)`. The trace model mirrors the real networks' shape:
/// per-sample difficulty drives the exit depth (easy samples pass the
/// utility test early), exited units predict well (92 % correct), and
/// pre-exit units are barely better than chance.
pub fn synthetic_task(
    id: usize,
    n_units: usize,
    period_ms: f64,
    deadline_ms: f64,
    n_traces: usize,
    seed: u64,
) -> TaskSpec {
    assert!(n_units > 0 && n_traces > 0);
    let mut rng = Pcg32::new(seed, id as u64);
    let n_classes = 4i32;
    let mut traces = Vec::with_capacity(n_traces);
    for _ in 0..n_traces {
        let label = rng.below(n_classes as u64) as i32;
        let difficulty = rng.f64();
        let exit_unit = ((difficulty * n_units as f64) as usize).min(n_units - 1);
        let units: Vec<UnitOutcome> = (0..n_units)
            .map(|u| {
                let exited = u >= exit_unit;
                let correct = if exited { rng.chance(0.92) } else { rng.chance(0.55) };
                UnitOutcome {
                    gap: if exited { 5.0 + 5.0 * rng.f32() } else { 2.0 * rng.f32() },
                    pred: if correct { label } else { (label + 1) % n_classes },
                    exit: u == exit_unit,
                    correct,
                }
            })
            .collect();
        let oracle_unit = units.iter().position(|u| u.correct);
        traces.push(SampleTrace { label, units, exit_unit, oracle_unit });
    }
    TaskSpec {
        id,
        name: format!("synthetic{id}"),
        period_ms,
        deadline_ms,
        // 20 ms / 2 mJ units in 4 fragments: a 100 mW active draw, the
        // same scale the engine unit tests use, so intermittency bites
        // under the weak harvesters.
        unit_time_ms: vec![20.0; n_units],
        unit_energy_mj: vec![2.0; n_units],
        unit_fragments: vec![4; n_units],
        release_energy_mj: 0.05,
        // A small 2 KB activation buffer per unit (the synthetic agile
        // DNN's checkpoint footprint for the NVM commit-cost model).
        unit_state_bytes: vec![2048; n_units],
        traces: Arc::new(traces),
        imprecise: true,
    }
}

/// Fluent builder for multi-task workloads (Fig. 23 uses two tasks).
pub struct WorkloadBuilder {
    tasks: Vec<TaskSpec>,
}

impl WorkloadBuilder {
    pub fn new() -> Self {
        WorkloadBuilder { tasks: Vec::new() }
    }

    pub fn add_network(
        mut self,
        net: &Network,
        period_ms: f64,
        deadline_ms: f64,
    ) -> Self {
        let id = self.tasks.len();
        self.tasks.push(task_from_network(id, net, period_ms, deadline_ms, None));
        self
    }

    pub fn add_task(mut self, mut spec: TaskSpec) -> Self {
        spec.id = self.tasks.len();
        self.tasks.push(spec);
        self
    }

    pub fn build(self) -> Vec<TaskSpec> {
        assert!(!self.tasks.is_empty(), "workload needs at least one task");
        self.tasks
    }
}

impl Default for WorkloadBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_task_is_deterministic_and_well_formed() {
        let a = synthetic_task(0, 3, 300.0, 600.0, 40, 42);
        let b = synthetic_task(0, 3, 300.0, 600.0, 40, 42);
        assert_eq!(a.traces.len(), 40);
        for (ta, tb) in a.traces.iter().zip(b.traces.iter()) {
            assert_eq!(ta.label, tb.label);
            assert_eq!(ta.exit_unit, tb.exit_unit);
            for (ua, ub) in ta.units.iter().zip(tb.units.iter()) {
                assert_eq!(ua.pred, ub.pred);
                assert_eq!(ua.exit, ub.exit);
                assert_eq!(ua.gap, ub.gap);
            }
        }
        let c = synthetic_task(0, 3, 300.0, 600.0, 40, 43);
        assert!(
            a.traces.iter().zip(c.traces.iter()).any(|(x, y)| x.label != y.label
                || x.exit_unit != y.exit_unit),
            "different seeds should give different traces"
        );
        for t in a.traces.iter() {
            assert_eq!(t.units.len(), 3);
            assert_eq!(t.units.iter().filter(|u| u.exit).count(), 1);
            assert!(t.units[t.exit_unit].exit);
            for u in &t.units {
                // `correct` is consistent with pred-vs-label.
                assert_eq!(u.correct, u.pred == t.label);
            }
        }
        assert!(a.wcet_ms() == 60.0);
    }

    #[test]
    fn builds_task_from_real_network() {
        let dir = crate::artifacts_root().join("mnist");
        if !dir.join("meta.json").exists() {
            return;
        }
        let net = Network::load(&dir).unwrap();
        let t = task_from_network(0, &net, 3000.0, 6000.0, None);
        assert_eq!(t.n_units(), net.meta.n_layers);
        assert_eq!(t.traces.len(), net.test.len());
        assert!(t.wcet_ms() > 0.0);
        // cost model total matches the meta total
        assert!((t.wcet_ms() - net.meta.cost.total_time_ms).abs() / t.wcet_ms() < 1e-6);
    }

    #[test]
    fn builder_assigns_sequential_ids() {
        let dir = crate::artifacts_root().join("mnist");
        if !dir.join("meta.json").exists() {
            return;
        }
        let net = Network::load(&dir).unwrap();
        let tasks = WorkloadBuilder::new()
            .add_network(&net, 3000.0, 6000.0)
            .add_network(&net, 5000.0, 10_000.0)
            .build();
        assert_eq!(tasks[0].id, 0);
        assert_eq!(tasks[1].id, 1);
    }
}
