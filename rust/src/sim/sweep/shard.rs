//! Sharded multi-process sweep execution with deterministic merging.
//!
//! The per-scenario `(matrix_seed, scenario_index)` seed derivation makes
//! every cell of a [`ScenarioMatrix`] location-independent, so scaling a
//! sweep across processes or hosts is purely an orchestration + merge
//! problem. This module supplies the three pieces:
//!
//! * [`ShardSpec`] — `shard_index/shard_count`, a deterministic strided
//!   partition of the matrix expansion (cell `i` belongs to shard
//!   `i % shard_count`). Striding, not contiguous ranges, so uneven-cost
//!   cells (a 470 mF cold-start runs ~10× a 1 mF cell) load-balance.
//! * [`PartialReport`] — one shard's [`CellResult`]s serialized with
//!   `util::json`, carrying a [`MatrixFingerprint`] (matrix seed, axis
//!   hash, total cell count) so shards of *different* matrices — or of a
//!   matrix whose axes drifted between runs — are rejected at merge time
//!   instead of producing a silently wrong report.
//! * [`merge`] — reassembles any complete set of partial reports into a
//!   [`SweepReport`] that is **byte-identical** to the single-process
//!   `SweepReport::json_string` for any shard count (including 1): cells
//!   are re-sorted by scenario index and [`SummaryStats`] recomputed from
//!   the union in index order, which replays the exact f64 operation
//!   sequence of the single-process path.
//!
//! CLI: `zygarde sweep --matrix M --shard I/N --out shard_I.json` on N
//! hosts, then `zygarde merge shard_*.json --out report.json` anywhere.
//!
//! [`SummaryStats`]: super::report::SummaryStats

use std::fmt;
use std::path::Path;

use crate::util::json::Value;

use crate::nvm::CommitPolicy;

use super::report::{CellResult, SweepReport};
use super::runner;
use super::{HarvesterSpec, ScenarioMatrix, SeedPolicy};

/// One shard of a strided partition: this process owns every scenario
/// index `i` with `i % shard_count == shard_index`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub shard_index: usize,
    pub shard_count: usize,
}

impl ShardSpec {
    pub fn new(shard_index: usize, shard_count: usize) -> Result<ShardSpec, String> {
        if shard_count == 0 {
            return Err("shard count must be > 0".to_string());
        }
        if shard_index >= shard_count {
            return Err(format!(
                "shard index {shard_index} out of range for {shard_count} shards"
            ));
        }
        Ok(ShardSpec { shard_index, shard_count })
    }

    /// The degenerate single-shard spec: owns every scenario.
    pub fn whole() -> ShardSpec {
        ShardSpec { shard_index: 0, shard_count: 1 }
    }

    /// Parse the CLI form `I/N` (e.g. `--shard 2/8`).
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let (i, n) = s
            .split_once('/')
            .ok_or_else(|| format!("bad shard spec `{s}`: expected I/N, e.g. 0/4"))?;
        let i = i.trim().parse::<usize>().map_err(|_| format!("bad shard index in `{s}`"))?;
        let n = n.trim().parse::<usize>().map_err(|_| format!("bad shard count in `{s}`"))?;
        ShardSpec::new(i, n)
    }

    /// Does this shard own scenario index `idx`?
    pub fn owns(&self, idx: usize) -> bool {
        idx % self.shard_count == self.shard_index
    }

    /// Number of scenarios this shard owns out of `total`.
    pub fn len_of(&self, total: usize) -> usize {
        (total + self.shard_count - 1 - self.shard_index) / self.shard_count
    }

    pub fn label(&self) -> String {
        format!("{}/{}", self.shard_index, self.shard_count)
    }
}

// ---- matrix fingerprint --------------------------------------------------

/// Identity of a matrix expansion, embedded in every [`PartialReport`]:
/// shards only merge when they were cut from the same matrix. The axis
/// hash covers every expansion-relevant field — axes (including task-mix
/// traces), seed policy, horizon, queue geometry — so two matrices agree
/// on the fingerprint only if they expand to identical scenario lists.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MatrixFingerprint {
    pub name: String,
    pub seed: u64,
    pub n_scenarios: usize,
    pub axes_hash: u64,
}

/// Incremental FNV-1a (64-bit) — dependency-free and stable across
/// platforms, unlike `DefaultHasher` whose algorithm is unspecified.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }

    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }

    fn bool(&mut self, b: bool) {
        self.u64(b as u64);
    }
}

/// Compute the [`MatrixFingerprint`] of a matrix.
pub fn fingerprint(m: &ScenarioMatrix) -> MatrixFingerprint {
    let mut h = Fnv::new();
    h.str(&m.name);
    h.u64(m.seed);
    h.u64(match m.seed_policy {
        SeedPolicy::PerScenario => 0,
        SeedPolicy::PairedEnvironment => 1,
    });
    h.u64(m.mixes.len() as u64);
    for mix in &m.mixes {
        h.str(&mix.name);
        h.u64(mix.tasks.len() as u64);
        for t in &mix.tasks {
            h.u64(t.id as u64);
            h.str(&t.name);
            h.f64(t.period_ms);
            h.f64(t.deadline_ms);
            h.f64(t.release_energy_mj);
            h.bool(t.imprecise);
            // Every variable-length vector is length-prefixed so element
            // boundaries are unambiguous in the hash stream (the per-unit
            // vectors can legitimately differ in length — e.g. a short
            // `unit_state_bytes` falls back to the default).
            h.u64(t.unit_time_ms.len() as u64);
            for &x in &t.unit_time_ms {
                h.f64(x);
            }
            h.u64(t.unit_energy_mj.len() as u64);
            for &x in &t.unit_energy_mj {
                h.f64(x);
            }
            h.u64(t.unit_fragments.len() as u64);
            for &x in &t.unit_fragments {
                h.u64(x as u64);
            }
            h.u64(t.unit_state_bytes.len() as u64);
            for &x in &t.unit_state_bytes {
                h.u64(x as u64);
            }
            // Trace content drives the simulated outcomes; hash it so two
            // mixes that differ only in data cannot share a fingerprint.
            h.u64(t.traces.len() as u64);
            for tr in t.traces.iter() {
                h.u64(tr.label as u64);
                h.u64(tr.exit_unit as u64);
                h.u64(tr.oracle_unit.map(|o| o as u64 + 1).unwrap_or(0));
                h.u64(tr.units.len() as u64);
                for u in &tr.units {
                    h.u64(u.gap.to_bits() as u64);
                    h.u64(u.pred as u64);
                    h.bool(u.exit);
                    h.bool(u.correct);
                }
            }
        }
    }
    // Axes are hashed field by field, NOT via their display labels —
    // labels are lossy (a Markov harvester's label omits q and eta, a
    // fault plan's omits the burst offset), and a lossy fingerprint would
    // let shards of *different* simulations merge silently.
    h.u64(m.harvesters.len() as u64);
    for hs in &m.harvesters {
        match *hs {
            HarvesterSpec::System(id) => {
                h.u64(1);
                h.u64(id as u64);
            }
            HarvesterSpec::Persistent { power_mw } => {
                h.u64(2);
                h.f64(power_mw);
            }
            HarvesterSpec::Markov { kind, on_power_mw, q, duty, eta } => {
                h.u64(3);
                h.str(&format!("{kind:?}"));
                h.f64(on_power_mw);
                h.f64(q);
                h.f64(duty);
                h.f64(eta);
            }
            HarvesterSpec::Piezo { eta } => {
                h.u64(4);
                h.f64(eta);
            }
            HarvesterSpec::SolarDiurnal { eta } => {
                h.u64(5);
                h.f64(eta);
            }
        }
    }
    h.u64(m.capacitors_mf.len() as u64);
    for &c in &m.capacitors_mf {
        h.f64(c);
    }
    h.bool(m.precharge);
    h.u64(m.schedulers.len() as u64);
    for s in &m.schedulers {
        h.str(s.name());
    }
    h.u64(m.exits.len() as u64);
    for e in &m.exits {
        h.str(e.map(|e| e.name()).unwrap_or("scheduler-default"));
    }
    h.u64(m.faults.len() as u64);
    for f in &m.faults {
        h.str(f.clock.name());
        match f.brownout {
            None => h.u64(0),
            Some(w) => {
                h.u64(1);
                h.f64(w.period_ms);
                h.f64(w.duration_ms);
                h.f64(w.offset_ms);
            }
        }
    }
    h.u64(m.nvms.len() as u64);
    for n in &m.nvms {
        h.str(n.model.name());
        match n.policy {
            CommitPolicy::EveryFragment => h.u64(0),
            CommitPolicy::UnitBoundary => h.u64(1),
            CommitPolicy::JitVoltage { margin_v } => {
                h.u64(2);
                h.f64(margin_v);
            }
        }
    }
    h.u64(m.n_reps);
    h.f64(m.duration_ms);
    h.u64(m.queue_size as u64);
    h.f64(m.release_jitter);
    h.bool(m.log_jobs);
    MatrixFingerprint {
        name: m.name.clone(),
        seed: m.seed,
        n_scenarios: m.len(),
        axes_hash: h.0,
    }
}

impl MatrixFingerprint {
    pub fn to_json(&self) -> Value {
        let mut m = std::collections::BTreeMap::new();
        m.insert("matrix".to_string(), Value::Str(self.name.clone()));
        m.insert("matrix_seed".to_string(), Value::Str(self.seed.to_string()));
        m.insert("n_scenarios".to_string(), Value::Num(self.n_scenarios as f64));
        m.insert("axes_hash".to_string(), Value::Str(format!("{:016x}", self.axes_hash)));
        Value::Obj(m)
    }

    pub fn from_json(v: &Value) -> Result<MatrixFingerprint, String> {
        let name = v
            .get("matrix")
            .and_then(Value::as_str)
            .ok_or_else(|| "fingerprint: missing `matrix`".to_string())?
            .to_string();
        let seed = v
            .get("matrix_seed")
            .and_then(Value::as_str)
            .ok_or_else(|| "fingerprint: missing `matrix_seed`".to_string())?
            .parse::<u64>()
            .map_err(|e| format!("fingerprint: bad matrix_seed: {e}"))?;
        let n_scenarios = v
            .get("n_scenarios")
            .and_then(Value::as_f64)
            .ok_or_else(|| "fingerprint: missing `n_scenarios`".to_string())?
            as usize;
        let axes_hash = u64::from_str_radix(
            v.get("axes_hash")
                .and_then(Value::as_str)
                .ok_or_else(|| "fingerprint: missing `axes_hash`".to_string())?,
            16,
        )
        .map_err(|e| format!("fingerprint: bad axes_hash: {e}"))?;
        Ok(MatrixFingerprint { name, seed, n_scenarios, axes_hash })
    }
}

// ---- partial reports -----------------------------------------------------

/// One shard's finished cells plus the identity of the matrix they were
/// cut from — the unit of cross-host result shipping.
#[derive(Clone, Debug)]
pub struct PartialReport {
    pub fingerprint: MatrixFingerprint,
    pub shard: ShardSpec,
    /// In scenario-index order (ascending, strided by `shard_count`).
    pub cells: Vec<CellResult>,
}

impl PartialReport {
    pub fn to_json(&self) -> Value {
        let mut m = std::collections::BTreeMap::new();
        m.insert("fingerprint".to_string(), self.fingerprint.to_json());
        m.insert("shard_index".to_string(), Value::Num(self.shard.shard_index as f64));
        m.insert("shard_count".to_string(), Value::Num(self.shard.shard_count as f64));
        m.insert(
            "cells".to_string(),
            Value::Arr(self.cells.iter().map(|c| c.to_json()).collect()),
        );
        Value::Obj(m)
    }

    pub fn json_string(&self) -> String {
        self.to_json().to_json()
    }

    pub fn from_json(v: &Value) -> Result<PartialReport, String> {
        let fingerprint = MatrixFingerprint::from_json(
            v.get("fingerprint").ok_or_else(|| "partial: missing `fingerprint`".to_string())?,
        )?;
        let idx = v
            .get("shard_index")
            .and_then(Value::as_f64)
            .ok_or_else(|| "partial: missing `shard_index`".to_string())? as usize;
        let count = v
            .get("shard_count")
            .and_then(Value::as_f64)
            .ok_or_else(|| "partial: missing `shard_count`".to_string())? as usize;
        let shard = ShardSpec::new(idx, count)?;
        let cells = v
            .get("cells")
            .and_then(Value::as_arr)
            .ok_or_else(|| "partial: missing `cells`".to_string())?
            .iter()
            .map(CellResult::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(PartialReport { fingerprint, shard, cells })
    }

    pub fn parse(src: &str) -> Result<PartialReport, String> {
        let v = Value::parse(src).map_err(|e| e.to_string())?;
        PartialReport::from_json(&v)
    }

    pub fn from_file(path: &Path) -> Result<PartialReport, String> {
        let v = Value::parse_file(path)?;
        PartialReport::from_json(&v).map_err(|e| format!("{}: {e}", path.display()))
    }
}

/// Run one shard of a matrix: expand, keep the strided subset, execute on
/// `threads` workers. Each scenario carries its own `(matrix_seed, index)`
/// RNG derivation, so the subset runs exactly as it would inside the full
/// sweep.
pub fn run_shard(matrix: &ScenarioMatrix, shard: ShardSpec, threads: usize) -> PartialReport {
    let scenarios: Vec<_> =
        matrix.expand().into_iter().filter(|s| shard.owns(s.index)).collect();
    let cells = runner::run_scenarios(&scenarios, threads);
    PartialReport { fingerprint: fingerprint(matrix), shard, cells }
}

// ---- merging -------------------------------------------------------------

/// Why a set of partial reports cannot be merged.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MergeError {
    /// No partial reports were supplied.
    Empty,
    /// Two shards carry different matrix fingerprints (different matrix,
    /// seed, axes, or total cell count).
    FingerprintMismatch { expected: String, got: String },
    /// Shards disagree on how many shards the matrix was cut into.
    ShardCountMismatch { expected: usize, got: usize },
    /// A shard's index is out of range for its own shard count.
    InvalidShard { index: usize, count: usize },
    /// The same shard index appears twice.
    DuplicateShard(usize),
    /// A shard of the partition is missing.
    MissingShard(usize),
    /// A cell's scenario index does not belong to the shard that carried
    /// it, or exceeds the matrix's cell count.
    ForeignCell { shard: usize, index: usize },
    /// The union of cells has the wrong size (a shard file was truncated
    /// or carries extra cells).
    IncompleteCover { expected: usize, got: usize },
    /// The union of cells has the right size but skips or duplicates a
    /// scenario index (a corrupted shard file).
    CellIndexMismatch { expected: usize, found: usize },
}

impl fmt::Display for MergeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MergeError::Empty => write!(f, "no partial reports to merge"),
            MergeError::FingerprintMismatch { expected, got } => write!(
                f,
                "matrix fingerprint mismatch: {got} vs {expected} — these shards \
                 were not cut from the same matrix"
            ),
            MergeError::ShardCountMismatch { expected, got } => {
                write!(f, "shard count mismatch: {got} vs {expected}")
            }
            MergeError::InvalidShard { index, count } => {
                write!(f, "shard index {index} out of range for {count} shards")
            }
            MergeError::DuplicateShard(i) => write!(f, "shard {i} supplied twice"),
            MergeError::MissingShard(i) => write!(f, "shard {i} missing from the partition"),
            MergeError::ForeignCell { shard, index } => {
                write!(f, "cell index {index} does not belong to shard {shard}")
            }
            MergeError::IncompleteCover { expected, got } => write!(
                f,
                "merged cells do not cover the matrix: got {got} of {expected} scenarios"
            ),
            MergeError::CellIndexMismatch { expected, found } => write!(
                f,
                "merged cells skip or duplicate a scenario: expected index {expected}, \
                 found {found} (corrupted shard file)"
            ),
        }
    }
}

impl std::error::Error for MergeError {}

/// Merge a complete shard partition back into the [`SweepReport`] the
/// single-process sweep would have produced — byte-identical for any shard
/// count, including `shard_count = 1`. Order of `parts` does not matter.
pub fn merge(parts: &[PartialReport]) -> Result<SweepReport, MergeError> {
    let first = parts.first().ok_or(MergeError::Empty)?;
    let fp = &first.fingerprint;
    let count = first.shard.shard_count;
    // Shard count and cell count come from files — bound every allocation
    // by the *actual* input size before trusting them. A complete
    // partition needs one report per shard, so count > parts.len() means
    // a shard is missing; by pigeonhole the smallest absent index is
    // <= parts.len(), so this scan is bounded too.
    if count > parts.len() {
        let seen: std::collections::BTreeSet<usize> =
            parts.iter().map(|p| p.shard.shard_index).collect();
        let missing = (0..count).find(|i| !seen.contains(i)).unwrap_or(0);
        return Err(MergeError::MissingShard(missing));
    }
    let mut seen = vec![false; count];
    let total_cells: usize = parts.iter().map(|p| p.cells.len()).sum();
    let mut cells: Vec<CellResult> = Vec::with_capacity(total_cells);
    for p in parts {
        if p.fingerprint != *fp {
            return Err(MergeError::FingerprintMismatch {
                expected: format!("{:?}", fp),
                got: format!("{:?}", p.fingerprint),
            });
        }
        if p.shard.shard_count != count {
            return Err(MergeError::ShardCountMismatch {
                expected: count,
                got: p.shard.shard_count,
            });
        }
        if p.shard.shard_index >= count {
            return Err(MergeError::InvalidShard {
                index: p.shard.shard_index,
                count,
            });
        }
        if seen[p.shard.shard_index] {
            return Err(MergeError::DuplicateShard(p.shard.shard_index));
        }
        seen[p.shard.shard_index] = true;
        for c in &p.cells {
            if c.index >= fp.n_scenarios || !p.shard.owns(c.index) {
                return Err(MergeError::ForeignCell {
                    shard: p.shard.shard_index,
                    index: c.index,
                });
            }
        }
        cells.extend(p.cells.iter().cloned());
    }
    if let Some(missing) = seen.iter().position(|&s| !s) {
        return Err(MergeError::MissingShard(missing));
    }
    // Matrix-expansion order, regardless of which shard (or host) ran what.
    cells.sort_by_key(|c| c.index);
    if cells.len() != fp.n_scenarios {
        return Err(MergeError::IncompleteCover {
            expected: fp.n_scenarios,
            got: cells.len(),
        });
    }
    if let Some((i, c)) = cells.iter().enumerate().find(|(i, c)| c.index != *i) {
        return Err(MergeError::CellIndexMismatch { expected: i, found: c.index });
    }
    // SweepReport::new recomputes SummaryStats from the union in index
    // order — the same f64 operation sequence as the single-process path,
    // so the serialized summary is byte-identical too.
    Ok(SweepReport::new(&fp.name, fp.seed, cells))
}

/// Parse and merge shard files — the `zygarde merge` entry point.
pub fn merge_files(paths: &[std::path::PathBuf]) -> Result<SweepReport, String> {
    let parts = paths
        .iter()
        .map(|p| PartialReport::from_file(p.as_path()))
        .collect::<Result<Vec<_>, _>>()?;
    merge(&parts).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::SchedulerKind;
    use crate::sim::sweep::{run_matrix, HarvesterSpec, TaskMix};

    fn tiny_matrix(seed: u64) -> ScenarioMatrix {
        ScenarioMatrix::new("shard-test", seed)
            .mixes(vec![TaskMix::synthetic("m", 1, 3, seed)])
            .harvesters(vec![
                HarvesterSpec::Persistent { power_mw: 600.0 },
                HarvesterSpec::Persistent { power_mw: 120.0 },
            ])
            .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
            .reps(3)
            .duration_ms(3_000.0)
    }

    #[test]
    fn shard_spec_parses_and_validates() {
        assert_eq!(ShardSpec::parse("2/8").unwrap(), ShardSpec::new(2, 8).unwrap());
        assert_eq!(ShardSpec::parse("0/1").unwrap(), ShardSpec::whole());
        assert!(ShardSpec::parse("8/8").is_err());
        assert!(ShardSpec::parse("3").is_err());
        assert!(ShardSpec::parse("a/b").is_err());
        assert!(ShardSpec::new(0, 0).is_err());
    }

    #[test]
    fn strided_partition_covers_everything_once() {
        let total = 13;
        for count in 1..=5usize {
            let mut owned = vec![0u32; total];
            let mut sizes = Vec::new();
            for i in 0..count {
                let spec = ShardSpec::new(i, count).unwrap();
                let n = (0..total).filter(|&x| spec.owns(x)).count();
                assert_eq!(n, spec.len_of(total));
                sizes.push(n);
                for (x, o) in owned.iter_mut().enumerate() {
                    if spec.owns(x) {
                        *o += 1;
                    }
                }
            }
            assert!(owned.iter().all(|&o| o == 1), "{count} shards double/un-covered");
            // Strided partitions are balanced to within one cell.
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn single_shard_merge_is_identity() {
        let m = tiny_matrix(0x51);
        let full = run_matrix(&m, 2);
        let part = run_shard(&m, ShardSpec::whole(), 2);
        let merged = merge(&[part]).unwrap();
        assert_eq!(merged.json_string(), full.json_string());
    }

    #[test]
    fn fingerprint_is_sensitive_to_axes_and_seed() {
        let base = fingerprint(&tiny_matrix(1));
        assert_eq!(base, fingerprint(&tiny_matrix(1)));
        assert_ne!(base, fingerprint(&tiny_matrix(2)));
        assert_ne!(base, fingerprint(&tiny_matrix(1).duration_ms(4_000.0)));
        assert_ne!(
            base,
            fingerprint(&tiny_matrix(1).schedulers(vec![SchedulerKind::Zygarde]))
        );
        assert_ne!(
            base.axes_hash,
            fingerprint(&tiny_matrix(1).capacitors_mf(vec![5.0])).axes_hash
        );
    }

    #[test]
    fn fingerprint_sees_fields_that_labels_omit() {
        use crate::energy::harvester::HarvesterKind;
        use crate::sim::sweep::FaultPlan;
        // Markov q/eta and brownout offset do not appear in display
        // labels; the fingerprint must still distinguish them.
        let markov = |q: f64, eta: f64| {
            tiny_matrix(1).harvesters(vec![HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 100.0,
                q,
                duty: 0.5,
                eta,
            }])
        };
        assert_ne!(
            fingerprint(&markov(0.9, 0.5)).axes_hash,
            fingerprint(&markov(0.5, 0.5)).axes_hash
        );
        assert_ne!(
            fingerprint(&markov(0.9, 0.5)).axes_hash,
            fingerprint(&markov(0.9, 0.6)).axes_hash
        );
        let burst = |offset_ms: f64| {
            tiny_matrix(1).faults(vec![FaultPlan::none().with_brownouts(1000.0, 200.0, offset_ms)])
        };
        assert_ne!(
            fingerprint(&burst(0.0)).axes_hash,
            fingerprint(&burst(150.0)).axes_hash
        );
    }

    #[test]
    fn partial_report_round_trips_through_json() {
        let m = tiny_matrix(0xAB);
        let part = run_shard(&m, ShardSpec::new(1, 3).unwrap(), 1);
        assert!(part.cells.iter().all(|c| c.index % 3 == 1));
        let back = PartialReport::parse(&part.json_string()).unwrap();
        assert_eq!(back.json_string(), part.json_string());
        assert_eq!(back.fingerprint, part.fingerprint);
        assert_eq!(back.shard, part.shard);
    }

    #[test]
    fn mismatched_fingerprints_refuse_to_merge() {
        let a = run_shard(&tiny_matrix(1), ShardSpec::new(0, 2).unwrap(), 1);
        let b = run_shard(&tiny_matrix(2), ShardSpec::new(1, 2).unwrap(), 1);
        match merge(&[a, b]) {
            Err(MergeError::FingerprintMismatch { .. }) => {}
            other => panic!("expected fingerprint mismatch, got {other:?}"),
        }
    }

    #[test]
    fn incomplete_partitions_refuse_to_merge() {
        let m = tiny_matrix(7);
        let a = run_shard(&m, ShardSpec::new(0, 3).unwrap(), 1);
        let b = run_shard(&m, ShardSpec::new(2, 3).unwrap(), 1);
        assert_eq!(merge(&[a.clone(), b.clone()]).unwrap_err(), MergeError::MissingShard(1));
        assert_eq!(
            merge(&[a.clone(), a.clone(), b.clone()]).unwrap_err(),
            MergeError::DuplicateShard(0)
        );
        assert_eq!(merge(&[]).unwrap_err(), MergeError::Empty);
        // A truncated shard file fails the cover check.
        let mut c = run_shard(&m, ShardSpec::new(1, 3).unwrap(), 1);
        c.cells.pop();
        let n = fingerprint(&m).n_scenarios;
        assert_eq!(
            merge(&[a, c, b]).unwrap_err(),
            MergeError::IncompleteCover { expected: n, got: n - 1 }
        );
    }

    #[test]
    fn duplicated_plus_skipped_cells_in_one_shard_are_detected() {
        let m = tiny_matrix(7);
        let mut a = run_shard(&m, ShardSpec::new(0, 2).unwrap(), 1);
        let b = run_shard(&m, ShardSpec::new(1, 2).unwrap(), 1);
        // Replace one owned cell with a copy of another owned cell: sizes
        // and ownership both check out, so only the positional scan can
        // catch the duplicate/gap pair — and its error must name it.
        let dup = a.cells[0].clone();
        let last = a.cells.len() - 1;
        a.cells[last] = dup;
        match merge(&[a, b]) {
            Err(MergeError::CellIndexMismatch { expected: 1, found: 0 }) => {}
            other => panic!("expected cell-index mismatch, got {other:?}"),
        }
    }

    #[test]
    fn foreign_cells_are_rejected() {
        let m = tiny_matrix(7);
        let mut a = run_shard(&m, ShardSpec::new(0, 2).unwrap(), 1);
        let b = run_shard(&m, ShardSpec::new(1, 2).unwrap(), 1);
        // Steal a cell from the other shard.
        a.cells.push(b.cells[0].clone());
        match merge(&[a, b]) {
            Err(MergeError::ForeignCell { shard: 0, .. }) => {}
            other => panic!("expected foreign-cell error, got {other:?}"),
        }
    }
}
