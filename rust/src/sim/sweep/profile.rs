//! Campaign profiling: per-axis time-and-energy waterfalls over a sweep.
//!
//! `zygarde profile --matrix M [--by AXIS]` runs the matrix with a
//! [`Registry`] attached to every cell's engine and groups the per-cell
//! registries by one label axis — which harvester / capacitor /
//! scheduler / NVM policy burns its ticks where (off, on-idle, probed,
//! active), how its bulk fast-forwards are bounded, and what its NVM
//! commits/rollbacks/restores cost. The report side of a profiled sweep
//! is byte-identical to an unprofiled one (registries are passive
//! observers), and the profile itself composes exactly like reports do:
//! grouping is per-label and [`Registry::merge`] is order-independent
//! integer addition, so any sharding of the expansion, merged in any
//! order, yields the same bytes (`rust/tests/registry_determinism.rs`).
//!
//! Axes index the slash-separated scenario label
//! `{mix}/{harvester}/{cap}mF/{sched}/{exit}/{fault}/{nvm}/r{rep}` —
//! see [`AXES`].

use std::collections::BTreeMap;

use crate::telemetry::registry::{Counter, Hist, Registry, SCHEMA_VERSION};
use crate::util::json::Value;

use super::runner::run_scenarios_profiled;
use super::{Scenario, ScenarioMatrix};

/// Groupable axes, in label-component order.
pub const AXES: [&str; 8] =
    ["mix", "harvester", "cap", "sched", "exit", "fault", "nvm", "rep"];

/// The default `--by` axis.
pub const DEFAULT_AXIS: &str = "harvester";

fn axis_index(by: &str) -> Option<usize> {
    AXES.iter().position(|a| *a == by)
}

/// One axis value's merged registry.
pub struct ProfileGroup {
    pub value: String,
    pub n_cells: usize,
    pub registry: Registry,
}

/// A grouped campaign profile. `groups` is sorted by axis value;
/// `total` is every cell merged regardless of group.
pub struct ProfileReport {
    pub matrix_name: String,
    pub seed: u64,
    pub by: String,
    pub n_cells: usize,
    pub groups: Vec<ProfileGroup>,
    pub total: Registry,
}

impl ProfileReport {
    /// Group labeled per-cell registries by the `by` axis. Pure fold:
    /// input order never matters (BTreeMap grouping + order-independent
    /// merges), which is what lets shard-split profiles reassemble
    /// byte-identically.
    pub fn from_cells(
        matrix_name: &str,
        seed: u64,
        by: &str,
        cells: impl IntoIterator<Item = (String, Registry)>,
    ) -> Result<ProfileReport, String> {
        let Some(axis) = axis_index(by) else {
            return Err(format!(
                "unknown profile axis '{by}' (expected one of: {})",
                AXES.join(", ")
            ));
        };
        let mut groups: BTreeMap<String, (usize, Registry)> = BTreeMap::new();
        let mut total = Registry::new();
        let mut n_cells = 0usize;
        for (label, reg) in cells {
            let value = label.split('/').nth(axis).unwrap_or("?").to_string();
            let slot = groups.entry(value).or_insert_with(|| (0, Registry::new()));
            slot.0 += 1;
            slot.1.merge(&reg);
            total.merge(&reg);
            n_cells += 1;
        }
        Ok(ProfileReport {
            matrix_name: matrix_name.to_string(),
            seed,
            by: by.to_string(),
            n_cells,
            groups: groups
                .into_iter()
                .map(|(value, (n, registry))| ProfileGroup { value, n_cells: n, registry })
                .collect(),
            total,
        })
    }

    /// The profile document: versioned header, one registry snapshot per
    /// group, one for the campaign total.
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("schema_version".to_string(), Value::Num(SCHEMA_VERSION as f64));
        m.insert("matrix".to_string(), Value::Str(self.matrix_name.clone()));
        m.insert("seed".to_string(), Value::Num(self.seed as f64));
        m.insert("by".to_string(), Value::Str(self.by.clone()));
        m.insert("n_cells".to_string(), Value::Num(self.n_cells as f64));
        let groups = self
            .groups
            .iter()
            .map(|g| {
                let mut o = BTreeMap::new();
                o.insert("value".to_string(), Value::Str(g.value.clone()));
                o.insert("n_cells".to_string(), Value::Num(g.n_cells as f64));
                o.insert("registry".to_string(), g.registry.snapshot());
                Value::Obj(o)
            })
            .collect();
        m.insert("groups".to_string(), Value::Arr(groups));
        m.insert("total".to_string(), self.total.snapshot());
        Value::Obj(m)
    }

    /// Canonical byte form — the unit of every determinism comparison.
    pub fn json_string(&self) -> String {
        self.to_json().to_json()
    }

    /// Aligned human table: the tick waterfall (percent of each group's
    /// occupancy per regime) and the NVM cost columns. Display only —
    /// the JSON above is the machine-readable artifact.
    pub fn render_table(&self) -> String {
        let mut out = format!(
            "profile matrix={} seed={} by={} cells={}\n",
            self.matrix_name, self.seed, self.by, self.n_cells
        );
        let rows: Vec<[String; 11]> = self
            .groups
            .iter()
            .map(|g| profile_row(&g.value, g.n_cells, &g.registry))
            .chain(std::iter::once(profile_row("TOTAL", self.n_cells, &self.total)))
            .collect();
        let header = [
            "value", "cells", "ticks", "off%", "idle%", "probe%", "active%", "ff_jumps",
            "commits", "rollbacks", "nvm_mj",
        ];
        let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
        for r in &rows {
            for (w, cell) in widths.iter_mut().zip(r.iter()) {
                *w = (*w).max(cell.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, (cell, &w)) in cells.iter().zip(widths.iter()).enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                if i == 0 {
                    // Left-align the value column, right-align numbers.
                    line.push_str(&format!("{cell:<w$}"));
                } else {
                    line.push_str(&format!("{cell:>w$}"));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(
            &header.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
        ));
        for r in &rows {
            out.push_str(&fmt_row(r));
        }
        out
    }
}

fn profile_row(value: &str, n_cells: usize, r: &Registry) -> [String; 11] {
    let off = r.get(Counter::TicksOff);
    let idle = r.get(Counter::TicksOnIdle);
    let probed = r.get(Counter::TicksProbed);
    let active = r.get(Counter::TicksActive);
    let ticks = off + idle + probed + active;
    let pct = |v: u64| {
        if ticks == 0 {
            "-".to_string()
        } else {
            format!("{:.1}", v as f64 * 100.0 / ticks as f64)
        }
    };
    let ff = r.get(Counter::FfOffJumps) + r.get(Counter::FfOnIdleJumps);
    let nvm_uj = r.get(Counter::CommitUj) + r.get(Counter::RestoreUj);
    [
        value.to_string(),
        n_cells.to_string(),
        ticks.to_string(),
        pct(off),
        pct(idle),
        pct(probed),
        pct(active),
        ff.to_string(),
        r.get(Counter::Commits).to_string(),
        r.get(Counter::Rollbacks).to_string(),
        format!("{:.3}", nvm_uj as f64 / 1000.0),
    ]
}

/// Profile an explicit scenario list (a shard of an expansion, or the
/// whole of one).
pub fn profile_scenarios(
    matrix_name: &str,
    seed: u64,
    scenarios: &[Scenario],
    threads: usize,
    by: &str,
) -> Result<ProfileReport, String> {
    // Validate the axis before burning compute on the sweep.
    if axis_index(by).is_none() {
        return Err(format!(
            "unknown profile axis '{by}' (expected one of: {})",
            AXES.join(", ")
        ));
    }
    let cells = run_scenarios_profiled(scenarios, threads);
    ProfileReport::from_cells(
        matrix_name,
        seed,
        by,
        cells.into_iter().map(|(c, r)| (c.label, r)),
    )
}

/// Expand and profile a whole matrix (`zygarde profile`).
pub fn profile_matrix(
    matrix: &ScenarioMatrix,
    threads: usize,
    by: &str,
) -> Result<ProfileReport, String> {
    let scenarios = matrix.expand();
    profile_scenarios(&matrix.name, matrix.seed, &scenarios, threads, by)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::SchedulerKind;
    use crate::sim::sweep::{HarvesterSpec, ScenarioMatrix};

    fn tiny() -> ScenarioMatrix {
        ScenarioMatrix::new("profile-test", 0x5EED)
            .harvesters(vec![
                HarvesterSpec::Persistent { power_mw: 600.0 },
                HarvesterSpec::Piezo { eta: 0.3 },
            ])
            .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
            .duration_ms(5_000.0)
    }

    #[test]
    fn groups_follow_the_axis_and_counts_add_up() {
        let p = profile_matrix(&tiny(), 2, "harvester").unwrap();
        assert_eq!(p.n_cells, 4);
        assert_eq!(p.groups.len(), 2, "two harvesters");
        assert!(p.groups.iter().all(|g| g.n_cells == 2));
        let by_sched = profile_matrix(&tiny(), 2, "sched").unwrap();
        assert_eq!(by_sched.groups.len(), 2, "two schedulers");
        // Same cells, different grouping: the campaign total is the same
        // registry either way.
        assert_eq!(p.total.snapshot_string(), by_sched.total.snapshot_string());
        assert!(!p.total.is_zero());
    }

    #[test]
    fn unknown_axis_is_rejected() {
        assert!(profile_matrix(&tiny(), 1, "voltage").is_err());
        for axis in AXES {
            assert!(profile_matrix(&tiny(), 1, axis).is_ok(), "axis {axis}");
        }
    }

    #[test]
    fn json_carries_schema_version_and_groups() {
        let p = profile_matrix(&tiny(), 1, "sched").unwrap();
        let v = p.to_json();
        assert_eq!(v.get("schema_version").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("by").unwrap().as_str(), Some("sched"));
        let groups = v.get("groups").unwrap().as_arr().unwrap();
        assert_eq!(groups.len(), 2);
        for g in groups {
            let reg = g.get("registry").unwrap();
            assert!(reg.get("counters").unwrap().get("engine.ticks_off").is_some());
        }
        assert!(v.get("total").unwrap().get("hists").is_some());
    }

    #[test]
    fn profiled_report_half_is_byte_identical_to_plain_sweep() {
        let m = tiny();
        let plain = crate::sim::sweep::run_matrix(&m, 2);
        let scenarios = m.expand();
        let profiled = run_scenarios_profiled(&scenarios, 2);
        let report = crate::sim::sweep::SweepReport::new(
            &m.name,
            m.seed,
            profiled.into_iter().map(|(c, _)| c).collect(),
        );
        assert_eq!(plain.json_string(), report.json_string());
    }

    #[test]
    fn table_renders_a_total_row_per_axis() {
        let p = profile_matrix(&tiny(), 1, "harvester").unwrap();
        let t = p.render_table();
        assert!(t.contains("TOTAL"));
        assert!(t.contains("piezo"));
        assert!(t.starts_with("profile matrix=profile-test"));
        // Hist sanity through the public accessors: every observed jump
        // landed under exactly one bounding event.
        let jumps: u64 = [
            Hist::FfRelease,
            Hist::FfDeadline,
            Hist::FfBoot,
            Hist::FfWindow,
            Hist::FfJit,
            Hist::FfHorizon,
        ]
        .iter()
        .map(|&h| p.total.hist(h).count)
        .sum();
        let calls = p.total.get(Counter::FfOffJumps) + p.total.get(Counter::FfOnIdleJumps);
        assert_eq!(jumps, calls, "each bulk jump attributed exactly once");
    }
}
