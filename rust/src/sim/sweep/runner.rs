//! Multi-threaded sweep execution.
//!
//! Plain `std::thread::scope` workers pulling fixed-size chunks off an
//! atomic work-queue cursor. Determinism does not depend on scheduling:
//! each [`Scenario`] is self-contained (own engine, own RNG streams
//! derived from `(matrix_seed, scenario_index)`), results are written back
//! by scenario index, and the only cross-thread state — the harvester
//! calibration memo — caches a pure function.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::priority::PriorityParams;
use crate::coordinator::sched::Scheduler;
use crate::energy::capacitor::Capacitor;
use crate::energy::manager::EnergyManager;
use crate::sim::engine::{Engine, SimConfig};
use crate::telemetry::registry::{Registry, RegistryHandle};
use crate::telemetry::{TraceBuffer, TraceEvent, TraceSink};

use super::report::{CellResult, SweepReport};
use super::{HarvesterSpec, Scenario, ScenarioMatrix};

/// Scenarios per work-queue grab: big enough to amortize the atomic,
/// small enough to load-balance uneven cells (a 470 mF cold-start cell
/// can run 10× longer than a 1 mF one).
const CHUNK: usize = 4;

/// Worker count to use when the caller has no preference.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Assemble the engine for one scenario. Public so tests can attach a
/// probe or inspect the configuration before running; sweep execution
/// goes through [`run_scenario`].
pub fn build_engine(sc: &Scenario) -> Engine {
    // Scenario-local stream: consumed only for per-scenario derived seeds,
    // never shared across cells. The first draw is skipped — under
    // SeedPolicy::PerScenario it IS the engine seed, and the clock must
    // not replay the same random sequence as the harvester and engine.
    let mut stream = sc.stream();
    let _engine_seed_draw = stream.next_u64();
    let clock_seed = stream.next_u64();

    let (harvester, eta) = sc.harvester.build(sc.engine_seed);
    let harvester = match sc.fault.brownout {
        Some(w) => harvester.with_blackouts(w),
        None => harvester,
    };

    let cap = Capacitor::new(sc.capacitor_mf * 1e-3, 3.3, 2.8, 1.9);

    let tasks = sc.mix.tasks.clone();
    // E_man: the largest atomic fragment's energy (same rule as
    // exp::common::engine_for). Scale parameters for ζ come from the mix.
    let e_man = tasks
        .iter()
        .flat_map(|t| (0..t.n_units()).map(|u| t.fragment_energy_mj(u)))
        .fold(0.0f64, f64::max);
    let max_deadline = tasks.iter().map(|t| t.deadline_ms).fold(0.0f64, f64::max);
    let max_utility = tasks
        .iter()
        .flat_map(|t| t.traces.iter())
        .flat_map(|tr| tr.units.iter().map(|u| u.gap as f64))
        .fold(1.0f64, f64::max);

    let energy = EnergyManager::new(cap, harvester, eta, e_man);
    let params = PriorityParams::new(max_deadline, max_utility);
    let mut engine = Engine::new(
        SimConfig {
            duration_ms: sc.duration_ms,
            queue_size: sc.queue_size,
            seed: sc.engine_seed,
            release_jitter: sc.release_jitter,
            log_jobs: sc.log_jobs,
            ..Default::default()
        },
        tasks,
        Scheduler::new(sc.scheduler, params),
        sc.exit,
        energy,
        sc.fault.clock.build(clock_seed),
    );
    // Nonvolatile-progress model: the JIT threshold is an absolute voltage
    // derived from this scenario's capacitor.
    engine.nvm = crate::nvm::Nvm::build(sc.nvm, &engine.energy.capacitor);
    // Explicit pre-t0 warm-up phase (deployment harvesting before t = 0);
    // `precharge(false)` scenarios pay their cold-start charge in-run.
    if sc.precharge {
        engine.warm_up();
    }
    engine
}

fn run_cell(sc: &Scenario, reference: bool) -> CellResult {
    let mut engine = build_engine(sc);
    engine.reference = reference;
    CellResult {
        index: sc.index,
        label: sc.label(),
        engine_seed: sc.engine_seed,
        metrics: engine.run(),
    }
}

/// Run one scenario to completion (a pure function of the scenario).
pub fn run_scenario(sc: &Scenario) -> CellResult {
    run_cell(sc, false)
}

/// Run one scenario on the naive reference stepper — the
/// differential-exactness baseline ([`crate::sim::engine::Engine::reference`]).
pub fn run_scenario_reference(sc: &Scenario) -> CellResult {
    run_cell(sc, true)
}

/// Run one scenario with a telemetry sink attached. The cell result is
/// byte-identical to [`run_scenario`]'s — sinks are out-of-band by
/// construction (`rust/tests/telemetry_trace.rs` proves it) — so traced
/// re-runs of sweep cells never perturb a report.
pub fn run_scenario_with_sink(sc: &Scenario, sink: Box<dyn TraceSink>) -> CellResult {
    let mut engine = build_engine(sc);
    engine.trace = Some(sink);
    CellResult {
        index: sc.index,
        label: sc.label(),
        engine_seed: sc.engine_seed,
        metrics: engine.run(),
    }
}

/// Run one scenario and collect its full event trace alongside the cell
/// result (`zygarde trace`, `zygarde sweep --trace-dir`).
pub fn run_scenario_traced(sc: &Scenario) -> (CellResult, Vec<TraceEvent>) {
    let buf = TraceBuffer::new();
    let cell = run_scenario_with_sink(sc, Box::new(buf.clone()));
    (cell, buf.take())
}

/// Run one scenario with a metrics registry attached and return the
/// accumulated per-cell [`Registry`] alongside the (byte-identical)
/// cell result. The registry is a pure function of the scenario — see
/// `rust/tests/registry_determinism.rs`.
pub fn run_scenario_profiled(sc: &Scenario) -> (CellResult, Registry) {
    let handle = RegistryHandle::new();
    let mut engine = build_engine(sc);
    engine.registry = Some(handle.clone());
    let cell = CellResult {
        index: sc.index,
        label: sc.label(),
        engine_seed: sc.engine_seed,
        metrics: engine.run(),
    };
    (cell, handle.take())
}

/// Run a scenario list on `threads` workers; results come back in
/// scenario-index order regardless of completion order.
pub fn run_scenarios(scenarios: &[Scenario], threads: usize) -> Vec<CellResult> {
    run_scenarios_impl(scenarios, threads, false)
}

/// [`run_scenarios`] on the naive reference stepper (bench/differential
/// harnesses; byte-identical results, several times slower on
/// off-dominated cells).
pub fn run_scenarios_reference(scenarios: &[Scenario], threads: usize) -> Vec<CellResult> {
    run_scenarios_impl(scenarios, threads, true)
}

fn run_scenarios_impl(scenarios: &[Scenario], threads: usize, reference: bool) -> Vec<CellResult> {
    run_scenarios_map(scenarios, threads, |sc| run_cell(sc, reference))
}

/// Run every scenario with a registry attached; results (and their
/// per-cell registries) come back in scenario-index order. The work
/// queue, chunking, and prewarm are identical to [`run_scenarios`] —
/// only the per-cell closure differs — so the report half is
/// byte-identical to an unprofiled sweep.
pub fn run_scenarios_profiled(
    scenarios: &[Scenario],
    threads: usize,
) -> Vec<(CellResult, Registry)> {
    run_scenarios_map(scenarios, threads, run_scenario_profiled)
}

/// The shared sweep executor: plain scoped workers pulling fixed-size
/// chunks off an atomic cursor, writing results back by scenario index.
/// `run` must be a pure function of the scenario (every caller's is).
fn run_scenarios_map<T, F>(scenarios: &[Scenario], threads: usize, run: F) -> Vec<T>
where
    T: Send,
    F: Fn(&Scenario) -> T + Sync,
{
    // Warm the harvester-calibration memo serially, once per unique
    // system spec per sweep: parallel workers then only ever take the
    // shared read lock instead of racing to duplicate the (identical)
    // calibration search. Only `HarvesterSpec::System` calibrates, and
    // a sweep holds at most the seven Table-4 ids — dedup here keeps
    // the pre-pass O(ids), not O(scenarios).
    let mut warmed: Vec<usize> = Vec::new();
    for sc in scenarios {
        if let HarvesterSpec::System(id) = sc.harvester {
            if !warmed.contains(&id) {
                warmed.push(id);
                sc.harvester.prewarm();
            }
        }
    }
    let threads = threads.clamp(1, scenarios.len().max(1));
    if threads <= 1 {
        return scenarios.iter().map(run).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..scenarios.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let start = next.fetch_add(CHUNK, Ordering::Relaxed);
                        if start >= scenarios.len() {
                            break;
                        }
                        let end = (start + CHUNK).min(scenarios.len());
                        for i in start..end {
                            local.push((i, run(&scenarios[i])));
                        }
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("sweep worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|c| c.expect("work queue covered every scenario"))
        .collect()
}

/// Expand and run a whole matrix.
pub fn run_matrix(matrix: &ScenarioMatrix, threads: usize) -> SweepReport {
    let scenarios = matrix.expand();
    let cells = run_scenarios(&scenarios, threads);
    SweepReport::new(&matrix.name, matrix.seed, cells)
}

/// [`run_matrix`] on the naive reference stepper: same report, byte for
/// byte — the bench job runs both over the off-dominated matrices and
/// asserts exactly that while measuring the speedup.
pub fn run_matrix_reference(matrix: &ScenarioMatrix, threads: usize) -> SweepReport {
    let scenarios = matrix.expand();
    let cells = run_scenarios_reference(&scenarios, threads);
    SweepReport::new(&matrix.name, matrix.seed, cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::sweep::{HarvesterSpec, ScenarioMatrix};
    use crate::coordinator::sched::SchedulerKind;

    fn tiny_matrix() -> ScenarioMatrix {
        ScenarioMatrix::new("runner-test", 0xBEEF)
            .harvesters(vec![HarvesterSpec::Persistent { power_mw: 600.0 }])
            .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
            .reps(2)
            .duration_ms(5_000.0)
    }

    #[test]
    fn single_thread_runs_all_cells() {
        let r = run_matrix(&tiny_matrix(), 1);
        assert_eq!(r.n_scenarios, 4);
        assert!(r.summary.released > 0);
        for c in &r.cells {
            assert!(c.metrics.released > 0, "{}: nothing released", c.label);
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let m = tiny_matrix();
        let a = run_matrix(&m, 1).json_string();
        let b = run_matrix(&m, 3).json_string();
        assert_eq!(a, b);
    }

    #[test]
    fn more_threads_than_scenarios_is_fine() {
        let r = run_matrix(&tiny_matrix(), 64);
        assert_eq!(r.cells.len(), 4);
    }

    #[test]
    fn reference_runner_matches_fast_runner_byte_for_byte() {
        use crate::energy::harvester::HarvesterKind;
        let m = tiny_matrix()
            .harvesters(vec![
                HarvesterSpec::Markov {
                    kind: HarvesterKind::Rf,
                    on_power_mw: 60.0,
                    q: 0.92,
                    duty: 0.25,
                    eta: 0.4,
                },
                HarvesterSpec::Piezo { eta: 0.3 },
            ])
            .capacitors_mf(vec![5.0])
            .duration_ms(60_000.0);
        let fast = run_matrix(&m, 2);
        let reference = run_matrix_reference(&m, 2);
        assert_eq!(fast.json_string(), reference.json_string());
    }

    #[test]
    fn nvm_axis_is_deterministic_across_thread_counts() {
        use crate::nvm::NvmSpec;
        let m = tiny_matrix().nvms(vec![
            NvmSpec::ideal(),
            NvmSpec::fram_every_fragment(),
            NvmSpec::fram_unit_boundary(),
            NvmSpec::fram_jit(),
        ]);
        let a = run_matrix(&m, 1);
        let b = run_matrix(&m, 4);
        assert_eq!(a.n_scenarios, 16);
        assert_eq!(a.json_string(), b.json_string());
    }
}
