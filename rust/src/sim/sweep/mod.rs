//! Deterministic parallel scenario sweeps.
//!
//! The paper's evaluation is a grid of intermittent-power scenarios —
//! harvester profiles × capacitor sizes × schedulers × exit policies ×
//! task mixes × NVM commit policies × seeds (§8, Tables 5–7). This module
//! turns that grid into a first-class object:
//!
//! * [`ScenarioMatrix`] — a declarative cartesian product over the sweep
//!   dimensions, expanded into self-contained [`Scenario`] specs.
//! * [`runner::run_matrix`] — a multi-threaded runner (plain
//!   `std::thread` chunked work queue, no external deps). Every scenario
//!   derives its own RNG streams from `(matrix_seed, scenario_index)`, so
//!   the resulting [`SweepReport`] is **bitwise identical regardless of
//!   thread count or execution order** — a failing seed replays exactly
//!   and becomes a regression test (see `rust/tests/sweep_determinism.rs`).
//! * [`FaultPlan`] — per-scenario failure injection: brownout bursts
//!   masked onto the harvester and post-reboot clock skew via the CHRT
//!   remanence-clock models.
//! * [`SweepReport`] — per-cell metrics plus aggregate summary statistics
//!   (`util::stats`), serialized with `util::json`.
//! * [`shard`] — *static* multi-process / multi-host scale-out: a
//!   [`ShardSpec`] deterministically partitions the expansion (strided by
//!   scenario index), each shard ships a [`PartialReport`], and [`merge`]
//!   reassembles the byte-identical single-process [`SweepReport`]
//!   (`zygarde sweep --shard I/N` / `zygarde merge`).
//! * [`serve`] — *dynamic* scale-out: a work-stealing dispatcher streams
//!   fine-grained index-range leases to worker processes (pipes or TCP),
//!   reissues them on death or timeout, and merges results out-of-core —
//!   still byte-identical (`zygarde serve` / `zygarde work`).
//! * [`profile`] — campaign observability: the same sweep with a
//!   [`crate::telemetry::registry::Registry`] attached per cell,
//!   grouped into a per-axis time/energy waterfall that merges across
//!   shards exactly like reports do (`zygarde profile`).
//!
//! Seed discipline: by default every scenario's engine seed is an
//! independent function of `(matrix_seed, scenario_index)`
//! ([`SeedPolicy::PerScenario`]). Comparison sweeps (scheduler A vs B on
//! the *same* energy trace, RTC vs CHRT on the same outage pattern) use
//! [`SeedPolicy::PairedEnvironment`]: the engine seed then depends only on
//! the stream-generating dimensions (task mix, harvester, rep), so cells
//! that differ only in scheduler / exit policy / fault plan / capacitor
//! size see identical release and harvest streams.

pub mod faults;
pub mod profile;
pub mod report;
pub mod runner;
pub mod serve;
pub mod shard;

pub use faults::FaultPlan;
pub use profile::{profile_matrix, profile_scenarios, ProfileGroup, ProfileReport, AXES, DEFAULT_AXIS};
pub use report::{CellResult, SummaryStats, SweepReport};
pub use runner::{
    build_engine, default_threads, run_matrix, run_matrix_reference, run_scenario,
    run_scenario_profiled, run_scenario_reference, run_scenario_traced,
    run_scenario_with_sink, run_scenarios, run_scenarios_profiled, run_scenarios_reference,
};
pub use shard::{
    fingerprint, merge, run_shard, MatrixFingerprint, MergeError, PartialReport, ShardSpec,
};

use crate::coordinator::sched::{ExitPolicy, SchedulerKind};
use crate::coordinator::task::TaskSpec;
use crate::energy::harvester::{harvester_for, system, Harvester, HarvesterKind};
use crate::nvm::NvmSpec;
use crate::sim::workload::synthetic_task;
use crate::util::rng::Pcg32;

/// Declarative harvester choice — a plain value a matrix can hold, built
/// into a seeded [`Harvester`] per scenario.
#[derive(Clone, Copy, Debug)]
pub enum HarvesterSpec {
    /// A Table 4 evaluation system (1–7): η-calibrated Markov burst
    /// source (memoized calibration) or the persistent System 1.
    System(usize),
    /// Constant supply at the given power (η = 1).
    Persistent { power_mw: f64 },
    /// Explicit two-state Markov burst source with an offline-estimated η
    /// (the deployment's `eta` the scheduler is told, not re-measured).
    Markov { kind: HarvesterKind, on_power_mw: f64, q: f64, duty: f64, eta: f64 },
    /// Footstep-driven piezo bouts (ΔT = 5 min, long dark gaps — the
    /// Fig. 4(b) regime; the simulator's off-phase-dominated workload).
    Piezo { eta: f64 },
    /// Window-sill solar: ~5 lit hours per 24 h day plus cloud flicker
    /// (the two-month Fig. 4(c) study; overwhelmingly off-dominated).
    SolarDiurnal { eta: f64 },
}

impl HarvesterSpec {
    /// Build the seeded harvester and the η the energy manager reports.
    pub fn build(&self, seed: u64) -> (Harvester, f64) {
        match *self {
            HarvesterSpec::System(id) => {
                let sys = system(id);
                (harvester_for(sys, seed), sys.eta)
            }
            HarvesterSpec::Persistent { power_mw } => (Harvester::persistent(power_mw), 1.0),
            HarvesterSpec::Markov { kind, on_power_mw, q, duty, eta } => {
                (Harvester::markov(kind, on_power_mw, q, duty, 1000.0, seed), eta)
            }
            HarvesterSpec::Piezo { eta } => (Harvester::piezo(seed), eta),
            HarvesterSpec::SolarDiurnal { eta } => (Harvester::solar_diurnal(seed), eta),
        }
    }

    /// Warm the shared calibration memo this spec will consult, so a
    /// sweep can pay the (deterministic, memoized) calibration search
    /// once up front instead of inside the first worker that hits it.
    /// No-op for specs that need no calibration.
    pub fn prewarm(&self) {
        if let HarvesterSpec::System(id) = *self {
            let sys = system(id);
            if sys.kind != HarvesterKind::Persistent {
                let _ = crate::energy::harvester::calibrated_q(
                    sys.kind,
                    sys.avg_power_mw / crate::energy::harvester::DUTY,
                    crate::energy::harvester::DUTY,
                    sys.eta,
                );
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            HarvesterSpec::System(id) => format!("S{id}"),
            HarvesterSpec::Persistent { power_mw } => format!("persistent{power_mw}mW"),
            HarvesterSpec::Markov { kind, on_power_mw, duty, .. } => {
                format!("{kind:?}{on_power_mw}mW@{duty}")
            }
            HarvesterSpec::Piezo { .. } => "piezo".to_string(),
            HarvesterSpec::SolarDiurnal { .. } => "solar-diurnal".to_string(),
        }
    }
}

/// A named workload: the tasks one scenario simulates. Task ids are
/// re-assigned to queue order on construction (the engine indexes
/// per-task metrics by id).
#[derive(Clone, Debug)]
pub struct TaskMix {
    pub name: String,
    pub tasks: Vec<TaskSpec>,
}

impl TaskMix {
    pub fn from_tasks(name: impl Into<String>, mut tasks: Vec<TaskSpec>) -> Self {
        assert!(!tasks.is_empty(), "task mix needs at least one task");
        for (i, t) in tasks.iter_mut().enumerate() {
            t.id = i;
        }
        TaskMix { name: name.into(), tasks }
    }

    /// Synthetic mix (no `artifacts/` required): `n_tasks` tasks of
    /// `n_units` units each, with staggered periods (300, 500, 700, … ms)
    /// and D = 2T, traces generated from `seed`.
    pub fn synthetic(name: impl Into<String>, n_tasks: usize, n_units: usize, seed: u64) -> Self {
        let tasks = (0..n_tasks)
            .map(|i| {
                let period_ms = 300.0 + 200.0 * i as f64;
                synthetic_task(i, n_units, period_ms, 2.0 * period_ms, 40, seed)
            })
            .collect();
        TaskMix::from_tasks(name, tasks)
    }
}

/// How engine seeds are derived at expansion time (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedPolicy {
    /// Seed = f(matrix_seed, scenario_index): every cell independent.
    PerScenario,
    /// Seed = f(matrix_seed, mix, harvester, rep): cells that differ only
    /// in scheduler / exit policy / fault plan / capacitor size share
    /// their environment's release and harvest streams (paired
    /// comparisons — storage size changes what can be banked, not what
    /// arrives).
    PairedEnvironment,
}

/// One self-contained cell of a sweep: everything needed to build and run
/// an engine, with no shared mutable state — the unit of parallelism.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Position in the matrix expansion (also this scenario's RNG stream).
    pub index: usize,
    pub matrix_seed: u64,
    pub harvester: HarvesterSpec,
    pub capacitor_mf: f64,
    /// Start with a full capacitor (deployment harvesting before t = 0) or
    /// cold (the Fig. 21 regime where the 470 mF unit pays its charge).
    pub precharge: bool,
    pub scheduler: SchedulerKind,
    pub exit: ExitPolicy,
    pub mix: TaskMix,
    /// Index within the matrix's seed range.
    pub rep: u64,
    pub fault: FaultPlan,
    /// Nonvolatile-progress model + commit policy for this cell.
    pub nvm: NvmSpec,
    pub duration_ms: f64,
    pub queue_size: usize,
    pub release_jitter: f64,
    pub log_jobs: bool,
    /// Derived per [`SeedPolicy`]; seeds the engine, harvester, and task
    /// release jitter.
    pub engine_seed: u64,
}

impl Scenario {
    /// The scenario's own deterministic RNG stream, derived from
    /// `(matrix_seed, scenario_index)`: identical no matter which thread
    /// runs the scenario, or in what order.
    pub fn stream(&self) -> Pcg32 {
        Pcg32::new(self.matrix_seed, self.index as u64)
    }

    /// Human-readable cell label (stable across runs; used in reports).
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}mF/{}/{}/{}/{}/r{}",
            self.mix.name,
            self.harvester.label(),
            self.capacitor_mf,
            self.scheduler.name(),
            self.exit.name(),
            self.fault.label(),
            self.nvm.label(),
            self.rep
        )
    }
}

/// Declarative cartesian product over sweep dimensions. Build with
/// [`ScenarioMatrix::new`] plus the fluent setters, then [`expand`] or
/// hand it to [`runner::run_matrix`].
///
/// Expansion order (outermost first): task mixes → harvesters →
/// capacitors → schedulers → exit policies → fault plans → NVM specs →
/// reps. The order is part of the format: scenario indices (and thus
/// per-scenario RNG streams) depend on it.
///
/// [`expand`]: ScenarioMatrix::expand
#[derive(Clone, Debug)]
pub struct ScenarioMatrix {
    pub name: String,
    pub seed: u64,
    pub harvesters: Vec<HarvesterSpec>,
    pub capacitors_mf: Vec<f64>,
    pub precharge: bool,
    pub schedulers: Vec<SchedulerKind>,
    /// `None` = the scheduler's paper-default exit policy.
    pub exits: Vec<Option<ExitPolicy>>,
    pub mixes: Vec<TaskMix>,
    pub faults: Vec<FaultPlan>,
    /// NVM commit-policy axis; default = the zero-cost idealization.
    pub nvms: Vec<NvmSpec>,
    /// Seed range: reps 0..n_reps.
    pub n_reps: u64,
    pub duration_ms: f64,
    pub queue_size: usize,
    pub release_jitter: f64,
    pub log_jobs: bool,
    pub seed_policy: SeedPolicy,
}

impl ScenarioMatrix {
    pub fn new(name: impl Into<String>, seed: u64) -> Self {
        ScenarioMatrix {
            name: name.into(),
            seed,
            harvesters: vec![HarvesterSpec::Persistent { power_mw: 600.0 }],
            capacitors_mf: vec![50.0],
            precharge: true,
            schedulers: vec![SchedulerKind::Zygarde],
            exits: vec![None],
            mixes: vec![TaskMix::synthetic("default", 1, 3, seed)],
            faults: vec![FaultPlan::none()],
            nvms: vec![NvmSpec::ideal()],
            n_reps: 1,
            duration_ms: 30_000.0,
            queue_size: 3,
            release_jitter: 0.1,
            log_jobs: false,
            seed_policy: SeedPolicy::PerScenario,
        }
    }

    pub fn harvesters(mut self, v: Vec<HarvesterSpec>) -> Self {
        assert!(!v.is_empty());
        self.harvesters = v;
        self
    }

    pub fn capacitors_mf(mut self, v: Vec<f64>) -> Self {
        assert!(!v.is_empty());
        self.capacitors_mf = v;
        self
    }

    pub fn precharge(mut self, yes: bool) -> Self {
        self.precharge = yes;
        self
    }

    pub fn schedulers(mut self, v: Vec<SchedulerKind>) -> Self {
        assert!(!v.is_empty());
        self.schedulers = v;
        self
    }

    /// Fix explicit exit policies (one scenario per entry). The default
    /// (`vec![None]`) uses each scheduler's paper-default policy.
    pub fn exits(mut self, v: Vec<ExitPolicy>) -> Self {
        assert!(!v.is_empty());
        self.exits = v.into_iter().map(Some).collect();
        self
    }

    pub fn mixes(mut self, v: Vec<TaskMix>) -> Self {
        assert!(!v.is_empty());
        self.mixes = v;
        self
    }

    pub fn faults(mut self, v: Vec<FaultPlan>) -> Self {
        assert!(!v.is_empty());
        self.faults = v;
        self
    }

    /// Set the NVM commit-policy axis (one scenario per entry).
    pub fn nvms(mut self, v: Vec<NvmSpec>) -> Self {
        assert!(!v.is_empty());
        self.nvms = v;
        self
    }

    pub fn reps(mut self, n: u64) -> Self {
        assert!(n > 0);
        self.n_reps = n;
        self
    }

    pub fn duration_ms(mut self, ms: f64) -> Self {
        self.duration_ms = ms;
        self
    }

    pub fn queue_size(mut self, n: usize) -> Self {
        self.queue_size = n;
        self
    }

    pub fn release_jitter(mut self, j: f64) -> Self {
        self.release_jitter = j;
        self
    }

    pub fn log_jobs(mut self, yes: bool) -> Self {
        self.log_jobs = yes;
        self
    }

    pub fn seed_policy(mut self, p: SeedPolicy) -> Self {
        self.seed_policy = p;
        self
    }

    /// Number of scenarios the matrix expands to.
    pub fn len(&self) -> usize {
        self.mixes.len()
            * self.harvesters.len()
            * self.capacitors_mf.len()
            * self.schedulers.len()
            * self.exits.len()
            * self.faults.len()
            * self.nvms.len()
            * self.n_reps as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand into self-contained scenarios (documented dimension order).
    pub fn expand(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        let mut index = 0usize;
        for (mix_i, mix) in self.mixes.iter().enumerate() {
            for (h_i, harvester) in self.harvesters.iter().enumerate() {
                for (c_i, &capacitor_mf) in self.capacitors_mf.iter().enumerate() {
                    for &scheduler in &self.schedulers {
                        for &exit_choice in &self.exits {
                            for &fault in &self.faults {
                                for &nvm in &self.nvms {
                                    for rep in 0..self.n_reps {
                                        let engine_seed = match self.seed_policy {
                                            SeedPolicy::PerScenario => {
                                                Pcg32::new(self.seed, index as u64).next_u64()
                                            }
                                            SeedPolicy::PairedEnvironment => {
                                                // Only the stream-generating
                                                // dims (mix, harvester, rep):
                                                // identical harvest + release
                                                // streams across scheduler /
                                                // exit / fault / capacitor /
                                                // NVM policy. Storage size
                                                // and persistence policy do
                                                // not alter what arrives,
                                                // only what can be banked or
                                                // kept — so those cells stay
                                                // paired too.
                                                let env = (mix_i * self.harvesters.len()
                                                    + h_i)
                                                    as u64
                                                    * self.n_reps
                                                    + rep;
                                                Pcg32::new(self.seed, env).next_u64()
                                            }
                                        };
                                        out.push(Scenario {
                                            index,
                                            matrix_seed: self.seed,
                                            harvester: *harvester,
                                            capacitor_mf,
                                            precharge: self.precharge,
                                            scheduler,
                                            exit: exit_choice
                                                .unwrap_or_else(|| scheduler.default_exit()),
                                            mix: mix.clone(),
                                            rep,
                                            fault,
                                            nvm,
                                            duration_ms: self.duration_ms,
                                            queue_size: self.queue_size,
                                            release_jitter: self.release_jitter,
                                            log_jobs: self.log_jobs,
                                            engine_seed,
                                        });
                                        index += 1;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_by_two() -> ScenarioMatrix {
        ScenarioMatrix::new("t", 99)
            .harvesters(vec![
                HarvesterSpec::Persistent { power_mw: 600.0 },
                HarvesterSpec::Persistent { power_mw: 100.0 },
            ])
            .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
            .reps(3)
    }

    #[test]
    fn expansion_counts_and_indices() {
        let m = two_by_two();
        assert_eq!(m.len(), 2 * 2 * 3);
        let sc = m.expand();
        assert_eq!(sc.len(), 12);
        for (i, s) in sc.iter().enumerate() {
            assert_eq!(s.index, i);
        }
        // Labels are unique across the expansion.
        let mut labels: Vec<String> = sc.iter().map(|s| s.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 12);
    }

    #[test]
    fn per_scenario_seeds_differ() {
        let sc = two_by_two().expand();
        let mut seeds: Vec<u64> = sc.iter().map(|s| s.engine_seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 12, "independent cells must not share seeds");
    }

    #[test]
    fn paired_environment_shares_seeds_across_schedulers() {
        let sc = two_by_two().seed_policy(SeedPolicy::PairedEnvironment).expand();
        // Same (harvester, rep), different scheduler → same engine seed.
        for s in &sc {
            let twin = sc
                .iter()
                .find(|o| {
                    o.index != s.index
                        && o.rep == s.rep
                        && o.harvester.label() == s.harvester.label()
                })
                .expect("each cell has a scheduler twin");
            assert_eq!(twin.engine_seed, s.engine_seed);
        }
        // Different rep → different seed.
        assert_ne!(sc[0].engine_seed, sc[1].engine_seed);
    }

    #[test]
    fn scenario_streams_are_index_stable() {
        let sc = two_by_two().expand();
        let mut a = sc[5].stream();
        let mut b = sc[5].clone().stream();
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = sc[6].stream();
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn nvm_axis_multiplies_and_stays_paired() {
        let m = two_by_two()
            .nvms(vec![NvmSpec::ideal(), NvmSpec::fram_unit_boundary(), NvmSpec::fram_jit()])
            .seed_policy(SeedPolicy::PairedEnvironment);
        assert_eq!(m.len(), 2 * 2 * 3 * 3);
        let sc = m.expand();
        assert_eq!(sc.len(), 36);
        // NVM twins replay identical harvest + release streams: same
        // (mix, harvester, rep) but different policy → same engine seed.
        for s in &sc {
            let twin = sc
                .iter()
                .find(|o| {
                    o.index != s.index
                        && o.rep == s.rep
                        && o.harvester.label() == s.harvester.label()
                        && o.nvm != s.nvm
                })
                .expect("each cell has an NVM twin");
            assert_eq!(twin.engine_seed, s.engine_seed);
        }
        // Cell labels carry the policy and stay unique.
        let mut labels: Vec<String> = sc.iter().map(|s| s.label()).collect();
        assert!(labels[0].contains("ideal+frag"), "{}", labels[0]);
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 36);
    }

    #[test]
    fn exit_defaults_follow_scheduler() {
        let sc = ScenarioMatrix::new("d", 1)
            .schedulers(vec![SchedulerKind::Edf, SchedulerKind::Zygarde])
            .expand();
        assert_eq!(sc[0].exit, ExitPolicy::None);
        assert_eq!(sc[1].exit, ExitPolicy::Utility);
    }
}
