//! The worker side of the serve protocol: rebuild the matrix, verify the
//! fingerprint, then turn leases into streamed cell batches.
//!
//! The loop is transport-agnostic (any `BufRead` + `Write` pair): the CLI
//! hands it stdin/stdout for `zygarde work --connect -` (pipe workers the
//! dispatcher spawns itself) or a TCP stream for `--connect host:port`.
//! Matrix construction is injected as a resolver closure so this module
//! stays below the experiment layer — the CLI passes the
//! `exp::sweep_cli::build_matrix` registry, tests can pass anything.
//!
//! A lease is executed in sub-chunks of `batch` scenarios (each sub-chunk
//! through the ordinary multi-threaded [`runner::run_scenarios`]), and
//! every sub-chunk is streamed back as its own [`Msg::Cells`] the moment
//! it finishes. Fine-grained streaming is what makes the dispatcher's
//! watermarks (and therefore stealing, timeout reissue, and kill-recovery)
//! precise: after a `kill -9`, only the un-streamed part of the lease is
//! recomputed elsewhere.

use std::io::{BufRead, Write};

use crate::sim::sweep::runner;
use crate::sim::sweep::shard::fingerprint;
use crate::sim::sweep::{Scenario, ScenarioMatrix};
use crate::util::json::Value;
use crate::util::rng::Pcg32;

use super::protocol::{read_msg, write_msg, Msg};

/// What a finished worker did — the CLI prints it to stderr (stdout may
/// be the protocol stream).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerOutcome {
    pub leases: usize,
    pub cells_run: usize,
}

/// Why a worker session ended without a clean `Shutdown`. `handshaken`
/// is the reconnect policy's pivot: once a session completed the matrix
/// handshake, a later refused reconnect most likely means the dispatcher
/// finalized its report and exited — the CLI's retry loop then exits
/// cleanly instead of reporting an error (`work --retry`).
#[derive(Clone, Debug)]
pub struct WorkerError {
    /// The `Ready` reply had been sent before the session died.
    pub handshaken: bool,
    pub msg: String,
}

impl std::fmt::Display for WorkerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Exponential backoff with deterministic jitter for `work --retry`:
/// attempt `a` sleeps in `[cap/2, cap]` ms where `cap = base << min(a, 6)`
/// — jittered from a seeded [`Pcg32`] so tests (and the simnet analogue)
/// are reproducible, halved-floor so retries never collapse to zero and
/// hammer a restarting dispatcher.
pub fn backoff_ms(attempt: u32, base_ms: u64, rng: &mut Pcg32) -> u64 {
    let cap = base_ms.max(1).saturating_mul(1u64 << attempt.min(6));
    let lo = (cap / 2).max(1);
    lo + rng.below(cap - lo + 1)
}

/// Serve-side matrix registry hook: `(name, opts-json) -> matrix`.
pub type MatrixResolver<'a> = dyn Fn(&str, &Value) -> Result<ScenarioMatrix, String> + 'a;

/// Run the worker loop until `Shutdown` (clean) or a protocol/IO error.
/// `threads` parallelizes within a sub-chunk; `batch` is the sub-chunk
/// size (clamped to ≥ 1) — the streaming granularity discussed above.
pub fn run_worker(
    rx: &mut dyn BufRead,
    tx: &mut dyn Write,
    threads: usize,
    batch: usize,
    resolve: &MatrixResolver,
) -> Result<WorkerOutcome, WorkerError> {
    let batch = batch.max(1);
    let mut scenarios: Vec<Scenario> = Vec::new();
    let mut outcome = WorkerOutcome::default();
    let mut handshaken = false;
    let fail = |handshaken: bool, msg: String| Err(WorkerError { handshaken, msg });
    loop {
        let msg = match read_msg(rx).map_err(|msg| WorkerError { handshaken, msg })? {
            Some(m) => m,
            None => {
                return fail(
                    handshaken,
                    "dispatcher closed the connection before shutdown".to_string(),
                );
            }
        };
        match msg {
            Msg::Matrix { name, opts, fingerprint: announced } => {
                let matrix = match resolve(&name, &opts) {
                    Ok(m) => m,
                    Err(e) => {
                        let reason = format!("cannot rebuild matrix `{name}`: {e}");
                        let _ = write_msg(tx, &Msg::Error { reason: reason.clone() });
                        return fail(handshaken, reason);
                    }
                };
                let fp = fingerprint(&matrix);
                if fp != announced {
                    // Same admission control as `zygarde merge`, applied
                    // before a single cell runs: this binary expands the
                    // matrix differently than the dispatcher's.
                    let reason = format!(
                        "fingerprint mismatch for `{name}`: local {fp:?} vs dispatcher \
                         {announced:?} — mixed binaries or drifted options"
                    );
                    let _ = write_msg(tx, &Msg::Error { reason: reason.clone() });
                    return fail(handshaken, reason);
                }
                scenarios = matrix.expand();
                write_msg(tx, &Msg::Ready { fingerprint: fp })
                    .map_err(|e| WorkerError { handshaken, msg: e.to_string() })?;
                handshaken = true;
            }
            Msg::Lease { id, start, end } => {
                if scenarios.is_empty() {
                    return fail(handshaken, "lease before matrix handshake".to_string());
                }
                if start >= end || end > scenarios.len() {
                    return fail(
                        handshaken,
                        format!(
                            "lease {id} range {start}..{end} exceeds the {}-cell expansion",
                            scenarios.len()
                        ),
                    );
                }
                let mut at = start;
                while at < end {
                    let stop = (at + batch).min(end);
                    let cells = runner::run_scenarios(&scenarios[at..stop], threads);
                    outcome.cells_run += cells.len();
                    write_msg(tx, &Msg::Cells { lease: id, cells })
                        .map_err(|e| WorkerError { handshaken, msg: e.to_string() })?;
                    at = stop;
                }
                write_msg(tx, &Msg::LeaseDone { lease: id })
                    .map_err(|e| WorkerError { handshaken, msg: e.to_string() })?;
                outcome.leases += 1;
            }
            Msg::Shutdown => return Ok(outcome),
            Msg::Error { reason } => {
                return fail(handshaken, format!("dispatcher aborted: {reason}"));
            }
            Msg::Ready { .. } | Msg::Cells { .. } | Msg::LeaseDone { .. } => {
                return fail(
                    handshaken,
                    "worker-bound stream got a dispatcher-bound message".to_string(),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::SchedulerKind;
    use crate::sim::sweep::{run_matrix, HarvesterSpec, ScenarioMatrix};

    fn matrix() -> ScenarioMatrix {
        ScenarioMatrix::new("worker-test", 0x33)
            .harvesters(vec![HarvesterSpec::Persistent { power_mw: 500.0 }])
            .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
            .reps(2)
            .duration_ms(1_500.0)
    }

    fn scripted(messages: &[Msg]) -> Vec<u8> {
        let mut buf = Vec::new();
        for m in messages {
            write_msg(&mut buf, m).unwrap();
        }
        buf
    }

    #[test]
    fn worker_streams_lease_cells_in_batches_and_exits_on_shutdown() {
        let m = matrix();
        let fp = fingerprint(&m);
        let script = scripted(&[
            Msg::Matrix { name: "any".into(), opts: Value::Null, fingerprint: fp.clone() },
            Msg::Lease { id: 7, start: 1, end: 4 },
            Msg::Shutdown,
        ]);
        let mut rx = std::io::BufReader::new(&script[..]);
        let mut tx = Vec::new();
        let resolve = |_: &str, _: &Value| Ok(matrix());
        let outcome = run_worker(&mut rx, &mut tx, 1, 2, &resolve).unwrap();
        assert_eq!(outcome.leases, 1);
        assert_eq!(outcome.cells_run, 3);

        // Replies: Ready, Cells(2), Cells(1), LeaseDone — in order, with
        // the cells byte-identical to the single-process run's.
        let text = String::from_utf8(tx).unwrap();
        let replies: Vec<Msg> =
            text.lines().map(|l| Msg::parse_line(l).unwrap()).collect();
        assert!(matches!(replies[0], Msg::Ready { .. }));
        let reference = run_matrix(&m, 1);
        let mut got = Vec::new();
        for r in &replies[1..3] {
            let Msg::Cells { lease: 7, cells } = r else {
                panic!("expected cells for lease 7, got {r:?}");
            };
            got.extend(cells.iter().cloned());
        }
        assert!(matches!(replies[3], Msg::LeaseDone { lease: 7 }));
        assert_eq!(got.len(), 3);
        for (c, want) in got.iter().zip(&reference.cells[1..4]) {
            assert_eq!(c.to_json().to_json(), want.to_json().to_json());
        }
    }

    #[test]
    fn fingerprint_mismatch_aborts_with_an_error_message() {
        let mut fp = fingerprint(&matrix());
        fp.axes_hash ^= 1;
        let script = scripted(&[Msg::Matrix {
            name: "any".into(),
            opts: Value::Null,
            fingerprint: fp,
        }]);
        let mut rx = std::io::BufReader::new(&script[..]);
        let mut tx = Vec::new();
        let resolve = |_: &str, _: &Value| Ok(matrix());
        let err = run_worker(&mut rx, &mut tx, 1, 4, &resolve).unwrap_err();
        assert!(err.msg.contains("fingerprint mismatch"), "{err}");
        assert!(!err.handshaken, "handshake never completed");
        let text = String::from_utf8(tx).unwrap();
        assert!(
            matches!(Msg::parse_line(text.lines().next().unwrap()), Ok(Msg::Error { .. })),
            "worker should tell the dispatcher why it left"
        );
    }

    #[test]
    fn eof_after_handshake_is_marked_handshaken() {
        let m = matrix();
        let fp = fingerprint(&m);
        let script = scripted(&[Msg::Matrix {
            name: "any".into(),
            opts: Value::Null,
            fingerprint: fp,
        }]);
        let mut rx = std::io::BufReader::new(&script[..]);
        let mut tx = Vec::new();
        let resolve = |_: &str, _: &Value| Ok(matrix());
        let err = run_worker(&mut rx, &mut tx, 1, 4, &resolve).unwrap_err();
        assert!(err.msg.contains("closed the connection"), "{err}");
        assert!(err.handshaken, "the Ready reply had been sent");
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_never_zero() {
        let mut a = Pcg32::new(0x7e77, 9);
        let mut b = Pcg32::new(0x7e77, 9);
        for attempt in 0..12 {
            let base = 50;
            let d1 = backoff_ms(attempt, base, &mut a);
            let d2 = backoff_ms(attempt, base, &mut b);
            assert_eq!(d1, d2, "same seed, same jitter");
            let cap = base * (1u64 << attempt.min(6));
            assert!(d1 >= cap / 2 && d1 <= cap, "attempt {attempt}: {d1} vs cap {cap}");
            assert!(d1 > 0);
        }
        // Degenerate bases never collapse to a zero sleep.
        assert_eq!(backoff_ms(0, 0, &mut a), 1);
        assert_eq!(backoff_ms(0, 1, &mut a), 1);
    }
}
