//! The serve wire protocol: line-delimited JSON messages.
//!
//! One message per line, each a JSON object tagged with a `"type"` field,
//! written with the in-crate `util::json` writer (no external deps). The
//! framing is deliberately dumb — newline-delimited objects survive
//! stdin/stdout pipes, TCP streams, and `kill -9` mid-line equally well
//! (a torn final line parses as an error and the dispatcher treats the
//! connection as dead, exactly like an EOF).
//!
//! Handshake (dispatcher → worker → dispatcher):
//!
//! 1. [`Msg::Matrix`] — the dispatcher announces the named matrix, the
//!    registry options to rebuild it from, and its [`MatrixFingerprint`].
//! 2. [`Msg::Ready`] — the worker rebuilds the matrix *locally* from the
//!    registry, fingerprints its own expansion, and echoes it. Both sides
//!    compare: a worker running drifted code (different axes, different
//!    trace generation, different seed derivation) is rejected before a
//!    single cell runs — the same admission control `zygarde merge`
//!    applies to shard files, moved to connection time.
//!
//! Work flow: [`Msg::Lease`] grants a half-open scenario-index range;
//! the worker streams [`Msg::Cells`] batches back (ascending index order
//! within a lease) and finishes with [`Msg::LeaseDone`]. [`Msg::Shutdown`]
//! ends a worker; [`Msg::Error`] aborts a connection in either direction.

use std::collections::BTreeMap;
use std::io::{BufRead, Write};

use crate::sim::sweep::report::CellResult;
use crate::sim::sweep::shard::MatrixFingerprint;
use crate::util::json::Value;

/// One protocol message (see module docs for the exchange order).
#[derive(Clone, Debug)]
pub enum Msg {
    /// Dispatcher → worker: rebuild this named matrix from the registry
    /// with these options; verify the fingerprint before touching work.
    Matrix { name: String, opts: Value, fingerprint: MatrixFingerprint },
    /// Dispatcher → worker: run scenario indexes `start..end`.
    Lease { id: u64, start: usize, end: usize },
    /// Dispatcher → worker: the sweep is complete (or aborted); exit.
    Shutdown,
    /// Worker → dispatcher: matrix rebuilt and fingerprint-verified.
    Ready { fingerprint: MatrixFingerprint },
    /// Worker → dispatcher: a batch of finished cells for one lease.
    Cells { lease: u64, cells: Vec<CellResult> },
    /// Worker → dispatcher: every cell of the lease has been sent.
    LeaseDone { lease: u64 },
    /// Either direction: something is wrong; the connection is over.
    Error { reason: String },
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

fn num(v: &Value, key: &str) -> Result<f64, String> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| format!("protocol: missing numeric `{key}`"))
}

fn str_field(v: &Value, key: &str) -> Result<String, String> {
    Ok(v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| format!("protocol: missing string `{key}`"))?
        .to_string())
}

impl Msg {
    pub fn to_json(&self) -> Value {
        match self {
            Msg::Matrix { name, opts, fingerprint } => obj(vec![
                ("type", Value::Str("matrix".into())),
                ("name", Value::Str(name.clone())),
                ("opts", opts.clone()),
                ("fingerprint", fingerprint.to_json()),
            ]),
            Msg::Lease { id, start, end } => obj(vec![
                ("type", Value::Str("lease".into())),
                ("id", Value::Num(*id as f64)),
                ("start", Value::Num(*start as f64)),
                ("end", Value::Num(*end as f64)),
            ]),
            Msg::Shutdown => obj(vec![("type", Value::Str("shutdown".into()))]),
            Msg::Ready { fingerprint } => obj(vec![
                ("type", Value::Str("ready".into())),
                ("fingerprint", fingerprint.to_json()),
            ]),
            Msg::Cells { lease, cells } => obj(vec![
                ("type", Value::Str("cells".into())),
                ("lease", Value::Num(*lease as f64)),
                ("cells", Value::Arr(cells.iter().map(|c| c.to_json()).collect())),
            ]),
            Msg::LeaseDone { lease } => obj(vec![
                ("type", Value::Str("lease_done".into())),
                ("lease", Value::Num(*lease as f64)),
            ]),
            Msg::Error { reason } => obj(vec![
                ("type", Value::Str("error".into())),
                ("reason", Value::Str(reason.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<Msg, String> {
        let kind = str_field(v, "type")?;
        match kind.as_str() {
            "matrix" => Ok(Msg::Matrix {
                name: str_field(v, "name")?,
                opts: v
                    .get("opts")
                    .cloned()
                    .ok_or_else(|| "protocol: matrix without `opts`".to_string())?,
                fingerprint: MatrixFingerprint::from_json(
                    v.get("fingerprint")
                        .ok_or_else(|| "protocol: matrix without `fingerprint`".to_string())?,
                )?,
            }),
            "lease" => Ok(Msg::Lease {
                id: num(v, "id")? as u64,
                start: num(v, "start")? as usize,
                end: num(v, "end")? as usize,
            }),
            "shutdown" => Ok(Msg::Shutdown),
            "ready" => Ok(Msg::Ready {
                fingerprint: MatrixFingerprint::from_json(
                    v.get("fingerprint")
                        .ok_or_else(|| "protocol: ready without `fingerprint`".to_string())?,
                )?,
            }),
            "cells" => Ok(Msg::Cells {
                lease: num(v, "lease")? as u64,
                cells: v
                    .get("cells")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| "protocol: cells without `cells`".to_string())?
                    .iter()
                    .map(CellResult::from_json)
                    .collect::<Result<Vec<_>, _>>()?,
            }),
            "lease_done" => Ok(Msg::LeaseDone { lease: num(v, "lease")? as u64 }),
            "error" => Ok(Msg::Error { reason: str_field(v, "reason")? }),
            other => Err(format!("protocol: unknown message type `{other}`")),
        }
    }

    /// Serialize as one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_json()
    }

    pub fn parse_line(line: &str) -> Result<Msg, String> {
        let v = Value::parse(line.trim()).map_err(|e| e.to_string())?;
        Msg::from_json(&v)
    }
}

/// Write one message and flush — the peer blocks on whole lines, so
/// buffering a message would deadlock a pipe transport.
pub fn write_msg(w: &mut dyn Write, msg: &Msg) -> std::io::Result<()> {
    let mut line = msg.to_line();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

/// Read the next message. `Ok(None)` means a clean EOF; blank lines are
/// skipped; a torn or malformed line is an error (the caller treats the
/// connection as dead).
pub fn read_msg(r: &mut dyn BufRead) -> Result<Option<Msg>, String> {
    let mut line = String::new();
    loop {
        line.clear();
        let n = r.read_line(&mut line).map_err(|e| e.to_string())?;
        if n == 0 {
            return Ok(None);
        }
        if line.trim().is_empty() {
            continue;
        }
        return Msg::parse_line(&line).map(Some);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::metrics::Metrics;

    fn fp() -> MatrixFingerprint {
        MatrixFingerprint { name: "m".into(), seed: 9, n_scenarios: 4, axes_hash: 0xABCD }
    }

    fn cell(index: usize) -> CellResult {
        CellResult {
            index,
            label: format!("cell-{index}"),
            engine_seed: 0xFEED + index as u64,
            metrics: Metrics::new(1),
        }
    }

    #[test]
    fn every_variant_round_trips() {
        let msgs = vec![
            Msg::Matrix {
                name: "synthetic".into(),
                opts: Value::parse(r#"{"seed":"7"}"#).unwrap(),
                fingerprint: fp(),
            },
            Msg::Lease { id: 3, start: 8, end: 16 },
            Msg::Shutdown,
            Msg::Ready { fingerprint: fp() },
            Msg::Cells { lease: 3, cells: vec![cell(8), cell(9)] },
            Msg::LeaseDone { lease: 3 },
            Msg::Error { reason: "fingerprint mismatch".into() },
        ];
        for m in msgs {
            let line = m.to_line();
            assert!(!line.contains('\n'), "line framing must hold: {line}");
            let back = Msg::parse_line(&line).unwrap();
            assert_eq!(back.to_line(), line, "round trip drifted for {line}");
        }
    }

    #[test]
    fn stream_of_lines_reads_back_in_order() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Lease { id: 1, start: 0, end: 4 }).unwrap();
        write_msg(&mut buf, &Msg::Shutdown).unwrap();
        let mut r = std::io::BufReader::new(&buf[..]);
        assert!(matches!(read_msg(&mut r).unwrap(), Some(Msg::Lease { id: 1, .. })));
        assert!(matches!(read_msg(&mut r).unwrap(), Some(Msg::Shutdown)));
        assert!(read_msg(&mut r).unwrap().is_none(), "EOF is Ok(None)");
    }

    #[test]
    fn torn_lines_and_unknown_types_are_errors() {
        assert!(Msg::parse_line(r#"{"type":"lease","id":1,"star"#).is_err());
        assert!(Msg::parse_line(r#"{"type":"warp"}"#).is_err());
        assert!(Msg::parse_line(r#"{"id":1}"#).is_err());
    }
}
