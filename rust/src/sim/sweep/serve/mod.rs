//! Streaming sweep dispatcher: work-stealing shard service with
//! out-of-core incremental merge.
//!
//! PR 3's `sim::sweep::shard` scales a sweep across hosts *statically*:
//! shard counts are fixed up front, a straggler host strands its stride,
//! and `merge` holds every cell in memory. This subsystem replaces both
//! limits with a dispatcher **process**:
//!
//! * [`protocol`] — line-delimited JSON messages over stdin/stdout pipes
//!   or TCP, with a fingerprint handshake (the `zygarde merge` admission
//!   control, moved to connection time).
//! * [`dispatch`] — [`DispatcherCore`], a pure state machine that streams
//!   fine-grained index-range *leases* to workers, steals the tails of
//!   slow leases for idle workers, reissues leases on timeout or worker
//!   death, and deduplicates overlapping results by scenario index.
//! * [`worker`] — the lease-executing loop behind `zygarde work`.
//! * [`spill`] — [`SpillMerger`], the out-of-core merger: sorted runs
//!   spilled to disk, k-way merged, report streamed out — peak memory is
//!   the spill-run size, never the matrix size.
//! * [`journal`] — the checksummed write-ahead log behind
//!   `zygarde serve --journal/--resume`: every spilled run is committed
//!   as provisional range records plus a manifest, torn tails truncate,
//!   and a restarted dispatcher leases out only the missing indices.
//! * [`service`] — the IO shell behind `zygarde serve`: transports,
//!   reader/writer threads, the event loop.
//! * [`simnet`] — a seeded discrete-event network that drives the same
//!   core and merger through latency, reordering, duplication, drops,
//!   partitions, and crash/restart chaos on a virtual clock — the engine
//!   behind `zygarde simtest` and the CI seed-corpus soak.
//!
//! The headline guarantee is inherited from the seed discipline
//! (`(matrix_seed, index)`-derived streams make every cell
//! location-independent) and enforced end to end: **the dispatcher's
//! merged report is byte-identical to the single-process
//! `SweepReport::json_string()`** for any worker count, lease schedule,
//! completion order, steal pattern, and mid-lease worker kill —
//! `rust/tests/sweep_serve.rs` proves it against arbitrary interleavings
//! of the core, and CI kills a live worker mid-run and `cmp`s the bytes.
//!
//! CLI:
//!
//! ```console
//! $ zygarde serve --matrix bench --workers 4 --out report.json
//! $ zygarde serve --matrix synthetic --listen 0.0.0.0:7177 --out report.json
//! $ zygarde work --connect dispatcher-host:7177   # on any number of hosts
//! ```

pub mod dispatch;
pub mod journal;
pub mod protocol;
pub mod service;
pub mod simnet;
pub mod spill;
pub mod worker;

pub use dispatch::{DispatchStats, DispatcherCore, Out, WorkerId, WorkerStats, LATENCY_BUCKETS};
pub use journal::{recover, Journal, Recovery, RunRecord};
pub use protocol::{read_msg, write_msg, Msg};
pub use service::{serve_to, ServeConfig, ServeOutcome};
pub use spill::{RunInfo, SpillMerger};
pub use worker::{backoff_ms, run_worker, MatrixResolver, WorkerError, WorkerOutcome};
