//! The dispatcher's IO shell: transports, threads, and the event loop
//! around [`DispatcherCore`] + [`SpillMerger`].
//!
//! Two transports, freely mixed in one run:
//!
//! * **Pipe workers** — `spawn_workers` child processes of
//!   `<worker_exe> work --connect -`, protocol over stdin/stdout pipes
//!   (stderr inherited for diagnostics). The zero-setup local mode.
//! * **TCP workers** — a `--listen addr` socket accepting external
//!   `zygarde work --connect addr` processes from anywhere; connections
//!   may come and go at any point of the sweep (late joiners steal work,
//!   deaths reissue it).
//!
//! Per connection: a reader thread parses inbound lines into an event
//! channel, a writer thread drains an outbound channel. The single main
//! loop owns all state — core and merger never see a lock. Every effect
//! the core emits is applied in order; `Out::Ingest` feeds the merger,
//! `Out::Done` ends the loop, and the merger then streams the final
//! report (byte-identical to the single-process `SweepReport`) to the
//! output writer.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::clock::wall::{SystemClock, WallClock};
use crate::sim::sweep::report::SummaryStats;
use crate::sim::sweep::shard::fingerprint;
use crate::sim::sweep::ScenarioMatrix;
use crate::telemetry::registry::{Counter, SCHEMA_VERSION};
use crate::telemetry::timeline::Timeline;
use crate::util::json::Value;

use super::dispatch::{DispatcherCore, Out, WorkerId};
use super::journal::{recover as recover_journal, Journal};
use super::protocol::{read_msg, write_msg, Msg};
use super::spill::SpillMerger;

/// Everything `serve_to` needs; the CLI fills this from flags.
pub struct ServeConfig {
    /// The matrix being served (built from the registry); used for its
    /// name, seed, fingerprint, and cell count — the dispatcher itself
    /// never runs a scenario.
    pub matrix: ScenarioMatrix,
    /// Registry name workers rebuild the matrix from.
    pub matrix_name: String,
    /// Registry options (`SweepOpts` JSON) shipped to workers verbatim.
    pub opts: Value,
    /// TCP listen address (e.g. `127.0.0.1:7177`); `None` = pipes only.
    pub listen: Option<String>,
    /// Local pipe workers to spawn.
    pub spawn_workers: usize,
    /// `--threads` handed to each spawned worker.
    pub worker_threads: usize,
    /// `--batch` handed to each spawned worker (streaming granularity).
    pub batch: usize,
    /// Cells per lease; 0 picks a size that gives every worker several
    /// refills (stealing and reissue stay fine-grained).
    pub lease_size: usize,
    /// Reissue a lease after this long without progress; 0 disables.
    pub lease_timeout_ms: u64,
    /// Spill-run size in cells — the merger's peak memory.
    pub spill_cells: usize,
    /// Where run files go; default: a per-pid dir under the temp dir.
    pub spill_dir: Option<PathBuf>,
    /// Write-ahead journal path (`--journal F` / `--resume F`): every
    /// spilled run is committed to it, run files are preserved across
    /// crashes, and a restarted dispatcher can `--resume` instead of
    /// recomputing. `None` = no journal (exactly the old behavior).
    pub journal: Option<PathBuf>,
    /// `--resume F`: `journal` holds an existing journal to recover.
    /// The received bitmap is rebuilt, persisted spill runs re-admitted,
    /// only missing indices leased out — and journaling continues to the
    /// same file.
    pub resume: bool,
    /// Binary to spawn pipe workers from; default: this executable.
    /// (Tests pass `CARGO_BIN_EXE_zygarde` — a test harness binary has
    /// no `work` subcommand.)
    pub worker_exe: Option<PathBuf>,
    pub quiet: bool,
    /// Write the final [`DispatchStats`] JSON here after the report is
    /// streamed out; `None` disables. A write failure only warns — the
    /// report itself already left through `out` and must not be voided
    /// by a metrics-file error.
    pub metrics_out: Option<PathBuf>,
    /// Emit a stderr heartbeat line at this period (wall-clock ms);
    /// 0 disables. Suppressed by `quiet` like the progress lines.
    pub heartbeat_ms: u64,
    /// `--trace-out F`: write a Chrome `trace_event` timeline of the
    /// campaign here after the report is streamed — lease lifecycle
    /// spans per worker, dispatcher/journal instants (see
    /// [`Timeline`]). Events are stamped with wall-clock milliseconds
    /// since serve start (the dispatcher clock, so a [`ManualClock`]
    /// makes the file deterministic). Like `metrics_out`, a write
    /// failure only warns.
    ///
    /// [`ManualClock`]: crate::clock::wall::ManualClock
    pub trace_out: Option<PathBuf>,
    /// The dispatcher's wall clock: every time the core is told
    /// (lease-timeout expiry, the lease-latency histogram) and every
    /// shell pacing decision (tick rate limit, heartbeat period,
    /// `wall_ms` in `--metrics-out`) reads this — never `Instant`
    /// directly — so simulated/traced runs get deterministic latencies
    /// instead of scheduler noise. Defaults to [`SystemClock`].
    pub clock: Box<dyn WallClock>,
}

impl ServeConfig {
    pub fn new(matrix: ScenarioMatrix, matrix_name: &str, opts: Value) -> ServeConfig {
        ServeConfig {
            matrix,
            matrix_name: matrix_name.to_string(),
            opts,
            listen: None,
            spawn_workers: 0,
            worker_threads: 1,
            batch: 4,
            lease_size: 0,
            lease_timeout_ms: 30_000,
            spill_cells: 10_000,
            spill_dir: None,
            journal: None,
            resume: false,
            worker_exe: None,
            quiet: true,
            metrics_out: None,
            heartbeat_ms: 5_000,
            trace_out: None,
            clock: Box::new(SystemClock::new()),
        }
    }
}

/// What a completed serve run looked like.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    pub n_scenarios: usize,
    pub workers_seen: u64,
    pub leases_granted: u64,
    pub steals: u64,
    pub reissues: u64,
    pub duplicates: u64,
    pub runs_spilled: usize,
    pub peak_buffered: usize,
    pub summary: SummaryStats,
}

enum Event {
    /// A TCP worker connected (pipe workers are registered inline). The
    /// extra stream handle is the *closer*: `Out::Kick` must be able to
    /// actually shut the socket down (dropping the writer half alone
    /// leaves the reader's dup'd fd open, and a hostile peer that
    /// ignores the `Error` would otherwise keep the connection alive).
    Connect(WorkerId, mpsc::Sender<Msg>, TcpStream),
    Inbound(WorkerId, Msg),
    Gone(WorkerId),
}

/// Start a writer thread draining `rx` into `w`; exits when the channel
/// closes or the peer goes away.
fn spawn_writer<W: Write + Send + 'static>(mut w: W, rx: mpsc::Receiver<Msg>) {
    std::thread::spawn(move || {
        for msg in rx {
            if write_msg(&mut w, &msg).is_err() {
                break;
            }
        }
    });
}

/// Start a reader thread parsing `r` into events; a clean EOF, a torn
/// line, or an IO error all end as `Gone`.
fn spawn_reader<R: std::io::Read + Send + 'static>(
    r: R,
    id: WorkerId,
    events: mpsc::Sender<Event>,
) {
    std::thread::spawn(move || {
        let mut rx = BufReader::new(r);
        loop {
            match read_msg(&mut rx) {
                Ok(Some(msg)) => {
                    if events.send(Event::Inbound(id, msg)).is_err() {
                        return;
                    }
                }
                Ok(None) | Err(_) => {
                    let _ = events.send(Event::Gone(id));
                    return;
                }
            }
        }
    });
}

/// Auto lease size: aim for every worker to refill several times so the
/// queue (not luck) does the load balancing, clamped to a useful range.
fn auto_lease_size(n: usize, workers: usize) -> usize {
    (n / (workers.max(1) * 8)).clamp(1, 256)
}

/// Owns the spawned pipe-worker children; `Drop` reaps them so every
/// error path out of `serve_to` (merge failure, all-workers-dead, closed
/// event channel) kills and waits instead of leaking zombies. The happy
/// path politely polls for a graceful exit first.
struct Reaper(Vec<Child>);

impl Drop for Reaper {
    fn drop(&mut self) {
        for child in &mut self.0 {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Run the dispatcher until every cell of the matrix has been ingested,
/// then stream the merged report to `out`. See module docs.
pub fn serve_to(cfg: ServeConfig, out: &mut dyn Write) -> Result<ServeOutcome, String> {
    let n = cfg.matrix.len();
    let fp = fingerprint(&cfg.matrix);
    let t_start = cfg.clock.now_ms();
    // The campaign timeline (`--trace-out`): stamped relative to
    // `t_start`, recorded inline by the single main loop — no locks,
    // no extra threads, rendered once at finalize.
    let mut timeline: Option<Timeline> =
        cfg.trace_out.as_ref().map(|_| Timeline::new(&format!("serve {}", cfg.matrix_name)));

    // --- journal / resume --------------------------------------------------
    let mut journal: Option<Journal> = None;
    let mut recovered = None;
    if let Some(jpath) = &cfg.journal {
        if cfg.resume {
            let rec = recover_journal(jpath)?;
            rec.verify_matches(&fp, &cfg.opts, jpath)?;
            if let Some(tl) = timeline.as_mut() {
                tl.journal_recovered(
                    cfg.clock.now_ms().saturating_sub(t_start),
                    rec.intact_len,
                    rec.torn_bytes,
                    rec.runs.len(),
                    rec.n_received,
                );
            }
            if rec.finalized {
                return Err(format!(
                    "journal {} is already finalized — its report was fully streamed; \
                     start a fresh serve (new --journal) instead of resuming",
                    jpath.display()
                ));
            }
            if !cfg.quiet {
                let torn = if rec.torn_bytes > 0 {
                    format!(" (dropped {} torn tail byte(s))", rec.torn_bytes)
                } else {
                    String::new()
                };
                eprintln!(
                    "serve: resuming from {} — {}/{n} cells journaled in {} run(s){torn}",
                    jpath.display(),
                    rec.n_received,
                    rec.runs.len(),
                );
            }
            journal = Some(Journal::resume(jpath, &rec)?);
            recovered = Some(rec);
        } else {
            journal = Some(Journal::create(jpath, &fp, &cfg.opts)?);
        }
    } else if cfg.resume {
        return Err("--resume requires a journal path".to_string());
    }

    let lease_size = if cfg.lease_size > 0 {
        cfg.lease_size
    } else {
        auto_lease_size(n, cfg.spawn_workers.max(1))
    };
    let mut core = match &recovered {
        Some(rec) => DispatcherCore::resume(
            &cfg.matrix_name,
            cfg.opts.clone(),
            fp.clone(),
            lease_size,
            cfg.lease_timeout_ms,
            rec.received.clone(),
        ),
        None => DispatcherCore::new(
            &cfg.matrix_name,
            cfg.opts.clone(),
            fp.clone(),
            lease_size,
            cfg.lease_timeout_ms,
        ),
    };
    let spill_dir = cfg.spill_dir.clone().unwrap_or_else(|| {
        std::env::temp_dir().join(format!("zygarde_serve_{}", std::process::id()))
    });
    let mut merger = Some(SpillMerger::new(spill_dir, cfg.spill_cells)?);
    if let Some(m) = merger.as_mut() {
        if journal.is_some() {
            // Journaled run files must survive this process: the journal
            // references them by path and a restarted dispatcher adopts
            // them. They are deleted only after the finalize marker.
            m.set_preserve(true);
        }
        if let Some(rec) = &recovered {
            for run in &rec.runs {
                m.adopt_run(run)?;
                if let Some(tl) = timeline.as_mut() {
                    tl.journal_run_adopted(
                        cfg.clock.now_ms().saturating_sub(t_start),
                        run.cells,
                    );
                }
            }
        }
    }

    // A journal that already covers every cell: nothing to lease, go
    // straight to the merge — no workers needed or spawned.
    if core.is_done() {
        if !cfg.quiet {
            eprintln!("serve: journal already covers all {n} cells — finalizing without workers");
        }
        return finish(
            &cfg,
            &core,
            merger.take().expect("merger"),
            &mut journal,
            &mut timeline,
            t_start,
            out,
        );
    }

    let expected_workers = cfg.spawn_workers + usize::from(cfg.listen.is_some());
    if expected_workers == 0 {
        return Err("serve needs pipe workers (--workers) or a --listen address".to_string());
    }

    let (events_tx, events_rx) = mpsc::channel::<Event>();
    let next_id = Arc::new(AtomicUsize::new(0));
    let mut senders: HashMap<WorkerId, mpsc::Sender<Msg>> = HashMap::new();
    // TCP closer handles so a kick can force the socket shut (see Event).
    let mut closers: HashMap<WorkerId, TcpStream> = HashMap::new();
    // Connections that have not produced a `Gone` yet (kicks only drop
    // the sender; the reader thread still delivers the eventual EOF).
    let mut live: std::collections::HashSet<WorkerId> = std::collections::HashSet::new();
    let mut children = Reaper(Vec::new());

    // --- pipe workers ----------------------------------------------------
    let exe = match &cfg.worker_exe {
        Some(p) => p.clone(),
        None => std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?,
    };
    let mut pending_connects: Vec<WorkerId> = Vec::new();
    for _ in 0..cfg.spawn_workers {
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let mut child = Command::new(&exe)
            .args([
                "work",
                "--connect",
                "-",
                "--threads",
                &cfg.worker_threads.to_string(),
                "--batch",
                &cfg.batch.to_string(),
            ])
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| format!("spawning worker `{}`: {e}", exe.display()))?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = child.stdout.take().expect("piped stdout");
        let (out_tx, out_rx) = mpsc::channel::<Msg>();
        spawn_writer(stdin, out_rx);
        spawn_reader(stdout, id, events_tx.clone());
        senders.insert(id, out_tx);
        live.insert(id);
        children.0.push(child);
        pending_connects.push(id);
    }

    // --- TCP listener ----------------------------------------------------
    if let Some(addr) = &cfg.listen {
        let listener =
            TcpListener::bind(addr).map_err(|e| format!("listen on {addr}: {e}"))?;
        if !cfg.quiet {
            eprintln!("serve: listening on {addr}");
        }
        let events = events_tx.clone();
        let ids = Arc::clone(&next_id);
        // Detached: blocks in accept() until the process exits. Workers
        // that connect after completion get an EOF and exit on their own.
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let id = ids.fetch_add(1, Ordering::Relaxed);
                let Ok(read_half) = stream.try_clone() else { continue };
                let Ok(closer) = stream.try_clone() else { continue };
                let (out_tx, out_rx) = mpsc::channel::<Msg>();
                spawn_writer(stream, out_rx);
                spawn_reader(read_half, id, events.clone());
                if events.send(Event::Connect(id, out_tx, closer)).is_err() {
                    return;
                }
            }
        });
    }

    // --- main loop --------------------------------------------------------
    let mut done = false;
    let mut merge_err: Option<String> = None;
    let mut last_report = 0usize;
    let mut last_tick = t_start;
    let mut last_heartbeat = t_start;
    {
        let route = |outs: Vec<Out>,
                     now_ms: u64,
                     senders: &mut HashMap<WorkerId, mpsc::Sender<Msg>>,
                     closers: &mut HashMap<WorkerId, TcpStream>,
                     merger: &mut Option<SpillMerger>,
                     journal: &mut Option<Journal>,
                     timeline: &mut Option<Timeline>,
                     done: &mut bool,
                     merge_err: &mut Option<String>| {
            let t_rel = now_ms.saturating_sub(t_start);
            for o in outs {
                match o {
                    Out::Send(w, msg) => {
                        // A lease leaving the dispatcher opens its span
                        // (stolen ranges included — they are ordinary
                        // grants of a split tail).
                        if let (Some(tl), Msg::Lease { id, start, end }) =
                            (timeline.as_mut(), &msg)
                        {
                            tl.lease_granted(*id, w as u64, *start, *end, t_rel);
                        }
                        // A closed channel means the worker already died;
                        // its Gone event will requeue everything.
                        if let Some(tx) = senders.get(&w) {
                            let _ = tx.send(msg);
                        }
                    }
                    Out::Ingest(cell) => {
                        if let Some(m) = merger.as_mut() {
                            if let Err(e) = m.push(cell) {
                                *merge_err = Some(e);
                                *done = true;
                            } else {
                                // Commit freshly spilled runs to the WAL
                                // before anything else happens: ranges
                                // first (provisional), then the manifest
                                // that makes them durable. A journal that
                                // cannot commit voids the resume guarantee
                                // — abort loudly rather than serve on.
                                let spilled = m.take_spilled();
                                if !spilled.is_empty() {
                                    if let Some(tl) = timeline.as_mut() {
                                        tl.spill_run(m.runs_spilled(), t_rel);
                                    }
                                }
                                for info in spilled {
                                    if let Some(j) = journal.as_mut() {
                                        if let Err(e) =
                                            j.append_spill(&info.ranges, &info.record)
                                        {
                                            *merge_err = Some(e);
                                            *done = true;
                                        }
                                    }
                                }
                            }
                        }
                    }
                    Out::Kick(w) => {
                        // Dropping the sender lets the writer thread
                        // drain the just-queued explanatory Error before
                        // it closes the write side (pipe workers then die
                        // of stdin EOF). For TCP, additionally shut only
                        // the *read* half: the violator can say nothing
                        // more and our reader sees EOF, while the Error
                        // still flushes out the intact write half.
                        senders.remove(&w);
                        if let Some(s) = closers.remove(&w) {
                            let _ = s.shutdown(Shutdown::Read);
                        }
                    }
                    Out::Done => {
                        if let Some(tl) = timeline.as_mut() {
                            tl.dispatch_done(n, t_rel);
                        }
                        *done = true;
                    }
                }
            }
        };

        for id in pending_connects {
            if let Some(tl) = timeline.as_mut() {
                tl.worker_connected(id as u64, cfg.clock.now_ms().saturating_sub(t_start));
            }
            let outs = core.on_connect(id);
            route(outs, cfg.clock.now_ms(), &mut senders, &mut closers, &mut merger, &mut journal, &mut timeline, &mut done, &mut merge_err);
        }

        while !done {
            match events_rx.recv_timeout(Duration::from_millis(200)) {
                Ok(Event::Connect(id, tx, closer)) => {
                    senders.insert(id, tx);
                    closers.insert(id, closer);
                    live.insert(id);
                    if !cfg.quiet {
                        eprintln!("serve: worker {id} connected");
                    }
                    if let Some(tl) = timeline.as_mut() {
                        tl.worker_connected(id as u64, cfg.clock.now_ms().saturating_sub(t_start));
                    }
                    let outs = core.on_connect(id);
                    route(outs, cfg.clock.now_ms(), &mut senders, &mut closers, &mut merger, &mut journal, &mut timeline, &mut done, &mut merge_err);
                }
                Ok(Event::Inbound(id, msg)) => {
                    let now = cfg.clock.now_ms();
                    if let Some(tl) = timeline.as_mut() {
                        // Record against the inbound message itself: a
                        // batch under an unknown lease (a violation the
                        // core will kick) is a no-op on the open-lease
                        // map, so the timeline never invents spans.
                        match &msg {
                            Msg::Cells { lease, cells } => {
                                tl.lease_cells(*lease, cells.len() as u64, now.saturating_sub(t_start));
                            }
                            Msg::LeaseDone { lease } => {
                                tl.lease_closed(*lease, now.saturating_sub(t_start), "done");
                            }
                            _ => {}
                        }
                    }
                    let outs = core.on_message(id, msg, now);
                    route(outs, now, &mut senders, &mut closers, &mut merger, &mut journal, &mut timeline, &mut done, &mut merge_err);
                }
                Ok(Event::Gone(id)) => {
                    senders.remove(&id);
                    closers.remove(&id);
                    if live.remove(&id) && !cfg.quiet {
                        eprintln!("serve: worker {id} disconnected");
                    }
                    if let Some(tl) = timeline.as_mut() {
                        tl.worker_gone(id as u64, cfg.clock.now_ms().saturating_sub(t_start));
                    }
                    let outs = core.on_disconnect(id, cfg.clock.now_ms());
                    route(outs, cfg.clock.now_ms(), &mut senders, &mut closers, &mut merger, &mut journal, &mut timeline, &mut done, &mut merge_err);
                    if live.is_empty() && cfg.listen.is_none() && !core.is_done() {
                        return Err(format!(
                            "all workers exited with {} of {n} cells ingested",
                            core.cells_received()
                        ));
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err("event channel closed unexpectedly".to_string());
                }
            }
            // Tick for lease timeouts and idle regrants, rate-limited:
            // an unconditional per-message tick would rescan every lease
            // and worker on each Cells batch — pure bookkeeping made
            // quadratic on big matrices.
            let now = cfg.clock.now_ms();
            if !done && now.saturating_sub(last_tick) >= 100 {
                last_tick = now;
                let outs = core.on_tick(now);
                route(outs, now, &mut senders, &mut closers, &mut merger, &mut journal, &mut timeline, &mut done, &mut merge_err);
            }
            if !cfg.quiet {
                let got = core.cells_received();
                if got * 10 / n > last_report * 10 / n.max(1) {
                    eprintln!("serve: {got}/{n} cells");
                    last_report = got;
                }
                if cfg.heartbeat_ms > 0 && now.saturating_sub(last_heartbeat) >= cfg.heartbeat_ms {
                    last_heartbeat = now;
                    // The heartbeat reads the same registry snapshot that
                    // `--metrics-out` and `zygarde profile` serialize —
                    // one `serve.*` schema, three consumers.
                    let reg = core.stats.to_registry();
                    eprintln!(
                        "serve: heartbeat {got}/{n} cells | leases {} granted {} active | \
                         steals {} reissues {} | dup {} | workers {} | spill runs {} peak {}",
                        reg.get(Counter::ServeLeasesGranted),
                        core.leases_active(),
                        reg.get(Counter::ServeSteals),
                        reg.get(Counter::ServeReissues),
                        reg.get(Counter::ServeDuplicates),
                        reg.get(Counter::ServeWorkersSeen),
                        merger.as_ref().map_or(0, |m| m.runs_spilled()),
                        merger.as_ref().map_or(0, |m| m.peak_buffered()),
                    );
                    let dup = reg.get(Counter::ServeDuplicates);
                    let recv = reg.get(Counter::ServeCellsReceived);
                    if recv > 0 && dup as f64 / recv as f64 > 0.01 {
                        eprintln!(
                            "serve: WARN duplicate cells at {:.1}% of deliveries ({dup} of {recv}) — \
                             late post-reissue results are being dropped after dedup",
                            dup as f64 * 100.0 / recv as f64,
                        );
                    }
                }
            }
        }
    }
    if let Some(e) = merge_err {
        return Err(e);
    }

    // Let the queued Shutdowns drain, then reap the children gracefully
    // (a worker mid-sub-chunk notices the closed pipe at its next write);
    // the Reaper's Drop force-kills whatever is left — and covers the
    // early error returns above, which never reach this loop.
    drop(senders);
    drop(events_tx);
    for child in &mut children.0 {
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                _ => {
                    let _ = child.kill();
                    let _ = child.wait();
                    break;
                }
            }
        }
    }

    let merger = merger.take().expect("merger still present at finalize");
    finish(&cfg, &core, merger, &mut journal, &mut timeline, t_start, out)
}

/// Stream the merged report, retire the journal, and assemble the
/// outcome — shared by the normal loop exit and the resumed-complete
/// fast path (a journal that already covers every cell).
fn finish(
    cfg: &ServeConfig,
    core: &DispatcherCore,
    merger: SpillMerger,
    journal: &mut Option<Journal>,
    timeline: &mut Option<Timeline>,
    t_start: u64,
    out: &mut dyn Write,
) -> Result<ServeOutcome, String> {
    let n = cfg.matrix.len();
    let runs_spilled = merger.runs_spilled();
    let peak_buffered = merger.peak_buffered();
    let run_paths = merger.run_paths();
    let run_dir = merger.dir().to_path_buf();
    let summary = merger.finalize(&cfg.matrix.name, cfg.matrix.seed, n, out)?;
    if let Some(j) = journal.as_mut() {
        // The report has fully left through `out`: mark the journal
        // spent, then the preserved run files (possibly adopted from a
        // crashed pid's spill dir) can finally go. The journal file
        // itself stays — it is the durable record that this campaign
        // completed, and `--resume` on it fails loudly.
        j.append_finalize(n)?;
        if let Some(tl) = timeline.as_mut() {
            tl.journal_finalized(cfg.clock.now_ms().saturating_sub(t_start), n);
        }
        for p in &run_paths {
            let _ = std::fs::remove_file(p);
            if let Some(parent) = p.parent() {
                let _ = std::fs::remove_dir(parent);
            }
        }
        let _ = std::fs::remove_dir(&run_dir);
    }
    if core.stats.duplicate_ratio() > 0.01 {
        eprintln!(
            "serve: WARN {:.1}% of delivered cells were late duplicates ({} of {}) — \
             consider a longer --lease-timeout-ms",
            core.stats.duplicate_ratio() * 100.0,
            core.stats.duplicates,
            core.stats.cells_received
        );
    }
    if let Some(path) = &cfg.metrics_out {
        // The flat legacy keys stay for existing consumers; the
        // versioned `registry` snapshot is the shared schema (`serve.*`
        // ids, same bytes `zygarde profile` and the heartbeat read).
        let mut doc = core.stats.to_json();
        if let Value::Obj(map) = &mut doc {
            map.insert("schema_version".to_string(), Value::Num(SCHEMA_VERSION as f64));
            map.insert("registry".to_string(), core.stats.to_registry().snapshot());
            map.insert("n_scenarios".to_string(), Value::Num(n as f64));
            map.insert("runs_spilled".to_string(), Value::Num(runs_spilled as f64));
            map.insert("peak_buffered".to_string(), Value::Num(peak_buffered as f64));
            map.insert(
                "wall_ms".to_string(),
                Value::Num(cfg.clock.now_ms().saturating_sub(t_start) as f64),
            );
        }
        let body = format!("{}\n", doc.to_json());
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("serve: WARN could not write metrics to {}: {e}", path.display());
        } else if !cfg.quiet {
            eprintln!("serve: metrics written to {}", path.display());
        }
    }
    if let Some(path) = &cfg.trace_out {
        let tl = timeline.take().expect("trace_out implies a timeline");
        let body = format!("{}\n", tl.finish(cfg.clock.now_ms().saturating_sub(t_start)));
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("serve: WARN could not write trace to {}: {e}", path.display());
        } else if !cfg.quiet {
            eprintln!("serve: timeline written to {}", path.display());
        }
    }
    Ok(ServeOutcome {
        n_scenarios: n,
        workers_seen: core.stats.workers_seen,
        leases_granted: core.stats.leases_granted,
        steals: core.stats.steals,
        reissues: core.stats.reissues,
        duplicates: core.stats.duplicates,
        runs_spilled,
        peak_buffered,
        summary,
    })
}
