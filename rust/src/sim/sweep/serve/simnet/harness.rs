//! The discrete-event transport: a whole serve campaign on one thread,
//! over a virtual clock, under a seeded [`FaultPlan`].
//!
//! The harness owns the real [`DispatcherCore`] and the real
//! [`SpillMerger`] — nothing is mocked on the dispatcher side. Workers
//! are modeled in-process: a connected worker acks the matrix handshake,
//! "computes" leased cells by replaying the precomputed single-process
//! reference cells at seeded per-cell costs, and streams `Cells` batches
//! plus a `LeaseDone`, exactly like `zygarde work` over a pipe. Every
//! message crosses the simulated network, where the plan may delay,
//! reorder, duplicate, drop, or partition it; planned crashes kill a
//! lease-holding worker mid-flight and reconnect its slot later.
//!
//! Everything is driven off one `BinaryHeap` of timestamped events with
//! a sequence-number tiebreaker and one `Pcg32` stream, so the whole
//! campaign — dispatcher decisions, network chaos, the event log — is a
//! pure function of `(matrix, SimConfig)`. [`run_campaign`] finalizes
//! the merge into bytes and compares them against
//! `SweepReport::json_string()`: `SimOutcome::matches` is the headline
//! assertion, and `SimOutcome::log_hash` pins the dispatcher event
//! schedule for same-seed reruns.
//!
//! Convergence is by construction, not hope: chaos probabilities switch
//! off once `heal_permille` of the cells are ingested, partitions are
//! finite, crashed workers restart, and a stalled or worker-less
//! campaign gets deterministic "relief" workers — so any seed either
//! completes byte-identical or fails loudly within the virtual horizon,
//! and a failure message always carries the seed to commit to the
//! corpus.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};

use crate::sim::sweep::report::CellResult;
use crate::sim::sweep::shard::{fingerprint, MatrixFingerprint};
use crate::sim::sweep::{default_threads, run_matrix, ScenarioMatrix};
use crate::telemetry::timeline::Timeline;
use crate::util::json::Value;
use crate::util::rng::Pcg32;

use super::super::dispatch::{DispatchStats, DispatcherCore, Out, WorkerId};
use super::super::journal::{recover as recover_journal, Journal};
use super::super::protocol::Msg;
use super::super::spill::SpillMerger;
use super::plan::{FaultPlan, FaultSpec};

/// Pcg32 stream id for transport draws (latency, drops, batch sizes) —
/// distinct from the plan-derivation stream so the plan of seed N never
/// shifts when the transport consumes a different number of draws.
const NET_STREAM: u64 = 0x6E65_742D_7369_6D; // "net-sim"

/// Hard virtual-time ceiling: a campaign that has not converged after
/// ten virtual minutes is wedged, and the run fails with its seed.
const HORIZON_MS: u64 = 600_000;

/// With no progress for this long (virtual ms), spawn a relief worker.
const RELIEF_AFTER_MS: u64 = 2_000;

/// Disambiguates spill directories when parallel tests in one process
/// run campaigns with the same seed.
static RUN_SERIAL: AtomicU64 = AtomicU64::new(0);

/// One simulated campaign's knobs. `seed` drives *everything*: the
/// fault plan (under `spec`'s overrides) and every transport draw.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub seed: u64,
    /// Initial worker count (restarted crash victims reuse their slot;
    /// relief workers get fresh slots beyond this range).
    pub workers: usize,
    pub spec: FaultSpec,
    /// Cells per lease; 0 picks `n / (workers * 4)` clamped to 1..=32.
    pub lease_size: usize,
    /// Virtual-ms lease timeout handed to the core.
    pub lease_timeout_ms: u64,
    /// Virtual-ms period of the dispatcher maintenance tick.
    pub tick_ms: u64,
    /// Spill-run size for the out-of-core merger (small by default so
    /// every campaign exercises the spill path).
    pub spill_cells: usize,
    /// Threads for the single-process reference run; 0 = all cores.
    pub threads: usize,
    /// Keep the per-event dispatcher log (the reproducibility artifact).
    pub collect_log: bool,
    /// Record a [`Timeline`] of the campaign (`simtest --trace-out`).
    /// Every event is stamped with the virtual clock, so the rendered
    /// document in [`SimOutcome::timeline`] is a pure function of the
    /// seed — CI byte-compares repeat runs.
    pub trace: bool,
}

impl SimConfig {
    pub fn new(seed: u64, workers: usize) -> SimConfig {
        SimConfig {
            seed,
            workers,
            spec: FaultSpec::default(),
            lease_size: 0,
            lease_timeout_ms: 300,
            tick_ms: 50,
            spill_cells: 32,
            threads: 0,
            collect_log: true,
            trace: false,
        }
    }
}

/// Transport-level tallies, separate from the core's [`DispatchStats`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetCounters {
    /// Messages handed to the network (both directions).
    pub sent: u64,
    /// Messages actually processed by a live endpoint.
    pub delivered: u64,
    /// Dropped by chance or a partition window.
    pub dropped: u64,
    /// Messages the network delivered twice.
    pub duplicated: u64,
    /// Messages given pathological extra latency (overtaken in flight).
    pub reordered: u64,
    /// Planned crashes that found a victim.
    pub crashes: u64,
    /// Planned dispatcher crash+resume cycles that fired (each one runs
    /// the real `journal::recover` / `DispatcherCore::resume` path).
    pub dcrashes: u64,
    /// Partition windows that opened.
    pub partitions: u64,
    /// Workers the core kicked for protocol violations (reordered or
    /// duplicated streams trip the contiguous-ascending cells check).
    pub kicks: u64,
    /// Relief workers spawned against stalls.
    pub relief_spawns: u64,
}

/// What a simulated campaign produced. `report` vs `reference` is the
/// byte-identity check; `log`/`log_hash` pin the event schedule.
pub struct SimOutcome {
    /// `report == reference.as_bytes()` — the headline guarantee.
    pub matches: bool,
    /// The streamed, merged report bytes out of the real [`SpillMerger`].
    pub report: Vec<u8>,
    /// `SweepReport::json_string()` of the single-process run.
    pub reference: String,
    /// Dispatcher event log (empty unless `collect_log`).
    pub log: Vec<String>,
    /// FNV-1a over the log lines — the compact schedule fingerprint.
    pub log_hash: u64,
    /// Virtual milliseconds the campaign took.
    pub virtual_ms: u64,
    /// Discrete events processed.
    pub events: u64,
    pub stats: DispatchStats,
    pub net: NetCounters,
    pub plan: FaultPlan,
    /// Connections made over the campaign's lifetime (initial workers +
    /// crash restarts + relief workers).
    pub workers_spawned: usize,
    /// The rendered Chrome `trace_event` document (`SimConfig::trace`):
    /// lease spans per worker, journal recovery, and fault-plan markers,
    /// all on the virtual clock — byte-identical across same-seed runs.
    pub timeline: Option<String>,
}

enum Ev {
    /// A worker process starts and its connection reaches the dispatcher.
    Connect { slot: usize },
    /// Network delivery, dispatcher → worker.
    ToWorker { w: WorkerId, msg: Msg },
    /// Network delivery, worker → dispatcher.
    ToDispatcher { w: WorkerId, msg: Msg },
    /// A worker finished composing `msg`; hand it to the network (the
    /// chaos draws happen here, not at composition time).
    Emit { w: WorkerId, msg: Msg },
    /// The transport notices a closed connection.
    Gone { w: WorkerId },
    PartitionEnd { idx: usize },
    /// A crashed dispatcher comes back up and recovers its journal.
    DispatcherRestart,
    Tick,
}

struct Scheduled {
    t: u64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    /// Reversed so the max-heap pops the earliest `(t, seq)` — the seq
    /// tiebreaker makes same-instant ordering deterministic.
    fn cmp(&self, other: &Self) -> Ordering {
        other.t.cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct Conn {
    slot: usize,
    /// The process is running and its connection is open.
    alive: bool,
    /// The dispatcher-side transport processed this connection's EOF.
    gone: bool,
    /// Best-effort "currently holds a live lease" (set at grant
    /// transmission, cleared at `LeaseDone` receipt or death) — only
    /// used to pick crash victims that die mid-lease.
    holding: bool,
}

struct Sim {
    plan: FaultPlan,
    fp: MatrixFingerprint,
    cells: Vec<CellResult>,
    n: usize,
    tick_ms: u64,
    collect_log: bool,
    core: DispatcherCore,
    merger: Option<SpillMerger>,
    /// Write-ahead journal, present only for campaigns with planned
    /// dispatcher crashes (mirrors `serve --journal`).
    journal: Option<Journal>,
    journal_path: Option<PathBuf>,
    /// Everything a restarted dispatcher needs to rebuild its core and
    /// merger exactly the way `serve --resume` does.
    matrix_name: String,
    spill_dir: PathBuf,
    spill_cells: usize,
    lease_size: usize,
    lease_timeout_ms: u64,
    heap: BinaryHeap<Scheduled>,
    seq: u64,
    now: u64,
    rng: Pcg32,
    conns: Vec<Conn>,
    /// Latency multiplier per slot (slow links); relief slots append 1s.
    slot_factor: Vec<u64>,
    next_slot: usize,
    partition_active: Vec<bool>,
    crash_cursor: usize,
    partition_cursor: usize,
    dcrash_cursor: usize,
    /// Ingested-cell thresholds the permille triggers resolve to.
    crash_at: Vec<usize>,
    partition_at: Vec<usize>,
    dcrash_at: Vec<usize>,
    /// The dispatcher process is down (between a dcrash and its restart):
    /// connects are refused and no core exists to make progress.
    dispatcher_down: bool,
    /// Slots of the workers that were alive at dcrash time — they retry
    /// their connection after the restart, like `work --retry`.
    reconnect_slots: Vec<usize>,
    heal_cells: usize,
    pending_connects: usize,
    done: bool,
    merge_err: Option<String>,
    log: Vec<String>,
    net: NetCounters,
    last_progress_ms: u64,
    events: u64,
    /// `SimConfig::trace`: the campaign timeline, stamped with `now`.
    timeline: Option<Timeline>,
}

impl Sim {
    fn schedule(&mut self, t: u64, ev: Ev) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { t, seq, ev });
    }

    fn note(&mut self, line: String) {
        if self.collect_log {
            self.log.push(line);
        }
    }

    /// Chaos probabilities only apply before the heal point (see plan).
    fn chaos_active(&self) -> bool {
        self.core.cells_received() < self.heal_cells
    }

    fn in_partition(&self, slot: usize) -> bool {
        self.partition_active.iter().enumerate().any(|(i, &on)| {
            on && {
                let p = &self.plan.partitions[i];
                p.lo_slot <= slot && slot < p.hi_slot
            }
        })
    }

    fn latency(&mut self, slot: usize) -> u64 {
        let (lo, hi) = self.plan.latency_ms;
        let base = lo + self.rng.below(hi - lo + 1);
        base * self.slot_factor.get(slot).copied().unwrap_or(1)
    }

    /// Push one message through the simulated network. All chaos draws
    /// happen here, in event order, on the single seeded stream.
    fn transmit(&mut self, w: WorkerId, to_dispatcher: bool, msg: Msg) {
        self.net.sent += 1;
        let slot = self.conns[w].slot;
        if self.in_partition(slot) {
            self.net.dropped += 1;
            return;
        }
        let chaos = self.chaos_active();
        if chaos && self.rng.chance(self.plan.drop_p) {
            self.net.dropped += 1;
            return;
        }
        let mut delay = self.latency(slot);
        if chaos && self.rng.chance(self.plan.reorder_p) {
            // Enough extra latency that later messages on the same link
            // overtake this one — the pathological-WAN case the
            // contiguous-cells protocol check exists for.
            delay += 1 + self.rng.below(4 * self.plan.latency_ms.1.max(2));
            self.net.reordered += 1;
        }
        let copies = if chaos && self.rng.chance(self.plan.dup_p) {
            self.net.duplicated += 1;
            2
        } else {
            1
        };
        for i in 0..copies {
            let extra =
                if i == 0 { 0 } else { 1 + self.rng.below(2 * self.plan.latency_ms.1.max(2)) };
            let ev = if to_dispatcher {
                Ev::ToDispatcher { w, msg: msg.clone() }
            } else {
                Ev::ToWorker { w, msg: msg.clone() }
            };
            self.schedule(self.now + delay + extra, ev);
        }
    }

    /// A worker process dies (crash, kick, shutdown): cancel its future
    /// emissions and let the dispatcher notice the EOF one latency later.
    fn kill_conn(&mut self, w: WorkerId) {
        if !self.conns[w].alive {
            return;
        }
        self.conns[w].alive = false;
        self.conns[w].holding = false;
        let slot = self.conns[w].slot;
        let delay = self.latency(slot);
        self.schedule(self.now + delay, Ev::Gone { w });
    }

    /// Apply the core's effects; log a one-line summary when anything
    /// happened, then fire any progress-triggered faults.
    fn apply(&mut self, tag: &str, outs: Vec<Out>) {
        if !outs.is_empty() {
            if self.collect_log {
                self.log.push(format!("t={} {tag} -> {}", self.now, fmt_outs(&outs)));
            }
            self.last_progress_ms = self.now;
            self.route(outs);
        }
        self.fire_progress_faults();
    }

    fn route(&mut self, outs: Vec<Out>) {
        for o in outs {
            match o {
                Out::Send(w, msg) => {
                    if self.conns[w].alive {
                        if let Msg::Lease { id, start, end } = &msg {
                            self.conns[w].holding = true;
                            if let Some(tl) = self.timeline.as_mut() {
                                tl.lease_granted(*id, w as u64, *start, *end, self.now);
                            }
                        }
                        self.transmit(w, false, msg);
                    }
                }
                Out::Ingest(cell) => {
                    if let Some(m) = self.merger.as_mut() {
                        if let Err(e) = m.push(cell) {
                            self.merge_err = Some(e);
                            self.done = true;
                        } else {
                            let spilled = m.take_spilled();
                            if !spilled.is_empty() {
                                if let Some(tl) = self.timeline.as_mut() {
                                    tl.spill_run(m.runs_spilled(), self.now);
                                }
                            }
                            // Same write-through as the serve shell:
                            // ranges first, then the committing manifest.
                            if self.journal.is_some() {
                                for info in spilled {
                                    let j = self.journal.as_mut().expect("journal present");
                                    if let Err(e) = j.append_spill(&info.ranges, &info.record) {
                                        self.merge_err = Some(e);
                                        self.done = true;
                                    }
                                }
                            }
                        }
                    }
                }
                Out::Kick(w) => {
                    self.net.kicks += 1;
                    let line = format!("t={} kick w{w}", self.now);
                    self.note(line);
                    if let Some(tl) = self.timeline.as_mut() {
                        tl.fault("kick", self.now, &format!("w{w}"));
                    }
                    self.kill_conn(w);
                }
                Out::Done => {
                    self.done = true;
                    let line = format!("t={} done", self.now);
                    self.note(line);
                    if let Some(tl) = self.timeline.as_mut() {
                        tl.dispatch_done(self.n, self.now);
                    }
                }
            }
        }
    }

    /// Fire every planned fault whose ingested-cell threshold has been
    /// crossed. Progress-triggered (not time-triggered) so "crash
    /// mid-campaign" holds for any matrix size or worker count.
    fn fire_progress_faults(&mut self) {
        let got = self.core.cells_received();
        while self.crash_cursor < self.crash_at.len() && got >= self.crash_at[self.crash_cursor] {
            let idx = self.crash_cursor;
            self.crash_cursor += 1;
            let restart_after = self.plan.crashes[idx].restart_after_ms;
            // Victim: lowest-id live worker currently holding a lease —
            // a genuine mid-lease crash — falling back to any live one.
            let victim = self
                .conns
                .iter()
                .enumerate()
                .filter(|(_, c)| c.alive)
                .min_by_key(|(i, c)| (!c.holding, *i))
                .map(|(i, _)| i);
            let Some(v) = victim else { continue };
            self.net.crashes += 1;
            let slot = self.conns[v].slot;
            let line =
                format!("t={} crash w{v} slot{slot} restart=+{restart_after}ms", self.now);
            self.note(line);
            if let Some(tl) = self.timeline.as_mut() {
                tl.fault("crash", self.now, &format!("w{v} slot{slot}"));
            }
            self.kill_conn(v);
            self.pending_connects += 1;
            self.schedule(self.now + restart_after, Ev::Connect { slot });
        }
        while self.partition_cursor < self.partition_at.len()
            && got >= self.partition_at[self.partition_cursor]
        {
            let idx = self.partition_cursor;
            self.partition_cursor += 1;
            self.partition_active[idx] = true;
            self.net.partitions += 1;
            let (lo, hi, dur) = {
                let p = &self.plan.partitions[idx];
                (p.lo_slot, p.hi_slot, p.duration_ms)
            };
            let line = format!("t={} partition#{idx} slots {lo}..{hi} for {dur}ms", self.now);
            self.note(line);
            if let Some(tl) = self.timeline.as_mut() {
                tl.fault("partition", self.now, &format!("#{idx} slots {lo}..{hi} {dur}ms"));
            }
            self.schedule(self.now + dur, Ev::PartitionEnd { idx });
        }
        // At most one dispatcher crash per apply; if a later threshold is
        // already crossed when the restarted dispatcher makes progress,
        // the next apply fires it. Never after `done`: a finalizing
        // campaign has consumed its merger.
        if !self.done
            && !self.dispatcher_down
            && self.dcrash_cursor < self.dcrash_at.len()
            && got >= self.dcrash_at[self.dcrash_cursor]
        {
            self.crash_dispatcher();
        }
    }

    /// kill -9 the dispatcher: the core and the merger's in-memory buffer
    /// vanish, the journal handle closes wherever it stands, and every
    /// connection drops without ceremony (no `on_disconnect` — there is
    /// no core left to tell). Only journaled spill runs survive on disk.
    fn crash_dispatcher(&mut self) {
        let idx = self.dcrash_cursor;
        self.dcrash_cursor += 1;
        let restart_after = self.plan.dcrashes[idx].restart_after_ms;
        self.net.dcrashes += 1;
        self.dispatcher_down = true;
        let line = format!(
            "t={} dcrash#{idx} received={} restart=+{restart_after}ms",
            self.now,
            self.core.cells_received()
        );
        self.note(line);
        if let Some(tl) = self.timeline.as_mut() {
            tl.fault(
                "dcrash",
                self.now,
                &format!("#{idx} received={} restart=+{restart_after}ms", self.core.cells_received()),
            );
            // Every connection dies with the process; their held leases
            // resolve as `gone` here — the resumed dispatcher grants
            // fresh lease ids for whatever the journal did not cover.
            for (w, c) in self.conns.iter().enumerate() {
                if c.alive {
                    tl.worker_gone(w as u64, self.now);
                }
            }
        }
        // Preserved run files outlive this drop; buffered cells die here,
        // exactly like the real process's heap.
        self.merger = None;
        self.journal = None;
        self.reconnect_slots =
            self.conns.iter().filter(|c| c.alive).map(|c| c.slot).collect();
        for c in self.conns.iter_mut() {
            c.alive = false;
            c.gone = true;
            c.holding = false;
        }
        self.schedule(self.now + restart_after, Ev::DispatcherRestart);
    }

    /// The restarted dispatcher: recover the journal, rebuild the core
    /// from the received bitmap, re-admit the committed runs — the exact
    /// code path behind `zygarde serve --resume`, nothing simulated.
    fn on_dispatcher_restart(&mut self) {
        let path = self.journal_path.clone().expect("dcrash campaigns always journal");
        let fail = |s: &mut Sim, e: String| {
            s.merge_err = Some(e);
            s.done = true;
        };
        let rec = match recover_journal(&path) {
            Ok(r) => r,
            Err(e) => return fail(self, e),
        };
        if let Err(e) = rec.verify_matches(&self.fp, &Value::Null, &path) {
            return fail(self, e);
        }
        if let Some(tl) = self.timeline.as_mut() {
            tl.journal_recovered(
                self.now,
                rec.intact_len,
                rec.torn_bytes,
                rec.runs.len(),
                rec.n_received,
            );
        }
        let mut merger = match SpillMerger::new(self.spill_dir.clone(), self.spill_cells) {
            Ok(m) => m,
            Err(e) => return fail(self, e),
        };
        merger.set_preserve(true);
        for run in &rec.runs {
            if let Err(e) = merger.adopt_run(run) {
                return fail(self, e);
            }
            if let Some(tl) = self.timeline.as_mut() {
                tl.journal_run_adopted(self.now, run.cells);
            }
        }
        let journal = match Journal::resume(&path, &rec) {
            Ok(j) => j,
            Err(e) => return fail(self, e),
        };
        self.core = DispatcherCore::resume(
            &self.matrix_name,
            Value::Null,
            self.fp.clone(),
            self.lease_size,
            self.lease_timeout_ms,
            rec.received.clone(),
        );
        self.merger = Some(merger);
        self.journal = Some(journal);
        self.dispatcher_down = false;
        self.last_progress_ms = self.now;
        let line = format!(
            "t={} dispatcher resumed {}/{} cells from {} journaled run(s)",
            self.now,
            rec.n_received,
            self.n,
            rec.runs.len()
        );
        self.note(line);
        if self.core.is_done() {
            // Every cell was durably spilled before the crash: the resumed
            // serve goes straight to finalize, no workers needed.
            self.done = true;
            let line = format!("t={} done (journal already complete)", self.now);
            self.note(line);
            return;
        }
        // The crashed-out workers reconnect with the same stagger the
        // campaign opened with (the `work --retry` backoff analogue),
        // getting fresh WorkerIds like any new connection.
        let slots = std::mem::take(&mut self.reconnect_slots);
        for slot in slots {
            self.pending_connects += 1;
            let delay = 1 + (slot as u64 % 5);
            self.schedule(self.now + delay, Ev::Connect { slot });
        }
    }

    fn on_connect_event(&mut self, slot: usize) {
        if self.dispatcher_down {
            // Connection refused; the worker backs off and retries —
            // `pending_connects` stays claimed so relief logic holds off.
            self.schedule(self.now + 10, Ev::Connect { slot });
            return;
        }
        self.pending_connects = self.pending_connects.saturating_sub(1);
        let w = self.conns.len();
        while self.slot_factor.len() <= slot {
            self.slot_factor.push(1);
        }
        self.conns.push(Conn { slot, alive: true, gone: false, holding: false });
        let line = format!("t={} connect w{w} slot{slot}", self.now);
        self.note(line);
        if let Some(tl) = self.timeline.as_mut() {
            tl.worker_connected(w as u64, self.now);
        }
        let outs = self.core.on_connect(w);
        self.apply("connect", outs);
    }

    /// The in-process worker model: matrix → Ready, lease → seeded
    /// compute schedule of Cells batches then LeaseDone, shutdown → die.
    fn worker_receive(&mut self, w: WorkerId, msg: Msg) {
        if !self.conns[w].alive {
            return;
        }
        self.net.delivered += 1;
        match msg {
            Msg::Matrix { .. } => {
                // Cells are precomputed per campaign, so the "rebuild"
                // is a small seeded think time before the Ready ack.
                let delay = 1 + self.rng.below(3);
                let fp = self.fp.clone();
                self.schedule(
                    self.now + delay,
                    Ev::Emit { w, msg: Msg::Ready { fingerprint: fp } },
                );
            }
            Msg::Lease { id, start, end } => {
                let mut t = self.now;
                let mut at = start;
                while at < end {
                    let stop = (at + 1 + self.rng.below(4) as usize).min(end);
                    for _ in at..stop {
                        t += 1 + self.rng.below(3);
                    }
                    let cells = self.cells[at..stop].to_vec();
                    self.schedule(t, Ev::Emit { w, msg: Msg::Cells { lease: id, cells } });
                    at = stop;
                }
                self.schedule(t + 1, Ev::Emit { w, msg: Msg::LeaseDone { lease: id } });
            }
            Msg::Shutdown | Msg::Error { .. } => {
                self.kill_conn(w);
            }
            // Worker-bound streams never carry these; a duplicated
            // delivery of one is simply ignored.
            Msg::Ready { .. } | Msg::Cells { .. } | Msg::LeaseDone { .. } => {}
        }
    }

    fn dispatcher_receive(&mut self, w: WorkerId, msg: Msg) {
        if self.conns[w].gone {
            // The transport already processed this connection's EOF;
            // stragglers never reach the core — same as a closed socket.
            return;
        }
        self.net.delivered += 1;
        if let Msg::LeaseDone { .. } = msg {
            self.conns[w].holding = false;
        }
        if let Some(tl) = self.timeline.as_mut() {
            // Keyed by lease id, so a batch for a reissued-away or
            // unknown lease is a no-op on the open-span map — the
            // timeline never invents spans the dispatcher refused.
            match &msg {
                Msg::Cells { lease, cells } => {
                    tl.lease_cells(*lease, cells.len() as u64, self.now);
                }
                Msg::LeaseDone { lease } => {
                    tl.lease_closed(*lease, self.now, "done");
                }
                _ => {}
            }
        }
        let tag = format!("w{w} {}", fmt_msg(&msg));
        let now = self.now;
        let outs = self.core.on_message(w, msg, now);
        self.apply(&tag, outs);
    }

    fn on_gone_event(&mut self, w: WorkerId) {
        if self.conns[w].gone {
            return;
        }
        self.conns[w].gone = true;
        self.conns[w].alive = false;
        self.conns[w].holding = false;
        if let Some(tl) = self.timeline.as_mut() {
            tl.worker_gone(w as u64, self.now);
        }
        let now = self.now;
        let outs = self.core.on_disconnect(w, now);
        let line = format!("t={} gone w{w} reissues={}", self.now, self.core.stats.reissues);
        self.note(line);
        self.apply("gone", outs);
    }

    fn on_tick_event(&mut self) {
        let now = self.now;
        if self.dispatcher_down {
            // No process, no maintenance and no relief — just keep the
            // clock alive until the restart event fires.
            self.schedule(now + self.tick_ms, Ev::Tick);
            return;
        }
        let outs = self.core.on_tick(now);
        self.apply("tick", outs);
        if self.done {
            return;
        }
        // Stall relief: every connection dead with none pending (e.g. a
        // kick storm before the heal point), or no effect applied for a
        // long virtual while — connect a fresh worker on a fresh slot
        // (outside every partition range and slow link). This is what
        // makes convergence unconditional.
        let alive = self.conns.iter().filter(|c| c.alive).count();
        let stalled = now.saturating_sub(self.last_progress_ms) >= RELIEF_AFTER_MS;
        if (alive == 0 && self.pending_connects == 0) || stalled {
            let slot = self.next_slot;
            self.next_slot += 1;
            self.net.relief_spawns += 1;
            self.pending_connects += 1;
            self.last_progress_ms = now;
            let line = format!("t={} relief slot{slot}", self.now);
            self.note(line);
            if let Some(tl) = self.timeline.as_mut() {
                tl.fault("relief", now, &format!("slot{slot}"));
            }
            self.schedule(now + 1, Ev::Connect { slot });
        }
        self.schedule(now + self.tick_ms, Ev::Tick);
    }

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::Connect { slot } => self.on_connect_event(slot),
            Ev::ToWorker { w, msg } => self.worker_receive(w, msg),
            Ev::ToDispatcher { w, msg } => self.dispatcher_receive(w, msg),
            Ev::Emit { w, msg } => {
                // Composition was cancelled if the worker died meanwhile.
                if self.conns[w].alive {
                    self.transmit(w, true, msg);
                }
            }
            Ev::Gone { w } => self.on_gone_event(w),
            Ev::DispatcherRestart => self.on_dispatcher_restart(),
            Ev::PartitionEnd { idx } => {
                self.partition_active[idx] = false;
                let line = format!("t={} partition#{idx} healed", self.now);
                self.note(line);
                if let Some(tl) = self.timeline.as_mut() {
                    tl.fault("heal", self.now, &format!("partition#{idx}"));
                }
            }
            Ev::Tick => self.on_tick_event(),
        }
    }

    fn run(&mut self) -> Result<(), String> {
        while !self.done {
            let Some(sc) = self.heap.pop() else {
                return Err(format!(
                    "simnet seed {}: event queue drained with {}/{} cells ingested",
                    self.plan.seed,
                    self.core.cells_received(),
                    self.n
                ));
            };
            self.now = sc.t;
            self.events += 1;
            if self.now > HORIZON_MS {
                return Err(format!(
                    "simnet seed {}: virtual horizon {HORIZON_MS} ms exceeded with {}/{} \
                     cells ingested",
                    self.plan.seed,
                    self.core.cells_received(),
                    self.n
                ));
            }
            self.dispatch(sc.ev);
        }
        match self.merge_err.take() {
            Some(e) => Err(format!("simnet seed {}: merge failed: {e}", self.plan.seed)),
            None => Ok(()),
        }
    }
}

fn fmt_msg(msg: &Msg) -> String {
    match msg {
        Msg::Matrix { .. } => "matrix".to_string(),
        Msg::Lease { id, start, end } => format!("lease{id}[{start}..{end})"),
        Msg::Shutdown => "shutdown".to_string(),
        Msg::Ready { .. } => "ready".to_string(),
        Msg::Cells { lease, cells } => format!("cells lease{lease} n={}", cells.len()),
        Msg::LeaseDone { lease } => format!("lease_done lease{lease}"),
        Msg::Error { .. } => "error".to_string(),
    }
}

fn fmt_outs(outs: &[Out]) -> String {
    let mut sends: Vec<String> = Vec::new();
    let mut ingests = 0usize;
    let mut kicks = 0usize;
    let mut done = false;
    for o in outs {
        match o {
            Out::Send(w, m) => sends.push(format!("w{w}:{}", fmt_msg(m))),
            Out::Ingest(_) => ingests += 1,
            Out::Kick(_) => kicks += 1,
            Out::Done => done = true,
        }
    }
    let mut parts = Vec::new();
    if !sends.is_empty() {
        parts.push(sends.join(" "));
    }
    if ingests > 0 {
        parts.push(format!("ingest={ingests}"));
    }
    if kicks > 0 {
        parts.push(format!("kick={kicks}"));
    }
    if done {
        parts.push("done".to_string());
    }
    parts.join(" | ")
}

/// FNV-1a over the log lines (newline-folded): the compact fingerprint
/// of the dispatcher event schedule.
pub fn log_fingerprint(lines: &[String]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u8| {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    };
    for line in lines {
        for &b in line.as_bytes() {
            eat(b);
        }
        eat(b'\n');
    }
    h
}

/// Run one simulated campaign of `matrix` under `cfg` and check the
/// streamed report against the single-process reference. See module
/// docs; errors always embed the seed.
pub fn run_campaign(matrix: &ScenarioMatrix, cfg: &SimConfig) -> Result<SimOutcome, String> {
    let workers = cfg.workers.max(1);
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    // The single-process reference run doubles as the cell store: the
    // simulated workers replay these cells (determinism makes recompute
    // and replay indistinguishable), so a 200-worker fault campaign
    // costs one sweep plus bookkeeping.
    let reference = run_matrix(matrix, threads);
    let want = reference.json_string();
    let cells = reference.cells;
    let n = cells.len();
    let plan = FaultPlan::from_seed(cfg.seed, workers, &cfg.spec);
    let lease_size =
        if cfg.lease_size > 0 { cfg.lease_size } else { (n / (workers * 4)).clamp(1, 32) };
    let fp = fingerprint(matrix);
    let core = DispatcherCore::new(
        &matrix.name,
        // Simulated workers never rebuild the matrix from the registry,
        // so no options ship over the simulated wire.
        Value::Null,
        fp.clone(),
        lease_size,
        cfg.lease_timeout_ms.max(1),
    );
    let serial = RUN_SERIAL.fetch_add(1, AtomicOrdering::Relaxed);
    let spill_dir = std::env::temp_dir().join(format!(
        "zygarde_simnet_{}_{}_{serial}",
        std::process::id(),
        cfg.seed
    ));
    let mut merger = SpillMerger::new(spill_dir.clone(), cfg.spill_cells.max(1))?;
    // Campaigns with planned dispatcher crashes run the real journal:
    // preserved spill runs plus a write-ahead log inside the (per-run)
    // spill dir, all removed together after finalize.
    let journal_path =
        (!plan.dcrashes.is_empty()).then(|| spill_dir.join("journal.wal"));
    let journal = match &journal_path {
        Some(p) => {
            merger.set_preserve(true);
            match Journal::create(p, &fp, &Value::Null) {
                Ok(j) => Some(j),
                Err(e) => {
                    drop(merger);
                    let _ = std::fs::remove_dir_all(&spill_dir);
                    return Err(format!("simnet seed {}: {e}", cfg.seed));
                }
            }
        }
        None => None,
    };
    let heal_cells = (n * plan.heal_permille as usize).div_euclid(1000);
    let crash_at: Vec<usize> =
        plan.crashes.iter().map(|c| (n * c.at_permille as usize / 1000).max(1)).collect();
    let partition_at: Vec<usize> =
        plan.partitions.iter().map(|p| (n * p.at_permille as usize / 1000).max(1)).collect();
    let dcrash_at: Vec<usize> =
        plan.dcrashes.iter().map(|c| (n * c.at_permille as usize / 1000).max(1)).collect();
    let mut slot_factor = vec![1u64; workers];
    for &(slot, factor) in &plan.slow_links {
        slot_factor[slot] = factor;
    }
    let n_partitions = plan.partitions.len();
    let mut sim = Sim {
        plan,
        fp,
        cells,
        n,
        tick_ms: cfg.tick_ms.max(1),
        collect_log: cfg.collect_log,
        core,
        merger: Some(merger),
        journal,
        journal_path,
        matrix_name: matrix.name.clone(),
        spill_dir: spill_dir.clone(),
        spill_cells: cfg.spill_cells.max(1),
        lease_size,
        lease_timeout_ms: cfg.lease_timeout_ms.max(1),
        heap: BinaryHeap::new(),
        seq: 0,
        now: 0,
        rng: Pcg32::new(cfg.seed, NET_STREAM),
        conns: Vec::new(),
        slot_factor,
        next_slot: workers,
        partition_active: vec![false; n_partitions],
        crash_cursor: 0,
        partition_cursor: 0,
        dcrash_cursor: 0,
        crash_at,
        partition_at,
        dcrash_at,
        dispatcher_down: false,
        reconnect_slots: Vec::new(),
        heal_cells,
        pending_connects: 0,
        done: false,
        merge_err: None,
        log: Vec::new(),
        net: NetCounters::default(),
        last_progress_ms: 0,
        events: 0,
        timeline: cfg
            .trace
            .then(|| Timeline::new(&format!("simnet seed {} {}", cfg.seed, matrix.name))),
    };
    // Stagger the initial connects a little so hundreds of workers do
    // not handshake on the same virtual instant.
    for slot in 0..workers {
        sim.pending_connects += 1;
        sim.schedule(1 + (slot as u64 % 5), Ev::Connect { slot });
    }
    sim.schedule(sim.tick_ms, Ev::Tick);
    if let Err(e) = sim.run() {
        let _ = std::fs::remove_dir_all(&spill_dir);
        return Err(e);
    }
    let merger = sim.merger.take().expect("merger present at finalize");
    let mut report: Vec<u8> = Vec::with_capacity(want.len());
    let finalize = merger.finalize(&matrix.name, matrix.seed, n, &mut report);
    if finalize.is_ok() {
        // Keep the record sequence faithful to the serve shell (spent
        // journals end in a finalize marker) even though the whole spill
        // dir — journal included — is removed right below.
        if let Some(j) = sim.journal.as_mut() {
            let _ = j.append_finalize(n);
            if let Some(tl) = sim.timeline.as_mut() {
                tl.journal_finalized(sim.now, n);
            }
        }
    }
    let _ = std::fs::remove_dir_all(&spill_dir);
    finalize.map_err(|e| format!("simnet seed {}: finalize failed: {e}", cfg.seed))?;
    let matches = report == want.as_bytes();
    let log_hash = log_fingerprint(&sim.log);
    let virtual_ms = sim.now;
    let timeline = sim.timeline.take().map(|tl| tl.finish(virtual_ms));
    Ok(SimOutcome {
        matches,
        report,
        reference: want,
        log: std::mem::take(&mut sim.log),
        log_hash,
        virtual_ms,
        events: sim.events,
        stats: sim.core.stats.clone(),
        net: sim.net,
        plan: sim.plan,
        workers_spawned: sim.conns.len(),
        timeline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_pops_by_time_then_sequence() {
        let mut heap = BinaryHeap::new();
        heap.push(Scheduled { t: 9, seq: 0, ev: Ev::Tick });
        heap.push(Scheduled { t: 3, seq: 2, ev: Ev::Tick });
        heap.push(Scheduled { t: 3, seq: 1, ev: Ev::Tick });
        heap.push(Scheduled { t: 0, seq: 3, ev: Ev::Tick });
        let order: Vec<(u64, u64)> = std::iter::from_fn(|| heap.pop())
            .map(|s| (s.t, s.seq))
            .collect();
        assert_eq!(order, vec![(0, 3), (3, 1), (3, 2), (9, 0)]);
    }

    #[test]
    fn log_fingerprint_is_stable_and_line_sensitive() {
        let a = vec!["t=1 connect w0 slot0".to_string(), "t=2 done".to_string()];
        let b = a.clone();
        assert_eq!(log_fingerprint(&a), log_fingerprint(&b));
        let mut c = a.clone();
        c[1] = "t=3 done".to_string();
        assert_ne!(log_fingerprint(&a), log_fingerprint(&c));
        // Folding must distinguish line boundaries from concatenation.
        let joined = vec![format!("{}\n{}", a[0], a[1])];
        assert_ne!(log_fingerprint(&a), log_fingerprint(&joined));
    }
}
