//! Seeded fault plans: what the simulated network will do to a campaign.
//!
//! A [`FaultPlan`] is a pure function of `(seed, workers, spec)` — no
//! wall time, no global state — so the same seed always yields the same
//! schedule (same seed → same run: the property suite asserts plan
//! equality and the harness asserts byte/log equality on top).
//!
//! Fault *triggers* are expressed in **progress permille** (cells
//! ingested out of the matrix total), not virtual milliseconds: a crash
//! at 300‰ fires mid-campaign whatever the matrix size or worker count,
//! which is what makes the "crash mid-lease" CI criterion deterministic
//! instead of a timing lottery. Durations (restart delay, partition
//! window) stay in virtual milliseconds.
//!
//! The spec grammar (CLI `--faults`, seed-corpus `faults=` field) is a
//! comma-separated `key=value` list; any key left out is derived from
//! the seed:
//!
//! ```text
//! latency=LO..HI   per-message delivery latency range, virtual ms
//! drop=P           P(message silently dropped)        [clamped to 0.4]
//! dup=P            P(message delivered twice)         [clamped to 0.5]
//! reorder=P        P(message gets extra latency, overtaking later ones)
//! crash=N          worker crashes (victim chosen mid-lease, restarts)
//! partition=N      link partitions (a slot range goes dark for a while)
//! slow=N           slow links (a slot's latency multiplied 2–8x)
//! heal=PERMILLE    progress point after which the network behaves
//! dcrash=N         dispatcher crashes (recovered through the real
//!                  `--journal`/`--resume` code path; defaults to 0)
//! none             shorthand for a clean network (all of the above off)
//! ```

use crate::util::rng::Pcg32;

/// Stream id for the plan-derivation RNG — distinct from every other
/// Pcg32 stream in the crate so plan draws never correlate with
/// scenario or transport draws for the same seed.
const PLAN_STREAM: u64 = 0x51A7_E7_FA_17;

/// Parsed `--faults` overrides; `None` fields are derived from the seed.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub latency: Option<(u64, u64)>,
    pub drop: Option<f64>,
    pub dup: Option<f64>,
    pub reorder: Option<f64>,
    pub crashes: Option<usize>,
    pub partitions: Option<usize>,
    pub slow: Option<usize>,
    pub heal: Option<u32>,
    /// Dispatcher crash+resume cycles. Unlike every other field this
    /// defaults to **0**, not a seeded draw: dispatcher crashes route the
    /// campaign through journal recovery, and the pre-journal seed corpus
    /// must keep replaying byte-identically.
    pub dcrashes: Option<usize>,
}

impl FaultSpec {
    /// A clean network: fixed 1 ms latency, no chaos. The fault-free
    /// cross-check against real pipes uses this.
    pub fn none() -> FaultSpec {
        FaultSpec {
            latency: Some((1, 1)),
            drop: Some(0.0),
            dup: Some(0.0),
            reorder: Some(0.0),
            crashes: Some(0),
            partitions: Some(0),
            slow: Some(0),
            heal: Some(0),
            dcrashes: Some(0),
        }
    }

    /// Parse the comma-separated `key=value` grammar (module docs).
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let s = s.trim();
        if s == "none" {
            return Ok(FaultSpec::none());
        }
        if s.is_empty() {
            return Ok(FaultSpec::default());
        }
        let mut spec = FaultSpec::default();
        for tok in s.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| format!("fault spec: `{tok}` is not key=value"))?;
            let prob = |v: &str| -> Result<f64, String> {
                let p: f64 =
                    v.parse().map_err(|_| format!("fault spec: bad probability `{v}`"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("fault spec: probability `{v}` outside [0, 1]"));
                }
                Ok(p)
            };
            let count = |v: &str| -> Result<usize, String> {
                v.parse().map_err(|_| format!("fault spec: bad count `{v}`"))
            };
            match key {
                "latency" => {
                    let (lo, hi) = match val.split_once("..") {
                        Some((a, b)) => (
                            a.parse()
                                .map_err(|_| format!("fault spec: bad latency `{val}`"))?,
                            b.parse()
                                .map_err(|_| format!("fault spec: bad latency `{val}`"))?,
                        ),
                        None => {
                            let n = val
                                .parse()
                                .map_err(|_| format!("fault spec: bad latency `{val}`"))?;
                            (n, n)
                        }
                    };
                    if lo > hi {
                        return Err(format!("fault spec: latency range `{val}` is inverted"));
                    }
                    spec.latency = Some((lo, hi));
                }
                "drop" => spec.drop = Some(prob(val)?),
                "dup" => spec.dup = Some(prob(val)?),
                "reorder" => spec.reorder = Some(prob(val)?),
                "crash" => spec.crashes = Some(count(val)?),
                "dcrash" => spec.dcrashes = Some(count(val)?),
                "partition" => spec.partitions = Some(count(val)?),
                "slow" => spec.slow = Some(count(val)?),
                "heal" => {
                    let p: u32 =
                        val.parse().map_err(|_| format!("fault spec: bad heal `{val}`"))?;
                    if p > 1000 {
                        return Err(format!("fault spec: heal `{val}` outside 0..=1000"));
                    }
                    spec.heal = Some(p);
                }
                other => {
                    return Err(format!(
                        "fault spec: unknown key `{other}` (known: latency, drop, dup, \
                         reorder, crash, dcrash, partition, slow, heal, none)"
                    ))
                }
            }
        }
        Ok(spec)
    }
}

/// One planned worker crash: when the campaign's ingested-cell count
/// crosses `at_permille` of the matrix, the harness kills a worker that
/// currently holds a live lease (guaranteeing "crash mid-lease"), then
/// reconnects its slot `restart_after_ms` later.
#[derive(Clone, Debug, PartialEq)]
pub struct CrashPlan {
    pub at_permille: u32,
    pub restart_after_ms: u64,
}

/// One planned link partition: slots `lo_slot..hi_slot` lose every
/// message in both directions for `duration_ms` once progress crosses
/// `at_permille`.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionPlan {
    pub at_permille: u32,
    pub duration_ms: u64,
    pub lo_slot: usize,
    pub hi_slot: usize,
}

/// The full seeded schedule the simnet transport executes. Distinct
/// from `sim::sweep::FaultPlan` (per-*scenario* brownouts/clock skew):
/// this one describes the *network between dispatcher and workers*.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Per-message delivery latency, uniform in `lo..=hi` virtual ms.
    pub latency_ms: (u64, u64),
    pub drop_p: f64,
    pub dup_p: f64,
    pub reorder_p: f64,
    /// Sorted by `at_permille`; fired in order as progress crosses.
    pub crashes: Vec<CrashPlan>,
    /// Sorted by `at_permille`.
    pub partitions: Vec<PartitionPlan>,
    /// `(slot, factor)` — the slot's latency is multiplied by `factor`.
    pub slow_links: Vec<(usize, u64)>,
    /// Chaos probabilities only apply while ingested cells are below
    /// this permille of the matrix; past it the network is clean, which
    /// (with lease reissue) guarantees every campaign converges.
    pub heal_permille: u32,
    /// Dispatcher crash+resume cycles, sorted by `at_permille`. The
    /// harness runs each one through the real journal code: drop the
    /// core and merger on the floor, `journal::recover`, resume. Drawn
    /// *after* every legacy field — and only when `dcrash=` is present —
    /// so pre-journal corpus seeds replay byte-identically.
    pub dcrashes: Vec<CrashPlan>,
}

impl FaultPlan {
    /// Derive the plan for a campaign of `workers` initial workers.
    /// Pure: same `(seed, workers, spec)` → equal plan (asserted by the
    /// property suite).
    pub fn from_seed(seed: u64, workers: usize, spec: &FaultSpec) -> FaultPlan {
        let workers = workers.max(1);
        let mut rng = Pcg32::new(seed, PLAN_STREAM);
        let latency_ms = spec.latency.unwrap_or_else(|| {
            let lo = 1 + rng.below(4);
            (lo, lo + 1 + rng.below(24))
        });
        // Clamps keep even hostile specs convergent: a lease that keeps
        // being reissued retries the same probabilities forever, so any
        // drop probability strictly below 1 converges — but capping it
        // keeps the expected retry count (and the event log) small.
        let drop_p = spec.drop.unwrap_or_else(|| rng.f64() * 0.04).min(0.4);
        let dup_p = spec.dup.unwrap_or_else(|| rng.f64() * 0.05).min(0.5);
        let reorder_p = spec.reorder.unwrap_or_else(|| rng.f64() * 0.08).min(0.9);
        let n_crashes = spec.crashes.unwrap_or_else(|| rng.below(3) as usize);
        let mut crashes: Vec<CrashPlan> = (0..n_crashes)
            .map(|_| CrashPlan {
                at_permille: 50 + rng.below(750) as u32,
                restart_after_ms: 10 + rng.below(200),
            })
            .collect();
        crashes.sort_by_key(|c| c.at_permille);
        let n_partitions = spec.partitions.unwrap_or_else(|| rng.below(2) as usize);
        let mut partitions: Vec<PartitionPlan> = (0..n_partitions)
            .map(|_| {
                let lo = rng.below(workers as u64) as usize;
                let len = 1 + rng.below((workers / 4).max(1) as u64) as usize;
                PartitionPlan {
                    at_permille: 50 + rng.below(600) as u32,
                    duration_ms: 50 + rng.below(400),
                    lo_slot: lo,
                    hi_slot: (lo + len).min(workers),
                }
            })
            .collect();
        partitions.sort_by_key(|p| p.at_permille);
        let n_slow = spec.slow.unwrap_or_else(|| rng.below(workers.min(4) as u64 + 1) as usize);
        let slow_links: Vec<(usize, u64)> = (0..n_slow)
            .map(|_| (rng.below(workers as u64) as usize, 2 + rng.below(7)))
            .collect();
        let heal_permille = spec.heal.unwrap_or(850).min(1000);
        // Dispatcher crashes come last in the draw order and the count is
        // never seeded (`unwrap_or(0)`, not a draw): a spec without
        // `dcrash=` consumes exactly the same rng stream as before the
        // feature existed, so the committed seed corpus stays stable.
        let n_dcrashes = spec.dcrashes.unwrap_or(0);
        let mut dcrashes: Vec<CrashPlan> = (0..n_dcrashes)
            .map(|_| CrashPlan {
                at_permille: 50 + rng.below(700) as u32,
                restart_after_ms: 20 + rng.below(200),
            })
            .collect();
        dcrashes.sort_by_key(|c| c.at_permille);
        FaultPlan {
            seed,
            latency_ms,
            drop_p,
            dup_p,
            reorder_p,
            crashes,
            partitions,
            slow_links,
            heal_permille,
            dcrashes,
        }
    }

    /// One-line human summary for `simtest` output and logs.
    pub fn summary(&self) -> String {
        format!(
            "latency {}..{} ms, drop {:.2}%, dup {:.2}%, reorder {:.2}%, crashes {}, \
             dispatcher crashes {}, partitions {}, slow links {}, heal at {}/1000 cells",
            self.latency_ms.0,
            self.latency_ms.1,
            self.drop_p * 100.0,
            self.dup_p * 100.0,
            self.reorder_p * 100.0,
            self.crashes.len(),
            self.dcrashes.len(),
            self.partitions.len(),
            self.slow_links.len(),
            self.heal_permille,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_plan() {
        let spec = FaultSpec::default();
        let a = FaultPlan::from_seed(1234, 50, &spec);
        let b = FaultPlan::from_seed(1234, 50, &spec);
        assert_eq!(a, b);
    }

    #[test]
    fn disjoint_seeds_distinct_plans() {
        let spec = FaultSpec::default();
        let a = FaultPlan::from_seed(1, 50, &spec);
        let b = FaultPlan::from_seed(2, 50, &spec);
        // Latency bounds, probabilities, and fault counts are all drawn
        // fresh; two seeds agreeing on every f64 draw is impossible in
        // practice and a red flag for the stream derivation if it happens.
        assert_ne!(a, b);
    }

    #[test]
    fn schedules_are_sorted_by_trigger() {
        let spec = FaultSpec::parse("crash=5,partition=3").unwrap();
        let plan = FaultPlan::from_seed(99, 32, &spec);
        assert_eq!(plan.crashes.len(), 5);
        assert_eq!(plan.partitions.len(), 3);
        assert!(plan.crashes.windows(2).all(|w| w[0].at_permille <= w[1].at_permille));
        assert!(plan.partitions.windows(2).all(|w| w[0].at_permille <= w[1].at_permille));
        for p in &plan.partitions {
            assert!(p.lo_slot < p.hi_slot && p.hi_slot <= 32);
        }
    }

    #[test]
    fn spec_grammar_parses_and_overrides() {
        let spec =
            FaultSpec::parse("latency=1..20,drop=0.02,dup=0.04,reorder=0.08,crash=3,heal=900")
                .unwrap();
        assert_eq!(spec.latency, Some((1, 20)));
        assert_eq!(spec.drop, Some(0.02));
        assert_eq!(spec.crashes, Some(3));
        assert_eq!(spec.heal, Some(900));
        assert_eq!(spec.partitions, None);
        let plan = FaultPlan::from_seed(7, 16, &spec);
        assert_eq!(plan.latency_ms, (1, 20));
        assert_eq!(plan.drop_p, 0.02);
        assert_eq!(plan.crashes.len(), 3);
        assert_eq!(plan.heal_permille, 900);
    }

    #[test]
    fn spec_none_is_a_clean_network() {
        let spec = FaultSpec::parse("none").unwrap();
        assert_eq!(spec, FaultSpec::none());
        let plan = FaultPlan::from_seed(5, 8, &spec);
        assert_eq!(plan.latency_ms, (1, 1));
        assert_eq!(plan.drop_p, 0.0);
        assert!(plan.crashes.is_empty() && plan.partitions.is_empty());
        assert!(plan.slow_links.is_empty());
        assert!(plan.dcrashes.is_empty());
    }

    #[test]
    fn dcrash_draws_do_not_disturb_legacy_fields() {
        // The whole point of appending the dcrash draws: a spec that only
        // adds `dcrash=` must leave every pre-existing planned fault
        // byte-identical, or the committed seed corpus would shift.
        let base = FaultSpec::parse("crash=2,partition=1").unwrap();
        let with = FaultSpec::parse("crash=2,partition=1,dcrash=3").unwrap();
        let a = FaultPlan::from_seed(0xD15, 64, &base);
        let b = FaultPlan::from_seed(0xD15, 64, &with);
        assert_eq!(a.latency_ms, b.latency_ms);
        assert_eq!(a.drop_p, b.drop_p);
        assert_eq!(a.dup_p, b.dup_p);
        assert_eq!(a.reorder_p, b.reorder_p);
        assert_eq!(a.crashes, b.crashes);
        assert_eq!(a.partitions, b.partitions);
        assert_eq!(a.slow_links, b.slow_links);
        assert!(a.dcrashes.is_empty());
        assert_eq!(b.dcrashes.len(), 3);
        assert!(b.dcrashes.windows(2).all(|w| w[0].at_permille <= w[1].at_permille));
        for c in &b.dcrashes {
            assert!((50..750).contains(&c.at_permille));
            assert!((20..220).contains(&c.restart_after_ms));
        }
        assert!(b.summary().contains("dispatcher crashes 3"), "{}", b.summary());
    }

    #[test]
    fn spec_single_latency_and_empty() {
        assert_eq!(FaultSpec::parse("latency=5").unwrap().latency, Some((5, 5)));
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(FaultSpec::parse("warp=1").is_err());
        assert!(FaultSpec::parse("drop").is_err());
        assert!(FaultSpec::parse("drop=1.5").is_err());
        assert!(FaultSpec::parse("latency=9..2").is_err());
        assert!(FaultSpec::parse("heal=2000").is_err());
        assert!(FaultSpec::parse("crash=-1").is_err());
        assert!(FaultSpec::parse("dcrash=x").is_err());
    }

    #[test]
    fn hostile_probabilities_are_clamped() {
        let spec = FaultSpec::parse("drop=1.0,dup=1.0,reorder=1.0").unwrap();
        let plan = FaultPlan::from_seed(3, 4, &spec);
        assert_eq!(plan.drop_p, 0.4);
        assert_eq!(plan.dup_p, 0.5);
        assert_eq!(plan.reorder_p, 0.9);
    }
}
