//! Deterministic simulated network for the serve layer.
//!
//! `rust/tests/sweep_serve.rs` already drives [`super::DispatcherCore`]
//! through seeded interleavings, and CI kills one real worker process
//! mid-run — but neither explores what a *hostile network* does to a
//! campaign: latency spikes that reorder batches, duplicated delivery,
//! silent drops, link partitions, slow links, and crash/restart cycles,
//! all interleaved. Standing up real sockets for that makes the search
//! slow and the failures unreproducible.
//!
//! This module is the alternative: a single-threaded discrete-event
//! transport over a virtual clock. A `u64` seed derives a [`FaultPlan`]
//! ([`plan`]) and drives every transport decision ([`harness`]), so a
//! campaign of hundreds of workers runs in milliseconds of real time and
//! **the same seed reproduces the same run, byte for byte** — the report
//! out of the real [`super::SpillMerger`] must equal the single-process
//! `SweepReport::json_string()`, and the dispatcher event log hashes to
//! the same fingerprint every rerun.
//!
//! Entry points: `zygarde simtest --seed N` on the CLI, the committed
//! seed corpus in `rust/tests/seeds/serve/` (replayed forever by
//! `rust/tests/sweep_simnet.rs` and the CI `sim-soak` job), and
//! `tools/simnet_soak.py` for random-seed exploration — a failing seed
//! is one line to commit as a permanent regression test.

pub mod harness;
pub mod plan;

pub use harness::{log_fingerprint, run_campaign, NetCounters, SimConfig, SimOutcome};
pub use plan::{CrashPlan, FaultPlan, FaultSpec, PartitionPlan};
