//! The dispatcher's write-ahead journal: crash recovery for `serve`.
//!
//! Zygarde's engine survives brown-outs by committing progress to NVM
//! and rolling back to the last durable checkpoint; this module applies
//! the same commit-then-crash-then-restore discipline to the dispatcher
//! itself. A journaled serve appends one record per durable state
//! change; a killed dispatcher restarted with `--resume` rebuilds the
//! received-index bitmap and re-admits the spilled runs, leases out
//! only the missing indices, and still streams a report byte-identical
//! to the single-process `SweepReport::json_string()`.
//!
//! # Record format
//!
//! The journal is line-delimited text. Each record is one line:
//!
//! ```text
//! <payload-json>#<fnv1a-64 of the payload, 16 lowercase hex digits>\n
//! ```
//!
//! Payloads are `util::json` objects tagged by `"type"`:
//!
//! * `header` — first record, exactly once: the [`MatrixFingerprint`]
//!   plus the sweep opts JSON, pinning *which* campaign this journal
//!   belongs to. Resume refuses a journal whose fingerprint or opts
//!   differ from the command line's matrix.
//! * `range` — a half-open index range `[start, end)` whose cells went
//!   into the spill run committed by the *next* `run` record. Ranges
//!   are **provisional** until that `run` record lands (see below).
//! * `run` — the commit marker for one spilled run: file path, index
//!   span, cell count, and an FNV-1a content hash of the file bytes.
//!   Committing marks every preceding provisional range as received.
//! * `finalize` — the report was fully streamed; the journal is spent
//!   and cannot be resumed.
//!
//! # Torn-tail rule
//!
//! `kill -9` can land mid-write, so recovery **truncates at the first
//! bad checksum** (or missing trailing newline) and resumes from the
//! last intact record. Likewise, provisional `range` records with no
//! committing `run` record behind them are dropped — a run file whose
//! manifest never landed is ignored entirely, so a crash *between*
//! writing a spill file and journaling it can only cause recomputation,
//! never a duplicate index in the merge. Everything else — a record
//! that checksums correctly but is semantically corrupt (overlapping
//! ranges, counts outside the matrix, malformed payload) — fails
//! loudly with the offending record's byte offset: a journal either
//! recovers or errors, it never yields a divergent report.

use std::fs::OpenOptions;
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::sim::sweep::shard::MatrixFingerprint;
use crate::util::json::Value;

/// FNV-1a 64-bit offset basis — the same dependency-free hash the shard
/// fingerprint and the simnet log fingerprint use.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `bytes` into a running FNV-1a state (start from [`FNV_OFFSET`]).
pub fn fnv1a_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// One-shot FNV-1a of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_extend(FNV_OFFSET, bytes)
}

/// One committed spill run, as journaled and as re-admitted on resume.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunRecord {
    /// The run file as the crashed dispatcher wrote it (resume adopts
    /// it in place — spill directories are per-pid, so a restarted
    /// process reads the old dir's runs and spills new ones elsewhere).
    pub path: PathBuf,
    /// Smallest index in the run.
    pub start: usize,
    /// Largest index in the run, plus one.
    pub end: usize,
    /// Lines in the run file. Runs may have interior index gaps (dedup,
    /// interleaved leases), so `cells <= end - start`; the exact indices
    /// are the preceding `range` records.
    pub cells: usize,
    /// FNV-1a over the file's raw bytes.
    pub hash: u64,
}

/// What `recover` rebuilt from an intact journal prefix.
#[derive(Clone, Debug)]
pub struct Recovery {
    pub fingerprint: MatrixFingerprint,
    /// The sweep opts JSON pinned by the header, compared verbatim.
    pub opts: Value,
    /// Per-index "durably spilled" bitmap (length `n_scenarios`).
    pub received: Vec<bool>,
    pub n_received: usize,
    /// Committed runs, in journal order.
    pub runs: Vec<RunRecord>,
    pub finalized: bool,
    /// Byte length of the intact prefix; `Journal::resume` truncates
    /// the file here before appending.
    pub intact_len: u64,
    /// Bytes dropped off the tail (torn write or uncommitted ranges);
    /// 0 means the journal was clean.
    pub torn_bytes: u64,
}

impl Recovery {
    pub fn is_complete(&self) -> bool {
        self.n_received == self.received.len()
    }

    /// Reject a journal that belongs to a different campaign. Byte 0 is
    /// cited because the header record is always the first line.
    pub fn verify_matches(
        &self,
        fp: &MatrixFingerprint,
        opts: &Value,
        path: &Path,
    ) -> Result<(), String> {
        if self.fingerprint != *fp {
            return Err(format!(
                "journal {} at byte 0: fingerprint mismatch: journal pins {:?}, \
                 this serve expands {:?} — mixed binaries or drifted options",
                path.display(),
                self.fingerprint,
                fp
            ));
        }
        if self.opts.to_json() != opts.to_json() {
            return Err(format!(
                "journal {} at byte 0: sweep opts mismatch: journal pins {}, \
                 this serve was given {}",
                path.display(),
                self.opts.to_json(),
                opts.to_json()
            ));
        }
        Ok(())
    }
}

/// Append handle over a journal file. Every append is checksummed and
/// flushed to the OS before it returns, so a `kill -9` at any instant
/// leaves at worst one torn record at the tail.
pub struct Journal {
    file: std::fs::File,
    path: PathBuf,
}

fn io_err(path: &Path, e: std::io::Error) -> String {
    format!("journal {}: {e}", path.display())
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl Journal {
    /// Start a fresh journal: refuses to clobber an existing file (it
    /// may be a resumable crash artifact — `--resume` it or delete it).
    pub fn create(
        path: &Path,
        fp: &MatrixFingerprint,
        opts: &Value,
    ) -> Result<Journal, String> {
        let file = OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(path)
            .map_err(|e| {
                format!(
                    "journal {}: {e} (an existing journal is never overwritten — \
                     resume it with --resume or remove it first)",
                    path.display()
                )
            })?;
        let mut j = Journal { file, path: path.to_path_buf() };
        j.append(&obj(vec![
            ("fingerprint", fp.to_json()),
            ("opts", opts.clone()),
            ("type", Value::Str("header".into())),
        ]))?;
        Ok(j)
    }

    /// Reopen a recovered journal for appending: truncates the torn /
    /// uncommitted tail to `rec.intact_len`, then appends continue.
    pub fn resume(path: &Path, rec: &Recovery) -> Result<Journal, String> {
        let file = OpenOptions::new()
            .write(true)
            .open(path)
            .map_err(|e| io_err(path, e))?;
        file.set_len(rec.intact_len).map_err(|e| io_err(path, e))?;
        let mut j = Journal { file, path: path.to_path_buf() };
        j.file.seek(SeekFrom::End(0)).map_err(|e| io_err(path, e))?;
        Ok(j)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn append(&mut self, payload: &Value) -> Result<(), String> {
        let body = payload.to_json();
        let line = format!("{body}#{:016x}\n", fnv1a(body.as_bytes()));
        self.file
            .write_all(line.as_bytes())
            .map_err(|e| io_err(&self.path, e))?;
        self.file.sync_data().map_err(|e| io_err(&self.path, e))
    }

    /// Journal one provisional received range (committed by the next
    /// [`Journal::append_run`]).
    pub fn append_range(&mut self, start: usize, end: usize) -> Result<(), String> {
        self.append(&obj(vec![
            ("end", Value::Num(end as f64)),
            ("start", Value::Num(start as f64)),
            ("type", Value::Str("range".into())),
        ]))
    }

    /// Journal one run manifest — the commit marker for every range
    /// record appended since the previous run record.
    pub fn append_run(&mut self, run: &RunRecord) -> Result<(), String> {
        self.append(&obj(vec![
            ("cells", Value::Num(run.cells as f64)),
            ("end", Value::Num(run.end as f64)),
            ("hash", Value::Str(format!("{:016x}", run.hash))),
            ("path", Value::Str(run.path.display().to_string())),
            ("start", Value::Num(run.start as f64)),
            ("type", Value::Str("run".into())),
        ]))
    }

    /// One committed spill, atomically enough for `kill -9`: the exact
    /// index ranges first, then the run manifest that commits them.
    pub fn append_spill(
        &mut self,
        ranges: &[(usize, usize)],
        run: &RunRecord,
    ) -> Result<(), String> {
        for &(s, e) in ranges {
            self.append_range(s, e)?;
        }
        self.append_run(run)
    }

    /// Mark the report fully streamed; the journal can no longer resume.
    pub fn append_finalize(&mut self, received: usize) -> Result<(), String> {
        self.append(&obj(vec![
            ("received", Value::Num(received as f64)),
            ("type", Value::Str("finalize".into())),
        ]))
    }
}

/// Split one line into its checksummed payload; `None` = torn record.
fn checksummed_payload(line: &[u8]) -> Option<&[u8]> {
    if line.len() < 18 {
        return None;
    }
    let (payload, tail) = line.split_at(line.len() - 17);
    if tail[0] != b'#' {
        return None;
    }
    let hex = std::str::from_utf8(&tail[1..]).ok()?;
    let want = u64::from_str_radix(hex, 16).ok()?;
    (fnv1a(payload) == want).then_some(payload)
}

/// Mirror of `CellResult::from_json`'s index hardening: a count field
/// must be a non-negative exact integer within the matrix.
fn exact_usize(v: &Value, what: &str, at: &str) -> Result<usize, String> {
    let raw = v
        .as_f64()
        .ok_or_else(|| format!("{at}: `{what}` is not a number"))?;
    if !raw.is_finite() || raw < 0.0 || raw.fract() != 0.0 || raw > (1u64 << 53) as f64 {
        return Err(format!(
            "{at}: `{what}` {raw} is not a non-negative exact integer"
        ));
    }
    Ok(raw as usize)
}

/// Read and validate a journal: torn tails (bad checksum, missing
/// newline, uncommitted ranges) are tolerated by truncation; semantic
/// corruption in an intact record fails loudly with its byte offset.
pub fn recover(path: &Path) -> Result<Recovery, String> {
    let bytes = std::fs::read(path).map_err(|e| io_err(path, e))?;
    let mut off = 0usize;
    let mut intact = 0usize;
    let mut rec: Option<Recovery> = None;
    // Provisional ranges since the last committing run record.
    let mut pending: Vec<(usize, usize)> = Vec::new();
    while off < bytes.len() {
        let at = format!("journal {} at byte {off}", path.display());
        let Some(rel_nl) = bytes[off..].iter().position(|&b| b == b'\n') else {
            break; // torn tail: record never got its newline
        };
        let line = &bytes[off..off + rel_nl];
        let next = off + rel_nl + 1;
        let Some(payload) = checksummed_payload(line) else {
            break; // torn tail: bad checksum — truncate here
        };
        let text = std::str::from_utf8(payload)
            .map_err(|_| format!("{at}: record is not UTF-8"))?;
        let v = Value::parse(text).map_err(|e| format!("{at}: {e}"))?;
        let kind = v
            .get("type")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("{at}: record has no `type`"))?
            .to_string();
        match (kind.as_str(), rec.as_mut()) {
            ("header", None) => {
                let fp = MatrixFingerprint::from_json(
                    v.get("fingerprint")
                        .ok_or_else(|| format!("{at}: header has no `fingerprint`"))?,
                )
                .map_err(|e| format!("{at}: {e}"))?;
                if fp.n_scenarios == 0 {
                    return Err(format!("{at}: header pins an empty matrix"));
                }
                let opts = v
                    .get("opts")
                    .ok_or_else(|| format!("{at}: header has no `opts`"))?
                    .clone();
                let n = fp.n_scenarios;
                rec = Some(Recovery {
                    fingerprint: fp,
                    opts,
                    received: vec![false; n],
                    n_received: 0,
                    runs: Vec::new(),
                    finalized: false,
                    intact_len: 0,
                    torn_bytes: 0,
                });
                intact = next;
            }
            ("header", Some(_)) => {
                return Err(format!("{at}: second header record"));
            }
            (_, None) => {
                return Err(format!(
                    "{at}: first record is `{kind}`, expected `header`"
                ));
            }
            ("range", Some(r)) => {
                if r.finalized {
                    return Err(format!("{at}: record after finalize"));
                }
                let start = exact_usize(v.req("start"), "start", &at)?;
                let end = exact_usize(v.req("end"), "end", &at)?;
                let n = r.received.len();
                if start >= end || end > n {
                    return Err(format!(
                        "{at}: range {start}..{end} outside the {n}-cell matrix"
                    ));
                }
                for i in start..end {
                    if r.received[i] || pending.iter().any(|&(s, e)| s <= i && i < e) {
                        return Err(format!(
                            "{at}: range {start}..{end} duplicates/overlaps index {i} \
                             already journaled as received"
                        ));
                    }
                }
                pending.push((start, end));
                // Provisional: `intact` only advances when a run record
                // commits this group (torn-tail rule in module docs).
            }
            ("run", Some(r)) => {
                if r.finalized {
                    return Err(format!("{at}: record after finalize"));
                }
                if pending.is_empty() {
                    return Err(format!(
                        "{at}: run manifest with no preceding range records"
                    ));
                }
                let start = exact_usize(v.req("start"), "start", &at)?;
                let end = exact_usize(v.req("end"), "end", &at)?;
                let cells = exact_usize(v.req("cells"), "cells", &at)?;
                let n = r.received.len();
                if start >= end || end > n {
                    return Err(format!(
                        "{at}: run span {start}..{end} outside the {n}-cell matrix"
                    ));
                }
                if cells == 0 || cells > end - start {
                    return Err(format!(
                        "{at}: run cell count {cells} outside its span {start}..{end}"
                    ));
                }
                let covered: usize = pending.iter().map(|&(s, e)| e - s).sum();
                if covered != cells {
                    return Err(format!(
                        "{at}: run commits {cells} cells but its range records \
                         cover {covered}"
                    ));
                }
                if pending.iter().any(|&(s, e)| s < start || e > end) {
                    return Err(format!(
                        "{at}: a committed range escapes the run span {start}..{end}"
                    ));
                }
                let hash_str = v
                    .get("hash")
                    .and_then(Value::as_str)
                    .ok_or_else(|| format!("{at}: run has no `hash`"))?;
                let hash = u64::from_str_radix(hash_str, 16)
                    .map_err(|_| format!("{at}: bad run hash `{hash_str}`"))?;
                let run_path = v
                    .get("path")
                    .and_then(Value::as_str)
                    .ok_or_else(|| format!("{at}: run has no `path`"))?;
                for &(s, e) in &pending {
                    for i in s..e {
                        r.received[i] = true;
                    }
                    r.n_received += e - s;
                }
                pending.clear();
                r.runs.push(RunRecord {
                    path: PathBuf::from(run_path),
                    start,
                    end,
                    cells,
                    hash,
                });
                intact = next;
            }
            ("finalize", Some(r)) => {
                if r.finalized {
                    return Err(format!("{at}: second finalize record"));
                }
                if !pending.is_empty() {
                    return Err(format!(
                        "{at}: finalize with {} uncommitted range record(s)",
                        pending.len()
                    ));
                }
                let received = exact_usize(v.req("received"), "received", &at)?;
                if received != r.received.len() || r.n_received != r.received.len() {
                    return Err(format!(
                        "{at}: finalize claims {received} cells but the journal \
                         covers {} of {}",
                        r.n_received,
                        r.received.len()
                    ));
                }
                r.finalized = true;
                intact = next;
            }
            (other, Some(_)) => {
                return Err(format!("{at}: unknown record type `{other}`"));
            }
        }
        off = next;
    }
    let mut rec = rec.ok_or_else(|| {
        format!(
            "journal {} at byte 0: no intact header record — not a journal, \
             or torn before the first write completed",
            path.display()
        )
    })?;
    rec.intact_len = intact as u64;
    rec.torn_bytes = bytes.len() as u64 - rec.intact_len;
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(n: usize) -> MatrixFingerprint {
        MatrixFingerprint { name: "jt".into(), seed: 5, n_scenarios: n, axes_hash: 0xabc }
    }

    fn temp(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("zygarde_journal_{tag}_{}.wal", std::process::id()))
    }

    fn fresh(tag: &str, n: usize) -> (PathBuf, Journal) {
        let path = temp(tag);
        let _ = std::fs::remove_file(&path);
        let j = Journal::create(&path, &fp(n), &Value::Null).unwrap();
        (path, j)
    }

    fn run(path: &str, start: usize, end: usize, cells: usize) -> RunRecord {
        RunRecord { path: PathBuf::from(path), start, end, cells, hash: 0x1234 }
    }

    #[test]
    fn roundtrip_header_ranges_runs_finalize() {
        let (path, mut j) = fresh("roundtrip", 10);
        j.append_spill(&[(0, 3), (5, 7)], &run("r0", 0, 7, 5)).unwrap();
        j.append_spill(&[(3, 5), (7, 10)], &run("r1", 3, 10, 5)).unwrap();
        j.append_finalize(10).unwrap();
        let rec = recover(&path).unwrap();
        assert_eq!(rec.fingerprint, fp(10));
        assert_eq!(rec.n_received, 10);
        assert!(rec.is_complete() && rec.finalized);
        assert_eq!(rec.runs.len(), 2);
        assert_eq!(rec.runs[0], run("r0", 0, 7, 5));
        assert_eq!(rec.torn_bytes, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn create_refuses_to_clobber_an_existing_journal() {
        let (path, j) = fresh("clobber", 4);
        drop(j);
        let err = Journal::create(&path, &fp(4), &Value::Null).unwrap_err();
        assert!(err.contains("never overwritten"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_truncates_to_the_last_intact_record() {
        let (path, mut j) = fresh("torn", 8);
        j.append_spill(&[(0, 4)], &run("r0", 0, 4, 4)).unwrap();
        j.append_spill(&[(4, 8)], &run("r1", 4, 8, 4)).unwrap();
        let full = std::fs::read(&path).unwrap();
        let clean = recover(&path).unwrap();
        assert_eq!(clean.n_received, 8);
        // Truncate at every byte: recovery must never error (the header
        // is intact) and must recover a monotone prefix of the state.
        let header_len = full.iter().position(|&b| b == b'\n').unwrap() + 1;
        for cut in header_len..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let rec = recover(&path).unwrap();
            assert!(rec.n_received <= clean.n_received);
            assert!(rec.runs.len() <= clean.runs.len());
            assert!(rec.intact_len <= cut as u64);
            for (i, &got) in rec.received.iter().enumerate() {
                assert!(!got || clean.received[i], "cut={cut} index {i}");
            }
            // Resume truncates to the intact prefix and recovery of the
            // truncated file is byte-stable.
            let j2 = Journal::resume(&path, &rec).unwrap();
            drop(j2);
            let again = recover(&path).unwrap();
            assert_eq!(again.n_received, rec.n_received);
            assert_eq!(again.torn_bytes, 0);
            std::fs::write(&path, &full).unwrap();
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn uncommitted_ranges_are_dropped_not_trusted() {
        let (path, mut j) = fresh("uncommitted", 8);
        j.append_spill(&[(0, 4)], &run("r0", 0, 4, 4)).unwrap();
        j.append_range(4, 8).unwrap(); // crash before the run record
        let rec = recover(&path).unwrap();
        assert_eq!(rec.n_received, 4, "uncommitted range must not count");
        assert!(rec.torn_bytes > 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mid_file_corruption_truncates_at_the_bad_checksum() {
        let (path, mut j) = fresh("midflip", 8);
        j.append_spill(&[(0, 4)], &run("r0", 0, 4, 4)).unwrap();
        j.append_spill(&[(4, 8)], &run("r1", 4, 8, 4)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one payload byte of the second spill's range record.
        let header_end = bytes.iter().position(|&b| b == b'\n').unwrap() + 1;
        let mut line_starts = vec![header_end];
        for (i, &b) in bytes.iter().enumerate().skip(header_end) {
            if b == b'\n' && i + 1 < bytes.len() {
                line_starts.push(i + 1);
            }
        }
        let third = line_starts[2]; // header, range, run, [range], run
        bytes[third + 2] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();
        let rec = recover(&path).unwrap();
        assert_eq!(rec.n_received, 4);
        assert_eq!(rec.runs.len(), 1);
        assert!(rec.torn_bytes > 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn overlapping_ranges_fail_loudly_with_the_byte_offset() {
        let (path, mut j) = fresh("overlap", 8);
        j.append_spill(&[(0, 4)], &run("r0", 0, 4, 4)).unwrap();
        j.append_spill(&[(2, 6)], &run("r1", 2, 6, 4)).unwrap();
        let err = recover(&path).unwrap_err();
        assert!(err.contains("duplicates/overlaps"), "{err}");
        assert!(err.contains("at byte"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn out_of_range_counts_fail_loudly() {
        let (path, mut j) = fresh("oob", 4);
        j.append_spill(&[(0, 9)], &run("r0", 0, 9, 9)).unwrap();
        let err = recover(&path).unwrap_err();
        assert!(err.contains("outside the 4-cell matrix"), "{err}");
        assert!(err.contains("at byte"), "{err}");

        let (path2, mut j2) = fresh("count", 8);
        j2.append_range(0, 4).unwrap();
        j2.append_run(&run("r0", 0, 4, 3)).unwrap(); // count lies
        let err = recover(&path2).unwrap_err();
        assert!(err.contains("commits 3 cells"), "{err}");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&path2);
    }

    #[test]
    fn run_without_ranges_fails_loudly() {
        let (path, mut j) = fresh("norange", 4);
        j.append_run(&run("r0", 0, 4, 4)).unwrap();
        let err = recover(&path).unwrap_err();
        assert!(err.contains("no preceding range records"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fingerprint_and_opts_mismatch_are_rejected() {
        let (path, j) = fresh("fpmm", 4);
        drop(j);
        let rec = recover(&path).unwrap();
        let err = rec.verify_matches(&fp(9), &Value::Null, &path).unwrap_err();
        assert!(err.contains("fingerprint mismatch"), "{err}");
        let err = rec
            .verify_matches(&fp(4), &Value::Str("other".into()), &path)
            .unwrap_err();
        assert!(err.contains("opts mismatch"), "{err}");
        assert!(rec.verify_matches(&fp(4), &Value::Null, &path).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resume_appends_continue_a_recovered_journal() {
        let (path, mut j) = fresh("resumeapp", 8);
        j.append_spill(&[(0, 4)], &run("r0", 0, 4, 4)).unwrap();
        j.append_range(4, 6).unwrap(); // torn group
        drop(j);
        let rec = recover(&path).unwrap();
        let mut j2 = Journal::resume(&path, &rec).unwrap();
        j2.append_spill(&[(4, 8)], &run("r1", 4, 8, 4)).unwrap();
        j2.append_finalize(8).unwrap();
        let done = recover(&path).unwrap();
        assert!(done.finalized && done.is_complete());
        assert_eq!(done.runs.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fnv_is_the_shared_constant_stream() {
        // Pinned: the same bytes must hash identically to the simnet
        // log fingerprint's inline FNV-1a.
        assert_eq!(fnv1a(b""), FNV_OFFSET);
        assert_eq!(fnv1a(b"a"), fnv1a_extend(FNV_OFFSET, b"a"));
        assert_ne!(fnv1a(b"ab"), fnv1a(b"ba"));
    }
}
