//! The streaming dispatcher: lease bookkeeping as a pure state machine.
//!
//! [`DispatcherCore`] owns no sockets, threads, or clocks — it consumes
//! events (`on_connect`, `on_message`, `on_disconnect`, `on_tick`) and
//! returns the [`Out`] effects the transport layer must apply (send a
//! message, ingest an accepted cell, drop a connection, finish). That
//! split is what makes the headline guarantee testable: the property
//! suite (`rust/tests/sweep_serve.rs`) drives the core through arbitrary
//! lease sizes, interleavings, worker deaths, and timeouts with zero
//! real IO and zero timing flakes, and asserts the merged report is
//! byte-identical every time. The IO shell ([`super::service`]) stays a
//! thin, boring loop.
//!
//! # Lease discipline
//!
//! Work is granted as fine-grained half-open index ranges
//! (`lease_size` cells each) popped off a pending queue, one outstanding
//! lease per worker. Three things return work to the queue:
//!
//! * **Death** — a worker's connection drops: the un-received tail of its
//!   leases is requeued (`reissues`).
//! * **Timeout** — a lease shows no progress for `lease_timeout_ms`: the
//!   tail is requeued and the lease marked dead. Late results from the
//!   original worker are still *accepted* (they are byte-identical by
//!   determinism) and deduplicated.
//! * **Stealing** — an idle worker asks for work while the queue is
//!   empty: the largest un-started tail among live leases is split and
//!   the far half re-leased (`steals`). The victim worker is not
//!   interrupted — it may compute the stolen half anyway; whichever copy
//!   arrives first wins, the other counts as `duplicates`.
//!
//! Every accepted cell is recorded in a per-index bitmap, so duplicate
//! and reissued work can never double-ingest, and completion is exact:
//! the sweep is done when every index has arrived, regardless of which
//! lease carried it.

use std::collections::{BTreeMap, VecDeque};

use crate::sim::sweep::report::CellResult;
use crate::sim::sweep::shard::MatrixFingerprint;
use crate::util::json::Value;

use super::protocol::Msg;

/// Transport-assigned connection id.
pub type WorkerId = usize;

/// An effect the transport layer must apply after feeding the core an
/// event. Ordering within the returned batch matters (e.g. an `Error`
/// send precedes its `Kick`).
#[derive(Debug)]
pub enum Out {
    /// Send this message to this worker.
    Send(WorkerId, Msg),
    /// A newly accepted (non-duplicate, in-lease) cell — feed the merger.
    Ingest(CellResult),
    /// Drop the worker's connection (protocol violation or admission
    /// failure; an explanatory `Send` precedes it in the batch).
    Kick(WorkerId),
    /// Every scenario index has been ingested; finalize the merge.
    Done,
}

struct Lease {
    worker: WorkerId,
    /// Dispatcher-clock grant time, for the lease-latency histogram.
    granted_ms: u64,
    start: usize,
    /// Exclusive end as granted. Results in `start..end` are always
    /// acceptable from the lease owner, even past a stolen boundary.
    end: usize,
    /// Watermark: the worker streams cells in ascending index order, so
    /// everything in `start..hwm` has been received from *this* lease.
    hwm: usize,
    /// Stealing may have re-leased `steal_end..end` to someone else; the
    /// un-started tail of this lease is `hwm..steal_end`.
    steal_end: usize,
    last_activity_ms: u64,
    /// Dead leases (worker gone or timed out) still accept late results.
    dead: bool,
    done: bool,
}

impl Lease {
    /// The range a reissue (death/timeout) must put back in the queue.
    fn tail(&self) -> (usize, usize) {
        (self.hwm.max(self.start), self.steal_end)
    }
}

struct WorkerState {
    admitted: bool,
    alive: bool,
    active_leases: usize,
}

/// Number of log2 buckets in the lease-latency histogram: bucket 0 holds
/// 0 ms completions, bucket `b ≥ 1` holds `[2^(b-1), 2^b)` ms, and the
/// last bucket is open-ended (≳ 16 s — a stalled or stolen-from lease).
pub const LATENCY_BUCKETS: usize = 16;

/// Per-worker accounting inside [`DispatchStats`].
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Leases this worker completed with `LeaseDone`.
    pub leases_done: u64,
    /// Raw cells streamed back (duplicates included — the dedup verdict
    /// is a dispatcher-side property, not the worker's fault).
    pub cells: u64,
    /// Sum of grant→`LeaseDone` latencies (dispatcher clock, ms).
    pub lease_ms_sum: u64,
    /// Worst single lease latency (ms).
    pub lease_ms_max: u64,
}

/// Counters/histograms the dispatcher accumulates as pure state-machine
/// data. The IO shell reads them for the stderr heartbeat and serializes
/// them via [`DispatchStats::to_json`] for `--metrics-out`.
#[derive(Clone, Debug, Default)]
pub struct DispatchStats {
    pub leases_granted: u64,
    pub steals: u64,
    pub reissues: u64,
    /// Late-duplicate cells dropped after per-index dedup (reissued or
    /// stolen work arriving from both claimants). A high ratio against
    /// `cells_received` means the lease timeout is too aggressive for the
    /// workers' cell times.
    pub duplicates: u64,
    pub workers_seen: u64,
    /// Every cell received, duplicates included (`cells_received -
    /// duplicates` were ingested into the merge).
    pub cells_received: u64,
    /// Log2-bucketed grant→`LeaseDone` latency histogram
    /// (see [`LATENCY_BUCKETS`]).
    pub lease_latency_hist: [u64; LATENCY_BUCKETS],
    pub per_worker: BTreeMap<WorkerId, WorkerStats>,
}

impl DispatchStats {
    fn latency_bucket(ms: u64) -> usize {
        (64 - ms.leading_zeros() as usize).min(LATENCY_BUCKETS - 1)
    }

    fn record_lease_done(&mut self, w: WorkerId, latency_ms: u64) {
        self.lease_latency_hist[Self::latency_bucket(latency_ms)] += 1;
        let ws = self.per_worker.entry(w).or_default();
        ws.leases_done += 1;
        ws.lease_ms_sum += latency_ms;
        ws.lease_ms_max = ws.lease_ms_max.max(latency_ms);
    }

    /// Fraction of received cells that were late duplicates (0 when
    /// nothing has arrived yet).
    pub fn duplicate_ratio(&self) -> f64 {
        if self.cells_received == 0 {
            0.0
        } else {
            self.duplicates as f64 / self.cells_received as f64
        }
    }

    /// JSON object for `--metrics-out` (field reference in README
    /// § Observability).
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        let num = |m: &mut BTreeMap<String, Value>, k: &str, v: u64| {
            m.insert(k.to_string(), Value::Num(v as f64));
        };
        num(&mut m, "leases_granted", self.leases_granted);
        num(&mut m, "steals", self.steals);
        num(&mut m, "reissues", self.reissues);
        num(&mut m, "duplicates", self.duplicates);
        num(&mut m, "workers_seen", self.workers_seen);
        num(&mut m, "cells_received", self.cells_received);
        m.insert(
            "lease_latency_hist_ms".to_string(),
            Value::Arr(
                self.lease_latency_hist
                    .iter()
                    .map(|&c| Value::Num(c as f64))
                    .collect(),
            ),
        );
        let workers: BTreeMap<String, Value> = self
            .per_worker
            .iter()
            .map(|(w, s)| {
                let mut wm = BTreeMap::new();
                num(&mut wm, "leases_done", s.leases_done);
                num(&mut wm, "cells", s.cells);
                num(&mut wm, "lease_ms_sum", s.lease_ms_sum);
                num(&mut wm, "lease_ms_max", s.lease_ms_max);
                (w.to_string(), Value::Obj(wm))
            })
            .collect();
        m.insert("per_worker".to_string(), Value::Obj(workers));
        Value::Obj(m)
    }

    /// The dispatcher's registry view (`serve.*` metric ids): the same
    /// counters as [`to_json`], folded into a
    /// [`crate::telemetry::registry::Registry`] so the stderr heartbeat,
    /// `--metrics-out`, and `zygarde profile` all share one snapshot
    /// schema. The lease-latency buckets inject whole — the bucketing
    /// rule is identical ([`LATENCY_BUCKETS`] log2 buckets, bucket 0 for
    /// zero) — with the exact total reconstructed from the per-worker
    /// latency sums (every histogram observation also added there).
    ///
    /// [`to_json`]: DispatchStats::to_json
    pub fn to_registry(&self) -> crate::telemetry::registry::Registry {
        use crate::telemetry::registry::{Counter, Hist, HistData, Registry};
        let mut r = Registry::new();
        r.add(Counter::ServeLeasesGranted, self.leases_granted);
        r.add(Counter::ServeSteals, self.steals);
        r.add(Counter::ServeReissues, self.reissues);
        r.add(Counter::ServeDuplicates, self.duplicates);
        r.add(Counter::ServeWorkersSeen, self.workers_seen);
        r.add(Counter::ServeCellsReceived, self.cells_received);
        *r.hist_mut(Hist::ServeLeaseLatencyMs) = HistData {
            buckets: self.lease_latency_hist,
            count: self.lease_latency_hist.iter().sum(),
            total: self.per_worker.values().map(|w| w.lease_ms_sum).sum(),
        };
        r
    }
}

/// The dispatcher state machine. See module docs for the event model.
pub struct DispatcherCore {
    matrix_name: String,
    opts: Value,
    fingerprint: MatrixFingerprint,
    n: usize,
    received: Vec<bool>,
    n_received: usize,
    /// Half-open ranges not currently under any live lease. Ranges may
    /// contain already-received indexes (reissue after partial receipt);
    /// granting trims them against the bitmap.
    pending: VecDeque<(usize, usize)>,
    leases: BTreeMap<u64, Lease>,
    next_lease_id: u64,
    workers: BTreeMap<WorkerId, WorkerState>,
    lease_size: usize,
    lease_timeout_ms: u64,
    done: bool,
    pub stats: DispatchStats,
}

impl DispatcherCore {
    /// `lease_size` is the grant granularity (clamped to ≥ 1);
    /// `lease_timeout_ms` is how long a lease may sit with no progress
    /// before its tail is reissued (0 disables timeouts).
    pub fn new(
        matrix_name: &str,
        opts: Value,
        fingerprint: MatrixFingerprint,
        lease_size: usize,
        lease_timeout_ms: u64,
    ) -> DispatcherCore {
        let n = fingerprint.n_scenarios;
        assert!(n > 0, "cannot serve an empty matrix");
        DispatcherCore {
            matrix_name: matrix_name.to_string(),
            opts,
            fingerprint,
            n,
            received: vec![false; n],
            n_received: 0,
            pending: VecDeque::from(vec![(0, n)]),
            leases: BTreeMap::new(),
            next_lease_id: 0,
            workers: BTreeMap::new(),
            lease_size: lease_size.max(1),
            lease_timeout_ms,
            done: false,
            stats: DispatchStats::default(),
        }
    }

    /// Rebuild a dispatcher from a recovered journal's received bitmap
    /// (`super::journal::recover`): the pending queue holds exactly the
    /// maximal runs of missing indices, so a restarted dispatcher leases
    /// out only what the journal does not already cover. If the bitmap
    /// is complete the core starts `done` and the shell goes straight to
    /// the merge — no workers needed.
    pub fn resume(
        matrix_name: &str,
        opts: Value,
        fingerprint: MatrixFingerprint,
        lease_size: usize,
        lease_timeout_ms: u64,
        received: Vec<bool>,
    ) -> DispatcherCore {
        let n = fingerprint.n_scenarios;
        assert!(n > 0, "cannot serve an empty matrix");
        assert_eq!(received.len(), n, "recovered bitmap does not match the matrix");
        let n_received = received.iter().filter(|&&got| got).count();
        let mut pending = VecDeque::new();
        let mut i = 0;
        while i < n {
            if received[i] {
                i += 1;
                continue;
            }
            let start = i;
            while i < n && !received[i] {
                i += 1;
            }
            pending.push_back((start, i));
        }
        DispatcherCore {
            matrix_name: matrix_name.to_string(),
            opts,
            fingerprint,
            n,
            received,
            n_received,
            pending,
            leases: BTreeMap::new(),
            next_lease_id: 0,
            workers: BTreeMap::new(),
            lease_size: lease_size.max(1),
            lease_timeout_ms,
            done: n_received == n,
            stats: DispatchStats::default(),
        }
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    pub fn cells_received(&self) -> usize {
        self.n_received
    }

    /// Leases currently outstanding (granted, not finished, not dead) —
    /// a heartbeat figure, not part of the state machine's decisions.
    pub fn leases_active(&self) -> usize {
        self.leases.values().filter(|l| !l.dead && !l.done).count()
    }

    /// A connection appeared: open the handshake.
    pub fn on_connect(&mut self, w: WorkerId) -> Vec<Out> {
        self.workers
            .insert(w, WorkerState { admitted: false, alive: true, active_leases: 0 });
        self.stats.workers_seen += 1;
        vec![Out::Send(
            w,
            Msg::Matrix {
                name: self.matrix_name.clone(),
                opts: self.opts.clone(),
                fingerprint: self.fingerprint.clone(),
            },
        )]
    }

    /// A connection dropped (EOF, broken pipe, kill -9): requeue the
    /// un-received tails of its leases.
    pub fn on_disconnect(&mut self, w: WorkerId, _now_ms: u64) -> Vec<Out> {
        self.drop_worker(w);
        Vec::new()
    }

    /// The disconnect bookkeeping: mark the worker gone and requeue its
    /// live leases' tails. Also runs eagerly on every kick — correctness
    /// must not depend on the transport actually managing to close a
    /// violator's socket (a hostile peer can ignore the `Error` and keep
    /// its connection open). Idempotent: dead leases are skipped, so the
    /// transport's eventual real `on_disconnect` is a no-op.
    fn drop_worker(&mut self, w: WorkerId) {
        if let Some(state) = self.workers.get_mut(&w) {
            state.alive = false;
        }
        let mut requeue = Vec::new();
        for lease in self.leases.values_mut() {
            if lease.worker == w && !lease.dead && !lease.done {
                lease.dead = true;
                requeue.push(lease.tail());
            }
        }
        for (s, e) in requeue {
            self.requeue_range(s, e);
        }
    }

    /// Periodic maintenance: expire stalled leases and hand queued work
    /// to idle workers (e.g. after a death requeued a tail).
    pub fn on_tick(&mut self, now_ms: u64) -> Vec<Out> {
        let mut out = Vec::new();
        if self.done {
            return out;
        }
        if self.lease_timeout_ms > 0 {
            let mut requeue = Vec::new();
            for lease in self.leases.values_mut() {
                if !lease.dead
                    && !lease.done
                    && now_ms.saturating_sub(lease.last_activity_ms) >= self.lease_timeout_ms
                {
                    lease.dead = true;
                    requeue.push(lease.tail());
                }
            }
            for (s, e) in requeue {
                self.requeue_range(s, e);
            }
        }
        let idle: Vec<WorkerId> = self
            .workers
            .iter()
            .filter(|(_, s)| s.alive && s.admitted && s.active_leases == 0)
            .map(|(&w, _)| w)
            .collect();
        for w in idle {
            self.grant(w, now_ms, &mut out);
        }
        out
    }

    /// One inbound protocol message. Violations (unknown lease, cells
    /// outside the leased range, admission failure) kick the worker —
    /// its leases requeue via the kick's `on_disconnect`, which the
    /// transport calls when it drops the connection.
    pub fn on_message(&mut self, w: WorkerId, msg: Msg, now_ms: u64) -> Vec<Out> {
        let mut out = Vec::new();
        // Unknown or already-dropped workers are ignored entirely: a
        // kicked violator that keeps its socket open gets no further
        // say. (A *stalled-but-alive* worker's late results are still
        // welcome — its leases may be dead, the worker is not.)
        let alive = self.workers.get(&w).map(|s| s.alive).unwrap_or(false);
        if !alive {
            return out;
        }
        match msg {
            Msg::Ready { fingerprint } => {
                if fingerprint != self.fingerprint {
                    return self.violation(
                        w,
                        format!(
                            "fingerprint mismatch: worker expanded {:?}, dispatcher \
                             serves {:?} — mixed binaries or drifted options",
                            fingerprint, self.fingerprint
                        ),
                    );
                }
                self.workers.get_mut(&w).expect("checked above").admitted = true;
                self.grant(w, now_ms, &mut out);
            }
            Msg::Cells { lease, cells } => {
                let Some(l) = self.leases.get(&lease) else {
                    return self.violation(w, format!("cells for unknown lease {lease}"));
                };
                if l.worker != w {
                    return self.violation(w, format!("cells for someone else's lease {lease}"));
                }
                let (start, end) = (l.start, l.end);
                // The protocol requires a lease's cells to stream as one
                // contiguous ascending run (the worker computes the range
                // in order). Enforcing it keeps the hwm watermark honest:
                // a peer that skipped ahead would otherwise fake a full
                // watermark, and its skipped indexes could never be
                // reissued — a silent permanent hang.
                let mut expect = l.hwm;
                for c in &cells {
                    if c.index < start || c.index >= end {
                        return self.violation(
                            w,
                            format!(
                                "cell index {} outside leased range {start}..{end}",
                                c.index
                            ),
                        );
                    }
                    if c.index != expect {
                        return self.violation(
                            w,
                            format!(
                                "out-of-order cell {} on lease {lease} (expected {expect})",
                                c.index
                            ),
                        );
                    }
                    expect += 1;
                }
                self.stats.cells_received += cells.len() as u64;
                self.stats.per_worker.entry(w).or_default().cells += cells.len() as u64;
                let l = self.leases.get_mut(&lease).expect("checked above");
                l.last_activity_ms = now_ms;
                for c in cells {
                    l.hwm = l.hwm.max(c.index + 1);
                    if self.received[c.index] {
                        self.stats.duplicates += 1;
                        continue;
                    }
                    self.received[c.index] = true;
                    self.n_received += 1;
                    out.push(Out::Ingest(c));
                }
                if !self.done && self.n_received == self.n {
                    self.finish(&mut out);
                }
            }
            Msg::LeaseDone { lease } => {
                let Some(l) = self.leases.get_mut(&lease) else {
                    return self.violation(w, format!("done for unknown lease {lease}"));
                };
                if l.worker != w {
                    return self.violation(w, format!("done for someone else's lease {lease}"));
                }
                if l.done {
                    // A second LeaseDone would decrement active_leases
                    // twice and let one worker hold multiple concurrent
                    // leases — protocol violation, same as the rest.
                    return self.violation(w, format!("lease {lease} finished twice"));
                }
                let was_dead = l.dead;
                l.done = true;
                let latency_ms = now_ms.saturating_sub(l.granted_ms);
                let (tail_start, tail_end) = l.tail();
                self.stats.record_lease_done(w, latency_ms);
                // Free the worker's lease slot even when the lease timed
                // out underneath it (it was merely slow, not dead): the
                // finished worker is immediately eligible for new work.
                if let Some(state) = self.workers.get_mut(&w) {
                    state.active_leases = state.active_leases.saturating_sub(1);
                }
                // A conforming worker streams every cell before its
                // LeaseDone, so the tail is empty here; if a worker
                // skipped cells anyway, requeue them rather than stall.
                // (A dead lease's tail was already requeued at
                // death/timeout time — don't requeue it twice.)
                if !was_dead && tail_start < tail_end {
                    self.requeue_range(tail_start, tail_end);
                }
                if !self.done {
                    self.grant(w, now_ms, &mut out);
                }
            }
            Msg::Error { reason: _ } => {
                // The worker is aborting on its own: do the disconnect
                // bookkeeping now (its leases requeue) instead of waiting
                // for the transport to notice the closed socket.
                self.drop_worker(w);
                out.push(Out::Send(w, Msg::Shutdown));
                out.push(Out::Kick(w));
            }
            Msg::Matrix { .. } | Msg::Lease { .. } | Msg::Shutdown => {
                let reason = "dispatcher-bound stream got a worker-bound message";
                return self.violation(w, reason.into());
            }
        }
        out
    }

    // ---- internals -------------------------------------------------------

    fn violation(&mut self, w: WorkerId, reason: String) -> Vec<Out> {
        self.drop_worker(w);
        vec![Out::Send(w, Msg::Error { reason }), Out::Kick(w)]
    }

    fn finish(&mut self, out: &mut Vec<Out>) {
        self.done = true;
        let alive: Vec<WorkerId> = self
            .workers
            .iter()
            .filter(|(_, s)| s.alive)
            .map(|(&w, _)| w)
            .collect();
        for w in alive {
            out.push(Out::Send(w, Msg::Shutdown));
        }
        out.push(Out::Done);
    }

    /// Put a range back on the queue, trimming received indexes off both
    /// ends (interior holes are handled at grant time / by dedup).
    fn requeue_range(&mut self, mut start: usize, mut end: usize) {
        while start < end && self.received[start] {
            start += 1;
        }
        while end > start && self.received[end - 1] {
            end -= 1;
        }
        if start < end {
            self.stats.reissues += 1;
            self.pending.push_back((start, end));
        }
    }

    /// Pop the next grantable range: at most `lease_size` cells, front
    /// trimmed against the received bitmap.
    fn next_range(&mut self) -> Option<(usize, usize)> {
        while let Some((mut start, end)) = self.pending.pop_front() {
            while start < end && self.received[start] {
                start += 1;
            }
            if start >= end {
                continue;
            }
            let grant_end = end.min(start + self.lease_size);
            if grant_end < end {
                self.pending.push_front((grant_end, end));
            }
            return Some((start, grant_end));
        }
        None
    }

    /// The largest un-started live-lease tail worth splitting: returns
    /// `(lease_id, mid)` where `mid..steal_end` is the half to re-lease.
    fn steal_candidate(&self) -> Option<(u64, usize)> {
        let mut best: Option<(u64, usize, usize)> = None; // (id, tail_start, tail_end)
        for (&id, l) in &self.leases {
            if l.dead || l.done {
                continue;
            }
            let (s, e) = l.tail();
            let len = e.saturating_sub(s);
            if len >= 2 && best.map(|(_, bs, be)| len > be - bs).unwrap_or(true) {
                best = Some((id, s, e));
            }
        }
        best.map(|(id, s, e)| (id, s + (e - s) / 2))
    }

    /// Grant one lease to an idle admitted worker: queued work first,
    /// else steal the far half of the largest outstanding tail.
    fn grant(&mut self, w: WorkerId, now_ms: u64, out: &mut Vec<Out>) {
        if self.done {
            return;
        }
        let ready = self
            .workers
            .get(&w)
            .map(|s| s.alive && s.admitted && s.active_leases == 0)
            .unwrap_or(false);
        if !ready {
            return;
        }
        let range = self.next_range().or_else(|| {
            self.steal_candidate().map(|(victim, mid)| {
                let l = self.leases.get_mut(&victim).expect("candidate exists");
                let end = l.steal_end;
                l.steal_end = mid;
                self.stats.steals += 1;
                (mid, end)
            })
        });
        let Some((start, end)) = range else {
            return;
        };
        let id = self.next_lease_id;
        self.next_lease_id += 1;
        self.leases.insert(
            id,
            Lease {
                worker: w,
                granted_ms: now_ms,
                start,
                end,
                hwm: start,
                steal_end: end,
                last_activity_ms: now_ms,
                dead: false,
                done: false,
            },
        );
        self.workers.get_mut(&w).expect("checked ready").active_leases += 1;
        self.stats.leases_granted += 1;
        out.push(Out::Send(w, Msg::Lease { id, start, end }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::metrics::Metrics;

    fn fp(n: usize) -> MatrixFingerprint {
        MatrixFingerprint { name: "t".into(), seed: 1, n_scenarios: n, axes_hash: 7 }
    }

    fn core(n: usize, lease: usize) -> DispatcherCore {
        DispatcherCore::new("t", Value::Null, fp(n), lease, 1_000)
    }

    fn cell(index: usize) -> CellResult {
        CellResult {
            index,
            label: format!("c{index}"),
            engine_seed: index as u64,
            metrics: Metrics::new(1),
        }
    }

    fn admit(c: &mut DispatcherCore, w: WorkerId) -> Vec<Out> {
        let outs = c.on_connect(w);
        assert!(matches!(outs[..], [Out::Send(_, Msg::Matrix { .. })]));
        c.on_message(w, Msg::Ready { fingerprint: fp(c.n) }, 0)
    }

    fn lease_of(outs: &[Out]) -> (u64, usize, usize) {
        for o in outs {
            if let Out::Send(_, Msg::Lease { id, start, end }) = o {
                return (*id, *start, *end);
            }
        }
        panic!("no lease in {outs:?}");
    }

    #[test]
    fn resumed_core_leases_only_the_gaps() {
        let mut received = vec![false; 10];
        for i in [0, 1, 2, 5, 8] {
            received[i] = true;
        }
        let mut c =
            DispatcherCore::resume("t", Value::Null, fp(10), 64, 1_000, received);
        assert!(!c.is_done());
        assert_eq!(c.cells_received(), 5);
        // Gaps are 3..5, 6..8, 9..10; lease_size 64 grants each maximal
        // gap whole, one lease at a time.
        let mut outs = admit(&mut c, 0);
        let mut got = Vec::new();
        while !c.is_done() {
            let (id, s, e) = lease_of(&outs);
            got.push((s, e));
            let cells: Vec<CellResult> = (s..e).map(cell).collect();
            c.on_message(0, Msg::Cells { lease: id, cells }, 1);
            outs = c.on_message(0, Msg::LeaseDone { lease: id }, 1);
        }
        assert_eq!(got, vec![(3, 5), (6, 8), (9, 10)]);
        assert_eq!(c.stats.cells_received, 5, "no covered cell recomputed");
    }

    #[test]
    fn resumed_core_with_a_complete_bitmap_is_born_done() {
        let c = DispatcherCore::resume("t", Value::Null, fp(4), 8, 0, vec![true; 4]);
        assert!(c.is_done());
        assert_eq!(c.cells_received(), 4);
    }

    #[test]
    fn handshake_grants_a_lease_and_completion_shuts_down() {
        let mut c = core(5, 8);
        let outs = admit(&mut c, 0);
        let (id, start, end) = lease_of(&outs);
        assert_eq!((start, end), (0, 5));
        let outs =
            c.on_message(0, Msg::Cells { lease: id, cells: (0..5).map(cell).collect() }, 1);
        let ingested = outs.iter().filter(|o| matches!(o, Out::Ingest(_))).count();
        assert_eq!(ingested, 5);
        assert!(outs.iter().any(|o| matches!(o, Out::Done)));
        assert!(outs.iter().any(|o| matches!(o, Out::Send(0, Msg::Shutdown))));
        assert!(c.is_done());
    }

    #[test]
    fn wrong_fingerprint_is_kicked_before_any_work() {
        let mut c = core(4, 2);
        c.on_connect(0);
        let outs = c.on_message(0, Msg::Ready { fingerprint: fp(9) }, 0);
        assert!(matches!(outs[..], [Out::Send(0, Msg::Error { .. }), Out::Kick(0)]));
    }

    #[test]
    fn death_requeues_the_unreceived_tail() {
        let mut c = core(6, 6);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        // Worker 0 delivers 2 of 6 cells, then dies.
        c.on_message(0, Msg::Cells { lease: id, cells: vec![cell(0), cell(1)] }, 1);
        c.on_disconnect(0, 2);
        assert_eq!(c.stats.reissues, 1);
        // A fresh worker picks up exactly the tail.
        let outs = admit(&mut c, 1);
        let (_, start, end) = lease_of(&outs);
        assert_eq!((start, end), (2, 6));
    }

    #[test]
    fn timeout_reissues_but_late_results_still_count_once() {
        let mut c = core(4, 4);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        // No progress for longer than the 1000 ms timeout.
        assert!(c.on_tick(2_000).is_empty());
        assert_eq!(c.stats.reissues, 1);
        // Second worker gets the reissued range and finishes half.
        let outs = admit(&mut c, 1);
        let (id2, start, end) = lease_of(&outs);
        assert_eq!((start, end), (0, 4));
        c.on_message(1, Msg::Cells { lease: id2, cells: vec![cell(0), cell(1)] }, 2_100);
        // The stalled worker wakes up and sends everything: 2 dups, 2 new.
        let outs =
            c.on_message(0, Msg::Cells { lease: id, cells: (0..4).map(cell).collect() }, 2_200);
        let ingested = outs.iter().filter(|o| matches!(o, Out::Ingest(_))).count();
        assert_eq!(ingested, 2);
        assert_eq!(c.stats.duplicates, 2);
        assert!(c.is_done());
    }

    #[test]
    fn idle_worker_steals_the_far_half_of_the_biggest_tail() {
        let mut c = core(8, 8);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        // Worker 0 has sent 2/8; worker 1 connects with the queue empty.
        c.on_message(0, Msg::Cells { lease: id, cells: vec![cell(0), cell(1)] }, 1);
        let outs = admit(&mut c, 1);
        let (_, start, end) = lease_of(&outs);
        // Tail is 2..8; far half 5..8 goes to the thief.
        assert_eq!((start, end), (5, 8));
        assert_eq!(c.stats.steals, 1);
        // Both deliver their (overlapping) share; report completes.
        c.on_message(1, Msg::Cells { lease: 1, cells: (5..8).map(cell).collect() }, 2);
        let outs =
            c.on_message(0, Msg::Cells { lease: id, cells: (2..8).map(cell).collect() }, 3);
        assert!(c.is_done());
        assert_eq!(c.stats.duplicates, 3);
        let ingested = outs.iter().filter(|o| matches!(o, Out::Ingest(_))).count();
        assert_eq!(ingested, 3);
    }

    #[test]
    fn out_of_lease_cells_are_a_violation() {
        let mut c = core(8, 4);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        let outs = c.on_message(0, Msg::Cells { lease: id, cells: vec![cell(7)] }, 1);
        assert!(matches!(outs[..], [Out::Send(0, Msg::Error { .. }), Out::Kick(0)]));
    }

    #[test]
    fn out_of_order_cells_are_a_violation_and_the_lease_requeues() {
        let mut c = core(6, 6);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        // Skipping ahead would fake the hwm watermark and strand the
        // skipped indexes forever — it must kick, not be believed.
        let outs = c.on_message(0, Msg::Cells { lease: id, cells: vec![cell(3)] }, 1);
        assert!(matches!(outs[..], [Out::Send(0, Msg::Error { .. }), Out::Kick(0)]));
        // The violator's untouched lease requeues eagerly (no reliance
        // on the transport managing to close the socket)...
        assert_eq!(c.stats.reissues, 1);
        // ...and anything else it says is ignored.
        let late = c.on_message(0, Msg::Cells { lease: id, cells: vec![cell(0)] }, 2);
        assert!(late.is_empty());
        // A fresh worker still covers the whole matrix.
        let outs = admit(&mut c, 1);
        let (_, start, end) = lease_of(&outs);
        assert_eq!((start, end), (0, 6));
    }

    #[test]
    fn stats_count_cells_latency_and_per_worker_shares() {
        let mut c = core(4, 4);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        assert_eq!(c.leases_active(), 1);
        c.on_message(0, Msg::Cells { lease: id, cells: (0..4).map(cell).collect() }, 5);
        c.on_message(0, Msg::LeaseDone { lease: id }, 7);
        assert_eq!(c.stats.cells_received, 4);
        assert_eq!(c.stats.duplicates, 0);
        assert_eq!(c.stats.duplicate_ratio(), 0.0);
        assert_eq!(c.leases_active(), 0);
        let ws = &c.stats.per_worker[&0];
        assert_eq!(ws.cells, 4);
        assert_eq!(ws.leases_done, 1);
        assert_eq!(ws.lease_ms_sum, 7);
        assert_eq!(ws.lease_ms_max, 7);
        // 7 ms lands in bucket [4, 8).
        assert_eq!(c.stats.lease_latency_hist[3], 1);
        assert_eq!(c.stats.lease_latency_hist.iter().sum::<u64>(), 1);
    }

    #[test]
    fn duplicates_count_against_received_cells() {
        let mut c = core(4, 4);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        // Timeout, reissue to a second worker, then both deliver all 4.
        c.on_tick(2_000);
        let outs = admit(&mut c, 1);
        let (id2, _, _) = lease_of(&outs);
        c.on_message(1, Msg::Cells { lease: id2, cells: (0..4).map(cell).collect() }, 2_100);
        c.on_message(0, Msg::Cells { lease: id, cells: (0..4).map(cell).collect() }, 2_200);
        assert_eq!(c.stats.cells_received, 8);
        assert_eq!(c.stats.duplicates, 4);
        assert_eq!(c.stats.duplicate_ratio(), 0.5);
        assert_eq!(c.stats.per_worker[&0].cells, 4);
        assert_eq!(c.stats.per_worker[&1].cells, 4);
    }

    #[test]
    fn latency_buckets_are_log2() {
        assert_eq!(DispatchStats::latency_bucket(0), 0);
        assert_eq!(DispatchStats::latency_bucket(1), 1);
        assert_eq!(DispatchStats::latency_bucket(2), 2);
        assert_eq!(DispatchStats::latency_bucket(3), 2);
        assert_eq!(DispatchStats::latency_bucket(1_000), 10);
        assert_eq!(DispatchStats::latency_bucket(u64::MAX), LATENCY_BUCKETS - 1);
    }

    #[test]
    fn stats_json_carries_every_field() {
        let mut c = core(2, 2);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        c.on_message(0, Msg::Cells { lease: id, cells: (0..2).map(cell).collect() }, 3);
        let v = c.stats.to_json();
        for key in [
            "leases_granted",
            "steals",
            "reissues",
            "duplicates",
            "workers_seen",
            "cells_received",
        ] {
            assert!(v.get(key).is_some(), "missing {key}");
        }
        assert_eq!(v.req("cells_received").f64(), 2.0);
        assert_eq!(v.req("lease_latency_hist_ms").arr().len(), LATENCY_BUCKETS);
        assert_eq!(v.req("per_worker").req("0").req("cells").f64(), 2.0);
    }

    #[test]
    fn stats_registry_mirrors_the_counters_and_injects_the_histogram() {
        use crate::telemetry::registry::{Counter, Hist};
        let mut c = core(4, 4);
        let outs = admit(&mut c, 0);
        let (id, _, _) = lease_of(&outs);
        c.on_message(0, Msg::Cells { lease: id, cells: (0..4).map(cell).collect() }, 5);
        c.on_message(0, Msg::LeaseDone { lease: id }, 7);
        let r = c.stats.to_registry();
        assert_eq!(r.get(Counter::ServeLeasesGranted), c.stats.leases_granted);
        assert_eq!(r.get(Counter::ServeCellsReceived), 4);
        assert_eq!(r.get(Counter::ServeWorkersSeen), 1);
        let h = r.hist(Hist::ServeLeaseLatencyMs);
        assert_eq!(h.buckets, c.stats.lease_latency_hist);
        assert_eq!(h.count, 1);
        assert_eq!(h.total, 7, "exact total reconstructed from worker sums");
        // Engine-side ids stay zero: the two layers share one schema.
        assert_eq!(r.get(Counter::TicksOff), 0);
        let snap = r.snapshot();
        assert_eq!(
            snap.req("counters").req("serve.cells_received").f64(),
            4.0
        );
    }
}
