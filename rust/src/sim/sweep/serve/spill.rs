//! Out-of-core incremental merge: spill sorted runs, k-way merge, stream
//! the report — byte-identical to `SweepReport::json_string()` without
//! ever holding all cells in memory.
//!
//! Cells arrive in arbitrary order (leases complete out of order, workers
//! interleave). [`SpillMerger::push`] buffers up to `limit` cells; at the
//! limit the buffer is sorted by scenario index and written to disk as
//! one *run* (one compact cell-JSON per line). [`SpillMerger::finalize`]
//! k-way merges the runs plus the final in-memory buffer with a binary
//! heap keyed on scenario index — indexes are globally unique, so the
//! merge order is total — and streams the report straight to the output
//! writer:
//!
//! * the `"cells"` array is emitted cell by cell in index order, each
//!   serialized exactly as `CellResult::to_json().to_json()` (runs store
//!   that very byte string, and our JSON writer is round-trip stable, so
//!   re-parsing a spilled line re-serializes to identical bytes);
//! * [`SummaryAccumulator`] consumes the metrics *in index order during
//!   the same pass*, replaying the exact f64 operation sequence of
//!   `SweepReport::new`, so the trailing `"summary"` object is
//!   byte-identical too;
//! * the surrounding object layout mirrors `SweepReport::to_json`'s
//!   `BTreeMap` key order (`cells` < `matrix` < `matrix_seed` <
//!   `n_scenarios` < `summary` — alphabetical), with every scalar
//!   formatted by the same `util::json` writer.
//!
//! Peak memory is `limit` buffered cells plus one in-flight cell per run
//! (heap of run heads) — bounded by the spill-run size, never by the
//! total cell count. The byte-exactness and the memory bound are both
//! enforced by `rust/tests/sweep_serve.rs`.

use std::collections::BinaryHeap;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::sim::sweep::report::{CellResult, SummaryAccumulator, SummaryStats};
use crate::util::json::Value;

use super::journal::{fnv1a, fnv1a_extend, RunRecord, FNV_OFFSET};

/// One freshly spilled run plus the bookkeeping the serve shell needs to
/// commit it to the write-ahead journal (drained via
/// [`SpillMerger::take_spilled`]).
#[derive(Clone, Debug)]
pub struct RunInfo {
    /// The journal manifest (path, index span, cell count, content hash).
    pub record: RunRecord,
    /// The run's maximal contiguous index sub-ranges, ascending —
    /// journaled as provisional `range` records ahead of the manifest.
    pub ranges: Vec<(usize, usize)>,
}

/// One spilled run or the final buffer, as an index-ordered line stream.
enum RunSource {
    File(BufReader<File>),
    Memory(std::vec::IntoIter<CellResult>),
}

/// One run head: the exact line bytes to emit plus the parsed cell (for
/// the summary pass). Spilled lines are parsed once, here; in-memory
/// cells never re-parse bytes they serialized a moment earlier.
type RunHead = (String, CellResult);

impl RunSource {
    fn next_cell(&mut self) -> Result<Option<RunHead>, String> {
        match self {
            RunSource::File(r) => {
                let mut line = String::new();
                loop {
                    line.clear();
                    let n = r.read_line(&mut line).map_err(|e| format!("run read: {e}"))?;
                    if n == 0 {
                        return Ok(None);
                    }
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        continue;
                    }
                    let v = Value::parse(trimmed).map_err(|e| format!("run parse: {e}"))?;
                    let cell = CellResult::from_json(&v)?;
                    return Ok(Some((trimmed.to_string(), cell)));
                }
            }
            RunSource::Memory(it) => Ok(it.next().map(|c| (c.to_json().to_json(), c))),
        }
    }
}

/// Accepts each scenario's [`CellResult`] exactly once, in any order, and
/// streams out the byte-exact single-process report. See module docs.
pub struct SpillMerger {
    dir: PathBuf,
    limit: usize,
    buf: Vec<CellResult>,
    runs: Vec<PathBuf>,
    total_pushed: usize,
    peak_buffered: usize,
    /// Manifests of runs spilled since the last `take_spilled` drain.
    pending_manifests: Vec<RunInfo>,
    /// Journaled serves keep their run files on disk after `Drop` — the
    /// journal references them by path and a restarted dispatcher
    /// re-admits them; the serve shell deletes them only after the
    /// finalize marker lands.
    preserve: bool,
}

impl SpillMerger {
    /// `dir` holds the run files (created if missing, removed on a clean
    /// finalize); `limit` is the in-memory buffer size in cells.
    pub fn new(dir: PathBuf, limit: usize) -> Result<SpillMerger, String> {
        std::fs::create_dir_all(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        Ok(SpillMerger {
            dir,
            limit: limit.max(1),
            buf: Vec::new(),
            runs: Vec::new(),
            total_pushed: 0,
            peak_buffered: 0,
            pending_manifests: Vec::new(),
            preserve: false,
        })
    }

    /// Keep (or stop keeping) run files on disk when this merger drops.
    pub fn set_preserve(&mut self, preserve: bool) {
        self.preserve = preserve;
    }

    /// Every run file currently part of the merge (spilled + adopted).
    pub fn run_paths(&self) -> Vec<PathBuf> {
        self.runs.clone()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Drain the manifests of runs spilled since the last drain, for the
    /// journal. Callers that don't journal may simply never call this —
    /// the backlog is one small struct per run.
    pub fn take_spilled(&mut self) -> Vec<RunInfo> {
        std::mem::take(&mut self.pending_manifests)
    }

    /// Cells pushed so far (across buffer and spilled runs).
    pub fn len(&self) -> usize {
        self.total_pushed
    }

    pub fn is_empty(&self) -> bool {
        self.total_pushed == 0
    }

    /// High-water mark of the in-memory buffer — the memory-bound proof
    /// handle: never exceeds the configured limit.
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    pub fn runs_spilled(&self) -> usize {
        self.runs.len()
    }

    /// Accept one cell. The caller (the dispatcher) guarantees each
    /// scenario index arrives exactly once; [`SpillMerger::finalize`]
    /// verifies it.
    pub fn push(&mut self, cell: CellResult) -> Result<(), String> {
        self.buf.push(cell);
        self.total_pushed += 1;
        self.peak_buffered = self.peak_buffered.max(self.buf.len());
        if self.buf.len() >= self.limit {
            self.spill()?;
        }
        Ok(())
    }

    fn spill(&mut self) -> Result<(), String> {
        if self.buf.is_empty() {
            return Ok(());
        }
        self.buf.sort_by_key(|c| c.index);
        let path = self.dir.join(format!("run_{:06}.jsonl", self.runs.len()));
        let file = File::create(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut w = BufWriter::new(file);
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        for c in &self.buf {
            match ranges.last_mut() {
                Some((_, e)) if *e == c.index => *e += 1,
                _ => ranges.push((c.index, c.index + 1)),
            }
        }
        let start = self.buf.first().expect("non-empty").index;
        let end = self.buf.last().expect("non-empty").index + 1;
        let cells = self.buf.len();
        let mut hash = FNV_OFFSET;
        for c in self.buf.drain(..) {
            let mut line = c.to_json().to_json();
            line.push('\n');
            hash = fnv1a_extend(hash, line.as_bytes());
            w.write_all(line.as_bytes())
                .map_err(|e| format!("{}: {e}", path.display()))?;
        }
        w.flush().map_err(|e| format!("{}: {e}", path.display()))?;
        self.pending_manifests.push(RunInfo {
            record: RunRecord { path: path.clone(), start, end, cells, hash },
            ranges,
        });
        self.runs.push(path);
        Ok(())
    }

    /// Re-admit a run file journaled by a crashed dispatcher. The file
    /// is fully re-verified before it joins the merge — content hash,
    /// per-line cell parse, strictly ascending indices pinned to the
    /// journaled span and count — and any mismatch fails loudly with the
    /// offending record's byte offset (same discipline as the shard-file
    /// `index` corruption checks): a resumed serve either merges exactly
    /// what the journal committed or refuses to produce a report.
    pub fn adopt_run(&mut self, rec: &RunRecord) -> Result<(), String> {
        let at =
            |off: usize, detail: String| format!("{} at byte {off}: {detail}", rec.path.display());
        let bytes =
            std::fs::read(&rec.path).map_err(|e| format!("{}: {e}", rec.path.display()))?;
        let hash = fnv1a(&bytes);
        if hash != rec.hash {
            return Err(format!(
                "{}: content hash {hash:016x} does not match the journaled {:016x} — \
                 the run file changed after it was committed",
                rec.path.display(),
                rec.hash
            ));
        }
        let mut off = 0usize;
        let mut count = 0usize;
        let mut prev: Option<usize> = None;
        for line in bytes.split(|&b| b == b'\n') {
            if !line.is_empty() {
                let text = std::str::from_utf8(line)
                    .map_err(|_| at(off, "run line is not UTF-8".into()))?;
                let v = Value::parse(text).map_err(|e| at(off, format!("{e}")))?;
                let cell = CellResult::from_json(&v).map_err(|e| at(off, e))?;
                if cell.index < rec.start || cell.index >= rec.end {
                    return Err(at(
                        off,
                        format!(
                            "cell index {} outside the journaled span {}..{}",
                            cell.index, rec.start, rec.end
                        ),
                    ));
                }
                match prev {
                    Some(p) if cell.index <= p => {
                        return Err(at(
                            off,
                            format!(
                                "cell index {} not ascending after {p} \
                                 (duplicate or shuffled run)",
                                cell.index
                            ),
                        ));
                    }
                    None if cell.index != rec.start => {
                        return Err(at(
                            off,
                            format!(
                                "first cell index {} does not open the journaled \
                                 span {}..{}",
                                cell.index, rec.start, rec.end
                            ),
                        ));
                    }
                    _ => {}
                }
                prev = Some(cell.index);
                count += 1;
            }
            off += line.len() + 1;
        }
        if prev.map(|p| p + 1) != Some(rec.end) {
            return Err(format!(
                "{}: the run does not close its journaled span {}..{}",
                rec.path.display(),
                rec.start,
                rec.end
            ));
        }
        if count != rec.cells {
            return Err(format!(
                "{}: {count} cells on disk, journal committed {}",
                rec.path.display(),
                rec.cells
            ));
        }
        self.total_pushed += count;
        self.runs.push(rec.path.clone());
        Ok(())
    }

    /// K-way merge every run plus the remaining buffer and stream the
    /// full report to `out`. Verifies exact cover (every index in
    /// `0..n_expected` exactly once) and returns the summary it computed.
    pub fn finalize(
        mut self,
        matrix_name: &str,
        matrix_seed: u64,
        n_expected: usize,
        out: &mut dyn Write,
    ) -> Result<SummaryStats, String> {
        let io = |e: std::io::Error| format!("report write: {e}");
        self.buf.sort_by_key(|c| c.index);
        let mut sources: Vec<RunSource> = Vec::with_capacity(self.runs.len() + 1);
        for path in &self.runs {
            let f = File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
            sources.push(RunSource::File(BufReader::new(f)));
        }
        sources.push(RunSource::Memory(std::mem::take(&mut self.buf).into_iter()));

        // Heap of run heads: (Reverse(index), source id). Indexes are
        // unique, so ties cannot occur and the pop order is total.
        let mut heap: BinaryHeap<std::cmp::Reverse<(usize, usize)>> = BinaryHeap::new();
        let mut heads: Vec<Option<RunHead>> = Vec::with_capacity(sources.len());
        for (i, s) in sources.iter_mut().enumerate() {
            match s.next_cell()? {
                Some(head) => {
                    heap.push(std::cmp::Reverse((head.1.index, i)));
                    heads.push(Some(head));
                }
                None => heads.push(None),
            }
        }

        out.write_all(b"{\"cells\":[").map_err(io)?;
        let mut acc = SummaryAccumulator::new();
        let mut next_index = 0usize;
        while let Some(std::cmp::Reverse((idx, src))) = heap.pop() {
            if idx != next_index {
                return Err(format!(
                    "merge cover broken: expected scenario index {next_index}, got {idx} \
                     (missing or duplicated cell)"
                ));
            }
            let (line, cell) = heads[src].take().expect("head present for popped source");
            if next_index > 0 {
                out.write_all(b",").map_err(io)?;
            }
            out.write_all(line.as_bytes()).map_err(io)?;
            acc.push(&cell.metrics);
            next_index += 1;
            if let Some(head) = sources[src].next_cell()? {
                heap.push(std::cmp::Reverse((head.1.index, src)));
                heads[src] = Some(head);
            }
        }
        if next_index != n_expected {
            return Err(format!(
                "merge cover broken: {next_index} of {n_expected} scenarios ingested"
            ));
        }
        let summary = acc.finish();
        out.write_all(b"],\"matrix\":").map_err(io)?;
        out.write_all(Value::Str(matrix_name.to_string()).to_json().as_bytes()).map_err(io)?;
        out.write_all(b",\"matrix_seed\":").map_err(io)?;
        out.write_all(Value::Str(matrix_seed.to_string()).to_json().as_bytes()).map_err(io)?;
        out.write_all(b",\"n_scenarios\":").map_err(io)?;
        out.write_all(Value::Num(n_expected as f64).to_json().as_bytes()).map_err(io)?;
        out.write_all(b",\"summary\":").map_err(io)?;
        out.write_all(summary.to_json().to_json().as_bytes()).map_err(io)?;
        out.write_all(b"}").map_err(io)?;
        out.flush().map_err(io)?;
        // Run files are removed by Drop (which also covers every error
        // path out of this function); `sources` is a local, so the open
        // handles close before the consumed `self` drops.
        Ok(summary)
    }
}

impl Drop for SpillMerger {
    /// Best-effort cleanup of the spill runs — on the happy path and on
    /// every error path (a failed serve must not leave a matrix worth of
    /// JSONL in the temp dir). The dir is only removed once empty, in
    /// case the caller pointed several mergers at a shared directory.
    /// Journaled serves set `preserve` — their run files outlive the
    /// process on purpose.
    fn drop(&mut self) {
        if self.preserve {
            return;
        }
        for path in &self.runs {
            let _ = std::fs::remove_file(path);
        }
        let _ = std::fs::remove_dir(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::SchedulerKind;
    use crate::sim::sweep::{run_matrix, HarvesterSpec, ScenarioMatrix};

    fn matrix() -> ScenarioMatrix {
        ScenarioMatrix::new("spill-test", 0x5111)
            .harvesters(vec![
                HarvesterSpec::Persistent { power_mw: 600.0 },
                HarvesterSpec::Persistent { power_mw: 150.0 },
            ])
            .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
            .reps(3)
            .duration_ms(2_000.0)
    }

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("zygarde_spill_{tag}_{}", std::process::id()))
    }

    #[test]
    fn out_of_order_spilled_merge_is_byte_identical() {
        let m = matrix();
        let report = run_matrix(&m, 2);
        let mut cells = report.cells.clone();
        // Worst-case arrival order: reversed, so every run overlaps.
        cells.reverse();
        let mut merger = SpillMerger::new(temp_dir("rev"), 3).unwrap();
        for c in cells {
            merger.push(c).unwrap();
        }
        assert!(merger.runs_spilled() >= 3, "limit 3 over 12 cells must spill");
        assert!(merger.peak_buffered() <= 3);
        let mut bytes = Vec::new();
        let summary = merger.finalize(&m.name, m.seed, report.n_scenarios, &mut bytes).unwrap();
        assert_eq!(String::from_utf8(bytes).unwrap(), report.json_string());
        assert_eq!(summary.released, report.summary.released);
    }

    #[test]
    fn spilled_manifests_pin_hash_span_and_contiguous_ranges() {
        let m = matrix();
        let report = run_matrix(&m, 1);
        let mut merger = SpillMerger::new(temp_dir("manifest"), 4).unwrap();
        // Push 2,3,0,1 then 7,5: first run is contiguous 0..4, second
        // (forced by a manual drain at finalize) has a gap.
        for i in [2usize, 3, 0, 1] {
            merger.push(report.cells[i].clone()).unwrap();
        }
        let infos = merger.take_spilled();
        assert_eq!(infos.len(), 1);
        let info = &infos[0];
        assert_eq!((info.record.start, info.record.end, info.record.cells), (0, 4, 4));
        assert_eq!(info.ranges, vec![(0, 4)]);
        let disk = std::fs::read(&info.record.path).unwrap();
        assert_eq!(super::fnv1a(&disk), info.record.hash);
        assert!(merger.take_spilled().is_empty(), "drain is one-shot");
    }

    #[test]
    fn adopted_runs_merge_byte_identically_and_survive_preserve() {
        let m = matrix();
        let report = run_matrix(&m, 2);
        // First merger spills everything and preserves its runs (the
        // crashed dispatcher).
        let mut first = SpillMerger::new(temp_dir("adopt_src"), 3).unwrap();
        first.set_preserve(true);
        for c in report.cells.iter().rev().take(11).cloned() {
            first.push(c).unwrap();
        }
        let infos = first.take_spilled();
        assert!(infos.len() >= 3);
        assert!(!first.is_empty());
        // Cells still buffered in `first` die with it — only spilled
        // runs are durable, exactly like a kill -9.
        let durable: Vec<usize> = infos
            .iter()
            .flat_map(|i| i.ranges.iter().flat_map(|&(s, e)| s..e))
            .collect();
        drop(first);
        // Second merger (the restarted dispatcher) adopts the runs and
        // takes the remaining cells as fresh pushes.
        let mut second = SpillMerger::new(temp_dir("adopt_dst"), 3).unwrap();
        for info in &infos {
            second.adopt_run(&info.record).unwrap();
        }
        for c in &report.cells {
            if !durable.contains(&c.index) {
                second.push(c.clone()).unwrap();
            }
        }
        let mut bytes = Vec::new();
        second.finalize(&m.name, m.seed, report.n_scenarios, &mut bytes).unwrap();
        assert_eq!(String::from_utf8(bytes).unwrap(), report.json_string());
        for info in &infos {
            let _ = std::fs::remove_file(&info.record.path);
        }
    }

    #[test]
    fn adopt_run_rejects_corruption_with_byte_offsets() {
        let m = matrix();
        let report = run_matrix(&m, 1);
        let mut merger = SpillMerger::new(temp_dir("adopt_bad"), 4).unwrap();
        merger.set_preserve(true);
        for c in report.cells.iter().take(4).cloned() {
            merger.push(c).unwrap();
        }
        let info = merger.take_spilled().pop().unwrap();
        drop(merger);
        let good = std::fs::read(&info.record.path).unwrap();

        // Content tampering: hash check fires first.
        let mut bad = good.clone();
        bad[10] ^= 0x01;
        std::fs::write(&info.record.path, &bad).unwrap();
        let mut fresh = SpillMerger::new(temp_dir("adopt_bad2"), 4).unwrap();
        let err = fresh.adopt_run(&info.record).unwrap_err();
        assert!(err.contains("content hash"), "{err}");

        // A journaled count that lies about the (hash-intact) file.
        std::fs::write(&info.record.path, &good).unwrap();
        let mut lying = info.record.clone();
        lying.cells = 3;
        let err = fresh.adopt_run(&lying).unwrap_err();
        assert!(err.contains("not ascending") || err.contains("cells on disk"), "{err}");

        // A journaled span the file does not open.
        let mut shifted = info.record.clone();
        shifted.start += 1;
        shifted.end += 1;
        shifted.cells = info.record.cells;
        let err = fresh.adopt_run(&shifted).unwrap_err();
        assert!(err.contains("at byte 0"), "{err}");
        assert!(err.contains("outside the journaled span"), "{err}");
        let _ = std::fs::remove_file(&info.record.path);
    }

    #[test]
    fn missing_and_duplicate_cells_fail_the_cover_check() {
        let m = matrix();
        let report = run_matrix(&m, 1);
        // Missing cell.
        let mut merger = SpillMerger::new(temp_dir("miss"), 64).unwrap();
        for c in report.cells.iter().skip(1).cloned() {
            merger.push(c).unwrap();
        }
        let err = merger
            .finalize(&m.name, m.seed, report.n_scenarios, &mut Vec::new())
            .unwrap_err();
        assert!(err.contains("expected scenario index 0"), "{err}");
        // Duplicate cell (the dispatcher's bitmap normally prevents this).
        let mut merger = SpillMerger::new(temp_dir("dup"), 64).unwrap();
        for c in report.cells.iter().cloned() {
            merger.push(c).unwrap();
        }
        merger.push(report.cells[4].clone()).unwrap();
        let err = merger
            .finalize(&m.name, m.seed, report.n_scenarios, &mut Vec::new())
            .unwrap_err();
        assert!(err.contains("missing or duplicated"), "{err}");
    }
}
