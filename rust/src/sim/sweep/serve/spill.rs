//! Out-of-core incremental merge: spill sorted runs, k-way merge, stream
//! the report — byte-identical to `SweepReport::json_string()` without
//! ever holding all cells in memory.
//!
//! Cells arrive in arbitrary order (leases complete out of order, workers
//! interleave). [`SpillMerger::push`] buffers up to `limit` cells; at the
//! limit the buffer is sorted by scenario index and written to disk as
//! one *run* (one compact cell-JSON per line). [`SpillMerger::finalize`]
//! k-way merges the runs plus the final in-memory buffer with a binary
//! heap keyed on scenario index — indexes are globally unique, so the
//! merge order is total — and streams the report straight to the output
//! writer:
//!
//! * the `"cells"` array is emitted cell by cell in index order, each
//!   serialized exactly as `CellResult::to_json().to_json()` (runs store
//!   that very byte string, and our JSON writer is round-trip stable, so
//!   re-parsing a spilled line re-serializes to identical bytes);
//! * [`SummaryAccumulator`] consumes the metrics *in index order during
//!   the same pass*, replaying the exact f64 operation sequence of
//!   `SweepReport::new`, so the trailing `"summary"` object is
//!   byte-identical too;
//! * the surrounding object layout mirrors `SweepReport::to_json`'s
//!   `BTreeMap` key order (`cells` < `matrix` < `matrix_seed` <
//!   `n_scenarios` < `summary` — alphabetical), with every scalar
//!   formatted by the same `util::json` writer.
//!
//! Peak memory is `limit` buffered cells plus one in-flight cell per run
//! (heap of run heads) — bounded by the spill-run size, never by the
//! total cell count. The byte-exactness and the memory bound are both
//! enforced by `rust/tests/sweep_serve.rs`.

use std::collections::BinaryHeap;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::PathBuf;

use crate::sim::sweep::report::{CellResult, SummaryAccumulator, SummaryStats};
use crate::util::json::Value;

/// One spilled run or the final buffer, as an index-ordered line stream.
enum RunSource {
    File(BufReader<File>),
    Memory(std::vec::IntoIter<CellResult>),
}

/// One run head: the exact line bytes to emit plus the parsed cell (for
/// the summary pass). Spilled lines are parsed once, here; in-memory
/// cells never re-parse bytes they serialized a moment earlier.
type RunHead = (String, CellResult);

impl RunSource {
    fn next_cell(&mut self) -> Result<Option<RunHead>, String> {
        match self {
            RunSource::File(r) => {
                let mut line = String::new();
                loop {
                    line.clear();
                    let n = r.read_line(&mut line).map_err(|e| format!("run read: {e}"))?;
                    if n == 0 {
                        return Ok(None);
                    }
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        continue;
                    }
                    let v = Value::parse(trimmed).map_err(|e| format!("run parse: {e}"))?;
                    let cell = CellResult::from_json(&v)?;
                    return Ok(Some((trimmed.to_string(), cell)));
                }
            }
            RunSource::Memory(it) => Ok(it.next().map(|c| (c.to_json().to_json(), c))),
        }
    }
}

/// Accepts each scenario's [`CellResult`] exactly once, in any order, and
/// streams out the byte-exact single-process report. See module docs.
pub struct SpillMerger {
    dir: PathBuf,
    limit: usize,
    buf: Vec<CellResult>,
    runs: Vec<PathBuf>,
    total_pushed: usize,
    peak_buffered: usize,
}

impl SpillMerger {
    /// `dir` holds the run files (created if missing, removed on a clean
    /// finalize); `limit` is the in-memory buffer size in cells.
    pub fn new(dir: PathBuf, limit: usize) -> Result<SpillMerger, String> {
        std::fs::create_dir_all(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        Ok(SpillMerger {
            dir,
            limit: limit.max(1),
            buf: Vec::new(),
            runs: Vec::new(),
            total_pushed: 0,
            peak_buffered: 0,
        })
    }

    /// Cells pushed so far (across buffer and spilled runs).
    pub fn len(&self) -> usize {
        self.total_pushed
    }

    pub fn is_empty(&self) -> bool {
        self.total_pushed == 0
    }

    /// High-water mark of the in-memory buffer — the memory-bound proof
    /// handle: never exceeds the configured limit.
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    pub fn runs_spilled(&self) -> usize {
        self.runs.len()
    }

    /// Accept one cell. The caller (the dispatcher) guarantees each
    /// scenario index arrives exactly once; [`SpillMerger::finalize`]
    /// verifies it.
    pub fn push(&mut self, cell: CellResult) -> Result<(), String> {
        self.buf.push(cell);
        self.total_pushed += 1;
        self.peak_buffered = self.peak_buffered.max(self.buf.len());
        if self.buf.len() >= self.limit {
            self.spill()?;
        }
        Ok(())
    }

    fn spill(&mut self) -> Result<(), String> {
        if self.buf.is_empty() {
            return Ok(());
        }
        self.buf.sort_by_key(|c| c.index);
        let path = self.dir.join(format!("run_{:06}.jsonl", self.runs.len()));
        let file = File::create(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut w = BufWriter::new(file);
        for c in self.buf.drain(..) {
            let mut line = c.to_json().to_json();
            line.push('\n');
            w.write_all(line.as_bytes())
                .map_err(|e| format!("{}: {e}", path.display()))?;
        }
        w.flush().map_err(|e| format!("{}: {e}", path.display()))?;
        self.runs.push(path);
        Ok(())
    }

    /// K-way merge every run plus the remaining buffer and stream the
    /// full report to `out`. Verifies exact cover (every index in
    /// `0..n_expected` exactly once) and returns the summary it computed.
    pub fn finalize(
        mut self,
        matrix_name: &str,
        matrix_seed: u64,
        n_expected: usize,
        out: &mut dyn Write,
    ) -> Result<SummaryStats, String> {
        let io = |e: std::io::Error| format!("report write: {e}");
        self.buf.sort_by_key(|c| c.index);
        let mut sources: Vec<RunSource> = Vec::with_capacity(self.runs.len() + 1);
        for path in &self.runs {
            let f = File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
            sources.push(RunSource::File(BufReader::new(f)));
        }
        sources.push(RunSource::Memory(std::mem::take(&mut self.buf).into_iter()));

        // Heap of run heads: (Reverse(index), source id). Indexes are
        // unique, so ties cannot occur and the pop order is total.
        let mut heap: BinaryHeap<std::cmp::Reverse<(usize, usize)>> = BinaryHeap::new();
        let mut heads: Vec<Option<RunHead>> = Vec::with_capacity(sources.len());
        for (i, s) in sources.iter_mut().enumerate() {
            match s.next_cell()? {
                Some(head) => {
                    heap.push(std::cmp::Reverse((head.1.index, i)));
                    heads.push(Some(head));
                }
                None => heads.push(None),
            }
        }

        out.write_all(b"{\"cells\":[").map_err(io)?;
        let mut acc = SummaryAccumulator::new();
        let mut next_index = 0usize;
        while let Some(std::cmp::Reverse((idx, src))) = heap.pop() {
            if idx != next_index {
                return Err(format!(
                    "merge cover broken: expected scenario index {next_index}, got {idx} \
                     (missing or duplicated cell)"
                ));
            }
            let (line, cell) = heads[src].take().expect("head present for popped source");
            if next_index > 0 {
                out.write_all(b",").map_err(io)?;
            }
            out.write_all(line.as_bytes()).map_err(io)?;
            acc.push(&cell.metrics);
            next_index += 1;
            if let Some(head) = sources[src].next_cell()? {
                heap.push(std::cmp::Reverse((head.1.index, src)));
                heads[src] = Some(head);
            }
        }
        if next_index != n_expected {
            return Err(format!(
                "merge cover broken: {next_index} of {n_expected} scenarios ingested"
            ));
        }
        let summary = acc.finish();
        out.write_all(b"],\"matrix\":").map_err(io)?;
        out.write_all(Value::Str(matrix_name.to_string()).to_json().as_bytes()).map_err(io)?;
        out.write_all(b",\"matrix_seed\":").map_err(io)?;
        out.write_all(Value::Str(matrix_seed.to_string()).to_json().as_bytes()).map_err(io)?;
        out.write_all(b",\"n_scenarios\":").map_err(io)?;
        out.write_all(Value::Num(n_expected as f64).to_json().as_bytes()).map_err(io)?;
        out.write_all(b",\"summary\":").map_err(io)?;
        out.write_all(summary.to_json().to_json().as_bytes()).map_err(io)?;
        out.write_all(b"}").map_err(io)?;
        out.flush().map_err(io)?;
        // Run files are removed by Drop (which also covers every error
        // path out of this function); `sources` is a local, so the open
        // handles close before the consumed `self` drops.
        Ok(summary)
    }
}

impl Drop for SpillMerger {
    /// Best-effort cleanup of the spill runs — on the happy path and on
    /// every error path (a failed serve must not leave a matrix worth of
    /// JSONL in the temp dir). The dir is only removed once empty, in
    /// case the caller pointed several mergers at a shared directory.
    fn drop(&mut self) {
        for path in &self.runs {
            let _ = std::fs::remove_file(path);
        }
        let _ = std::fs::remove_dir(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::SchedulerKind;
    use crate::sim::sweep::{run_matrix, HarvesterSpec, ScenarioMatrix};

    fn matrix() -> ScenarioMatrix {
        ScenarioMatrix::new("spill-test", 0x5111)
            .harvesters(vec![
                HarvesterSpec::Persistent { power_mw: 600.0 },
                HarvesterSpec::Persistent { power_mw: 150.0 },
            ])
            .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::Edf])
            .reps(3)
            .duration_ms(2_000.0)
    }

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("zygarde_spill_{tag}_{}", std::process::id()))
    }

    #[test]
    fn out_of_order_spilled_merge_is_byte_identical() {
        let m = matrix();
        let report = run_matrix(&m, 2);
        let mut cells = report.cells.clone();
        // Worst-case arrival order: reversed, so every run overlaps.
        cells.reverse();
        let mut merger = SpillMerger::new(temp_dir("rev"), 3).unwrap();
        for c in cells {
            merger.push(c).unwrap();
        }
        assert!(merger.runs_spilled() >= 3, "limit 3 over 12 cells must spill");
        assert!(merger.peak_buffered() <= 3);
        let mut bytes = Vec::new();
        let summary = merger.finalize(&m.name, m.seed, report.n_scenarios, &mut bytes).unwrap();
        assert_eq!(String::from_utf8(bytes).unwrap(), report.json_string());
        assert_eq!(summary.released, report.summary.released);
    }

    #[test]
    fn missing_and_duplicate_cells_fail_the_cover_check() {
        let m = matrix();
        let report = run_matrix(&m, 1);
        // Missing cell.
        let mut merger = SpillMerger::new(temp_dir("miss"), 64).unwrap();
        for c in report.cells.iter().skip(1).cloned() {
            merger.push(c).unwrap();
        }
        let err = merger
            .finalize(&m.name, m.seed, report.n_scenarios, &mut Vec::new())
            .unwrap_err();
        assert!(err.contains("expected scenario index 0"), "{err}");
        // Duplicate cell (the dispatcher's bitmap normally prevents this).
        let mut merger = SpillMerger::new(temp_dir("dup"), 64).unwrap();
        for c in report.cells.iter().cloned() {
            merger.push(c).unwrap();
        }
        merger.push(report.cells[4].clone()).unwrap();
        let err = merger
            .finalize(&m.name, m.seed, report.n_scenarios, &mut Vec::new())
            .unwrap_err();
        assert!(err.contains("missing or duplicated"), "{err}");
    }
}
