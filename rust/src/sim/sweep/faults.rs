//! Per-scenario failure injection.
//!
//! Two fault channels, both riding on existing substrate models so a
//! faulted scenario stays bitwise comparable to its baseline:
//!
//! * **Brownout bursts** — periodic forced-dark windows masked onto the
//!   harvester output ([`BlackoutWindows`]): the capacitor drains through
//!   them, forcing mid-fragment power failures and SONIC-style
//!   re-execution.
//! * **Post-reboot clock skew** — the scheduler reads a CHRT remanence
//!   clock ([`ClockSpec::Chrt`]) whose per-outage read error follows the
//!   published §8.7 distribution, instead of a perfect RTC.

use crate::clock::ClockSpec;
use crate::energy::harvester::BlackoutWindows;

/// What goes wrong in one scenario. [`FaultPlan::none`] is the clean
/// baseline (RTC, no bursts).
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Periodic brownout bursts masked onto the harvester, if any.
    pub brownout: Option<BlackoutWindows>,
    /// The clock the scheduler consults (skew source across reboots).
    pub clock: ClockSpec,
}

impl FaultPlan {
    /// Clean baseline: perfect clock, no injected outages.
    pub fn none() -> Self {
        FaultPlan { brownout: None, clock: ClockSpec::Rtc }
    }

    /// Add periodic brownout bursts: `duration_ms` of darkness every
    /// `period_ms`, starting `offset_ms` into each period.
    pub fn with_brownouts(mut self, period_ms: f64, duration_ms: f64, offset_ms: f64) -> Self {
        self.brownout = Some(BlackoutWindows { period_ms, duration_ms, offset_ms });
        self
    }

    /// Replace the scheduler's clock (post-reboot skew injection).
    pub fn with_clock(mut self, clock: ClockSpec) -> Self {
        self.clock = clock;
        self
    }

    /// Short human label for report rows.
    pub fn label(&self) -> String {
        match self.brownout {
            None => self.clock.name().to_string(),
            Some(w) => format!(
                "{}+burst{}of{}ms",
                self.clock.name(),
                w.duration_ms,
                w.period_ms
            ),
        }
    }
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ChrtTier;

    #[test]
    fn labels_are_descriptive() {
        assert_eq!(FaultPlan::none().label(), "rtc");
        let f = FaultPlan::none()
            .with_brownouts(1000.0, 250.0, 0.0)
            .with_clock(ClockSpec::Chrt(ChrtTier::Tier3));
        assert_eq!(f.label(), "chrt-t3+burst250of1000ms");
    }
}
