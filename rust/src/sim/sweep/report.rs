//! Aggregated sweep results: per-cell metrics plus summary statistics,
//! serializable with the in-crate JSON writer.
//!
//! The JSON form is the determinism contract: two runs of the same matrix
//! must produce byte-identical [`SweepReport::json_string`] output no
//! matter the thread count. Seeds are serialized as decimal *strings*
//! (u64 does not fit f64's exact-integer range).

use std::collections::BTreeMap;

use crate::sim::metrics::Metrics;
use crate::util::json::Value;
use crate::util::stats::Online;

/// One executed scenario's outcome.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Scenario index in the matrix expansion.
    pub index: usize,
    /// Stable human-readable cell label (mix/harvester/cap/sched/…).
    pub label: String,
    pub engine_seed: u64,
    pub metrics: Metrics,
}

impl CellResult {
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("index".to_string(), Value::Num(self.index as f64));
        m.insert("label".to_string(), Value::Str(self.label.clone()));
        m.insert("engine_seed".to_string(), Value::Str(self.engine_seed.to_string()));
        m.insert("metrics".to_string(), self.metrics.to_json());
        Value::Obj(m)
    }

    /// Inverse of [`CellResult::to_json`]; the shard-merge path uses this
    /// to reassemble a [`SweepReport`] byte-identical to a single-process
    /// run (see `sim::sweep::shard`).
    pub fn from_json(v: &Value) -> Result<CellResult, String> {
        let raw_index = v
            .get("index")
            .and_then(Value::as_f64)
            .ok_or_else(|| "cell: missing numeric `index`".to_string())?;
        // `to_json` writes `index as f64`, which round-trips exactly for
        // any real matrix (indices are far below 2^53). Anything that does
        // NOT round-trip — NaN, negatives, fractions, overflow — is a
        // corrupt or hand-edited shard file; a saturating `as usize` would
        // silently alias it onto cell 0 (or clamp), and the shard merge
        // would then mis-order or drop cells without a diagnostic.
        let index = raw_index as usize;
        if index as f64 != raw_index {
            return Err(format!(
                "cell: `index` {raw_index} is not a non-negative exact integer"
            ));
        }
        let label = v
            .get("label")
            .and_then(Value::as_str)
            .ok_or_else(|| "cell: missing string `label`".to_string())?
            .to_string();
        let engine_seed = v
            .get("engine_seed")
            .and_then(Value::as_str)
            .ok_or_else(|| "cell: missing string `engine_seed`".to_string())?
            .parse::<u64>()
            .map_err(|e| format!("cell: bad engine_seed: {e}"))?;
        let metrics = Metrics::from_json(
            v.get("metrics").ok_or_else(|| "cell: missing `metrics`".to_string())?,
        )?;
        Ok(CellResult { index, label, engine_seed, metrics })
    }
}

/// Aggregate statistics over every cell (totals for counters; Welford
/// moments over the per-cell rates via `util::stats::Online`).
#[derive(Clone, Debug, Default)]
pub struct SummaryStats {
    pub released: u64,
    pub capture_missed: u64,
    pub queue_dropped: u64,
    pub scheduled: u64,
    pub correct: u64,
    pub deadline_missed: u64,
    pub reboots: u64,
    pub refragments: u64,
    pub commits: u64,
    pub restores: u64,
    pub lost_fragments: u64,
    pub commit_mj: f64,
    pub restore_mj: f64,
    pub harvested_mj: f64,
    pub wasted_mj: f64,
    pub scheduled_rate_mean: f64,
    pub scheduled_rate_std: f64,
    pub scheduled_rate_min: f64,
    pub scheduled_rate_max: f64,
    pub accuracy_mean: f64,
}

/// Incremental [`SummaryStats`] builder: push per-cell metrics **in
/// scenario-index order** and [`finish`]. Replays the exact f64 operation
/// sequence of the batch path ([`SweepReport::new`] delegates here), so a
/// streaming consumer — the serve dispatcher's out-of-core merger — can
/// produce a byte-identical summary without materializing the cell list.
///
/// [`finish`]: SummaryAccumulator::finish
#[derive(Clone, Debug)]
pub struct SummaryAccumulator {
    s: SummaryStats,
    rate: Online,
    acc: Online,
}

impl Default for SummaryAccumulator {
    fn default() -> Self {
        Self::new()
    }
}

impl SummaryAccumulator {
    pub fn new() -> Self {
        // NB: `Online::new()`, not `Online::default()` — the derived
        // default zeroes the min/max seeds the batch path relies on.
        SummaryAccumulator { s: SummaryStats::default(), rate: Online::new(), acc: Online::new() }
    }

    pub fn push(&mut self, m: &Metrics) {
        let s = &mut self.s;
        s.released += m.released;
        s.capture_missed += m.capture_missed;
        s.queue_dropped += m.queue_dropped;
        s.scheduled += m.scheduled;
        s.correct += m.correct;
        s.deadline_missed += m.deadline_missed;
        s.reboots += m.reboots;
        s.refragments += m.refragments;
        s.commits += m.commits;
        s.restores += m.restores;
        s.lost_fragments += m.lost_fragments;
        s.commit_mj += m.commit_mj;
        s.restore_mj += m.restore_mj;
        s.harvested_mj += m.harvested_mj;
        s.wasted_mj += m.wasted_mj;
        self.rate.push(m.event_scheduled_rate());
        self.acc.push(m.accuracy());
    }

    pub fn finish(mut self) -> SummaryStats {
        if self.rate.count() > 0 {
            self.s.scheduled_rate_mean = self.rate.mean();
            self.s.scheduled_rate_std = self.rate.std();
            self.s.scheduled_rate_min = self.rate.min();
            self.s.scheduled_rate_max = self.rate.max();
            self.s.accuracy_mean = self.acc.mean();
        }
        self.s
    }
}

impl SummaryStats {
    fn from_cells(cells: &[CellResult]) -> Self {
        let mut acc = SummaryAccumulator::new();
        for c in cells {
            acc.push(&c.metrics);
        }
        acc.finish()
    }

    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        let mut num = |k: &str, v: f64| {
            m.insert(k.to_string(), Value::Num(v));
        };
        num("released", self.released as f64);
        num("capture_missed", self.capture_missed as f64);
        num("queue_dropped", self.queue_dropped as f64);
        num("scheduled", self.scheduled as f64);
        num("correct", self.correct as f64);
        num("deadline_missed", self.deadline_missed as f64);
        num("reboots", self.reboots as f64);
        num("refragments", self.refragments as f64);
        num("commits", self.commits as f64);
        num("restores", self.restores as f64);
        num("lost_fragments", self.lost_fragments as f64);
        num("commit_mj", self.commit_mj);
        num("restore_mj", self.restore_mj);
        num("harvested_mj", self.harvested_mj);
        num("wasted_mj", self.wasted_mj);
        num("scheduled_rate_mean", self.scheduled_rate_mean);
        num("scheduled_rate_std", self.scheduled_rate_std);
        num("scheduled_rate_min", self.scheduled_rate_min);
        num("scheduled_rate_max", self.scheduled_rate_max);
        num("accuracy_mean", self.accuracy_mean);
        Value::Obj(m)
    }
}

/// The result of running a whole [`super::ScenarioMatrix`].
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub matrix_name: String,
    pub matrix_seed: u64,
    pub n_scenarios: usize,
    /// In matrix-expansion order (sorted by scenario index), regardless of
    /// which thread finished which cell first.
    pub cells: Vec<CellResult>,
    pub summary: SummaryStats,
}

impl SweepReport {
    pub fn new(matrix_name: &str, matrix_seed: u64, cells: Vec<CellResult>) -> Self {
        debug_assert!(cells.windows(2).all(|w| w[0].index < w[1].index));
        let summary = SummaryStats::from_cells(&cells);
        SweepReport {
            matrix_name: matrix_name.to_string(),
            matrix_seed,
            n_scenarios: cells.len(),
            cells,
            summary,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("matrix".to_string(), Value::Str(self.matrix_name.clone()));
        m.insert("matrix_seed".to_string(), Value::Str(self.matrix_seed.to_string()));
        m.insert("n_scenarios".to_string(), Value::Num(self.n_scenarios as f64));
        m.insert(
            "cells".to_string(),
            Value::Arr(self.cells.iter().map(|c| c.to_json()).collect()),
        );
        m.insert("summary".to_string(), self.summary.to_json());
        Value::Obj(m)
    }

    /// Canonical serialized form — the byte string the determinism tests
    /// compare across thread counts.
    pub fn json_string(&self) -> String {
        self.to_json().to_json()
    }

    /// Console table, one row per cell.
    pub fn print(&self) {
        println!(
            "\n== sweep `{}` (seed {}, {} scenarios) ==",
            self.matrix_name, self.matrix_seed, self.n_scenarios
        );
        println!(
            "{:<52} {:>9} {:>9} {:>9} {:>8} {:>8}",
            "scenario", "released", "sched%", "correct%", "missed", "reboots"
        );
        for c in &self.cells {
            let m = &c.metrics;
            println!(
                "{:<52} {:>9} {:>8.1}% {:>8.1}% {:>8} {:>8}",
                c.label,
                m.released,
                100.0 * m.event_scheduled_rate(),
                100.0 * m.event_correct_rate(),
                m.deadline_missed,
                m.reboots
            );
        }
        println!(
            "summary: scheduled {}/{} (rate mean {:.3} ± {:.3}, min {:.3}, max {:.3}), accuracy mean {:.3}",
            self.summary.scheduled,
            self.summary.released,
            self.summary.scheduled_rate_mean,
            self.summary.scheduled_rate_std,
            self.summary.scheduled_rate_min,
            self.summary.scheduled_rate_max,
            self.summary.accuracy_mean
        );
    }
}
