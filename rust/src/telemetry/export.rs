//! Trace exporters: Chrome `trace_event` JSON and line-delimited JSONL.
//!
//! The Chrome form loads directly into `chrome://tracing` or
//! <https://ui.perfetto.dev>: each scenario is one track (`pid 0`,
//! `tid` = scenario index, named via a `thread_name` metadata event),
//! fragments are `B`/`E` duration pairs, bulk fast-forwards are `X`
//! complete events spanning the replayed window, and everything else is
//! a thread-scoped instant (`ph: "i"`, `s: "t"`). Timestamps are
//! microseconds of *simulated* time (`ts = t_ms * 1000`), so the
//! timeline you scrub is the scenario's own clock, not wall time.
//!
//! The JSONL form is one `TraceEvent::to_json` object per line — the
//! compact, greppable stream for scripted analysis.
//!
//! `tools/trace_check.py` validates the Chrome output (phase vocabulary,
//! `B`/`E` balance per track, monotone timestamps) and CI runs it
//! against a traced sweep.

use std::collections::BTreeMap;

use super::{EventKind, TraceEvent};
use crate::util::json::Value;

/// One scenario's recorded events plus the identity of its track.
pub struct ScenarioTrace {
    /// Human-readable track name (the scenario label).
    pub label: String,
    /// Scenario index within its matrix — becomes the Chrome `tid`.
    pub index: usize,
    pub events: Vec<TraceEvent>,
}

/// Compact JSONL: one event object per line, trailing newline.
pub fn jsonl_string(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.to_json().to_json());
        out.push('\n');
    }
    out
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn num(x: f64) -> Value {
    Value::Num(x)
}

fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

/// Common fields of every emitted Chrome event.
fn base(ph: &str, name: &str, tid: usize, ts_us: f64) -> Vec<(&'static str, Value)> {
    // Leak-free &'static str keys: use fixed key names, values vary.
    vec![
        ("ph", s(ph)),
        ("name", s(name)),
        ("pid", num(0.0)),
        ("tid", num(tid as f64)),
        ("ts", num(ts_us)),
    ]
}

/// Chrome `trace_event` document for one or more scenario tracks.
pub fn chrome_trace(traces: &[ScenarioTrace]) -> Value {
    let mut events: Vec<Value> = Vec::new();
    for tr in traces {
        // Name the track after the scenario.
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("thread_name")),
            ("pid", num(0.0)),
            ("tid", num(tr.index as f64)),
            ("args", obj(vec![("name", s(&tr.label))])),
        ]));
        for ev in &tr.events {
            let ts = ev.t_ms * 1000.0;
            let energy = ("energy_mj", num(ev.energy_mj));
            match &ev.kind {
                EventKind::FragmentStart { task, job, unit } => {
                    let mut e =
                        base("B", &format!("frag t{task} u{unit}"), tr.index, ts);
                    e.push((
                        "args",
                        obj(vec![("job", num(*job as f64)), energy]),
                    ));
                    events.push(obj(e));
                }
                EventKind::FragmentEnd { task, unit, ok, .. } => {
                    let mut e =
                        base("E", &format!("frag t{task} u{unit}"), tr.index, ts);
                    e.push(("args", obj(vec![("ok", Value::Bool(*ok)), energy])));
                    events.push(obj(e));
                }
                EventKind::FastForward { regime, from_ms, ticks } => {
                    let mut e = base(
                        "X",
                        &format!("ff {}", regime.name()),
                        tr.index,
                        from_ms * 1000.0,
                    );
                    e.push(("dur", num((ev.t_ms - from_ms) * 1000.0)));
                    e.push((
                        "args",
                        obj(vec![("ticks", num(*ticks as f64)), energy]),
                    ));
                    events.push(obj(e));
                }
                _ => {
                    // Everything else is a thread-scoped instant carrying
                    // its JSONL payload as args.
                    let mut e = base("i", ev.kind_name(), tr.index, ts);
                    e.push(("s", s("t")));
                    let mut args = ev.to_json();
                    if let Value::Obj(m) = &mut args {
                        // kind/t_ms are redundant with name/ts here.
                        m.remove("kind");
                        m.remove("t_ms");
                    }
                    e.push(("args", args));
                    events.push(obj(e));
                }
            }
        }
    }
    obj(vec![
        ("displayTimeUnit", s("ms")),
        ("traceEvents", Value::Arr(events)),
    ])
}

/// [`chrome_trace`] serialized to a compact JSON string.
pub fn chrome_string(traces: &[ScenarioTrace]) -> String {
    chrome_trace(traces).to_json()
}

#[cfg(test)]
mod tests {
    use super::super::FfRegime;
    use super::*;

    fn sample() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                t_ms: 0.0,
                energy_mj: 2.0,
                kind: EventKind::Boot { outage_ms: 0.0 },
            },
            TraceEvent {
                t_ms: 10.0,
                energy_mj: 1.9,
                kind: EventKind::Release { task: 0, job: 0 },
            },
            TraceEvent {
                t_ms: 10.0,
                energy_mj: 1.9,
                kind: EventKind::FragmentStart { task: 0, job: 0, unit: 0 },
            },
            TraceEvent {
                t_ms: 15.0,
                energy_mj: 1.7,
                kind: EventKind::FragmentEnd { task: 0, job: 0, unit: 0, ok: true },
            },
            TraceEvent {
                t_ms: 115.0,
                energy_mj: 0.9,
                kind: EventKind::FastForward {
                    regime: FfRegime::Off,
                    from_ms: 15.0,
                    ticks: 20,
                },
            },
        ]
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line() {
        let text = jsonl_string(&sample());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        for line in lines {
            let v = Value::parse(line).expect("jsonl line parses");
            assert!(v.get("kind").is_some());
        }
    }

    #[test]
    fn chrome_trace_has_balanced_durations_and_valid_phases() {
        let doc = chrome_trace(&[ScenarioTrace {
            label: "cell".to_string(),
            index: 3,
            events: sample(),
        }]);
        let evs = doc.req("traceEvents").arr();
        // metadata + 5 events
        assert_eq!(evs.len(), 6);
        let mut depth = 0i64;
        for e in evs {
            let ph = e.req("ph").str();
            assert!(matches!(ph, "B" | "E" | "X" | "i" | "M"), "bad ph {ph}");
            match ph {
                "B" => depth += 1,
                "E" => {
                    depth -= 1;
                    assert!(depth >= 0);
                }
                "i" => assert!(e.get("s").is_some(), "instant without scope"),
                "X" => assert!(e.req("dur").f64() >= 0.0),
                _ => {}
            }
            if ph != "M" {
                assert_eq!(e.req("tid").f64(), 3.0);
            }
        }
        assert_eq!(depth, 0, "unbalanced B/E");
        // Fast-forward span: ts = from_ms µs, dur = span µs.
        let x = evs.iter().find(|e| e.req("ph").str() == "X").unwrap();
        assert_eq!(x.req("ts").f64(), 15_000.0);
        assert_eq!(x.req("dur").f64(), 100_000.0);
    }
}
