//! Deterministic telemetry: typed engine event traces and their sinks.
//!
//! The sweep engine's headline invariant is that `Metrics` /
//! `SweepReport::json_string()` are byte-identical across thread counts,
//! shards, serve workers, and the fast vs. reference steppers. Telemetry
//! must observe a run without ever joining it, so the contract here is
//! strict:
//!
//! * **Out-of-band.** A [`TraceSink`] only *receives* [`TraceEvent`]s.
//!   The engine's emission hooks read state (`now_ms`, capacitor energy,
//!   job ids) and never mutate anything the simulation observes — no RNG
//!   draws, no `Metrics` writes, no dispatch-path changes. Unlike
//!   `Engine::probe` (which pins the engine to per-tick stepping so it
//!   can observe every tick), an attached sink leaves the event-driven
//!   fast-forward loops fully engaged; bulk replays surface as
//!   [`EventKind::FastForward`] span events instead of per-tick samples.
//! * **Zero-cost when disabled.** Every hook is guarded by a single
//!   `Option` check on `Engine::trace`; with no sink attached nothing is
//!   constructed. `benches/bench_sweep.rs` measures the enabled-path
//!   (null sink) overhead against the disabled path and
//!   `tools/bench_gate.py` gates the ratio — the disabled path does
//!   strictly less work, so the gate bounds it too.
//! * **Byte-exactness is enforced**, not assumed:
//!   `rust/tests/telemetry_trace.rs` runs matrices traced and untraced
//!   and asserts the report bytes are identical.
//!
//! Event timestamps are the engine's true simulated time (`t_ms`), and
//! every event carries the capacitor energy at emission — the two axes
//! the paper's timing/overhead analyses (§8) plot everything against.
//! Exporters (Chrome `trace_event` JSON and line-delimited JSONL) live
//! in [`export`]; `zygarde trace` / `zygarde sweep --trace-dir` are the
//! CLI front-ends.
//!
//! Two campaign-scale siblings share the contract. [`registry`] is the
//! aggregate view: a deterministic counters/histograms [`registry::Registry`]
//! attached to the engine the same way a sink is (passive, `Option`-guarded,
//! byte-identical snapshots at any thread/shard count) and merged across
//! cells/shards by pure integer addition — `zygarde profile` is its
//! front-end. [`timeline`] is the serving-layer view: one Chrome
//! `trace_event` document per campaign (lease lifecycle spans, journal
//! recovery, simnet fault markers) behind `zygarde serve --trace-out` /
//! `zygarde simtest --trace-out`.

pub mod export;
pub mod registry;
pub mod timeline;

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::util::json::Value;

/// Which event-driven idle loop produced a [`EventKind::FastForward`]
/// span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FfRegime {
    /// `Engine::advance_off_phase`: MCU below boot voltage, dark window.
    Off,
    /// `Engine::advance_on_phase_idle`: MCU up but starved or idle.
    OnIdle,
}

impl FfRegime {
    pub fn name(self) -> &'static str {
        match self {
            FfRegime::Off => "off",
            FfRegime::OnIdle => "on-idle",
        }
    }
}

/// The typed payload of one engine event. Fragment start/end pairs are
/// the only duration-shaped events (they never nest: the engine executes
/// one fragment at a time); everything else is an instant, except
/// [`EventKind::FastForward`], which is a span *ending* at the event's
/// `t_ms` and starting at `from_ms`.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// MCU crossed the boot voltage (off → on edge).
    Boot { outage_ms: f64 },
    /// MCU browned out (on → off edge); volatile progress died.
    BrownOut { lost_fragments: u64 },
    /// One job's uncommitted fragments rolled back at a brown-out
    /// (emitted per affected job, right after the `BrownOut` instant).
    Rollback { task: usize, job: u64, lost_fragments: u64 },
    /// A job entered the queue (sensor event captured and released).
    Release { task: usize, job: u64 },
    /// An atomic fragment is about to execute.
    FragmentStart { task: usize, job: u64, unit: usize },
    /// The fragment finished (`ok`) or lost its work to a mid-fragment
    /// power failure (`!ok` — it will re-execute, SONIC-style).
    FragmentEnd { task: usize, job: u64, unit: usize, ok: bool },
    /// An NVM commit transaction took effect (`jit`: fired by the
    /// low-voltage trigger rather than a fragment/unit boundary).
    Commit { jit: bool, e_mj: f64, t_ms: f64 },
    /// A post-reboot NVM restore took effect.
    Restore { e_mj: f64, t_ms: f64 },
    /// A job left the system with its mandatory part done in time
    /// (counted in `Metrics::scheduled`).
    DeadlineMet { task: usize, job: u64 },
    /// A job left the system late or incomplete
    /// (counted in `Metrics::deadline_missed`).
    DeadlineMissed { task: usize, job: u64 },
    /// The per-tick probe (`Engine::probe`) observed this tick.
    Probe,
    /// A bulk fast-forward replayed `ticks` idle ticks in one call; the
    /// span covers `[from_ms, t_ms]`. No other event can fall strictly
    /// inside the span — that is exactly what the next-event budget
    /// proves, and what the well-formedness property test checks.
    FastForward { regime: FfRegime, from_ms: f64, ticks: u64 },
}

/// One recorded engine event: payload plus the true simulated time and
/// the capacitor's stored energy at emission.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub t_ms: f64,
    pub energy_mj: f64,
    pub kind: EventKind,
}

impl TraceEvent {
    /// Stable machine-readable event-type name (the `kind` field of the
    /// JSONL form and the event name of the Chrome form).
    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            EventKind::Boot { .. } => "boot",
            EventKind::BrownOut { .. } => "brown_out",
            EventKind::Rollback { .. } => "rollback",
            EventKind::Release { .. } => "release",
            EventKind::FragmentStart { .. } => "fragment_start",
            EventKind::FragmentEnd { .. } => "fragment_end",
            EventKind::Commit { .. } => "commit",
            EventKind::Restore { .. } => "restore",
            EventKind::DeadlineMet { .. } => "deadline_met",
            EventKind::DeadlineMissed { .. } => "deadline_missed",
            EventKind::Probe => "probe",
            EventKind::FastForward { .. } => "fast_forward",
        }
    }

    /// Flat JSON object: `kind`, `t_ms`, `energy_mj`, plus the payload
    /// fields of the variant. This is the JSONL line schema.
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        let mut num = |m: &mut BTreeMap<String, Value>, k: &str, v: f64| {
            m.insert(k.to_string(), Value::Num(v));
        };
        m.insert("kind".to_string(), Value::Str(self.kind_name().to_string()));
        num(&mut m, "t_ms", self.t_ms);
        num(&mut m, "energy_mj", self.energy_mj);
        match &self.kind {
            EventKind::Boot { outage_ms } => num(&mut m, "outage_ms", *outage_ms),
            EventKind::BrownOut { lost_fragments } => {
                num(&mut m, "lost_fragments", *lost_fragments as f64)
            }
            EventKind::Rollback { task, job, lost_fragments } => {
                num(&mut m, "task", *task as f64);
                num(&mut m, "job", *job as f64);
                num(&mut m, "lost_fragments", *lost_fragments as f64);
            }
            EventKind::Release { task, job }
            | EventKind::DeadlineMet { task, job }
            | EventKind::DeadlineMissed { task, job } => {
                num(&mut m, "task", *task as f64);
                num(&mut m, "job", *job as f64);
            }
            EventKind::FragmentStart { task, job, unit } => {
                num(&mut m, "task", *task as f64);
                num(&mut m, "job", *job as f64);
                num(&mut m, "unit", *unit as f64);
            }
            EventKind::FragmentEnd { task, job, unit, ok } => {
                num(&mut m, "task", *task as f64);
                num(&mut m, "job", *job as f64);
                num(&mut m, "unit", *unit as f64);
                m.insert("ok".to_string(), Value::Bool(*ok));
            }
            EventKind::Commit { jit, e_mj, t_ms } => {
                m.insert("jit".to_string(), Value::Bool(*jit));
                num(&mut m, "e_mj", *e_mj);
                num(&mut m, "cost_ms", *t_ms);
            }
            EventKind::Restore { e_mj, t_ms } => {
                num(&mut m, "e_mj", *e_mj);
                num(&mut m, "cost_ms", *t_ms);
            }
            EventKind::Probe => {}
            EventKind::FastForward { regime, from_ms, ticks } => {
                m.insert("regime".to_string(), Value::Str(regime.name().to_string()));
                num(&mut m, "from_ms", *from_ms);
                num(&mut m, "ticks", *ticks as f64);
            }
        }
        Value::Obj(m)
    }
}

/// Receives engine events. Implementations must be passive observers —
/// the engine's byte-exactness contract assumes `record` has no way to
/// influence the simulation (it gets the event by value and nothing
/// else).
pub trait TraceSink {
    fn record(&mut self, ev: TraceEvent);
}

/// In-memory sink with a shared handle: clone it, hand one clone to the
/// engine (`engine.trace = Some(Box::new(buf.clone()))`), and [`take`]
/// the recorded events from the other after `Engine::run` consumed the
/// engine (and with it, the boxed clone).
///
/// [`take`]: TraceBuffer::take
#[derive(Clone, Debug, Default)]
pub struct TraceBuffer {
    events: Rc<RefCell<Vec<TraceEvent>>>,
}

impl TraceBuffer {
    pub fn new() -> TraceBuffer {
        TraceBuffer::default()
    }

    /// Drain and return everything recorded so far.
    pub fn take(&self) -> Vec<TraceEvent> {
        self.events.borrow_mut().split_off(0)
    }

    pub fn len(&self) -> usize {
        self.events.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl TraceSink for TraceBuffer {
    fn record(&mut self, ev: TraceEvent) {
        self.events.borrow_mut().push(ev);
    }
}

/// Sink that counts events and drops them — the bench harness's probe
/// for the enabled-path overhead (hook firing + event construction,
/// none of the storage).
#[derive(Clone, Debug)]
pub struct CountingSink {
    count: Rc<Cell<u64>>,
}

impl CountingSink {
    pub fn new(count: Rc<Cell<u64>>) -> CountingSink {
        CountingSink { count }
    }
}

impl TraceSink for CountingSink {
    fn record(&mut self, _ev: TraceEvent) {
        self.count.set(self.count.get() + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_buffer_records_through_a_cloned_handle() {
        let buf = TraceBuffer::new();
        let mut sink: Box<dyn TraceSink> = Box::new(buf.clone());
        sink.record(TraceEvent {
            t_ms: 5.0,
            energy_mj: 1.25,
            kind: EventKind::Boot { outage_ms: 100.0 },
        });
        sink.record(TraceEvent {
            t_ms: 10.0,
            energy_mj: 1.0,
            kind: EventKind::Release { task: 0, job: 3 },
        });
        assert_eq!(buf.len(), 2);
        let evs = buf.take();
        assert!(buf.is_empty());
        assert_eq!(evs[0].kind_name(), "boot");
        assert_eq!(evs[1].kind_name(), "release");
    }

    #[test]
    fn jsonl_schema_carries_kind_and_payload() {
        let ev = TraceEvent {
            t_ms: 40.0,
            energy_mj: 0.5,
            kind: EventKind::FragmentEnd { task: 1, job: 9, unit: 2, ok: false },
        };
        let v = ev.to_json();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("fragment_end"));
        assert_eq!(v.get("t_ms").unwrap().as_f64(), Some(40.0));
        assert_eq!(v.get("unit").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn counting_sink_counts() {
        let n = Rc::new(Cell::new(0u64));
        let mut sink = CountingSink::new(n.clone());
        for i in 0..7 {
            sink.record(TraceEvent {
                t_ms: i as f64,
                energy_mj: 0.0,
                kind: EventKind::Probe,
            });
        }
        assert_eq!(n.get(), 7);
    }
}
