//! Unified cross-layer serve timeline: one Chrome `trace_event` document
//! for a whole campaign — lease lifecycle spans on per-worker tracks,
//! dispatcher progress, journal recovery, and simnet fault-plan markers.
//!
//! Where [`export`] renders one *cell's* engine events, this module
//! records the *serving* layer around the cells: which worker held which
//! lease when, where cells streamed in, when spill runs hit disk, what
//! the journal trusted after a crash, and when the (simulated) network
//! injected faults. `zygarde serve --trace-out F` stamps events with
//! wall-clock milliseconds since serve start; `zygarde simtest
//! --trace-out F` stamps them with the virtual clock, making the whole
//! file a pure function of the seed.
//!
//! # Track layout
//!
//! Everything lives in one process (`pid` [`PID`]):
//!
//! | tid                    | track        | events                          |
//! |------------------------|--------------|---------------------------------|
//! | [`TID_DISPATCH`]       | `dispatcher` | spill/progress/done instants    |
//! | [`TID_JOURNAL`]        | `journal`    | recovery + finalize instants    |
//! | [`TID_FAULTS`]         | `faults`     | crash/partition/dcrash/heal/... |
//! | [`TID_WORKER_BASE`]+w  | `worker w`   | lease spans, cells, connect/gone|
//!
//! Lease lifecycle spans are **retroactive `X` events**: opened in
//! memory at grant time, emitted with their full duration when the lease
//! resolves (`LeaseDone`, the holder's death, or campaign finalize), so
//! they are exempt from per-track stream order exactly like the engine
//! exporter's fast-forward spans. Every lease span carries `args` with
//! the lease id, its index range, the cells streamed under it, and an
//! `outcome` in `{done, gone, unresolved}` — `tools/trace_check.py
//! --timeline` validates all of this structurally.
//!
//! [`export`]: super::export

use std::collections::BTreeMap;

use crate::util::json::Value;

/// The single pid every track lives under.
pub const PID: u64 = 0;
/// Dispatcher progress track.
pub const TID_DISPATCH: u64 = 0;
/// Journal recovery/finalize track.
pub const TID_JOURNAL: u64 = 1;
/// Fault-plan marker track (simnet campaigns; empty under real serve).
pub const TID_FAULTS: u64 = 2;
/// Per-worker tracks start here: worker `w` is tid `TID_WORKER_BASE + w`.
pub const TID_WORKER_BASE: u64 = 100;

fn num(v: f64) -> Value {
    Value::Num(v)
}

fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

/// An in-flight lease: everything the eventual `X` span needs.
struct OpenLease {
    worker: u64,
    start: usize,
    end: usize,
    t_ms: u64,
    cells: u64,
}

/// Records campaign events and renders the Chrome document. All
/// timestamps are caller-provided milliseconds on one monotone clock
/// (wall-since-start or virtual), so the output bytes are a pure
/// function of the recorded sequence.
pub struct Timeline {
    label: String,
    events: Vec<Value>,
    open: BTreeMap<u64, OpenLease>,
    workers: std::collections::BTreeSet<u64>,
    used_journal: bool,
    used_faults: bool,
}

impl Timeline {
    pub fn new(label: &str) -> Timeline {
        Timeline {
            label: label.to_string(),
            events: Vec::new(),
            open: BTreeMap::new(),
            workers: std::collections::BTreeSet::new(),
            used_journal: false,
            used_faults: false,
        }
    }

    fn instant(&mut self, tid: u64, name: &str, t_ms: u64, args: Value) {
        let mut pairs = vec![
            ("ph", s("i")),
            ("name", s(name)),
            ("pid", num(PID as f64)),
            ("tid", num(tid as f64)),
            ("ts", num(t_ms as f64 * 1000.0)),
            ("s", s("t")),
        ];
        if !matches!(args, Value::Null) {
            pairs.push(("args", args));
        }
        self.events.push(obj(pairs));
    }

    fn worker_tid(&mut self, worker: u64) -> u64 {
        self.workers.insert(worker);
        TID_WORKER_BASE + worker
    }

    // --- worker / lease lifecycle -------------------------------------

    pub fn worker_connected(&mut self, worker: u64, t_ms: u64) {
        let tid = self.worker_tid(worker);
        self.instant(tid, "connect", t_ms, Value::Null);
    }

    pub fn worker_gone(&mut self, worker: u64, t_ms: u64) {
        let tid = self.worker_tid(worker);
        self.instant(tid, "gone", t_ms, Value::Null);
        // Every lease the dead worker still held resolves here: the
        // dispatcher will reissue the range under a fresh lease id.
        let held: Vec<u64> = self
            .open
            .iter()
            .filter(|(_, l)| l.worker == worker)
            .map(|(&id, _)| id)
            .collect();
        for id in held {
            self.lease_closed(id, t_ms, "gone");
        }
    }

    /// A lease left the dispatcher for `worker` (granted or stolen work;
    /// the span opens here and closes at `lease_closed`).
    pub fn lease_granted(&mut self, lease: u64, worker: u64, start: usize, end: usize, t_ms: u64) {
        self.worker_tid(worker);
        self.open.insert(lease, OpenLease { worker, start, end, t_ms, cells: 0 });
    }

    /// A `Cells` batch arrived under `lease`.
    pub fn lease_cells(&mut self, lease: u64, n: u64, t_ms: u64) {
        let Some(l) = self.open.get_mut(&lease) else { return };
        l.cells += n;
        let (worker, lease_id) = (l.worker, lease);
        let tid = self.worker_tid(worker);
        self.instant(
            tid,
            "cells",
            t_ms,
            obj(vec![("lease", num(lease_id as f64)), ("n", num(n as f64))]),
        );
    }

    /// The lease resolved; emit its retroactive span. `outcome` is one
    /// of `done` (LeaseDone received), `gone` (holder died), or
    /// `unresolved` (campaign finalized around it). Double closes (e.g.
    /// a duplicated LeaseDone delivery) are ignored.
    pub fn lease_closed(&mut self, lease: u64, t_ms: u64, outcome: &str) {
        let Some(l) = self.open.remove(&lease) else { return };
        let tid = TID_WORKER_BASE + l.worker;
        let dur_ms = t_ms.saturating_sub(l.t_ms);
        self.events.push(obj(vec![
            ("ph", s("X")),
            ("name", s(&format!("lease {lease}"))),
            ("pid", num(PID as f64)),
            ("tid", num(tid as f64)),
            ("ts", num(l.t_ms as f64 * 1000.0)),
            ("dur", num(dur_ms as f64 * 1000.0)),
            (
                "args",
                obj(vec![
                    ("lease", num(lease as f64)),
                    ("start", num(l.start as f64)),
                    ("end", num(l.end as f64)),
                    ("cells", num(l.cells as f64)),
                    ("outcome", s(outcome)),
                ]),
            ),
        ]));
    }

    // --- dispatcher ----------------------------------------------------

    /// A spill run hit disk (`runs` is the new total).
    pub fn spill_run(&mut self, runs: usize, t_ms: u64) {
        self.instant(
            TID_DISPATCH,
            "spill-run",
            t_ms,
            obj(vec![("runs", num(runs as f64))]),
        );
    }

    /// Every cell is ingested; the merge begins.
    pub fn dispatch_done(&mut self, cells: usize, t_ms: u64) {
        self.instant(
            TID_DISPATCH,
            "done",
            t_ms,
            obj(vec![("cells", num(cells as f64))]),
        );
    }

    // --- journal -------------------------------------------------------

    /// `journal::recover` finished: what the intact prefix yielded.
    pub fn journal_recovered(
        &mut self,
        t_ms: u64,
        intact_len: u64,
        torn_bytes: u64,
        runs: usize,
        n_received: usize,
    ) {
        self.used_journal = true;
        self.instant(
            TID_JOURNAL,
            "recover",
            t_ms,
            obj(vec![
                ("intact_len", num(intact_len as f64)),
                ("torn_bytes", num(torn_bytes as f64)),
                ("runs", num(runs as f64)),
                ("n_received", num(n_received as f64)),
            ]),
        );
    }

    /// One persisted spill run re-admitted (content hash re-verified).
    pub fn journal_run_adopted(&mut self, t_ms: u64, cells: usize) {
        self.used_journal = true;
        self.instant(
            TID_JOURNAL,
            "run-adopted",
            t_ms,
            obj(vec![("cells", num(cells as f64))]),
        );
    }

    /// The finalize marker landed; the journal is spent.
    pub fn journal_finalized(&mut self, t_ms: u64, n: usize) {
        self.used_journal = true;
        self.instant(
            TID_JOURNAL,
            "finalize",
            t_ms,
            obj(vec![("n_scenarios", num(n as f64))]),
        );
    }

    // --- faults (simnet) ----------------------------------------------

    /// A fault-plan event fired. `kind` is one of the marker names
    /// `tools/trace_check.py --timeline` accepts: `crash`, `partition`,
    /// `dcrash`, `heal`, `kick`, `relief`.
    pub fn fault(&mut self, kind: &str, t_ms: u64, detail: &str) {
        self.used_faults = true;
        let args = if detail.is_empty() {
            Value::Null
        } else {
            obj(vec![("detail", s(detail))])
        };
        self.instant(TID_FAULTS, kind, t_ms, args);
    }

    // --- render --------------------------------------------------------

    /// Close every still-open lease at `t_ms` and render the document.
    pub fn finish(mut self, t_ms: u64) -> String {
        let open: Vec<u64> = self.open.keys().copied().collect();
        for id in open {
            self.lease_closed(id, t_ms, "unresolved");
        }
        let mut events: Vec<Value> = Vec::with_capacity(self.events.len() + 8);
        let meta = |tid: u64, name: &str| {
            obj(vec![
                ("ph", s("M")),
                ("name", s("thread_name")),
                ("pid", num(PID as f64)),
                ("tid", num(tid as f64)),
                ("args", obj(vec![("name", s(name))])),
            ])
        };
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("process_name")),
            ("pid", num(PID as f64)),
            ("tid", num(TID_DISPATCH as f64)),
            ("args", obj(vec![("name", s(&self.label))])),
        ]));
        events.push(meta(TID_DISPATCH, "dispatcher"));
        if self.used_journal {
            events.push(meta(TID_JOURNAL, "journal"));
        }
        if self.used_faults {
            events.push(meta(TID_FAULTS, "faults"));
        }
        for &w in &self.workers {
            events.push(meta(TID_WORKER_BASE + w, &format!("worker {w}")));
        }
        events.append(&mut self.events);
        let doc = obj(vec![
            ("displayTimeUnit", s("ms")),
            ("traceEvents", Value::Arr(events)),
        ]);
        doc.to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_lifecycle_renders_a_span_with_args() {
        let mut tl = Timeline::new("test");
        tl.worker_connected(3, 1);
        tl.lease_granted(7, 3, 0, 4, 2);
        tl.lease_cells(7, 2, 5);
        tl.lease_cells(7, 2, 6);
        tl.lease_closed(7, 9, "done");
        // A duplicated LeaseDone must be a no-op.
        tl.lease_closed(7, 11, "done");
        let body = tl.finish(20);
        let doc = Value::parse(&body).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let span = events
            .iter()
            .find(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .expect("one lease span");
        assert_eq!(span.get("name").and_then(Value::as_str), Some("lease 7"));
        assert_eq!(span.get("ts").unwrap().as_f64(), Some(2000.0));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(7000.0));
        let args = span.get("args").unwrap();
        assert_eq!(args.get("cells").unwrap().as_f64(), Some(4.0));
        assert_eq!(args.get("outcome").and_then(Value::as_str), Some("done"));
        assert_eq!(
            events.iter().filter(|e| e.get("ph").and_then(Value::as_str) == Some("X")).count(),
            1,
            "the duplicate close must not emit a second span"
        );
    }

    #[test]
    fn dead_workers_resolve_their_leases_and_finalize_closes_the_rest() {
        let mut tl = Timeline::new("test");
        tl.lease_granted(1, 0, 0, 8, 10);
        tl.lease_granted(2, 1, 8, 16, 10);
        tl.worker_gone(0, 30);
        let body = tl.finish(50);
        let doc = Value::parse(&body).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let outcomes: Vec<String> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .map(|e| e.get("args").unwrap().get("outcome").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(outcomes, vec!["gone".to_string(), "unresolved".to_string()]);
    }

    #[test]
    fn tracks_are_named_only_when_used() {
        let mut tl = Timeline::new("quiet");
        tl.dispatch_done(4, 9);
        let body = tl.finish(9);
        assert!(body.contains("dispatcher"));
        assert!(!body.contains("\"journal\""));
        assert!(!body.contains("\"faults\""));

        let mut tl = Timeline::new("loud");
        tl.journal_recovered(5, 100, 3, 2, 16);
        tl.fault("dcrash", 6, "dcrash#0");
        let body = tl.finish(9);
        assert!(body.contains("\"journal\""));
        assert!(body.contains("\"faults\""));
        assert!(body.contains("intact_len"));
    }

    #[test]
    fn rendering_is_deterministic_in_the_recorded_sequence() {
        let build = || {
            let mut tl = Timeline::new("det");
            tl.worker_connected(0, 1);
            tl.lease_granted(1, 0, 0, 2, 2);
            tl.lease_cells(1, 2, 3);
            tl.lease_closed(1, 4, "done");
            tl.spill_run(1, 5);
            tl.dispatch_done(2, 6);
            tl.finish(6)
        };
        assert_eq!(build(), build());
    }
}
