//! Deterministic counters/histograms registry — the campaign-scale half
//! of the telemetry layer.
//!
//! A [`Registry`] is a fixed pair of arrays indexed by typed metric ids
//! ([`Counter`], [`Hist`]): no allocation on the record path, no string
//! lookups, no hashing. Like a [`TraceSink`], an attached registry is a
//! passive observer — every engine hook reads simulation state and adds
//! to a `u64`, so same seed ⇒ byte-identical [`snapshot`] at any thread
//! or shard count. Two design rules make that exact rather than
//! approximate:
//!
//! * **Integer units only.** Energy totals accumulate as *per-event
//!   rounded* microjoules (`(e_mj * 1000.0).round() as u64`), never as
//!   `f64` running sums — float addition is not associative, and the
//!   merge below must be order-independent the way `shard::merge` is.
//! * **Merge is pure `u64` addition.** [`Registry::merge`] adds
//!   counters, bucket counts, and totals element-wise, so any grouping
//!   of per-cell registries into shards, merged in any order, yields the
//!   same bytes as the single-process accumulation. `zygarde profile`
//!   composes across shards exactly like `zygarde merge` composes
//!   reports.
//!
//! # Snapshot JSON schema
//!
//! ```text
//! {
//!   "schema_version": 1,
//!   "counters": { "<metric-id>": <u64>, ... },
//!   "hists":    { "<metric-id>": { "buckets": [<u64>; 16],
//!                                   "count": <u64>,
//!                                   "total": <u64> }, ... }
//! }
//! ```
//!
//! Metric ids are dotted lowercase `layer.noun[_unit]` — `engine.*` for
//! the simulation core, `serve.*` for the dispatcher (see
//! [`DispatchStats::to_registry`]). Counters whose unit is not "events"
//! carry a suffix: `_uj` (microjoules), `_ticks`, `_ms`. Histograms use
//! log2 buckets: value `v` lands in bucket `floor(log2(v)) + 1`, clamped
//! to 15, with bucket 0 reserved for `v == 0` — the same bucketing as
//! the dispatcher's lease-latency histogram.
//!
//! [`snapshot`]: Registry::snapshot
//! [`TraceSink`]: super::TraceSink
//! [`DispatchStats::to_registry`]: crate::sim::sweep::serve::DispatchStats::to_registry

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::util::json::Value;

/// Version stamp carried by every snapshot (and by the compat
/// `--metrics-out` document): consumers can key parsing off it when the
/// schema grows.
pub const SCHEMA_VERSION: u64 = 1;

/// Log2 histogram width, shared with `DispatchStats::lease_latency_hist`.
pub const HIST_BUCKETS: usize = 16;

/// Typed counter ids. The `usize` discriminant is the array index;
/// `name()` is the snapshot key. Keep [`Counter::ALL`] in declaration
/// order — the snapshot iterates it (BTreeMap re-sorts by name anyway,
/// but `ALL` is also the exhaustiveness anchor for tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Ticks spent with the MCU below boot voltage (dark window).
    TicksOff,
    /// Ticks spent powered on but idle (no runnable fragment).
    TicksOnIdle,
    /// Ticks observed by the per-tick probe path (probe pins the engine
    /// to naive stepping, so these are genuine single ticks).
    TicksProbed,
    /// Tick-equivalents spent executing fragments (`frag_ms / dt`,
    /// rounded per fragment).
    TicksActive,
    /// Boundary/JIT NVM commit transactions.
    Commits,
    /// The subset of commits fired by the low-voltage JIT trigger.
    JitCommits,
    /// Brown-out rollbacks (one per on→off edge that lost progress).
    Rollbacks,
    /// Uncommitted fragments lost across all rollbacks.
    RollbackLostFragments,
    /// Post-reboot NVM restore transactions.
    Restores,
    /// Energy spent in commit transactions, microjoules (rounded per
    /// event).
    CommitUj,
    /// Energy spent in restore transactions, microjoules.
    RestoreUj,
    /// Bulk fast-forward calls in the off regime.
    FfOffJumps,
    /// Bulk fast-forward calls in the powered-on idle regime.
    FfOnIdleJumps,
    /// Dispatcher: leases granted (initial grants + steals + reissues).
    ServeLeasesGranted,
    /// Dispatcher: tail-steal grants.
    ServeSteals,
    /// Dispatcher: timed-out leases reissued.
    ServeReissues,
    /// Dispatcher: duplicate cell deliveries dropped by per-index dedup.
    ServeDuplicates,
    /// Dispatcher: distinct workers that completed the handshake.
    ServeWorkersSeen,
    /// Dispatcher: cells accepted (first delivery per index).
    ServeCellsReceived,
}

impl Counter {
    pub const ALL: &'static [Counter] = &[
        Counter::TicksOff,
        Counter::TicksOnIdle,
        Counter::TicksProbed,
        Counter::TicksActive,
        Counter::Commits,
        Counter::JitCommits,
        Counter::Rollbacks,
        Counter::RollbackLostFragments,
        Counter::Restores,
        Counter::CommitUj,
        Counter::RestoreUj,
        Counter::FfOffJumps,
        Counter::FfOnIdleJumps,
        Counter::ServeLeasesGranted,
        Counter::ServeSteals,
        Counter::ServeReissues,
        Counter::ServeDuplicates,
        Counter::ServeWorkersSeen,
        Counter::ServeCellsReceived,
    ];

    pub const COUNT: usize = Counter::ALL.len();

    /// Snapshot key: dotted lowercase `layer.noun[_unit]`.
    pub fn name(self) -> &'static str {
        match self {
            Counter::TicksOff => "engine.ticks_off",
            Counter::TicksOnIdle => "engine.ticks_on_idle",
            Counter::TicksProbed => "engine.ticks_probed",
            Counter::TicksActive => "engine.ticks_active",
            Counter::Commits => "engine.commits",
            Counter::JitCommits => "engine.jit_commits",
            Counter::Rollbacks => "engine.rollbacks",
            Counter::RollbackLostFragments => "engine.rollback_lost_fragments",
            Counter::Restores => "engine.restores",
            Counter::CommitUj => "engine.commit_uj",
            Counter::RestoreUj => "engine.restore_uj",
            Counter::FfOffJumps => "engine.ff_off_jumps",
            Counter::FfOnIdleJumps => "engine.ff_on_idle_jumps",
            Counter::ServeLeasesGranted => "serve.leases_granted",
            Counter::ServeSteals => "serve.steals",
            Counter::ServeReissues => "serve.reissues",
            Counter::ServeDuplicates => "serve.duplicates",
            Counter::ServeWorkersSeen => "serve.workers_seen",
            Counter::ServeCellsReceived => "serve.cells_received",
        }
    }
}

/// Typed histogram ids. The six `Ff*` histograms record bulk
/// fast-forward jump sizes (in ticks) *attributed by the bounding
/// event*: each jump's budget is the minimum over the active next-event
/// legs, and the jump is observed under the leg that bound it
/// (tie-break priority is declaration order here — release first,
/// horizon last — fixed so attribution is deterministic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// Bound by the next task release.
    FfRelease,
    /// Bound by the earliest believed deadline (clock-skew adjusted).
    FfDeadline,
    /// Bound by a predicted boot / brown-out voltage crossing.
    FfBoot,
    /// Bound by a harvester window edge (duty-cycle transition).
    FfWindow,
    /// Bound by the JIT commit trigger voltage crossing.
    FfJit,
    /// Bound by the scenario horizon (`duration_ms`).
    FfHorizon,
    /// Dispatcher lease grant→completion latency, milliseconds (injected
    /// whole by [`DispatchStats::to_registry`], same bucketing).
    ///
    /// [`DispatchStats::to_registry`]: crate::sim::sweep::serve::DispatchStats::to_registry
    ServeLeaseLatencyMs,
}

impl Hist {
    pub const ALL: &'static [Hist] = &[
        Hist::FfRelease,
        Hist::FfDeadline,
        Hist::FfBoot,
        Hist::FfWindow,
        Hist::FfJit,
        Hist::FfHorizon,
        Hist::ServeLeaseLatencyMs,
    ];

    pub const COUNT: usize = Hist::ALL.len();

    pub fn name(self) -> &'static str {
        match self {
            Hist::FfRelease => "engine.ff_ticks_release",
            Hist::FfDeadline => "engine.ff_ticks_deadline",
            Hist::FfBoot => "engine.ff_ticks_boot",
            Hist::FfWindow => "engine.ff_ticks_window",
            Hist::FfJit => "engine.ff_ticks_jit",
            Hist::FfHorizon => "engine.ff_ticks_horizon",
            Hist::ServeLeaseLatencyMs => "serve.lease_latency_ms",
        }
    }
}

/// One log2 histogram: bucket counts plus exact count/total so means
/// survive the bucketing.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistData {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    /// Sum of observed values (saturating — ticks never approach 2^64).
    pub total: u64,
}

impl HistData {
    pub fn observe(&mut self, v: u64) {
        self.buckets[log2_bucket(v)] += 1;
        self.count += 1;
        self.total = self.total.saturating_add(v);
    }

    pub fn merge(&mut self, other: &HistData) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.total = self.total.saturating_add(other.total);
    }
}

/// Bucket index for a log2 histogram: 0 holds exactly the zeros, bucket
/// `b >= 1` holds `[2^(b-1), 2^b)`, and the last bucket absorbs the
/// tail. Mirrors `DispatchStats::latency_bucket`.
pub fn log2_bucket(v: u64) -> usize {
    if v == 0 {
        return 0;
    }
    ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Round a millijoule quantity to integer microjoules — the per-event
/// conversion every energy counter goes through, so merges stay pure
/// integer addition.
pub fn mj_to_uj(e_mj: f64) -> u64 {
    let uj = (e_mj * 1000.0).round();
    if uj <= 0.0 {
        0
    } else {
        uj as u64
    }
}

/// The registry itself: two fixed arrays. `Default`/`new` start all-zero.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Registry {
    counters: [u64; Counter::COUNT],
    hists: [HistData; Hist::COUNT],
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    #[inline]
    pub fn add(&mut self, c: Counter, n: u64) {
        self.counters[c as usize] += n;
    }

    #[inline]
    pub fn observe(&mut self, h: Hist, v: u64) {
        self.hists[h as usize].observe(v);
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn hist(&self, h: Hist) -> &HistData {
        &self.hists[h as usize]
    }

    /// Mutable histogram access for layers that maintain their own
    /// bucket arrays and inject them whole (the dispatcher's
    /// lease-latency histogram) rather than observing per event.
    pub fn hist_mut(&mut self, h: Hist) -> &mut HistData {
        &mut self.hists[h as usize]
    }

    /// True when nothing has been recorded.
    pub fn is_zero(&self) -> bool {
        self.counters.iter().all(|&c| c == 0) && self.hists.iter().all(|h| h.count == 0)
    }

    /// Fold `other` into `self`. Pure element-wise `u64` addition:
    /// commutative and associative, so any merge tree over any grouping
    /// of registries produces identical bytes.
    pub fn merge(&mut self, other: &Registry) {
        for (a, b) in self.counters.iter_mut().zip(other.counters.iter()) {
            *a += b;
        }
        for (a, b) in self.hists.iter_mut().zip(other.hists.iter()) {
            a.merge(b);
        }
    }

    /// The snapshot document (see the module docs for the schema).
    /// Counters serialize as JSON numbers — every value here is far
    /// below 2^53, and the in-crate writer prints integral floats as
    /// integers, so the bytes are stable.
    pub fn snapshot(&self) -> Value {
        let mut counters = BTreeMap::new();
        for &c in Counter::ALL {
            counters.insert(c.name().to_string(), Value::Num(self.get(c) as f64));
        }
        let mut hists = BTreeMap::new();
        for &h in Hist::ALL {
            let d = self.hist(h);
            let mut obj = BTreeMap::new();
            obj.insert(
                "buckets".to_string(),
                Value::Arr(d.buckets.iter().map(|&b| Value::Num(b as f64)).collect()),
            );
            obj.insert("count".to_string(), Value::Num(d.count as f64));
            obj.insert("total".to_string(), Value::Num(d.total as f64));
            hists.insert(h.name().to_string(), Value::Obj(obj));
        }
        let mut m = BTreeMap::new();
        m.insert("schema_version".to_string(), Value::Num(SCHEMA_VERSION as f64));
        m.insert("counters".to_string(), Value::Obj(counters));
        m.insert("hists".to_string(), Value::Obj(hists));
        Value::Obj(m)
    }

    /// Snapshot rendered to its canonical byte form — the unit of every
    /// determinism comparison.
    pub fn snapshot_string(&self) -> String {
        self.snapshot().to_json()
    }
}

/// Shared handle for attaching a [`Registry`] to an engine whose `run`
/// consumes it — the same retrieval idiom as [`TraceBuffer`]: clone the
/// handle, hand one clone to the engine, `take()` the accumulated
/// registry afterwards. Engines are single-threaded per cell, so a
/// plain `Rc<RefCell<..>>` suffices (the extracted [`Registry`] itself
/// is `Send` and crosses sweep-worker joins by value).
///
/// [`TraceBuffer`]: super::TraceBuffer
#[derive(Clone, Debug, Default)]
pub struct RegistryHandle {
    inner: Rc<RefCell<Registry>>,
}

impl RegistryHandle {
    pub fn new() -> RegistryHandle {
        RegistryHandle::default()
    }

    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        self.inner.borrow_mut().add(c, n);
    }

    #[inline]
    pub fn observe(&self, h: Hist, v: u64) {
        self.inner.borrow_mut().observe(h, v);
    }

    /// Extract the accumulated registry, leaving the handle zeroed.
    pub fn take(&self) -> Registry {
        self.inner.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_buckets_have_the_documented_edges() {
        assert_eq!(log2_bucket(0), 0);
        assert_eq!(log2_bucket(1), 1);
        assert_eq!(log2_bucket(2), 2);
        assert_eq!(log2_bucket(3), 2);
        assert_eq!(log2_bucket(4), 3);
        assert_eq!(log2_bucket(1 << 13), 14);
        assert_eq!(log2_bucket(1 << 14), 15);
        assert_eq!(log2_bucket(u64::MAX), 15);
    }

    #[test]
    fn mj_rounds_to_integer_microjoules() {
        assert_eq!(mj_to_uj(0.0), 0);
        assert_eq!(mj_to_uj(0.0004), 0);
        assert_eq!(mj_to_uj(0.0006), 1);
        assert_eq!(mj_to_uj(1.25), 1250);
        assert_eq!(mj_to_uj(-1.0), 0);
    }

    #[test]
    fn merge_is_order_independent() {
        let mk = |seed: u64| {
            let mut r = Registry::new();
            for i in 0..20u64 {
                let v = seed.wrapping_mul(0x9E37_79B9).wrapping_add(i * 7) % 1000;
                r.add(Counter::TicksOff, v);
                r.observe(Hist::FfRelease, v);
                r.add(Counter::CommitUj, mj_to_uj(v as f64 * 0.123));
            }
            r
        };
        let parts: Vec<Registry> = (0..5).map(mk).collect();
        let mut fwd = Registry::new();
        for p in &parts {
            fwd.merge(p);
        }
        let mut rev = Registry::new();
        for p in parts.iter().rev() {
            rev.merge(p);
        }
        // Pairwise tree: ((0+3)+(4+1))+2
        let mut a = parts[0].clone();
        a.merge(&parts[3]);
        let mut b = parts[4].clone();
        b.merge(&parts[1]);
        a.merge(&b);
        a.merge(&parts[2]);
        assert_eq!(fwd.snapshot_string(), rev.snapshot_string());
        assert_eq!(fwd.snapshot_string(), a.snapshot_string());
        assert_eq!(fwd, rev);
    }

    #[test]
    fn snapshot_schema_is_stable_and_versioned() {
        let mut r = Registry::new();
        r.add(Counter::Commits, 3);
        r.observe(Hist::FfHorizon, 1024);
        let v = r.snapshot();
        assert_eq!(v.get("schema_version").unwrap().as_f64(), Some(1.0));
        let counters = v.get("counters").unwrap();
        assert_eq!(counters.get("engine.commits").unwrap().as_f64(), Some(3.0));
        assert_eq!(counters.get("engine.ticks_off").unwrap().as_f64(), Some(0.0));
        let h = v.get("hists").unwrap().get("engine.ff_ticks_horizon").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64(), Some(1.0));
        assert_eq!(h.get("total").unwrap().as_f64(), Some(1024.0));
        let buckets = h.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), HIST_BUCKETS);
        assert_eq!(buckets[11].as_f64(), Some(1.0));
        // Every declared id appears exactly once; names are dotted
        // lowercase (the naming convention the README documents).
        for &c in Counter::ALL {
            assert!(counters.get(c.name()).is_some(), "missing {}", c.name());
            assert!(c.name().contains('.') && c.name() == c.name().to_lowercase());
        }
        for &h in Hist::ALL {
            assert!(v.get("hists").unwrap().get(h.name()).is_some());
        }
        // Byte-stability: same registry, same string.
        assert_eq!(r.snapshot_string(), r.snapshot_string());
    }

    #[test]
    fn zero_registry_knows_it_is_zero() {
        let mut r = Registry::new();
        assert!(r.is_zero());
        r.observe(Hist::FfJit, 0);
        assert!(!r.is_zero(), "a zero-valued observation still counts");
    }
}
