//! `zygarde` — the leader binary: runs any paper experiment from the CLI.
//!
//! Usage: `zygarde <experiment> [--flags]`. Run with no arguments (or
//! `help`) for the experiment list. `zygarde all` regenerates every table
//! and figure in DESIGN.md §3 at the paper's full workload sizes.

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::dnn::network::Network;
use zygarde::exp;
use zygarde::util::cli::Args;

const HELP: &str = "\
zygarde — Zygarde (IMWUT 2020) reproduction driver

experiments (DESIGN.md §3):
  eta            Fig. 4 h(N) distributions + Fig. 25 eta validation
  threshold      Fig. 8 utility-threshold trade-off     [--dataset cifar100 --layer 0]
  overhead       Fig. 14 component overheads (ESC-10)
  loss-compare   Fig. 15 loss functions under early exit
  termination    Fig. 16 termination policies
  schedule       Figs. 17-20 EDF / EDF-M / Zygarde      [--dataset mnist --jobs N --systems 1,2,...]
  capacitor      Fig. 21 capacitor-size sweep           [--jobs N]
  nvm            NVM commit-policy comparison (ideal / FRAM every-fragment
                 / unit-boundary / JIT voltage-triggered) [--jobs N]
  chrt           Table 5 RTC vs CHRT remanence clock    [--jobs N]
  acoustic       Fig. 22 six acoustic applications      [--minutes 10]
  visual         Fig. 23 multi-task visual sensing      [--minutes 10]
  classifiers    Table 7 CNN vs traditional classifiers
  adaptation     Fig. 24 semi-supervised adaptation
  schedulability Sec. 5.3 necessary condition
  infer          run PJRT inference over a test set     [--dataset mnist --samples N]
  all            everything above at paper-scale sizes

common flags: --seed N (default 7), --jobs N, --dataset NAME
";

fn main() {
    let args = Args::from_env();
    let seed = args.u64_or("seed", 7);
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "help" | "--help" | "-h" => print!("{HELP}"),
        "eta" => {
            let studies = exp::eta::run(args.usize_or("max-n", 20), seed);
            exp::eta::print_figure4(&studies);
            exp::eta::print_figure25(&studies);
        }
        "threshold" => {
            let ds = args.str_or("dataset", "cifar100");
            let net = Network::load(&zygarde::artifacts_root().join(ds)).expect("artifacts");
            let layer = args.usize_or("layer", 0);
            let pts = exp::threshold::sweep_layer(&net, layer, args.usize_or("points", 16));
            exp::threshold::print(&net, layer, &pts);
        }
        "overhead" => {
            let net = Network::load(&zygarde::artifacts_root().join("esc10")).expect("artifacts");
            exp::overhead::print(&exp::overhead::run(&net));
        }
        "loss-compare" => {
            exp::loss_compare::print(&exp::loss_compare::run(&["mnist", "esc10"]));
        }
        "termination" => {
            exp::termination::print(&exp::termination::run(&[
                "mnist", "esc10", "cifar100", "vww",
            ]));
        }
        "schedule" => {
            let ds = args.str_or("dataset", "mnist").to_string();
            let systems: Vec<usize> = args
                .opt_str("systems")
                .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
                .unwrap_or_else(|| (1..=7).collect());
            let jobs = args.opt_str("jobs").map(|j| j.parse().unwrap());
            let cells = exp::schedule::run(&ds, &systems, jobs, seed);
            exp::schedule::print(&ds, &cells);
        }
        "capacitor" => {
            let cells = exp::capacitor_sweep::run(args.u64_or("jobs", 200), seed);
            exp::capacitor_sweep::print(&cells);
        }
        "nvm" => {
            let (matrix, report) = exp::nvm_cmp::run(args.u64_or("jobs", 300), seed);
            exp::nvm_cmp::print(&exp::nvm_cmp::summarize(&matrix, &report));
        }
        "chrt" => {
            let rows = exp::chrt_cmp::run(args.u64_or("jobs", 2000), seed);
            exp::chrt_cmp::print(&rows);
        }
        "acoustic" => {
            let mins = args.f64_or("minutes", 10.0);
            let results = exp::acoustic::run(mins * 60_000.0, seed);
            exp::acoustic::print(&results);
        }
        "visual" => {
            let mins = args.f64_or("minutes", 10.0);
            let cells = exp::visual::run(mins * 60_000.0, seed);
            exp::visual::print(&cells);
        }
        "classifiers" => {
            exp::classifiers_cmp::print(&exp::classifiers_cmp::run(&[
                "mnist", "esc10", "cifar100", "vww",
            ]));
        }
        "adaptation" => {
            exp::adaptation::print(&exp::adaptation::run());
        }
        "schedulability" => {
            let rows = exp::schedulability::run(
                &["mnist", "esc10", "cifar100", "vww"],
                &[0.38, 0.51, 0.71, 0.9],
            );
            exp::schedulability::print(&rows);
        }
        "infer" => run_infer(&args),
        "all" => run_all(seed, &args),
        other => {
            eprintln!("unknown experiment `{other}`\n");
            print!("{HELP}");
            std::process::exit(2);
        }
    }
}

/// End-to-end PJRT inference: load the AOT per-unit HLO artifacts, run the
/// agile DNN with early exit over test samples, report accuracy + exit
/// histogram + latency. This is the serving path (Python never runs).
fn run_infer(args: &Args) {
    let ds = args.str_or("dataset", "mnist");
    let n = args.usize_or("samples", 50);
    let dir = zygarde::artifacts_root().join(ds);
    let net = Network::load(&dir).expect("artifacts");
    let mut rt = zygarde::runtime::Runtime::cpu().expect("PJRT client");
    rt.load_network(&dir, &net.meta).expect("load units");
    println!(
        "loaded {} units of `{ds}` on {} (PJRT)",
        rt.loaded_units(),
        rt.platform()
    );

    let mut exit_hist = vec![0usize; net.meta.n_layers];
    let mut correct = 0usize;
    let n = n.min(net.test.len());
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let mut act = net.test.sample(i).to_vec();
        let mut pred = None;
        for li in 0..net.meta.n_layers {
            let (next, dists) = rt
                .execute_unit(ds, li, &act, &net.classifiers[li].centroids)
                .expect("execute");
            let res = net.classifiers[li].classify_from_dists(&dists);
            pred = Some(res.pred);
            if res.exit || li == net.meta.n_layers - 1 {
                exit_hist[li] += 1;
                break;
            }
            act = next;
        }
        if pred == Some(net.test.y[i]) {
            correct += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "{n} samples: accuracy {:.1}%  mean latency {:.2} ms  exit histogram {:?}",
        100.0 * correct as f64 / n as f64,
        dt.as_secs_f64() * 1e3 / n as f64,
        exit_hist
    );
}

fn run_all(seed: u64, args: &Args) {
    let studies = exp::eta::run(20, seed);
    exp::eta::print_figure4(&studies);
    exp::eta::print_figure25(&studies);

    let net = Network::load(&zygarde::artifacts_root().join("cifar100")).expect("artifacts");
    let pts = exp::threshold::sweep_layer(&net, 0, 16);
    exp::threshold::print(&net, 0, &pts);

    let esc = Network::load(&zygarde::artifacts_root().join("esc10")).expect("artifacts");
    exp::overhead::print(&exp::overhead::run(&esc));
    exp::loss_compare::print(&exp::loss_compare::run(&["mnist", "esc10"]));
    exp::termination::print(&exp::termination::run(&["mnist", "esc10", "cifar100", "vww"]));

    for ds in ["mnist", "esc10", "cifar100", "vww"] {
        let jobs = args.opt_str("jobs").map(|j| j.parse().unwrap());
        let cells = exp::schedule::run(ds, &(1..=7).collect::<Vec<_>>(), jobs, seed);
        exp::schedule::print(ds, &cells);
    }

    exp::capacitor_sweep::print(&exp::capacitor_sweep::run(args.u64_or("jobs", 200), seed));
    {
        let (matrix, report) = exp::nvm_cmp::run(args.u64_or("nvm-jobs", 300), seed);
        exp::nvm_cmp::print(&exp::nvm_cmp::summarize(&matrix, &report));
    }
    exp::chrt_cmp::print(&exp::chrt_cmp::run(args.u64_or("chrt-jobs", 2000), seed));
    exp::acoustic::print(&exp::acoustic::run(600_000.0, seed));
    exp::visual::print(&exp::visual::run(600_000.0, seed));
    exp::classifiers_cmp::print(&exp::classifiers_cmp::run(&[
        "mnist", "esc10", "cifar100", "vww",
    ]));
    exp::adaptation::print(&exp::adaptation::run());
    exp::schedulability::print(&exp::schedulability::run(
        &["mnist", "esc10", "cifar100", "vww"],
        &[0.38, 0.51, 0.71, 0.9],
    ));

    // Cross-check SchedulerKind exposure for the CLI docs.
    let _ = SchedulerKind::Zygarde.name();
}
