//! `zygarde` — the leader binary: runs any paper experiment from the CLI.
//!
//! Usage: `zygarde <experiment> [--flags]`. Run with no arguments (or
//! `help`) for the experiment list. `zygarde all` regenerates every table
//! and figure in DESIGN.md §3 at the paper's full workload sizes.

use zygarde::coordinator::sched::SchedulerKind;
use zygarde::dnn::network::Network;
use zygarde::exp;
use zygarde::exp::sweep_cli::{self, SweepOpts};
use zygarde::nvm::NvmSpec;
use zygarde::sim::sweep::{self, ShardSpec};
use zygarde::util::cli::Args;

const HELP: &str = "\
zygarde — Zygarde (IMWUT 2020) reproduction driver

experiments (DESIGN.md §3):
  eta            Fig. 4 h(N) distributions + Fig. 25 eta validation
  threshold      Fig. 8 utility-threshold trade-off     [--dataset cifar100 --layer 0]
  overhead       Fig. 14 component overheads (ESC-10)
  loss-compare   Fig. 15 loss functions under early exit
  termination    Fig. 16 termination policies
  schedule       Figs. 17-20 EDF / EDF-M / Zygarde      [--dataset mnist --jobs N --systems 1,2,...
                                                         --nvm ideal,fram-jit]
  capacitor      Fig. 21 capacitor-size sweep           [--jobs N --nvm fram-unit]
  nvm            NVM commit-policy comparison (ideal / FRAM every-fragment
                 / unit-boundary / JIT voltage-triggered) [--jobs N]
  chrt           Table 5 RTC vs CHRT remanence clock    [--jobs N]
  acoustic       Fig. 22 six acoustic applications      [--minutes 10]
  visual         Fig. 23 multi-task visual sensing      [--minutes 10]
  classifiers    Table 7 CNN vs traditional classifiers
  adaptation     Fig. 24 semi-supervised adaptation
  schedulability Sec. 5.3 necessary condition
  infer          run PJRT inference over a test set     [--dataset mnist --samples N]
  all            everything above at paper-scale sizes

sharded execution (scale any sweep across processes / hosts):
  sweep          run a named scenario matrix, whole or one shard of it
                 [--matrix synthetic|bench|nvm|schedule|capacitor|chrt]
                 [--shard I/N --threads N --jobs N --reps N --duration-ms X
                  --dataset NAME --systems 1,2 --nvm ideal,fram-jit --out FILE]
                 with --shard: writes a PartialReport JSON (default
                 shard_I_of_N.json); without: writes/prints the SweepReport
                 [--trace-dir DIR --trace-every N] additionally re-runs every
                 Nth cell (default 8) with tracing and writes Chrome JSON
                 traces into DIR (out-of-band: the report bytes don't change);
                 under --shard I/N only the shard's own cells are traced,
                 into trace_sI_cXXXXX.json (shards can share one DIR)
  merge          zygarde merge shard_*.json [--out report.json] [--table]
                 reassembles shards into the byte-identical single-process
                 report; rejects shards from mismatched matrices

observability:
  trace          run ONE cell of a named matrix with the telemetry sink on
                 and export its event trace
                 [--matrix NAME --index I --format chrome|jsonl --out FILE
                  + the sweep matrix flags (--seed/--jobs/--reps/...)]
                 chrome: load in chrome://tracing or ui.perfetto.dev;
                 jsonl: one flat event object per line (see README)
  profile        run a named matrix with the metric registry attached to
                 every cell and print the per-axis time-and-energy
                 waterfall: tick occupancy per regime (off / on-idle /
                 probed / active), bulk fast-forward jumps by bounding
                 event, NVM commit/rollback/restore costs
                 [--matrix NAME --by mix|harvester|cap|sched|exit|fault|
                  nvm|rep (default harvester) --threads N --out FILE
                  + the sweep matrix flags (--seed/--jobs/--reps/...)]
                 table to stdout; --out writes the snapshot JSON (schema
                 in README \"Observability\"); registries are passive, so
                 the sweep itself is byte-identical to an unprofiled run

streaming execution (work-stealing dispatcher, out-of-core merge):
  serve          dispatch a named matrix as fine-grained leases to workers
                 and stream-merge their results into --out (byte-identical
                 to the single-process report)
                 [--matrix NAME --workers N --worker-threads N
                  --listen HOST:PORT --lease N --lease-timeout-ms X
                  --spill-cells N --spill-dir DIR --out report.json --quiet
                  --metrics-out metrics.json --heartbeat-ms X
                  --trace-out trace.json --journal FILE | --resume FILE
                  + the sweep matrix flags (--seed/--jobs/--reps/...)]
                 --trace-out: Chrome trace_event timeline of the campaign
                 (lease spans per worker, spill/journal instants), stamped
                 with wall-clock ms since serve start
                 --journal: checksummed write-ahead log of received ranges
                 + spill runs; after a crash, --resume FILE rebuilds the
                 received bitmap, re-admits the persisted runs, and leases
                 out only the missing cells — the report stays
                 byte-identical (see README \"Crash recovery\")
  work           run leases for a dispatcher until it shuts us down
                 [--connect -|HOST:PORT --threads N --batch N
                  --retry N --retry-base-ms X --retry-seed N]
                 `-` speaks the protocol on stdin/stdout (what
                 `serve --workers N` spawns); HOST:PORT joins over TCP
                 --retry: survive a dispatcher restart — reconnect with
                 bounded exponential backoff (jitter from a seeded rng)
                 and re-handshake; a refused reconnect after real work
                 exits 0 (\"dispatcher finalized\")

deterministic simulation (single thread, virtual clock, no sockets):
  simtest        run a whole serve campaign over a seeded simulated
                 network — latency, reordering, duplication, drops,
                 partitions, worker crashes, dispatcher crash+resume
                 (faults key dcrash=N, recovered through the real
                 journal) — and verify the streamed report is
                 byte-identical to the single-process sweep
                 [--seed N --workers N --faults SPEC|none --lease N
                  --lease-timeout-ms X --spill-cells N --threads N
                  --out report.json --log events.log --trace-out trace.json
                  + the sweep matrix flags (--reps/--duration-ms/...)]
                 --trace-out: the campaign timeline on the virtual clock —
                 lease spans, journal recovery, fault markers — a pure
                 function of the seed (CI byte-compares repeat runs)
                 same seed -> same run, byte for byte; on failure prints
                 the one-line seed entry to commit under
                 rust/tests/seeds/serve/ as a permanent regression

common flags: --seed N (default 7), --jobs N, --dataset NAME
";

fn main() {
    let args = Args::from_env();
    let seed = args.u64_or("seed", 7);
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "help" | "--help" | "-h" => print!("{HELP}"),
        "eta" => {
            let studies = exp::eta::run(args.usize_or("max-n", 20), seed);
            exp::eta::print_figure4(&studies);
            exp::eta::print_figure25(&studies);
        }
        "threshold" => {
            let ds = args.str_or("dataset", "cifar100");
            let net = Network::load(&zygarde::artifacts_root().join(ds)).expect("artifacts");
            let layer = args.usize_or("layer", 0);
            let pts = exp::threshold::sweep_layer(&net, layer, args.usize_or("points", 16));
            exp::threshold::print(&net, layer, &pts);
        }
        "overhead" => {
            let net = Network::load(&zygarde::artifacts_root().join("esc10")).expect("artifacts");
            exp::overhead::print(&exp::overhead::run(&net));
        }
        "loss-compare" => {
            exp::loss_compare::print(&exp::loss_compare::run(&["mnist", "esc10"]));
        }
        "termination" => {
            exp::termination::print(&exp::termination::run(&[
                "mnist", "esc10", "cifar100", "vww",
            ]));
        }
        "schedule" => {
            let ds = args.str_or("dataset", "mnist").to_string();
            let systems: Vec<usize> = args
                .opt_str("systems")
                .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
                .unwrap_or_else(|| (1..=7).collect());
            let jobs = args.opt_str("jobs").map(|j| j.parse().unwrap());
            let nvms = parse_nvms(&args);
            let cells = exp::schedule::run_with_nvms(&ds, &systems, jobs, seed, &nvms);
            exp::schedule::print(&ds, &cells);
        }
        "capacitor" => {
            let nvms = parse_nvms(&args);
            let cells = exp::capacitor_sweep::run_with_nvms(args.u64_or("jobs", 200), seed, &nvms);
            exp::capacitor_sweep::print(&cells);
        }
        "nvm" => {
            let (matrix, report) = exp::nvm_cmp::run(args.u64_or("jobs", 300), seed);
            exp::nvm_cmp::print(&exp::nvm_cmp::summarize(&matrix, &report));
        }
        "chrt" => {
            let rows = exp::chrt_cmp::run(args.u64_or("jobs", 2000), seed);
            exp::chrt_cmp::print(&rows);
        }
        "acoustic" => {
            let mins = args.f64_or("minutes", 10.0);
            let results = exp::acoustic::run(mins * 60_000.0, seed);
            exp::acoustic::print(&results);
        }
        "visual" => {
            let mins = args.f64_or("minutes", 10.0);
            let cells = exp::visual::run(mins * 60_000.0, seed);
            exp::visual::print(&cells);
        }
        "classifiers" => {
            exp::classifiers_cmp::print(&exp::classifiers_cmp::run(&[
                "mnist", "esc10", "cifar100", "vww",
            ]));
        }
        "adaptation" => {
            exp::adaptation::print(&exp::adaptation::run());
        }
        "schedulability" => {
            let rows = exp::schedulability::run(
                &["mnist", "esc10", "cifar100", "vww"],
                &[0.38, 0.51, 0.71, 0.9],
            );
            exp::schedulability::print(&rows);
        }
        "sweep" => run_sweep(&args, seed),
        "trace" => run_trace(&args, seed),
        "profile" => run_profile(&args, seed),
        "merge" => run_merge(&args),
        "serve" => run_serve(&args, seed),
        "work" => run_work(&args),
        "simtest" => run_simtest(&args, seed),
        "infer" => run_infer(&args),
        "all" => run_all(seed, &args),
        other => {
            eprintln!("unknown experiment `{other}`\n");
            print!("{HELP}");
            std::process::exit(2);
        }
    }
}

/// Parse the `--nvm` policy list (empty = each matrix's zero-cost
/// default); exits with the known-policy list on a typo.
fn parse_nvms(args: &Args) -> Vec<NvmSpec> {
    match args.opt_str("nvm") {
        None => Vec::new(),
        Some(s) => NvmSpec::parse_list(s).unwrap_or_else(|e| {
            eprintln!("--nvm: {e}");
            std::process::exit(2);
        }),
    }
}

fn die(msg: &str) -> ! {
    eprintln!("{msg}");
    std::process::exit(2);
}

/// Parse the matrix-tunable flags shared by `sweep` and `serve`, warning
/// on flags the named matrix ignores, and build the matrix.
fn matrix_from_flags(args: &Args, seed: u64) -> (String, SweepOpts, sweep::ScenarioMatrix) {
    let name = args.str_or("matrix", "synthetic").to_string();
    let opts = SweepOpts {
        seed,
        jobs: args.u64_or("jobs", 200),
        reps: args.u64_or("reps", 2),
        duration_ms: args.opt_str("duration-ms").map(|v| {
            v.parse().unwrap_or_else(|_| die(&format!("--duration-ms: bad number `{v}`")))
        }),
        dataset: args.str_or("dataset", "mnist").to_string(),
        systems: args
            .opt_str("systems")
            .map(|s| {
                s.split(',')
                    .map(|x| {
                        x.parse().unwrap_or_else(|_| die(&format!("--systems: bad id `{x}`")))
                    })
                    .collect()
            })
            .unwrap_or_else(|| (1..=7).collect()),
        nvms: parse_nvms(args),
    };
    for &flag in sweep_cli::TUNABLE_FLAGS {
        if args.has(flag) && !sweep_cli::consumed_flags(&name).contains(&flag) {
            eprintln!("warning: --{flag} is ignored by --matrix {name}");
        }
    }
    let matrix = sweep_cli::build_matrix(&name, &opts).unwrap_or_else(|e| die(&e));
    (name, opts, matrix)
}

/// `zygarde trace`: run one cell of a named matrix with the telemetry
/// sink attached and export its event trace. The traced run is the same
/// simulation the sweep would execute — sinks are out-of-band, so its
/// metrics match the corresponding sweep cell byte for byte.
fn run_trace(args: &Args, seed: u64) {
    use zygarde::telemetry::export::{chrome_string, jsonl_string, ScenarioTrace};
    let (name, _, matrix) = matrix_from_flags(args, seed);
    let scenarios = matrix.expand();
    let index = args.usize_or("index", 0);
    if index >= scenarios.len() {
        die(&format!(
            "--index {index} out of range: matrix `{name}` has {} cells",
            scenarios.len()
        ));
    }
    let format = args.str_or("format", "chrome").to_string();
    let sc = &scenarios[index];
    let (cell, events) = sweep::run_scenario_traced(sc);
    let body = match format.as_str() {
        "chrome" => chrome_string(&[ScenarioTrace {
            label: cell.label.clone(),
            index,
            events,
        }]),
        "jsonl" => jsonl_string(&events),
        other => die(&format!("--format: `{other}` (expected chrome or jsonl)")),
    };
    match args.opt_str("out") {
        Some(out) => {
            std::fs::write(out, &body).expect("writing trace");
            eprintln!(
                "trace `{name}` cell {index} ({}): {} bytes -> {out}",
                cell.label,
                body.len()
            );
        }
        None => print!("{body}"),
    }
}

/// `zygarde profile`: run a named matrix with a metric registry attached
/// to every cell's engine and print the per-axis waterfall. Registries
/// are passive observers — the cells computed here are byte-identical to
/// an unprofiled sweep's.
fn run_profile(args: &Args, seed: u64) {
    use zygarde::sim::sweep::{profile_matrix, DEFAULT_AXIS};
    let (name, _, matrix) = matrix_from_flags(args, seed);
    let threads = args.usize_or("threads", sweep::default_threads());
    let by = args.str_or("by", DEFAULT_AXIS).to_string();
    let report = profile_matrix(&matrix, threads, &by).unwrap_or_else(|e| die(&e));
    print!("{}", report.render_table());
    if let Some(out) = args.opt_str("out") {
        let mut body = report.json_string();
        body.push('\n');
        std::fs::write(out, body).unwrap_or_else(|e| die(&format!("{out}: {e}")));
        println!("profile `{name}` by {by}: {} cells -> {out}", report.n_cells);
    }
}

/// Re-run every `every`-th cell with the telemetry sink on and drop one
/// Chrome-format trace file per sampled cell into `dir`. Runs after the
/// sweep so the report is untouched by construction — traced re-runs are
/// byte-identical anyway, and deterministic re-execution is cheaper than
/// plumbing sinks through the parallel runner. Under `--shard` only the
/// shard's own cells are sampled and files carry the shard index
/// (`trace_sI_cXXXXX.json`), so N shards can share one directory without
/// clobbering each other.
fn write_sampled_traces(
    dir: &str,
    every: usize,
    matrix: &sweep::ScenarioMatrix,
    shard: Option<ShardSpec>,
) {
    use zygarde::telemetry::export::{chrome_string, ScenarioTrace};
    std::fs::create_dir_all(dir).unwrap_or_else(|e| die(&format!("--trace-dir {dir}: {e}")));
    let scenarios = matrix.expand();
    let owned: Vec<_> = scenarios
        .iter()
        .filter(|sc| shard.map_or(true, |s| s.owns(sc.index)))
        .collect();
    let mut written = 0usize;
    for sc in owned.iter().step_by(every.max(1)) {
        let (cell, events) = sweep::run_scenario_traced(sc);
        let body = chrome_string(&[ScenarioTrace {
            label: cell.label.clone(),
            index: sc.index,
            events,
        }]);
        let path = match shard {
            Some(s) => format!("{dir}/trace_s{}_c{:05}.json", s.shard_index, sc.index),
            None => format!("{dir}/cell_{:05}.trace.json", sc.index),
        };
        std::fs::write(&path, body).unwrap_or_else(|e| die(&format!("{path}: {e}")));
        written += 1;
    }
    println!(
        "traces: {written} of {} cells (every {every}th) -> {dir}",
        owned.len()
    );
}

/// `zygarde sweep`: run a named matrix — the whole thing, or one strided
/// shard of it for multi-process / multi-host execution.
fn run_sweep(args: &Args, seed: u64) {
    let (_, _, matrix) = matrix_from_flags(args, seed);
    let threads = args.usize_or("threads", sweep::default_threads());
    match args.opt_str("shard") {
        Some(spec) => {
            let shard = ShardSpec::parse(spec).unwrap_or_else(|e| die(&format!("--shard: {e}")));
            let part = sweep::run_shard(&matrix, shard, threads);
            let out = args.opt_str("out").map(String::from).unwrap_or_else(|| {
                format!("shard_{}_of_{}.json", shard.shard_index, shard.shard_count)
            });
            std::fs::write(&out, part.json_string()).expect("writing shard report");
            println!(
                "sweep `{}` shard {}: {} of {} cells -> {out}",
                matrix.name,
                shard.label(),
                part.cells.len(),
                part.fingerprint.n_scenarios
            );
            if let Some(dir) = args.opt_str("trace-dir") {
                write_sampled_traces(dir, args.usize_or("trace-every", 8), &matrix, Some(shard));
            }
        }
        None => {
            let report = sweep::run_matrix(&matrix, threads);
            match args.opt_str("out") {
                Some(out) => {
                    std::fs::write(out, report.json_string()).expect("writing sweep report");
                    println!(
                        "sweep `{}`: {} scenarios -> {out}",
                        report.matrix_name, report.n_scenarios
                    );
                }
                None => report.print(),
            }
            if let Some(dir) = args.opt_str("trace-dir") {
                write_sampled_traces(dir, args.usize_or("trace-every", 8), &matrix, None);
            }
        }
    }
}

/// `zygarde serve`: dispatch a named matrix as work-stealing leases to
/// worker processes and stream-merge their cells out-of-core; the merged
/// report is byte-identical to the single-process `SweepReport`.
fn run_serve(args: &Args, seed: u64) {
    use zygarde::sim::sweep::serve::{serve_to, ServeConfig};
    let (name, opts, matrix) = matrix_from_flags(args, seed);
    let listen = args.opt_str("listen").map(String::from);
    // Pipes-only by default: one local worker per core. With --listen the
    // default is pure-TCP (workers join from anywhere); --workers N still
    // adds local ones alongside.
    let default_workers = if listen.is_some() { 0 } else { sweep::default_threads() };
    let mut cfg = ServeConfig::new(matrix, &name, opts.to_json());
    cfg.listen = listen;
    cfg.spawn_workers = args.usize_or("workers", default_workers);
    cfg.worker_threads = args.usize_or("worker-threads", 1);
    cfg.batch = args.usize_or("batch", 4);
    cfg.lease_size = args.usize_or("lease", 0);
    cfg.lease_timeout_ms = args.u64_or("lease-timeout-ms", 30_000);
    cfg.spill_cells = args.usize_or("spill-cells", 10_000);
    cfg.spill_dir = args.opt_str("spill-dir").map(std::path::PathBuf::from);
    cfg.journal = args.opt_str("journal").map(std::path::PathBuf::from);
    if let Some(j) = args.opt_str("resume") {
        if cfg.journal.is_some() {
            die("--journal and --resume are mutually exclusive: --resume FILE \
                 recovers FILE and keeps journaling to it");
        }
        cfg.journal = Some(std::path::PathBuf::from(j));
        cfg.resume = true;
    }
    cfg.quiet = args.bool_or("quiet", false);
    cfg.metrics_out = args.opt_str("metrics-out").map(std::path::PathBuf::from);
    cfg.heartbeat_ms = args.u64_or("heartbeat-ms", 5_000);
    cfg.trace_out = args.opt_str("trace-out").map(std::path::PathBuf::from);
    let out_path = args.str_or("out", "report.json").to_string();
    let file = std::fs::File::create(&out_path)
        .unwrap_or_else(|e| die(&format!("{out_path}: {e}")));
    let mut out = std::io::BufWriter::new(file);
    let n = cfg.matrix.len();
    match serve_to(cfg, &mut out) {
        Ok(o) => {
            println!(
                "serve `{name}`: {} cells -> {out_path} ({} workers, {} leases, \
                 {} steals, {} reissues, {} duplicate cells, {} spill runs, \
                 peak {} cells in memory)",
                o.n_scenarios,
                o.workers_seen,
                o.leases_granted,
                o.steals,
                o.reissues,
                o.duplicates,
                o.runs_spilled,
                o.peak_buffered,
            );
        }
        Err(e) => {
            // Leave no half-written report behind a failed serve.
            drop(out);
            let _ = std::fs::remove_file(&out_path);
            die(&format!("serve failed after dispatching over {n} cells: {e}"));
        }
    }
}

/// `zygarde simtest`: one campaign over the simulated network. Exit 0
/// means the streamed report matched the single-process bytes (and the
/// event log is a pure function of the seed); exit 1 prints everything
/// needed to reproduce and to commit the seed as a regression.
fn run_simtest(args: &Args, seed: u64) {
    use zygarde::sim::sweep::serve::simnet::{run_campaign, FaultSpec, SimConfig};
    let (name, _, matrix) = matrix_from_flags(args, seed);
    let faults = args.str_or("faults", "").to_string();
    let spec = FaultSpec::parse(&faults).unwrap_or_else(|e| die(&format!("--faults: {e}")));
    let mut cfg = SimConfig::new(seed, args.usize_or("workers", 32));
    cfg.spec = spec;
    cfg.lease_size = args.usize_or("lease", 0);
    cfg.lease_timeout_ms = args.u64_or("lease-timeout-ms", 300);
    cfg.spill_cells = args.usize_or("spill-cells", 32);
    cfg.threads = args.usize_or("threads", 0);
    cfg.trace = args.has("trace-out");
    let fail = |detail: &str| {
        eprintln!("simtest `{name}` seed {seed}: FAILED — {detail}");
        eprintln!(
            "reproduce: zygarde simtest --matrix {name} --seed {seed} --workers {} \
             --faults \"{faults}\"",
            cfg.workers
        );
        eprintln!(
            "commit as a regression: echo \"seed={seed} workers={} faults={faults}\" \
             > rust/tests/seeds/serve/seed_{seed}.seed",
            cfg.workers
        );
        std::process::exit(1)
    };
    let outcome = run_campaign(&matrix, &cfg).unwrap_or_else(|e| fail(&e));
    println!("simtest `{name}` seed {seed}: {}", outcome.plan.summary());
    println!(
        "  {} workers over the campaign, {} events in {} virtual ms, log hash {:016x}",
        outcome.workers_spawned, outcome.events, outcome.virtual_ms, outcome.log_hash
    );
    let net = &outcome.net;
    println!(
        "  net: {} sent, {} delivered, {} dropped, {} duplicated, {} reordered, \
         {} crashes, {} dispatcher crashes, {} partitions, {} kicks, {} relief workers",
        net.sent,
        net.delivered,
        net.dropped,
        net.duplicated,
        net.reordered,
        net.crashes,
        net.dcrashes,
        net.partitions,
        net.kicks,
        net.relief_spawns
    );
    let st = &outcome.stats;
    println!(
        "  core: {} leases, {} steals, {} reissues, {} duplicate cells",
        st.leases_granted, st.steals, st.reissues, st.duplicates
    );
    if let Some(out) = args.opt_str("out") {
        std::fs::write(out, &outcome.report).unwrap_or_else(|e| die(&format!("{out}: {e}")));
        println!("  report -> {out}");
    }
    if let Some(path) = args.opt_str("log") {
        let mut body = outcome.log.join("\n");
        body.push('\n');
        std::fs::write(path, body).unwrap_or_else(|e| die(&format!("{path}: {e}")));
        println!("  event log ({} lines) -> {path}", outcome.log.len());
    }
    if let Some(path) = args.opt_str("trace-out") {
        let tl = outcome.timeline.as_ref().expect("--trace-out sets cfg.trace");
        std::fs::write(path, format!("{tl}\n")).unwrap_or_else(|e| die(&format!("{path}: {e}")));
        println!("  timeline ({} bytes, virtual clock) -> {path}", tl.len());
    }
    if !outcome.matches {
        fail(&format!(
            "report diverged from the single-process bytes ({} vs {} bytes)",
            outcome.report.len(),
            outcome.reference.len()
        ));
    }
    println!(
        "  report: byte-identical to the single-process sweep ({} bytes)",
        outcome.report.len()
    );
}

/// `zygarde work`: execute leases for a dispatcher — over stdin/stdout
/// (`--connect -`, the pipe workers `serve` spawns) or TCP
/// (`--connect host:port`). All diagnostics go to stderr; stdout may be
/// the protocol stream.
fn run_work(args: &Args) {
    use zygarde::sim::sweep::serve::{backoff_ms, run_worker};
    use zygarde::util::rng::Pcg32;
    let threads = args.usize_or("threads", sweep::default_threads());
    let batch = args.usize_or("batch", 4);
    let resolve = |name: &str, opts: &zygarde::util::json::Value| {
        let opts = SweepOpts::from_json(opts)?;
        sweep_cli::build_matrix(name, &opts)
    };
    let connect = args.str_or("connect", "-").to_string();
    if connect == "-" {
        // Pipe workers live and die with the dispatcher that spawned
        // them — there is nothing to reconnect to.
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let mut rx = stdin.lock();
        let mut tx = stdout.lock();
        match run_worker(&mut rx, &mut tx, threads, batch, &resolve) {
            Ok(o) => {
                eprintln!("work: {} cells over {} leases, clean shutdown", o.cells_run, o.leases)
            }
            Err(e) => die(&format!("work: {e}")),
        }
        return;
    }
    // TCP, with bounded exponential-backoff reconnect: a dispatcher that
    // was kill -9'd and restarted with `serve --resume` looks like an
    // EOF or a refused connect from here, and the worker should
    // re-handshake rather than die. The jitter stream is seeded
    // (--retry-seed) so tests are deterministic.
    let retries = args.usize_or("retry", 0) as u32;
    let retry_base = args.u64_or("retry-base-ms", 100);
    let mut rng = Pcg32::new(args.u64_or("retry-seed", 0x7e77), 0x6261_636b_6f66_66);
    let mut attempt: u32 = 0;
    let mut handshaken_once = false;
    loop {
        // Distinguishes "the dispatcher is gone" (refused connect — after
        // real work that means it finalized and exited, a clean ending)
        // from "the dispatcher is there and rejected us" (an error).
        let mut dispatcher_absent = false;
        let failure = match std::net::TcpStream::connect(&connect) {
            Ok(stream) => {
                // A live dispatcher resets the retry budget: only
                // *consecutive* failures count against --retry.
                attempt = 0;
                match stream.try_clone() {
                    Ok(read_half) => {
                        let mut rx = std::io::BufReader::new(read_half);
                        let mut tx = stream;
                        match run_worker(&mut rx, &mut tx, threads, batch, &resolve) {
                            Ok(o) => {
                                eprintln!(
                                    "work: {} cells over {} leases, clean shutdown",
                                    o.cells_run, o.leases
                                );
                                return;
                            }
                            Err(e) => {
                                handshaken_once |= e.handshaken;
                                format!("work: {e}")
                            }
                        }
                    }
                    Err(e) => format!("clone {connect}: {e}"),
                }
            }
            Err(e) => {
                dispatcher_absent = true;
                format!("connect {connect}: {e}")
            }
        };
        if attempt >= retries {
            if handshaken_once && dispatcher_absent {
                // We did real work for a dispatcher that has since gone
                // away for good — overwhelmingly because it finalized
                // its report and exited. A worker outliving a finished
                // campaign is a success, not an error.
                eprintln!("work: {failure}");
                eprintln!("work: dispatcher finalized or left; exiting cleanly");
                return;
            }
            if retries == 0 {
                die(&failure);
            }
            die(&format!("{failure} (after {attempt} reconnect attempt(s))"));
        }
        let delay = backoff_ms(attempt, retry_base, &mut rng);
        attempt += 1;
        eprintln!("work: {failure}; reconnect attempt {attempt}/{retries} in {delay} ms");
        std::thread::sleep(std::time::Duration::from_millis(delay));
    }
}

/// `zygarde merge`: reassemble shard files into the byte-identical
/// single-process report.
fn run_merge(args: &Args) {
    if args.positional.is_empty() {
        die("usage: zygarde merge shard_*.json [--out report.json] [--table]");
    }
    let paths: Vec<std::path::PathBuf> =
        args.positional.iter().map(std::path::PathBuf::from).collect();
    match sweep::shard::merge_files(&paths) {
        Ok(report) => {
            let json = report.json_string();
            match args.opt_str("out") {
                Some(out) => {
                    std::fs::write(out, &json).expect("writing merged report");
                    println!(
                        "merged {} shard file(s) -> {out} ({} scenarios)",
                        paths.len(),
                        report.n_scenarios
                    );
                }
                None => println!("{json}"),
            }
            if args.bool_or("table", false) {
                report.print();
            }
        }
        Err(e) => {
            eprintln!("merge failed: {e}");
            std::process::exit(1);
        }
    }
}

/// End-to-end PJRT inference: load the AOT per-unit HLO artifacts, run the
/// agile DNN with early exit over test samples, report accuracy + exit
/// histogram + latency. This is the serving path (Python never runs).
fn run_infer(args: &Args) {
    let ds = args.str_or("dataset", "mnist");
    let n = args.usize_or("samples", 50);
    let dir = zygarde::artifacts_root().join(ds);
    let net = Network::load(&dir).expect("artifacts");
    let mut rt = zygarde::runtime::Runtime::cpu().expect("PJRT client");
    rt.load_network(&dir, &net.meta).expect("load units");
    println!(
        "loaded {} units of `{ds}` on {} (PJRT)",
        rt.loaded_units(),
        rt.platform()
    );

    let mut exit_hist = vec![0usize; net.meta.n_layers];
    let mut correct = 0usize;
    let n = n.min(net.test.len());
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let mut act = net.test.sample(i).to_vec();
        let mut pred = None;
        for li in 0..net.meta.n_layers {
            let (next, dists) = rt
                .execute_unit(ds, li, &act, &net.classifiers[li].centroids)
                .expect("execute");
            let res = net.classifiers[li].classify_from_dists(&dists);
            pred = Some(res.pred);
            if res.exit || li == net.meta.n_layers - 1 {
                exit_hist[li] += 1;
                break;
            }
            act = next;
        }
        if pred == Some(net.test.y[i]) {
            correct += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "{n} samples: accuracy {:.1}%  mean latency {:.2} ms  exit histogram {:?}",
        100.0 * correct as f64 / n as f64,
        dt.as_secs_f64() * 1e3 / n as f64,
        exit_hist
    );
}

fn run_all(seed: u64, args: &Args) {
    let studies = exp::eta::run(20, seed);
    exp::eta::print_figure4(&studies);
    exp::eta::print_figure25(&studies);

    let net = Network::load(&zygarde::artifacts_root().join("cifar100")).expect("artifacts");
    let pts = exp::threshold::sweep_layer(&net, 0, 16);
    exp::threshold::print(&net, 0, &pts);

    let esc = Network::load(&zygarde::artifacts_root().join("esc10")).expect("artifacts");
    exp::overhead::print(&exp::overhead::run(&esc));
    exp::loss_compare::print(&exp::loss_compare::run(&["mnist", "esc10"]));
    exp::termination::print(&exp::termination::run(&["mnist", "esc10", "cifar100", "vww"]));

    for ds in ["mnist", "esc10", "cifar100", "vww"] {
        let jobs = args.opt_str("jobs").map(|j| j.parse().unwrap());
        let cells = exp::schedule::run(ds, &(1..=7).collect::<Vec<_>>(), jobs, seed);
        exp::schedule::print(ds, &cells);
    }

    exp::capacitor_sweep::print(&exp::capacitor_sweep::run(args.u64_or("jobs", 200), seed));
    {
        let (matrix, report) = exp::nvm_cmp::run(args.u64_or("nvm-jobs", 300), seed);
        exp::nvm_cmp::print(&exp::nvm_cmp::summarize(&matrix, &report));
    }
    exp::chrt_cmp::print(&exp::chrt_cmp::run(args.u64_or("chrt-jobs", 2000), seed));
    exp::acoustic::print(&exp::acoustic::run(600_000.0, seed));
    exp::visual::print(&exp::visual::run(600_000.0, seed));
    exp::classifiers_cmp::print(&exp::classifiers_cmp::run(&[
        "mnist", "esc10", "cifar100", "vww",
    ]));
    exp::adaptation::print(&exp::adaptation::run());
    exp::schedulability::print(&exp::schedulability::run(
        &["mnist", "esc10", "cifar100", "vww"],
        &[0.38, 0.51, 0.71, 0.9],
    ));

    // Cross-check SchedulerKind exposure for the CLI docs.
    let _ = SchedulerKind::Zygarde.name();
}
