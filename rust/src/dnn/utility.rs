//! Generic utility functions (paper §11.2).
//!
//! Zygarde's default utility test is the top-2 L1-distance gap of the
//! k-means classifier. The paper sketches how the same *principle*
//! (confidence at this unit decides whether deeper units are optional)
//! extends to other classifier families:
//!
//! * distance-margin classifiers (SVM/KNN): distance to the decision
//!   boundary / the neighbour-vote margin;
//! * probabilistic classifiers (softmax heads, naive Bayes): the entropy
//!   of the predictive distribution, U = −Σ pᵢ log₂ pᵢ — low entropy ⇒
//!   concentrated mass ⇒ confident ⇒ exit.
//!
//! This module implements those alternatives behind one trait so a
//! deployment can swap the exit test without touching the scheduler.

/// A utility score plus the exit decision derived from it. Higher utility
/// always means MORE confident (the scheduler's ζ gives low-utility jobs
/// priority for further refinement).
#[derive(Clone, Copy, Debug)]
pub struct UtilityScore {
    pub utility: f32,
    pub exit: bool,
}

pub trait UtilityFn {
    /// Score one unit's classifier evidence. The meaning of `evidence`
    /// depends on the family: distances for margin-based, probabilities
    /// for probabilistic classifiers.
    fn score(&self, evidence: &[f32]) -> UtilityScore;
    fn name(&self) -> &'static str;
}

/// The paper's default: |d2 − d1| of the two smallest distances.
#[derive(Clone, Copy, Debug)]
pub struct DistanceGap {
    pub threshold: f32,
}

impl UtilityFn for DistanceGap {
    fn score(&self, dists: &[f32]) -> UtilityScore {
        let (mut d1, mut d2) = (f32::INFINITY, f32::INFINITY);
        for &d in dists {
            if d < d1 {
                d2 = d1;
                d1 = d;
            } else if d < d2 {
                d2 = d;
            }
        }
        let gap = if dists.len() > 1 { d2 - d1 } else { f32::INFINITY };
        UtilityScore { utility: gap, exit: gap >= self.threshold }
    }

    fn name(&self) -> &'static str {
        "distance-gap"
    }
}

/// §11.2's recommendation for probability-output classifiers: exit when
/// the predictive entropy is low. `evidence` is a probability vector;
/// utility is reported as (max-entropy − entropy) so that higher is more
/// confident, consistent with the gap-based score.
#[derive(Clone, Copy, Debug)]
pub struct EntropyUtility {
    /// Exit when H(p) <= threshold_bits.
    pub threshold_bits: f32,
}

impl EntropyUtility {
    pub fn entropy_bits(p: &[f32]) -> f32 {
        let mut h = 0f32;
        for &x in p {
            if x > 0.0 {
                h -= x * x.log2();
            }
        }
        h
    }
}

impl UtilityFn for EntropyUtility {
    fn score(&self, probs: &[f32]) -> UtilityScore {
        debug_assert!(
            (probs.iter().sum::<f32>() - 1.0).abs() < 1e-3,
            "entropy utility expects a probability vector"
        );
        let h = Self::entropy_bits(probs);
        let h_max = (probs.len() as f32).log2();
        UtilityScore { utility: h_max - h, exit: h <= self.threshold_bits }
    }

    fn name(&self) -> &'static str {
        "entropy"
    }
}

/// Distances → pseudo-probabilities via a softmax over negative distances
/// (temperature τ). Lets the entropy utility ride on the existing k-means
/// evidence so the two tests are comparable on the same artifacts.
pub fn dists_to_probs(dists: &[f32], tau: f32) -> Vec<f32> {
    let m = dists.iter().cloned().fold(f32::INFINITY, f32::min);
    let exps: Vec<f32> = dists.iter().map(|&d| (-(d - m) / tau).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gap_matches_classifier_semantics() {
        let u = DistanceGap { threshold: 5.0 };
        let confident = u.score(&[1.0, 10.0, 12.0]);
        assert!(confident.exit);
        assert_eq!(confident.utility, 9.0);
        let ambiguous = u.score(&[1.0, 2.0, 12.0]);
        assert!(!ambiguous.exit);
    }

    #[test]
    fn entropy_extremes() {
        assert!(EntropyUtility::entropy_bits(&[1.0, 0.0, 0.0, 0.0]).abs() < 1e-6);
        let uniform = EntropyUtility::entropy_bits(&[0.25; 4]);
        assert!((uniform - 2.0).abs() < 1e-6);
    }

    #[test]
    fn entropy_exit_agrees_with_confidence() {
        let u = EntropyUtility { threshold_bits: 0.5 };
        assert!(u.score(&[0.97, 0.01, 0.01, 0.01]).exit);
        assert!(!u.score(&[0.4, 0.3, 0.2, 0.1]).exit);
        // more confident => higher utility
        let a = u.score(&[0.97, 0.01, 0.01, 0.01]).utility;
        let b = u.score(&[0.7, 0.1, 0.1, 0.1]).utility;
        assert!(a > b);
    }

    #[test]
    fn dists_to_probs_is_a_distribution_and_order_preserving() {
        let p = dists_to_probs(&[1.0, 5.0, 2.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[0] > p[2] && p[2] > p[1]);
    }

    #[test]
    fn entropy_and_gap_agree_on_real_artifacts() {
        // On the mnist artifacts, rank samples by both utilities at layer
        // 0; confident-by-gap should be overwhelmingly confident-by-
        // entropy as well (the tests measure the same ambiguity).
        let dir = crate::artifacts_root().join("mnist");
        if !dir.join("meta.json").exists() {
            return;
        }
        let net = crate::dnn::network::Network::load(&dir).unwrap();
        let mut scratch = crate::dnn::kmeans::Scratch::default();
        let mut agree = 0usize;
        let mut n = 0usize;
        let gap = DistanceGap { threshold: net.classifiers[0].threshold };
        let ent = EntropyUtility { threshold_bits: 2.4 };
        for i in 0..net.test.len() {
            let (_, res) = net.run_unit_native(0, net.test.sample(i), &mut scratch);
            let _ = res;
            let dists = scratch.dists.clone();
            let g = gap.score(&dists);
            let e = ent.score(&dists_to_probs(&dists, 8.0));
            n += 1;
            if g.exit == e.exit {
                agree += 1;
            }
        }
        assert!(
            agree as f64 / n as f64 > 0.6,
            "utilities disagree too much: {agree}/{n}"
        );
    }
}
