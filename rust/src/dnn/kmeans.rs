//! Semi-supervised k-means classifier with the utility test (paper §4.1,
//! §4.3) — the multiplication-free per-unit classifier.
//!
//! Classification: L1 distance from the unit's selected feature vector to
//! each of k centroids; predicted class = nearest centroid's label.
//! Utility test: exit iff |d2 - d1| >= unit threshold (Fig. 5) — the input
//! is unambiguously close to exactly one mean.
//! Adaptation: weighted-average centroid update on confident
//! classifications (§4.3 "Updating Centroids at Run-Time").

#[derive(Clone, Debug)]
pub struct Classifier {
    pub k: usize,
    pub n_features: usize,
    /// Flat-activation indices of the selected features (sorted).
    pub feat_idx: Vec<usize>,
    /// (k, F) row-major; mutable at runtime (adaptation).
    pub centroids: Vec<f32>,
    pub labels: Vec<i32>,
    pub threshold: f32,
    /// Adaptation weight for the new example (paper: "more weights to the
    /// current centroid" — gradual drift, outlier-robust).
    pub adapt_rate: f32,
    /// Running cluster sizes r (used by the deep-propagation rule).
    pub cluster_size: Vec<f32>,
}

/// Outcome of running one unit's classifier.
#[derive(Clone, Copy, Debug)]
pub struct ClassifyResult {
    pub pred: i32,
    pub best: usize,
    /// |d2 - d1|: the utility score's raw gap.
    pub gap: f32,
    pub d1: f32,
    /// Utility test passed => confident => the *next* unit is optional.
    pub exit: bool,
}

impl Classifier {
    pub fn new(
        feat_idx: Vec<usize>,
        centroids: Vec<f32>,
        labels: Vec<i32>,
        threshold: f32,
        train_hist: &[i32],
    ) -> Self {
        let k = labels.len();
        let n_features = feat_idx.len();
        assert_eq!(centroids.len(), k * n_features);
        // Initial cluster sizes from the training label histogram (each
        // centroid was seeded from its class's members).
        let cluster_size = labels
            .iter()
            .map(|&l| train_hist.get(l as usize).copied().unwrap_or(1).max(1) as f32)
            .collect();
        Classifier {
            k,
            n_features,
            feat_idx,
            centroids,
            labels,
            threshold,
            adapt_rate: 0.05,
            cluster_size,
        }
    }

    /// Gather the unit's selected features from a flat activation.
    pub fn gather<'a>(&self, act: &[f32], buf: &'a mut Vec<f32>) -> &'a [f32] {
        buf.clear();
        buf.extend(self.feat_idx.iter().map(|&i| act[i]));
        buf
    }

    /// L1 distances to all centroids into `dists` (len k).
    pub fn distances(&self, feat: &[f32], dists: &mut [f32]) {
        debug_assert_eq!(feat.len(), self.n_features);
        debug_assert_eq!(dists.len(), self.k);
        for (c, d) in dists.iter_mut().enumerate() {
            let row = &self.centroids[c * self.n_features..(c + 1) * self.n_features];
            let mut acc = 0f32;
            for (a, b) in feat.iter().zip(row) {
                acc += (a - b).abs();
            }
            *d = acc;
        }
    }

    /// Classify from a precomputed distance vector (as returned by the PJRT
    /// unit executable or by `distances`).
    pub fn classify_from_dists(&self, dists: &[f32]) -> ClassifyResult {
        debug_assert_eq!(dists.len(), self.k);
        let (mut b1, mut d1) = (0usize, f32::INFINITY);
        let mut d2 = f32::INFINITY;
        for (i, &d) in dists.iter().enumerate() {
            if d < d1 {
                d2 = d1;
                d1 = d;
                b1 = i;
            } else if d < d2 {
                d2 = d;
            }
        }
        let gap = if self.k > 1 { d2 - d1 } else { f32::INFINITY };
        ClassifyResult {
            pred: self.labels[b1],
            best: b1,
            gap,
            d1,
            exit: gap >= self.threshold,
        }
    }

    /// Full classify from a flat activation (native path).
    pub fn classify(&self, act: &[f32], scratch: &mut Scratch) -> ClassifyResult {
        let feat_len = self.n_features;
        scratch.feat.clear();
        scratch
            .feat
            .extend(self.feat_idx.iter().map(|&i| act[i]));
        scratch.dists.resize(self.k, 0.0);
        let (feat, dists) = (&scratch.feat[..feat_len], &mut scratch.dists[..]);
        self.distances(feat, dists);
        self.classify_from_dists(dists)
    }

    /// Runtime centroid update: weighted average of the current centroid
    /// and the new example (only called when the utility test passed — the
    /// semi-supervised "confident pseudo-label" rule).
    pub fn adapt(&mut self, cluster: usize, feat: &[f32]) {
        debug_assert_eq!(feat.len(), self.n_features);
        let a = self.adapt_rate;
        let row = &mut self.centroids[cluster * self.n_features..(cluster + 1) * self.n_features];
        for (c, &f) in row.iter_mut().zip(feat) {
            *c = (1.0 - a) * *c + a * f;
        }
        self.cluster_size[cluster] += 1.0;
    }
}

/// Reusable buffers for the hot classify path (no allocation per call).
#[derive(Default, Clone, Debug)]
pub struct Scratch {
    pub feat: Vec<f32>,
    pub dists: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clf(threshold: f32) -> Classifier {
        // Two centroids in 2-D: (0,0) labeled 7 and (10,10) labeled 3.
        let mut hist = vec![0; 8];
        hist[7] = 50;
        hist[3] = 50;
        Classifier::new(
            vec![0, 1],
            vec![0.0, 0.0, 10.0, 10.0],
            vec![7, 3],
            threshold,
            &hist,
        )
    }

    #[test]
    fn classifies_nearest_l1() {
        let c = clf(1.0);
        let mut s = Scratch::default();
        let r = c.classify(&[1.0, 1.0], &mut s);
        assert_eq!(r.pred, 7);
        assert_eq!(r.d1, 2.0);
        assert_eq!(r.gap, 18.0 - 2.0);
        assert!(r.exit);
    }

    #[test]
    fn ambiguous_input_does_not_exit() {
        let c = clf(1.0);
        let mut s = Scratch::default();
        // Equidistant point: gap 0 < threshold.
        let r = c.classify(&[5.0, 5.0], &mut s);
        assert!(!r.exit);
        assert_eq!(r.gap, 0.0);
    }

    #[test]
    fn threshold_controls_exit() {
        let mut s = Scratch::default();
        let r_tight = clf(100.0).classify(&[1.0, 1.0], &mut s);
        assert!(!r_tight.exit);
        let r_loose = clf(0.1).classify(&[1.0, 1.0], &mut s);
        assert!(r_loose.exit);
    }

    #[test]
    fn adapt_moves_centroid_gradually() {
        let mut c = clf(1.0);
        let before = c.centroids[..2].to_vec();
        c.adapt(0, &[2.0, 2.0]);
        let after = &c.centroids[..2];
        assert!(after[0] > before[0] && after[0] < 2.0);
        assert_eq!(c.cluster_size[0], 51.0);
        // Repeated adaptation converges toward the new point.
        for _ in 0..500 {
            c.adapt(0, &[2.0, 2.0]);
        }
        assert!((c.centroids[0] - 2.0).abs() < 1e-3);
    }

    #[test]
    fn outlier_barely_moves_centroid() {
        let mut c = clf(1.0);
        c.adapt(0, &[100.0, 100.0]);
        // one outlier moves the centroid by adapt_rate fraction only
        assert!((c.centroids[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn single_cluster_always_exits() {
        let c = Classifier::new(vec![0], vec![0.0], vec![1], 5.0, &[10]);
        let r = c.classify_from_dists(&[3.0]);
        assert!(r.exit);
        assert_eq!(r.pred, 1);
    }

    #[test]
    fn dists_match_manual_l1() {
        let c = clf(0.0);
        let mut d = vec![0.0; 2];
        c.distances(&[3.0, -1.0], &mut d);
        assert_eq!(d, vec![4.0, 18.0]);
    }
}
