//! Centroid adaptation beyond the exit layer (paper §4.3, "Updating
//! Centroids beyond Mandatory Layers").
//!
//! When a sample exits early at layer i, the deeper layers' classifiers
//! never see it. The paper estimates the deeper centroids from the current
//! layer's centroid instead of running the sample through:
//!
//! ```text
//! c^{i+1} = (1/r) * sigma(W^{i+1} · r · c^i)
//! ```
//!
//! i.e. push the (cluster-size-weighted) centroid itself through the next
//! layer's affine map + ReLU, in O(1) per adaptation instead of O(r)
//! forward passes.
//!
//! Our centroids live in a *selected-feature* subspace (top-F of the flat
//! activation, exactly as in the paper's SelectKBest pipeline), so applying
//! W^{i+1} requires a full activation. We scatter the centroid back into
//! the flat activation (zeros elsewhere — the unselected coordinates were
//! the low-information ones by construction), apply the real layer map,
//! and gather the next layer's selected features. This follows the paper's
//! formula including the sigma and the r-weighting, with the scatter step
//! documented as the necessary inverse of feature selection.

use super::forward;
use super::network::Network;

/// Propagate an adaptation of `cluster` at layer `li` into layer `li + 1`.
/// No-op on the last layer.
pub fn propagate_centroid(net: &mut Network, li: usize, cluster: usize) {
    if li + 1 >= net.meta.n_layers {
        return;
    }
    let f_i = net.classifiers[li].n_features;
    let r = net.classifiers[li].cluster_size[cluster].max(1.0);

    // Scatter c^i into a flat activation of layer i's output space.
    let flat_dim = net.meta.flat_dim(li);
    let mut act = vec![0f32; flat_dim];
    {
        let clf = &net.classifiers[li];
        let row = &clf.centroids[cluster * f_i..(cluster + 1) * f_i];
        for (&idx, &v) in clf.feat_idx.iter().zip(row) {
            act[idx] = v * r; // the paper's r-scaling
        }
    }

    // sigma(W^{i+1} (r c^i)): the real next-layer map (conv or fc) + ReLU.
    // layer_forward applies the layer's own nonlinearity; the paper's
    // sigma(x) = (x + |x|)/2 is exactly ReLU.
    let in_shape = net.unit_in_shape(li + 1);
    let mut next = forward::layer_forward(
        &net.meta.layers[li + 1],
        &net.weights[li + 1],
        &act,
        &in_shape,
    );
    if !net.meta.layers[li + 1].relu {
        // Final embedding layers have no ReLU in the forward pass, but the
        // paper's update rule always rectifies; follow the paper.
        for v in next.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    // Gather layer i+1's selected features and blend into the matching
    // cluster (same label) with the 1/r scale.
    let label = net.classifiers[li].labels[cluster];
    let clf_next = &mut net.classifiers[li + 1];
    let Some(tgt) = clf_next.labels.iter().position(|&l| l == label) else {
        return;
    };
    let f_n = clf_next.n_features;
    let a = clf_next.adapt_rate;
    let row = &mut clf_next.centroids[tgt * f_n..(tgt + 1) * f_n];
    for (c, &idx) in row.iter_mut().zip(&clf_next.feat_idx) {
        let est = next[idx] / r;
        *c = (1.0 - a) * *c + a * est;
    }
}

/// The paper's stated bound on the approximation error of estimating the
/// next-layer centroid from the current one (§4.3): for cluster members
/// X_1..X_r,  err <= (Σ|W x_k| - |W Σ x_k|) / (2r). Exposed for the
/// analysis test, computed on explicit member activations.
pub fn approximation_error_bound(members: &[Vec<f32>], w_row: &[f32]) -> f64 {
    let r = members.len() as f64;
    if r == 0.0 {
        return 0.0;
    }
    let mut sum_abs = 0.0f64;
    let mut sum_vec = vec![0f32; members[0].len()];
    for m in members {
        let dot: f32 = m.iter().zip(w_row).map(|(a, b)| a * b).sum();
        sum_abs += dot.abs() as f64;
        for (s, &v) in sum_vec.iter_mut().zip(m) {
            *s += v;
        }
    }
    let dot_sum: f32 = sum_vec.iter().zip(w_row).map(|(a, b)| a * b).sum();
    (sum_abs - (dot_sum.abs() as f64)) / (2.0 * r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_bound_nonnegative_and_zero_for_aligned() {
        // All members identical: |sum of dots| == sum of |dots| -> bound 0.
        let members = vec![vec![1.0, 2.0]; 5];
        let w = vec![0.5, -0.25];
        assert!(approximation_error_bound(&members, &w).abs() < 1e-9);
        // Opposing members create slack: bound strictly positive.
        let members2 = vec![vec![1.0, 0.0], vec![-1.0, 0.0]];
        assert!(approximation_error_bound(&members2, &w) > 0.0);
    }

    #[test]
    fn propagation_moves_next_layer_centroid() {
        let dir = crate::artifacts_root().join("mnist");
        if !dir.join("meta.json").exists() {
            return;
        }
        let mut net = Network::load(&dir).unwrap();
        let before = net.classifiers[1].centroids.clone();
        // Perturb layer-0 centroid 0 and propagate.
        let f0 = net.classifiers[0].n_features;
        for v in net.classifiers[0].centroids[..f0].iter_mut() {
            *v += 0.5;
        }
        propagate_centroid(&mut net, 0, 0);
        let after = &net.classifiers[1].centroids;
        assert_ne!(&before, after, "propagation did not update layer 1");
        // Only one row (the matching label) may change.
        let f = net.classifiers[1].n_features;
        let label0 = net.classifiers[0].labels[0];
        let tgt = net.classifiers[1].labels.iter().position(|&l| l == label0).unwrap();
        for row in 0..net.classifiers[1].k {
            let changed = before[row * f..(row + 1) * f] != after[row * f..(row + 1) * f];
            assert_eq!(changed, row == tgt, "row {row}");
        }
    }

    #[test]
    fn propagation_last_layer_is_noop() {
        let dir = crate::artifacts_root().join("mnist");
        if !dir.join("meta.json").exists() {
            return;
        }
        let mut net = Network::load(&dir).unwrap();
        let last = net.meta.n_layers - 1;
        let before = net.classifiers[last].centroids.clone();
        propagate_centroid(&mut net, last, 0);
        assert_eq!(before, net.classifiers[last].centroids);
    }
}
