//! Parsed form of `artifacts/<ds>/meta.json` (written by aot.py).

use std::path::Path;

use crate::util::json::Value;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
}

/// Static description of one unit: layer topology + classifier geometry +
/// the compile-time cost model (the EnergyTrace++ substitute).
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub kind: LayerKind,
    pub out: usize,
    pub pool: bool,
    pub relu: bool,
    /// Activation shape *after* this layer (post-pool).
    pub act_shape: Vec<usize>,
    pub k: usize,
    pub n_features: usize,
    /// Utility-test threshold on |d2 - d1| (offline-tuned, Fig. 8).
    pub threshold: f64,
    /// Fig. 8 trade-off curve: (threshold, exit_rate, exit_accuracy).
    pub curve: Vec<(f64, f64, f64)>,
    pub macs: u64,
    pub adds: u64,
    pub time_ms: f64,
    pub energy_mj: f64,
    pub n_fragments: usize,
    pub fragment_ms: f64,
    pub fragment_energy_mj: f64,
}

#[derive(Clone, Debug)]
pub struct CostMeta {
    pub e_man_mj: f64,
    pub total_time_ms: f64,
    pub total_energy_mj: f64,
    pub job_generator_ms: f64,
    pub job_generator_energy_mj: f64,
    pub scheduler_overhead_ms: f64,
    pub scheduler_overhead_mj: f64,
}

#[derive(Clone, Debug)]
pub struct NetMeta {
    pub name: String,
    pub loss: String,
    pub input_shape: [usize; 3],
    pub n_classes: usize,
    pub n_layers: usize,
    pub n_test: usize,
    pub with_hlo: bool,
    pub layers: Vec<LayerMeta>,
    pub cost: CostMeta,
}

impl NetMeta {
    pub fn load(dir: &Path) -> Result<NetMeta, String> {
        let v = Value::parse_file(&dir.join("meta.json"))?;
        Ok(Self::from_json(&v))
    }

    pub fn from_json(v: &Value) -> NetMeta {
        let ishape: Vec<usize> = v.req("input_shape").arr().iter().map(|d| d.usize()).collect();
        let layers = v
            .req("layers")
            .arr()
            .iter()
            .map(|l| LayerMeta {
                kind: match l.req("kind").str() {
                    "conv" => LayerKind::Conv,
                    "fc" => LayerKind::Fc,
                    k => panic!("unknown layer kind `{k}`"),
                },
                out: l.req("out").usize(),
                pool: l.req("pool").as_bool().unwrap_or(false),
                relu: l.req("relu").as_bool().unwrap_or(true),
                act_shape: l.req("act_shape").arr().iter().map(|d| d.usize()).collect(),
                k: l.req("k").usize(),
                n_features: l.req("n_features").usize(),
                threshold: l.req("threshold").f64(),
                curve: l
                    .req("curve")
                    .arr()
                    .iter()
                    .map(|row| {
                        let r = row.arr();
                        (r[0].f64(), r[1].f64(), r[2].f64())
                    })
                    .collect(),
                macs: l.req("macs").f64() as u64,
                adds: l.req("adds").f64() as u64,
                time_ms: l.req("time_ms").f64(),
                energy_mj: l.req("energy_mj").f64(),
                n_fragments: l.req("n_fragments").usize(),
                fragment_ms: l.req("fragment_ms").f64(),
                fragment_energy_mj: l.req("fragment_energy_mj").f64(),
            })
            .collect();
        let c = v.req("cost_model");
        NetMeta {
            name: v.req("name").str().to_string(),
            loss: v.req("loss").str().to_string(),
            input_shape: [ishape[0], ishape[1], ishape[2]],
            n_classes: v.req("n_classes").usize(),
            n_layers: v.req("n_layers").usize(),
            n_test: v.req("n_test").usize(),
            with_hlo: v.req("with_hlo").as_bool().unwrap_or(false),
            layers,
            cost: CostMeta {
                e_man_mj: c.req("e_man_mj").f64(),
                total_time_ms: c.req("total_time_ms").f64(),
                total_energy_mj: c.req("total_energy_mj").f64(),
                job_generator_ms: c.req("job_generator_ms").f64(),
                job_generator_energy_mj: c.req("job_generator_energy_mj").f64(),
                scheduler_overhead_ms: c.req("scheduler_overhead_ms").f64(),
                scheduler_overhead_mj: c.req("scheduler_overhead_mj").f64(),
            },
        }
    }

    /// Input shape of unit `li` as XLA dims (layer 0 sees the raw sample;
    /// deeper units see the previous layer's activation).
    pub fn unit_input_shape(&self, li: usize) -> Vec<i64> {
        let s: Vec<usize> = if li == 0 {
            self.input_shape.to_vec()
        } else {
            self.layers[li - 1].act_shape.clone()
        };
        s.into_iter().map(|d| d as i64).collect()
    }

    pub fn flat_dim(&self, li: usize) -> usize {
        self.layers[li].act_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_meta() -> Value {
        Value::parse(
            r#"{
          "name": "t", "loss": "layer_aware", "input_shape": [4, 4, 1],
          "n_classes": 2, "n_layers": 2, "n_test": 10, "with_hlo": false,
          "layers": [
            {"kind": "conv", "out": 3, "pool": false, "relu": true,
             "act_shape": [2, 2, 3], "k": 2, "n_features": 4,
             "threshold": 0.5, "curve": [[0.0, 1.0, 0.6]], "macs": 100,
             "adds": 20, "time_ms": 10.0, "energy_mj": 0.1,
             "n_fragments": 2, "fragment_ms": 5.0, "fragment_energy_mj": 0.05},
            {"kind": "fc", "out": 4, "pool": false, "relu": false,
             "act_shape": [4], "k": 2, "n_features": 4, "threshold": 0.7,
             "curve": [[0.0, 1.0, 0.8]], "macs": 48, "adds": 20,
             "time_ms": 5.0, "energy_mj": 0.05, "n_fragments": 1,
             "fragment_ms": 5.0, "fragment_energy_mj": 0.05}],
          "cost_model": {"e_man_mj": 0.05, "total_time_ms": 15.0,
            "total_energy_mj": 0.15, "job_generator_ms": 100.0,
            "job_generator_energy_mj": 1.0, "scheduler_overhead_ms": 0.3,
            "scheduler_overhead_mj": 0.05}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_shapes() {
        let m = NetMeta::from_json(&fake_meta());
        assert_eq!(m.n_layers, 2);
        assert_eq!(m.unit_input_shape(0), vec![4, 4, 1]);
        assert_eq!(m.unit_input_shape(1), vec![2, 2, 3]);
        assert_eq!(m.flat_dim(0), 12);
        assert_eq!(m.layers[0].kind, LayerKind::Conv);
        assert_eq!(m.layers[1].kind, LayerKind::Fc);
        assert!((m.cost.e_man_mj - 0.05).abs() < 1e-12);
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let root = crate::artifacts_root();
        if !root.join("mnist/meta.json").exists() {
            return;
        }
        let m = NetMeta::load(&root.join("mnist")).unwrap();
        assert_eq!(m.name, "mnist");
        assert_eq!(m.n_layers, m.layers.len());
        assert_eq!(m.input_shape, [16, 16, 1]);
        // per-layer invariants from the compile path
        for l in &m.layers {
            assert!(l.threshold >= 0.0);
            assert!(l.n_fragments >= 1);
            assert!((l.fragment_ms * l.n_fragments as f64 - l.time_ms).abs() / l.time_ms < 1e-6);
            assert!(!l.curve.is_empty());
        }
        assert!(m.cost.e_man_mj > 0.0);
    }
}
