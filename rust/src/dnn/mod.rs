//! Agile DNN: artifact metadata, native forward pass, per-layer k-means
//! classifiers with the utility test and online adaptation, and per-sample
//! unit traces used by the scheduler experiments.
//!
//! Two execution paths exist for a unit:
//!
//! * [`crate::runtime`] — the PJRT path: executes the AOT-lowered HLO
//!   artifact (which embeds the Pallas kernels). This is the serving path
//!   used by the examples.
//! * [`forward`] — a pure-Rust reference implementation, validated against
//!   the PJRT path in `rust/tests/runtime_vs_native.rs`, used to
//!   precompute the per-sample traces that drive the large scheduler
//!   sweeps (Figs. 17–20 run up to 40 000 jobs; re-running XLA per job
//!   would measure XLA, not the scheduler).

pub mod adapt;
pub mod forward;
pub mod kmeans;
pub mod meta;
pub mod network;
pub mod trace;
pub mod utility;

pub use kmeans::Classifier;
pub use meta::{LayerKind, LayerMeta, NetMeta};
pub use network::Network;
pub use trace::{SampleTrace, UnitOutcome};
