//! Pure-Rust reference forward pass for the agile DNN.
//!
//! Mirrors `python/compile/model.py` exactly (3x3 VALID conv + ReLU +
//! optional 2x2/2 max-pool; FC = matmul + bias + optional ReLU) and is
//! validated element-wise against the PJRT execution of the AOT artifacts
//! in `rust/tests/runtime_vs_native.rs`. Used for fast trace precomputation
//! and as the baseline in the §Perf log.

use super::meta::{LayerKind, LayerMeta};

/// Weights for one layer, loaded from the ZYGT archive.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    /// conv: (3, 3, cin, cout) row-major; fc: (in, out) row-major.
    pub w: Vec<f32>,
    pub w_dims: Vec<usize>,
    pub b: Vec<f32>,
}

pub const KSIZE: usize = 3;

/// VALID 3x3 convolution, x: (h, w, cin) row-major -> (h-2, w-2, cout).
pub fn conv2d(x: &[f32], h: usize, w: usize, cin: usize, wt: &LayerWeights) -> Vec<f32> {
    let cout = wt.w_dims[3];
    debug_assert_eq!(wt.w_dims, vec![KSIZE, KSIZE, cin, cout]);
    debug_assert_eq!(x.len(), h * w * cin);
    let (oh, ow) = (h - KSIZE + 1, w - KSIZE + 1);
    let mut out = vec![0f32; oh * ow * cout];
    // Accumulate kernel-position-major to keep the inner loop over `cout`
    // contiguous in both the weight and output layouts.
    for i in 0..oh {
        for j in 0..ow {
            let o_base = (i * ow + j) * cout;
            let acc = &mut out[o_base..o_base + cout];
            acc.copy_from_slice(&wt.b);
            for dy in 0..KSIZE {
                for dx in 0..KSIZE {
                    let x_base = ((i + dy) * w + (j + dx)) * cin;
                    let w_base = (dy * KSIZE + dx) * cin * cout;
                    for c in 0..cin {
                        let xv = x[x_base + c];
                        let wrow = &wt.w[w_base + c * cout..w_base + (c + 1) * cout];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
        }
    }
    out
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// 2x2 stride-2 max-pool (truncating odd edges), x: (h, w, c).
pub fn maxpool2(x: &[f32], h: usize, w: usize, c: usize) -> Vec<f32> {
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; oh * ow * c];
    for i in 0..oh {
        for j in 0..ow {
            let o_base = (i * ow + j) * c;
            for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                let x_base = ((2 * i + dy) * w + (2 * j + dx)) * c;
                for ch in 0..c {
                    let v = x[x_base + ch];
                    if v > out[o_base + ch] {
                        out[o_base + ch] = v;
                    }
                }
            }
        }
    }
    out
}

/// Fully connected: out[j] = b[j] + sum_i x[i] * w[i, j].
pub fn fc(x: &[f32], wt: &LayerWeights) -> Vec<f32> {
    let (n_in, n_out) = (wt.w_dims[0], wt.w_dims[1]);
    debug_assert_eq!(x.len(), n_in);
    let mut out = wt.b.clone();
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue; // post-ReLU activations are sparse
        }
        let row = &wt.w[i * n_out..(i + 1) * n_out];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xv * wv;
        }
    }
    out
}

/// Run one full layer given its metadata; `in_shape` is (h, w, c) for conv
/// input or the flat length for fc.
pub fn layer_forward(
    layer: &LayerMeta,
    wt: &LayerWeights,
    x: &[f32],
    in_shape: &[usize],
) -> Vec<f32> {
    match layer.kind {
        LayerKind::Conv => {
            let (h, w, c) = (in_shape[0], in_shape[1], in_shape[2]);
            let mut out = conv2d(x, h, w, c, wt);
            if layer.relu {
                relu(&mut out);
            }
            if layer.pool {
                out = maxpool2(&out, h - 2, w - 2, layer.out);
            }
            out
        }
        LayerKind::Fc => {
            let mut out = fc(x, wt);
            if layer.relu {
                relu(&mut out);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::meta::LayerKind;

    fn lw(w: Vec<f32>, dims: Vec<usize>, b: Vec<f32>) -> LayerWeights {
        LayerWeights { w, w_dims: dims, b }
    }

    #[test]
    fn conv_identity_kernel() {
        // 4x4x1 input, kernel = center tap only -> output equals the 2x2
        // interior of the input.
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut w = vec![0f32; 9];
        w[4] = 1.0; // center of the 3x3
        let out = conv2d(&x, 4, 4, 1, &lw(w, vec![3, 3, 1, 1], vec![0.0]));
        assert_eq!(out, vec![5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn conv_multi_channel_sum() {
        // cin=2 with all-ones kernel and zero bias: each output = sum over
        // the 3x3x2 window.
        let x = vec![1f32; 3 * 3 * 2];
        let w = vec![1f32; 9 * 2];
        let out = conv2d(&x, 3, 3, 2, &lw(w, vec![3, 3, 2, 1], vec![0.5]));
        assert_eq!(out, vec![18.5]);
    }

    #[test]
    fn conv_bias_per_output_channel() {
        let x = vec![0f32; 3 * 3 * 1];
        let w = vec![0f32; 9 * 2];
        let out = conv2d(&x, 3, 3, 1, &lw(w, vec![3, 3, 1, 2], vec![1.0, -2.0]));
        assert_eq!(out, vec![1.0, -2.0]);
    }

    #[test]
    fn maxpool_truncates_odd() {
        // 3x3x1 -> 1x1x1, max over top-left 2x2 block only.
        let x = vec![1.0, 2.0, 9.0, 4.0, 3.0, 9.0, 9.0, 9.0, 9.0];
        assert_eq!(maxpool2(&x, 3, 3, 1), vec![4.0]);
    }

    #[test]
    fn fc_matches_manual() {
        // w: (2, 3) row-major: [[1,2,3],[4,5,6]], x = [1, 2], b = [0.5, 0, -1]
        let wt = lw(vec![1., 2., 3., 4., 5., 6.], vec![2, 3], vec![0.5, 0., -1.]);
        assert_eq!(fc(&[1.0, 2.0], &wt), vec![9.5, 12.0, 14.0]);
    }

    #[test]
    fn fc_skips_zeros_correctly() {
        let wt = lw(vec![1., 2., 3., 4.], vec![2, 2], vec![0., 0.]);
        assert_eq!(fc(&[0.0, 1.0], &wt), vec![3.0, 4.0]);
    }

    #[test]
    fn layer_forward_conv_relu_pool() {
        let layer = LayerMeta {
            kind: LayerKind::Conv,
            out: 1,
            pool: true,
            relu: true,
            act_shape: vec![1, 1, 1],
            k: 2,
            n_features: 1,
            threshold: 0.0,
            curve: vec![],
            macs: 0,
            adds: 0,
            time_ms: 0.0,
            energy_mj: 0.0,
            n_fragments: 1,
            fragment_ms: 0.0,
            fragment_energy_mj: 0.0,
        };
        // 4x4 input, center-tap kernel, bias -6 => interior [5,6,9,10]-6 =
        // [-1,0,3,4] -> relu [0,0,3,4] -> 2x2 pool -> [4]
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut w = vec![0f32; 9];
        w[4] = 1.0;
        let out = layer_forward(&layer, &lw(w, vec![3, 3, 1, 1], vec![-6.0]), &x, &[4, 4, 1]);
        assert_eq!(out, vec![4.0]);
    }
}
