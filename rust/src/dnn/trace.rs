//! Per-sample unit traces.
//!
//! A trace records, for one test sample, the outcome of every unit had it
//! executed: the utility gap, the predicted class, and whether the utility
//! test would exit there. The discrete-event scheduler sweeps (Figs.
//! 17–20: up to 40 000 jobs) sample jobs from these traces instead of
//! re-running inference per job — inference happens once (natively or via
//! PJRT), scheduling is measured separately. The oracle exit layer
//! (earliest layer whose prediction is already correct, Fig. 16) is also
//! recorded.

use super::kmeans::Scratch;
use super::network::Network;

#[derive(Clone, Copy, Debug)]
pub struct UnitOutcome {
    pub gap: f32,
    pub pred: i32,
    pub exit: bool,
    pub correct: bool,
}

#[derive(Clone, Debug)]
pub struct SampleTrace {
    pub label: i32,
    pub units: Vec<UnitOutcome>,
    /// First unit where the utility test passes (== number of mandatory
    /// units - 1). If it never passes, the last unit.
    pub exit_unit: usize,
    /// Earliest unit whose prediction is correct; None if never correct.
    pub oracle_unit: Option<usize>,
}

impl SampleTrace {
    /// Prediction under utility-based early termination.
    pub fn utility_pred(&self) -> i32 {
        self.units[self.exit_unit].pred
    }

    pub fn utility_correct(&self) -> bool {
        self.units[self.exit_unit].correct
    }

    /// Prediction with no early exit (full execution).
    pub fn full_pred(&self) -> i32 {
        self.units.last().unwrap().pred
    }

    /// Number of mandatory units under the dynamic partition: every unit
    /// up to and including the first confident one.
    pub fn mandatory_units(&self) -> usize {
        self.exit_unit + 1
    }
}

/// Compute traces for every test sample using the native forward path.
/// `inputs` overrides the test inputs (used for the Fig. 24 environment
/// shifts); defaults to the network's own test set.
pub fn compute_traces(net: &Network, inputs: Option<&[f32]>) -> Vec<SampleTrace> {
    let xs = inputs.unwrap_or(&net.test.x);
    let n = net.test.len();
    let slen = net.test.sample_len;
    assert_eq!(xs.len(), n * slen, "input length mismatch");
    let mut scratch = Scratch::default();
    let mut traces = Vec::with_capacity(n);
    for i in 0..n {
        let label = net.test.y[i];
        let mut act = xs[i * slen..(i + 1) * slen].to_vec();
        let mut units = Vec::with_capacity(net.meta.n_layers);
        for li in 0..net.meta.n_layers {
            let (next, res) = net.run_unit_native(li, &act, &mut scratch);
            units.push(UnitOutcome {
                gap: res.gap,
                pred: res.pred,
                exit: res.exit,
                correct: res.pred == label,
            });
            act = next;
        }
        let exit_unit = units
            .iter()
            .position(|u| u.exit)
            .unwrap_or(net.meta.n_layers - 1);
        let oracle_unit = units.iter().position(|u| u.correct);
        traces.push(SampleTrace { label, units, exit_unit, oracle_unit });
    }
    traces
}

/// Summary statistics over a trace set (drives Figs. 15/16 rows).
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceSummary {
    pub n: usize,
    pub acc_full: f64,
    pub acc_utility: f64,
    pub acc_oracle: f64,
    /// Mean inference time (ms) with / without early termination.
    pub time_utility_ms: f64,
    pub time_full_ms: f64,
    pub time_oracle_ms: f64,
    /// Fraction of samples that executed the final layer under utility exit.
    pub final_layer_rate: f64,
}

pub fn summarize(net: &Network, traces: &[SampleTrace]) -> TraceSummary {
    let n = traces.len();
    let unit_ms: Vec<f64> = net.meta.layers.iter().map(|l| l.time_ms).collect();
    let prefix_ms = |u: usize| unit_ms[..=u].iter().sum::<f64>();
    let mut s = TraceSummary { n, ..Default::default() };
    for t in traces {
        s.acc_full += t.units.last().unwrap().correct as u8 as f64;
        s.acc_utility += t.utility_correct() as u8 as f64;
        let oracle_u = t.oracle_unit.unwrap_or(net.meta.n_layers - 1);
        s.acc_oracle += t.oracle_unit.is_some() as u8 as f64;
        s.time_utility_ms += prefix_ms(t.exit_unit);
        s.time_full_ms += prefix_ms(net.meta.n_layers - 1);
        s.time_oracle_ms += prefix_ms(oracle_u);
        s.final_layer_rate += (t.exit_unit == net.meta.n_layers - 1) as u8 as f64;
    }
    for v in [
        &mut s.acc_full,
        &mut s.acc_utility,
        &mut s.acc_oracle,
        &mut s.time_utility_ms,
        &mut s.time_full_ms,
        &mut s.time_oracle_ms,
        &mut s.final_layer_rate,
    ] {
        *v /= n as f64;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(name: &str) -> Option<Network> {
        let dir = crate::artifacts_root().join(name);
        dir.join("meta.json").exists().then(|| Network::load(&dir).unwrap())
    }

    #[test]
    fn traces_have_consistent_structure() {
        let Some(net) = net("mnist") else { return };
        let traces = compute_traces(&net, None);
        assert_eq!(traces.len(), net.test.len());
        for t in &traces {
            assert_eq!(t.units.len(), net.meta.n_layers);
            assert!(t.exit_unit < net.meta.n_layers);
            // exit_unit is the first exiting unit
            for u in &t.units[..t.exit_unit] {
                assert!(!u.exit);
            }
            if let Some(o) = t.oracle_unit {
                assert!(t.units[o].correct);
                for u in &t.units[..o] {
                    assert!(!u.correct);
                }
            }
        }
    }

    #[test]
    fn early_termination_saves_time_at_small_accuracy_cost() {
        // The paper's headline: 5-26 % mean-time reduction, accuracy within
        // 2.5 % of full execution (Figs. 15/16).
        let Some(net) = net("mnist") else { return };
        let traces = compute_traces(&net, None);
        let s = summarize(&net, &traces);
        assert!(s.time_utility_ms < s.time_full_ms, "no time saved");
        assert!(
            s.acc_full - s.acc_utility < 0.06,
            "early exit lost too much accuracy: full={} utility={}",
            s.acc_full,
            s.acc_utility
        );
        // The oracle (minimum units for a *correct* result) upper-bounds
        // accuracy; it is not a time lower bound because the utility test
        // may exit even earlier with a wrong answer.
        assert!(s.acc_oracle >= s.acc_utility - 1e-9);
    }

    #[test]
    fn difficulty_correlates_with_exit_depth() {
        // The generator's difficulty knob must drive the dynamic partition:
        // easy samples exit earlier on average than hard ones.
        let Some(net) = net("mnist") else { return };
        let traces = compute_traces(&net, None);
        let (mut easy_sum, mut easy_n, mut hard_sum, mut hard_n) = (0.0, 0, 0.0, 0);
        for (t, &d) in traces.iter().zip(&net.test.difficulty) {
            if d < 0.25 {
                easy_sum += t.exit_unit as f64;
                easy_n += 1;
            } else if d > 0.6 {
                hard_sum += t.exit_unit as f64;
                hard_n += 1;
            }
        }
        if easy_n > 10 && hard_n > 10 {
            assert!(
                easy_sum / easy_n as f64 <= hard_sum / hard_n as f64,
                "easy samples exit later than hard ones"
            );
        }
    }
}
