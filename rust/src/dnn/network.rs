//! A fully-loaded agile DNN: metadata + weights + per-layer classifiers +
//! the test set, read from one `artifacts/<name>/` directory.

use std::path::{Path, PathBuf};

use super::forward::{self, LayerWeights};
use super::kmeans::{Classifier, ClassifyResult, Scratch};
use super::meta::NetMeta;
use crate::util::binfmt::Archive;

#[derive(Clone, Debug)]
pub struct TestSet {
    /// (n, h, w, c) flattened row-major.
    pub x: Vec<f32>,
    pub sample_len: usize,
    pub y: Vec<i32>,
    /// Per-sample generator difficulty (oracle analysis only).
    pub difficulty: Vec<f32>,
}

impl TestSet {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.sample_len..(i + 1) * self.sample_len]
    }
}

pub struct Network {
    pub dir: PathBuf,
    pub meta: NetMeta,
    pub weights: Vec<LayerWeights>,
    pub classifiers: Vec<Classifier>,
    pub test: TestSet,
    /// Alternative-environment test inputs (Fig. 24; esc10 only).
    pub env_x: Vec<Vec<f32>>,
}

impl Network {
    pub fn load(dir: &Path) -> Result<Network, String> {
        let meta = NetMeta::load(dir)?;
        let arc = Archive::load(&dir.join("tensors.bin")).map_err(|e| e.to_string())?;
        let hist = arc.get("train_y_hist").i32().to_vec();

        let mut weights = Vec::with_capacity(meta.n_layers);
        let mut classifiers = Vec::with_capacity(meta.n_layers);
        for li in 0..meta.n_layers {
            let w = arc.get(&format!("layer{li}_w"));
            let b = arc.get(&format!("layer{li}_b"));
            weights.push(LayerWeights {
                w: w.f32().to_vec(),
                w_dims: w.dims.clone(),
                b: b.f32().to_vec(),
            });
            let cent = arc.get(&format!("layer{li}_centroids"));
            let fidx = arc.get(&format!("layer{li}_feat_idx"));
            let labels = arc.get(&format!("layer{li}_centroid_label"));
            classifiers.push(Classifier::new(
                fidx.i32().iter().map(|&i| i as usize).collect(),
                cent.f32().to_vec(),
                labels.i32().to_vec(),
                meta.layers[li].threshold as f32,
                &hist,
            ));
        }

        let tx = arc.get("test_x");
        let sample_len: usize = tx.dims[1..].iter().product();
        let test = TestSet {
            x: tx.f32().to_vec(),
            sample_len,
            y: arc.get("test_y").i32().to_vec(),
            difficulty: arc.get("test_d").f32().to_vec(),
        };
        let mut env_x = Vec::new();
        for e in 1.. {
            match arc.try_get(&format!("env{e}_x")) {
                Some(t) => env_x.push(t.f32().to_vec()),
                None => break,
            }
        }
        Ok(Network { dir: dir.to_path_buf(), meta, weights, classifiers, test, env_x })
    }

    /// Load `artifacts/<name>` relative to the artifact root.
    pub fn load_named(name: &str) -> Result<Network, String> {
        Self::load(&crate::artifacts_root().join(name))
    }

    /// Input shape (h, w, c) of unit `li`'s activation input.
    pub fn unit_in_shape(&self, li: usize) -> Vec<usize> {
        if li == 0 {
            self.meta.input_shape.to_vec()
        } else {
            self.meta.layers[li - 1].act_shape.clone()
        }
    }

    /// Native execution of unit `li`: layer forward + classify.
    /// Returns (next activation, classify result).
    pub fn run_unit_native(
        &self,
        li: usize,
        act_in: &[f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, ClassifyResult) {
        let in_shape = self.unit_in_shape(li);
        let act =
            forward::layer_forward(&self.meta.layers[li], &self.weights[li], act_in, &in_shape);
        let res = self.classifiers[li].classify(&act, scratch);
        (act, res)
    }

    /// Run a sample through the whole network natively with the utility
    /// test; returns (exit_layer, prediction).
    pub fn infer_native(&self, sample: &[f32], scratch: &mut Scratch) -> (usize, i32) {
        let mut act = sample.to_vec();
        let mut last = 0i32;
        for li in 0..self.meta.n_layers {
            let (next, res) = self.run_unit_native(li, &act, scratch);
            last = res.pred;
            if res.exit {
                return (li, res.pred);
            }
            act = next;
        }
        (self.meta.n_layers - 1, last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mnist() -> Option<Network> {
        let dir = crate::artifacts_root().join("mnist");
        dir.join("meta.json").exists().then(|| Network::load(&dir).unwrap())
    }

    #[test]
    fn loads_real_network() {
        let Some(net) = mnist() else { return };
        assert_eq!(net.weights.len(), net.meta.n_layers);
        assert_eq!(net.classifiers.len(), net.meta.n_layers);
        assert_eq!(net.test.len(), net.meta.n_test);
        assert_eq!(net.test.sample_len, 16 * 16);
        // weight dims line up with the layer topology
        assert_eq!(net.weights[0].w_dims, vec![3, 3, 1, net.meta.layers[0].out]);
    }

    #[test]
    fn native_inference_beats_chance() {
        let Some(net) = mnist() else { return };
        let mut scratch = Scratch::default();
        let mut correct = 0usize;
        let n = net.test.len();
        for i in 0..n {
            let (_, pred) = net.infer_native(net.test.sample(i), &mut scratch);
            if pred == net.test.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        assert!(acc > 0.6, "native inference accuracy {acc} too low");
    }

    #[test]
    fn unit_activation_shapes_match_meta() {
        let Some(net) = mnist() else { return };
        let mut scratch = Scratch::default();
        let mut act = net.test.sample(0).to_vec();
        for li in 0..net.meta.n_layers {
            let (next, _) = net.run_unit_native(li, &act, &mut scratch);
            assert_eq!(next.len(), net.meta.flat_dim(li), "layer {li}");
            act = next;
        }
    }
}
