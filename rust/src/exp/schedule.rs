//! Figs. 17–20 — the real-time scheduler evaluation: EDF vs EDF-M vs
//! Zygarde across the seven Table 4 systems on all four datasets.
//!
//! Workload parameters follow §8.5: MNIST runs overloaded (U > 1, T = 3 s,
//! D = 6 s); ESC-10 runs 80 jobs at T = 0.36 min; CIFAR-100 and VWW run
//! with D = 2T. "Scheduled" means the mandatory part completed before the
//! deadline; "correct" additionally requires the right prediction —
//! optional units can flip a wrong early answer to a right one, which is
//! where Zygarde beats EDF-M at high η.

use std::sync::Arc;

use crate::coordinator::sched::SchedulerKind;
use crate::dnn::network::Network;
use crate::dnn::trace::compute_traces;
use crate::nvm::NvmSpec;
use crate::sim::metrics::Metrics;
use crate::sim::sweep::{
    self, HarvesterSpec, ScenarioMatrix, SeedPolicy, SweepReport, TaskMix,
};
use crate::sim::workload::task_from_network;

use super::common::{pct, print_header, print_row, system, System};

#[derive(Clone, Debug)]
pub struct WorkloadParams {
    pub period_ms: f64,
    pub deadline_ms: f64,
    pub n_jobs: u64,
}

/// §8.5 workload parameters per dataset (job counts are the paper's; the
/// CLI can scale them down for quick runs).
pub fn params_for(dataset: &str) -> WorkloadParams {
    match dataset {
        // U > 1: C = 3.8 s > T = 3 s.
        "mnist" => WorkloadParams { period_ms: 3000.0, deadline_ms: 6000.0, n_jobs: 500 },
        // 80 jobs, T = 0.36 min, D = 0.72 min.
        "esc10" => WorkloadParams { period_ms: 21_600.0, deadline_ms: 43_200.0, n_jobs: 80 },
        // 500 jobs, D = 2T.
        "cifar100" => WorkloadParams { period_ms: 9000.0, deadline_ms: 18_000.0, n_jobs: 500 },
        // 40 000 jobs, D = 2T.
        "vww" => WorkloadParams { period_ms: 3000.0, deadline_ms: 6000.0, n_jobs: 40_000 },
        other => panic!("no workload params for `{other}`"),
    }
}

pub struct ScheduleCell {
    pub system: System,
    pub scheduler: SchedulerKind,
    /// NVM commit policy this cell ran under (ideal unless an `nvms` axis
    /// was set — `zygarde schedule --nvm fram-jit`).
    pub nvm: NvmSpec,
    pub metrics: Metrics,
}

pub const SCHEDULERS: [SchedulerKind; 3] =
    [SchedulerKind::Edf, SchedulerKind::EdfMandatory, SchedulerKind::Zygarde];

/// The (systems × schedulers [× NVM policies]) matrix behind Figs. 17–20,
/// with paired environment seeds so every scheduler sees the same release
/// and harvest streams within a system (the apples-to-apples comparison
/// the figures need). An empty `nvms` keeps the paper's zero-cost default;
/// passing policies regenerates the figures under realistic persistence
/// costs. The matrix is the shard-aware entry point: hand it to
/// `sweep::run_matrix`, or split it across hosts with
/// `sweep::shard::run_shard` / `zygarde sweep --matrix schedule --shard I/N`.
pub fn matrix(
    dataset: &str,
    systems: &[usize],
    n_jobs_override: Option<u64>,
    seed: u64,
    nvms: &[NvmSpec],
) -> ScenarioMatrix {
    let net = Network::load(&crate::artifacts_root().join(dataset)).unwrap();
    let p = params_for(dataset);
    let n_jobs = n_jobs_override.unwrap_or(p.n_jobs);
    // Release jitter averages ~5 %; pad the horizon so n_jobs release.
    let duration_ms = n_jobs as f64 * p.period_ms * 1.06;
    let traces = Arc::new(compute_traces(&net, None));
    let task = task_from_network(0, &net, p.period_ms, p.deadline_ms, Some(traces));

    let mut m = ScenarioMatrix::new(format!("schedule-{dataset}"), seed)
        .mixes(vec![TaskMix::from_tasks(dataset, vec![task])])
        .harvesters(systems.iter().map(|&sid| HarvesterSpec::System(sid)).collect())
        .schedulers(SCHEDULERS.to_vec())
        .duration_ms(duration_ms)
        .seed_policy(SeedPolicy::PairedEnvironment);
    if !nvms.is_empty() {
        m = m.nvms(nvms.to_vec());
    }
    m
}

/// Recover per-cell figure rows from a finished report (a local
/// `run_matrix` result or a `sweep::shard::merge` of shard files — the
/// report's cells are in matrix-expansion order either way).
pub fn cells_from(matrix: &ScenarioMatrix, report: &SweepReport) -> Vec<ScheduleCell> {
    let scenarios = matrix.expand();
    assert_eq!(scenarios.len(), report.cells.len(), "report does not match matrix");
    scenarios
        .iter()
        .zip(&report.cells)
        .map(|(sc, cell)| {
            let sid = match sc.harvester {
                HarvesterSpec::System(id) => id,
                _ => unreachable!("schedule matrix only uses Table 4 systems"),
            };
            ScheduleCell {
                system: system(sid),
                scheduler: sc.scheduler,
                nvm: sc.nvm,
                metrics: cell.metrics.clone(),
            }
        })
        .collect()
}

/// Run the matrix on all cores under the given NVM policies (empty =
/// the zero-cost ideal).
pub fn run_with_nvms(
    dataset: &str,
    systems: &[usize],
    n_jobs_override: Option<u64>,
    seed: u64,
    nvms: &[NvmSpec],
) -> Vec<ScheduleCell> {
    let m = matrix(dataset, systems, n_jobs_override, seed, nvms);
    let report = sweep::run_matrix(&m, sweep::default_threads());
    cells_from(&m, &report)
}

/// The paper-default run: zero-cost ideal persistence.
pub fn run(
    dataset: &str,
    systems: &[usize],
    n_jobs_override: Option<u64>,
    seed: u64,
) -> Vec<ScheduleCell> {
    run_with_nvms(dataset, systems, n_jobs_override, seed, &[])
}

pub fn print(dataset: &str, cells: &[ScheduleCell]) {
    print_header(
        &format!("Figs. 17-20: scheduler comparison — {dataset}"),
        &["system", "eta", "sched", "nvm", "released", "scheduled%", "correct%", "opt-units"],
    );
    for c in cells {
        print_row(&[
            format!("S{}", c.system.id),
            format!("{:.2}", c.system.eta),
            c.scheduler.name().into(),
            c.nvm.label(),
            c.metrics.released.to_string(),
            pct(c.metrics.event_scheduled_rate()),
            pct(c.metrics.event_correct_rate()),
            c.metrics.optional_units.to_string(),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ready() -> bool {
        crate::artifacts_root().join("mnist/meta.json").exists()
    }

    fn rate(cells: &[ScheduleCell], sid: usize, k: SchedulerKind) -> f64 {
        cells
            .iter()
            .find(|c| c.system.id == sid && c.scheduler == k)
            .unwrap()
            .metrics
            .event_scheduled_rate()
    }

    fn correct(cells: &[ScheduleCell], sid: usize, k: SchedulerKind) -> f64 {
        cells
            .iter()
            .find(|c| c.system.id == sid && c.scheduler == k)
            .unwrap()
            .metrics
            .event_correct_rate()
    }

    #[test]
    fn nvm_axis_multiplies_cells_and_labels_them() {
        if !ready() {
            return;
        }
        let nvms = [NvmSpec::ideal(), NvmSpec::fram_jit()];
        let cells = run_with_nvms("mnist", &[1], Some(20), 3, &nvms);
        assert_eq!(cells.len(), nvms.len() * SCHEDULERS.len());
        for spec in &nvms {
            assert_eq!(
                cells.iter().filter(|c| c.nvm == *spec).count(),
                SCHEDULERS.len()
            );
        }
    }

    #[test]
    fn overloaded_mnist_edfm_and_zygarde_beat_edf() {
        if !ready() {
            return;
        }
        // Persistent power, U > 1 (Fig. 17's left group).
        let cells = run("mnist", &[1], Some(60), 42);
        let edf = rate(&cells, 1, SchedulerKind::Edf);
        let edfm = rate(&cells, 1, SchedulerKind::EdfMandatory);
        let zyg = rate(&cells, 1, SchedulerKind::Zygarde);
        assert!(edf < 1.0, "EDF should not schedule everything at U>1: {edf}");
        assert!(edfm > edf, "edfm={edfm} edf={edf}");
        assert!(zyg > edf, "zyg={zyg} edf={edf}");
    }

    #[test]
    fn esc10_persistent_all_schedulable() {
        if !ready() {
            return;
        }
        // U < 1 on persistent power: everyone schedules everything (Fig. 18).
        let cells = run("esc10", &[1], Some(40), 7);
        for k in SCHEDULERS {
            let r = rate(&cells, 1, k);
            assert!(r > 0.97, "{}: rate={r}", k.name());
        }
    }

    #[test]
    fn intermittent_rf_zygarde_correctness_at_high_eta() {
        if !ready() {
            return;
        }
        // System 5 (RF, eta=.71): Zygarde >= EDF-M on correct results
        // (optional units refine), EDF-M >= EDF on scheduled (Fig. 17-20).
        let cells = run("mnist", &[5], Some(80), 11);
        let edf_s = rate(&cells, 5, SchedulerKind::Edf);
        let edfm_s = rate(&cells, 5, SchedulerKind::EdfMandatory);
        let zyg_c = correct(&cells, 5, SchedulerKind::Zygarde);
        let edfm_c = correct(&cells, 5, SchedulerKind::EdfMandatory);
        assert!(edfm_s >= edf_s, "edfm={edfm_s} edf={edf_s}");
        assert!(zyg_c >= edfm_c - 0.03, "zyg_c={zyg_c} edfm_c={edfm_c}");
    }
}
