//! Experiment drivers — one per table/figure of the paper's evaluation
//! (see DESIGN.md §3 for the index). Each driver prints the same rows or
//! series the paper reports and returns a machine-readable summary used by
//! the integration tests and EXPERIMENTS.md generation.

pub mod acoustic;
pub mod adaptation;
pub mod capacitor_sweep;
pub mod chrt_cmp;
pub mod classifiers_cmp;
pub mod common;
pub mod eta;
pub mod loss_compare;
pub mod nvm_cmp;
pub mod overhead;
pub mod schedule;
pub mod schedulability;
pub mod sweep_cli;
pub mod termination;
pub mod threshold;
pub mod visual;
