//! NVM commit-policy comparison: the checkpointing trade-off the paper's
//! §8 overhead numbers sit on top of, swept across commit policies ×
//! harvesters × capacitor sizes on the sweep engine.
//!
//! Paired environment seeds give every policy the same harvester (same
//! parameters and RNG seed) and the same release-jitter stream, so the
//! *only initial* difference between paired cells is what persistence
//! costs and what a power failure destroys. As in every closed-loop
//! paired comparison here (scheduler, clock), trajectories co-evolve:
//! a costly commit consumes harvester steps the ideal cell takes later,
//! so traces are paired, not bitwise-identical.
//!
//! * `ideal+frag` — the zero-cost idealization (upper bound);
//! * `fram+frag` — commit every fragment: highest steady-state overhead,
//!   at most the interrupted commit's fragment is ever lost;
//! * `fram+unit` — commit at unit boundaries: ~4× cheaper steady-state,
//!   but a brownout rolls mid-unit progress back for re-execution;
//! * `fram+jit` — commit only on the low-voltage trigger: near-zero
//!   overhead while energy is plentiful, one snapshot when it is not.
//!
//! Runs entirely on the synthetic workload — no `artifacts/` required.

use crate::coordinator::sched::SchedulerKind;
use crate::energy::harvester::HarvesterKind;
use crate::nvm::NvmSpec;
use crate::sim::sweep::{
    self, HarvesterSpec, ScenarioMatrix, SeedPolicy, SweepReport, TaskMix,
};

use super::common::{pct, print_header, print_row};

/// The four policies the comparison sweeps, in label order.
pub fn policies() -> Vec<NvmSpec> {
    vec![
        NvmSpec::ideal(),
        NvmSpec::fram_every_fragment(),
        NvmSpec::fram_unit_boundary(),
        NvmSpec::fram_jit(),
    ]
}

/// Policies × harvesters × capacitors, paired-seed. `n_jobs` scales the
/// per-cell horizon (task periods are 300/500 ms). The matrix is the
/// shard-aware entry point: run it locally with `sweep::run_matrix` or
/// split it across hosts with `sweep::shard::run_shard` /
/// `zygarde sweep --matrix nvm --shard I/N` (it needs no `artifacts/`,
/// which is why the CI shard jobs sweep it).
pub fn matrix(n_jobs: u64, seed: u64) -> ScenarioMatrix {
    let duration_ms = (n_jobs as f64 * 300.0).max(30_000.0);
    ScenarioMatrix::new("nvm-cmp", seed)
        .mixes(vec![TaskMix::synthetic("duo", 2, 3, seed ^ 0x9E37)])
        .harvesters(vec![
            // Plentiful: the steady-state commit bill dominates.
            HarvesterSpec::Persistent { power_mw: 600.0 },
            // Weak RF: frequent brownouts — lost work dominates.
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 90.0,
                q: 0.85,
                duty: 0.55,
                eta: 0.45,
            },
            // Mid solar: both effects visible.
            HarvesterSpec::Markov {
                kind: HarvesterKind::Solar,
                on_power_mw: 250.0,
                q: 0.92,
                duty: 0.5,
                eta: 0.6,
            },
        ])
        .capacitors_mf(vec![5.0, 50.0])
        .schedulers(vec![SchedulerKind::Zygarde])
        .nvms(policies())
        .reps(2)
        .duration_ms(duration_ms)
        .seed_policy(SeedPolicy::PairedEnvironment)
}

/// Aggregate of every cell that ran one NVM policy.
#[derive(Clone, Debug, Default)]
pub struct PolicyRow {
    pub nvm: String,
    pub released: u64,
    pub scheduled: u64,
    pub correct: u64,
    pub event_count: u64,
    pub commits: u64,
    pub jit_commits: u64,
    pub restores: u64,
    pub lost_fragments: u64,
    pub refragments: u64,
    pub reboots: u64,
    pub commit_mj: f64,
    pub restore_mj: f64,
    pub consumed_mj: f64,
}

impl PolicyRow {
    /// Scheduled / all sensor events — the paired-stream denominator.
    pub fn event_scheduled_rate(&self) -> f64 {
        self.scheduled as f64 / self.event_count.max(1) as f64
    }

    /// Commit + restore energy over everything consumed.
    pub fn overhead(&self) -> f64 {
        (self.commit_mj + self.restore_mj) / self.consumed_mj.max(1e-9)
    }
}

/// Fold a finished sweep into one row per NVM policy. The report's cells
/// are in matrix-expansion order — true for a local `run_matrix` result
/// and for a `sweep::shard::merge` of shard files alike — so zipping
/// against `matrix.expand()` recovers each cell's policy.
pub fn summarize(matrix: &ScenarioMatrix, report: &SweepReport) -> Vec<PolicyRow> {
    let scenarios = matrix.expand();
    assert_eq!(scenarios.len(), report.cells.len(), "report does not match matrix");
    let mut rows: Vec<PolicyRow> = matrix
        .nvms
        .iter()
        .map(|spec| PolicyRow { nvm: spec.label(), ..Default::default() })
        .collect();
    for (sc, cell) in scenarios.iter().zip(&report.cells) {
        let row = rows
            .iter_mut()
            .find(|r| r.nvm == sc.nvm.label())
            .expect("cell policy missing from matrix axis");
        let m = &cell.metrics;
        row.released += m.released;
        row.scheduled += m.scheduled;
        row.correct += m.correct;
        row.event_count += m.released + m.capture_missed;
        row.commits += m.commits;
        row.jit_commits += m.jit_commits;
        row.restores += m.restores;
        row.lost_fragments += m.lost_fragments;
        row.refragments += m.refragments;
        row.reboots += m.reboots;
        row.commit_mj += m.commit_mj;
        row.restore_mj += m.restore_mj;
        row.consumed_mj += m.consumed_mj;
    }
    rows
}

/// Run the comparison at the given horizon on all cores.
pub fn run(n_jobs: u64, seed: u64) -> (ScenarioMatrix, SweepReport) {
    let m = matrix(n_jobs, seed);
    let report = sweep::run_matrix(&m, sweep::default_threads());
    (m, report)
}

pub fn print(rows: &[PolicyRow]) {
    print_header(
        "NVM commit policies (Zygarde, 3 harvesters x {5,50} mF, paired seeds)",
        &["policy", "sched%", "acc%", "commits", "commit mJ", "restores", "lost", "ovh%"],
    );
    for r in rows {
        print_row(&[
            r.nvm.clone(),
            pct(r.event_scheduled_rate()),
            pct(r.correct as f64 / r.scheduled.max(1) as f64),
            r.commits.to_string(),
            format!("{:.2}", r.commit_mj),
            r.restores.to_string(),
            r.lost_fragments.to_string(),
            pct(r.overhead()),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Acceptance: the comparison's report is bitwise identical no matter
    /// the thread count.
    #[test]
    fn report_is_bitwise_identical_at_1_and_8_threads() {
        let m = matrix(40, 11);
        let one = sweep::run_matrix(&m, 1);
        let eight = sweep::run_matrix(&m, 8);
        assert_eq!(one.json_string(), eight.json_string());
    }

    /// The three FRAM policies occupy distinct, paper-plausible points on
    /// the commit-overhead vs. lost-work plane; the ideal policy is free.
    #[test]
    fn policies_trade_off_commit_cost_against_lost_work() {
        let (m, report) = run(150, 9);
        let rows = summarize(&m, &report);
        let row = |label: &str| rows.iter().find(|r| r.nvm == label).unwrap().clone();
        let ideal = row("ideal+frag");
        let every = row("fram+frag");
        let unit = row("fram+unit");
        let jit = row("fram+jit");

        // Paired seeds start every policy from the same release-jitter
        // stream, but commit latency shifts step boundaries, which can
        // re-order which task draws which jitter value and let the
        // schedules drift apart statistically. The drift stays small
        // (same jitter distribution either way); require the event
        // universes to agree within a few percent.
        let close = |a: u64, b: u64| {
            let diff = (a as i64 - b as i64).unsigned_abs();
            diff <= 24 + a.max(b) / 20
        };
        assert!(close(ideal.event_count, every.event_count));
        assert!(close(ideal.event_count, unit.event_count));
        assert!(close(ideal.event_count, jit.event_count));

        // Ideal: persistence is free and loses nothing.
        assert_eq!(ideal.commit_mj, 0.0);
        assert_eq!(ideal.restore_mj, 0.0);
        assert_eq!(ideal.lost_fragments, 0);

        // Every-fragment pays the highest steady-state commit bill.
        assert!(every.commit_mj > 0.0);
        assert!(every.commits > unit.commits, "{} vs {}", every.commits, unit.commits);
        assert!(every.commit_mj > unit.commit_mj);

        // Unit-boundary trades that saving for rolled-back work under
        // brownouts (the weak-harvester cells guarantee some).
        assert!(unit.lost_fragments > 0);
        assert!(unit.lost_fragments >= every.lost_fragments);

        // JIT commits rarely — only when the capacitor actually sags —
        // and every commit it does make is voltage-triggered.
        assert!(jit.commits < every.commits);
        assert_eq!(jit.commits, jit.jit_commits);

        // All four stay distinct outcomes.
        let mut commit_counts: Vec<u64> =
            vec![ideal.commits, every.commits, unit.commits, jit.commits];
        commit_counts.sort_unstable();
        commit_counts.dedup();
        assert!(commit_counts.len() >= 3, "policies collapsed: {commit_counts:?}");

        // Overheads stay paper-plausible (single-digit percents).
        for r in [&every, &unit, &jit] {
            assert!(r.overhead() < 0.10, "{}: overhead {}", r.nvm, r.overhead());
        }
    }
}
