//! Fig. 8 — the utility-threshold trade-off: exit rate and exit accuracy
//! as the |d2−d1| threshold sweeps, per layer. The compile path records
//! this curve on validation data; this driver re-derives it on the test
//! set from the traces so both views are available.

use crate::dnn::network::Network;
use crate::dnn::trace::compute_traces;

use super::common::{print_header, print_row};

pub struct ThresholdPoint {
    pub threshold: f64,
    pub exit_rate: f64,
    pub exit_accuracy: f64,
}

/// Test-set sweep for one layer: for each candidate threshold, the
/// fraction of samples whose gap clears it at that layer and their
/// accuracy if they exited there.
pub fn sweep_layer(net: &Network, layer: usize, n_points: usize) -> Vec<ThresholdPoint> {
    let traces = compute_traces(net, None);
    let mut gaps: Vec<f32> = traces.iter().map(|t| t.units[layer].gap).collect();
    gaps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut out = Vec::with_capacity(n_points);
    for i in 0..n_points {
        let q = i as f64 / (n_points - 1) as f64;
        let thr = gaps[((q * (gaps.len() - 1) as f64) as usize).min(gaps.len() - 1)] as f64;
        let exits: Vec<&crate::dnn::trace::SampleTrace> = traces
            .iter()
            .filter(|t| t.units[layer].gap as f64 >= thr)
            .collect();
        let rate = exits.len() as f64 / traces.len() as f64;
        let acc = if exits.is_empty() {
            0.0
        } else {
            exits.iter().filter(|t| t.units[layer].correct).count() as f64 / exits.len() as f64
        };
        out.push(ThresholdPoint { threshold: thr, exit_rate: rate, exit_accuracy: acc });
    }
    out
}

pub fn print(net: &Network, layer: usize, points: &[ThresholdPoint]) {
    print_header(
        &format!("Fig. 8: utility threshold trade-off ({} layer {layer})", net.meta.name),
        &["threshold", "exit-rate", "exit-acc"],
    );
    for p in points {
        print_row(&[
            format!("{:.3}", p.threshold),
            format!("{:.2}", p.exit_rate),
            format!("{:.3}", p.exit_accuracy),
        ]);
    }
    println!(
        "chosen offline threshold: {:.3}",
        net.meta.layers[layer].threshold
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tradeoff_shape_on_cifar() {
        let dir = crate::artifacts_root().join("cifar100");
        if !dir.join("meta.json").exists() {
            return;
        }
        let net = Network::load(&dir).unwrap();
        let pts = sweep_layer(&net, 0, 12);
        // Exit rate monotonically non-increasing in the threshold.
        for w in pts.windows(2) {
            assert!(w[1].exit_rate <= w[0].exit_rate + 1e-9);
        }
        // Larger thresholds should not *hurt* accuracy much: compare the
        // loosest vs tightest non-empty quartiles.
        let lo = &pts[1];
        let hi = pts.iter().rev().find(|p| p.exit_rate > 0.05).unwrap();
        assert!(
            hi.exit_accuracy >= lo.exit_accuracy - 0.05,
            "acc dropped with stricter threshold: {} -> {}",
            lo.exit_accuracy,
            hi.exit_accuracy
        );
    }
}
