//! Fig. 21 — effect of capacitor size. CIFAR-100 workload, RF η = 0.51,
//! T = 9–11 s, D = 2T, capacitors {0.1, 1, 50, 470} mF. Small capacitors
//! miss deadlines on re-executed fragments across outages; the 470 mF one
//! misses them while charging. 50 mF is the sweet spot.

use std::sync::Arc;

use crate::dnn::network::Network;
use crate::dnn::trace::compute_traces;
use crate::energy::harvester::HarvesterKind;
use crate::nvm::NvmSpec;
use crate::sim::metrics::Metrics;
use crate::sim::sweep::{self, HarvesterSpec, ScenarioMatrix, SeedPolicy, SweepReport, TaskMix};
use crate::sim::workload::task_from_network;

use super::common::{pct, print_header, print_row};

pub struct CapacitorCell {
    pub c_mf: f64,
    /// NVM commit policy this cell ran under (ideal unless an `nvms` axis
    /// was set — `zygarde capacitor --nvm fram-unit`).
    pub nvm: NvmSpec,
    pub metrics: Metrics,
}

pub const SIZES_MF: [f64; 4] = [0.1, 1.0, 50.0, 470.0];

/// The paper's §8.6 setup "stress tests the system": the RF source at
/// ~0.5 m is *nearly always on but weak* — its instantaneous power sits
/// below the MCU's 110 mW active draw, so execution always drains the
/// capacitor and the device duty-cycles through it. That is the regime
/// where capacitor sizing matters: 0.1 mF cannot complete one fragment
/// per boot, 1 mF thrashes on re-executions, 50 mF cycles fine-grained
/// (every deadline window gets CPU time), 470 mF blanks whole deadline
/// windows while recharging its 994 mJ hysteresis band.
pub const STRESS_AVG_POWER_MW: f64 = 70.0;
pub const STRESS_DUTY: f64 = 0.92;

/// The Fig. 21 matrix: one capacitor-size scenario per cell (× NVM
/// policies when `nvms` is non-empty), cold start (`precharge(false)`) so
/// the 470 mF unit pays its long initial charge, as in the paper. The
/// matrix is the shard-aware entry point: run it locally with
/// `sweep::run_matrix` or split it across hosts with
/// `sweep::shard::run_shard` / `zygarde sweep --matrix capacitor --shard I/N`.
pub fn matrix(n_jobs: u64, seed: u64, nvms: &[NvmSpec]) -> ScenarioMatrix {
    let net = Network::load(&crate::artifacts_root().join("cifar100")).unwrap();
    let traces = Arc::new(compute_traces(&net, None));
    let stress_mw: f64 = std::env::var("CAP_POWER")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(STRESS_AVG_POWER_MW);
    let duration_ms = n_jobs as f64 * 10_000.0 * 1.06;
    // Period 9-11 s -> midpoint, with the engine's sporadic jitter.
    let task = task_from_network(0, &net, 10_000.0, 20_000.0, Some(traces));

    let mut m = ScenarioMatrix::new("capacitor-sweep", seed)
        .mixes(vec![TaskMix::from_tasks("cifar100", vec![task])])
        .harvesters(vec![HarvesterSpec::Markov {
            kind: HarvesterKind::Rf,
            on_power_mw: stress_mw / STRESS_DUTY,
            q: 0.75, // bursty at η ≈ 0.5 like Table 4's System 6
            duty: STRESS_DUTY,
            eta: 0.51, // same offline-estimated η as system(6)
        }])
        .capacitors_mf(SIZES_MF.to_vec())
        .precharge(false)
        .duration_ms(duration_ms)
        .seed_policy(SeedPolicy::PairedEnvironment);
    if !nvms.is_empty() {
        m = m.nvms(nvms.to_vec());
    }
    m
}

/// Recover figure rows from a finished report (local or shard-merged).
pub fn cells_from(matrix: &ScenarioMatrix, report: &SweepReport) -> Vec<CapacitorCell> {
    let scenarios = matrix.expand();
    assert_eq!(scenarios.len(), report.cells.len(), "report does not match matrix");
    scenarios
        .iter()
        .zip(&report.cells)
        .map(|(sc, cell)| CapacitorCell {
            c_mf: sc.capacitor_mf,
            nvm: sc.nvm,
            metrics: cell.metrics.clone(),
        })
        .collect()
}

/// Run the matrix on all cores under the given NVM policies (empty =
/// the zero-cost ideal).
pub fn run_with_nvms(n_jobs: u64, seed: u64, nvms: &[NvmSpec]) -> Vec<CapacitorCell> {
    let m = matrix(n_jobs, seed, nvms);
    let report = sweep::run_matrix(&m, sweep::default_threads());
    cells_from(&m, &report)
}

/// The paper-default run: zero-cost ideal persistence.
pub fn run(n_jobs: u64, seed: u64) -> Vec<CapacitorCell> {
    run_with_nvms(n_jobs, seed, &[])
}

pub fn print(cells: &[CapacitorCell]) {
    print_header(
        "Fig. 21: effect of capacitor size (CIFAR-100, RF eta=0.51)",
        &["C (mF)", "nvm", "scheduled%", "missed", "re-frags", "reboots"],
    );
    for c in cells {
        print_row(&[
            format!("{}", c.c_mf),
            c.nvm.label(),
            pct(c.metrics.event_scheduled_rate()),
            c.metrics.deadline_missed.to_string(),
            c.metrics.refragments.to_string(),
            c.metrics.reboots.to_string(),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvm_axis_multiplies_capacitor_cells() {
        if !crate::artifacts_root().join("cifar100/meta.json").exists() {
            return;
        }
        let nvms = [NvmSpec::ideal(), NvmSpec::fram_unit_boundary()];
        let cells = run_with_nvms(10, 5, &nvms);
        assert_eq!(cells.len(), SIZES_MF.len() * nvms.len());
        for spec in &nvms {
            assert_eq!(cells.iter().filter(|c| c.nvm == *spec).count(), SIZES_MF.len());
        }
    }

    #[test]
    fn fifty_mf_is_the_sweet_spot() {
        if !crate::artifacts_root().join("cifar100/meta.json").exists() {
            return;
        }
        // Average over seeds: single-trace burst alignment is noisy.
        let runs: Vec<_> = [3u64, 11, 29].iter().map(|&s| run(40, s)).collect();
        let rate = |mf: f64| {
            runs.iter()
                .map(|cells| {
                    cells.iter().find(|c| c.c_mf == mf).unwrap().metrics.event_scheduled_rate()
                })
                .sum::<f64>()
                / runs.len() as f64
        };
        // 50 mF beats both extremes (the paper's Fig. 21 shape).
        assert!(rate(50.0) >= rate(0.1), "50mF {} vs 0.1mF {}", rate(50.0), rate(0.1));
        assert!(rate(50.0) >= rate(470.0) - 0.02, "50mF {} vs 470mF {}", rate(50.0), rate(470.0));
        // The 0.1 mF capacitor cannot bank even one atomic fragment's
        // energy (usable 0.36 mJ < ~0.8 mJ/fragment): E_man gates all
        // execution, so nothing is ever scheduled — the left edge of the
        // paper's U.
        let tiny = &runs[0].iter().find(|c| c.c_mf == 0.1).unwrap().metrics;
        assert_eq!(tiny.scheduled, 0, "0.1 mF should never complete a job");
        // 1 mF makes *some* progress but with heavy re-execution overhead.
        let one = &runs[0].iter().find(|c| c.c_mf == 1.0).unwrap().metrics;
        assert!(one.refragments > 0 || one.reboots > 10, "1 mF should thrash");
    }
}
