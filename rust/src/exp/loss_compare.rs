//! Fig. 15 — loss-function comparison under early termination: the
//! layer-aware loss (Eq. 4) vs cross-entropy [142] vs contrastive-last-
//! layer [71], on MNIST and ESC-10. All three networks share structure,
//! hyper-parameters and data; only the training loss differs (the
//! ablation artifacts are built by `aot.py`).

use crate::dnn::network::Network;
use crate::dnn::trace::{compute_traces, summarize, TraceSummary};

use super::common::{pct, print_header, print_row};

pub struct LossRow {
    pub dataset: String,
    pub loss: String,
    pub summary: TraceSummary,
}

fn artifact_dir(dataset: &str, loss: &str) -> std::path::PathBuf {
    let root = crate::artifacts_root();
    if loss == "layer_aware" {
        root.join(dataset)
    } else {
        root.join(format!("ablation_{loss}_{dataset}"))
    }
}

pub fn run(datasets: &[&str]) -> Vec<LossRow> {
    let mut rows = Vec::new();
    for &ds in datasets {
        for loss in ["layer_aware", "contrastive", "cross_entropy"] {
            let dir = artifact_dir(ds, loss);
            let net = Network::load(&dir)
                .unwrap_or_else(|e| panic!("missing ablation artifact {}: {e}", dir.display()));
            let traces = compute_traces(&net, None);
            rows.push(LossRow {
                dataset: ds.into(),
                loss: loss.into(),
                summary: summarize(&net, &traces),
            });
        }
    }
    rows
}

pub fn print(rows: &[LossRow]) {
    print_header(
        "Fig. 15: loss functions under early termination",
        &["dataset", "loss", "acc(exit)", "acc(full)", "time(exit)", "final-layer%"],
    );
    for r in rows {
        print_row(&[
            r.dataset.clone(),
            r.loss.clone(),
            pct(r.summary.acc_utility),
            pct(r.summary.acc_full),
            format!("{:.0} ms", r.summary.time_utility_ms),
            pct(r.summary.final_layer_rate),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ready() -> bool {
        artifact_dir("mnist", "cross_entropy").join("meta.json").exists()
    }

    #[test]
    fn layer_aware_wins_under_early_exit() {
        if !ready() {
            return;
        }
        let rows = run(&["mnist", "esc10"]);
        for ds in ["mnist", "esc10"] {
            let get = |loss: &str| {
                &rows
                    .iter()
                    .find(|r| r.dataset == ds && r.loss == loss)
                    .unwrap()
                    .summary
            };
            let la = get("layer_aware");
            let ce = get("cross_entropy");
            // The paper's claim: layer-aware beats cross-entropy on early-
            // exit accuracy (4.13-13.4 % in the paper) because CE gives the
            // hidden layers no metric supervision.
            assert!(
                la.acc_utility >= ce.acc_utility - 0.02,
                "{ds}: layer-aware {} vs cross-entropy {}",
                la.acc_utility,
                ce.acc_utility
            );
            // And saves time relative to full execution.
            assert!(la.time_utility_ms < la.time_full_ms);
        }
    }
}
