//! Table 7 — classification accuracy of the agile CNN (with and without
//! early termination) vs traditional classifiers (KNN, k-means, random
//! forest, linear SVM) trained on raw inputs, across all four datasets.
//!
//! The baselines need the *training* inputs, which the artifacts do not
//! ship (only weights + test set), so this driver regenerates the same
//! deterministic synthetic training split the compile path used — the
//! generator is seeded identically in `python/compile/datasets.py` and
//! here. A pytest cross-check (`test_datasets_match_rust`) pins the two
//! generators together via exported test tensors.

use crate::classifiers::{accuracy, forest::RandomForest, kmeans_raw::KmeansRaw, knn::Knn,
                         svm::LinearSvm, Baseline};
use crate::dnn::network::Network;
use crate::dnn::trace::{compute_traces, summarize};

use super::common::{pct, print_header, print_row};

pub struct ClassifierRow {
    pub dataset: String,
    pub knn: f64,
    pub kmeans: f64,
    pub forest: f64,
    pub svm: f64,
    pub cnn_full: f64,
    pub cnn_early: f64,
}

/// Fit all baselines on the network's *test* split via k-fold style
/// holdout: we train on the first 60 % of test samples and evaluate on the
/// rest. (The artifacts do not carry the training split; using a fixed
/// sub-split of held-out data keeps every classifier on identical footing,
/// which is what the Table 7 comparison needs.)
pub fn run(datasets: &[&str]) -> Vec<ClassifierRow> {
    datasets
        .iter()
        .map(|&ds| {
            let net = Network::load(&crate::artifacts_root().join(ds)).unwrap();
            let n = net.test.len();
            let slen = net.test.sample_len;
            let n_classes = net.meta.n_classes;
            let split = n * 3 / 5;
            let (tr_x, te_x) = net.test.x.split_at(split * slen);
            let (tr_y, te_y) = net.test.y.split_at(split);

            let knn = Knn::fit(5, tr_x, slen, tr_y, n_classes);
            let km = KmeansRaw::fit(tr_x, slen, tr_y, n_classes, 10);
            let rf = RandomForest::fit(tr_x, slen, tr_y, n_classes, 20, 8, 7);
            let svm = LinearSvm::fit(tr_x, slen, tr_y, n_classes, 10, 0.01, 7);

            let eval = |m: &dyn Baseline| accuracy(m, te_x, slen, te_y);

            // CNN accuracies on the same held-out 40 % (traces are per test
            // sample; slice the tail).
            let traces = compute_traces(&net, None);
            let tail = &traces[split..];
            let s = summarize(&net, tail);

            ClassifierRow {
                dataset: ds.into(),
                knn: eval(&knn),
                kmeans: eval(&km),
                forest: eval(&rf),
                svm: eval(&svm),
                cnn_full: s.acc_full,
                cnn_early: s.acc_utility,
            }
        })
        .collect()
}

pub fn print(rows: &[ClassifierRow]) {
    print_header(
        "Table 7: classifier accuracy comparison",
        &["dataset", "KNN", "k-means", "forest", "SVM", "CNN", "CNN(early)"],
    );
    for r in rows {
        print_row(&[
            r.dataset.clone(),
            pct(r.knn),
            pct(r.kmeans),
            pct(r.forest),
            pct(r.svm),
            pct(r.cnn_full),
            pct(r.cnn_early),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cnn_beats_traditional_classifiers() {
        if !crate::artifacts_root().join("mnist/meta.json").exists() {
            return;
        }
        // The paper's Table 7 story: the CNN (even with early termination)
        // is the most accurate model, by 1-15 %. On our *synthetic*
        // stand-in data the raw-pixel KNN is stronger than on natural
        // images (class templates are literally nearest-neighbour
        // matchable — a documented substitution artifact, EXPERIMENTS.md),
        // so the faithful checks are: the CNN clearly beats the parametric
        // baselines everywhere, stays within a whisker of the best
        // traditional model on every dataset, and early termination costs
        // almost nothing.
        let rows = run(&["mnist", "esc10", "cifar100", "vww"]);
        for r in &rows {
            let parametric_best = r.kmeans.max(r.forest).max(r.svm);
            assert!(
                r.cnn_full >= parametric_best - 0.02,
                "{}: cnn {} vs parametric best {}",
                r.dataset,
                r.cnn_full,
                parametric_best
            );
            let best_traditional = r.knn.max(parametric_best);
            assert!(
                r.cnn_full >= best_traditional - 0.15,
                "{}: cnn {} too far below best traditional {}",
                r.dataset,
                r.cnn_full,
                best_traditional
            );
            assert!(
                r.cnn_early >= r.cnn_full - 0.06,
                "{}: early termination lost too much",
                r.dataset
            );
        }
    }
}
