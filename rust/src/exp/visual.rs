//! Fig. 23 — multi-task visual sensing: traffic-sign recognition + shape
//! recognition on one solar-powered device with an OV2640 camera. Zygarde
//! vs SONIC-EDF (EDF order, full execution) vs SONIC-RR (non-preemptive
//! round-robin, full execution).
//!
//! The camera dominates the energy budget (the paper loses 37 % of events
//! before they enter any system), so the sign task carries a large capture
//! energy; the shape task reuses the captured frame.

use std::sync::Arc;

use crate::coordinator::sched::SchedulerKind;
use crate::dnn::network::Network;
use crate::dnn::trace::compute_traces;
use crate::sim::metrics::Metrics;
use crate::sim::workload::task_from_network;

use super::common::{engine_for, pct, print_header, print_row, system};

pub struct VisualCell {
    pub scheduler: SchedulerKind,
    pub metrics: Metrics,
}

/// Camera capture energy (mJ) charged to the sign task's release — an
/// OV2640 burst at ~120 mA/3.3 V for the 4 s capture window, scaled to the
/// repo's energy units so that a meaningful fraction of events is lost
/// (the paper reports 37 %).
pub const CAMERA_ENERGY_MJ: f64 = 60.0;

pub fn run(duration_ms: f64, seed: u64) -> Vec<VisualCell> {
    let sign = Network::load(&crate::artifacts_root().join("sign")).unwrap();
    let shape = Network::load(&crate::artifacts_root().join("shape")).unwrap();
    let sign_traces = Arc::new(compute_traces(&sign, None));
    let shape_traces = Arc::new(compute_traces(&shape, None));

    [SchedulerKind::Zygarde, SchedulerKind::Edf, SchedulerKind::RoundRobin]
        .into_iter()
        .map(|kind| {
            // Camera frames every 4 s; sign deadline = its full exec time
            // (~2 s), shape deadline roughly half (its net is ~2x smaller).
            let mut sign_task =
                task_from_network(0, &sign, 4000.0, sign.meta.cost.total_time_ms * 1.05,
                                  Some(sign_traces.clone()));
            sign_task.release_energy_mj = CAMERA_ENERGY_MJ;
            let mut shape_task =
                task_from_network(1, &shape, 4000.0, shape.meta.cost.total_time_ms * 1.15,
                                  Some(shape_traces.clone()));
            shape_task.release_energy_mj = 1.0; // reuses the frame

            let engine = engine_for(
                system(4), // solar, the weakest (η=0.38, 310 mW)
                vec![sign_task, shape_task],
                kind,
                kind.default_exit(),
                duration_ms,
                None,
                None,
                seed,
            );
            VisualCell { scheduler: kind, metrics: engine.run() }
        })
        .collect()
}

pub fn print(cells: &[VisualCell]) {
    print_header(
        "Fig. 23: multi-task visual sensing (sign + shape, solar)",
        &["scheduler", "entered%", "sched%", "sign%", "shape%", "sign-acc", "shape-acc"],
    );
    for c in cells {
        let m = &c.metrics;
        let entered = m.released as f64 / (m.released + m.capture_missed).max(1) as f64;
        let name = match c.scheduler {
            SchedulerKind::Zygarde => "zygarde",
            SchedulerKind::Edf => "sonic-edf",
            SchedulerKind::RoundRobin => "sonic-rr",
            k => k.name(),
        };
        let task_rate = |t: usize| {
            m.per_task_scheduled[t] as f64 / m.per_task_released[t].max(1) as f64
        };
        let task_acc = |t: usize| {
            m.per_task_correct[t] as f64 / m.per_task_scheduled[t].max(1) as f64
        };
        print_row(&[
            name.into(),
            pct(entered),
            pct(m.scheduled_rate()),
            pct(task_rate(0)),
            pct(task_rate(1)),
            pct(task_acc(0)),
            pct(task_acc(1)),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zygarde_schedules_more_and_fairer() {
        if !crate::artifacts_root().join("sign/meta.json").exists() {
            return;
        }
        let cells = run(400_000.0, 21);
        let get = |k: SchedulerKind| &cells.iter().find(|c| c.scheduler == k).unwrap().metrics;
        let zyg = get(SchedulerKind::Zygarde);
        let edf = get(SchedulerKind::Edf);
        let rr = get(SchedulerKind::RoundRobin);
        // Paper: Zygarde 93 % >> SONIC-EDF 55 % >> SONIC-RR 11 %.
        assert!(
            zyg.scheduled_rate() > edf.scheduled_rate(),
            "zygarde {} <= sonic-edf {}",
            zyg.scheduled_rate(),
            edf.scheduled_rate()
        );
        assert!(
            edf.scheduled_rate() > rr.scheduled_rate(),
            "sonic-edf {} <= sonic-rr {}",
            edf.scheduled_rate(),
            rr.scheduled_rate()
        );
        // Fairness: Zygarde schedules BOTH tasks substantially.
        let zr0 = zyg.per_task_scheduled[0] as f64 / zyg.per_task_released[0].max(1) as f64;
        let zr1 = zyg.per_task_scheduled[1] as f64 / zyg.per_task_released[1].max(1) as f64;
        assert!(zr0 > 0.2 && zr1 > 0.2, "zygarde unfair: sign {zr0} shape {zr1}");
        // Camera energy keeps some events out of every system.
        assert!(zyg.capture_missed > 0, "camera cost should drop captures");
    }
}
