//! Fig. 22 — six real-life acoustic event-detection applications, each a
//! 10-minute deployment sampling audio every 2 s with a 3 s relative
//! deadline (the ESC-10 agile DNN): car detector, dog monitor, and people
//! detector on solar; baby, laundry, and printer monitors on RF. Each
//! app's harvester reflects its Table 6 intermittence cause (passing
//! clouds/pedestrians for solar, distance/interference for RF).

use std::sync::Arc;

use crate::coordinator::sched::{ExitPolicy, SchedulerKind};
use crate::dnn::network::Network;
use crate::dnn::trace::compute_traces;
use crate::energy::capacitor::Capacitor;
use crate::energy::harvester::{Harvester, HarvesterKind};
use crate::energy::manager::EnergyManager;
use crate::sim::metrics::Metrics;
use crate::sim::workload::task_from_network;

use super::common::{pct, print_header, print_row};

#[derive(Clone, Debug)]
pub struct AppSpec {
    pub name: &'static str,
    pub kind: HarvesterKind,
    pub on_power_mw: f64,
    /// Burst persistence / duty reflecting the app's intermittence cause.
    pub q: f64,
    pub duty: f64,
}

/// Table 6's six applications, ordered as in Fig. 22(a)-(f).
pub const APPS: [AppSpec; 6] = [
    // Car detector: roadside sun, effectively continuous harvest ("always
    // harvests sufficient energy from the sun", §9.1).
    AppSpec { name: "car-detector", kind: HarvesterKind::Solar, on_power_mw: 700.0, q: 0.999, duty: 0.99 },
    // Dog monitor: people block the panel.
    AppSpec { name: "dog-monitor", kind: HarvesterKind::Solar, on_power_mw: 550.0, q: 0.97, duty: 0.7 },
    // People detector: window light.
    AppSpec { name: "people-detector", kind: HarvesterKind::Solar, on_power_mw: 500.0, q: 0.98, duty: 0.8 },
    // Baby monitor: RF at ~1 m.
    AppSpec { name: "baby-monitor", kind: HarvesterKind::Rf, on_power_mw: 90.0, q: 0.97, duty: 0.75 },
    // Laundry monitor: RF mid-distance.
    AppSpec { name: "laundry-monitor", kind: HarvesterKind::Rf, on_power_mw: 75.0, q: 0.95, duty: 0.65 },
    // Printer monitor: farthest / most interference — highest intermittence.
    AppSpec { name: "printer-monitor", kind: HarvesterKind::Rf, on_power_mw: 60.0, q: 0.90, duty: 0.5 },
];

pub struct AppResult {
    pub app: &'static str,
    pub metrics: Metrics,
    /// Downsampled (t_ms, volts) trace — Fig. 22's voltage plot.
    pub voltage: Vec<(f64, f64)>,
}

pub fn run(duration_ms: f64, seed: u64) -> Vec<AppResult> {
    let mut net = Network::load(&crate::artifacts_root().join("esc10")).unwrap();
    // Deployment-specific utility thresholds: the sampling period (2 s) is
    // tighter than the offline default thresholds' mean mandatory time, so
    // the developer dials the per-layer thresholds down (the §4.3 knob —
    // "a desired minimum inference accuracy as configured by the
    // programmer") to favour earlier exits.
    for clf in &mut net.classifiers {
        clf.threshold *= 0.5;
    }
    let traces = Arc::new(compute_traces(&net, None));
    APPS.iter()
        .map(|app| {
            // Audio every 2 s; D = 3 s = whole-model execution time (§9.1).
            let mut task = task_from_network(0, &net, 2000.0, 3000.0, Some(traces.clone()));
            // The Fig. 22 deployment uses a smaller net than Table 3's
            // ESC-10 (one conv + two FC): execution ~1.7 s after the first
            // unit, ~3 s for the whole model, against a 3 s deadline.
            // Rescale the unit profile to that front-loaded shape
            // (energies follow the 110 mW draw).
            let profile = [0.553, 0.2, 0.14, 0.107]; // unit0 ≈ 1.55 s
            let total_ms = 2800.0;
            for (u, &p) in profile.iter().enumerate() {
                task.unit_time_ms[u] = total_ms * p;
                task.unit_energy_mj[u] = total_ms * p * 0.110; // 110 mW
                task.unit_fragments[u] = ((total_ms * p) / 7.5).ceil() as usize;
            }
            let e_man = (0..task.n_units())
                .map(|u| task.fragment_energy_mj(u))
                .fold(0.0f64, f64::max);
            let mut cap = Capacitor::standard();
            cap.precharge();
            let h = if app.duty >= 0.99 {
                Harvester::persistent(app.on_power_mw)
            } else {
                Harvester::markov(app.kind, app.on_power_mw, app.q, app.duty, 1000.0, seed)
            };
            // η per app estimated from its own trace statistics: use q as
            // the deployment's offline estimate (monotone proxy).
            let eta = 2.0 * app.q - 1.0;
            let em = EnergyManager::new(cap, h, eta.clamp(0.0, 1.0), e_man);

            let params = crate::coordinator::priority::PriorityParams::new(3000.0, 30.0);
            let mut engine = crate::sim::engine::Engine::new(
                crate::sim::engine::SimConfig {
                    duration_ms,
                    seed,
                    ..Default::default()
                },
                vec![task],
                crate::coordinator::sched::Scheduler::new(SchedulerKind::Zygarde, params),
                ExitPolicy::Utility,
                em,
                Box::new(crate::clock::Rtc),
            );
            let log: std::rc::Rc<std::cell::RefCell<Vec<(f64, f64)>>> =
                std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
            {
                let log = log.clone();
                let mut last = -1e18f64;
                engine.probe = Some(Box::new(move |t, em, _| {
                    if t - last >= 500.0 {
                        last = t;
                        log.borrow_mut().push((t, em.capacitor.voltage()));
                    }
                }));
            }
            let metrics = engine.run();
            let voltage = log.borrow().clone();
            AppResult { app: app.name, metrics, voltage }
        })
        .collect()
}

pub fn print(results: &[AppResult]) {
    print_header(
        "Fig. 22: real-life acoustic event detection (10-minute runs)",
        &["app", "events", "missed-capture", "deadline-miss", "sched%", "accuracy"],
    );
    for r in results {
        print_row(&[
            r.app.into(),
            (r.metrics.released + r.metrics.capture_missed).to_string(),
            r.metrics.capture_missed.to_string(),
            r.metrics.deadline_missed.to_string(),
            pct(r.metrics.scheduled_rate()),
            pct(r.metrics.accuracy()),
        ]);
    }
    // Compact voltage sparkline per app (the Fig. 22 waveform).
    for r in results {
        let marks: String = r
            .voltage
            .iter()
            .step_by((r.voltage.len() / 60).max(1))
            .map(|&(_, v)| {
                let lvl = ((v / 3.3) * 7.0).clamp(0.0, 7.0) as usize;
                ['.', ':', '-', '=', '+', '*', '#', '@'][lvl]
            })
            .collect();
        println!("{:<18} V(t): {marks}", r.app);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intermittence_ordering_matches_apps() {
        if !crate::artifacts_root().join("esc10/meta.json").exists() {
            return;
        }
        let results = run(600_000.0, 9); // the paper's 10 minutes
        let get = |name: &str| results.iter().find(|r| r.app == name).unwrap();
        // Car detector (always sunny): near-full scheduling. The workload
        // is inherently tight (T = 2 s < mean mandatory time for hard
        // samples), so allow the few utility-test-driven misses the paper
        // itself reports.
        let car = get("car-detector");
        assert!(car.metrics.event_scheduled_rate() > 0.8, "car: {:?}", car.metrics.event_scheduled_rate());
        // Printer monitor (highest intermittence): visibly worse than car.
        let printer = get("printer-monitor");
        assert!(
            printer.metrics.event_scheduled_rate() < car.metrics.event_scheduled_rate(),
            "printer {} vs car {}",
            printer.metrics.event_scheduled_rate(),
            car.metrics.event_scheduled_rate()
        );
        let trouble = printer.metrics.deadline_missed
            + printer.metrics.capture_missed
            + printer.metrics.refragments;
        assert!(trouble > 0, "printer monitor should struggle");
        // Voltage traces recorded for every app.
        for r in &results {
            assert!(r.voltage.len() > 100);
        }
    }
}
