//! Shared experiment plumbing: the Table 4 evaluation systems, calibrated
//! harvester construction, engine assembly, and table formatting.

use crate::clock::{Clock, Rtc};
use crate::coordinator::priority::PriorityParams;
use crate::coordinator::sched::{ExitPolicy, Scheduler, SchedulerKind};
use crate::coordinator::task::TaskSpec;
use crate::energy::capacitor::Capacitor;
use crate::energy::manager::EnergyManager;
use crate::sim::engine::{Engine, SimConfig};
use crate::sim::metrics::Metrics;

// The Table 4 system descriptions (and their memoized harvester
// calibration) moved into `energy::harvester` so the sweep engine can use
// them without depending on the experiment drivers; re-exported here to
// keep the historical import paths working.
pub use crate::energy::harvester::{harvester_for, system, HarvesterKind, System, DUTY, SYSTEMS};

/// Assemble an EnergyManager for a system with the given E_man and an
/// optionally non-standard capacitor. The capacitor starts full via the
/// explicit warm-up ([`Capacitor::precharge`] — the deployment has been
/// harvesting before t=0, without touching the in-simulation ledgers).
pub fn energy_for(sys: System, e_man_mj: f64, cap: Option<Capacitor>, seed: u64) -> EnergyManager {
    let mut cap = cap.unwrap_or_else(Capacitor::standard);
    cap.precharge();
    EnergyManager::new(cap, harvester_for(sys, seed), sys.eta, e_man_mj)
}

/// Build a ready-to-run engine over `tasks` for one system × scheduler.
#[allow(clippy::too_many_arguments)]
pub fn engine_for(
    sys: System,
    tasks: Vec<TaskSpec>,
    kind: SchedulerKind,
    exit: ExitPolicy,
    duration_ms: f64,
    cap: Option<Capacitor>,
    clock: Option<Box<dyn Clock>>,
    seed: u64,
) -> Engine {
    let e_man = tasks
        .iter()
        .flat_map(|t| (0..t.n_units()).map(|u| t.fragment_energy_mj(u)))
        .fold(0.0f64, f64::max);
    let max_deadline = tasks.iter().map(|t| t.deadline_ms).fold(0.0f64, f64::max);
    let max_utility = tasks
        .iter()
        .flat_map(|t| t.traces.iter())
        .flat_map(|tr| tr.units.iter().map(|u| u.gap as f64))
        .fold(1.0f64, f64::max);
    let energy = energy_for(sys, e_man, cap, seed);
    let params = PriorityParams::new(max_deadline, max_utility);
    Engine::new(
        SimConfig { duration_ms, seed, ..Default::default() },
        tasks,
        Scheduler::new(kind, params),
        exit,
        energy,
        clock.unwrap_or_else(|| Box::new(Rtc)),
    )
}

/// Run one (system, scheduler) cell and return metrics.
pub fn run_cell(
    sys: System,
    tasks: Vec<TaskSpec>,
    kind: SchedulerKind,
    duration_ms: f64,
    seed: u64,
) -> Metrics {
    engine_for(sys, tasks, kind, kind.default_exit(), duration_ms, None, None, seed).run()
}

// ---- table formatting ----------------------------------------------------

pub fn print_header(title: &str, cols: &[&str]) {
    println!("\n== {title} ==");
    let mut line = String::new();
    for c in cols {
        line.push_str(&format!("{c:>14}"));
    }
    println!("{line}");
    println!("{}", "-".repeat(line.len()));
}

pub fn print_row(cells: &[String]) {
    let mut line = String::new();
    for c in cells {
        line.push_str(&format!("{c:>14}"));
    }
    println!("{line}");
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn systems_table_matches_paper() {
        assert_eq!(SYSTEMS.len(), 7);
        assert_eq!(system(1).eta, 1.0);
        assert_eq!(system(5).kind, HarvesterKind::Rf);
        assert!((system(4).avg_power_mw - 310.0).abs() < 1e-12);
    }

    #[test]
    fn harvester_calibration_cached() {
        let a = harvester_for(system(6), 1);
        let b = harvester_for(system(6), 2);
        assert!((a.p_stay_on - b.p_stay_on).abs() < 1e-12);
        assert!(a.p_stay_on > 0.5 && a.p_stay_on < 1.0);
    }
}
