//! Named sweep matrices for the `zygarde sweep` / `zygarde merge` CLI.
//!
//! Every figure sweep (and two synthetic grids that need no `artifacts/`)
//! is reachable by name, so any of them can be split across processes or
//! hosts with `--shard I/N` and reassembled with `zygarde merge`. The
//! matrix construction is deterministic in the options, which is what
//! makes cross-host sharding safe: every host that runs
//! `zygarde sweep --matrix M --seed S --jobs J --shard I/N` builds the
//! same expansion (and the same [`MatrixFingerprint`]), and the merge
//! rejects shards whose options drifted.
//!
//! [`MatrixFingerprint`]: crate::sim::sweep::MatrixFingerprint

use std::collections::BTreeMap;

use crate::coordinator::sched::SchedulerKind;
use crate::energy::harvester::HarvesterKind;
use crate::nvm::NvmSpec;
use crate::sim::sweep::{FaultPlan, HarvesterSpec, ScenarioMatrix, TaskMix};
use crate::util::json::Value;

/// Tunables shared by the named matrices; each matrix uses the subset it
/// needs (e.g. `dataset`/`systems` only matter to `schedule`).
#[derive(Clone, Debug, PartialEq)]
pub struct SweepOpts {
    pub seed: u64,
    pub jobs: u64,
    pub reps: u64,
    /// Per-cell simulated horizon override (ms) for the synthetic grids.
    pub duration_ms: Option<f64>,
    pub dataset: String,
    pub systems: Vec<usize>,
    /// NVM commit-policy axis (empty = each matrix's default).
    pub nvms: Vec<NvmSpec>,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts {
            seed: 7,
            jobs: 200,
            reps: 2,
            duration_ms: None,
            dataset: "mnist".to_string(),
            systems: (1..=7).collect(),
            nvms: Vec::new(),
        }
    }
}

impl SweepOpts {
    /// Wire form for the serve protocol: the dispatcher ships these to
    /// `zygarde work` processes so every worker rebuilds the *same*
    /// matrix from the registry (the fingerprint handshake then proves
    /// it). Seeds and counts are serialized as decimal strings, matching
    /// the report convention (u64 exceeds f64's exact-integer range).
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("seed".to_string(), Value::Str(self.seed.to_string()));
        m.insert("jobs".to_string(), Value::Str(self.jobs.to_string()));
        m.insert("reps".to_string(), Value::Str(self.reps.to_string()));
        m.insert(
            "duration_ms".to_string(),
            match self.duration_ms {
                Some(d) => Value::Num(d),
                None => Value::Null,
            },
        );
        m.insert("dataset".to_string(), Value::Str(self.dataset.clone()));
        m.insert(
            "systems".to_string(),
            Value::Arr(self.systems.iter().map(|&s| Value::Num(s as f64)).collect()),
        );
        m.insert(
            "nvms".to_string(),
            Value::Arr(self.nvms.iter().map(|n| Value::Str(n.label())).collect()),
        );
        Value::Obj(m)
    }

    /// Inverse of [`SweepOpts::to_json`] — the worker-side half of the
    /// serve handshake.
    pub fn from_json(v: &Value) -> Result<SweepOpts, String> {
        let u64_field = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(Value::as_str)
                .ok_or_else(|| format!("opts: missing string `{key}`"))?
                .parse::<u64>()
                .map_err(|e| format!("opts: bad {key}: {e}"))
        };
        let duration_ms = match v.get("duration_ms") {
            None | Some(Value::Null) => None,
            Some(d) => Some(
                d.as_f64().ok_or_else(|| "opts: bad duration_ms".to_string())?,
            ),
        };
        let systems = v
            .get("systems")
            .and_then(Value::as_arr)
            .ok_or_else(|| "opts: missing `systems`".to_string())?
            .iter()
            .map(|s| {
                s.as_f64()
                    .map(|x| x as usize)
                    .ok_or_else(|| "opts: bad system id".to_string())
            })
            .collect::<Result<Vec<_>, _>>()?;
        let nvms = v
            .get("nvms")
            .and_then(Value::as_arr)
            .ok_or_else(|| "opts: missing `nvms`".to_string())?
            .iter()
            .map(|s| {
                NvmSpec::parse(
                    s.as_str().ok_or_else(|| "opts: bad nvm entry".to_string())?,
                )
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(SweepOpts {
            seed: u64_field("seed")?,
            jobs: u64_field("jobs")?,
            reps: u64_field("reps")?,
            duration_ms,
            dataset: v
                .get("dataset")
                .and_then(Value::as_str)
                .ok_or_else(|| "opts: missing `dataset`".to_string())?
                .to_string(),
            systems,
            nvms,
        })
    }
}

/// `(name, description)` of every named matrix, for `zygarde help`.
pub const MATRICES: &[(&str, &str)] = &[
    ("synthetic", "stress grid: mixes x harvesters x caps x scheds x faults (no artifacts)"),
    ("bench", "the bench_sweep throughput grid (fixed seed; no artifacts)"),
    ("nvm", "NVM commit-policy comparison (no artifacts)"),
    ("schedule", "Figs. 17-20 scheduler comparison (needs artifacts/<dataset>)"),
    ("capacitor", "Fig. 21 capacitor-size sweep (needs artifacts/cifar100)"),
    ("chrt", "Table 5 RTC vs CHRT clocks (needs artifacts/vww)"),
];

/// Every [`SweepOpts`] tunable the CLI exposes, by flag name.
pub const TUNABLE_FLAGS: &[&str] =
    &["seed", "jobs", "reps", "duration-ms", "dataset", "systems", "nvm"];

/// The subset of [`TUNABLE_FLAGS`] a named matrix actually consumes.
/// `zygarde sweep` warns when an explicitly passed flag is not in this
/// list — otherwise `--matrix bench --seed 42` (bench pins its seed) or
/// `--matrix nvm --nvm fram-jit` (nvm sweeps its own policy axis) would
/// silently run a different configuration than the user asked for, and
/// the fingerprint could never catch it because every host would ignore
/// the flag identically.
pub fn consumed_flags(name: &str) -> &'static [&'static str] {
    match name {
        "synthetic" => &["seed", "reps", "duration-ms"],
        "bench" => &["reps", "duration-ms"],
        "nvm" => &["seed", "jobs"],
        "schedule" => &["seed", "jobs", "dataset", "systems", "nvm"],
        "capacitor" => &["seed", "jobs", "nvm"],
        "chrt" => &["seed", "jobs"],
        _ => &[],
    }
}

/// Build a named matrix. Unknown names list the known ones.
pub fn build_matrix(name: &str, opts: &SweepOpts) -> Result<ScenarioMatrix, String> {
    match name {
        "synthetic" => {
            Ok(synthetic_matrix(opts.seed, opts.reps, opts.duration_ms.unwrap_or(6_000.0)))
        }
        "bench" => Ok(bench_matrix(opts.reps, opts.duration_ms.unwrap_or(20_000.0))),
        "nvm" => Ok(super::nvm_cmp::matrix(opts.jobs, opts.seed)),
        "schedule" => Ok(super::schedule::matrix(
            &opts.dataset,
            &opts.systems,
            Some(opts.jobs),
            opts.seed,
            &opts.nvms,
        )),
        "capacitor" => Ok(super::capacitor_sweep::matrix(opts.jobs, opts.seed, &opts.nvms)),
        "chrt" => Ok(super::chrt_cmp::matrix(opts.jobs, opts.seed)),
        other => Err(format!(
            "unknown matrix `{other}` (known: {})",
            MATRICES.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
        )),
    }
}

/// A no-artifacts grid covering every sweep dimension — the CI shard
/// jobs' workload (2 mixes × 2 harvesters × 2 capacitors × 2 schedulers ×
/// 2 fault plans × reps).
pub fn synthetic_matrix(seed: u64, reps: u64, duration_ms: f64) -> ScenarioMatrix {
    ScenarioMatrix::new("synthetic", seed)
        .mixes(vec![
            TaskMix::synthetic("uni", 1, 3, seed ^ 0xA),
            TaskMix::synthetic("duo", 2, 2, seed ^ 0xB),
        ])
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 600.0 },
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 120.0,
                q: 0.9,
                duty: 0.6,
                eta: 0.51,
            },
        ])
        .capacitors_mf(vec![5.0, 50.0])
        .schedulers(vec![SchedulerKind::Zygarde, SchedulerKind::EdfMandatory])
        .faults(vec![
            FaultPlan::none(),
            FaultPlan::none().with_brownouts(1_500.0, 300.0, 100.0),
        ])
        .reps(reps.max(1))
        .duration_ms(duration_ms)
}

/// The `benches/bench_sweep.rs` grid, shared so the sharded-throughput
/// bench rows can spawn `zygarde sweep --matrix bench --shard I/N`
/// processes that run *exactly* the matrix the in-process rows ran.
/// 2 mixes × 2 harvesters × 3 schedulers × 2 faults × reps scenarios
/// (96 at the default 4 reps); the seed is fixed so the throughput
/// trajectory is comparable across PRs.
pub fn bench_matrix(reps: u64, duration_ms: f64) -> ScenarioMatrix {
    ScenarioMatrix::new("bench-sweep", 0xB5EE9)
        .mixes(vec![
            TaskMix::synthetic("uni", 1, 3, 11),
            TaskMix::synthetic("duo", 2, 3, 12),
        ])
        .harvesters(vec![
            HarvesterSpec::Persistent { power_mw: 600.0 },
            HarvesterSpec::Markov {
                kind: HarvesterKind::Rf,
                on_power_mw: 120.0,
                q: 0.9,
                duty: 0.6,
                eta: 0.51,
            },
        ])
        .schedulers(vec![
            SchedulerKind::Zygarde,
            SchedulerKind::EdfMandatory,
            SchedulerKind::Edf,
        ])
        .faults(vec![
            FaultPlan::none(),
            FaultPlan::none().with_brownouts(2_000.0, 400.0, 250.0),
        ])
        .reps(reps.max(1))
        .duration_ms(duration_ms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::sweep::fingerprint;

    #[test]
    fn registry_builds_the_no_artifact_matrices() {
        let opts = SweepOpts { seed: 3, reps: 1, ..Default::default() };
        for name in ["synthetic", "bench", "nvm"] {
            let m = build_matrix(name, &opts).unwrap();
            assert!(!m.is_empty(), "{name} expanded to nothing");
        }
        let err = build_matrix("bogus", &opts).unwrap_err();
        assert!(err.contains("synthetic"), "{err}");
    }

    #[test]
    fn same_opts_same_fingerprint_across_builds() {
        let opts = SweepOpts { seed: 9, reps: 2, ..Default::default() };
        let a = fingerprint(&build_matrix("synthetic", &opts).unwrap());
        let b = fingerprint(&build_matrix("synthetic", &opts).unwrap());
        assert_eq!(a, b, "matrix construction must be deterministic in the options");
        let other = SweepOpts { seed: 10, ..opts };
        let c = fingerprint(&build_matrix("synthetic", &other).unwrap());
        assert_ne!(a, c);
    }

    #[test]
    fn consumed_flags_cover_every_matrix_and_stay_tunable() {
        for &(name, _) in MATRICES {
            let used = consumed_flags(name);
            assert!(!used.is_empty(), "{name} consumes no flags?");
            for f in used {
                assert!(TUNABLE_FLAGS.contains(f), "{name}: unknown flag {f}");
            }
        }
        assert!(consumed_flags("bogus").is_empty());
        // The cases the warning exists for: bench pins its seed, nvm owns
        // its policy axis.
        assert!(!consumed_flags("bench").contains(&"seed"));
        assert!(!consumed_flags("nvm").contains(&"nvm"));
    }

    #[test]
    fn sweep_opts_round_trip_through_the_wire_form() {
        let opts = SweepOpts {
            seed: 0xDEAD_BEEF_CAFE,
            jobs: 321,
            reps: 5,
            duration_ms: Some(12_500.0),
            dataset: "esc10".to_string(),
            systems: vec![1, 4, 7],
            nvms: vec![NvmSpec::ideal(), NvmSpec::fram_jit()],
        };
        let back = SweepOpts::from_json(&opts.to_json()).unwrap();
        assert_eq!(back, opts);
        // None duration survives too (the CLI default).
        let opts = SweepOpts { duration_ms: None, ..opts };
        assert_eq!(SweepOpts::from_json(&opts.to_json()).unwrap(), opts);
        // And the round-tripped options rebuild a fingerprint-identical
        // matrix — the property the serve handshake rests on.
        let a = fingerprint(&build_matrix("synthetic", &opts).unwrap());
        let b = fingerprint(
            &build_matrix("synthetic", &SweepOpts::from_json(&opts.to_json()).unwrap()).unwrap(),
        );
        assert_eq!(a, b);
    }

    #[test]
    fn bench_matrix_matches_the_documented_shape() {
        let m = bench_matrix(4, 20_000.0);
        assert_eq!(m.len(), 2 * 2 * 3 * 2 * 4, "96 scenarios at default reps");
        assert_eq!(m.seed, 0xB5EE9);
    }
}
