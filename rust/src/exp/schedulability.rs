//! §5.3 — schedulability analysis: the necessary condition
//! T_E ≥ (η/(1−η)) / (1 − Σ C_i/T_i), checked analytically and against a
//! Monte-Carlo sweep of the simulator (as η rises, tolerable outage
//! frequency falls; past the bound misses appear).

use std::sync::Arc;

use crate::coordinator::analysis::{analyze, Schedulability};
use crate::dnn::network::Network;
use crate::dnn::trace::compute_traces;
use crate::sim::workload::task_from_network;

use super::common::{print_header, print_row};

pub struct SchedulabilityRow {
    pub dataset: String,
    pub eta: f64,
    pub analysis: Schedulability,
}

pub fn run(datasets: &[&str], etas: &[f64]) -> Vec<SchedulabilityRow> {
    let mut out = Vec::new();
    for &ds in datasets {
        let net = Network::load(&crate::artifacts_root().join(ds)).unwrap();
        let traces = Arc::new(compute_traces(&net, None));
        let p = super::schedule::params_for(ds);
        let task = task_from_network(0, &net, p.period_ms, p.deadline_ms, Some(traces));
        for &eta in etas {
            out.push(SchedulabilityRow {
                dataset: ds.into(),
                eta,
                analysis: analyze(&[&task], eta),
            });
        }
    }
    out
}

pub fn print(rows: &[SchedulabilityRow]) {
    print_header(
        "Sec. 5.3: schedulability condition T_E >= (eta/(1-eta))/(1-U)",
        &["dataset", "eta", "U(mandatory)", "E[C_e]", "min T_E", "feasible"],
    );
    for r in rows {
        print_row(&[
            r.dataset.clone(),
            format!("{:.2}", r.eta),
            format!("{:.3}", r.analysis.utilization),
            format!("{:.2}", r.analysis.expected_outage),
            if r.analysis.min_energy_period.is_finite() {
                format!("{:.2}", r.analysis.min_energy_period)
            } else {
                "inf".into()
            },
            r.analysis.feasible.to_string(),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn condition_tightens_with_eta() {
        if !crate::artifacts_root().join("esc10/meta.json").exists() {
            return;
        }
        let rows = run(&["esc10"], &[0.38, 0.51, 0.71]);
        assert!(rows.windows(2).all(|w| {
            w[1].analysis.min_energy_period >= w[0].analysis.min_energy_period
        }));
        // ESC-10 runs far below U=1: feasible at all etas.
        assert!(rows.iter().all(|r| r.analysis.feasible));
    }

    #[test]
    fn mnist_overload_is_infeasible_without_early_exit() {
        if !crate::artifacts_root().join("mnist/meta.json").exists() {
            return;
        }
        // With the *mandatory-only* utilization (early exit), MNIST at
        // T = 3 s may become feasible; with full execution it is not:
        // C = 3.8 s > T = 3 s. analyze() uses the mandatory fraction, so
        // verify the raw utilization exceeds 1 while the imprecise one is
        // smaller.
        let net = Network::load(&crate::artifacts_root().join("mnist")).unwrap();
        let traces = Arc::new(compute_traces(&net, None));
        let task = task_from_network(0, &net, 3000.0, 6000.0, Some(traces));
        let full_u = task.wcet_ms() / task.period_ms;
        assert!(full_u > 1.0, "expected overload, U={full_u}");
        let s = analyze(&[&task], 0.5);
        assert!(
            s.utilization < full_u,
            "mandatory-only utilization should shrink: {} vs {}",
            s.utilization,
            full_u
        );
    }
}
