//! Fig. 14 — per-component execution-time and energy overhead of Zygarde
//! on the ESC-10 network: job generator, each DNN layer (unit), the
//! k-means classifier + utility test, the scheduler, and the energy
//! manager. Values come from the compile-time cost model (the
//! EnergyTrace++ substitute) and the engine's measured per-invocation
//! counts.

use crate::dnn::network::Network;

use super::common::{print_header, print_row};

pub struct ComponentCost {
    pub name: String,
    pub time_ms: f64,
    pub energy_mj: f64,
}

pub fn run(net: &Network) -> Vec<ComponentCost> {
    let m = &net.meta;
    let mut rows = vec![ComponentCost {
        name: "job generator".into(),
        time_ms: m.cost.job_generator_ms,
        energy_mj: m.cost.job_generator_energy_mj,
    }];
    for (i, l) in m.layers.iter().enumerate() {
        // Split the unit cost back into layer-compute vs classifier parts
        // using the op counts (MACs are 4x adds, paper refs [4, 13]).
        let mac_cost = l.macs as f64 * 4.0;
        let add_cost = l.adds as f64;
        let clf_frac = add_cost / (mac_cost + add_cost);
        rows.push(ComponentCost {
            name: format!(
                "unit {i} ({}) compute",
                if l.kind == crate::dnn::meta::LayerKind::Conv { "conv" } else { "fc" }
            ),
            time_ms: l.time_ms * (1.0 - clf_frac),
            energy_mj: l.energy_mj * (1.0 - clf_frac),
        });
        rows.push(ComponentCost {
            name: format!("unit {i} k-means + utility"),
            time_ms: l.time_ms * clf_frac,
            energy_mj: l.energy_mj * clf_frac,
        });
    }
    rows.push(ComponentCost {
        name: "scheduler (per invocation)".into(),
        time_ms: m.cost.scheduler_overhead_ms,
        energy_mj: m.cost.scheduler_overhead_mj,
    });
    rows.push(ComponentCost {
        name: "energy manager".into(),
        time_ms: m.cost.scheduler_overhead_ms * 0.1,
        energy_mj: m.cost.scheduler_overhead_mj * 0.1,
    });
    rows
}

pub fn print(rows: &[ComponentCost]) {
    print_header("Fig. 14: component overhead (ESC-10 net)", &["component", "time", "energy"]);
    for r in rows {
        print_row(&[
            format!("{:<28}", r.name),
            format!("{:.2} ms", r.time_ms),
            format!("{:.3} mJ", r.energy_mj),
        ]);
    }
}

/// The paper's headline ratios for this figure, used by tests. NOTE: the
/// paper's ESC-10 conv-1 is 2.6–3.6x its other conv layers because its
/// audio input has much larger spatial dimensions than our 16x16
/// channel-scaled nets; at our scale channel growth outweighs spatial
/// shrink, so the faithful invariants are (a) conv layers dominate FC
/// layers and (b) the k-means classifier is far cheaper than the DNN
/// (paper: 14x time / 13x energy). Recorded in EXPERIMENTS.md.
pub struct OverheadShape {
    pub conv_over_fc: f64,
    pub dnn_over_classifier: f64,
}

pub fn shape(net: &Network) -> OverheadShape {
    let l = &net.meta.layers;
    let mean = |kind: crate::dnn::meta::LayerKind| {
        let xs: Vec<f64> =
            l.iter().filter(|x| x.kind == kind).map(|x| x.time_ms).collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    let conv_over_fc = mean(crate::dnn::meta::LayerKind::Conv)
        / mean(crate::dnn::meta::LayerKind::Fc).max(1e-9);
    let dnn_ms: f64 = l.iter().map(|x| x.time_ms).sum();
    // classifier cost across all units
    let clf_ms: f64 = l
        .iter()
        .map(|x| {
            let mac = x.macs as f64 * 4.0;
            let add = x.adds as f64;
            x.time_ms * add / (mac + add)
        })
        .sum();
    OverheadShape { conv_over_fc, dnn_over_classifier: dnn_ms / clf_ms }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn esc10_shape_matches_paper() {
        let dir = crate::artifacts_root().join("esc10");
        if !dir.join("meta.json").exists() {
            return;
        }
        let net = Network::load(&dir).unwrap();
        let s = shape(&net);
        // Conv layers dominate FC layers (the paper's per-layer profile)…
        assert!(s.conv_over_fc > 2.0, "conv/fc = {}", s.conv_over_fc);
        // …and classification is >= 10x cheaper than the full DNN (paper: 14x).
        assert!(s.dnn_over_classifier > 10.0, "ratio = {}", s.dnn_over_classifier);
        let rows = run(&net);
        assert!(rows.len() >= 2 + 2 * net.meta.n_layers);
        for r in &rows {
            assert!(r.time_ms >= 0.0 && r.energy_mj >= 0.0);
        }
    }
}
