//! Table 5 — effect of the CHRT batteryless remanence clock vs a
//! battery-backed RTC on Systems 2–4 (solar): reboots, power-on time, and
//! tasks scheduled under each clock. The paper's finding: the loss of
//! schedulable tasks from clock error stays below 0.1 %.

use std::sync::Arc;

use crate::clock::{ChrtTier, ClockSpec};
use crate::dnn::network::Network;
use crate::dnn::trace::compute_traces;
use crate::sim::sweep::{self, FaultPlan, HarvesterSpec, ScenarioMatrix, SeedPolicy, TaskMix};
use crate::sim::workload::task_from_network;

use super::common::{print_header, print_row};

pub struct ChrtRow {
    pub system_id: usize,
    pub reboots: u64,
    pub on_time_pct: f64,
    pub scheduled_rtc: u64,
    pub scheduled_chrt: u64,
}

const SYSTEM_IDS: [usize; 3] = [2, 3, 4];

/// One matrix: (Systems 2–4) × (RTC, CHRT tier-3) on the sweep engine.
/// Paired environment seeds mean both clock variants of a system replay
/// the *same* harvest and release streams — the only difference between
/// the paired cells is the clock error, exactly Table 5's contrast. The
/// matrix is the shard-aware entry point: run it locally with
/// `sweep::run_matrix` or split it across hosts with
/// `sweep::shard::run_shard` / `zygarde sweep --matrix chrt --shard I/N`.
pub fn matrix(n_jobs: u64, seed: u64) -> ScenarioMatrix {
    let net = Network::load(&crate::artifacts_root().join("vww")).unwrap();
    let traces = Arc::new(compute_traces(&net, None));
    // Table 5's deployments schedule ~99.9 % of tasks (29 989 / ~30 000),
    // i.e. the workload is comfortably feasible and the only loss channel
    // is clock error. T = 6 s (U ≈ 0.42) reproduces that regime; the
    // overloaded VWW configuration is exercised by Figs. 17–20 instead.
    let task = task_from_network(0, &net, 6000.0, 12_000.0, Some(traces));
    let duration_ms = n_jobs as f64 * 6000.0 * 1.06;

    ScenarioMatrix::new("chrt-cmp", seed)
        .mixes(vec![TaskMix::from_tasks("vww", vec![task])])
        .harvesters(SYSTEM_IDS.iter().map(|&sid| HarvesterSpec::System(sid)).collect())
        .faults(vec![
            FaultPlan::none(),
            FaultPlan::none().with_clock(ClockSpec::Chrt(ChrtTier::Tier3)),
        ])
        .duration_ms(duration_ms)
        .seed_policy(SeedPolicy::PairedEnvironment)
}

/// Fold a finished report (local or shard-merged) into Table 5 rows.
/// Expansion order: harvesters outer, faults inner → cells[2i] is the
/// RTC run of SYSTEM_IDS[i] and cells[2i+1] its CHRT twin.
pub fn rows_from(report: &crate::sim::sweep::SweepReport) -> Vec<ChrtRow> {
    assert_eq!(report.cells.len(), 2 * SYSTEM_IDS.len(), "report does not match matrix");
    SYSTEM_IDS
        .iter()
        .enumerate()
        .map(|(i, &sid)| {
            let rtc = &report.cells[2 * i].metrics;
            let chrt = &report.cells[2 * i + 1].metrics;
            ChrtRow {
                system_id: sid,
                reboots: rtc.reboots,
                on_time_pct: rtc.on_fraction() * 100.0,
                scheduled_rtc: rtc.scheduled,
                scheduled_chrt: chrt.scheduled,
            }
        })
        .collect()
}

pub fn run(n_jobs: u64, seed: u64) -> Vec<ChrtRow> {
    let m = matrix(n_jobs, seed);
    rows_from(&sweep::run_matrix(&m, sweep::default_threads()))
}

pub fn print(rows: &[ChrtRow]) {
    print_header(
        "Table 5: RTC vs CHRT remanence clock (Systems 2-4, VWW workload)",
        &["system", "reboots", "power-on%", "sched(RTC)", "sched(CHRT)", "loss%"],
    );
    for r in rows {
        let loss = 100.0 * (r.scheduled_rtc.saturating_sub(r.scheduled_chrt)) as f64
            / r.scheduled_rtc.max(1) as f64;
        print_row(&[
            format!("S{}", r.system_id),
            r.reboots.to_string(),
            format!("{:.2}", r.on_time_pct),
            r.scheduled_rtc.to_string(),
            r.scheduled_chrt.to_string(),
            format!("{loss:.2}"),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrt_loss_is_small() {
        if !crate::artifacts_root().join("vww/meta.json").exists() {
            return;
        }
        let rows = run(250, 5);
        for r in &rows {
            let loss = (r.scheduled_rtc as f64 - r.scheduled_chrt as f64)
                / r.scheduled_rtc.max(1) as f64;
            // Paper: < 0.1 %; allow slack at our smaller job counts and
            // coarser (1 s error vs 6 s deadline) geometry.
            assert!(
                loss.abs() < 0.06,
                "S{}: CHRT loss {:.3} too large (rtc={} chrt={})",
                r.system_id,
                loss,
                r.scheduled_rtc,
                r.scheduled_chrt
            );
        }
    }
}
