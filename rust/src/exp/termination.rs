//! Fig. 16 — termination policies: no early exit vs the utility test vs an
//! oracle that knows the exact number of units each sample needs. The
//! paper's claim: utility-based exit achieves similar accuracy (within
//! 2.5 %) while lowering mean inference time 4–26 %.

use crate::dnn::network::Network;
use crate::dnn::trace::{compute_traces, summarize, TraceSummary};

use super::common::{pct, print_header, print_row};

pub struct TerminationRow {
    pub dataset: String,
    pub summary: TraceSummary,
}

pub fn run(datasets: &[&str]) -> Vec<TerminationRow> {
    datasets
        .iter()
        .map(|&ds| {
            let net = Network::load(&crate::artifacts_root().join(ds)).unwrap();
            let traces = compute_traces(&net, None);
            TerminationRow { dataset: ds.into(), summary: summarize(&net, &traces) }
        })
        .collect()
}

pub fn print(rows: &[TerminationRow]) {
    print_header(
        "Fig. 16: termination policies (accuracy / mean inference time)",
        &["dataset", "policy", "accuracy", "time"],
    );
    for r in rows {
        let s = &r.summary;
        for (policy, acc, t) in [
            ("no-exit", s.acc_full, s.time_full_ms),
            ("utility", s.acc_utility, s.time_utility_ms),
            ("oracle", s.acc_oracle, s.time_oracle_ms),
        ] {
            print_row(&[
                r.dataset.clone(),
                policy.into(),
                pct(acc),
                format!("{t:.0} ms"),
            ]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utility_exit_saves_time_keeps_accuracy() {
        if !crate::artifacts_root().join("mnist/meta.json").exists() {
            return;
        }
        for r in run(&["mnist", "esc10"]) {
            let s = &r.summary;
            let saving = 1.0 - s.time_utility_ms / s.time_full_ms;
            assert!(
                saving > 0.03,
                "{}: early exit saved only {:.1}%",
                r.dataset,
                saving * 100.0
            );
            assert!(
                (s.acc_full - s.acc_utility).abs() < 0.07,
                "{}: accuracy diverged {} vs {}",
                r.dataset,
                s.acc_full,
                s.acc_utility
            );
            // oracle dominates both accuracies by construction
            assert!(s.acc_oracle >= s.acc_full - 1e-9);
        }
    }
}
