//! Fig. 24 — performance gain due to semi-supervised adaptation. The
//! ESC-10 test split "re-recorded" in three environments (lab → hall →
//! office, simulated as affine channel shifts); classifiers trained only
//! on environment 1. Without adaptation accuracy drops across
//! environments; with the centroid-update rule more than half the loss is
//! recovered (paper §11.3).

use crate::dnn::kmeans::Scratch;
use crate::dnn::network::Network;

use super::common::{pct, print_header, print_row};

pub struct AdaptationRow {
    pub environment: usize,
    pub acc_no_adapt: f64,
    pub acc_adapt: f64,
}

/// Run the test split of each environment sequentially (the deployment
/// moves lab → hall → office), with or without centroid adaptation, and
/// report accuracy per environment.
pub fn run() -> Vec<AdaptationRow> {
    let dir = crate::artifacts_root().join("esc10");
    let no_adapt = run_pass(&Network::load(&dir).unwrap(), false);
    let adapt = run_pass(&Network::load(&dir).unwrap(), true);
    no_adapt
        .into_iter()
        .zip(adapt)
        .enumerate()
        .map(|(e, (a, b))| AdaptationRow { environment: e + 1, acc_no_adapt: a, acc_adapt: b })
        .collect()
}

fn run_pass(net: &Network, adapt: bool) -> Vec<f64> {
    // env inputs: env0 = original test_x, then env1_x, env2_x.
    let mut envs: Vec<&[f32]> = vec![&net.test.x];
    for e in &net.env_x {
        envs.push(e);
    }
    let mut net = Network::load(&net.dir).unwrap(); // fresh centroids
    let mut scratch = Scratch::default();
    let slen = net.test.sample_len;
    let mut accs = Vec::new();
    for xs in envs {
        let mut correct = 0usize;
        for i in 0..net.test.len() {
            let sample = &xs[i * slen..(i + 1) * slen];
            // Run with early exit; adapt on confident classifications.
            let mut act = sample.to_vec();
            let mut pred = None;
            for li in 0..net.meta.n_layers {
                let (next, res) = net.run_unit_native(li, &act, &mut scratch);
                pred = Some(res.pred);
                if res.exit {
                    if adapt {
                        let mut feat = Vec::new();
                        net.classifiers[li].gather(&next, &mut feat);
                        let feat_owned = feat.clone();
                        net.classifiers[li].adapt(res.best, &feat_owned);
                        crate::dnn::adapt::propagate_centroid(&mut net, li, res.best);
                    }
                    break;
                }
                act = next;
            }
            if pred == Some(net.test.y[i]) {
                correct += 1;
            }
        }
        accs.push(correct as f64 / net.test.len() as f64);
    }
    accs
}

pub fn print(rows: &[AdaptationRow]) {
    print_header(
        "Fig. 24: adaptation across environments (ESC-10)",
        &["environment", "no-adapt", "with-adapt", "gain"],
    );
    for r in rows {
        print_row(&[
            format!("env {}", r.environment),
            pct(r.acc_no_adapt),
            pct(r.acc_adapt),
            format!("{:+.1}pp", 100.0 * (r.acc_adapt - r.acc_no_adapt)),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptation_recovers_environment_shift_loss() {
        let dir = crate::artifacts_root().join("esc10");
        if !dir.join("meta.json").exists() {
            return;
        }
        let rows = run();
        assert_eq!(rows.len(), 3, "expected 3 environments");
        // Environment shift hurts the frozen classifier...
        let drop = rows[0].acc_no_adapt - rows[2].acc_no_adapt;
        assert!(drop > 0.0, "no accuracy drop to recover (drop={drop})");
        // ...and adaptation recovers part of the loss in shifted envs.
        let recovered: f64 = rows[1..]
            .iter()
            .map(|r| r.acc_adapt - r.acc_no_adapt)
            .sum::<f64>()
            / 2.0;
        assert!(
            recovered > -0.02,
            "adaptation made things worse: {recovered}"
        );
    }
}
