//! Fig. 4 — conditional energy-event distributions h(N) for a persistent
//! source, a piezo (footstep) harvester, a stationary solar harvester, and
//! a stationary RF harvester (ΔT = 5 min over a two-month-equivalent
//! trace); and Fig. 25 — validation that the estimated η converges to the
//! measured next-slot prediction accuracy.

use crate::energy::events::{conditional_event_dist, eta_factor};
use crate::energy::harvester::{Harvester, HarvesterKind};

use super::common::{print_header, print_row};

pub struct HarvesterStudy {
    pub name: String,
    pub eta: f64,
    pub prediction_accuracy: f64,
    pub h_curve: Vec<(i32, f64)>,
}

fn study_trace(name: &str, seed: u64) -> (String, Vec<bool>) {
    // Two months of 5-minute windows = 17 280 windows.
    const WINDOWS: usize = 2 * 30 * 24 * 12;
    match name {
        "persistent" => ("persistent".into(), vec![true; WINDOWS]),
        "piezo" => {
            let mut h = Harvester::piezo(seed);
            // ΔK: enough footsteps-energy in 5 min — half the on-window yield.
            let dk = h.on_power_mw * h.dt_ms * 1e-3 * 0.5;
            ("piezo".into(), h.event_trace(WINDOWS, dk))
        }
        "solar" => {
            let mut h = Harvester::solar_diurnal(seed);
            let dk = h.on_power_mw * h.dt_ms * 1e-3 * 0.4;
            ("solar".into(), h.event_trace(WINDOWS, dk))
        }
        "rf" => {
            let mut h = Harvester::markov(
                HarvesterKind::Rf,
                70.0,
                0.93,
                0.55,
                5.0 * 60.0 * 1000.0,
                seed,
            );
            let dk = h.on_power_mw * h.dt_ms * 1e-3 * 0.5;
            ("rf".into(), h.event_trace(WINDOWS, dk))
        }
        other => panic!("unknown harvester study `{other}`"),
    }
}

/// Measured next-slot prediction accuracy: predict H_{t+1} = H_t (the
/// burst-persistence predictor η licenses) and score it (Fig. 25).
pub fn next_slot_prediction_accuracy(trace: &[bool]) -> f64 {
    if trace.len() < 2 {
        return 1.0;
    }
    let hits = trace.windows(2).filter(|w| w[0] == w[1]).count();
    hits as f64 / (trace.len() - 1) as f64
}

pub fn run(max_n: usize, seed: u64) -> Vec<HarvesterStudy> {
    let mut out = Vec::new();
    for name in ["persistent", "piezo", "solar", "rf"] {
        let (name, trace) = study_trace(name, seed);
        let est = eta_factor(&trace, max_n, seed);
        let acc = next_slot_prediction_accuracy(&trace);
        out.push(HarvesterStudy {
            name,
            eta: est.eta,
            prediction_accuracy: acc,
            h_curve: conditional_event_dist(&trace, max_n),
        });
    }
    out
}

pub fn print_figure4(studies: &[HarvesterStudy]) {
    for s in studies {
        print_header(
            &format!("Fig. 4: h(N) — {} (eta = {:.2})", s.name, s.eta),
            &["N", "h(N)"],
        );
        for &(n, h) in &s.h_curve {
            // Sparse print: powers-of-two-ish Ns keep the table readable.
            if n.abs() <= 4 || n.abs() % 5 == 0 {
                print_row(&[n.to_string(), format!("{h:.3}")]);
            }
        }
    }
}

pub fn print_figure25(studies: &[HarvesterStudy]) {
    print_header(
        "Fig. 25: eta-factor vs measured next-slot prediction accuracy",
        &["harvester", "eta", "pred-acc", "|diff|"],
    );
    for s in studies {
        print_row(&[
            s.name.clone(),
            format!("{:.3}", s.eta),
            format!("{:.3}", s.prediction_accuracy),
            format!("{:.3}", (s.eta - s.prediction_accuracy).abs()),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure4_shapes() {
        let studies = run(20, 7);
        let by_name = |n: &str| studies.iter().find(|s| s.name == n).unwrap();
        // Persistent: eta == 1, h(N>0) == 1 everywhere it is defined.
        let p = by_name("persistent");
        assert!(p.eta > 0.99);
        assert!(p.h_curve.iter().filter(|&&(n, _)| n > 0).all(|&(_, h)| h == 1.0));
        // Harvesters are bursty: h(1) > marginal rate.
        for name in ["piezo", "solar", "rf"] {
            let s = by_name(name);
            let h1 = s.h_curve.iter().find(|&&(n, _)| n == 1).unwrap().1;
            assert!(h1 > 0.6, "{name}: h(1)={h1}");
            assert!(s.eta > 0.2 && s.eta < 1.0, "{name}: eta={}", s.eta);
        }
    }

    #[test]
    fn figure25_eta_tracks_prediction_accuracy() {
        // The paper's validation: estimated η converges near the measured
        // next-slot prediction accuracy for the harvested sources.
        let studies = run(20, 7);
        for s in &studies {
            if s.name == "persistent" {
                continue;
            }
            assert!(
                (s.eta - s.prediction_accuracy).abs() < 0.25,
                "{}: eta={} acc={}",
                s.name,
                s.eta,
                s.prediction_accuracy
            );
        }
    }
}
