//! Traditional-classifier baselines for Table 7 (paper §11.1): KNN,
//! k-means, linear SVM (one-vs-rest Pegasos), and a random forest —
//! trained on raw pixels, exactly the comparison the paper makes to argue
//! that DNN features are worth their cost on batteryless systems.

pub mod forest;
pub mod knn;
pub mod kmeans_raw;
pub mod svm;

/// Common interface: fit on (x, y), predict a label per sample.
pub trait Baseline {
    fn name(&self) -> &'static str;
    fn predict(&self, sample: &[f32]) -> i32;
}

/// Accuracy of a fitted baseline over a test set of flattened samples.
pub fn accuracy(model: &dyn Baseline, xs: &[f32], sample_len: usize, ys: &[i32]) -> f64 {
    let n = ys.len();
    let mut correct = 0usize;
    for i in 0..n {
        if model.predict(&xs[i * sample_len..(i + 1) * sample_len]) == ys[i] {
            correct += 1;
        }
    }
    correct as f64 / n.max(1) as f64
}
