//! Linear one-vs-rest SVM trained with Pegasos (stochastic sub-gradient on
//! the hinge loss) — the "SVM" row of Table 7.

use super::Baseline;
use crate::util::rng::Pcg32;

pub struct LinearSvm {
    /// (n_classes, sample_len + 1) weights incl. bias.
    w: Vec<f32>,
    sample_len: usize,
    n_classes: usize,
}

impl LinearSvm {
    pub fn fit(
        xs: &[f32],
        sample_len: usize,
        ys: &[i32],
        n_classes: usize,
        epochs: usize,
        lambda: f32,
        seed: u64,
    ) -> Self {
        let n = ys.len();
        let d = sample_len + 1;
        let mut w = vec![0f32; n_classes * d];
        let mut rng = Pcg32::seeded(seed);
        let mut t = 0u64;
        for _ in 0..epochs {
            for _ in 0..n {
                t += 1;
                let i = rng.below(n as u64) as usize;
                let x = &xs[i * sample_len..(i + 1) * sample_len];
                let lr = 1.0 / (lambda * t as f32);
                for c in 0..n_classes {
                    let y = if ys[i] as usize == c { 1.0f32 } else { -1.0 };
                    let wc = &mut w[c * d..(c + 1) * d];
                    let margin = {
                        let mut m = wc[sample_len]; // bias
                        for (a, b) in x.iter().zip(wc.iter()) {
                            m += a * b;
                        }
                        y * m
                    };
                    // w <- (1 - lr*lambda) w [+ lr*y*x if margin < 1]
                    let shrink = 1.0 - lr * lambda;
                    for v in wc.iter_mut() {
                        *v *= shrink;
                    }
                    if margin < 1.0 {
                        for (v, &xv) in wc.iter_mut().zip(x) {
                            *v += lr * y * xv;
                        }
                        wc[sample_len] += lr * y;
                    }
                }
            }
        }
        LinearSvm { w, sample_len, n_classes }
    }
}

impl Baseline for LinearSvm {
    fn name(&self) -> &'static str {
        "svm"
    }

    fn predict(&self, sample: &[f32]) -> i32 {
        let d = self.sample_len + 1;
        let mut best = (0usize, f32::NEG_INFINITY);
        for c in 0..self.n_classes {
            let wc = &self.w[c * d..(c + 1) * d];
            let mut s = wc[self.sample_len];
            for (a, b) in sample.iter().zip(wc.iter()) {
                s += a * b;
            }
            if s > best.1 {
                best = (c, s);
            }
        }
        best.0 as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn separates_linear_blobs() {
        let mut rng = Pcg32::seeded(11);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..200 {
            let c = rng.below(2) as usize;
            let off = if c == 0 { -2.0 } else { 2.0 };
            xs.push(off + 0.6 * rng.normal() as f32);
            xs.push(off + 0.6 * rng.normal() as f32);
            ys.push(c as i32);
        }
        let m = LinearSvm::fit(&xs, 2, &ys, 2, 8, 0.01, 1);
        let acc = super::super::accuracy(&m, &xs, 2, &ys);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn multiclass_one_vs_rest() {
        // Three corner blobs in 2-D (each class linearly separable from
        // the rest — the setting one-vs-rest handles).
        let mut rng = Pcg32::seeded(3);
        let centers = [(-4.0, -4.0), (4.0, -4.0), (0.0, 5.0)];
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..240 {
            let c = rng.below(3) as usize;
            xs.push(centers[c].0 + 0.5 * rng.normal() as f32);
            xs.push(centers[c].1 + 0.5 * rng.normal() as f32);
            ys.push(c as i32);
        }
        let m = LinearSvm::fit(&xs, 2, &ys, 3, 15, 0.005, 2);
        let acc = super::super::accuracy(&m, &xs, 2, &ys);
        assert!(acc > 0.9, "acc={acc}");
    }
}
