//! Random forest (bagged CART trees, Gini impurity, feature subsampling) —
//! the "Random Forest" row of Table 7.

use super::Baseline;
use crate::util::rng::Pcg32;

struct Node {
    /// Leaf if `feature == usize::MAX`.
    feature: usize,
    threshold: f32,
    left: usize,
    right: usize,
    label: i32,
}

struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f32]) -> i32 {
        let mut i = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.feature == usize::MAX {
                return n.label;
            }
            i = if x[n.feature] <= n.threshold { n.left } else { n.right };
        }
    }
}

pub struct RandomForest {
    trees: Vec<Tree>,
    sample_len: usize,
    n_classes: usize,
}

struct Builder<'a> {
    xs: &'a [f32],
    ys: &'a [i32],
    sample_len: usize,
    n_classes: usize,
    max_depth: usize,
    min_leaf: usize,
    n_feat_try: usize,
}

impl<'a> Builder<'a> {
    fn gini(&self, idx: &[usize]) -> f64 {
        let mut counts = vec![0f64; self.n_classes];
        for &i in idx {
            counts[self.ys[i] as usize] += 1.0;
        }
        let n = idx.len() as f64;
        1.0 - counts.iter().map(|c| (c / n) * (c / n)).sum::<f64>()
    }

    fn majority(&self, idx: &[usize]) -> i32 {
        let mut counts = vec![0u32; self.n_classes];
        for &i in idx {
            counts[self.ys[i] as usize] += 1;
        }
        counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }

    fn build(&self, idx: &mut Vec<usize>, depth: usize, rng: &mut Pcg32,
             nodes: &mut Vec<Node>) -> usize {
        let label = self.majority(idx);
        let impurity = self.gini(idx);
        if depth >= self.max_depth || idx.len() < 2 * self.min_leaf || impurity < 1e-9 {
            nodes.push(Node { feature: usize::MAX, threshold: 0.0, left: 0, right: 0, label });
            return nodes.len() - 1;
        }
        // Random feature subset; best threshold by Gini gain over a few
        // sampled split points.
        let mut best: Option<(usize, f32, f64)> = None;
        for _ in 0..self.n_feat_try {
            let f = rng.below(self.sample_len as u64) as usize;
            for _ in 0..4 {
                let pick = idx[rng.below(idx.len() as u64) as usize];
                let thr = self.xs[pick * self.sample_len + f];
                let (mut l, mut r) = (Vec::new(), Vec::new());
                for &i in idx.iter() {
                    if self.xs[i * self.sample_len + f] <= thr {
                        l.push(i);
                    } else {
                        r.push(i);
                    }
                }
                if l.len() < self.min_leaf || r.len() < self.min_leaf {
                    continue;
                }
                let n = idx.len() as f64;
                let w =
                    self.gini(&l) * l.len() as f64 / n + self.gini(&r) * r.len() as f64 / n;
                let gain = impurity - w;
                if best.map(|(_, _, g)| gain > g).unwrap_or(gain > 1e-9) {
                    best = Some((f, thr, gain));
                }
            }
        }
        let Some((f, thr, _)) = best else {
            nodes.push(Node { feature: usize::MAX, threshold: 0.0, left: 0, right: 0, label });
            return nodes.len() - 1;
        };
        let (mut l, mut r) = (Vec::new(), Vec::new());
        for &i in idx.iter() {
            if self.xs[i * self.sample_len + f] <= thr {
                l.push(i);
            } else {
                r.push(i);
            }
        }
        let left = self.build(&mut l, depth + 1, rng, nodes);
        let right = self.build(&mut r, depth + 1, rng, nodes);
        nodes.push(Node { feature: f, threshold: thr, left, right, label });
        nodes.len() - 1
    }
}

impl RandomForest {
    pub fn fit(
        xs: &[f32],
        sample_len: usize,
        ys: &[i32],
        n_classes: usize,
        n_trees: usize,
        max_depth: usize,
        seed: u64,
    ) -> Self {
        let n = ys.len();
        let n_feat_try = ((sample_len as f64).sqrt() as usize).max(1) * 2;
        let b = Builder {
            xs,
            ys,
            sample_len,
            n_classes,
            max_depth,
            min_leaf: 2,
            n_feat_try,
        };
        let mut trees = Vec::with_capacity(n_trees);
        let mut rng = Pcg32::seeded(seed);
        for _ in 0..n_trees {
            // Bootstrap sample.
            let mut idx: Vec<usize> =
                (0..n).map(|_| rng.below(n as u64) as usize).collect();
            let mut nodes = Vec::new();
            let root = b.build(&mut idx, 0, &mut rng, &mut nodes);
            // Make the root index 0 by convention: rotate via wrapper.
            if root != nodes.len() - 1 {
                unreachable!("root is always pushed last");
            }
            // Store with root-last; prediction starts at last node.
            nodes.reverse_root();
            trees.push(Tree { nodes });
        }
        RandomForest { trees, sample_len, n_classes }
    }
}

/// Helper: we built trees with the root as the LAST node; rewire indices so
/// the root is node 0 (prediction loops start at 0).
trait RootLast {
    fn reverse_root(&mut self);
}

impl RootLast for Vec<Node> {
    fn reverse_root(&mut self) {
        let last = self.len() - 1;
        if last == 0 {
            return;
        }
        self.swap(0, last);
        // Fix child indices that pointed at 0 or last.
        for n in self.iter_mut() {
            if n.feature != usize::MAX {
                for c in [&mut n.left, &mut n.right] {
                    if *c == last {
                        *c = 0;
                    } else if *c == 0 {
                        *c = last;
                    }
                }
            }
        }
    }
}

impl Baseline for RandomForest {
    fn name(&self) -> &'static str {
        "forest"
    }

    fn predict(&self, sample: &[f32]) -> i32 {
        debug_assert_eq!(sample.len(), self.sample_len);
        let mut votes = vec![0u32; self.n_classes];
        for t in &self.trees {
            votes[t.predict(sample) as usize] += 1;
        }
        votes
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn learns_axis_aligned_rule() {
        // Class = (x0 > 0) as a simple axis split.
        let mut rng = Pcg32::seeded(2);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..300 {
            let v = rng.normal() as f32 * 2.0;
            xs.push(v);
            xs.push(rng.normal() as f32);
            ys.push((v > 0.0) as i32);
        }
        let m = RandomForest::fit(&xs, 2, &ys, 2, 15, 6, 3);
        let acc = super::super::accuracy(&m, &xs, 2, &ys);
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn learns_xor_needs_depth() {
        // XOR of signs: linear models fail; trees handle it.
        let mut rng = Pcg32::seeded(4);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..400 {
            let a = rng.normal() as f32;
            let b = rng.normal() as f32;
            xs.push(a);
            xs.push(b);
            ys.push(((a > 0.0) ^ (b > 0.0)) as i32);
        }
        let m = RandomForest::fit(&xs, 2, &ys, 2, 25, 8, 5);
        let acc = super::super::accuracy(&m, &xs, 2, &ys);
        assert!(acc > 0.8, "acc={acc}");
    }
}
