//! k-nearest-neighbours on raw flattened inputs (L2 metric, majority vote).

use super::Baseline;

pub struct Knn {
    pub k: usize,
    train_x: Vec<f32>,
    sample_len: usize,
    train_y: Vec<i32>,
    n_classes: usize,
}

impl Knn {
    pub fn fit(k: usize, xs: &[f32], sample_len: usize, ys: &[i32], n_classes: usize) -> Self {
        Knn {
            k,
            train_x: xs.to_vec(),
            sample_len,
            train_y: ys.to_vec(),
            n_classes,
        }
    }
}

impl Baseline for Knn {
    fn name(&self) -> &'static str {
        "knn"
    }

    fn predict(&self, sample: &[f32]) -> i32 {
        let n = self.train_y.len();
        // Partial selection of the k nearest (k is tiny; linear scan).
        let mut best: Vec<(f32, i32)> = Vec::with_capacity(self.k + 1);
        for i in 0..n {
            let row = &self.train_x[i * self.sample_len..(i + 1) * self.sample_len];
            let mut d = 0f32;
            for (a, b) in sample.iter().zip(row) {
                let diff = a - b;
                d += diff * diff;
            }
            if best.len() < self.k || d < best.last().unwrap().0 {
                let pos = best.partition_point(|&(bd, _)| bd < d);
                best.insert(pos, (d, self.train_y[i]));
                if best.len() > self.k {
                    best.pop();
                }
            }
        }
        let mut votes = vec![0u32; self.n_classes];
        for &(_, y) in &best {
            votes[y as usize] += 1;
        }
        votes
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separable_clusters_classified() {
        // Two 2-D blobs.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..20 {
            let o = (i % 5) as f32 * 0.01;
            xs.extend([0.0 + o, 0.0 + o]);
            ys.push(0);
            xs.extend([5.0 + o, 5.0 + o]);
            ys.push(1);
            xs.extend([0.0, 0.0]); // keep interleaved layout honest
            ys.push(0);
        }
        let m = Knn::fit(3, &xs, 2, &ys, 2);
        assert_eq!(m.predict(&[0.2, -0.1]), 0);
        assert_eq!(m.predict(&[4.9, 5.2]), 1);
    }

    #[test]
    fn k_one_matches_nearest() {
        let xs = vec![0.0, 0.0, 10.0, 10.0];
        let ys = vec![3, 7];
        let m = Knn::fit(1, &xs, 2, &ys, 8);
        assert_eq!(m.predict(&[1.0, 1.0]), 3);
        assert_eq!(m.predict(&[9.0, 9.0]), 7);
    }
}
