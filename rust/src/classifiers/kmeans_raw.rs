//! k-means on raw flattened inputs (Lloyd with labeled seeding) — the
//! "k-means" row of Table 7. Unlike Zygarde's per-layer classifiers this
//! sees no learned representation, which is the point of the comparison.

use super::Baseline;

pub struct KmeansRaw {
    centroids: Vec<f32>,
    sample_len: usize,
    labels: Vec<i32>,
}

impl KmeansRaw {
    pub fn fit(
        xs: &[f32],
        sample_len: usize,
        ys: &[i32],
        n_classes: usize,
        iters: usize,
    ) -> Self {
        let n = ys.len();
        // Seed at labeled class means.
        let mut centroids = vec![0f32; n_classes * sample_len];
        let mut counts = vec![0f32; n_classes];
        for i in 0..n {
            let c = ys[i] as usize;
            counts[c] += 1.0;
            let row = &xs[i * sample_len..(i + 1) * sample_len];
            for (acc, &v) in centroids[c * sample_len..(c + 1) * sample_len]
                .iter_mut()
                .zip(row)
            {
                *acc += v;
            }
        }
        for c in 0..n_classes {
            let cnt = counts[c].max(1.0);
            for v in &mut centroids[c * sample_len..(c + 1) * sample_len] {
                *v /= cnt;
            }
        }
        // Lloyd iterations.
        let mut assign = vec![0usize; n];
        for _ in 0..iters {
            for i in 0..n {
                let row = &xs[i * sample_len..(i + 1) * sample_len];
                let mut best = (0usize, f32::INFINITY);
                for c in 0..n_classes {
                    let cent = &centroids[c * sample_len..(c + 1) * sample_len];
                    let mut d = 0f32;
                    for (a, b) in row.iter().zip(cent) {
                        let x = a - b;
                        d += x * x;
                    }
                    if d < best.1 {
                        best = (c, d);
                    }
                }
                assign[i] = best.0;
            }
            centroids.iter_mut().for_each(|v| *v = 0.0);
            counts.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1.0;
                let row = &xs[i * sample_len..(i + 1) * sample_len];
                for (acc, &v) in centroids[c * sample_len..(c + 1) * sample_len]
                    .iter_mut()
                    .zip(row)
                {
                    *acc += v;
                }
            }
            for c in 0..n_classes {
                let cnt = counts[c].max(1.0);
                for v in &mut centroids[c * sample_len..(c + 1) * sample_len] {
                    *v /= cnt;
                }
            }
        }
        // Majority label per cluster.
        let mut labels = vec![0i32; n_classes];
        for c in 0..n_classes {
            let mut votes = vec![0u32; n_classes];
            for i in 0..n {
                if assign[i] == c {
                    votes[ys[i] as usize] += 1;
                }
            }
            labels[c] = votes
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(i, _)| i as i32)
                .unwrap_or(c as i32);
        }
        KmeansRaw { centroids, sample_len, labels }
    }
}

impl Baseline for KmeansRaw {
    fn name(&self) -> &'static str {
        "kmeans"
    }

    fn predict(&self, sample: &[f32]) -> i32 {
        let k = self.labels.len();
        let mut best = (0usize, f32::INFINITY);
        for c in 0..k {
            let cent = &self.centroids[c * self.sample_len..(c + 1) * self.sample_len];
            let mut d = 0f32;
            for (a, b) in sample.iter().zip(cent) {
                let x = a - b;
                d += x * x;
            }
            if d < best.1 {
                best = (c, d);
            }
        }
        self.labels[best.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn recovers_gaussian_blobs() {
        let mut rng = Pcg32::seeded(5);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let centers = [(-3.0, -3.0), (3.0, 3.0), (-3.0, 3.0)];
        for _ in 0..120 {
            let c = rng.below(3) as usize;
            xs.push(centers[c].0 + 0.5 * rng.normal() as f32);
            xs.push(centers[c].1 + 0.5 * rng.normal() as f32);
            ys.push(c as i32);
        }
        let m = KmeansRaw::fit(&xs, 2, &ys, 3, 10);
        let acc = super::super::accuracy(&m, &xs, 2, &ys);
        assert!(acc > 0.95, "acc={acc}");
    }
}
