//! Host wall-clock abstraction for the serve layer.
//!
//! Not to be confused with this module's parent [`super::Clock`], which
//! models the *scheduler's* notion of simulated time inside a scenario
//! (RTC vs. CHRT remanence clocks, §7/§8.7). [`WallClock`] is about the
//! *dispatcher process*: lease timeouts, heartbeats, and the
//! lease-latency histogram all need "how many milliseconds has this
//! serve been running", and reading `Instant::now()` inline made those
//! paths untestable without sleeping and non-deterministic under
//! tracing. The IO shell takes a `Box<dyn WallClock>` instead:
//!
//! * [`SystemClock`] — the production clock: monotonic milliseconds
//!   since construction (`Instant`-backed).
//! * [`ManualClock`] — a hand-cranked clock for tests and the simnet
//!   harness: shared-handle `set`/`advance`, no real waiting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Milliseconds elapsed on the dispatcher's own clock. Implementations
/// must be monotone non-decreasing.
pub trait WallClock: Send {
    fn now_ms(&self) -> u64;
}

/// Monotonic wall time in milliseconds since the clock was created.
pub struct SystemClock {
    t0: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { t0: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl WallClock for SystemClock {
    fn now_ms(&self) -> u64 {
        self.t0.elapsed().as_millis() as u64
    }
}

/// A clock that only moves when told to. Clones share the same time
/// cell, so a test can hold one handle while the code under test holds
/// the other (boxed) one.
#[derive(Clone)]
pub struct ManualClock {
    ms: Arc<AtomicU64>,
}

impl ManualClock {
    pub fn new(start_ms: u64) -> ManualClock {
        ManualClock { ms: Arc::new(AtomicU64::new(start_ms)) }
    }

    /// Jump to an absolute time. Callers are responsible for keeping the
    /// clock monotone (the trait contract).
    pub fn set(&self, ms: u64) {
        self.ms.store(ms, Ordering::SeqCst);
    }

    pub fn advance(&self, delta_ms: u64) {
        self.ms.fetch_add(delta_ms, Ordering::SeqCst);
    }
}

impl WallClock for ManualClock {
    fn now_ms(&self) -> u64 {
        self.ms.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotone_and_starts_near_zero() {
        let c = SystemClock::new();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(a <= b);
        assert!(a < 60_000, "a fresh clock should read ~0, got {a}");
    }

    #[test]
    fn manual_clock_shares_time_across_handles() {
        let c = ManualClock::new(5);
        let handle = c.clone();
        let boxed: Box<dyn WallClock> = Box::new(c);
        assert_eq!(boxed.now_ms(), 5);
        handle.advance(95);
        assert_eq!(boxed.now_ms(), 100);
        handle.set(250);
        assert_eq!(boxed.now_ms(), 250);
    }
}
