//! Timekeeping across power failures (paper §7 "Time Keeping", §8.7).
//!
//! The scheduler needs the current time to compute remaining deadlines.
//! Two implementations:
//!
//! * [`Rtc`] — a battery-backed DS3231: perfect time (the paper's default).
//! * [`Chrt`] — the Cascaded Hierarchical Remanence Timekeeper [46], a
//!   batteryless clock read on every reboot. Its tier-3 (1 s resolution,
//!   100 s range) reports exact time ~80 % of reads, +1 s most of the
//!   rest, and rarely ±2 s / −1 s — the error model of §8.7. Errors only
//!   occur when the clock is *consulted across an outage*; while powered,
//!   the MCU's own timer is exact.

use crate::util::rng::Pcg32;

pub mod wall;

pub trait Clock {
    /// The time the scheduler believes it is, given true time `t_ms`.
    ///
    /// Must be a *pure observation* (no state change): the engine's
    /// off-phase fast path skips reads that cannot influence anything
    /// (empty queue), and the differential-exactness suite holds the
    /// optimized and naive steppers — which read at different rates — to
    /// byte-identical outcomes. State may only change in `on_reboot`.
    fn now_ms(&mut self, true_t_ms: f64) -> f64;
    /// Called when the MCU reboots after an outage of `outage_ms`.
    fn on_reboot(&mut self, true_t_ms: f64, outage_ms: f64);
    fn name(&self) -> &'static str;
    /// Constant-offset contract for the engine's event-driven idle loops:
    /// `Some(o)` promises that, until the next `on_reboot`, every read
    /// satisfies `now_ms(t) == (t + o).max(0.0)` **bitwise** for all
    /// `t >= 0.0`. The engine then predicts believed-deadline crossings
    /// with plain f64 arithmetic instead of a virtual clock read per tick.
    /// Return `None` when no such offset exists (the loops fall back to
    /// naive per-tick stepping — a correctness-neutral, perf-only choice).
    fn const_offset(&self) -> Option<f64> {
        None
    }
}

/// Declarative clock choice for scenario specs (`sim::sweep`): a plain
/// value that can be stored in a matrix cell and built into a boxed
/// [`Clock`] per scenario. The CHRT variants inject post-reboot clock skew
/// through the existing remanence-clock error models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockSpec {
    Rtc,
    Chrt(ChrtTier),
}

impl ClockSpec {
    pub fn build(self, seed: u64) -> Box<dyn Clock> {
        match self {
            ClockSpec::Rtc => Box::new(Rtc),
            ClockSpec::Chrt(tier) => Box::new(Chrt::new(tier, seed)),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ClockSpec::Rtc => "rtc",
            ClockSpec::Chrt(ChrtTier::Tier1) => "chrt-t1",
            ClockSpec::Chrt(ChrtTier::Tier2) => "chrt-t2",
            ClockSpec::Chrt(ChrtTier::Tier3) => "chrt-t3",
        }
    }
}

/// Battery-backed real-time clock: exact.
#[derive(Default, Clone, Debug)]
pub struct Rtc;

impl Clock for Rtc {
    fn now_ms(&mut self, true_t_ms: f64) -> f64 {
        true_t_ms
    }

    fn on_reboot(&mut self, _true_t_ms: f64, _outage_ms: f64) {}

    fn name(&self) -> &'static str {
        "rtc"
    }

    /// Exact: for `t >= 0.0`, `t + 0.0 == t` bitwise (simulation time is
    /// never `-0.0`) and `max(t, 0.0) == t`.
    fn const_offset(&self) -> Option<f64> {
        Some(0.0)
    }
}

/// CHRT tiers (paper §8.7): each tier trades range for resolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChrtTier {
    /// ~100 ms range, near-perfect accuracy (optimized for RF).
    Tier1,
    /// Mid-range (interpolated between the published tiers).
    Tier2,
    /// 1 s resolution, 100 s range, 80 % exact.
    Tier3,
}

#[derive(Clone, Debug)]
pub struct Chrt {
    pub tier: ChrtTier,
    /// Current accumulated clock error (ms); reset only by resync.
    pub error_ms: f64,
    rng: Pcg32,
    pub reads: u64,
    pub exact_reads: u64,
}

impl Chrt {
    pub fn new(tier: ChrtTier, seed: u64) -> Self {
        Chrt { tier, error_ms: 0.0, rng: Pcg32::seeded(seed), reads: 0, exact_reads: 0 }
    }

    /// Sample the read error for one reboot, per the published error
    /// distribution: 80 % exact; +1 s ~17 %; +2 s 1.5 %; −1 s 1 %; −2 s 0.5 %.
    fn sample_error_ms(&mut self, outage_ms: f64) -> f64 {
        match self.tier {
            ChrtTier::Tier1 => {
                // Near-perfect within its 100 ms range; beyond range the
                // paper says results are identical to RTC for RF systems,
                // so outages longer than the range fall back to tier-3
                // statistics scaled down.
                if outage_ms <= 100.0 {
                    0.0
                } else {
                    self.tier3_error()
                * 0.0 // tier-1 deployments pair with RF: still exact (§8.7)
                }
            }
            ChrtTier::Tier2 => self.tier3_error() * 0.5,
            ChrtTier::Tier3 => self.tier3_error(),
        }
    }

    fn tier3_error(&mut self) -> f64 {
        let u = self.rng.f64();
        if u < 0.80 {
            0.0
        } else if u < 0.97 {
            1000.0
        } else if u < 0.985 {
            2000.0
        } else if u < 0.995 {
            -1000.0
        } else {
            -2000.0
        }
    }
}

impl Clock for Chrt {
    fn now_ms(&mut self, true_t_ms: f64) -> f64 {
        (true_t_ms + self.error_ms).max(0.0)
    }

    fn on_reboot(&mut self, _true_t_ms: f64, outage_ms: f64) {
        self.reads += 1;
        let e = self.sample_error_ms(outage_ms);
        if e == 0.0 {
            self.exact_reads += 1;
        }
        // Successive read errors do not accumulate unboundedly: each read
        // re-times from the remanence state, so the error is per-outage
        // (and often a positive error is compensated later, §8.7).
        self.error_ms = e;
    }

    fn name(&self) -> &'static str {
        "chrt"
    }

    /// `now_ms` *is* `(t + error_ms).max(0.0)`, and `error_ms` changes
    /// only in `on_reboot` — the exact shape the contract requires.
    fn const_offset(&self) -> Option<f64> {
        Some(self.error_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtc_is_exact() {
        let mut c = Rtc;
        c.on_reboot(5000.0, 1000.0);
        assert_eq!(c.now_ms(1234.5), 1234.5);
    }

    #[test]
    fn chrt_tier3_error_distribution() {
        let mut c = Chrt::new(ChrtTier::Tier3, 42);
        let mut hist = std::collections::BTreeMap::<i64, u32>::new();
        for _ in 0..20_000 {
            c.on_reboot(0.0, 5000.0);
            *hist.entry(c.error_ms as i64).or_default() += 1;
        }
        let frac = |e: i64| *hist.get(&e).unwrap_or(&0) as f64 / 20_000.0;
        assert!((frac(0) - 0.80).abs() < 0.02, "exact={}", frac(0));
        assert!((frac(1000) - 0.17).abs() < 0.02);
        assert!(frac(-1000) < 0.03 && frac(-2000) < 0.02 && frac(2000) < 0.03);
        assert_eq!(c.reads, 20_000);
    }

    #[test]
    fn chrt_tier1_exact_in_range() {
        let mut c = Chrt::new(ChrtTier::Tier1, 1);
        for _ in 0..1000 {
            c.on_reboot(0.0, 50.0);
            assert_eq!(c.error_ms, 0.0);
        }
    }

    #[test]
    fn const_offset_reproduces_now_ms_bitwise() {
        let mut rtc = Rtc;
        let o = rtc.const_offset().expect("rtc offers an offset");
        for t in [0.0, 5.0, 1234.5, 9.9e7] {
            assert_eq!(
                rtc.now_ms(t).to_bits(),
                (t + o).max(0.0).to_bits(),
                "rtc offset contract broken at t={t}"
            );
        }
        let mut chrt = Chrt::new(ChrtTier::Tier3, 77);
        for reboot in 0..50 {
            chrt.on_reboot(1000.0 * reboot as f64, 5000.0);
            let o = chrt.const_offset().expect("chrt offers an offset");
            // Negative errors must clamp identically (believed time never
            // runs before t = 0).
            for t in [0.0, 1.0, 250.0, 1999.5, 3.6e6] {
                assert_eq!(
                    chrt.now_ms(t).to_bits(),
                    (t + o).max(0.0).to_bits(),
                    "chrt offset contract broken at t={t} error={o}"
                );
            }
        }
    }

    #[test]
    fn chrt_error_offsets_reported_time() {
        let mut c = Chrt::new(ChrtTier::Tier3, 7);
        // Force until a nonzero error appears.
        let mut saw_nonzero = false;
        for _ in 0..200 {
            c.on_reboot(0.0, 5000.0);
            if c.error_ms != 0.0 {
                saw_nonzero = true;
                assert_eq!(c.now_ms(10_000.0), 10_000.0 + c.error_ms);
            }
        }
        assert!(saw_nonzero);
    }
}
