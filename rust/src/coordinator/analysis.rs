//! Schedulability analysis (paper §5.3).
//!
//! For an imprecise scheduler, N sporadic tasks are schedulable when
//! Σ C_i/T_i ≤ 1 with C_i counting only mandatory work. Intermittence is
//! modeled as an extra highest-priority sporadic *energy task* whose
//! execution time is the outage duration: Σ C_i/T_i + C_e/T_e ≤ 1, where
//! E[C_e] = η/(1−η) (geometric state persistence). The necessary
//! condition on the outage inter-arrival T_E follows:
//!
//! ```text
//! T_E ≥ (η/(1−η)) / (1 − Σ C_i/T_i)
//! ```

use super::task::TaskSpec;
use crate::energy::events::expected_outage_events;

/// CPU utilization of the task set, counting only mandatory work when
/// `mandatory_fraction` < 1 (the expected fraction of unit time that is
/// mandatory under the dynamic partition — estimated from traces).
pub fn utilization(tasks: &[&TaskSpec], mandatory_fraction: f64) -> f64 {
    tasks
        .iter()
        .map(|t| t.wcet_ms() * mandatory_fraction / t.period_ms)
        .sum()
}

/// Expected mandatory fraction of a task's WCET from its trace set: the
/// mean over samples of (time of units 0..=exit) / (time of all units).
pub fn mandatory_fraction(task: &TaskSpec) -> f64 {
    if task.traces.is_empty() || !task.imprecise {
        return 1.0;
    }
    let total: f64 = task.unit_time_ms.iter().sum();
    let mut acc = 0.0;
    for tr in task.traces.iter() {
        let m: f64 = task.unit_time_ms[..=tr.exit_unit].iter().sum();
        acc += m / total;
    }
    acc / task.traces.len() as f64
}

#[derive(Clone, Copy, Debug)]
pub struct Schedulability {
    /// Σ C_i/T_i over mandatory work.
    pub utilization: f64,
    /// E[C_e] in energy-event units, η/(1−η).
    pub expected_outage: f64,
    /// Minimum outage inter-arrival T_E for the necessary condition.
    pub min_energy_period: f64,
    /// Whether the necessary condition can hold at all (utilization < 1).
    pub feasible: bool,
}

/// The §5.3 necessary condition for N sporadic imprecise tasks on an
/// intermittently-powered system with predictability η.
pub fn analyze(tasks: &[&TaskSpec], eta: f64) -> Schedulability {
    let mf: f64 = if tasks.is_empty() {
        1.0
    } else {
        tasks.iter().map(|t| mandatory_fraction(t)).sum::<f64>() / tasks.len() as f64
    };
    let u = utilization(tasks, mf);
    let ce = expected_outage_events(eta);
    let feasible = u < 1.0;
    let min_t_e = if feasible { ce / (1.0 - u) } else { f64::INFINITY };
    Schedulability {
        utilization: u,
        expected_outage: ce,
        min_energy_period: min_t_e,
        feasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::trace::{SampleTrace, UnitOutcome};
    use std::sync::Arc;

    fn spec(period: f64, unit_ms: Vec<f64>, exit_units: &[usize]) -> TaskSpec {
        let n = unit_ms.len();
        let traces = exit_units
            .iter()
            .map(|&e| SampleTrace {
                label: 0,
                units: (0..n)
                    .map(|i| UnitOutcome { gap: 0.0, pred: 0, exit: i == e, correct: true })
                    .collect(),
                exit_unit: e,
                oracle_unit: None,
            })
            .collect();
        TaskSpec {
            id: 0,
            name: "t".into(),
            period_ms: period,
            deadline_ms: period,
            unit_energy_mj: vec![1.0; n],
            unit_fragments: vec![1; n],
            unit_time_ms: unit_ms,
            release_energy_mj: 0.0,
            unit_state_bytes: vec![2048; n],
            traces: Arc::new(traces),
            imprecise: true,
        }
    }

    #[test]
    fn mandatory_fraction_from_traces() {
        // 2 units of 50 ms each; half the samples exit at unit 0, half at 1.
        let t = spec(1000.0, vec![50.0, 50.0], &[0, 1]);
        let mf = mandatory_fraction(&t);
        assert!((mf - 0.75).abs() < 1e-12); // (0.5 + 1.0) / 2
    }

    #[test]
    fn utilization_scales_with_mandatory_fraction() {
        let t = spec(200.0, vec![50.0, 50.0], &[0]);
        assert!((utilization(&[&t], 1.0) - 0.5).abs() < 1e-12);
        assert!((utilization(&[&t], 0.5) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn min_energy_period_grows_with_eta_and_load() {
        let light = spec(1000.0, vec![100.0], &[0]);
        let a = analyze(&[&light], 0.5);
        let b = analyze(&[&light], 0.9);
        assert!(b.min_energy_period > a.min_energy_period);
        let heavy = spec(125.0, vec![100.0], &[0]);
        let c = analyze(&[&heavy], 0.5);
        assert!(c.min_energy_period > a.min_energy_period);
    }

    #[test]
    fn overload_is_infeasible() {
        let t = spec(50.0, vec![100.0], &[0]);
        let s = analyze(&[&t], 0.5);
        assert!(!s.feasible);
        assert!(s.min_energy_period.is_infinite());
    }

    #[test]
    fn persistent_power_needs_no_energy_slack() {
        let t = spec(1000.0, vec![100.0], &[0]);
        let s = analyze(&[&t], 0.0);
        assert_eq!(s.expected_outage, 0.0);
        assert_eq!(s.min_energy_period, 0.0);
    }
}
