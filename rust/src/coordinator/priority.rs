//! The priority functions ζ (Eq. 6) and ζ_I (Eq. 7).
//!
//!   ζ  = (1 − α(d − t_c)) + (1 − βΨ) + γ
//!
//! term 1: tighter remaining deadline ⇒ higher priority;
//! term 2: lower utility Ψ (less confident classification) ⇒ higher
//!         priority — uncertain jobs need more computation;
//! term 3: γ = 1 if the unit under consideration is mandatory.
//!
//!   ζ_I = ζ                           when η·E_curr ≥ E_opt
//!       = γ·(term1 + term2)           when η·E_curr <  E_opt
//!
//! i.e. under energy pressure only mandatory units score, and optional
//! units score exactly 0 (never selected while any mandatory unit exists,
//! and not selected at all by the engine's optional gate).

use super::task::Job;

/// Scaling parameters: α, β are "the inverse of the maximum deadline and
/// utility, respectively" (paper §5.1).
#[derive(Clone, Copy, Debug)]
pub struct PriorityParams {
    pub alpha: f64,
    pub beta: f64,
}

impl PriorityParams {
    pub fn new(max_deadline_ms: f64, max_utility: f64) -> Self {
        PriorityParams {
            alpha: 1.0 / max_deadline_ms.max(1e-9),
            beta: 1.0 / max_utility.max(1e-9),
        }
    }
}

/// Scheduler-visible energy state (supplied by the EnergyManager).
#[derive(Clone, Copy, Debug)]
pub struct EnergyView {
    pub e_curr_mj: f64,
    pub e_opt_mj: f64,
    pub e_man_mj: f64,
    pub eta: f64,
}

impl EnergyView {
    /// Persistent-power view (η = 1, storage unbounded).
    pub fn persistent() -> Self {
        EnergyView { e_curr_mj: f64::MAX, e_opt_mj: 0.0, e_man_mj: 0.0, eta: 1.0 }
    }

    pub fn optional_allowed(&self) -> bool {
        self.eta * self.e_curr_mj >= self.e_opt_mj
    }
}

/// Eq. 6 for the job's next unit at scheduler-believed time `t_c`.
pub fn zeta(job: &Job, t_c_ms: f64, p: PriorityParams) -> f64 {
    let term_deadline = 1.0 - p.alpha * (job.deadline_ms - t_c_ms);
    let term_utility = 1.0 - p.beta * job.utility as f64;
    let gamma = job.next_is_mandatory() as u8 as f64;
    term_deadline + term_utility + gamma
}

/// Eq. 7.
pub fn zeta_intermittent(job: &Job, t_c_ms: f64, p: PriorityParams, e: &EnergyView) -> f64 {
    let term_deadline = 1.0 - p.alpha * (job.deadline_ms - t_c_ms);
    let term_utility = 1.0 - p.beta * job.utility as f64;
    let gamma = job.next_is_mandatory() as u8 as f64;
    if e.optional_allowed() {
        term_deadline + term_utility + gamma
    } else {
        gamma * (term_deadline + term_utility)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Job, JobState, TaskSpec};
    use std::sync::Arc;

    fn job(deadline: f64, utility: f32, mandatory: bool) -> Job {
        let spec = TaskSpec {
            id: 0,
            name: "t".into(),
            period_ms: 100.0,
            deadline_ms: deadline,
            unit_time_ms: vec![10.0],
            unit_energy_mj: vec![1.0],
            unit_fragments: vec![1],
            release_energy_mj: 0.0,
            unit_state_bytes: vec![2048],
            traces: Arc::new(vec![]),
            imprecise: true,
        };
        let mut j = Job::new(&spec, 0, 0.0, 0);
        j.utility = utility;
        if !mandatory {
            j.state = JobState::Optional;
        }
        j
    }

    const P: PriorityParams = PriorityParams { alpha: 1.0 / 1000.0, beta: 1.0 / 10.0 };

    #[test]
    fn tighter_deadline_wins() {
        let tight = job(100.0, 5.0, true);
        let loose = job(900.0, 5.0, true);
        assert!(zeta(&tight, 0.0, P) > zeta(&loose, 0.0, P));
    }

    #[test]
    fn lower_utility_wins() {
        let unsure = job(500.0, 1.0, true);
        let confident = job(500.0, 9.0, true);
        assert!(zeta(&unsure, 0.0, P) > zeta(&confident, 0.0, P));
    }

    #[test]
    fn mandatory_beats_optional() {
        let m = job(500.0, 5.0, true);
        let o = job(500.0, 5.0, false);
        assert!(zeta(&m, 0.0, P) > zeta(&o, 0.0, P));
        // γ bonus (1.0) dominates any in-range utility/deadline spread here.
        assert!((zeta(&m, 0.0, P) - zeta(&o, 0.0, P) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn priority_rises_as_time_passes() {
        let j = job(500.0, 5.0, true);
        assert!(zeta(&j, 400.0, P) > zeta(&j, 0.0, P));
    }

    #[test]
    fn zeta_i_zeroes_optional_under_pressure() {
        let o = job(500.0, 5.0, false);
        let m = job(500.0, 5.0, true);
        let starved = EnergyView { e_curr_mj: 10.0, e_opt_mj: 100.0, e_man_mj: 0.1, eta: 0.5 };
        assert_eq!(zeta_intermittent(&o, 0.0, P, &starved), 0.0);
        assert!(zeta_intermittent(&m, 0.0, P, &starved) > 0.0);
        // With plentiful predictable energy ζ_I == ζ.
        let rich = EnergyView { e_curr_mj: 1000.0, e_opt_mj: 100.0, e_man_mj: 0.1, eta: 0.9 };
        assert_eq!(zeta_intermittent(&o, 0.0, P, &rich), zeta(&o, 0.0, P));
    }

    #[test]
    fn eta_gates_like_paper_cases() {
        // (a) predictable + keeping charged; (b) medium-predictable + more
        // than sufficient energy -> optional allowed.
        let a = EnergyView { e_curr_mj: 100.0, e_opt_mj: 90.0, e_man_mj: 0.1, eta: 0.95 };
        assert!(a.optional_allowed());
        let b = EnergyView { e_curr_mj: 200.0, e_opt_mj: 90.0, e_man_mj: 0.1, eta: 0.5 };
        assert!(b.optional_allowed());
        // unpredictable, or predictable-but-insufficient -> blocked.
        let c = EnergyView { e_curr_mj: 100.0, e_opt_mj: 90.0, e_man_mj: 0.1, eta: 0.1 };
        assert!(!c.optional_allowed());
        let d = EnergyView { e_curr_mj: 50.0, e_opt_mj: 90.0, e_man_mj: 0.1, eta: 0.95 };
        assert!(!d.optional_allowed());
    }
}
