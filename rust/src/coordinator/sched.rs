//! Online schedulers: Zygarde (ζ_I), EDF, EDF-M, and round-robin.
//!
//! All schedulers run under *limited preemption* (paper §4.1): the engine
//! invokes `pick` only at unit boundaries and at deadlines, and the chosen
//! job executes exactly one unit (fragment-by-fragment) before returning
//! to the queue.
//!
//! Early-termination policy is orthogonal to the picking order (the paper
//! evaluates EDF without early exit, EDF-M and Zygarde with the utility
//! test, and an oracle policy in Fig. 16), so it is a separate enum the
//! engine applies when a unit completes.

use super::priority::{zeta_intermittent, EnergyView, PriorityParams};
use super::task::Job;

/// What ends a job early (applied by the engine at unit completion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExitPolicy {
    /// Run every unit (SONIC-style full execution).
    None,
    /// Exit once the utility test passes AND the scheduler decides not to
    /// run optional units (Zygarde / EDF-M behaviour).
    Utility,
    /// Exit at the earliest unit whose prediction is already correct
    /// (Fig. 16's oracle; needs ground truth).
    Oracle,
}

impl ExitPolicy {
    pub fn name(self) -> &'static str {
        match self {
            ExitPolicy::None => "none",
            ExitPolicy::Utility => "utility",
            ExitPolicy::Oracle => "oracle",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    Zygarde,
    Edf,
    /// EDF over mandatory parts only: optional units are never executed.
    EdfMandatory,
    /// Task-round-robin, *non-preemptive*: the picked job runs to
    /// completion before the cursor advances (SONIC-RR baseline — SONIC
    /// has no unit-boundary preemption).
    RoundRobin,
}

impl SchedulerKind {
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Zygarde => "zygarde",
            SchedulerKind::Edf => "edf",
            SchedulerKind::EdfMandatory => "edf-m",
            SchedulerKind::RoundRobin => "rr",
        }
    }

    /// Default exit policy the paper pairs with each scheduler (§8.5).
    pub fn default_exit(self) -> ExitPolicy {
        match self {
            SchedulerKind::Zygarde | SchedulerKind::EdfMandatory => ExitPolicy::Utility,
            SchedulerKind::Edf | SchedulerKind::RoundRobin => ExitPolicy::None,
        }
    }
}

/// Scheduler state (round-robin cursor; ζ parameters).
#[derive(Clone, Debug)]
pub struct Scheduler {
    pub kind: SchedulerKind,
    pub params: PriorityParams,
    rr_cursor: usize,
    /// RR's in-flight job (non-preemptive execution).
    rr_current: Option<u64>,
}

impl Scheduler {
    pub fn new(kind: SchedulerKind, params: PriorityParams) -> Self {
        Scheduler { kind, params, rr_cursor: 0, rr_current: None }
    }

    /// Choose the queue index of the job whose next unit should run, or
    /// None if nothing is eligible (e.g. only optional units under energy
    /// pressure). `now_ms` is the *scheduler-believed* time.
    pub fn pick(&mut self, queue: &[Job], now_ms: f64, energy: &EnergyView) -> Option<usize> {
        if queue.is_empty() {
            return None;
        }
        match self.kind {
            SchedulerKind::Zygarde => {
                let mut best: Option<(usize, f64)> = None;
                for (i, j) in queue.iter().enumerate() {
                    if j.finished() {
                        continue;
                    }
                    // Under energy pressure optional units are ineligible
                    // (their ζ_I is 0; treat as unschedulable, not merely
                    // lowest — matches Table 2's reasoning at t2).
                    if !j.next_is_mandatory() && !energy.optional_allowed() {
                        continue;
                    }
                    let z = zeta_intermittent(j, now_ms, self.params, energy);
                    if best.map(|(_, bz)| z > bz).unwrap_or(true) {
                        best = Some((i, z));
                    }
                }
                best.map(|(i, _)| i)
            }
            SchedulerKind::Edf => queue
                .iter()
                .enumerate()
                .filter(|(_, j)| !j.finished())
                .min_by(|(_, a), (_, b)| a.deadline_ms.partial_cmp(&b.deadline_ms).unwrap())
                .map(|(i, _)| i),
            SchedulerKind::EdfMandatory => queue
                .iter()
                .enumerate()
                .filter(|(_, j)| !j.finished() && j.next_is_mandatory())
                .min_by(|(_, a), (_, b)| a.deadline_ms.partial_cmp(&b.deadline_ms).unwrap())
                .map(|(i, _)| i),
            SchedulerKind::RoundRobin => {
                // Non-preemptive: finish the in-flight job first.
                if let Some(id) = self.rr_current {
                    if let Some(i) =
                        queue.iter().position(|j| j.id == id && !j.finished())
                    {
                        return Some(i);
                    }
                    self.rr_current = None;
                }
                // Rotate over task ids; within a task, oldest job first.
                let tasks: Vec<usize> = {
                    let mut t: Vec<usize> =
                        queue.iter().filter(|j| !j.finished()).map(|j| j.task).collect();
                    t.sort_unstable();
                    t.dedup();
                    t
                };
                if tasks.is_empty() {
                    return None;
                }
                let task = tasks[self.rr_cursor % tasks.len()];
                self.rr_cursor = self.rr_cursor.wrapping_add(1);
                let pick = queue
                    .iter()
                    .enumerate()
                    .filter(|(_, j)| !j.finished() && j.task == task)
                    .min_by(|(_, a), (_, b)| {
                        a.release_ms.partial_cmp(&b.release_ms).unwrap()
                    })
                    .map(|(i, _)| i);
                self.rr_current = pick.map(|i| queue[i].id);
                pick
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Job, JobState, TaskSpec};
    use std::sync::Arc;

    fn spec(id: usize) -> TaskSpec {
        TaskSpec {
            id,
            name: format!("t{id}"),
            period_ms: 100.0,
            deadline_ms: 1000.0,
            unit_time_ms: vec![10.0, 10.0],
            unit_energy_mj: vec![1.0, 1.0],
            unit_fragments: vec![1, 1],
            release_energy_mj: 0.0,
            unit_state_bytes: vec![2048; 2],
            traces: Arc::new(vec![]),
            imprecise: true,
        }
    }

    fn params() -> PriorityParams {
        PriorityParams::new(1000.0, 10.0)
    }

    #[test]
    fn edf_picks_earliest_deadline() {
        let s = spec(0);
        let mut q = vec![Job::new(&s, 0, 0.0, 0), Job::new(&s, 1, 0.0, 0)];
        q[1].deadline_ms = 10.0;
        let mut sch = Scheduler::new(SchedulerKind::Edf, params());
        assert_eq!(sch.pick(&q, 0.0, &EnergyView::persistent()), Some(1));
    }

    #[test]
    fn edfm_skips_optional_jobs() {
        let s = spec(0);
        let mut q = vec![Job::new(&s, 0, 0.0, 0), Job::new(&s, 1, 0.0, 0)];
        q[0].deadline_ms = 5.0;
        q[0].state = JobState::Optional; // confident already
        let mut sch = Scheduler::new(SchedulerKind::EdfMandatory, params());
        assert_eq!(sch.pick(&q, 0.0, &EnergyView::persistent()), Some(1));
        q[1].state = JobState::Optional;
        assert_eq!(sch.pick(&q, 0.0, &EnergyView::persistent()), None);
    }

    #[test]
    fn zygarde_prefers_mandatory_then_tight_deadline() {
        let s = spec(0);
        let mut q = vec![Job::new(&s, 0, 0.0, 0), Job::new(&s, 1, 0.0, 0), Job::new(&s, 2, 0.0, 0)];
        q[0].state = JobState::Optional;
        q[0].deadline_ms = 5.0; // tightest but optional
        q[1].deadline_ms = 500.0;
        q[2].deadline_ms = 100.0;
        let mut sch = Scheduler::new(SchedulerKind::Zygarde, params());
        // plentiful energy: mandatory γ bonus still wins over optional
        assert_eq!(sch.pick(&q, 0.0, &EnergyView::persistent()), Some(2));
    }

    #[test]
    fn zygarde_blocks_optional_under_pressure() {
        let s = spec(0);
        let mut q = vec![Job::new(&s, 0, 0.0, 0)];
        q[0].state = JobState::Optional;
        let starved = EnergyView { e_curr_mj: 1.0, e_opt_mj: 100.0, e_man_mj: 0.01, eta: 0.4 };
        let mut sch = Scheduler::new(SchedulerKind::Zygarde, params());
        assert_eq!(sch.pick(&q, 0.0, &starved), None);
        let rich = EnergyView { e_curr_mj: 1000.0, e_opt_mj: 100.0, e_man_mj: 0.01, eta: 0.9 };
        assert_eq!(sch.pick(&q, 0.0, &rich), Some(0));
    }

    #[test]
    fn zygarde_picks_tighter_deadline_among_optionals() {
        // Table 2, t6: only optional jobs remain and energy is plentiful —
        // the tighter deadline wins.
        let s = spec(0);
        let mut q = vec![Job::new(&s, 0, 0.0, 0), Job::new(&s, 1, 0.0, 0)];
        q[0].state = JobState::Optional;
        q[0].deadline_ms = 900.0;
        q[1].state = JobState::Optional;
        q[1].deadline_ms = 200.0;
        let mut sch = Scheduler::new(SchedulerKind::Zygarde, params());
        assert_eq!(sch.pick(&q, 0.0, &EnergyView::persistent()), Some(1));
    }

    #[test]
    fn round_robin_is_non_preemptive_then_rotates() {
        let s0 = spec(0);
        let s1 = spec(1);
        let mut q = vec![Job::new(&s0, 0, 0.0, 0), Job::new(&s1, 1, 1.0, 0)];
        let mut sch = Scheduler::new(SchedulerKind::RoundRobin, params());
        let a = sch.pick(&q, 0.0, &EnergyView::persistent()).unwrap();
        // SONIC-style: sticks with the same job until it completes.
        let b = sch.pick(&q, 0.0, &EnergyView::persistent()).unwrap();
        assert_eq!(a, b);
        // Once the job finishes, the cursor rotates to the other task.
        q[a].state = JobState::Exhausted;
        let c = sch.pick(&q, 0.0, &EnergyView::persistent()).unwrap();
        assert_ne!(q[a].task, q[c].task);
    }

    #[test]
    fn empty_queue_yields_none() {
        let mut sch = Scheduler::new(SchedulerKind::Zygarde, params());
        assert_eq!(sch.pick(&[], 0.0, &EnergyView::persistent()), None);
    }
}
