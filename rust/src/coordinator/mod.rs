//! The Zygarde coordinator (paper §4–5): imprecise sporadic task model,
//! dynamic mandatory/optional partitioning, the priority functions ζ
//! (Eq. 6) and ζ_I (Eq. 7), the online schedulers (Zygarde, EDF, EDF-M,
//! RR), and the schedulability analysis of §5.3.

pub mod analysis;
pub mod priority;
pub mod sched;
pub mod task;

pub use priority::{zeta, zeta_intermittent, EnergyView, PriorityParams};
pub use sched::{ExitPolicy, Scheduler, SchedulerKind};
pub use task::{Job, JobState, TaskSpec};
