//! Imprecise sporadic tasks, jobs, units, and fragments (paper §4.1).
//!
//! A *task* τ_i = (T_i, D_i, C_i) is the recurring processing of one
//! sensor stream for one classification problem. A *job* is one instance:
//! an ordered sequence of *units* (one DNN layer + its k-means classifier
//! each), where the first M units are mandatory and M is discovered at
//! runtime by the utility test. Units split into fixed-budget atomic
//! *fragments* (SONIC-style) — the granularity of intermittent execution.

use std::sync::Arc;

use crate::dnn::trace::SampleTrace;

/// Static description of one task. Unit costs come from the compile-time
/// cost model (`meta.json`); traces supply the data-dependent behaviour.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub id: usize,
    pub name: String,
    /// Minimum inter-release separation T_i (ms).
    pub period_ms: f64,
    /// Relative deadline D_i (ms).
    pub deadline_ms: f64,
    pub unit_time_ms: Vec<f64>,
    pub unit_energy_mj: Vec<f64>,
    pub unit_fragments: Vec<usize>,
    /// Sensor read + feature extraction cost at release (DMA/LEA path:
    /// consumes energy but not CPU time; paper Fig. 14 job generator).
    pub release_energy_mj: f64,
    /// Per-sample unit traces this task's jobs sample from.
    pub traces: Arc<Vec<SampleTrace>>,
    /// Non-imprecise task support (paper §5.1): if false, every unit is
    /// mandatory and Ψ is a constant.
    pub imprecise: bool,
}

impl TaskSpec {
    pub fn n_units(&self) -> usize {
        self.unit_time_ms.len()
    }

    /// Worst-case execution time of the whole job (all units).
    pub fn wcet_ms(&self) -> f64 {
        self.unit_time_ms.iter().sum()
    }

    pub fn fragment_time_ms(&self, unit: usize) -> f64 {
        self.unit_time_ms[unit] / self.unit_fragments[unit] as f64
    }

    pub fn fragment_energy_mj(&self, unit: usize) -> f64 {
        self.unit_energy_mj[unit] / self.unit_fragments[unit] as f64
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// More mandatory units to run (utility test has not passed yet).
    Mandatory,
    /// Utility test passed: remaining units are optional refinements.
    Optional,
    /// All units executed.
    Exhausted,
}

/// One job instance in the queue.
#[derive(Clone, Debug)]
pub struct Job {
    pub task: usize,
    pub id: u64,
    pub release_ms: f64,
    /// Absolute deadline (release + D_i).
    pub deadline_ms: f64,
    /// Index into the task's trace set (the data sample).
    pub trace_idx: usize,
    /// Next unit to execute.
    pub next_unit: usize,
    /// Fragments completed within the current unit.
    pub fragments_done: usize,
    pub state: JobState,
    /// Utility score Ψ of the last completed unit (0 before any unit —
    /// a brand-new job is maximally uncertain).
    pub utility: f32,
    /// Latest prediction (valid once ≥ 1 unit completed).
    pub pred: Option<i32>,
    /// True once the mandatory part finished before the deadline.
    pub mandatory_done: bool,
    /// Completion time of the mandatory part, if any.
    pub mandatory_done_at: Option<f64>,
    pub units_done: usize,
}

impl Job {
    pub fn new(task: &TaskSpec, id: u64, release_ms: f64, trace_idx: usize) -> Job {
        Job {
            task: task.id,
            id,
            release_ms,
            deadline_ms: release_ms + task.deadline_ms,
            trace_idx,
            next_unit: 0,
            fragments_done: 0,
            state: JobState::Mandatory,
            utility: 0.0,
            pred: None,
            mandatory_done: false,
            mandatory_done_at: None,
            units_done: 0,
        }
    }

    /// Is the *next* unit mandatory (γ = 1 in Eq. 6/7)?
    pub fn next_is_mandatory(&self) -> bool {
        self.state == JobState::Mandatory
    }

    pub fn finished(&self) -> bool {
        self.state == JobState::Exhausted
    }

    /// Record completion of the current unit using the sample's trace.
    /// `n_units` is the task's unit count. Returns true if the job just
    /// became confident (utility test passed at this unit).
    pub fn complete_unit(&mut self, trace: &SampleTrace, n_units: usize, now_ms: f64) -> bool {
        let u = self.next_unit;
        let outcome = &trace.units[u];
        self.units_done += 1;
        self.utility = outcome.gap;
        self.pred = Some(outcome.pred);
        self.fragments_done = 0;
        self.next_unit += 1;
        let mut just_confident = false;
        if self.state == JobState::Mandatory && outcome.exit {
            self.state = JobState::Optional;
            self.mandatory_done = true;
            self.mandatory_done_at = Some(now_ms);
            just_confident = true;
        }
        if self.next_unit >= n_units {
            if self.state == JobState::Mandatory {
                // Ran every unit without a confident exit: the full job IS
                // the mandatory part (the partition degenerates, §4.1).
                self.mandatory_done = true;
                self.mandatory_done_at = Some(now_ms);
            }
            self.state = JobState::Exhausted;
        }
        just_confident
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::trace::{SampleTrace, UnitOutcome};

    fn trace(exits: &[bool]) -> SampleTrace {
        let units = exits
            .iter()
            .map(|&e| UnitOutcome { gap: if e { 9.0 } else { 0.5 }, pred: 1, exit: e, correct: true })
            .collect::<Vec<_>>();
        let exit_unit = exits.iter().position(|&e| e).unwrap_or(exits.len() - 1);
        SampleTrace { label: 1, units, exit_unit, oracle_unit: Some(0) }
    }

    fn spec(n_units: usize) -> TaskSpec {
        TaskSpec {
            id: 0,
            name: "t".into(),
            period_ms: 1000.0,
            deadline_ms: 2000.0,
            unit_time_ms: vec![100.0; n_units],
            unit_energy_mj: vec![1.0; n_units],
            unit_fragments: vec![4; n_units],
            release_energy_mj: 0.5,
            traces: Arc::new(vec![]),
            imprecise: true,
        }
    }

    #[test]
    fn dynamic_partition_via_utility() {
        let s = spec(4);
        let t = trace(&[false, true, false, false]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        assert!(j.next_is_mandatory());
        assert!(!j.complete_unit(&t, 4, 100.0)); // unit 0: no exit
        assert!(j.next_is_mandatory());
        assert!(!j.mandatory_done);
        assert!(j.complete_unit(&t, 4, 200.0)); // unit 1: exit
        assert!(!j.next_is_mandatory());
        assert!(j.mandatory_done);
        assert_eq!(j.mandatory_done_at, Some(200.0));
        assert_eq!(j.state, JobState::Optional);
        j.complete_unit(&t, 4, 300.0);
        j.complete_unit(&t, 4, 400.0);
        assert!(j.finished());
    }

    #[test]
    fn never_confident_job_is_all_mandatory() {
        let s = spec(3);
        let t = trace(&[false, false, false]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        j.complete_unit(&t, 3, 1.0);
        j.complete_unit(&t, 3, 2.0);
        assert!(!j.mandatory_done);
        j.complete_unit(&t, 3, 3.0);
        assert!(j.mandatory_done); // degenerate partition: M = L
        assert!(j.finished());
    }

    #[test]
    fn wcet_and_fragment_costs() {
        let s = spec(4);
        assert_eq!(s.wcet_ms(), 400.0);
        assert_eq!(s.fragment_time_ms(0), 25.0);
        assert_eq!(s.fragment_energy_mj(0), 0.25);
    }

    #[test]
    fn utility_tracks_last_unit() {
        let s = spec(2);
        let t = trace(&[false, true]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        assert_eq!(j.utility, 0.0);
        j.complete_unit(&t, 2, 1.0);
        assert_eq!(j.utility, 0.5);
        j.complete_unit(&t, 2, 2.0);
        assert_eq!(j.utility, 9.0);
    }
}
