//! Imprecise sporadic tasks, jobs, units, and fragments (paper §4.1).
//!
//! A *task* τ_i = (T_i, D_i, C_i) is the recurring processing of one
//! sensor stream for one classification problem. A *job* is one instance:
//! an ordered sequence of *units* (one DNN layer + its k-means classifier
//! each), where the first M units are mandatory and M is discovered at
//! runtime by the utility test. Units split into fixed-budget atomic
//! *fragments* (SONIC-style) — the granularity of intermittent execution.

use std::sync::Arc;

use crate::dnn::trace::SampleTrace;

/// Static description of one task. Unit costs come from the compile-time
/// cost model (`meta.json`); traces supply the data-dependent behaviour.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub id: usize,
    pub name: String,
    /// Minimum inter-release separation T_i (ms).
    pub period_ms: f64,
    /// Relative deadline D_i (ms).
    pub deadline_ms: f64,
    pub unit_time_ms: Vec<f64>,
    pub unit_energy_mj: Vec<f64>,
    pub unit_fragments: Vec<usize>,
    /// Sensor read + feature extraction cost at release (DMA/LEA path:
    /// consumes energy but not CPU time; paper Fig. 14 job generator).
    pub release_energy_mj: f64,
    /// Bytes of volatile working state a checkpoint at a fragment boundary
    /// of each unit must persist (the unit's activation buffer). Feeds the
    /// `nvm` commit/restore cost model; see [`TaskSpec::state_bytes`].
    pub unit_state_bytes: Vec<usize>,
    /// Per-sample unit traces this task's jobs sample from.
    pub traces: Arc<Vec<SampleTrace>>,
    /// Non-imprecise task support (paper §5.1): if false, every unit is
    /// mandatory and Ψ is a constant.
    pub imprecise: bool,
}

impl TaskSpec {
    pub fn n_units(&self) -> usize {
        self.unit_time_ms.len()
    }

    /// Worst-case execution time of the whole job (all units).
    pub fn wcet_ms(&self) -> f64 {
        self.unit_time_ms.iter().sum()
    }

    pub fn fragment_time_ms(&self, unit: usize) -> f64 {
        self.unit_time_ms[unit] / self.unit_fragments[unit] as f64
    }

    pub fn fragment_energy_mj(&self, unit: usize) -> f64 {
        self.unit_energy_mj[unit] / self.unit_fragments[unit] as f64
    }

    /// Checkpoint state size of `unit` (bytes); tasks that predate the
    /// NVM model (shorter or empty `unit_state_bytes`) fall back to
    /// [`DEFAULT_STATE_BYTES`].
    pub fn state_bytes(&self, unit: usize) -> usize {
        self.unit_state_bytes.get(unit).copied().unwrap_or(DEFAULT_STATE_BYTES)
    }
}

/// Fallback per-unit checkpoint size (a small activation buffer).
pub const DEFAULT_STATE_BYTES: usize = 2048;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// More mandatory units to run (utility test has not passed yet).
    Mandatory,
    /// Utility test passed: remaining units are optional refinements.
    Optional,
    /// All units executed.
    Exhausted,
}

/// One job's execution progress — every field that advances as fragments
/// and units complete. [`Job`] embeds it **twice**: once volatile (the
/// live SRAM state, reachable transparently through `Deref`) and once
/// committed (the durable rollback target), so checkpoint and rollback
/// are single struct assignments and a future progress field cannot
/// silently escape either path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Progress {
    /// Next unit to execute.
    pub next_unit: usize,
    /// Fragments completed within the current unit.
    pub fragments_done: usize,
    pub state: JobState,
    /// Utility score Ψ of the last completed unit (0 before any unit —
    /// a brand-new job is maximally uncertain).
    pub utility: f32,
    /// Latest prediction (valid once ≥ 1 unit completed).
    pub pred: Option<i32>,
    /// True once the mandatory part finished before the deadline.
    pub mandatory_done: bool,
    /// Completion time of the mandatory part, if any.
    pub mandatory_done_at: Option<f64>,
    pub units_done: usize,
}

/// The durable (committed-to-NVM) snapshot of a job's progress has the
/// same shape as the live progress — they are the same struct.
pub type JobCheckpoint = Progress;

impl Progress {
    /// A brand-new job: nothing executed, maximally uncertain.
    pub fn fresh() -> Progress {
        Progress {
            next_unit: 0,
            fragments_done: 0,
            state: JobState::Mandatory,
            utility: 0.0,
            pred: None,
            mandatory_done: false,
            mandatory_done_at: None,
            units_done: 0,
        }
    }

    /// Any progress worth restoring after a reboot?
    pub fn any(&self) -> bool {
        self.next_unit > 0 || self.fragments_done > 0 || self.units_done > 0
    }

    /// The unit whose activation buffer is live at this progress point:
    /// the executing unit mid-unit, or the just-completed unit at a
    /// boundary (its output is the next unit's input). This is the buffer
    /// a checkpoint must persist and a restore must read back.
    pub fn active_unit(&self, n_units: usize) -> usize {
        if self.fragments_done == 0 && self.next_unit > 0 {
            (self.next_unit - 1).min(n_units - 1)
        } else {
            self.next_unit.min(n_units.saturating_sub(1))
        }
    }

    /// Total fragment-granularity progress.
    pub fn progress_fragments(&self, spec: &TaskSpec) -> u64 {
        let done: usize = spec.unit_fragments.iter().take(self.next_unit).sum();
        (done + self.fragments_done) as u64
    }
}

/// One job instance in the queue. Progress fields (`next_unit`,
/// `fragments_done`, `state`, …) live in [`Job::progress`] and are read
/// and written through `Deref`/`DerefMut`, so `job.next_unit` keeps
/// working at every call site.
#[derive(Clone, Debug)]
pub struct Job {
    pub task: usize,
    pub id: u64,
    pub release_ms: f64,
    /// Absolute deadline (release + D_i).
    pub deadline_ms: f64,
    /// Index into the task's trace set (the data sample).
    pub trace_idx: usize,
    /// Volatile (SRAM) progress — what executes and what a power failure
    /// destroys.
    pub progress: Progress,
    /// Last committed (durable) progress; the rollback target on power
    /// failure. Maintained by the engine per its `CommitPolicy`.
    pub committed: Progress,
}

impl std::ops::Deref for Job {
    type Target = Progress;

    fn deref(&self) -> &Progress {
        &self.progress
    }
}

impl std::ops::DerefMut for Job {
    fn deref_mut(&mut self) -> &mut Progress {
        &mut self.progress
    }
}

impl Job {
    pub fn new(task: &TaskSpec, id: u64, release_ms: f64, trace_idx: usize) -> Job {
        Job {
            task: task.id,
            id,
            release_ms,
            deadline_ms: release_ms + task.deadline_ms,
            trace_idx,
            progress: Progress::fresh(),
            committed: Progress::fresh(),
        }
    }

    /// Snapshot the volatile progress (one struct copy).
    pub fn snapshot(&self) -> JobCheckpoint {
        self.progress
    }

    /// Make the current volatile progress durable (one struct assignment).
    pub fn checkpoint(&mut self) {
        self.committed = self.progress;
    }

    /// Volatile progress ahead of the last commit?
    pub fn is_dirty(&self) -> bool {
        self.progress != self.committed
    }

    /// Any durable progress worth restoring after a reboot?
    pub fn has_committed_progress(&self) -> bool {
        self.committed.any()
    }

    /// [`Progress::active_unit`] evaluated on the committed checkpoint
    /// (the volatile variant is reachable directly as `job.active_unit`).
    pub fn committed_active_unit(&self, n_units: usize) -> usize {
        self.committed.active_unit(n_units)
    }

    /// Total fragment-granularity progress of the committed state (the
    /// volatile variant is reachable directly as `job.progress_fragments`).
    pub fn committed_progress_fragments(&self, spec: &TaskSpec) -> u64 {
        self.committed.progress_fragments(spec)
    }

    /// Power failed: discard volatile progress, return to the last commit
    /// (one struct assignment — no field can be forgotten).
    /// Returns the number of completed-but-uncommitted fragments lost.
    pub fn rollback(&mut self, spec: &TaskSpec) -> u64 {
        let lost = self
            .progress
            .progress_fragments(spec)
            .saturating_sub(self.committed.progress_fragments(spec));
        self.progress = self.committed;
        lost
    }

    /// Is the *next* unit mandatory (γ = 1 in Eq. 6/7)?
    pub fn next_is_mandatory(&self) -> bool {
        self.state == JobState::Mandatory
    }

    pub fn finished(&self) -> bool {
        self.state == JobState::Exhausted
    }

    /// Record completion of the current unit using the sample's trace.
    /// `n_units` is the task's unit count. Returns true if the job just
    /// became confident (utility test passed at this unit).
    pub fn complete_unit(&mut self, trace: &SampleTrace, n_units: usize, now_ms: f64) -> bool {
        let u = self.next_unit;
        let outcome = &trace.units[u];
        self.units_done += 1;
        self.utility = outcome.gap;
        self.pred = Some(outcome.pred);
        self.fragments_done = 0;
        self.next_unit += 1;
        let mut just_confident = false;
        if self.state == JobState::Mandatory && outcome.exit {
            self.state = JobState::Optional;
            self.mandatory_done = true;
            self.mandatory_done_at = Some(now_ms);
            just_confident = true;
        }
        if self.next_unit >= n_units {
            if self.state == JobState::Mandatory {
                // Ran every unit without a confident exit: the full job IS
                // the mandatory part (the partition degenerates, §4.1).
                self.mandatory_done = true;
                self.mandatory_done_at = Some(now_ms);
            }
            self.state = JobState::Exhausted;
        }
        just_confident
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::trace::{SampleTrace, UnitOutcome};

    fn trace(exits: &[bool]) -> SampleTrace {
        let units = exits
            .iter()
            .map(|&e| UnitOutcome { gap: if e { 9.0 } else { 0.5 }, pred: 1, exit: e, correct: true })
            .collect::<Vec<_>>();
        let exit_unit = exits.iter().position(|&e| e).unwrap_or(exits.len() - 1);
        SampleTrace { label: 1, units, exit_unit, oracle_unit: Some(0) }
    }

    fn spec(n_units: usize) -> TaskSpec {
        TaskSpec {
            id: 0,
            name: "t".into(),
            period_ms: 1000.0,
            deadline_ms: 2000.0,
            unit_time_ms: vec![100.0; n_units],
            unit_energy_mj: vec![1.0; n_units],
            unit_fragments: vec![4; n_units],
            release_energy_mj: 0.5,
            unit_state_bytes: vec![2048; n_units],
            traces: Arc::new(vec![]),
            imprecise: true,
        }
    }

    #[test]
    fn dynamic_partition_via_utility() {
        let s = spec(4);
        let t = trace(&[false, true, false, false]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        assert!(j.next_is_mandatory());
        assert!(!j.complete_unit(&t, 4, 100.0)); // unit 0: no exit
        assert!(j.next_is_mandatory());
        assert!(!j.mandatory_done);
        assert!(j.complete_unit(&t, 4, 200.0)); // unit 1: exit
        assert!(!j.next_is_mandatory());
        assert!(j.mandatory_done);
        assert_eq!(j.mandatory_done_at, Some(200.0));
        assert_eq!(j.state, JobState::Optional);
        j.complete_unit(&t, 4, 300.0);
        j.complete_unit(&t, 4, 400.0);
        assert!(j.finished());
    }

    #[test]
    fn never_confident_job_is_all_mandatory() {
        let s = spec(3);
        let t = trace(&[false, false, false]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        j.complete_unit(&t, 3, 1.0);
        j.complete_unit(&t, 3, 2.0);
        assert!(!j.mandatory_done);
        j.complete_unit(&t, 3, 3.0);
        assert!(j.mandatory_done); // degenerate partition: M = L
        assert!(j.finished());
    }

    #[test]
    fn wcet_and_fragment_costs() {
        let s = spec(4);
        assert_eq!(s.wcet_ms(), 400.0);
        assert_eq!(s.fragment_time_ms(0), 25.0);
        assert_eq!(s.fragment_energy_mj(0), 0.25);
    }

    #[test]
    fn state_bytes_falls_back_when_undeclared() {
        let mut s = spec(3);
        assert_eq!(s.state_bytes(1), 2048);
        s.unit_state_bytes = vec![100];
        assert_eq!(s.state_bytes(0), 100);
        assert_eq!(s.state_bytes(2), DEFAULT_STATE_BYTES);
    }

    #[test]
    fn checkpoint_and_rollback_restore_committed_progress() {
        let s = spec(3); // 3 units x 4 fragments
        let t = trace(&[false, true, false]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        assert!(!j.is_dirty());
        assert!(!j.has_committed_progress());

        // Two fragments of unit 0, volatile.
        j.fragments_done = 2;
        assert!(j.is_dirty());
        assert_eq!(j.progress_fragments(&s), 2);
        assert_eq!(j.committed_progress_fragments(&s), 0);
        assert_eq!(j.rollback(&s), 2);
        assert_eq!(j.fragments_done, 0);
        assert!(!j.is_dirty());

        // Complete unit 0 and commit at the boundary.
        j.fragments_done = 4;
        j.complete_unit(&t, 3, 10.0); // resets fragments_done, next_unit=1
        j.checkpoint();
        assert!(j.has_committed_progress());
        assert_eq!(j.committed_progress_fragments(&s), 4);

        // Complete unit 1 (confident exit) but do NOT commit: a power
        // failure rolls the confidence back too.
        j.fragments_done = 4;
        j.complete_unit(&t, 3, 20.0);
        assert!(j.mandatory_done);
        assert_eq!(j.progress_fragments(&s), 8);
        assert_eq!(j.rollback(&s), 4);
        assert!(!j.mandatory_done);
        assert_eq!(j.state, JobState::Mandatory);
        assert_eq!(j.next_unit, 1);
        assert_eq!(j.units_done, 1);
    }

    #[test]
    fn active_unit_tracks_the_live_buffer() {
        let s = spec(3);
        let t = trace(&[false, true, false]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        assert_eq!(j.active_unit(3), 0); // fresh: unit 0's input buffer
        j.fragments_done = 2;
        assert_eq!(j.active_unit(3), 0); // mid-unit 0
        j.fragments_done = 4;
        j.complete_unit(&t, 3, 1.0);
        // Boundary: unit 0's output is what lives in SRAM, even though
        // next_unit already points at unit 1.
        assert_eq!(j.next_unit, 1);
        assert_eq!(j.active_unit(3), 0);
        j.fragments_done = 1; // executing unit 1 now
        assert_eq!(j.active_unit(3), 1);
        j.checkpoint();
        assert_eq!(j.committed_active_unit(3), 1);
    }

    #[test]
    fn rollback_and_checkpoint_are_whole_struct_assignments() {
        let s = spec(3);
        let t = trace(&[false, true, false]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        assert_eq!(j.progress, Progress::fresh());
        j.fragments_done = 4;
        j.complete_unit(&t, 3, 10.0);
        j.checkpoint();
        assert_eq!(j.progress, j.committed, "checkpoint copies every field");
        j.fragments_done = 2;
        j.utility = 3.5;
        assert!(j.is_dirty());
        j.rollback(&s);
        assert_eq!(j.progress, j.committed, "rollback restores every field");
        assert_eq!(j.snapshot(), j.committed);
        assert_eq!(j.utility, 0.5, "utility rolled back with the rest");
    }

    #[test]
    fn utility_tracks_last_unit() {
        let s = spec(2);
        let t = trace(&[false, true]);
        let mut j = Job::new(&s, 0, 0.0, 0);
        assert_eq!(j.utility, 0.0);
        j.complete_unit(&t, 2, 1.0);
        assert_eq!(j.utility, 0.5);
        j.complete_unit(&t, 2, 2.0);
        assert_eq!(j.utility, 9.0);
    }
}
