//! Seeded property-testing harness (proptest is not available offline).
//!
//! `forall` runs a property over `iters` generated cases. On failure it
//! retries the failing case against progressively "shrunk" variants
//! produced by the generator at smaller size hints, then reports the seed
//! and case so the failure is reproducible with `PROP_SEED=<n>`.

use super::rng::Pcg32;

/// Size hint passed to generators: starts small and grows, so early
/// iterations explore degenerate cases (empty queues, single jobs).
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

pub struct Config {
    pub iters: usize,
    pub seed: u64,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5eed);
        Config { iters: 256, seed, max_size: 64 }
    }
}

/// Run `prop` over `iters` cases from `gen`. Panics with a reproducible
/// report on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Pcg32, Size) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..cfg.iters {
        // Per-case stream so a failure reproduces independently of order.
        let mut rng = Pcg32::new(cfg.seed, i as u64);
        let size = Size(1 + (i * cfg.max_size) / cfg.iters.max(1));
        let case = gen(&mut rng, size);
        if let Err(msg) = prop(&case) {
            // Shrink attempt: regenerate at smaller sizes from the same
            // stream seed and keep the smallest still-failing case.
            let mut smallest: Option<(usize, T, String)> = None;
            for s in (1..size.0).rev() {
                let mut r2 = Pcg32::new(cfg.seed, i as u64);
                let c2 = gen(&mut r2, Size(s));
                if let Err(m2) = prop(&c2) {
                    smallest = Some((s, c2, m2));
                }
            }
            match smallest {
                Some((s, c2, m2)) => panic!(
                    "property `{name}` failed (seed={} case={} shrunk to size {s}):\n  {m2}\n  case: {c2:#?}",
                    cfg.seed, i
                ),
                None => panic!(
                    "property `{name}` failed (seed={} case={} size={}):\n  {msg}\n  case: {case:#?}",
                    cfg.seed, i, size.0
                ),
            }
        }
    }
}

/// Common generator: a vec of f64 in [lo, hi) with size-driven length.
pub fn vec_f64(rng: &mut Pcg32, size: Size, lo: f64, hi: f64) -> Vec<f64> {
    let n = rng.below(size.0 as u64 + 1) as usize;
    (0..n).map(|_| rng.range_f64(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(
            "reverse-reverse-identity",
            Config { iters: 64, ..Default::default() },
            |rng, size| vec_f64(rng, size, -1.0, 1.0),
            |xs| {
                let mut r = xs.clone();
                r.reverse();
                r.reverse();
                if r == *xs {
                    Ok(())
                } else {
                    Err("reverse twice changed the vec".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `sorted-is-identity` failed")]
    fn failing_property_reports() {
        forall(
            "sorted-is-identity",
            Config { iters: 64, ..Default::default() },
            |rng, size| {
                let mut v = vec_f64(rng, Size(size.0 + 2), 0.0, 1.0);
                v.push(0.0); // guarantee an unsorted case exists
                v.push(1.0);
                v
            },
            |xs| {
                let mut s = xs.clone();
                s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                if s == *xs {
                    Ok(())
                } else {
                    Err("input was not sorted".into())
                }
            },
        );
    }
}
