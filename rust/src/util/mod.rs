//! Hand-rolled substrates.
//!
//! The build image is fully offline and only the `xla` crate's dependency
//! closure is vendored, so the usual ecosystem crates (serde, clap, rand,
//! criterion, proptest) are unavailable. Everything the coordinator needs
//! from them is implemented here from scratch — which doubles as the
//! "build every substrate" requirement of this reproduction.

pub mod bench;
pub mod binfmt;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
