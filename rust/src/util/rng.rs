//! PCG32 pseudo-random number generator (O'Neill 2014) plus the sampling
//! helpers the simulator needs (uniform, normal, exponential, geometric,
//! Bernoulli, shuffles). Deterministic and seedable: every experiment in
//! EXPERIMENTS.md records its seed.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a state and stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-argument constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (no caching; simulator draws are
    /// sparse enough that the second value is not worth the state).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).max(f64::MIN_POSITIVE).ln()
    }

    /// Geometric: number of failures before the first success, p = success
    /// probability per trial. Mean = (1-p)/p.
    pub fn geometric(&mut self, p: f64) -> u64 {
        debug_assert!(p > 0.0 && p <= 1.0);
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        (u.ln() / (1.0 - p).max(f64::MIN_POSITIVE).ln()).floor() as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut rng = Pcg32::seeded(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_small_n() {
        let mut rng = Pcg32::seeded(2);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 / 10_000.0 - 1.0).abs() < 0.05, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn geometric_mean_matches() {
        let mut rng = Pcg32::seeded(4);
        let p = 0.25;
        let n = 40_000;
        let total: u64 = (0..n).map(|_| rng.geometric(p)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 3.0).abs() < 0.15, "mean={mean}"); // (1-p)/p = 3
    }

    #[test]
    fn exponential_mean_matches() {
        let mut rng = Pcg32::seeded(5);
        let n = 40_000;
        let total: f64 = (0..n).map(|_| rng.exponential(2.5)).sum();
        assert!((total / n as f64 - 2.5).abs() < 0.1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
