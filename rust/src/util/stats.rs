//! Descriptive statistics used across the experiment drivers and the bench
//! harness: Welford online moments, percentiles, histograms, and the
//! Kantorovich–Wasserstein distance on empirical CDFs (paper Eq. 2).

/// Online mean/variance (Welford). Numerically stable for long streams.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// p-th percentile (0..=100) by linear interpolation; sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Kantorovich–Wasserstein-1 distance between two empirical distributions
/// given as parallel (support, probability-mass) samples over the *same*
/// support grid — the form used by the η-factor (Eq. 2): the L1 distance
/// between the CDFs integrated over the support.
pub fn kw_distance(support: &[f64], p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(support.len(), p.len());
    assert_eq!(support.len(), q.len());
    let (mut cp, mut cq, mut acc) = (0.0, 0.0, 0.0);
    for i in 0..support.len() {
        cp += p[i];
        cq += q[i];
        let width = if i + 1 < support.len() { support[i + 1] - support[i] } else { 1.0 };
        acc += (cp - cq).abs() * width;
    }
    acc
}

/// Fixed-width histogram over [lo, hi); values outside clamp to edge bins.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<u64> {
    let mut h = vec![0u64; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let b = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[b] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - 6.2).abs() < 1e-12);
        let batch_var = xs.iter().map(|x| (x - 6.2) * (x - 6.2)).sum::<f64>() / 4.0;
        assert!((o.var() - batch_var).abs() < 1e-9);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 16.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn kw_zero_for_identical() {
        let s: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let p = vec![0.1; 10];
        assert!(kw_distance(&s, &p, &p).abs() < 1e-12);
    }

    #[test]
    fn kw_positive_and_monotone_in_shift() {
        let s: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut p = vec![0.0; 10];
        p[0] = 1.0;
        let mut q1 = vec![0.0; 10];
        q1[1] = 1.0;
        let mut q5 = vec![0.0; 10];
        q5[5] = 1.0;
        let d1 = kw_distance(&s, &p, &q1);
        let d5 = kw_distance(&s, &p, &q5);
        assert!(d1 > 0.0 && d5 > d1, "{d1} {d5}");
    }

    #[test]
    fn histogram_clamps() {
        let h = histogram(&[-5.0, 0.1, 0.9, 99.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }
}
