//! Minimal JSON parser + writer (serde is not available offline).
//!
//! Supports the full JSON grammar we emit from `python/compile/aot.py`
//! (objects, arrays, numbers incl. scientific notation, strings with
//! escapes, booleans, null). Numbers are stored as `f64` — all our
//! metadata is within f64's exact-integer range.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(src: &str) -> Result<Value, ParseError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Value, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Value::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required fields (artifact metadata is
    /// produced by our own compile path; a missing field is a build bug).
    pub fn req(&self, key: &str) -> &Value {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json field `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn f64(&self) -> f64 {
        self.as_f64().expect("json: expected number")
    }

    pub fn usize(&self) -> usize {
        let x = self.f64();
        debug_assert!(x >= 0.0 && x.fract() == 0.0, "not a usize: {x}");
        x as usize
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn str(&self) -> &str {
        self.as_str().expect("json: expected string")
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn arr(&self) -> &[Value] {
        self.as_arr().expect("json: expected array")
    }

    /// Serialize (compact).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our metadata).
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(
            Value::parse(r#""a\nbA""#).unwrap(),
            Value::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {} }"#).unwrap();
        assert_eq!(v.req("a").arr().len(), 3);
        assert_eq!(v.req("a").arr()[2].req("b").str(), "c");
        assert!(matches!(v.req("d"), Value::Obj(m) if m.is_empty()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"curve":[[0.1,0.9,0.75],[0.2,0.5,0.9]],"k":10,"name":"x","neg":-1.25e-3,"ok":true}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_artifact_meta() {
        // Representative slice of what aot.py emits.
        let src = r#"{"name": "mnist", "layers": [{"kind": "conv", "out": 8,
            "pool": true, "threshold": 16.117, "time_ms": 1092.3,
            "curve": [[0.0, 1.0, 0.83]]}], "cost_model": {"e_man_mj": 0.0508}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.req("layers").arr()[0].req("out").usize(), 8);
        assert!((v.req("cost_model").req("e_man_mj").f64() - 0.0508).abs() < 1e-9);
    }
}
