//! Reader for the ZYGT tensor-archive format written by
//! `python/compile/binfmt.py` (see that file for the byte layout).

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// Row-major 2-D view helper: element (i, j) of a (rows, cols) tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let cols = *self.dims.last().expect("row() on 0-d tensor");
        &self.f32()[i * cols..(i + 1) * cols]
    }
}

#[derive(Debug, Default)]
pub struct Archive {
    pub tensors: HashMap<String, Tensor>,
}

#[derive(Debug)]
pub struct BinError(pub String);

impl std::fmt::Display for BinError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ZYGT: {}", self.0)
    }
}

impl std::error::Error for BinError {}

fn rd_u32(b: &[u8], pos: &mut usize) -> Result<u32, BinError> {
    let s = b
        .get(*pos..*pos + 4)
        .ok_or_else(|| BinError("truncated u32".into()))?;
    *pos += 4;
    Ok(u32::from_le_bytes(s.try_into().unwrap()))
}

fn rd_u64(b: &[u8], pos: &mut usize) -> Result<u64, BinError> {
    let s = b
        .get(*pos..*pos + 8)
        .ok_or_else(|| BinError("truncated u64".into()))?;
    *pos += 8;
    Ok(u64::from_le_bytes(s.try_into().unwrap()))
}

impl Archive {
    pub fn load(path: &Path) -> Result<Archive, BinError> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .map_err(|e| BinError(format!("{}: {e}", path.display())))?;
        Self::parse(&bytes)
    }

    pub fn parse(b: &[u8]) -> Result<Archive, BinError> {
        if b.get(0..4) != Some(&b"ZYGT"[..]) {
            return Err(BinError("bad magic".into()));
        }
        let mut pos = 4usize;
        let version = rd_u32(b, &mut pos)?;
        if version != 1 {
            return Err(BinError(format!("unsupported version {version}")));
        }
        let count = rd_u32(b, &mut pos)? as usize;
        let mut tensors = HashMap::with_capacity(count);
        for _ in 0..count {
            let name_len = rd_u32(b, &mut pos)? as usize;
            let name = std::str::from_utf8(
                b.get(pos..pos + name_len)
                    .ok_or_else(|| BinError("truncated name".into()))?,
            )
            .map_err(|_| BinError("name not utf-8".into()))?
            .to_string();
            pos += name_len;
            let dtype = *b
                .get(pos)
                .ok_or_else(|| BinError("truncated dtype".into()))?;
            pos += 1;
            let ndim = rd_u32(b, &mut pos)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(rd_u64(b, &mut pos)? as usize);
            }
            let n: usize = dims.iter().product::<usize>().max(if ndim == 0 { 1 } else { 0 });
            let raw = b
                .get(pos..pos + 4 * n)
                .ok_or_else(|| BinError(format!("truncated data for `{name}`")))?;
            pos += 4 * n;
            let data = match dtype {
                0 => TensorData::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                1 => TensorData::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                d => return Err(BinError(format!("unknown dtype {d}"))),
            };
            tensors.insert(name, Tensor { dims, data });
        }
        Ok(Archive { tensors })
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("archive missing tensor `{name}`"))
    }

    pub fn try_get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build a small archive byte-for-byte per the format spec.
    fn sample() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend(b"ZYGT");
        b.extend(1u32.to_le_bytes());
        b.extend(2u32.to_le_bytes());
        // entry "a": f32 (2,3)
        b.extend(1u32.to_le_bytes());
        b.extend(b"a");
        b.push(0);
        b.extend(2u32.to_le_bytes());
        b.extend(2u64.to_le_bytes());
        b.extend(3u64.to_le_bytes());
        for i in 0..6 {
            b.extend((i as f32 * 0.5).to_le_bytes());
        }
        // entry "idx": i32 (4,)
        b.extend(3u32.to_le_bytes());
        b.extend(b"idx");
        b.push(1);
        b.extend(1u32.to_le_bytes());
        b.extend(4u64.to_le_bytes());
        for i in [7i32, -1, 0, 42] {
            b.extend(i.to_le_bytes());
        }
        b
    }

    #[test]
    fn parses_archive() {
        let a = Archive::parse(&sample()).unwrap();
        let t = a.get("a");
        assert_eq!(t.dims, vec![2, 3]);
        assert_eq!(t.row(1), &[1.5, 2.0, 2.5]);
        assert_eq!(a.get("idx").i32(), &[7, -1, 0, 42]);
    }

    #[test]
    fn rejects_corruption() {
        assert!(Archive::parse(b"NOPE").is_err());
        let mut b = sample();
        b.truncate(b.len() - 3); // truncated payload
        assert!(Archive::parse(&b).is_err());
        let mut b2 = sample();
        b2[4] = 9; // bad version
        assert!(Archive::parse(&b2).is_err());
    }

    #[test]
    fn reads_real_artifact_if_present() {
        let root = crate::artifacts_root().join("mnist/tensors.bin");
        if !root.exists() {
            return; // artifacts not built in this environment
        }
        let a = Archive::load(&root).unwrap();
        let tx = a.get("test_x");
        assert_eq!(tx.dims.len(), 4);
        assert_eq!(tx.dims[1..], [16, 16, 1]);
        assert_eq!(a.get("test_y").dims[0], tx.dims[0]);
        let c0 = a.get("layer0_centroids");
        assert_eq!(c0.dims[0], 10); // k = n_classes
    }
}
