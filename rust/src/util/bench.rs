//! Criterion-style micro/macro bench harness (criterion is not available
//! offline). Used by the `benches/` targets via `harness = false`.
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean / p50 / p99 per iteration, and can compare against a recorded
//! baseline (for the §Perf before/after log).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Optional throughput annotation, e.g. simulated-fragments/sec.
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) {
        let t = match self.throughput {
            Some((v, unit)) => format!("  ({} {unit})", human(v)),
            None => String::new(),
        };
        println!(
            "bench {:<44} {:>12}/iter  p50 {:>10}  p99 {:>10}  ({} iters){t}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters,
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn human(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}k", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

pub struct Bencher {
    /// Target total measurement time per benchmark.
    pub budget: Duration,
    /// Number of measurement batches (each batch = iters/batches runs).
    pub batches: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Overridable so `cargo bench` can run quickly in CI-style runs.
        let ms = std::env::var("BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(700u64);
        Bencher { budget: Duration::from_millis(ms), batches: 20 }
    }
}

impl Bencher {
    /// Benchmark `f`, which performs ONE logical iteration per call and
    /// returns a value (kept alive to prevent dead-code elimination).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup + calibration: find iters/batch for ~budget/batches each.
        let cal_start = Instant::now();
        let mut cal_iters = 0u64;
        while cal_start.elapsed() < self.budget / 10 {
            std::hint::black_box(f());
            cal_iters += 1;
            if cal_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = (self.budget.as_nanos() as f64 / 10.0) / cal_iters as f64;
        let batch_ns = self.budget.as_nanos() as f64 / self.batches as f64;
        let iters_per_batch = ((batch_ns / per_iter) as u64).max(1);

        let mut samples = Vec::with_capacity(self.batches);
        let mut total_iters = 0u64;
        for _ in 0..self.batches {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / iters_per_batch as f64;
            samples.push(dt);
            total_iters += iters_per_batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            p50_ns: samples[samples.len() / 2],
            p99_ns: samples[((samples.len() as f64 * 0.99) as usize).min(samples.len() - 1)],
            throughput: None,
        }
    }

    /// As `run`, but annotate throughput: `items_per_iter` logical items
    /// are processed by each call to `f`.
    pub fn run_throughput<T>(
        &self,
        name: &str,
        items_per_iter: f64,
        unit: &'static str,
        f: impl FnMut() -> T,
    ) -> BenchResult {
        let mut r = self.run(name, f);
        r.throughput = Some((items_per_iter * 1e9 / r.mean_ns, unit));
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let b = Bencher { budget: Duration::from_millis(30), batches: 5 };
        let r = b.run("noop-ish", || std::hint::black_box(2u64).wrapping_mul(3));
        assert!(r.mean_ns > 0.0 && r.mean_ns < 1e6, "{}", r.mean_ns);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert_eq!(fmt_ns(12_300.0), "12.30 µs");
        assert!(fmt_ns(3.2e9).ends_with(" s"));
    }
}
