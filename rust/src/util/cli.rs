//! Tiny CLI argument parser (clap is not available offline).
//!
//! Grammar: `zygarde <subcommand> [--key value | --flag] [positional...]`.
//! Unknown flags are an error — experiments should fail loudly on typos.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags the program declared; used for `--help` and typo detection.
    known: Vec<(String, String)>,
}

impl Args {
    /// Parse raw argv (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: expected a number, got `{v}`")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: expected an integer, got `{v}`")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.usize_or(key, default as usize) as u64
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key}: expected a bool, got `{v}`"),
        }
    }

    /// Declare a known flag (for --help output and typo checking).
    pub fn declare(&mut self, key: &str, help: &str) -> &mut Self {
        self.known.push((key.to_string(), help.to_string()));
        self
    }

    /// After declaring flags, error out on unknown ones.
    pub fn check_unknown(&self) -> Result<(), String> {
        for k in self.flags.keys() {
            if !self.known.iter().any(|(n, _)| n == k) {
                let hint = self
                    .known
                    .iter()
                    .map(|(n, _)| n.as_str())
                    .collect::<Vec<_>>()
                    .join(", --");
                return Err(format!("unknown flag --{k} (known: --{hint})"));
            }
        }
        Ok(())
    }

    pub fn help(&self) -> String {
        let mut s = String::new();
        for (k, h) in &self.known {
            s.push_str(&format!("  --{k:<18} {h}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        // NB: a bare `--flag` greedily takes the next non-flag token as its
        // value; use `--flag=true` (or put the flag last) before positionals.
        let a = parse("schedule pos1 --dataset mnist --eta 0.71 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("schedule"));
        assert_eq!(a.str_or("dataset", "x"), "mnist");
        assert!((a.f64_or("eta", 0.0) - 0.71).abs() < 1e-12);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --n=17 --name=abc");
        assert_eq!(a.usize_or("n", 0), 17);
        assert_eq!(a.str_or("name", ""), "abc");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize_or("n", 5), 5);
        assert!(!a.bool_or("flag", false));
    }

    #[test]
    fn unknown_flag_detected() {
        let mut a = parse("run --oops 1");
        a.declare("n", "count");
        assert!(a.check_unknown().is_err());
        let mut b = parse("run --n 1");
        b.declare("n", "count");
        assert!(b.check_unknown().is_ok());
    }

    #[test]
    #[should_panic(expected = "expected a number")]
    fn bad_number_panics() {
        let a = parse("run --eta abc");
        a.f64_or("eta", 0.0);
    }
}
