//! Supercapacitor energy-storage model.
//!
//! E = ½CV², charged by the harvester through an ideal regulator, drained
//! by fragment execution. The MCU boots when the voltage crosses `v_on`
//! and browns out below `v_off` (hysteresis, as in real intermittent
//! platforms); the capacitor clamps at `v_max` — excess harvest is wasted,
//! which is exactly the waste the ζ_I scheduler's optional-unit execution
//! is designed to absorb (paper §5.2).

#[derive(Clone, Debug)]
pub struct Capacitor {
    pub c_farads: f64,
    pub v_max: f64,
    pub v_on: f64,
    pub v_off: f64,
    /// Stored energy in millijoules.
    energy_mj: f64,
    mcu_on: bool,
    /// Cumulative harvested energy that arrived while full (wasted).
    pub wasted_mj: f64,
    /// Cumulative energy drawn from storage (fragments, idle drain, NVM
    /// commits/restores, and brownout remnants) — the consumption side of
    /// the energy-conservation identity the sweep tests check.
    pub consumed_mj: f64,
}

impl Capacitor {
    /// The paper's default: 50 mF, 3.3 V rail, MSP430 thresholds.
    pub fn standard() -> Self {
        Self::new(0.050, 3.3, 2.8, 1.9)
    }

    pub fn new(c_farads: f64, v_max: f64, v_on: f64, v_off: f64) -> Self {
        assert!(v_on > v_off && v_max >= v_on);
        Capacitor {
            c_farads,
            v_max,
            v_on,
            v_off,
            energy_mj: 0.0,
            mcu_on: false,
            wasted_mj: 0.0,
            consumed_mj: 0.0,
        }
    }

    /// Maximum storable energy (mJ).
    pub fn capacity_mj(&self) -> f64 {
        0.5 * self.c_farads * self.v_max * self.v_max * 1e3
    }

    /// Energy at the brown-out threshold — unusable remnant.
    pub fn floor_mj(&self) -> f64 {
        0.5 * self.c_farads * self.v_off * self.v_off * 1e3
    }

    pub fn energy_mj(&self) -> f64 {
        self.energy_mj
    }

    /// Usable energy above the brown-out floor (the scheduler's E_curr).
    pub fn usable_mj(&self) -> f64 {
        (self.energy_mj - self.floor_mj()).max(0.0)
    }

    pub fn voltage(&self) -> f64 {
        (2.0 * self.energy_mj * 1e-3 / self.c_farads).sqrt()
    }

    pub fn is_full(&self) -> bool {
        self.energy_mj >= self.capacity_mj() * (1.0 - 1e-9)
    }

    /// Pre-deployment warm-up: fill to capacity before t = 0 (the
    /// deployment has been harvesting before the simulation starts).
    /// Deliberately *not* harvest accounting — the charge does not count
    /// as harvested, wasted, or consumed, because those ledgers cover
    /// simulated time only and the energy-conservation identity
    /// (`harvested = Δstored + wasted + consumed`) must close over the
    /// run. This replaces the old fiction of `charge(1e9, 1000.0)`
    /// followed by zeroing `wasted_mj` in the engine constructor.
    pub fn precharge(&mut self) {
        self.energy_mj = self.capacity_mj();
        self.update_mcu();
    }

    /// Add harvested energy over `dt_ms` at `power_mw`; update MCU state.
    pub fn charge(&mut self, power_mw: f64, dt_ms: f64) {
        // mW · ms = µJ; µJ · 1e-3 = mJ.
        let add_mj = power_mw * dt_ms * 1e-3;
        let cap = self.capacity_mj();
        let new = self.energy_mj + add_mj;
        if new > cap {
            self.wasted_mj += new - cap;
            self.energy_mj = cap;
        } else {
            self.energy_mj = new;
        }
        self.update_mcu();
    }

    /// Try to draw `e_mj` for computation. Fails (returns false, draws
    /// nothing) if the MCU is off or the draw would brown out mid-way —
    /// the caller then re-executes the fragment later (idempotent).
    pub fn draw(&mut self, e_mj: f64) -> bool {
        if !self.mcu_on {
            return false;
        }
        if self.energy_mj - e_mj < self.floor_mj() {
            // Brown-out: the energy is still spent (the fragment ran and
            // died) but the work is lost, and the MCU powers off — it must
            // recharge past v_on before executing again.
            self.consumed_mj += self.energy_mj - self.floor_mj();
            self.energy_mj = self.floor_mj();
            self.mcu_on = false;
            return false;
        }
        self.energy_mj -= e_mj;
        self.consumed_mj += e_mj;
        self.update_mcu();
        true
    }

    /// MCU baseline draw (sleep/idle current) over `dt_ms`.
    pub fn idle_drain(&mut self, power_mw: f64, dt_ms: f64) {
        if self.mcu_on {
            // mW · ms · 1e-3 = mJ.
            let drained = (power_mw * dt_ms * 1e-3).min(self.energy_mj);
            self.energy_mj -= drained;
            self.consumed_mj += drained;
            self.update_mcu();
        }
    }

    /// Stored energy (mJ) at which the capacitor reads voltage `v` —
    /// the E = ½CV² inverse the event-driven engine core uses to turn a
    /// voltage trigger (JIT threshold, brown-out) into an energy guard.
    /// Algebraic, not ulp-exact against [`Capacitor::voltage`]'s rounded
    /// sqrt: callers must pad the guard (a couple of idle-drain quanta
    /// dwarfs the ~1-ulp discrepancy) and let an exact per-tick tail
    /// resolve the crossing itself.
    pub fn energy_at_voltage_mj(&self, v: f64) -> f64 {
        0.5 * self.c_farads * v * v * 1e3
    }

    /// Conservative lower bound on how many idle ticks draining
    /// `drain_mj_per_tick` each can run while the stored energy provably
    /// stays above `threshold_mj` — the capacitor leg of the engine's
    /// next-event budget. Zero drain (idle power 0) never crosses:
    /// saturates. The two-tick slack in [`super::conservative_ticks`]
    /// covers sequential-subtraction drift; the caller pads `threshold_mj`
    /// for sqrt-comparison discrepancies where the real trigger is a
    /// voltage compare.
    pub fn idle_ticks_above(&self, threshold_mj: f64, drain_mj_per_tick: f64) -> u64 {
        if drain_mj_per_tick <= 0.0 {
            return u64::MAX;
        }
        super::conservative_ticks(self.energy_mj - threshold_mj, drain_mj_per_tick)
    }

    /// Bulk replay of `n` [`Capacitor::idle_drain`] calls for which the
    /// caller has proved (via [`Capacitor::idle_ticks_above`] with padded
    /// guards) that no MCU state change can occur: the identical per-tick
    /// f64 sequence — `min` included — with only the crossing check
    /// (`update_mcu`'s sqrt + compares) hoisted out, so the post-state is
    /// bitwise what `n` individual calls produce.
    pub fn fast_forward_idle_drain(&mut self, power_mw: f64, dt_ms: f64, n: u64) {
        debug_assert!(self.mcu_on);
        for _ in 0..n {
            let drained = (power_mw * dt_ms * 1e-3).min(self.energy_mj);
            self.energy_mj -= drained;
            self.consumed_mj += drained;
        }
        debug_assert!(
            self.voltage() >= self.v_off,
            "bulk idle drain ran through the brown-out crossing"
        );
    }

    fn update_mcu(&mut self) {
        let v = self.voltage();
        if self.mcu_on {
            if v < self.v_off {
                self.mcu_on = false;
            }
        } else if v >= self.v_on {
            self.mcu_on = true;
        }
    }

    pub fn mcu_on(&self) -> bool {
        self.mcu_on
    }

    /// The paper's §8.6 sizing rule: C = sqrt(2 P δT / V²) — returns the
    /// "optimal" capacitance for average power P (mW), slack δT (ms), and
    /// rail voltage V. (Kept in the paper's own algebraic form.)
    pub fn optimal_capacitance(p_mw: f64, slack_ms: f64, v: f64) -> f64 {
        (2.0 * (p_mw * 1e-3) * (slack_ms * 1e-3) / (v * v)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_matches_physics() {
        let c = Capacitor::standard();
        // ½ · 0.05 F · 3.3² V² = 272.25 mJ
        assert!((c.capacity_mj() - 272.25).abs() < 1e-6);
    }

    #[test]
    fn precharge_fills_without_touching_the_ledgers() {
        let mut c = Capacitor::standard();
        c.precharge();
        assert!(c.is_full());
        assert!(c.mcu_on());
        assert_eq!(c.wasted_mj, 0.0, "pre-t0 fiction must not count as waste");
        assert_eq!(c.consumed_mj, 0.0);
        // Bitwise the same stored energy as the old clamped mega-charge
        // (whose overflow the engine constructor used to zero away).
        let mut old = Capacitor::standard();
        old.charge(1e9, 1000.0);
        assert_eq!(c.energy_mj().to_bits(), old.energy_mj().to_bits());
        assert!(old.wasted_mj > 0.0);
    }

    #[test]
    fn charges_until_full_then_wastes() {
        let mut c = Capacitor::new(0.001, 2.0, 1.8, 1.0);
        let cap = c.capacity_mj();
        // Push far more energy than capacity.
        for _ in 0..1000 {
            c.charge(100.0, 100.0);
        }
        assert!(c.is_full());
        assert!((c.energy_mj() - cap).abs() < 1e-9);
        assert!(c.wasted_mj > 0.0);
    }

    #[test]
    fn mcu_hysteresis() {
        let mut c = Capacitor::new(0.001, 3.0, 2.5, 1.5);
        assert!(!c.mcu_on());
        // Charge until boot.
        while !c.mcu_on() {
            c.charge(50.0, 100.0);
        }
        assert!(c.voltage() >= 2.5);
        // Drain: stays on until v_off.
        while c.mcu_on() {
            assert!(c.draw(0.1) || !c.mcu_on());
        }
        assert!(c.voltage() <= 1.5 + 1e-6);
        // Must re-reach v_on (not v_off) to boot again.
        c.charge(1.0, 1.0);
        assert!(!c.mcu_on());
    }

    #[test]
    fn draw_fails_when_off() {
        let mut c = Capacitor::standard();
        assert!(!c.draw(0.01));
        assert_eq!(c.energy_mj(), 0.0);
    }

    #[test]
    fn brownout_spends_energy_but_fails() {
        let mut c = Capacitor::new(0.001, 3.0, 2.5, 1.5);
        while !c.mcu_on() {
            c.charge(50.0, 100.0);
        }
        let huge = c.capacity_mj(); // more than usable
        assert!(!c.draw(huge));
        assert!(!c.mcu_on());
        assert!((c.energy_mj() - c.floor_mj()).abs() < 1e-9);
    }

    #[test]
    fn consumed_accounting_closes_the_energy_identity() {
        let mut c = Capacitor::new(0.001, 3.0, 2.5, 1.5);
        let mut harvested = 0.0;
        for _ in 0..200 {
            c.charge(50.0, 100.0);
            harvested += 50.0 * 100.0 * 1e-3;
            if c.mcu_on() {
                let _ = c.draw(0.8);
                c.idle_drain(0.3, 100.0);
            }
        }
        // Force a brownout remnant too.
        while !c.mcu_on() {
            c.charge(50.0, 100.0);
            harvested += 50.0 * 100.0 * 1e-3;
        }
        assert!(!c.draw(c.capacity_mj()));
        let balance = harvested - c.wasted_mj - c.consumed_mj - c.energy_mj();
        assert!(balance.abs() < 1e-9, "energy identity violated by {balance}");
        assert!(c.consumed_mj > 0.0);
    }

    /// Predictor + bulk-replay contract: the budget only admits ticks that
    /// provably cannot cross `threshold`, and draining them in bulk is
    /// bitwise identical to per-tick `idle_drain` calls.
    #[test]
    fn idle_ticks_above_budget_and_bulk_drain_match_per_tick_bitwise() {
        let mut bulk = Capacitor::standard();
        bulk.precharge();
        let mut tick = bulk.clone();
        let dt = 5.0;
        let power = 0.3;
        let drain = power * dt * 1e-3;
        let mut total = 0u64;
        loop {
            // Pad the floor by two drain quanta, as the engine does, so
            // the voltage-vs-energy comparison discrepancy is covered.
            let n = bulk.idle_ticks_above(bulk.floor_mj() + 2.0 * drain, drain);
            if n == 0 {
                break;
            }
            bulk.fast_forward_idle_drain(power, dt, n);
            for _ in 0..n {
                tick.idle_drain(power, dt);
            }
            total += n;
            assert!(tick.mcu_on(), "budget admitted a tick that browned out");
            assert_eq!(bulk.energy_mj().to_bits(), tick.energy_mj().to_bits());
            assert_eq!(bulk.consumed_mj.to_bits(), tick.consumed_mj.to_bits());
        }
        assert!(total > 100_000, "50 mF at 0.3 mW should idle a long time: {total}");
        // The exact tail: a handful of per-tick drains reach the real
        // crossing on both copies identically.
        for _ in 0..8 {
            bulk.idle_drain(power, dt);
            tick.idle_drain(power, dt);
            assert_eq!(bulk.mcu_on(), tick.mcu_on());
            assert_eq!(bulk.energy_mj().to_bits(), tick.energy_mj().to_bits());
        }
        // Zero drain never predicts a crossing.
        assert_eq!(bulk.idle_ticks_above(0.0, 0.0), u64::MAX);
        // The voltage inverse is the algebraic E(V) the guards build on.
        let c = Capacitor::standard();
        assert!((c.energy_at_voltage_mj(c.v_max) - c.capacity_mj()).abs() < 1e-9);
        assert!((c.energy_at_voltage_mj(c.v_off) - c.floor_mj()).abs() < 1e-9);
    }

    #[test]
    fn optimal_capacitance_formula() {
        // C = sqrt(2·P·δT / V²): plug P=1 W, δT=1 s, V=3.3 V
        let c = Capacitor::optimal_capacitance(1000.0, 1000.0, 3.3);
        assert!((c - (2.0f64 / (3.3 * 3.3)).sqrt()).abs() < 1e-9);
    }
}
