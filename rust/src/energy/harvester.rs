//! Harvester process models (the hardware substitute, DESIGN.md §1).
//!
//! Each model produces instantaneous harvested power (mW) per simulation
//! tick. The scheduler never sees these directly — only the capacitor
//! state and the offline-estimated η-factor — so what matters is that the
//! *energy-event statistics* match the paper's (bursty two-state processes
//! with the h(N) shapes of Fig. 4). Four models:
//!
//! * `Persistent` — constant supply (System 1, η = 1).
//! * `MarkovBurst` — symmetric-ish two-state Markov process; calibrated by
//!   [`calibrate_markov`] to hit a target η. Used for the controlled solar
//!   (bulb) and RF experiments (Systems 2–7, Table 4).
//! * `Piezo` — footstep-driven: bounded walk bouts (the paper's subject
//!   never walked > 100 min) with long idle gaps.
//! * `SolarDiurnal` — day/night cycle plus cloud flicker for the two-month
//!   Fig. 4(c) study: long on-runs (~5 h of light at a window), long
//!   off-runs (~19 h until the sun returns).

use std::collections::BTreeMap;
use std::sync::RwLock;

use crate::util::rng::Pcg32;

use super::events::eta_factor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HarvesterKind {
    Persistent,
    Solar,
    Rf,
    Piezo,
    SolarDiurnal,
}

/// Periodic forced-dark windows for failure injection (`sim::sweep`):
/// every `period_ms` the harvester output is masked to zero for
/// `duration_ms`, starting `offset_ms` into the period — a brownout burst
/// (shadowing, RF contention) layered on top of the stochastic process.
/// The underlying Markov state and RNG stream advance exactly as without
/// the mask, so a blackout scenario stays comparable to its baseline.
#[derive(Clone, Copy, Debug)]
pub struct BlackoutWindows {
    pub period_ms: f64,
    pub duration_ms: f64,
    pub offset_ms: f64,
}

#[derive(Clone, Debug)]
pub struct Harvester {
    pub kind: HarvesterKind,
    pub name: String,
    /// Average power while the source is ON (mW).
    pub on_power_mw: f64,
    /// Probability of staying in the current state per ΔT window.
    pub p_stay_on: f64,
    pub p_stay_off: f64,
    /// ΔT in milliseconds (the energy-event window).
    pub dt_ms: f64,
    state_on: bool,
    /// Time left in the current ΔT window (ms).
    window_left_ms: f64,
    rng: Pcg32,
    // SolarDiurnal / Piezo internal clocks.
    phase_ms: f64,
    /// Failure-injection mask; `None` for normal operation.
    blackout: Option<BlackoutWindows>,
}

impl Harvester {
    pub fn persistent(power_mw: f64) -> Self {
        Harvester {
            kind: HarvesterKind::Persistent,
            name: "persistent".into(),
            on_power_mw: power_mw,
            p_stay_on: 1.0,
            p_stay_off: 0.0,
            dt_ms: 1000.0,
            state_on: true,
            window_left_ms: 1000.0,
            rng: Pcg32::seeded(0),
            phase_ms: 0.0,
            blackout: None,
        }
    }

    /// Two-state Markov burst source with stay probability `q` for both
    /// states (marginal duty ≈ duty, enforced by asymmetric stays).
    pub fn markov(kind: HarvesterKind, on_power_mw: f64, q: f64, duty: f64,
                  dt_ms: f64, seed: u64) -> Self {
        // Asymmetric stay probabilities chosen so the stationary
        // distribution has P(on) = duty while both states stay bursty:
        //   P(on) = p01 / (p01 + p10), p10 = 1-q_on, p01 = 1-q_off.
        let p10 = 1.0 - q;
        let p01 = p10 * duty / (1.0 - duty).max(1e-6);
        let name = match kind {
            HarvesterKind::Solar => "solar",
            HarvesterKind::Rf => "rf",
            _ => "markov",
        };
        Harvester {
            kind,
            name: name.into(),
            on_power_mw,
            p_stay_on: q,
            p_stay_off: (1.0 - p01).clamp(0.0, 1.0),
            dt_ms,
            state_on: true,
            window_left_ms: dt_ms,
            rng: Pcg32::seeded(seed),
            phase_ms: 0.0,
            blackout: None,
        }
    }

    pub fn piezo(seed: u64) -> Self {
        Harvester {
            kind: HarvesterKind::Piezo,
            name: "piezo".into(),
            on_power_mw: 20.0,
            p_stay_on: 0.95,
            p_stay_off: 0.985,
            dt_ms: 5.0 * 60.0 * 1000.0, // ΔT = 5 min (Fig. 4)
            state_on: false,
            window_left_ms: 5.0 * 60.0 * 1000.0,
            rng: Pcg32::seeded(seed),
            phase_ms: 0.0,
            blackout: None,
        }
    }

    pub fn solar_diurnal(seed: u64) -> Self {
        Harvester {
            kind: HarvesterKind::SolarDiurnal,
            name: "solar-diurnal".into(),
            on_power_mw: 500.0,
            p_stay_on: 0.97, // cloud flicker within the lit window
            p_stay_off: 1.0,
            dt_ms: 5.0 * 60.0 * 1000.0,
            state_on: false,
            window_left_ms: 5.0 * 60.0 * 1000.0,
            rng: Pcg32::seeded(seed),
            phase_ms: 0.0,
            blackout: None,
        }
    }

    /// Inject periodic brownout bursts (failure injection; see
    /// [`BlackoutWindows`]).
    pub fn with_blackouts(mut self, windows: BlackoutWindows) -> Self {
        debug_assert!(windows.period_ms > 0.0 && windows.duration_ms >= 0.0);
        self.blackout = Some(windows);
        self
    }

    /// Advance by `dt_ms` and return the average harvested power over the
    /// step (mW). State transitions happen at ΔT window boundaries.
    pub fn step(&mut self, dt_ms: f64) -> f64 {
        self.phase_ms += dt_ms;
        self.window_left_ms -= dt_ms;
        while self.window_left_ms <= 0.0 {
            self.window_left_ms += self.dt_ms;
            self.transition();
        }
        let power = if self.state_on {
            // ±10 % power jitter models light-intensity / RF distance noise.
            self.on_power_mw * (0.9 + 0.2 * self.rng.f64())
        } else {
            0.0
        };
        // The mask applies *after* the stochastic process advanced, so the
        // RNG stream (and hence everything downstream of a given seed) is
        // identical with and without the injected fault.
        match self.blackout {
            Some(w) if (self.phase_ms - w.offset_ms).rem_euclid(w.period_ms) < w.duration_ms => {
                0.0
            }
            _ => power,
        }
    }

    /// One tick of the off-phase fast path: advances the window clock iff
    /// the source is OFF and the tick stays strictly inside the current
    /// ΔT window — i.e. iff the equivalent [`Harvester::step`] call would
    /// return 0.0 mW, draw no randomness, and cross no state boundary.
    /// When it returns `true`, the harvester state is **bitwise
    /// identical** to what `step(dt_ms)` would have produced (the same
    /// one `phase_ms` add and one `window_left_ms` subtract, in a tight
    /// loop with no power/jitter/mask arithmetic, all of which is
    /// identically zero for such a tick). When it returns `false` —
    /// source on, or a window boundary/transition due — it advances
    /// nothing and the caller must take the full `step` path for this
    /// tick. This is what lets `sim::engine` fast-forward the
    /// off/charging regime without perturbing a single bit of the
    /// simulation (see `Engine::advance_idle_off`).
    #[inline]
    pub fn off_tick(&mut self, dt_ms: f64) -> bool {
        if self.state_on {
            return false;
        }
        // Same operation `step` performs (`window_left_ms -= dt_ms`,
        // then `while window_left_ms <= 0.0`), evaluated before storing
        // so a boundary tick is left untouched for the slow path.
        let left = self.window_left_ms - dt_ms;
        if left <= 0.0 {
            return false;
        }
        self.window_left_ms = left;
        self.phase_ms += dt_ms;
        true
    }

    /// Analytic window-edge predictor for the event-driven engine core:
    /// a conservative lower bound on how many consecutive `dt_ms` ticks
    /// [`Harvester::off_tick`] is guaranteed to accept from the current
    /// state. Zero when the source is on. Works for **every** kind —
    /// including `Piezo` and `SolarDiurnal`, whose day/bout logic runs
    /// only inside [`transition`], i.e. only at ΔT window edges — because
    /// between edges the sole evolving state is the window countdown.
    /// Conservative: undershooting the true edge just means a few extra
    /// per-tick `off_tick` calls in the caller's tail loop.
    ///
    /// [`transition`]: Harvester::transition
    #[inline]
    pub fn off_ticks_hint(&self, dt_ms: f64) -> u64 {
        if self.state_on {
            return 0;
        }
        super::conservative_ticks(self.window_left_ms, dt_ms)
    }

    /// Bulk replay of `n` accepted [`Harvester::off_tick`] calls: the
    /// identical two sequential f64 operations per tick (`window_left_ms
    /// -= dt_ms`, `phase_ms += dt_ms`), so the post-state is bitwise what
    /// `n` individual calls produce — with the per-tick state/boundary
    /// branches hoisted out, because the caller already proved via
    /// [`Harvester::off_ticks_hint`] that none can fire within `n` ticks.
    #[inline]
    pub fn fast_forward_dark(&mut self, n: u64, dt_ms: f64) {
        debug_assert!(!self.state_on && n <= self.off_ticks_hint(dt_ms));
        for _ in 0..n {
            self.window_left_ms -= dt_ms;
            self.phase_ms += dt_ms;
        }
        debug_assert!(self.window_left_ms > 0.0, "bulk ran through a window edge");
    }

    fn transition(&mut self) {
        match self.kind {
            HarvesterKind::Persistent => {}
            HarvesterKind::SolarDiurnal => {
                // 24 h cycle: a ~5 h lit window at this window's position
                // (the paper's window stopped getting light after 5 h),
                // modulated by cloud bursts.
                const DAY_MS: f64 = 24.0 * 3600.0 * 1000.0;
                let t = self.phase_ms % DAY_MS;
                let lit = t > 7.0 * 3600.0 * 1000.0 && t < 12.0 * 3600.0 * 1000.0;
                if !lit {
                    self.state_on = false;
                } else if self.state_on {
                    self.state_on = self.rng.chance(self.p_stay_on);
                } else {
                    self.state_on = !self.rng.chance(0.6);
                }
            }
            _ => {
                let stay = if self.state_on { self.p_stay_on } else { self.p_stay_off };
                if !self.rng.chance(stay) {
                    self.state_on = !self.state_on;
                }
                // Piezo: cap walk bouts (never > ~100 min of walking).
                if self.kind == HarvesterKind::Piezo && self.state_on {
                    // handled statistically by p_stay_on < 1; no hard cap
                    // needed for the h(N) shape beyond the Markov decay.
                }
            }
        }
    }

    pub fn is_on(&self) -> bool {
        self.state_on
    }

    /// Generate an energy-event trace: one bool per ΔT window, true iff
    /// the window harvested at least `dk_mj` millijoules.
    pub fn event_trace(&mut self, windows: usize, dk_mj: f64) -> Vec<bool> {
        let mut out = Vec::with_capacity(windows);
        // Sample each ΔT window in 10 sub-steps for power jitter averaging.
        let sub = self.dt_ms / 10.0;
        for _ in 0..windows {
            let mut e_mj = 0.0;
            for _ in 0..10 {
                e_mj += self.step(sub) * sub * 1e-3; // mW * ms = µJ; /1e3 = mJ
            }
            out.push(e_mj >= dk_mj);
        }
        out
    }
}

// ---- Table 4 evaluation systems -----------------------------------------

/// One row of Table 4: the seven controlled evaluation systems. Lives here
/// (not in `exp`) so the `sim::sweep` scenario specs can name a system
/// without depending on the experiment drivers; `exp::common` re-exports.
#[derive(Clone, Copy, Debug)]
pub struct System {
    pub id: usize,
    pub kind: HarvesterKind,
    pub eta: f64,
    pub avg_power_mw: f64,
}

pub const SYSTEMS: [System; 7] = [
    System { id: 1, kind: HarvesterKind::Persistent, eta: 1.0, avg_power_mw: 600.0 },
    System { id: 2, kind: HarvesterKind::Solar, eta: 0.71, avg_power_mw: 600.0 },
    System { id: 3, kind: HarvesterKind::Solar, eta: 0.51, avg_power_mw: 420.0 },
    System { id: 4, kind: HarvesterKind::Solar, eta: 0.38, avg_power_mw: 310.0 },
    System { id: 5, kind: HarvesterKind::Rf, eta: 0.71, avg_power_mw: 58.0 },
    System { id: 6, kind: HarvesterKind::Rf, eta: 0.51, avg_power_mw: 71.0 },
    System { id: 7, kind: HarvesterKind::Rf, eta: 0.38, avg_power_mw: 80.0 },
];

pub fn system(id: usize) -> System {
    SYSTEMS[id - 1]
}

/// Harvester duty cycle used by the controlled experiments: the paper
/// varies bulb intensity / RF distance; we fix the duty and scale the
/// on-power to hit the average.
pub const DUTY: f64 = 0.6;

/// Deterministic seed for the calibration search. Shared by every caller
/// so the memo below stays consistent across threads and call orders.
const CALIBRATION_SEED: u64 = 0xCA11B;

// Calibration is deterministic but not free; memoize q per
// (kind, η, on-power, duty). Read-mostly: after `sim::sweep` pre-warms
// the cache once per sweep, parallel workers only ever take the shared
// read lock — the old `Mutex` serialized every scenario construction on
// one global lock. (`BTreeMap` because its `new` is const; the cache
// holds at most a handful of Table-4 entries.)
static CALIBRATION: RwLock<BTreeMap<(u8, u64, u64, u64), f64>> = RwLock::new(BTreeMap::new());

/// Memoized [`calibrate_markov`] with the shared calibration seed.
pub fn calibrated_q(kind: HarvesterKind, on_power_mw: f64, duty: f64, eta: f64) -> f64 {
    let key = (
        kind as u8,
        (eta * 1000.0).round() as u64,
        (on_power_mw * 1000.0).round() as u64,
        (duty * 1000.0).round() as u64,
    );
    if let Some(&q) = CALIBRATION.read().unwrap().get(&key) {
        return q;
    }
    // Calibrate outside the lock (it simulates a 30 k-window trace); a
    // racing thread may duplicate the work but computes the same value.
    let (q, _achieved) = calibrate_markov(kind, on_power_mw, duty, eta, CALIBRATION_SEED);
    CALIBRATION.write().unwrap().insert(key, q);
    q
}

/// Build the harvester for a Table 4 system (seeded per run).
pub fn harvester_for(sys: System, seed: u64) -> Harvester {
    match sys.kind {
        HarvesterKind::Persistent => Harvester::persistent(sys.avg_power_mw),
        kind => {
            let on_power = sys.avg_power_mw / DUTY;
            let q = calibrated_q(kind, on_power, DUTY, sys.eta);
            Harvester::markov(kind, on_power, q, DUTY, 1000.0, seed)
        }
    }
}

/// Binary-search the Markov stay probability `q` so the simulated trace's
/// estimated η matches `target` (the paper's Systems 2–7 use η ∈
/// {0.38, 0.51, 0.71}). Returns (q, achieved η).
pub fn calibrate_markov(
    kind: HarvesterKind,
    on_power_mw: f64,
    duty: f64,
    target: f64,
    seed: u64,
) -> (f64, f64) {
    let eval = |q: f64| -> f64 {
        let mut h = Harvester::markov(kind, on_power_mw, q, duty, 1000.0, seed);
        // ΔK chosen as half the per-window on-energy so events track state.
        let dk = on_power_mw * 1000.0 * 1e-3 * 0.5;
        let trace = h.event_trace(30_000, dk);
        eta_factor(&trace, 20, seed).eta
    };
    let (mut lo, mut hi) = (0.50, 0.999);
    for _ in 0..18 {
        let mid = 0.5 * (lo + hi);
        if eval(mid) < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let q = 0.5 * (lo + hi);
    (q, eval(q))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn persistent_always_on() {
        let mut h = Harvester::persistent(100.0);
        for _ in 0..1000 {
            assert!(h.step(100.0) > 0.0);
        }
    }

    #[test]
    fn markov_duty_cycle_respected() {
        let mut h = Harvester::markov(HarvesterKind::Rf, 80.0, 0.9, 0.6, 1000.0, 1);
        let mut on = 0usize;
        let n = 50_000;
        for _ in 0..n {
            if h.step(1000.0) > 0.0 {
                on += 1;
            }
        }
        let duty = on as f64 / n as f64;
        assert!((duty - 0.6).abs() < 0.08, "duty={duty}");
    }

    #[test]
    fn event_trace_tracks_state() {
        let mut h = Harvester::markov(HarvesterKind::Solar, 400.0, 0.95, 0.5, 1000.0, 2);
        let t = h.event_trace(5000, 200.0 * 0.5);
        let rate = t.iter().filter(|&&e| e).count() as f64 / t.len() as f64;
        assert!(rate > 0.3 && rate < 0.7, "rate={rate}");
    }

    /// The fast-path contract: interleaving `off_tick` (taken whenever it
    /// applies) with `step` walks the exact same state trajectory as pure
    /// `step`ping — every field bitwise, every RNG draw at the same tick.
    /// `Debug` output includes the private window/phase/RNG state with
    /// shortest-round-trip floats, so string equality is bit equality.
    #[test]
    fn off_tick_is_bitwise_equal_to_step() {
        let mk = |kind: u64, seed: u64| match kind {
            0 => Harvester::markov(HarvesterKind::Rf, 80.0, 0.93, 0.3, 1000.0, seed),
            1 => Harvester::piezo(seed),
            2 => Harvester::solar_diurnal(seed),
            _ => Harvester::markov(HarvesterKind::Solar, 400.0, 0.9, 0.5, 700.0, seed)
                .with_blackouts(BlackoutWindows {
                    period_ms: 1800.0,
                    duration_ms: 400.0,
                    offset_ms: 100.0,
                }),
        };
        for kind in 0u64..4 {
            let mut fast = mk(kind, 7 + kind);
            let mut slow = mk(kind, 7 + kind);
            let mut fast_ticks = 0u64;
            let n = if kind == 0 || kind == 3 { 200_000 } else { 2_000_000 };
            for i in 0..n {
                if fast.off_tick(5.0) {
                    fast_ticks += 1;
                    let p = slow.step(5.0);
                    assert_eq!(p, 0.0, "off_tick applied to a powered tick");
                } else {
                    let pf = fast.step(5.0);
                    let ps = slow.step(5.0);
                    assert_eq!(pf.to_bits(), ps.to_bits(), "tick {i} power diverged");
                }
                if i % 10_000 == 0 {
                    assert_eq!(format!("{fast:?}"), format!("{slow:?}"), "state diverged at {i}");
                }
            }
            assert_eq!(format!("{fast:?}"), format!("{slow:?}"));
            assert!(fast_ticks > 0, "kind {kind}: fast path never engaged");
        }
    }

    #[test]
    fn off_tick_refuses_powered_and_boundary_ticks() {
        let mut h = Harvester::persistent(100.0);
        assert!(!h.off_tick(5.0), "a powered source has no zero-power ticks");
        let mut m = Harvester::markov(HarvesterKind::Rf, 80.0, 0.9, 0.4, 10.0, 3);
        // Walk to an OFF window, then drain it: the boundary tick (which
        // would trigger a state transition inside `step`) is refused.
        while m.is_on() {
            m.step(10.0);
        }
        let mut guard = 0;
        while m.off_tick(4.0) {
            guard += 1;
            assert!(guard < 100, "off_tick ran through a window boundary");
        }
        let before = format!("{m:?}");
        assert!(!m.off_tick(4.0));
        assert_eq!(format!("{m:?}"), before, "a refused off_tick must not advance state");
    }

    /// The predictor + bulk-replay pair must walk the identical state
    /// trajectory as per-tick `off_tick` calls: every hinted tick is one
    /// `off_tick` would accept, and the bulk's post-state is bitwise equal
    /// to taking them one at a time.
    #[test]
    fn off_ticks_hint_and_bulk_replay_match_off_tick_bitwise() {
        let mk = |kind: u64| match kind {
            0 => Harvester::markov(HarvesterKind::Rf, 80.0, 0.93, 0.3, 1000.0, 11),
            1 => Harvester::piezo(11),
            2 => Harvester::solar_diurnal(11),
            _ => Harvester::markov(HarvesterKind::Solar, 400.0, 0.9, 0.5, 700.0, 11),
        };
        for kind in 0u64..4 {
            let mut bulk = mk(kind);
            let mut tick = mk(kind);
            let mut bulked = 0u64;
            for _ in 0..200_000u64 {
                let n = bulk.off_ticks_hint(5.0);
                assert_eq!(n, tick.off_ticks_hint(5.0));
                if n > 0 {
                    bulk.fast_forward_dark(n, 5.0);
                    for i in 0..n {
                        assert!(tick.off_tick(5.0), "hinted tick {i}/{n} refused");
                    }
                    bulked += n;
                    assert_eq!(format!("{bulk:?}"), format!("{tick:?}"), "bulk diverged");
                }
                // Boundary / powered tick: both take the full step path.
                let pb = bulk.step(5.0);
                let pt = tick.step(5.0);
                assert_eq!(pb.to_bits(), pt.to_bits());
            }
            assert_eq!(format!("{bulk:?}"), format!("{tick:?}"));
            assert!(bulked > 0, "kind {kind}: the bulk path never engaged");
            // On a powered source the hint must be zero.
            let h = Harvester::persistent(100.0);
            assert_eq!(h.off_ticks_hint(5.0), 0);
        }
    }

    #[test]
    fn calibration_hits_targets() {
        for &target in &[0.38, 0.51, 0.71] {
            let (_q, achieved) =
                calibrate_markov(HarvesterKind::Rf, 70.0, 0.55, target, 11);
            assert!(
                (achieved - target).abs() < 0.08,
                "target {target} achieved {achieved}"
            );
        }
    }

    #[test]
    fn diurnal_has_long_off_runs() {
        let mut h = Harvester::solar_diurnal(3);
        // Two simulated days at 5-minute windows.
        let t = h.event_trace(2 * 288, 500.0 * 300.0 * 1e-3 * 0.25);
        let on = t.iter().filter(|&&e| e).count();
        // lit ~5 h of 24 h => on-rate well below half
        assert!(on > 0 && (on as f64) < t.len() as f64 * 0.4, "on={on}/{}", t.len());
    }
}
