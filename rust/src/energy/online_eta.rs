//! Online η re-estimation (paper §11.4).
//!
//! The offline η can be wrong in the wild. The paper observes that η is
//! *checkable* at runtime: the system predicts the next slot's energy
//! state (the burst-persistence predictor η licenses) and immediately
//! observes the truth, so the prediction error is measurable; η can then
//! be nudged by ±δη proportional to the error. This module implements
//! that estimator as an exponentially-weighted accuracy tracker whose
//! output converges to the measured next-slot prediction accuracy — the
//! quantity Fig. 25 shows η itself converges to.

#[derive(Clone, Debug)]
pub struct OnlineEta {
    /// Current estimate, seeded from the offline study.
    pub eta: f64,
    /// Adaptation gain (δη per unit of prediction error).
    pub gain: f64,
    /// EWMA window for the measured accuracy.
    pub alpha: f64,
    acc_ewma: f64,
    last_state: Option<bool>,
    pub observations: u64,
}

impl OnlineEta {
    pub fn new(offline_eta: f64) -> Self {
        OnlineEta {
            eta: offline_eta,
            gain: 0.1,
            alpha: 0.02,
            acc_ewma: offline_eta,
            last_state: None,
            observations: 0,
        }
    }

    /// Feed one energy-event observation (the ΔT-window state). The
    /// persistence predictor forecasts state_t = state_{t-1}; its hit
    /// rate is tracked and η is pulled toward it.
    pub fn observe(&mut self, state: bool) {
        if let Some(prev) = self.last_state {
            let hit = (prev == state) as u8 as f64;
            self.acc_ewma = (1.0 - self.alpha) * self.acc_ewma + self.alpha * hit;
            let err = self.acc_ewma - self.eta;
            self.eta = (self.eta + self.gain * err).clamp(0.0, 1.0);
            self.observations += 1;
        }
        self.last_state = Some(state);
    }

    /// Measured next-slot prediction accuracy (EWMA).
    pub fn measured_accuracy(&self) -> f64 {
        self.acc_ewma
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn feed_markov(est: &mut OnlineEta, q: f64, n: usize, seed: u64) {
        let mut rng = Pcg32::seeded(seed);
        let mut s = true;
        for _ in 0..n {
            if !rng.chance(q) {
                s = !s;
            }
            est.observe(s);
        }
    }

    #[test]
    fn converges_up_from_bad_seed() {
        // Offline said 0.3 but the deployment is strongly bursty (q=0.95:
        // persistence accuracy 0.95). The estimate must climb.
        let mut est = OnlineEta::new(0.3);
        feed_markov(&mut est, 0.95, 20_000, 1);
        assert!(est.eta > 0.85, "eta={}", est.eta);
        assert!((est.measured_accuracy() - 0.95).abs() < 0.05);
    }

    #[test]
    fn converges_down_from_optimistic_seed() {
        // Offline said 0.9 but the field source is memoryless (accuracy
        // ~0.5): the estimate must fall toward 0.5.
        let mut est = OnlineEta::new(0.9);
        feed_markov(&mut est, 0.5, 20_000, 2);
        assert!(est.eta < 0.6, "eta={}", est.eta);
    }

    #[test]
    fn accurate_seed_stays_put() {
        let mut est = OnlineEta::new(0.9);
        feed_markov(&mut est, 0.9, 20_000, 3);
        assert!((est.eta - 0.9).abs() < 0.07, "eta={}", est.eta);
    }

    #[test]
    fn bounded_in_unit_interval() {
        let mut est = OnlineEta::new(1.0);
        feed_markov(&mut est, 0.5, 5000, 4);
        assert!((0.0..=1.0).contains(&est.eta));
        let mut est0 = OnlineEta::new(0.0);
        feed_markov(&mut est0, 0.99, 5000, 5);
        assert!((0.0..=1.0).contains(&est0.eta));
    }
}
