//! Intermittent-energy modeling (paper §3): energy events, the conditional
//! event distribution h(N), the Kantorovich–Wasserstein distance to an
//! ideal source, and the single-parameter η-factor; plus the harvester
//! process models, the supercapacitor, and the runtime energy manager.

pub mod capacitor;
pub mod events;
pub mod harvester;
pub mod manager;
pub mod online_eta;

pub use capacitor::Capacitor;
pub use events::{conditional_event_dist, eta_factor, EtaEstimate};
pub use harvester::{calibrate_markov, Harvester, HarvesterKind};
pub use manager::EnergyManager;

/// Conservative crossing predictor shared by the event-driven engine
/// core's analytic budgets: the number of `step_ms` decrements a counter
/// that starts `span_ms` away from its limit can take while provably
/// staying strictly on the near side.
///
/// The true crossing tick of a *sequentially accumulated* f64 counter
/// (`x -= step` / `x += step` per tick, never a closed-form multiply)
/// differs from the algebraic `floor(span/step)` by at most the
/// accumulated rounding drift — vanishingly below one 5 ms step for any
/// realistic span — so two steps of slack make the bound safe: a
/// fast-forward loop consuming at most this many ticks cannot cross the
/// limit, and the exact per-tick tail walks the remaining margin. Being
/// *under* the true count only costs a few extra tail compares, never
/// correctness. Infinite spans saturate (`as u64` clamps), NaN yields 0.
pub fn conservative_ticks(span_ms: f64, step_ms: f64) -> u64 {
    debug_assert!(step_ms > 0.0);
    if !(span_ms > 0.0) {
        return 0;
    }
    let n = (span_ms / step_ms).floor() - 2.0;
    if n > 0.0 {
        n as u64
    } else {
        0
    }
}
