//! Intermittent-energy modeling (paper §3): energy events, the conditional
//! event distribution h(N), the Kantorovich–Wasserstein distance to an
//! ideal source, and the single-parameter η-factor; plus the harvester
//! process models, the supercapacitor, and the runtime energy manager.

pub mod capacitor;
pub mod events;
pub mod harvester;
pub mod manager;
pub mod online_eta;

pub use capacitor::Capacitor;
pub use events::{conditional_event_dist, eta_factor, EtaEstimate};
pub use harvester::{calibrate_markov, Harvester, HarvesterKind};
pub use manager::EnergyManager;
