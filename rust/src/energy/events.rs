//! Energy events and the η-factor (paper §3.1–3.3).
//!
//! An *energy event* H_t ∈ {0,1} marks whether the harvester delivered at
//! least ΔK joules during the t-th ΔT window. The conditional event
//! probability (Eq. 1) is
//!
//! ```text
//! h(N) = P(H_t = 1 | previous N windows were all 1)   for N > 0
//! h(N) = P(H_t = 1 | previous |N| windows were all 0)  for N < 0
//! ```
//!
//! and the η-factor (Eq. 3) normalizes the KW distance between the
//! harvester's *state-persistence* distribution and an ideal (persistent)
//! source by the distance of a purely random (shuffled-trace) source:
//!
//! ```text
//! η = 1 − KW(H, P) / KW(R, P),  clamped to [0, 1].
//! ```
//!
//! We build the distributions over the persistence probability
//! p(N) = h(N) for N > 0 and 1 − h(N) for N < 0 ("the current state
//! continues"), which puts the ideal source at a point mass on 1 and makes
//! the random baseline exactly the same trace with its temporal structure
//! destroyed — the paper's normalization, computable from data alone.

use crate::util::rng::Pcg32;
use crate::util::stats;

/// h(N) over N ∈ [-max_n, -1] ∪ [1, max_n]; entries with no supporting
/// instances are omitted (the paper notes not all h(N) have equal support,
/// which is why η normalizes by the random baseline).
///
/// Single run-length-encoding pass, O(n + max_n) for ALL N at once
/// (§Perf iteration 2: the naive per-N scan is O(n·max_n²) and dominated
/// harvester calibration). Each position t whose preceding run of equal
/// values has length ℓ supports the conditions N = 1..=min(ℓ, max_n);
/// difference arrays turn that range update into O(1).
pub fn conditional_event_dist(trace: &[bool], max_n: usize) -> Vec<(i32, f64)> {
    if trace.len() < 2 {
        return Vec::new();
    }
    // diff arrays, index 1..=max_n (+1 slack for the range end).
    let mut tot_pos = vec![0i64; max_n + 2];
    let mut hit_pos = vec![0i64; max_n + 2];
    let mut tot_neg = vec![0i64; max_n + 2];
    let mut hit_neg = vec![0i64; max_n + 2];
    let mut run_val = trace[0];
    let mut run_len = 1usize;
    for t in 1..trace.len() {
        let hi = run_len.min(max_n);
        let (tot, hit) = if run_val {
            (&mut tot_pos, &mut hit_pos)
        } else {
            (&mut tot_neg, &mut hit_neg)
        };
        tot[1] += 1;
        tot[hi + 1] -= 1;
        if trace[t] {
            hit[1] += 1;
            hit[hi + 1] -= 1;
        }
        if trace[t] == run_val {
            run_len += 1;
        } else {
            run_val = trace[t];
            run_len = 1;
        }
    }
    let prefix = |d: &[i64]| {
        let mut acc = 0i64;
        d[1..=max_n].iter().map(move |&x| { // cumulative over N
            acc += x;
            acc
        }).collect::<Vec<i64>>()
    };
    let (tp, hp, tn, hn) = (prefix(&tot_pos), prefix(&hit_pos), prefix(&tot_neg), prefix(&hit_neg));
    let mut out = Vec::new();
    for n in (1..=max_n).rev() {
        if tn[n - 1] > 0 {
            out.push((-(n as i32), hn[n - 1] as f64 / tn[n - 1] as f64));
        }
    }
    for n in 1..=max_n {
        if tp[n - 1] > 0 {
            out.push((n as i32, hp[n - 1] as f64 / tp[n - 1] as f64));
        }
    }
    out
}

/// Single h(N) estimate; None if the condition never occurs.
pub fn h_of(trace: &[bool], n: i32) -> Option<f64> {
    let run = n.unsigned_abs() as usize;
    let want = n > 0;
    let (mut hits, mut total) = (0u64, 0u64);
    for t in run..trace.len() {
        if trace[t - run..t].iter().all(|&e| e == want) {
            total += 1;
            hits += trace[t] as u64;
        }
    }
    (total > 0).then(|| hits as f64 / total as f64)
}

#[derive(Clone, Debug)]
pub struct EtaEstimate {
    pub eta: f64,
    pub kw_harvester: f64,
    pub kw_random: f64,
    /// Marginal event rate of the trace.
    pub event_rate: f64,
}

/// Persistence values p(N): probability the current state continues.
fn persistence_values(trace: &[bool], max_n: usize) -> Vec<f64> {
    conditional_event_dist(trace, max_n)
        .into_iter()
        .map(|(n, h)| if n > 0 { h } else { 1.0 - h })
        .collect()
}

const BINS: usize = 50;

fn dist_of(vals: &[f64]) -> Vec<f64> {
    let h = stats::histogram(vals, 0.0, 1.0 + 1e-9, BINS);
    let total: u64 = h.iter().sum();
    h.into_iter().map(|c| c as f64 / total.max(1) as f64).collect()
}

/// Estimate the η-factor of an energy-event trace (Eq. 3).
pub fn eta_factor(trace: &[bool], max_n: usize, seed: u64) -> EtaEstimate {
    let event_rate = trace.iter().filter(|&&e| e).count() as f64 / trace.len().max(1) as f64;
    let support: Vec<f64> = (0..BINS).map(|i| (i as f64 + 0.5) / BINS as f64).collect();

    // Ideal persistent source: all persistence mass at 1.0.
    let mut ideal = vec![0.0; BINS];
    ideal[BINS - 1] = 1.0;

    let pv = persistence_values(trace, max_n);
    if pv.is_empty() {
        return EtaEstimate { eta: 1.0, kw_harvester: 0.0, kw_random: 0.0, event_rate };
    }
    let kw_h = stats::kw_distance(&support, &dist_of(&pv), &ideal);

    // Random baseline: same marginal, shuffled (destroys burstiness).
    let mut rng = Pcg32::seeded(seed);
    let mut shuffled = trace.to_vec();
    rng.shuffle(&mut shuffled);
    let rv = persistence_values(&shuffled, max_n);
    let kw_r = if rv.is_empty() {
        1.0
    } else {
        stats::kw_distance(&support, &dist_of(&rv), &ideal)
    };

    let eta = if kw_r <= 1e-12 { 1.0 } else { (1.0 - kw_h / kw_r).clamp(0.0, 1.0) };
    EtaEstimate { eta, kw_harvester: kw_h, kw_random: kw_r, event_rate }
}

/// Expected power-outage duration in events, E[C_e] = η/(1−η) (paper §5.3,
/// geometric persistence).
pub fn expected_outage_events(eta: f64) -> f64 {
    if eta >= 1.0 {
        0.0
    } else {
        eta / (1.0 - eta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn markov_trace(q: f64, n: usize, seed: u64) -> Vec<bool> {
        let mut rng = Pcg32::seeded(seed);
        let mut state = true;
        (0..n)
            .map(|_| {
                if !rng.chance(q) {
                    state = !state;
                }
                state
            })
            .collect()
    }

    #[test]
    fn h_of_periodic_trace() {
        // 1,0,1,0,... : after one 1 always comes 0 -> h(1) = 0;
        // after one 0 always comes 1 -> h(-1) = 1.
        let t: Vec<bool> = (0..1000).map(|i| i % 2 == 0).collect();
        assert_eq!(h_of(&t, 1), Some(0.0));
        assert_eq!(h_of(&t, -1), Some(1.0));
        // runs of length 2 never occur
        assert_eq!(h_of(&t, 2), None);
    }

    #[test]
    fn persistent_source_has_eta_one() {
        let t = vec![true; 5000];
        let e = eta_factor(&t, 20, 0);
        assert!(e.eta > 0.99, "eta={}", e.eta);
    }

    #[test]
    fn random_source_has_eta_near_zero() {
        let mut rng = Pcg32::seeded(9);
        let t: Vec<bool> = (0..20_000).map(|_| rng.chance(0.5)).collect();
        let e = eta_factor(&t, 20, 0);
        assert!(e.eta < 0.15, "eta={}", e.eta);
    }

    #[test]
    fn eta_monotone_in_burstiness() {
        let weak = eta_factor(&markov_trace(0.6, 30_000, 1), 20, 0).eta;
        let mid = eta_factor(&markov_trace(0.8, 30_000, 1), 20, 0).eta;
        let strong = eta_factor(&markov_trace(0.95, 30_000, 1), 20, 0).eta;
        assert!(weak < mid && mid < strong, "{weak} {mid} {strong}");
    }

    #[test]
    fn h_declines_with_n_for_bounded_bursts() {
        // Bursts capped at 20: h(N) must collapse past the cap (the paper's
        // "person never walked more than 100 minutes" observation, Fig. 4b).
        let mut t = Vec::new();
        let mut rng = Pcg32::seeded(3);
        while t.len() < 40_000 {
            let on = 5 + rng.below(16) as usize; // 5..=20
            let off = 5 + rng.below(30) as usize;
            t.extend(std::iter::repeat(true).take(on));
            t.extend(std::iter::repeat(false).take(off));
        }
        let h5 = h_of(&t, 5).unwrap();
        let h20 = h_of(&t, 20).unwrap_or(0.0);
        assert!(h5 > h20, "h(5)={h5} h(20)={h20}");
    }

    #[test]
    fn rle_dist_matches_naive_h_of() {
        // The O(n + N) RLE estimator must agree exactly with the
        // direct-definition h_of at every N, on several trace shapes.
        for (seed, style) in [(1u64, 0u8), (2, 1), (3, 2)] {
            let mut rng = Pcg32::seeded(seed);
            let mut state = true;
            let trace: Vec<bool> = (0..3000)
                .map(|i| match style {
                    0 => rng.chance(0.5),
                    1 => {
                        if !rng.chance(0.9) {
                            state = !state;
                        }
                        state
                    }
                    _ => i % 7 < 3,
                })
                .collect();
            let dist = conditional_event_dist(&trace, 12);
            for &(n, h) in &dist {
                let want = h_of(&trace, n).unwrap();
                assert!(
                    (h - want).abs() < 1e-12,
                    "style {style} N={n}: rle {h} vs naive {want}"
                );
            }
            // and every N the naive version defines appears in the dist
            for n in 1..=12i32 {
                for sign in [1, -1] {
                    let nn = n * sign;
                    assert_eq!(
                        h_of(&trace, nn).is_some(),
                        dist.iter().any(|&(m, _)| m == nn),
                        "style {style} N={nn} presence mismatch"
                    );
                }
            }
        }
    }

    #[test]
    fn expected_outage_matches_geometric() {
        assert_eq!(expected_outage_events(0.0), 0.0);
        assert!((expected_outage_events(0.5) - 1.0).abs() < 1e-12);
        assert!((expected_outage_events(0.75) - 3.0).abs() < 1e-12);
    }
}
